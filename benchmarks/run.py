"""Benchmark entry point: one module per paper table/figure + the
dry-run roofline summary. Prints ``name,us_per_call,derived`` CSV.

  PYTHONPATH=src python -m benchmarks.run            # everything
  PYTHONPATH=src python -m benchmarks.run --fast     # skip the search
"""
from __future__ import annotations

import argparse
import csv
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="skip the RL search benchmark (slowest)")
    args = ap.parse_args()

    from benchmarks import (
        compiler_bench,
        paper_fig5,
        paper_fig7,
        paper_table45,
        tpu_hetero,
    )
    modules = [paper_fig5, paper_fig7, paper_table45, tpu_hetero,
               compiler_bench]
    if not args.fast:
        from benchmarks import paper_fig9_12, paper_table3
        modules.append(paper_table3)
        modules.append(paper_fig9_12)
    # roofline rows only exist after a dry-run sweep has been captured
    try:
        from benchmarks import roofline
        modules.append(roofline)
    except Exception:                                  # pragma: no cover
        pass

    out = csv.writer(sys.stdout)
    out.writerow(["name", "us_per_call", "derived"])
    failures = 0
    for mod in modules:
        try:
            for row in mod.main():
                out.writerow(row)
                sys.stdout.flush()
        except Exception:                              # noqa: BLE001
            failures += 1
            traceback.print_exc()
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
