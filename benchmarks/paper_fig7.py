"""Fig. 7 reproduction: latency vs workload-split ratio.

The paper plots the 14th ResNet-18 layer under the manual 4-bit config
and finds the optimum at ratio = 0.75 (192 of 256 filters on the
LUT-core). We sweep the ratio with the same layer and report the
curve's optimum; the interior optimum (strictly better than either
pure core) is the existence proof for the whole heterogeneous idea.
"""
from __future__ import annotations

import time

from repro.core.scheduler import XC7Z020, DspCoreConfig, LutCoreConfig
from repro.core.split import solve_split
from repro.core.workloads import resnet18_specs


def run() -> dict:
    specs = resnet18_specs()
    layer = specs[13]                    # the paper's "14-th layer"
    lut = LutCoreConfig(m=8, n=16, k=128, d_a=1024)
    dsp = DspCoreConfig(n_reg_row_a=DspCoreConfig.rows_for_device(XC7Z020),
                        d_a=2048, d_w=1024)
    t0 = time.time()
    sol = solve_split(layer, lut, dsp, XC7Z020, bits_w_lut=4, bits_a=4,
                      keep_curve=True)
    wall = time.time() - t0
    curve = sol.curve
    all_dsp = float(curve[0])
    all_lut = float(curve[-1])
    return {
        "layer": layer.name,
        "c_out": layer.gemm().n,
        "best_ratio": sol.ratio,
        "best_n_lut": sol.n_lut,
        "best_ms": XC7Z020.cycles_to_ms(sol.cycles),
        "all_dsp_ms": XC7Z020.cycles_to_ms(all_dsp),
        "all_lut_ms": XC7Z020.cycles_to_ms(all_lut),
        "speedup_vs_dsp": all_dsp / sol.cycles,
        "speedup_vs_lut": all_lut / sol.cycles,
        "wall_s": wall,
    }


def main() -> list[tuple[str, float, str]]:
    r = run()
    derived = (f"ratio*={r['best_ratio']:.2f} (paper: 0.75) "
               f"n_lut={r['best_n_lut']}/{r['c_out']} "
               f"best={r['best_ms']:.2f}ms "
               f"vs all-DSP {r['all_dsp_ms']:.2f}ms "
               f"vs all-LUT {r['all_lut_ms']:.2f}ms")
    return [("paper_fig7.split_curve", 1e6 * r["wall_s"], derived)]


if __name__ == "__main__":
    for row in main():
        print(",".join(map(str, row)))
