"""Compiler benchmark: compile wall-time, instruction counts, bytes
moved, DDR footprint — plus the optimization-pass pipeline and executor
backends:

  * per network: -O0 vs -O1 instruction counts with per-pass deltas
    (weight-prefetch / sync-elision / dma-fusion) and, for the
    simulation subset, simulated-latency deltas from
    ``simulate_program`` (optimized streams are what gets timed);
  * one registry LM smoke program executed functionally on both
    backends: golden interpreter vs batched Pallas fast path, wall
    clock + speedup + a bit-exactness flag;
  * ``kernels.fused.*`` rows: the fused one-launch-per-layer path vs
    the per-partition batched path (launch counts, removed
    ``L{i}.col`` DDR staging, wall-clock speedup, bit-exactness) with
    a hard fused-must-not-be-slower regression guard;
  * whole-CNN inference rows: resnet18 and mobilenet_v2 executed end
    to end through the spatial im2col chain (depthwise grouped GEMMs
    included) on the pallas backend, with a golden bit-exactness
    cross-check on the reduced smoke variant;
  * multi-device scaling: the same LM compiled under 1 -> 2 -> 4-device
    pipeline and filter plans, with the cross-device makespan (link
    latency included) and speedup vs one device for a batched input
    stream.

Covers both CNN workloads and a slice of the LM registry, so compile
cost is tracked for every frontend family. Each row's ``derived`` field
carries a ``BENCH`` JSON blob with the program-level metrics the
roadmap cares about. ``--smoke`` restricts to a fast subset for CI.
"""
from __future__ import annotations

import argparse
import csv
import json
import sys
import time

import numpy as np

from repro.compiler import (
    GoldenExecutor,
    PallasExecutor,
    bind_synthetic,
    compile_network,
    optimize_program,
    to_binary,
)
from repro.core.scheduler import simulate_program

NETWORKS = [
    ("resnet18", {}),
    ("mobilenet_v2", {}),
    ("llama3.2-1b", {"seq_len": 64}),
    ("qwen3-moe-235b-a22b", {"seq_len": 64}),
    ("mamba2-780m", {"seq_len": 64}),
]
SMOKE_NETWORKS = [
    ("llama3.2-1b", {"seq_len": 16}),
    ("mamba2-780m", {"seq_len": 16}),
]
#: networks whose -O0/-O1 simulated latency is reported (simulation of
#: the big CNN im2col programs is minutes-long; instruction deltas are
#: still reported for every network)
SIMULATE = {"llama3.2-1b", "qwen3-moe-235b-a22b", "mamba2-780m"}

#: the registry LM smoke program used for the backend-speedup row
EXEC_NETWORK = "llama3.2-1b"


def bench_network(name: str, kw: dict) -> tuple[str, float, str]:
    t0 = time.time()
    prog = compile_network(name, **kw)
    compile_s = time.time() - t0
    t1 = time.time()
    opt = optimize_program(prog, 1, validate=False)
    opt_s = time.time() - t1
    t2 = time.time()
    image = to_binary(opt)
    pack_s = time.time() - t2
    s = prog.stats()
    bench = {
        "BENCH": "compiler",
        "network": name,
        "layers": len(prog.layers),
        "instructions": s.n_instructions,
        "instructions_o1": opt.n_instructions,
        "passes": [{
            "name": ps.name,
            "instrs_before": ps.instrs_before,
            "instrs_after": ps.instrs_after,
            **ps.detail,
        } for ps in opt.opt_stats],
        "by_opcode": s.by_opcode,
        "image_bytes": len(image),
        "ddr_footprint_bytes": s.ddr_footprint,
        "mb_fetched": round(s.bytes_fetched / 1e6, 3),
        "mb_written": round(s.bytes_written / 1e6, 3),
        "compile_s": round(compile_s, 4),
        "opt_s": round(opt_s, 4),
        "pack_s": round(pack_s, 4),
        "instrs_per_s": int(s.n_instructions / max(compile_s, 1e-9)),
    }
    if name in SIMULATE:
        t3 = time.time()
        c0 = simulate_program(prog).total_cycles
        c1 = simulate_program(opt).total_cycles
        bench.update({
            "sim_cycles_o0": c0,
            "sim_cycles_o1": c1,
            "sim_latency_gain_pct": round(100.0 * (c0 - c1) / max(c0, 1), 3),
            "sim_s": round(time.time() - t3, 4),
        })
    return (f"compiler.{name}", 1e6 * compile_s,
            json.dumps(bench, sort_keys=True))


def bench_backends(seq_len: int = 64) -> tuple[str, float, str]:
    """Golden interpreter vs batched Pallas fast path on one registry
    LM smoke program: wall clock per full program execution, bit-exact
    cross-check, speedup."""
    prog = compile_network(EXEC_NETWORK, seq_len=seq_len, opt_level=1)
    golden = GoldenExecutor(prog)
    pallas = PallasExecutor(prog)
    acts = {}
    for lp in prog.layers:
        bind_synthetic(golden, lp)
        bind_synthetic(pallas, lp)
        acts[lp.index] = np.random.default_rng(1000 + lp.index).integers(
            -8, 8, (lp.dims.m, lp.dims.k)).astype(np.int8)

    # warm the fast path once (jit/trace), then time both
    for lp in prog.layers:
        pallas.run_layer(lp.index, acts[lp.index])
    t0 = time.time()
    outs_g = {lp.index: np.asarray(golden.run_layer(lp.index,
                                                    acts[lp.index]))
              for lp in prog.layers}
    golden_s = time.time() - t0
    t1 = time.time()
    outs_p = {lp.index: np.asarray(pallas.run_layer(lp.index,
                                                    acts[lp.index]))
              for lp in prog.layers}
    pallas_s = time.time() - t1
    bit_exact = all((outs_g[i] == outs_p[i]).all() for i in outs_g)
    bench = {
        "BENCH": "compiler.backends",
        "network": EXEC_NETWORK,
        "seq_len": seq_len,
        "layers": len(prog.layers),
        "golden_s": round(golden_s, 4),
        "pallas_s": round(pallas_s, 4),
        "speedup_x": round(golden_s / max(pallas_s, 1e-9), 1),
        "bit_exact": bool(bit_exact),
    }
    return (f"compiler.backends.{EXEC_NETWORK}", 1e6 * pallas_s,
            json.dumps(bench, sort_keys=True))


def bench_cnn_execute(arch: str, smoke: bool = False
                      ) -> tuple[str, float, str]:
    """Whole-CNN inference through the compiled program: a synthetic
    quantized image chained end to end (im2col staging, depthwise
    grouped GEMMs, pool glue, shortcut sources, inter-layer requant).

    Full mode runs the full-size 224 network on the pallas backend;
    ``--smoke`` runs the reduced geometry-consistent variant and also
    cross-checks golden-vs-pallas bit-exactness.
    """
    kw = {"in_hw": 28, "width": 0.25} if smoke else {}
    prog = compile_network(arch, opt_level=1, **kw)
    geo0 = prog.layers[0].geometry
    rng = np.random.default_rng(0)
    x_q = rng.integers(-8, 8, geo0.in_shape).astype(np.int8)

    pallas = PallasExecutor(prog)
    for lp in prog.layers:
        bind_synthetic(pallas, lp, seed=lp.index)
    pallas.run(x_q)                       # warm the jit tables
    t0 = time.time()
    out_p = np.asarray(pallas.run(x_q))
    pallas_s = time.time() - t0

    bench = {
        "BENCH": "compiler.cnn_execute",
        "network": arch,
        "in_hw": geo0.in_hw,
        "layers": len(prog.layers),
        "depthwise_layers": sum(lp.depthwise for lp in prog.layers),
        "logits": list(out_p.shape),
        "abs_sum": float(np.abs(out_p).sum()),
        "pallas_s": round(pallas_s, 4),
    }
    if smoke:
        golden = GoldenExecutor(prog)
        for lp in prog.layers:
            bind_synthetic(golden, lp, seed=lp.index)
        t1 = time.time()
        out_g = np.asarray(golden.run(x_q))
        bench["golden_s"] = round(time.time() - t1, 4)
        bench["bit_exact"] = bool((out_g == out_p).all())
    return (f"compiler.cnn_execute.{arch}", 1e6 * pallas_s,
            json.dumps(bench, sort_keys=True))


def bench_multi_device(seq_len: int = 64,
                       batches: int = 8) -> tuple[str, float, str]:
    """1 -> 2 -> 4-device scaling of one registry LM program: simulated
    cross-device makespan (plan link latency included) for a stream of
    ``batches`` inputs, vs the single-device baseline."""
    t0 = time.time()
    prog = compile_network(EXEC_NETWORK, seq_len=seq_len, opt_level=1)
    base = simulate_program(prog).total_cycles * batches
    bench = {
        "BENCH": "compiler.multi_device",
        "network": EXEC_NETWORK,
        "seq_len": seq_len,
        "batches": batches,
        "makespan_1dev": base,
        "plans": {},
    }
    for kind in ("pipeline", "filter"):
        for n_dev in (2, 4):
            bundle = compile_network(EXEC_NETWORK, seq_len=seq_len,
                                     opt_level=1, devices=n_dev,
                                     partition=kind)
            bs = simulate_program(bundle, batches=batches)
            bench["plans"][f"{kind}_x{n_dev}"] = {
                "makespan": bs.total_cycles,
                "latency": bs.latency_cycles,
                "interval": bs.interval_cycles,
                "speedup_x": round(base / max(bs.total_cycles, 1), 3),
                "instructions": bundle.n_instructions,
                "link_bytes": sum(e.nbytes for e in bundle.edges),
            }
    bench["pipeline_x2_beats_1dev"] = \
        bench["plans"]["pipeline_x2"]["makespan"] < base
    wall = time.time() - t0
    return (f"compiler.multi_device.{EXEC_NETWORK}", 1e6 * wall,
            json.dumps(bench, sort_keys=True))


def bench_obs_overhead(seq_len: int = 16,
                       repeats: int = 7) -> tuple[str, float, str]:
    """``obs.overhead.*`` row: tracer-on vs tracer-off simulation wall
    time on one registry LM program.

    The observability contract says tracing is free when off (the
    ``trace is None`` fast path in ``scheduler.simulate``) and cheap
    when on (lazy replay — nothing per instruction); this row pins
    both — enabled overhead must stay under 15%. Off/on reps are
    interleaved and min-of-N timed, so a load ramp on a shared CI
    runner hits both sides alike instead of flaking the ratio.
    """
    from repro.obs import Tracer
    prog = compile_network(EXEC_NETWORK, seq_len=seq_len, opt_level=1)
    simulate_program(prog)              # warm imports/caches
    simulate_program(prog, tracer=Tracer())

    off_times, on_times, tracers = [], [], []
    for _ in range(repeats):
        t0 = time.perf_counter()
        simulate_program(prog)
        off_times.append(time.perf_counter() - t0)
        tr = Tracer()
        t0 = time.perf_counter()
        simulate_program(prog, tracer=tr)
        on_times.append(time.perf_counter() - t0)
        tracers.append(tr)

    off_s, on_s = min(off_times), min(on_times)
    overhead_pct = 100.0 * (on_s - off_s) / max(off_s, 1e-9)
    n_spans = len(tracers[-1].to_chrome()["traceEvents"])
    closure_ok = not tracers[-1].counters.closure_errors()
    assert overhead_pct < 15.0, \
        f"tracer-on simulation overhead {overhead_pct:.1f}% >= 15%"
    assert closure_ok, "traced simulation failed cycle-accounting closure"
    bench = {
        "BENCH": "obs.overhead",
        "network": EXEC_NETWORK,
        "seq_len": seq_len,
        "sim_off_s": round(off_s, 5),
        "sim_on_s": round(on_s, 5),
        "overhead_pct": round(overhead_pct, 2),
        "trace_events": n_spans,
        "closure_ok": closure_ok,
    }
    return (f"obs.overhead.{EXEC_NETWORK}", 1e6 * on_s,
            json.dumps(bench, sort_keys=True))


def bench_fused_kernels(smoke: bool = False) -> list[tuple[str, float, str]]:
    """``kernels.fused.*`` rows: the one-launch-per-layer fused path vs
    the per-partition batched path (``PallasExecutor(fused=False)``) —
    wall clock, launch counts, the DDR traffic the removed ``L{i}.col``
    staging would have cost, and a bit-exactness flag.

    Regression guard: fused must never be slower than the per-partition
    path on the ``llama3.2-1b`` program (hard assert), and must keep a
    wall-clock win on the conv e2e program. Fused/split reps are
    interleaved and min-of-N timed so a load ramp on a shared CI runner
    hits both sides alike.
    """
    import math

    def _measure(name, prog, drive, repeats=5):
        fused = PallasExecutor(prog)
        split = PallasExecutor(prog, fused=False)
        for lp in prog.layers:
            bind_synthetic(fused, lp, seed=lp.index)
            bind_synthetic(split, lp, seed=lp.index)
        out_f = drive(fused)
        out_s = drive(split)                   # also warms the jit tables
        f_times, s_times = [], []
        for _ in range(repeats):
            t0 = time.perf_counter()
            drive(fused)
            f_times.append(time.perf_counter() - t0)
            t0 = time.perf_counter()
            drive(split)
            s_times.append(time.perf_counter() - t0)
        fused_s, split_s = min(f_times), min(s_times)
        launches_split = sum((lp.lut is not None) + (lp.dsp is not None)
                             for lp in prog.layers)
        col_bytes = sum(
            math.ceil(lp.dims.m * lp.dims.k
                      * (lp.dims.n if lp.depthwise else 1) * lp.bits_a / 8)
            for lp in prog.layers if lp.geometry is not None)
        bench = {
            "BENCH": "kernels.fused",
            "network": name,
            "layers": len(prog.layers),
            "launches_fused": len(prog.layers),
            "launches_split": launches_split,
            "col_staging_bytes_removed": col_bytes,
            "fused_s": round(fused_s, 5),
            "split_s": round(split_s, 5),
            "speedup_x": round(split_s / max(fused_s, 1e-9), 2),
            "bit_exact": bool((out_f == out_s).all()),
        }
        return (f"kernels.fused.{name}", 1e6 * fused_s,
                json.dumps(bench, sort_keys=True)), fused_s, split_s

    rows = []
    # per-layer LM drive (the registry smoke program) — the guard case
    prog = compile_network(EXEC_NETWORK, seq_len=16 if smoke else 64,
                           opt_level=1)
    acts = {lp.index: np.random.default_rng(1000 + lp.index).integers(
        -8, 8, (lp.dims.m, lp.dims.k)).astype(np.int8)
        for lp in prog.layers}

    def drive_lm(ex):
        return np.concatenate(
            [np.asarray(ex.run_layer(i, acts[i])).ravel() for i in acts])

    row, fused_s, split_s = _measure(EXEC_NETWORK, prog, drive_lm)
    assert fused_s <= split_s, \
        (f"fused path regressed: {fused_s:.4f}s vs per-partition "
         f"{split_s:.4f}s on {EXEC_NETWORK}")
    rows.append(row)

    # conv e2e drive (in-kernel im2col, no L{i}.col staging)
    kw = {"in_hw": 28, "width": 0.25} if smoke else {}
    cprog = compile_network("resnet18", opt_level=1, **kw)
    x_q = np.random.default_rng(0).integers(
        -8, 8, cprog.layers[0].geometry.in_shape).astype(np.int8)
    row, fused_s, split_s = _measure(
        "resnet18_e2e", cprog, lambda ex: np.asarray(ex.run(x_q)))
    assert fused_s <= split_s, \
        (f"fused conv path regressed: {fused_s:.4f}s vs per-partition "
         f"{split_s:.4f}s on resnet18 e2e")
    rows.append(row)
    return rows


def bench_dse_sim_gap(smoke: bool = False) -> list[tuple[str, float, str]]:
    """``dse.sim_gap.*`` rows: the analytical latency model the DSE
    explores with vs ``simulate_program`` on the compiled ``-O1``
    program, for the registry LMs this bench already tracks — the gap
    the two-tier search loop (docs/dse.md) corrects inside the loop,
    with the documented tolerance flagged per row."""
    from repro.dse.evaluator import sim_gap_report
    nets = ["llama3.2-1b"] if smoke else ["llama3.2-1b", "mamba2-780m"]
    rows = []
    for net in nets:
        t0 = time.time()
        rep = sim_gap_report(net, seq_len=16 if smoke else 64)
        rows.append((f"dse.sim_gap.{net}", 1e6 * (time.time() - t0),
                     json.dumps(rep, sort_keys=True)))
    return rows


def bench_gather_overlap(smoke: bool = False) -> tuple[str, float, str]:
    """``compiler.gather_overlap`` row: filter-mode gather DMAs issued
    at the *producing* layer's fetch tail (riding under its compute)
    vs serialized at the consuming layer's head — the simulated
    filter-parallel makespan delta on a registry LM under a 2-device
    plan. Hard guard: the overlapped placement must not be slower."""
    from repro.compiler import derive_plan, lower_partitioned
    from repro.compiler.networks import network_layers
    from repro.core.scheduler import (DspCoreConfig, LutCoreConfig,
                                      XC7Z020)
    t0 = time.time()
    seq = 16 if smoke else 64
    layers = network_layers(EXEC_NETWORK, seq_len=seq)
    lut = LutCoreConfig(m=8, n=16, k=128)
    dsp = DspCoreConfig(
        n_reg_row_a=DspCoreConfig.rows_for_device(XC7Z020))
    plan = derive_plan(layers, 2, kind="filter")
    kw = dict(bits_w_lut=4, bits_a=4, opt_level=1)
    over = lower_partitioned(EXEC_NETWORK, layers, plan, lut, dsp,
                             XC7Z020, **kw)
    serial = lower_partitioned(EXEC_NETWORK, layers, plan, lut, dsp,
                               XC7Z020, gather_overlap=False, **kw)
    c_over = simulate_program(over).latency_cycles
    c_serial = simulate_program(serial).latency_cycles
    assert c_over < c_serial, \
        (f"filter gather overlap regressed: {c_over} cycles vs "
         f"{c_serial} serialized on {EXEC_NETWORK}")
    bench = {
        "BENCH": "compiler.gather_overlap",
        "network": EXEC_NETWORK,
        "seq_len": seq,
        "devices": 2,
        "latency_overlap": c_over,
        "latency_serialized": c_serial,
        "gain_pct": round(100.0 * (c_serial - c_over)
                          / max(c_serial, 1), 3),
    }
    return (f"compiler.gather_overlap.{EXEC_NETWORK}",
            1e6 * (time.time() - t0), json.dumps(bench, sort_keys=True))


def bench_serve_decode(smoke: bool = False) -> list[tuple[str, float, str]]:
    """``serve.decode.*`` rows: decode-resident step programs vs
    naively re-running the whole fixed-sequence program per generated
    token.

    Per network: the simulator's warm-up vs steady-state cycles/token
    (``DecodeSim`` — weight fetches elided after warm-up, KV/state
    segments persistent), the fixed-seq full re-invocation baseline,
    and host tokens/sec through a live ``ExecutorSession`` on the
    pallas backend (bind once, then ``step(token, pos)`` against the
    resident image). Hard regression guards: the resident steady-state
    step must beat both the naive per-token re-run and its own warm-up
    invocation.
    """
    from repro.compiler import compile_decode_network
    from repro.compiler.runtime import ExecutorSession

    max_seq = 8 if smoke else 16
    n_tokens = 4 if smoke else 8
    rows = []
    for net in ("llama3.2-1b", "mamba2-780m"):
        t0 = time.time()
        prog = compile_decode_network(net, batch=1, max_seq=max_seq,
                                      opt_level=1)
        ds = simulate_program(prog)
        fixed = compile_network(net, seq_len=max_seq, opt_level=1)
        naive = simulate_program(fixed).total_cycles
        assert ds.steady_cycles < naive, \
            (f"resident decode step ({ds.steady_cycles} cycles) not "
             f"faster than re-running the fixed-seq program per token "
             f"({naive} cycles) on {net}")
        assert ds.steady_cycles < ds.warmup_cycles, \
            (f"steady-state step ({ds.steady_cycles} cycles) not "
             f"faster than warm-up ({ds.warmup_cycles}) on {net}")

        session = ExecutorSession(prog, backend="pallas")
        session.bind_synthetic_all(seed=0)
        tok = np.array([1], np.int32)
        t1 = time.perf_counter()
        logits = session.step(tok, 0)          # warm-up invocation
        warm_s = time.perf_counter() - t1
        t1 = time.perf_counter()
        for i in range(1, n_tokens):
            tok = np.argmax(np.asarray(logits), axis=-1).astype(np.int32)
            logits = session.step(tok, i)
        steady_s = time.perf_counter() - t1
        bench = {
            "BENCH": "serve.decode",
            "network": net,
            "family": prog.step.family,
            "batch": 1,
            "max_seq": max_seq,
            "tokens": n_tokens,
            "warmup_cycles": ds.warmup_cycles,
            "steady_cycles": ds.steady_cycles,
            "naive_fixed_seq_cycles_per_token": naive,
            "resident_vs_naive_x": round(naive
                                         / max(ds.steady_cycles, 1), 2),
            "warmup_vs_steady_x": round(ds.warmup_cycles
                                        / max(ds.steady_cycles, 1), 3),
            "tokens_cycles": ds.tokens_cycles(n_tokens),
            "warmup_s": round(warm_s, 4),
            "steady_s_per_token": round(steady_s
                                        / max(n_tokens - 1, 1), 4),
            "host_tok_per_s": round((n_tokens - 1)
                                    / max(steady_s, 1e-9), 1),
        }
        rows.append((f"serve.decode.{net}", 1e6 * (time.time() - t0),
                     json.dumps(bench, sort_keys=True)))
    return rows


def run(smoke: bool = False) -> list[tuple[str, float, str]]:
    rows = [bench_network(name, kw)
            for name, kw in (SMOKE_NETWORKS if smoke else NETWORKS)]
    rows.append(bench_backends(seq_len=16 if smoke else 64))
    for arch in ("resnet18", "mobilenet_v2"):
        rows.append(bench_cnn_execute(arch, smoke=smoke))
    rows.append(bench_multi_device(seq_len=16 if smoke else 64))
    rows.append(bench_obs_overhead(seq_len=16 if smoke else 64))
    rows.extend(bench_fused_kernels(smoke=smoke))
    rows.extend(bench_dse_sim_gap(smoke=smoke))
    rows.append(bench_gather_overlap(smoke=smoke))
    rows.extend(bench_serve_decode(smoke=smoke))
    return rows


def main() -> list[tuple[str, float, str]]:
    return run()


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="fast subset for CI (small LM programs only)")
    args = ap.parse_args()
    writer = csv.writer(sys.stdout)
    for row in run(smoke=args.smoke):
        writer.writerow(row)
