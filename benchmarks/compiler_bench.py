"""Compiler throughput benchmark: compile wall-time, instruction count,
bytes moved and DDR footprint per network.

Covers both CNN workloads and a slice of the LM registry, so compile
cost is tracked for every frontend family. Each row's ``derived`` field
carries a ``BENCH`` JSON blob with the program-level metrics the
roadmap cares about (instruction mix, image size, traffic).
"""
from __future__ import annotations

import csv
import json
import sys
import time

from repro.compiler import compile_network, to_binary

NETWORKS = [
    ("resnet18", {}),
    ("mobilenet_v2", {}),
    ("llama3.2-1b", {"seq_len": 64}),
    ("qwen3-moe-235b-a22b", {"seq_len": 64}),
    ("mamba2-780m", {"seq_len": 64}),
]


def run() -> list[tuple[str, float, str]]:
    rows = []
    for name, kw in NETWORKS:
        t0 = time.time()
        prog = compile_network(name, **kw)
        compile_s = time.time() - t0
        t1 = time.time()
        image = to_binary(prog)
        pack_s = time.time() - t1
        s = prog.stats()
        bench = {
            "BENCH": "compiler",
            "network": name,
            "layers": len(prog.layers),
            "instructions": s.n_instructions,
            "by_opcode": s.by_opcode,
            "image_bytes": len(image),
            "ddr_footprint_bytes": s.ddr_footprint,
            "mb_fetched": round(s.bytes_fetched / 1e6, 3),
            "mb_written": round(s.bytes_written / 1e6, 3),
            "compile_s": round(compile_s, 4),
            "pack_s": round(pack_s, 4),
            "instrs_per_s": int(s.n_instructions / max(compile_s, 1e-9)),
        }
        rows.append((f"compiler.{name}", 1e6 * compile_s,
                     json.dumps(bench, sort_keys=True)))
    return rows


def main() -> list[tuple[str, float, str]]:
    return run()


if __name__ == "__main__":
    writer = csv.writer(sys.stdout)
    for row in main():
        writer.writerow(row)
