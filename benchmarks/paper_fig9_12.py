"""Figs. 9-12 reproduction: per-layer bit-width + split-ratio profiles.

The paper plots, per searched config, each layer's {B^{w-L}, B^a} and
workload-split ratio. We run a short search and report the structural
properties those figures exhibit:

  * first/last layers pinned to 8 bits (§4);
  * depthwise layers (MobileNet) get LOW split ratios — "LUT-Core is
    not efficient to compute depth-wise layers" (§6.2.2), many are
    assigned (almost) entirely to the DSP-core;
  * pointwise/dense layers keep high LUT ratios.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.workloads import mobilenet_v2_specs
from repro.dse.search import run_search


def main(episodes: int = 12) -> list[tuple[str, float, str]]:
    t0 = time.time()
    res = run_search(network="mobilenet_v2", device="XC7Z020",
                     target_latency_ms=1e6,        # unconstrained: profile
                     episodes=episodes, baseline_acc=71.88, seed=0)
    wall = time.time() - t0
    info = res.best_info
    specs = mobilenet_v2_specs()
    ratios = np.asarray(info["ratios"])
    dw = np.asarray([s.depthwise for s in specs])
    bw = info["bw_lut"]

    dw_ratio = float(ratios[dw].mean())
    pw_ratio = float(ratios[~dw].mean())
    derived = (f"first/last bits={bw[0]}/{bw[-1]} (pinned 8) | "
               f"mean ratio depthwise={dw_ratio:.2f} vs "
               f"pointwise={pw_ratio:.2f} "
               f"(paper Fig. 11: depthwise layers mostly on the DSP-core)")
    return [("paper_fig9_12.layer_profiles", 1e6 * wall / episodes,
             derived)]


if __name__ == "__main__":
    for row in main():
        print(",".join(map(str, row)))
