"""Table 3 reproduction: the RL framework's searched configurations.

Runs the DDPG search (short budget by default; the paper uses 900
episodes) for two (device, network, target) settings and prints the
searched hardware configuration rows — the same columns as Table 3 —
plus the reached latency and accuracy proxy.
"""
from __future__ import annotations

import time

from repro.dse.search import run_search


SETTINGS = [
    ("XC7Z020", "resnet18", 35.0, 69.76),
    ("XC7Z020", "mobilenet_v2", 7.0, 71.88),
]


def main(episodes: int = 40) -> list[tuple[str, float, str]]:
    rows = []
    for device, network, target, base in SETTINGS:
        t0 = time.time()
        res = run_search(network=network, device=device,
                         target_latency_ms=target, episodes=episodes,
                         baseline_acc=base, seed=0)
        wall = time.time() - t0
        r = res.table3_row() if res.best_info else {}
        derived = (f"K={r.get('K')} M={r.get('M')} N={r.get('N')} "
                   f"DLa={r.get('D_L_buf_a')} DDa={r.get('D_D_buf_a')} "
                   f"DDw={r.get('D_D_buf_w')} "
                   f"lat={r.get('latency_ms')}ms (target {target}) "
                   f"acc~{r.get('acc_proxy')} "
                   f"best_r={res.best_reward:+.3f} eps={episodes}")
        rows.append((f"paper_table3.{device}.{network}.T{int(target)}ms",
                     1e6 * wall / max(episodes, 1), derived))
    return rows


if __name__ == "__main__":
    for row in main():
        print(",".join(map(str, row)))
