"""Roofline analysis from the compiled dry-run artifacts (§Roofline).

Reads the JSON emitted by ``repro.launch.dryrun`` and derives, per
(arch x shape x mesh) cell:

    compute term    = HLO_FLOPs_per_device / peak_FLOP/s
    memory term     = HLO_bytes_per_device / HBM_bw
    collective term = collective_bytes_per_device / link_bw

plus MODEL_FLOPS (6*N*D train / 2*N_active*D inference), the useful-
compute ratio MODEL_FLOPS / HLO_FLOPs, the dominant bottleneck, and a
per-cell suggestion for what would move the dominant term.

Caveats carried into the table:
  * XLA:CPU cost analysis counts full operand bytes for slice /
    dynamic-update-slice, so decode memory terms are also reported with
    an *analytic* bytes model (params touched + cache R/W) — the
    dominant-term call uses the analytic value where they disagree.
  * HLO FLOPs for train include the remat recompute (that is real work
    the chip does) — the useful-ratio quantifies it.

Hardware constants (task spec): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.

Also emits analytic ``kernels.fused.*`` rows (``--fused``) straight
from the compiled programs: launch-count and DDR-traffic deltas of the
fused one-launch-per-layer executor path vs the per-partition batched
path (the removed ``L{i}.col`` im2col staging). ``--csv PATH`` writes
the rows as the CSV artifact CI uploads.
"""
from __future__ import annotations

import argparse
import json

from repro.configs import registry

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9


def model_flops(arch_id: str, shape_name: str) -> float:
    arch = registry.get(arch_id)
    shape = registry.SHAPES[shape_name]
    mod = arch.model_module()
    n_active = getattr(mod, "active_param_count", mod.param_count)(arch.model)
    tokens = shape.global_batch * shape.seq_len
    if shape.kind == "train":
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        return 2.0 * n_active * tokens
    return 2.0 * n_active * shape.global_batch          # decode: 1 token


def analytic_bytes_per_device(arch_id: str, shape_name: str,
                              n_chips: int) -> float:
    """Structural model of per-device HBM traffic per step.

    Used for the dominant-term call on every shape: XLA:CPU's
    bytes-accessed is a poor TPU proxy (bf16 legalized to f32, weaker
    fusion, full-operand counting on slices of scanned stacks) — the
    HLO value is still reported alongside.

    train  : params bf16 x (fwd read + remat read + bwd read + grad
             write) + fp32 m/v read+write (16 B/param) + layer-boundary
             activation carries (write fwd, read bwd, re-read remat).
    prefill: params once + boundary activations once.
    decode : active params once + KV cache read + write.
    """
    arch = registry.get(arch_id)
    shape = registry.SHAPES[shape_name]
    mod = arch.model_module()
    m = arch.model
    n_params = mod.param_count(m)
    n_active = getattr(mod, "active_param_count", mod.param_count)(m)
    n_layers = getattr(m, "n_layers", None) or (m.n_enc_layers
                                                + m.n_dec_layers)
    # tokens per device; the act_res rule shards the carries a further
    # model-axis factor when seq divides (approximate with /16)
    tokens_local = shape.global_batch * shape.seq_len / n_chips
    carry = n_layers * tokens_local * m.d_model * 2
    if shape.kind == "train":
        return (n_active * (2 + 2 + 2 + 2 + 16)) / n_chips + 4 * carry
    if shape.kind == "prefill":
        return (n_active * 2) / n_chips + 2 * carry
    cache = _cache_bytes(arch, shape)
    return (n_active * 2 + 3 * cache) / n_chips


def _cache_bytes(arch, shape) -> float:
    m = arch.model
    b, s = shape.global_batch, shape.seq_len
    if arch.module == "ssm":
        ss = m.ssm
        return m.n_layers * b * (ss.n_heads * ss.head_dim * ss.d_state * 4
                                 + (ss.d_inner + 2 * ss.n_groups * ss.d_state)
                                 * (ss.conv_kernel - 1) * 2)
    if arch.module == "hybrid":
        ss = m.ssm
        state = b * (ss.n_heads * ss.head_dim * ss.d_state * 4)
        kv = b * s * m.n_kv_heads * m.head_dim * 2 * 2
        n_attn = m.n_periods
        return (m.n_layers - n_attn) * state + n_attn * kv
    if getattr(m, "mla", None):
        a = m.mla
        return m.n_layers * b * s * (a.kv_lora + a.qk_rope_dim) * 2
    if arch.module == "encdec":
        return m.n_dec_layers * b * s * m.n_kv_heads * m.head_dim * 2 * 2 * 2
    return m.n_layers * b * s * m.n_kv_heads * m.head_dim * 2 * 2


def _suggest(dom: str, rec: dict) -> str:
    coll = rec.get("collective_bytes_per_device", {})
    big = max(coll, key=coll.get) if coll else "-"
    if dom == "compute":
        return ("compute-bound: int8/fp8 matmuls (2x MXU rate) or lighter "
                "remat policy")
    if dom == "memory":
        return ("memory-bound: fuse cache update with attention read; "
                "quantize weights/KV (int8/int4 halves bytes)")
    return (f"collective-bound ({big}): overlap {big} with compute, "
            "shard differently or compress")


def analyse(records: list[dict]) -> list[dict]:
    out = []
    for r in records:
        if r.get("status") != "ok":
            out.append(r)
            continue
        n = r["n_chips"]
        compute_s = r["flops_per_device"] / PEAK_FLOPS
        memory_hlo_s = r["bytes_per_device"] / HBM_BW
        ana = analytic_bytes_per_device(r["arch"], r["shape"], n)
        memory_ana_s = ana / HBM_BW
        # the dominant-term call uses the analytic memory model on
        # every shape (CPU HLO bytes are not a TPU HBM proxy — see
        # docstring); the HLO value stays in the record.
        memory_s = memory_ana_s
        coll_s = r["collective_bytes_total"] / ICI_BW
        mf = model_flops(r["arch"], r["shape"])
        hlo_total = r["flops_per_device"] * n
        terms = {"compute": compute_s, "memory": memory_s,
                 "collective": coll_s}
        dom = max(terms, key=terms.get)
        bound = terms[dom]
        useful_s = mf / (n * PEAK_FLOPS)
        # deployment bound: bidirectional ring on the model axis uses 2
        # links (and CPU-HLO f32 legalization inflated bf16 volumes 2x
        # -> /2 again would be fair; we only take the link factor), and
        # XLA overlaps async collectives with compute, so the wall-clock
        # bound is max(compute, memory, coll/2) rather than their max
        # with serial collectives.
        bound_overlap = max(compute_s, memory_s, coll_s / 2.0)
        out.append({
            **{k: r[k] for k in ("arch", "shape", "mesh", "n_chips")},
            "status": "ok",
            "compute_s": compute_s,
            "memory_hlo_s": memory_hlo_s,
            "memory_analytic_s": memory_ana_s,
            "collective_s": coll_s,
            "dominant": dom,
            "bound_s": bound,
            "model_flops": mf,
            "useful_ratio": mf / hlo_total if hlo_total else float("nan"),
            "roofline_frac": useful_s / bound if bound else float("nan"),
            "roofline_frac_overlap": (useful_s / bound_overlap
                                      if bound_overlap else float("nan")),
            "suggestion": _suggest(dom, r),
        })
    return out


def to_markdown(rows: list[dict]) -> str:
    hdr = ("| arch | shape | mesh | compute s | memory s | coll s | "
           "dominant | useful ratio | frac | frac(ovl) |\n"
           "|---|---|---|---|---|---|---|---|---|---|\n")
    lines = []
    for r in rows:
        if r.get("status") == "skipped":
            lines.append(f"| {r['arch']} | {r['shape']} | - | - | - | - | "
                         f"skipped | - | - | - |")
            continue
        if r.get("status") != "ok":
            continue
        mesh = "x".join(map(str, r["mesh"]))
        lines.append(
            f"| {r['arch']} | {r['shape']} | {mesh} "
            f"| {r['compute_s']:.3e} | {r['memory_analytic_s']:.3e} "
            f"| {r['collective_s']:.3e} | {r['dominant']} "
            f"| {r['useful_ratio']:.2f} | {r['roofline_frac']:.3f} "
            f"| {r['roofline_frac_overlap']:.3f} |")
    return hdr + "\n".join(lines)


def fused_kernel_rows(smoke: bool = True) -> list[tuple[str, float, str]]:
    """``kernels.fused.*`` rows from the *compiled programs* (analytic,
    no execution): per network, launch count and DDR traffic of the
    fused one-launch-per-layer path vs the per-partition batched path.

    The launch delta is structural (one call per layer vs one per
    partition, plus the dropped host-side concat); the DDR delta is the
    ``L{i}.col`` im2col staging the fused conv kernels eliminate — the
    kh*kw-duplicated column matrix each conv used to write to and
    re-fetch from DDR, now replaced by reading the raw spatial source.
    """
    import math

    from repro.compiler import compile_network

    cases = [("resnet18", {"in_hw": 28, "width": 0.25} if smoke else {}),
             ("mobilenet_v2", {"in_hw": 28, "width": 0.25} if smoke else {}),
             ("llama3.2-1b", {"seq_len": 16 if smoke else 64})]
    rows = []
    for net, kw in cases:
        prog = compile_network(net, opt_level=1, **kw)
        launches_fused = len(prog.layers)
        launches_split = sum((lp.lut is not None) + (lp.dsp is not None)
                             for lp in prog.layers)
        concats = sum((lp.lut is not None) and (lp.dsp is not None)
                      for lp in prog.layers)
        col_bytes = spatial_bytes = 0
        for lp in prog.layers:
            if lp.geometry is None:
                continue
            g, geo = lp.dims, lp.geometry
            col_bytes += math.ceil(
                g.m * g.k * (g.n if lp.depthwise else 1) * lp.bits_a / 8)
            spatial_bytes += math.ceil(
                geo.in_hw * geo.in_hw * geo.c_in * lp.bits_a / 8)
        # staging costs a write of the column matrix plus its re-fetch;
        # the fused path fetches the spatial source once
        ddr_delta = 2 * col_bytes - spatial_bytes
        blob = json.dumps({
            "BENCH": "kernels.fused.roofline",
            "network": net,
            "layers": len(prog.layers),
            "launches_fused": launches_fused,
            "launches_split": launches_split,
            "launch_delta": launches_split - launches_fused,
            "concats_removed": concats,
            "col_staging_bytes": col_bytes,
            "spatial_fetch_bytes": spatial_bytes,
            "ddr_traffic_delta_bytes": max(ddr_delta, 0),
        }, sort_keys=True)
        rows.append((f"kernels.fused.{net}",
                     float(launches_split - launches_fused), blob))
    return rows


def rows_to_csv(rows: list[tuple[str, float, str]], path: str) -> None:
    """Write bench rows (name, value, derived-JSON) as the CSV artifact
    CI uploads."""
    import csv

    with open(path, "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(["name", "value_us", "derived"])
        for r in rows:
            w.writerow(r)


def main() -> list[tuple[str, float, str]]:
    import os
    rows = []
    for path in ("dryrun_single_pod.json", "dryrun_multi_pod.json"):
        if not os.path.exists(path):
            continue
        with open(path) as f:
            records = json.load(f)
        for r in analyse(records):
            if r.get("status") != "ok":
                continue
            mesh = "x".join(map(str, r["mesh"]))
            rows.append((
                f"roofline.{r['arch']}.{r['shape']}.{mesh}",
                1e6 * r["bound_s"],
                f"dom={r['dominant']} useful={r['useful_ratio']:.2f} "
                f"frac={r['roofline_frac']:.3f}"))
    rows.extend(fused_kernel_rows())
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="dryrun_single_pod.json")
    ap.add_argument("--markdown", action="store_true")
    ap.add_argument("--fused", action="store_true",
                    help="emit only the kernels.fused.* analytic rows "
                         "(no dry-run artifact needed)")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced program geometry for the fused rows")
    ap.add_argument("--csv", default=None, metavar="PATH",
                    help="also write the rows as a CSV artifact")
    args = ap.parse_args()
    if args.fused:
        rows = fused_kernel_rows(smoke=args.smoke)
        if args.csv:
            rows_to_csv(rows, args.csv)
        for name, val, blob in rows:
            print(json.dumps({"name": name, "value": val,
                              **json.loads(blob)}))
    else:
        with open(args.json) as f:
            records = json.load(f)
        rows = analyse(records)
        if args.csv:
            bench_rows = [(f"roofline.{r['arch']}.{r['shape']}",
                           1e6 * r.get("bound_s", 0.0),
                           json.dumps(r, sort_keys=True))
                          for r in rows if r.get("status") == "ok"]
            rows_to_csv(bench_rows + fused_kernel_rows(), args.csv)
        if args.markdown:
            print(to_markdown(rows))
        else:
            for r in rows:
                print(json.dumps(r))
