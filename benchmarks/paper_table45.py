"""Tables 4 + 5 reproduction: end-to-end latency / throughput of the
manual and searched configs on both devices and both networks, plus the
heterogeneous-vs-single-core comparison (the Mix&Match-style baselines).

The baselines the paper compares against are implemented here as the
two degenerate operating points of our own system:
  * ratio = 0 everywhere  -> pure DSP-core accelerator (bit-parallel
    int4 — the DSP-centric design family Mix&Match belongs to);
  * ratio = 1 everywhere  -> pure LUT-core accelerator (BISMO).
The heterogeneous split (per-layer Eq. 12 optimum) must beat both.

Published anchors (paper Table 5, model latency):
  DA ResNet-18 manual 4/4:  40.96 ms     DB ResNet-18 manual: 30.26 ms
  DA MobileNet manual 4/4:   8.85 ms     (measured ~3-8% above model)
"""
from __future__ import annotations

import time

from repro.core.latency_model import network_latency
from repro.core.scheduler import (
    DEVICES,
    DspCoreConfig,
    LutCoreConfig,
)
from repro.core.split import solve_network_splits
from repro.core.workloads import WORKLOADS, total_gops

# Table 3 configs (the paper's searched manual-config hardware points).
CONFIGS = {
    ("XC7Z020", "resnet18"): LutCoreConfig(m=8, n=16, k=128, d_a=1024),
    ("XC7Z020", "mobilenet_v2"): LutCoreConfig(m=26, n=8, k=64, d_a=1024),
    ("XC7Z045", "resnet18"): LutCoreConfig(m=14, n=14, k=512, d_a=1024),
    ("XC7Z045", "mobilenet_v2"): LutCoreConfig(m=44, n=18, k=64, d_a=1024),
}
DSP_BUF = {
    ("XC7Z020", "resnet18"): (2048, 1024),
    ("XC7Z020", "mobilenet_v2"): (9 * 1024, 1024),
    ("XC7Z045", "resnet18"): (15 * 1024, 1024),
    ("XC7Z045", "mobilenet_v2"): (20 * 1024, 8 * 1024),
}
PAPER_MODEL_MS = {
    ("XC7Z020", "resnet18"): 40.96,
    ("XC7Z045", "resnet18"): 30.26,
    ("XC7Z020", "mobilenet_v2"): 8.85,
}


def run_one(device: str, network: str, bits: int = 4) -> dict:
    dev = DEVICES[device]
    specs = WORKLOADS[network]()
    lut_cfg = CONFIGS[(device, network)]
    d_a, d_w = DSP_BUF[(device, network)]
    dsp_cfg = DspCoreConfig(
        n_reg_row_a=DspCoreConfig.rows_for_device(dev), d_a=d_a, d_w=d_w)
    n = len(specs)
    bw = [8 if (s.is_first or s.is_last) else bits for s in specs]
    ba = [8 if (s.is_first or s.is_last) else bits for s in specs]

    sols = solve_network_splits(specs, lut_cfg, dsp_cfg, dev, bw, ba)
    hetero_ms = dev.cycles_to_ms(sum(s.cycles for s in sols))
    dsp_ms, _ = network_latency(specs, [0] * n, bw, ba, lut_cfg, dsp_cfg, dev)
    lut_ms, _ = network_latency(specs, [sp.gemm().n for sp in specs], bw, ba,
                                lut_cfg, dsp_cfg, dev)
    gops = total_gops(specs)
    return {
        "device": device,
        "network": network,
        "hetero_ms": hetero_ms,
        "all_dsp_ms": dsp_ms,
        "all_lut_ms": lut_ms,
        "speedup_vs_dsp": dsp_ms / hetero_ms,
        "speedup_vs_lut": lut_ms / hetero_ms,
        "throughput_gops": gops / (hetero_ms / 1e3),
        "gops_per_dsp": gops / (hetero_ms / 1e3) / dev.dsps,
        "fps": 1e3 / hetero_ms,
        "paper_model_ms": PAPER_MODEL_MS.get((device, network)),
    }


def main() -> list[tuple[str, float, str]]:
    rows = []
    for device in ("XC7Z020", "XC7Z045"):
        for network in ("resnet18", "mobilenet_v2"):
            t0 = time.time()
            r = run_one(device, network)
            wall = time.time() - t0
            anchor = (f" paper={r['paper_model_ms']:.2f}ms"
                      if r["paper_model_ms"] else "")
            derived = (f"hetero={r['hetero_ms']:.2f}ms{anchor} "
                       f"dsp-only={r['all_dsp_ms']:.2f}ms "
                       f"lut-only={r['all_lut_ms']:.2f}ms "
                       f"x{r['speedup_vs_dsp']:.2f}/x{r['speedup_vs_lut']:.2f} "
                       f"{r['throughput_gops']:.1f}GOPS "
                       f"{r['fps']:.1f}FPS")
            rows.append((f"paper_table45.{device}.{network}",
                         1e6 * wall, derived))
    return rows


if __name__ == "__main__":
    for row in main():
        print(",".join(map(str, row)))
