"""TPU adaptation of the paper's technique: split-ratio curves on the
v5e cost model + kernel-path equivalence check.

For representative LM projection GEMMs (llama3.2-1b / qwen3-8b shapes)
this reports the Eq.-12-analogue optimum under both compositions:
  * temporal (single core time-shares the MXU — optimum is usually a
    boundary unless precision constraints bind), and
  * spatial (partitions on disjoint mesh sub-axes — the FPGA's max()
    form, interior optimum re-emerges),
plus the bitplane-path latency law (cost ∝ weight bits on the MXU).
"""
from __future__ import annotations

import time

from repro.core.tpu_cost import hetero_gemm_cost, solve_tpu_split


GEMMS = {
    "llama1b.mlp": (4096, 2048, 8192),
    "qwen3-8b.mlp": (4096, 4096, 12288),
    "qwen3-8b.qkvo": (4096, 4096, 4096),
    "yi-34b.mlp": (4096, 7168, 20480),
}


def main() -> list[tuple[str, float, str]]:
    rows = []
    for name, (m, k, n) in GEMMS.items():
        t0 = time.time()
        r_t, s_t, _ = solve_tpu_split(m, k, n, bits_w_serial=4, bits_a=4,
                                      spatial=False)
        r_s, s_s, _ = solve_tpu_split(m, k, n, bits_w_serial=4, bits_a=4,
                                      spatial=True)
        # bit-proportionality of the bitplane path
        c2 = hetero_gemm_cost(m, k, n, 1.0, 2, 4).t_bitplane
        c8 = hetero_gemm_cost(m, k, n, 1.0, 8, 4).t_bitplane
        wall = time.time() - t0
        derived = (f"temporal r*={r_t:.2f} {s_t * 1e6:.0f}us | "
                   f"spatial r*={r_s:.2f} {s_s * 1e6:.0f}us | "
                   f"bitplane t8/t2={float(c8 / c2):.2f} (≈4 when "
                   f"compute-bound)")
        rows.append((f"tpu_hetero.{name}", 1e6 * wall, derived))
    return rows


if __name__ == "__main__":
    for row in main():
        print(",".join(map(str, row)))
