"""Fig. 5 reproduction: closed-form latency model vs instruction-stream
simulator over random design points.

The paper validates its model against FPGA hardware at <2% error and
shows the error shrinking with workload size (Fig. 5b). Offline, the
event-driven simulator plays the hardware's role; the closed form is
what the DSE loops evaluate (vectorized), so their agreement is what
makes the search results trustworthy.

The ``dse.sim_gap.*`` rows extend this to *whole compiled programs*:
per architecture, a fixed configuration is scored by the closed form
(sum of Eq.-10 layer makespans over the solved Eq.-12 splits) and by
``simulate_program`` on the program the compiler actually emits at
``-O1`` — the gap the two-tier search loop (docs/dse.md) corrects for,
with the documented agreement tolerance flagged per row.
"""
from __future__ import annotations

import json
import time

import numpy as np

from repro.core.latency_model import dsp_core_latency, lut_core_latency
from repro.core.scheduler import (
    XC7Z020,
    DspCoreConfig,
    GemmDims,
    LutCoreConfig,
    simulate_dsp_core,
    simulate_lut_core,
)


def run(n_points: int = 300, seed: int = 0) -> dict:
    rng = np.random.default_rng(seed)
    errs = []
    sizes = []
    t0 = time.time()
    for _ in range(n_points):
        m = int(rng.integers(64, 8192))
        k = int(rng.integers(64, 4096))
        n = int(rng.integers(16, 1024))
        bw = int(rng.integers(2, 9))
        ba = int(rng.integers(2, 5))
        which = rng.random() < 0.5
        g = GemmDims(m, k, n)
        if which:
            cfg = LutCoreConfig(m=int(rng.integers(4, 17)),
                                n=int(rng.integers(8, 33)), k=128)
            sim = simulate_lut_core(g, cfg, XC7Z020, bw, ba).total_cycles
            mod = float(lut_core_latency(m, k, n, cfg, XC7Z020, bw, ba))
        else:
            cfg = DspCoreConfig(n_reg_row_a=13)
            sim = simulate_dsp_core(g, cfg, XC7Z020).total_cycles
            mod = float(dsp_core_latency(m, k, n, cfg, XC7Z020))
        if sim > 0:
            errs.append(abs(mod - sim) / sim)
            sizes.append(sim)
    errs = np.asarray(errs)
    sizes = np.asarray(sizes)
    big = sizes > np.median(sizes)
    return {
        "n_points": len(errs),
        "mean_err_pct": 100 * float(errs.mean()),
        "p95_err_pct": 100 * float(np.quantile(errs, 0.95)),
        "max_err_pct": 100 * float(errs.max()),
        "mean_err_small_pct": 100 * float(errs[~big].mean()),
        "mean_err_large_pct": 100 * float(errs[big].mean()),
        "wall_s": time.time() - t0,
    }


#: per-architecture settings for the whole-program gap rows — the CNN
#: zoo runs its reduced geometry-consistent variants (full-size im2col
#: simulation is minutes-long; the gap is a per-layer property and
#: survives scaling), the registry LM its smoke config
SIM_GAP_SETTINGS = [
    ("resnet18", {"in_hw": 32, "width": 0.25}),
    ("mobilenet_v2", {"in_hw": 32, "width": 0.25}),
    ("llama3.2-1b", {"seq_len": 16}),
]


def sim_gap_rows() -> list[tuple[str, float, str]]:
    """``dse.sim_gap.<network>`` rows: closed form vs compiled program."""
    from repro.dse.evaluator import sim_gap_report
    from repro.models.cnn import CNNConfig, specs_for
    rows = []
    for network, kw in SIM_GAP_SETTINGS:
        t0 = time.time()
        if "in_hw" in kw:
            specs = specs_for(CNNConfig(arch=network, **kw))
            rep = sim_gap_report(network, specs=specs)
        else:
            rep = sim_gap_report(network, seq_len=kw["seq_len"])
        rep["wall_s"] = round(time.time() - t0, 4)
        rows.append((f"dse.sim_gap.{network}", 1e6 * (time.time() - t0),
                     json.dumps(rep, sort_keys=True)))
    return rows


def main() -> list[tuple[str, float, str]]:
    r = run()
    derived = (f"mean={r['mean_err_pct']:.2f}% p95={r['p95_err_pct']:.2f}% "
               f"small={r['mean_err_small_pct']:.2f}% "
               f"large={r['mean_err_large_pct']:.2f}% "
               f"(paper: <2% vs hardware; error shrinks with size)")
    us = 1e6 * r["wall_s"] / r["n_points"]
    return [("paper_fig5.model_vs_sim", us, derived)] + sim_gap_rows()


if __name__ == "__main__":
    for row in main():
        print(",".join(map(str, row)))
