"""Render bench-row CSVs as a markdown summary table.

Every benchmark in this repo emits rows of ``name, microseconds,
{"BENCH": kind, ...}`` (see ``compiler_bench.py``); CI used to carry
an inline heredoc that pretty-printed them into
``$GITHUB_STEP_SUMMARY``. That table lives here now — one ``describe``
dispatch per row kind — so adding a bench kind means editing this file
(unit-tested) instead of the workflow.

  PYTHONPATH=src python benchmarks/summarize_bench.py bench.csv \
      --title "compiler bench (smoke)" >> "$GITHUB_STEP_SUMMARY"
"""
from __future__ import annotations

import argparse
import csv
import json
import sys


def describe(kind: str, b: dict, us: float) -> str:
    """One-line key-metrics summary for a bench row's BENCH blob."""
    if kind == "compiler":
        return (f"{b['layers']} layers, {b['instructions']} instrs "
                f"(-O1 {b['instructions_o1']}), "
                f"{b.get('sim_latency_gain_pct', '-')}% sim gain")
    elif kind == "compiler.backends":
        return (f"golden {b['golden_s']}s vs pallas {b['pallas_s']}s "
                f"({b['speedup_x']}x), bit_exact={b['bit_exact']}")
    elif kind == "compiler.cnn_execute":
        return (f"e2e @{b['in_hw']}px: {b['layers']} layers "
                f"({b['depthwise_layers']} depthwise), "
                f"pallas {b['pallas_s']}s, "
                f"bit_exact={b.get('bit_exact', '-')}")
    elif kind == "compiler.multi_device":
        p2 = b["plans"]["pipeline_x2"]
        return (f"pipeline_x2 {p2['speedup_x']}x vs 1 device "
                f"(beats: {b['pipeline_x2_beats_1dev']})")
    elif kind == "obs.overhead":
        return (f"tracer on {b['sim_on_s']}s vs off {b['sim_off_s']}s "
                f"({b['overhead_pct']}% overhead, "
                f"{b['trace_events']} events, "
                f"closure_ok={b['closure_ok']})")
    elif kind == "kernels.fused":
        return (f"fused {b['fused_s']}s vs split {b['split_s']}s "
                f"({b['speedup_x']}x), launches "
                f"{b['launches_fused']} vs {b['launches_split']}, "
                f"col staging -{b['col_staging_bytes_removed']}B, "
                f"bit_exact={b['bit_exact']}")
    elif kind == "dse.sim_gap":
        return (f"analytical {b['analytical_ms']}ms vs simulated "
                f"{b['simulated_ms']}ms (gap {b['gap_pct']}%, "
                f"within_tol={b['within_tol']})")
    elif kind == "compiler.gather_overlap":
        return (f"filter_x2 gather overlap {b['latency_overlap']} "
                f"vs serialized {b['latency_serialized']} cycles "
                f"(-{b['gain_pct']}%)")
    elif kind == "serve.decode":
        return (f"{b['family']}: steady {b['steady_cycles']} "
                f"cycles/token vs naive re-run "
                f"{b['naive_fixed_seq_cycles_per_token']} "
                f"({b['resident_vs_naive_x']}x), warm-up "
                f"{b['warmup_cycles']}, "
                f"{b['host_tok_per_s']} tok/s host")
    elif kind == "serve.fleet":
        return (f"{b['policy']}: {b['req_per_s']} req/s "
                f"({b['completed']}/{b['requests']} ok, "
                f"{b['failed']} failed), p50 {b['p50_ms']}ms / "
                f"p99 {b['p99_ms']}ms, {b['workers']} workers "
                f"util {b['utilization_pct']}%, "
                f"bit_exact={b['bit_exact']}")
    elif kind == "accuracy.eval":
        lat = b.get("latency_ms")
        return (f"{b['network']}/{b['backend']}: "
                f"{b['agreement'] * 100:.2f}% top-1 agreement over "
                f"{b['n_samples']} samples "
                f"(floor {b['agreement_floor'] * 100:.0f}%, "
                f"meets={b['meets_floor']})"
                + (f", sim latency {lat}ms" if lat is not None else ""))
    elif kind == "serve.fleet.compare":
        return (f"continuous {b['continuous_req_per_s']} vs serial "
                f"{b['serial_req_per_s']} req/s "
                f"({b['speedup_x']}x, "
                f"beats={b['continuous_beats_serial']})")
    else:
        return f"{float(us) / 1e6:.2f}s"


def summarize(rows, title: str = "bench") -> str:
    """Markdown table over ``(name, microseconds, blob)`` rows."""
    lines = [f"### {title}", "", "| row | key metrics |", "| --- | --- |"]
    for name, us, blob in rows:
        b = json.loads(blob)
        lines.append(
            f"| `{name}` | {describe(b.get('BENCH', ''), b, us)} |")
    return "\n".join(lines) + "\n"


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(
        description="render bench-row CSVs as a markdown table")
    ap.add_argument("csv", nargs="+", help="bench CSV file(s)")
    ap.add_argument("--title", default="bench")
    args = ap.parse_args(argv)
    rows = []
    for path in args.csv:
        with open(path, newline="") as fh:
            rows.extend(r for r in csv.reader(fh) if r)
    sys.stdout.write(summarize(rows, args.title))


if __name__ == "__main__":
    main()
