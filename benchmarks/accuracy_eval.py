"""Dataset-scale accuracy validation of compiled CNN programs.

The Table 4/5 companion: for each architecture, train + freeze the
fp32 reference, compile the quantized network through the full NN->ISA
toolchain, bind folded weights, and measure **top-1 agreement** over a
synthetic eval stream next to the simulated latency
(``repro.eval.accuracy`` holds the machinery; this is the CLI).

Rows are the repo's standard ``name, us, BENCH-json`` CSV
(``summarize_bench.py`` renders them), kind ``accuracy.eval``. The
process exits nonzero when any row misses the documented agreement
floor (``repro.eval.accuracy.AGREEMENT_FLOOR``) — the CI ``accuracy``
job gates on that.

  PYTHONPATH=src python benchmarks/accuracy_eval.py              # full
  PYTHONPATH=src python benchmarks/accuracy_eval.py --smoke \\
      --backend golden --backend pallas                          # CI
"""
from __future__ import annotations

import argparse
import csv
import json
import sys
import time

from repro.eval.accuracy import AGREEMENT_FLOOR, measure

ARCHS = ("resnet18", "mobilenet_v2")


def run(arch: str, backend: str, n_samples: int, batch: int,
        train_steps: int, w_bits: int, a_bits: int, ratio: float,
        simulate: bool) -> tuple[tuple[str, float, str], bool]:
    t0 = time.time()
    rep = measure(arch, n_samples=n_samples, batch=batch, backend=backend,
                  w_bits=w_bits, a_bits=a_bits, ratio=ratio,
                  train_steps=train_steps, simulate=simulate)
    wall_us = 1e6 * (time.time() - t0)
    row = (f"accuracy.eval.{arch}.{backend}", wall_us,
           json.dumps(rep.bench_row(), sort_keys=True))
    return row, rep.agreement >= AGREEMENT_FLOOR


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="dataset-scale compiled-vs-fp32 top-1 agreement")
    ap.add_argument("--arch", action="append", choices=ARCHS,
                    help="architecture(s); default: both")
    ap.add_argument("--backend", action="append",
                    choices=("golden", "pallas"),
                    help="executor backend(s); default: pallas")
    ap.add_argument("--samples", type=int, default=10_000)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--train-steps", type=int, default=200)
    ap.add_argument("--w-bits", type=int, default=4)
    ap.add_argument("--a-bits", type=int, default=8)
    ap.add_argument("--ratio", type=float, default=0.5)
    ap.add_argument("--smoke", action="store_true",
                    help="CI size: 96 samples, no latency simulation "
                         "(training stays at the documented 200 steps "
                         "— the floor is calibrated for a converged "
                         "reference)")
    ap.add_argument("--no-gate", action="store_true",
                    help="report only; do not exit nonzero below the "
                         "agreement floor")
    args = ap.parse_args(argv)

    archs = args.arch or list(ARCHS)
    backends = args.backend or ["pallas"]
    n_samples, train_steps, simulate = args.samples, args.train_steps, True
    if args.smoke:
        n_samples, simulate = 96, False

    writer = csv.writer(sys.stdout)
    ok = True
    for arch in archs:
        for backend in backends:
            row, meets = run(arch, backend, n_samples, args.batch,
                             train_steps, args.w_bits, args.a_bits,
                             args.ratio, simulate)
            writer.writerow(row)
            sys.stdout.flush()
            if not meets:
                print(f"FAIL: {row[0]} below agreement floor "
                      f"{AGREEMENT_FLOOR}", file=sys.stderr)
                ok = False
    return 0 if (ok or args.no_gate) else 1


if __name__ == "__main__":
    sys.exit(main())
