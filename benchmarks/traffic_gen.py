"""Open-loop traffic generator for the serving fleet.

Drives a :class:`repro.serve.fleet.FleetServer` with Poisson arrivals
(open loop: the arrival process does not wait for completions, so the
fleet sees real queueing pressure) over a configurable prompt /
new-token mix, then emits ``serve.fleet.*`` bench rows in the repo's
CSV row format (requests/sec, p50/p99 latency from
``obs.METRICS``, worker utilization from the per-worker busy-time
series).

Two hard gates ride in the rows (the CI ``serving`` job fails on
either):

* **bit-exactness** — every completed request's tokens must equal the
  single-process ``ExecutorSession`` oracle
  (``engine.greedy_generate_compiled`` on a dedicated batch-1
  session);
* **continuous beats serial** — the continuous-batching policy must
  sustain at least the requests/sec of serial per-request dispatch on
  the same fleet and workload.

  PYTHONPATH=src python benchmarks/traffic_gen.py --smoke \
      --workers golden:thread,pallas:subprocess | tee serve-fleet.csv
"""
from __future__ import annotations

import argparse
import concurrent.futures
import csv
import json
import sys
import time

import numpy as np

from repro.obs import METRICS
from repro.serve.engine import greedy_generate_compiled
from repro.serve.fleet import FleetServer, RequestFailed


def _parse_workers(spec: str):
    """``golden:thread,pallas:subprocess`` -> fleet worker triples."""
    out = []
    for i, part in enumerate(x for x in spec.split(",") if x):
        backend, _, mode = part.partition(":")
        out.append((f"w{i}", backend, mode or "thread"))
    return out


def _workload(args):
    """Deterministic request mix + Poisson inter-arrival gaps."""
    rng = np.random.default_rng(args.seed)
    reqs = []
    for _ in range(args.requests):
        s0 = int(rng.integers(1, args.prompt_len + 1))
        prompt = rng.integers(0, 512, s0).astype(np.int32)
        reqs.append((prompt, args.new_tokens))
    gaps = rng.exponential(1.0 / args.rate, args.requests)
    return reqs, gaps


def _oracle_outputs(args, reqs):
    """Single-process batch-1 oracle for every request (the hard
    bit-exactness reference: same program config, same weight seed)."""
    from repro.compiler import compile_decode_network
    from repro.compiler.runtime import ExecutorSession
    prog = compile_decode_network(args.arch, batch=1,
                                  max_seq=args.max_seq, opt_level=1)
    session = ExecutorSession(prog, backend="golden")
    session.bind_synthetic_all(seed=args.seed)
    outs = []
    for prompt, n_new in reqs:
        outs.append(np.asarray(greedy_generate_compiled(
            session, prompt[None, :], n_new))[0])
    return outs


def _drive(fleet: FleetServer, reqs, gaps, timeout_s: float):
    """Submit the workload open-loop; returns (outputs, wall_s,
    completed, failed). ``outputs[i]`` is None for failed requests."""
    # one warm-up request so JIT compile time is paid outside the
    # measured window (both policies pay it identically)
    fleet.submit(reqs[0][0], reqs[0][1]).result(timeout_s)
    METRICS.clear()
    futures = []
    arrivals = np.cumsum(gaps)
    t0 = time.perf_counter()
    for (prompt, n_new), at in zip(reqs, arrivals):
        delay = t0 + at - time.perf_counter()
        if delay > 0:
            time.sleep(delay)
        futures.append(fleet.submit(prompt, n_new))
    outputs, failed = [], 0
    for fut in futures:
        try:
            outputs.append(np.asarray(fut.result(timeout_s)))
        except (RequestFailed, concurrent.futures.TimeoutError):
            outputs.append(None)
            failed += 1
    wall = time.perf_counter() - t0
    return outputs, wall, len(futures) - failed, failed


def _utilization_pct(worker_ids, wall_s: float) -> float:
    busy_ms = sum(sum(METRICS.series(f"serve.fleet.worker.{w}.busy_ms"))
                  for w in worker_ids)
    return round(busy_ms / max(wall_s * 1e3 * len(worker_ids), 1e-9)
                 * 100, 1)


def run_policy(args, policy: str, reqs, gaps, oracle):
    workers = _parse_workers(args.workers)
    with FleetServer(args.arch, workers, batch_slots=args.slots,
                     max_seq=args.max_seq, seed=args.seed,
                     policy=policy,
                     step_timeout_s=args.step_timeout) as fleet:
        outputs, wall, completed, failed = _drive(
            fleet, reqs, gaps, args.request_timeout)
    exact = all(out is None or np.array_equal(out, ref)
                for out, ref in zip(outputs, oracle))
    blob = {
        "BENCH": "serve.fleet",
        "arch": args.arch,
        "policy": policy,
        "workers": len(workers),
        "slots": args.slots,
        "requests": len(reqs),
        "completed": completed,
        "failed": failed,
        "req_per_s": round(completed / max(wall, 1e-9), 2),
        "p50_ms": round(METRICS.percentile("serve.fleet.request_ms", 50), 1),
        "p99_ms": round(METRICS.percentile("serve.fleet.request_ms", 99), 1),
        "utilization_pct": _utilization_pct(
            [w[0] for w in workers], wall),
        "steps": METRICS.counter("serve.fleet.steps"),
        "bit_exact": exact,
    }
    row = (f"serve.fleet.{policy}.{args.arch}", wall * 1e6,
           json.dumps(blob, sort_keys=True))
    assert exact, (f"{policy}: fleet outputs diverge from the "
                   f"single-process oracle")
    assert completed == len(reqs), \
        f"{policy}: {failed} of {len(reqs)} requests failed"
    return row, blob


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(
        description="open-loop Poisson traffic against the serving fleet")
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--workers", default="golden:thread,golden:thread",
                    metavar="B:M,B:M",
                    help="comma list of backend:mode worker specs")
    ap.add_argument("--slots", type=int, default=4,
                    help="continuous-batching slots per worker")
    ap.add_argument("--max-seq", type=int, default=16)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--rate", type=float, default=20.0,
                    help="Poisson arrival rate, requests/sec")
    ap.add_argument("--prompt-len", type=int, default=4,
                    help="max prompt length (uniform 1..N)")
    ap.add_argument("--new-tokens", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--step-timeout", type=float, default=300.0)
    ap.add_argument("--request-timeout", type=float, default=600.0)
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized workload (8 requests, short decode)")
    ap.add_argument("--metrics", default=None, metavar="PATH",
                    help="export the final obs.METRICS registry "
                         "(.json or .csv)")
    args = ap.parse_args(argv)
    if args.smoke:
        args.requests = 8
        args.prompt_len = 2
        args.new_tokens = 3
        args.slots = 4
        args.max_seq = 8
        args.rate = 50.0

    reqs, gaps = _workload(args)
    oracle = _oracle_outputs(args, reqs)

    rows = []
    row_c, blob_c = run_policy(args, "continuous", reqs, gaps, oracle)
    rows.append(row_c)
    metrics_continuous = METRICS.snapshot()
    row_s, blob_s = run_policy(args, "serial", reqs, gaps, oracle)
    rows.append(row_s)

    speedup = round(blob_c["req_per_s"] / max(blob_s["req_per_s"], 1e-9), 2)
    beats = blob_c["req_per_s"] >= blob_s["req_per_s"]
    rows.append((f"serve.fleet.compare.{args.arch}", 0.0, json.dumps({
        "BENCH": "serve.fleet.compare",
        "arch": args.arch,
        "continuous_req_per_s": blob_c["req_per_s"],
        "serial_req_per_s": blob_s["req_per_s"],
        "speedup_x": speedup,
        "continuous_beats_serial": beats,
    }, sort_keys=True)))

    if args.metrics:
        # merge the continuous phase back in so the export covers both
        # policies (run_policy clears between phases)
        for name, v in metrics_continuous["counters"].items():
            METRICS.incr(name, v)
        for name, stats in metrics_continuous["observations"].items():
            for v in stats["values"]:
                METRICS.observe(name, v)
        METRICS.save(args.metrics)

    writer = csv.writer(sys.stdout)
    for row in rows:
        writer.writerow(row)
    # the tentpole's hard gate: batching must pay for itself
    assert beats, (
        f"continuous batching ({blob_c['req_per_s']} req/s) does not "
        f"beat serial dispatch ({blob_s['req_per_s']} req/s)")


if __name__ == "__main__":
    main()
