"""Per-kernel shape/dtype sweeps: Pallas (interpret mode) vs ref oracle."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.bitserial_gemm import bitserial_gemm
from repro.kernels.flash_attention import flash_attention
from repro.kernels.int4_gemm import int4_gemm
from repro.kernels import ops

RNG = np.random.default_rng(0)


# ---------------------------------------------------------------------------
# representation helpers
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("bits", [2, 3, 4, 5, 6, 7, 8])
def test_bitplane_roundtrip(bits):
    q = RNG.integers(-(2 ** (bits - 1)), 2 ** (bits - 1), (17, 23))
    planes = ref.bitplane_decompose(jnp.asarray(q), bits)
    assert planes.shape == (bits, 17, 23)
    assert set(np.unique(np.asarray(planes))) <= {0, 1}
    rec = ref.bitplane_reconstruct(planes)
    np.testing.assert_array_equal(np.asarray(rec), q)


def test_int4_pack_roundtrip():
    q = RNG.integers(-8, 8, (9, 24))
    packed = ref.pack_int4(jnp.asarray(q))
    assert packed.shape == (9, 12)
    np.testing.assert_array_equal(np.asarray(ref.unpack_int4(packed)), q)


# ---------------------------------------------------------------------------
# bitserial kernel sweep
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("m,k,n", [(64, 64, 64), (128, 256, 128),
                                   (64, 128, 192)])
@pytest.mark.parametrize("bits", [2, 5, 8])
def test_bitserial_kernel_vs_oracle(m, k, n, bits):
    x = RNG.integers(-8, 8, (m, k)).astype(np.int8)
    wq = RNG.integers(-(2 ** (bits - 1)), 2 ** (bits - 1),
                      (k, n)).astype(np.int32)
    scale = RNG.uniform(0.01, 0.2, n).astype(np.float32)
    planes = ref.bitplane_decompose(jnp.asarray(wq), bits)
    out = bitserial_gemm(jnp.asarray(x), planes, jnp.asarray(scale), bits,
                         bm=64, bn=64, bk=64, interpret=True)
    want = ref.bitserial_gemm_ref(jnp.asarray(x), jnp.asarray(wq),
                                  jnp.asarray(scale), bits)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-6)


def test_bitserial_exact_integer_semantics():
    """fp32 output must equal exact integer GEMM x scale."""
    bits = 6
    x = RNG.integers(-8, 8, (64, 64)).astype(np.int8)
    wq = RNG.integers(-32, 32, (64, 64)).astype(np.int32)
    scale = np.ones(64, np.float32)
    planes = ref.bitplane_decompose(jnp.asarray(wq), bits)
    out = bitserial_gemm(jnp.asarray(x), planes, jnp.asarray(scale), bits,
                         bm=64, bn=64, bk=64, interpret=True)
    exact = x.astype(np.int64) @ wq.astype(np.int64)
    np.testing.assert_array_equal(np.asarray(out).astype(np.int64), exact)


# ---------------------------------------------------------------------------
# int4 kernel sweep
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("m,k,n", [(64, 64, 64), (64, 128, 128),
                                   (128, 64, 128)])
def test_int4_kernel_vs_oracle(m, k, n):
    x = RNG.integers(-8, 8, (m, k)).astype(np.int8)
    wq = RNG.integers(-8, 8, (k, n)).astype(np.int32)
    packed = ref.pack_int4(jnp.asarray(wq))
    scale = RNG.uniform(0.01, 0.2, n).astype(np.float32)
    out = int4_gemm(jnp.asarray(x), packed, jnp.asarray(scale),
                    bm=64, bn=64, bk=64, interpret=True)
    want = ref.int4_gemm_ref(jnp.asarray(x), packed, jnp.asarray(scale))
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-6)


# ---------------------------------------------------------------------------
# flash attention sweep
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("b,h,s,d", [(1, 2, 128, 32), (2, 3, 192, 64)])
def test_flash_vs_oracle(b, h, s, d, causal):
    q = (RNG.standard_normal((b, h, s, d)) * 0.3).astype(np.float32)
    k = (RNG.standard_normal((b, h, s, d)) * 0.3).astype(np.float32)
    v = RNG.standard_normal((b, h, s, d)).astype(np.float32)
    out = flash_attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                          causal=causal, bq=64, bkv=64, interpret=True)
    want = ref.flash_attention_ref(jnp.asarray(q), jnp.asarray(k),
                                   jnp.asarray(v), causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=3e-5, atol=3e-5)


def test_flash_decode_offset():
    b, h, s, d = 2, 2, 128, 32
    q = (RNG.standard_normal((b, h, 1, d)) * 0.3).astype(np.float32)
    k = (RNG.standard_normal((b, h, s, d)) * 0.3).astype(np.float32)
    v = RNG.standard_normal((b, h, s, d)).astype(np.float32)
    out = flash_attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                          causal=True, kv_offset=s - 1, bq=1, bkv=64,
                          interpret=True)
    want = ref.flash_attention_ref(jnp.asarray(q), jnp.asarray(k),
                                   jnp.asarray(v), causal=True,
                                   kv_offset=s - 1)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=3e-5, atol=3e-5)


# ---------------------------------------------------------------------------
# ops wrappers (padding + split)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n_serial", [0, 16, 40])
def test_hetero_matmul_equals_dense(n_serial):
    m, k, n = 32, 48, 40
    x = RNG.integers(-8, 8, (m, k)).astype(np.int8)
    wq = RNG.integers(-8, 8, (k, n)).astype(np.int32)
    s = np.full(n, 0.05, np.float32)
    out = ops.hetero_matmul(jnp.asarray(x), jnp.asarray(wq[:, :n_serial]),
                            jnp.asarray(s[:n_serial]), 6,
                            jnp.asarray(wq[:, n_serial:]),
                            jnp.asarray(s[n_serial:]))
    want = (x.astype(np.int64) @ wq) * 0.05
    np.testing.assert_allclose(np.asarray(out), want, rtol=1e-5)


def test_attention_wrapper_gqa():
    b, hq, hkv, s, d = 2, 8, 2, 96, 32
    q = jnp.asarray(RNG.standard_normal((b, hq, s, d)) * 0.3, jnp.float32)
    k = jnp.asarray(RNG.standard_normal((b, hkv, s, d)) * 0.3, jnp.float32)
    v = jnp.asarray(RNG.standard_normal((b, hkv, s, d)), jnp.float32)
    out = ops.attention(q, k, v, causal=True)
    kr = jnp.repeat(k, hq // hkv, axis=1)
    vr = jnp.repeat(v, hq // hkv, axis=1)
    want = ref.flash_attention_ref(q, kr, vr, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=3e-5, atol=3e-5)
