"""Functional conv execution: im2col staging, depthwise, CNN chains.

The contract under test (ISSUE 4 acceptance surface):
  * per-layer equivalence vs the ``models/cnn.py`` reference conv: the
    golden executor's im2col-staged GEMM equals ``cnn.conv2d`` (the
    network's ``lax.conv_general_dilated`` primitive) exactly, in the
    integer code domain, for dense and depthwise layers;
  * whole-CNN inference: resnet18 and mobilenet_v2 programs (reduced
    geometry-consistent variants) run end to end through the spatial
    chain — shortcut sources, max-pool/GAP glue, inter-layer requant —
    with pallas bit-identical to golden;
  * -O0 vs -O1 invariance on depthwise programs (passes change timing,
    never semantics);
  * programs carry their ConvGeometry bit-exactly through text assembly
    and the ``N3HPROG1`` binary image, and the memory map wires conv
    act fetches straight to the producer's spatial segment (no
    ``L{i}.col`` staging — the fused kernels im2col on chip);
  * multi-device bundles of CNNs (filter shards of depthwise layers,
    pipeline stages) stay bit-exact vs the single-device program.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.compiler import (
    ConvGeometry,
    GemmLayer,
    GoldenExecutor,
    MultiDeviceExecutor,
    PallasExecutor,
    assemble,
    bind_synthetic,
    compile_network,
    derive_plan,
    disassemble,
    from_binary,
    lower_network,
    lower_partitioned,
    optimize_program,
    to_binary,
)
from repro.compiler.cli import execute_report
from repro.compiler.runtime import (
    ExecutionError,
    apply_pool,
    im2col_patches,
    synthetic_weights,
)
from repro.core.scheduler import XC7Z020, DspCoreConfig, LutCoreConfig
from repro.core.workloads import WORKLOADS, ConvSpec
from repro.models import cnn
from repro.models.cnn import CNNConfig, specs_for

LUT = LutCoreConfig(m=8, n=16, k=128)
DSP = DspCoreConfig(n_reg_row_a=13)


def _cnn_layers(arch: str, in_hw: int = 28, width: float = 0.25):
    cfg = CNNConfig(arch=arch, n_classes=10, in_hw=in_hw, width=width)
    return [GemmLayer.from_conv(s) for s in specs_for(cfg)]


def _bound(cls, prog, **kw):
    ex = cls(prog, **kw)
    for lp in prog.layers:
        bind_synthetic(ex, lp, seed=lp.index)
    return ex


def _image(gl: GemmLayer, seed: int = 0) -> np.ndarray:
    return np.random.default_rng(seed).integers(
        -8, 8, gl.geometry.in_shape).astype(np.int8)


# ---------------------------------------------------------------------------
# Per-layer equivalence vs the models/cnn.py reference conv
# ---------------------------------------------------------------------------

CONV_CASES = [
    ConvSpec("k3s1", 5, 24, 3, 1, 10),
    ConvSpec("k3s2", 7, 20, 3, 2, 9),
    ConvSpec("k7s2", 3, 18, 7, 2, 16),        # the ResNet stem shape
    ConvSpec("k1s1", 12, 30, 1, 1, 6),        # pointwise
    ConvSpec("k1s2", 8, 16, 1, 2, 8),         # downsample shortcut
    ConvSpec("dw3s1", 20, 20, 3, 1, 8, depthwise=True),
    ConvSpec("dw3s2", 24, 24, 3, 2, 9, depthwise=True),
]


@pytest.mark.parametrize("spec", CONV_CASES, ids=lambda s: s.name)
def test_golden_matches_cnn_reference_conv(spec):
    """Im2col staging + (grouped) GEMM == lax.conv on the same codes.

    Both sides stay in exact arithmetic: integer activations/weight
    codes accumulate exactly (int32 GEMM vs fp32 conv of small ints),
    then the same per-filter fp32 scale applies — so equality is ==.
    """
    gl = GemmLayer.from_conv(spec)
    n_lut = gl.dims.n // 3
    prog = lower_network("one", [gl], LUT, DSP, XC7Z020, n_luts=[n_lut])
    ex = _bound(GoldenExecutor, prog)
    x = _image(gl, seed=7)
    got = np.asarray(ex.run_layer(0, x))

    w_lut, s_lut, w_dsp, s_dsp = synthetic_weights(
        0, gl.dims.k, n_lut, gl.dims.n - n_lut, 4, seed=0)
    w = np.concatenate([p for p in (w_lut, w_dsp) if p is not None], axis=1)
    s = np.concatenate([p for p in (s_lut, s_dsp) if p is not None])
    kk, ci = spec.kernel, 1 if spec.depthwise else spec.c_in
    w_hwio = w.reshape(kk, kk, ci, spec.c_out).astype(np.float32)
    ref = cnn.conv2d(jnp.asarray(x, jnp.float32)[None],
                     jnp.asarray(w_hwio), spec)
    ref = np.asarray(ref)[0].reshape(-1, spec.c_out) * s[None, :]
    assert got.shape == (gl.dims.m, gl.dims.n)
    assert (got == ref.astype(np.float32)).all()


def test_im2col_patch_order_matches_hwio_flattening():
    # column order (kh, kw, c) with c fastest == w.reshape(k, n) order
    geom = ConvGeometry(kernel=2, stride=1, pad=1, in_hw=3, out_hw=4,
                        c_in=2, c_out=1)
    x = np.arange(18, dtype=np.int8).reshape(3, 3, 2)
    pat = np.asarray(im2col_patches(jnp.asarray(x), geom))
    assert pat.shape == (16, 4, 2)
    # output position (1, 1) covers input rows/cols 0..1 (pad 1)
    m = 1 * 4 + 1
    want = np.stack([x[0, 0], x[0, 1], x[1, 0], x[1, 1]])
    assert (pat[m] == want).all()


# ---------------------------------------------------------------------------
# Whole-CNN inference: golden vs pallas, end to end
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ["resnet18", "mobilenet_v2"])
def test_cnn_end_to_end_pallas_bit_exact_vs_golden(arch):
    layers = _cnn_layers(arch)
    prog = lower_network(arch, layers, LUT, DSP, XC7Z020)
    x = _image(layers[0])
    out_g = np.asarray(_bound(GoldenExecutor, prog).run(x))
    out_p = np.asarray(_bound(PallasExecutor, prog).run(x))
    assert out_g.shape == (1, 10)
    assert np.abs(out_g).sum() > 0
    assert (out_g == out_p).all()


def test_resnet_chain_exercises_shortcut_and_pools():
    layers = _cnn_layers("resnet18")
    by_name = {gl.name: gl for gl in layers}
    assert by_name["conv1"].geometry.pool == "max"
    assert by_name["conv20"].geometry.pool == "gap"
    assert by_name["conv8_ds"].geometry.src_offset == 3
    # the shortcut reads the same spatial input as the block entry
    i = layers.index(by_name["conv8_ds"])
    src = layers[i - 3]
    assert src.geometry.pooled_hw() == by_name["conv8_ds"].geometry.in_hw
    assert src.geometry.c_out == by_name["conv8_ds"].geometry.c_in


def test_chain_rejects_wrong_input_shape():
    layers = _cnn_layers("resnet18")
    prog = lower_network("r", layers, LUT, DSP, XC7Z020)
    ex = _bound(GoldenExecutor, prog)
    with pytest.raises(ExecutionError, match="spatial"):
        ex.run(np.zeros((5, 5, 3), np.int8))


def test_apply_pool_glue():
    x = jnp.asarray(np.random.default_rng(0).normal(size=(8, 8, 4)),
                    jnp.float32)
    assert apply_pool(x, "").shape == (8, 8, 4)
    assert apply_pool(x, "max").shape == (4, 4, 4)
    gap = apply_pool(x, "gap")
    assert gap.shape == (1, 1, 4)
    np.testing.assert_allclose(np.asarray(gap)[0, 0],
                               np.asarray(x).mean(axis=(0, 1)), rtol=1e-6)


# ---------------------------------------------------------------------------
# -O0 vs -O1 invariance on depthwise programs
# ---------------------------------------------------------------------------


def test_depthwise_program_pass_invariant():
    # a mobilenet bottleneck slice: expand -> depthwise -> project
    specs = [ConvSpec("exp", 8, 48, 1, 1, 12),
             ConvSpec("dw", 48, 48, 3, 2, 12, depthwise=True),
             ConvSpec("pw", 48, 16, 1, 1, 6)]
    layers = [GemmLayer.from_conv(s) for s in specs]
    p0 = lower_network("block", layers, LUT, DSP, XC7Z020)
    p1 = optimize_program(p0, 1)
    assert p1.n_instructions < p0.n_instructions
    x = _image(layers[0], seed=3)
    out0 = np.asarray(_bound(GoldenExecutor, p0).run(x))
    out1 = np.asarray(_bound(GoldenExecutor, p1).run(x))
    outp = np.asarray(_bound(PallasExecutor, p1).run(x))
    assert (out0 == out1).all()
    assert (out0 == outp).all()


# ---------------------------------------------------------------------------
# Geometry round-trips + staging memory map
# ---------------------------------------------------------------------------


def test_geometry_round_trips_text_and_binary():
    layers = _cnn_layers("mobilenet_v2")
    prog = lower_network("mb2", layers, LUT, DSP, XC7Z020, opt_level=1)
    assert any(lp.depthwise for lp in prog.layers)
    text = disassemble(prog)
    assert " geom=" in text
    rt = assemble(text)
    assert rt == prog
    assert disassemble(rt) == text
    blob = to_binary(prog)
    rt2 = from_binary(blob)
    assert rt2 == prog
    assert to_binary(rt2) == blob
    for a, b in zip(prog.layers, rt2.layers):
        assert a.geometry == b.geometry


def test_memory_map_has_no_col_staging_segments():
    """Fused-kernel DDR map: conv layers read their producer's spatial
    NHWC segment directly (im2col happens inside the kernel) — no
    ``L{i}.col`` staging copy exists, and the act fetches address the
    ``src_offset`` producer's output (or ``act.in``)."""
    layers = _cnn_layers("resnet18")
    prog = lower_network("r", layers, LUT, DSP, XC7Z020)
    mem = prog.memory
    g0 = layers[0].geometry
    # program input is the spatial image, not its im2col expansion
    assert mem["act.in"].size == \
        (g0.in_hw * g0.in_hw * g0.c_in * 4 + 7) // 8
    assert not any(".col" in seg.name for seg in mem.segments)
    for pos, lp in enumerate(prog.layers):
        src = pos - lp.geometry.src_offset
        seg = mem["act.in"] if src < 0 else mem[f"L{src}.out"]
        # the act fetches address the producer's spatial segment
        for cp in lp.cores():
            from repro.core import isa
            bases = {op.instr.ddr_base for op in cp.streams["fetch"]
                     if isinstance(op.instr, isa.FetchInstr)
                     and op.instr.stage_ctrl == 1}
            assert bases == {seg.base}


def test_full_size_workload_geometry_is_chain_consistent():
    for name, fn in WORKLOADS.items():
        layers = [GemmLayer.from_conv(s) for s in fn()]
        for i, gl in enumerate(layers[1:], start=1):
            src = layers[i - gl.geometry.src_offset].geometry
            assert src.pooled_hw() == gl.geometry.in_hw, (name, gl.name)
            assert src.c_out == gl.geometry.c_in, (name, gl.name)


# ---------------------------------------------------------------------------
# Multi-device CNN bundles stay bit-exact
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kind", ["filter", "pipeline"])
def test_cnn_bundle_bit_exact_vs_single(kind):
    layers = _cnn_layers("mobilenet_v2")
    prog = lower_network("mb2", layers, LUT, DSP, XC7Z020)
    x = _image(layers[0])
    ref = np.asarray(_bound(GoldenExecutor, prog).run(x))
    plan = derive_plan(layers, 2, kind)
    mdp = lower_partitioned("mb2", layers, plan, LUT, DSP, XC7Z020)
    mex = MultiDeviceExecutor(mdp)
    for gi in range(mdp.n_layers):
        mex.bind_synthetic(gi, seed=gi)
    assert (np.asarray(mex.run(x)) == ref).all()


def test_filter_shard_of_depthwise_layer_bit_exact():
    # shard a lone depthwise layer: each device computes its channel
    # range from its own input slice; gathered shards == full layer
    spec = ConvSpec("dw", 32, 32, 3, 1, 10, depthwise=True)
    gl = GemmLayer.from_conv(spec)
    prog = lower_network("dw", [gl], LUT, DSP, XC7Z020)
    x = _image(gl, seed=11)
    ex = _bound(GoldenExecutor, prog)
    ref = np.asarray(ex.run_layer(0, x))
    plan = derive_plan([gl], 2, "filter")
    mdp = lower_partitioned("dw", [gl], plan, LUT, DSP, XC7Z020)
    mex = MultiDeviceExecutor(mdp)
    mex.bind_synthetic(0, seed=0)
    got = np.asarray(mex.run_layer(0, x))
    assert (got == ref).all()


# ---------------------------------------------------------------------------
# CLI --execute end-to-end report
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["golden", "pallas"])
def test_execute_report_runs_cnn_end_to_end(backend):
    specs = [ConvSpec("c0", 3, 16, 3, 2, 12, is_first=True),
             ConvSpec("dw", 16, 16, 3, 1, 6, depthwise=True),
             ConvSpec("fc", 16, 10, 1, 1, 6, is_last=True)]
    # fc here is a plain 1x1 conv on the 6x6 map (no GAP glue)
    layers = [GemmLayer.from_conv(s) for s in specs]
    prog = lower_network("tiny", layers, LUT, DSP, XC7Z020)
    report = execute_report(prog, backend=backend)
    assert "executed  3/3 layers end to end" in report
    assert "skipped" not in report


def test_execute_report_checksum_matches_across_backends():
    layers = _cnn_layers("mobilenet_v2", in_hw=14)
    prog = lower_network("mb2", layers, LUT, DSP, XC7Z020)
    r_g = execute_report(prog, backend="golden")
    r_p = execute_report(prog, backend="pallas")
    assert r_g.split("|out| sum")[1] == r_p.split("|out| sum")[1]


def test_compile_network_cnn_carries_geometry():
    prog = compile_network("resnet18")
    assert all(lp.geometry is not None for lp in prog.layers)
    lm = compile_network("llama3.2-1b", seq_len=4)
    assert all(lp.geometry is None for lp in lm.layers)
