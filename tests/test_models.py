"""Model-zoo behavioral tests: decode == forward, SSD == recurrence,
hetero-quant forward trains."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import encdec, hybrid, layers as L, lm, ssm
from repro.kernels.ref import flash_attention_ref


def _dense_cfg(**kw):
    base = dict(name="t", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                head_dim=16, d_ff=128, vocab=300, vocab_pad_multiple=16,
                param_dtype=jnp.float32)
    base.update(kw)
    return lm.LMConfig(**base)


def test_blockwise_attention_matches_oracle():
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((2, 80, 8, 32)) * 0.3, jnp.float32)
    k = jnp.asarray(rng.standard_normal((2, 80, 2, 32)) * 0.3, jnp.float32)
    v = jnp.asarray(rng.standard_normal((2, 80, 2, 32)), jnp.float32)
    out = L.blockwise_attention(q, k, v, causal=True, q_chunk=32,
                                kv_chunk=32)
    kr = jnp.repeat(k, 4, axis=2).transpose(0, 2, 1, 3)
    vr = jnp.repeat(v, 4, axis=2).transpose(0, 2, 1, 3)
    want = flash_attention_ref(q.transpose(0, 2, 1, 3), kr, vr,
                               causal=True).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=3e-5, atol=3e-5)


@pytest.mark.parametrize("qk_norm", [False, True])
def test_lm_decode_matches_forward(qk_norm):
    cfg = _dense_cfg(qk_norm=qk_norm)
    p = lm.init(cfg, jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (2, 16), 0, 300)
    logits, _ = lm.forward(p, toks, cfg)
    cache = lm.init_cache(cfg, 2, 24, jnp.float32)
    dec = []
    for t in range(8):
        lg, cache = lm.decode_step(p, toks[:, t:t + 1], cache, t, cfg)
        dec.append(lg)
    err = float(jnp.abs(jnp.stack(dec, 1) - logits[:, :8]).max())
    assert err < 2e-3, err


def test_lm_prefill_then_decode_matches_forward():
    cfg = _dense_cfg()
    p = lm.init(cfg, jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (2, 16), 0, 300)
    logits, _ = lm.forward(p, toks, cfg)
    cache = lm.init_cache(cfg, 2, 24, jnp.float32)
    lg, cache = lm.prefill(p, toks[:, :8], cache, cfg)
    assert float(jnp.abs(lg - logits[:, :8]).max()) < 2e-3
    lg2, cache = lm.decode_step(p, toks[:, 8:9], cache, 8, cfg)
    assert float(jnp.abs(lg2 - logits[:, 8]).max()) < 2e-3


def test_mla_decode_matches_forward():
    cfg = _dense_cfg(n_kv_heads=4,
                     mla=lm.MLAConfig(kv_lora=32, q_lora=48, qk_nope_dim=16,
                                      qk_rope_dim=8, v_dim=16))
    p = lm.init(cfg, jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (2, 12), 0, 300)
    logits, _ = lm.forward(p, toks, cfg)
    cache = lm.init_cache(cfg, 2, 16, jnp.float32)
    dec = []
    for t in range(6):
        lg, cache = lm.decode_step(p, toks[:, t:t + 1], cache, t, cfg)
        dec.append(lg)
    err = float(jnp.abs(jnp.stack(dec, 1) - logits[:, :6]).max())
    assert err < 2e-2, err


def test_moe_decode_matches_forward_high_capacity():
    cfg = _dense_cfg(moe=L.MoEConfig(n_experts=8, top_k=2, d_ff=96,
                                     n_shared=1, group_size=64,
                                     capacity_factor=8.0))
    p = lm.init(cfg, jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (2, 12), 0, 300)
    logits, aux = lm.forward(p, toks, cfg)
    assert float(aux) > 0.0                      # balance loss is live
    cache = lm.init_cache(cfg, 2, 16, jnp.float32)
    dec = []
    for t in range(6):
        lg, cache = lm.decode_step(p, toks[:, t:t + 1], cache, t, cfg)
        dec.append(lg)
    err = float(jnp.abs(jnp.stack(dec, 1) - logits[:, :6]).max())
    assert err < 2e-2, err


def test_ssd_chunked_equals_stepwise():
    rng = np.random.default_rng(0)
    B, S, H, P, G, N = 2, 40, 4, 8, 2, 16
    cfg = ssm.SSMConfig(d_model=32, d_inner=H * P, head_dim=P, d_state=N,
                        n_groups=G, chunk=16)
    x = jnp.asarray(rng.standard_normal((B, S, H, P)) * 0.5, jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.2, (B, S, H)), jnp.float32)
    a = -jnp.asarray(rng.uniform(0.5, 2.0, H), jnp.float32)
    b = jnp.asarray(rng.standard_normal((B, S, G, N)) * 0.3, jnp.float32)
    c = jnp.asarray(rng.standard_normal((B, S, G, N)) * 0.3, jnp.float32)
    y, fin = ssm.ssd_chunked(x, dt, a, b, c, cfg)
    st = jnp.zeros((B, H, P, N))
    ys = []
    for t in range(S):
        yt, st = ssm.ssd_step(x[:, t], dt[:, t], a, b[:, t], c[:, t], st)
        ys.append(yt)
    np.testing.assert_allclose(np.asarray(y), np.asarray(jnp.stack(ys, 1)),
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(fin), np.asarray(st), atol=1e-4)


def test_ssm_lm_decode_matches_forward():
    cfg = ssm.SSMLMConfig(
        "t", n_layers=2, d_model=32, vocab=120, vocab_pad_multiple=8,
        ssm=ssm.SSMConfig(d_model=32, d_inner=64, head_dim=16, d_state=16,
                          chunk=16),
        param_dtype=jnp.float32)
    p = ssm.init(cfg, jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (2, 12), 0, 120)
    logits, _ = ssm.forward(p, toks, cfg)
    cache = ssm.init_cache(cfg, 2, dtype=jnp.float32)
    dec = []
    for t in range(8):
        lg, cache = ssm.decode_step(p, toks[:, t:t + 1], cache, t, cfg)
        dec.append(lg)
    err = float(jnp.abs(jnp.stack(dec, 1) - logits[:, :8]).max())
    assert err < 1e-3, err


def test_hybrid_decode_matches_forward():
    cfg = hybrid.HybridConfig(
        "t", n_layers=8, d_model=48, n_heads=4, n_kv_heads=2, head_dim=12,
        d_ff=96, vocab=130, vocab_pad_multiple=8,
        ssm=ssm.SSMConfig(d_model=48, d_inner=96, head_dim=16, d_state=16,
                          chunk=16),
        moe=L.MoEConfig(n_experts=4, top_k=2, d_ff=64, group_size=32,
                        capacity_factor=8.0),
        param_dtype=jnp.float32)
    p = hybrid.init(cfg, jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (2, 12), 0, 130)
    logits, _ = hybrid.forward(p, toks, cfg)
    cache = hybrid.init_cache(cfg, 2, 16, jnp.float32)
    dec = []
    for t in range(6):
        lg, cache = hybrid.decode_step(p, toks[:, t:t + 1], cache, t, cfg)
        dec.append(lg)
    err = float(jnp.abs(jnp.stack(dec, 1) - logits[:, :6]).max())
    assert err < 2e-3, err


def test_encdec_decode_matches_forward():
    cfg = encdec.EncDecConfig(
        "t", n_enc_layers=2, n_dec_layers=2, d_model=48, n_heads=4,
        n_kv_heads=2, head_dim=12, d_ff=96, vocab=130,
        vocab_pad_multiple=8, param_dtype=jnp.float32)
    p = encdec.init(cfg, jax.random.key(0))
    frames = 0.5 * jax.random.normal(jax.random.key(1), (2, 20, 48))
    toks = jax.random.randint(jax.random.key(2), (2, 12), 0, 130)
    logits, _ = encdec.forward(p, frames, toks, cfg)
    memory = encdec.encode(p, frames, cfg)
    cache = encdec.init_cache(cfg, 2, 16, 20, jnp.float32)
    cache = encdec.build_cross_cache(p, memory, cfg, cache, jnp.float32)
    dec = []
    for t in range(6):
        lg, cache = encdec.decode_step(p, toks[:, t:t + 1], cache, t, cfg)
        dec.append(lg)
    err = float(jnp.abs(jnp.stack(dec, 1) - logits[:, :6]).max())
    assert err < 2e-3, err


def test_mrope_reduces_to_rope_for_text():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((2, 10, 4, 64)), jnp.float32)
    pos = jnp.arange(10)[None].repeat(2, 0)
    a = L.apply_rope(x, pos)
    b = L.apply_mrope(x, jnp.stack([pos] * 3), (16, 8, 8))
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_hetero_quant_lm_trains():
    cfg = _dense_cfg(hetero_quant=lm.HeteroQuantConfig(w_bits_lut=8,
                                                       a_bits=8, ratio=0.5))
    p = lm.init(cfg, jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (2, 16), 0, 300)

    def loss(p):
        lg, aux = lm.forward(p, toks, cfg)
        return jnp.mean((lg[:, :-1] - jax.nn.one_hot(toks[:, 1:], 300)) ** 2)

    g = jax.grad(loss)(p)
    norms = [float(jnp.abs(x).sum()) for x in jax.tree.leaves(g)]
    assert all(np.isfinite(norms)) and sum(norms) > 0


def test_cache_write_semantics():
    cache = jnp.zeros((2, 6, 3))
    new = jnp.ones((2, 1, 3))
    out = L.cache_write(cache, new, 4)
    assert float(out[:, 4].min()) == 1.0
    assert float(out.sum()) == 2 * 3
    # full-length write replaces
    full = L.cache_write(cache, 2 * jnp.ones((2, 6, 3)), 0)
    assert float(full.min()) == 2.0


def test_int8_kv_cache_decode_close_to_fp():
    """Q2 optimization: int8 KV cache (per-head prefill-calibrated
    scales) tracks the fp forward within quantization noise."""
    cfg = _dense_cfg(kv_cache_quant=True)
    p = lm.init(cfg, jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (2, 24), 0, 300)
    logits, _ = lm.forward(p, toks, cfg)
    cache = lm.init_cache(cfg, 2, 32, jnp.float32)
    assert cache["layers"]["k"].dtype == jnp.int8
    lg, cache = lm.prefill(p, toks[:, :8], cache, cfg)
    dec = []
    for t in range(8, 12):
        lgt, cache = lm.decode_step(p, toks[:, t:t + 1], cache, t, cfg)
        dec.append(lgt)
    err = float(jnp.abs(jnp.stack(dec, 1) - logits[:, 8:12]).max())
    rel = err / float(jnp.abs(logits[:, 8:12]).max())
    assert rel < 0.06, rel
