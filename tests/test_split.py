"""Eq. 12 split solver: exactness vs brute force + Fig. 7 structure."""
import hypothesis.strategies as st
from hypothesis import given, settings

from repro.core.scheduler import XC7Z020, DspCoreConfig, LutCoreConfig
from repro.core.split import brute_force_split, solve_split
from repro.core.workloads import ConvSpec, resnet18_specs

LUT = LutCoreConfig(m=8, n=16, k=128)
DSP = DspCoreConfig(n_reg_row_a=13)


@settings(max_examples=20, deadline=None)
@given(c_in=st.integers(16, 256), c_out=st.integers(16, 256),
       hw=st.sampled_from([7, 14, 28]), kernel=st.sampled_from([1, 3]),
       bw=st.integers(2, 8), ba=st.integers(2, 4))
def test_vectorized_matches_bruteforce(c_in, c_out, hw, kernel, bw, ba):
    spec = ConvSpec("t", c_in, c_out, kernel, 1, hw)
    fast = solve_split(spec, LUT, DSP, XC7Z020, bw, ba)
    slow = brute_force_split(spec, LUT, DSP, XC7Z020, bw, ba)
    assert fast.cycles == slow.cycles
    assert fast.n_lut == slow.n_lut


def test_split_beats_either_extreme():
    """Fig. 7: the makespan-optimal split beats pure-LUT and pure-DSP."""
    spec = resnet18_specs()[13]            # a middle conv layer
    sol = solve_split(spec, LUT, DSP, XC7Z020, 4, 4, keep_curve=True)
    curve = sol.curve
    assert sol.cycles <= curve[0]          # all-DSP (n_lut = 0)
    assert sol.cycles <= curve[-1]         # all-LUT
    assert 0 < sol.n_lut < spec.gemm().n   # interior optimum


def test_split_curve_is_max_of_monotone_pieces():
    spec = resnet18_specs()[10]
    sol = solve_split(spec, LUT, DSP, XC7Z020, 4, 4, keep_curve=True)
    best = int(sol.n_lut)
    curve = sol.curve
    # left of the optimum the DSP side dominates (nonincreasing);
    # right of it the LUT side dominates (nondecreasing)
    assert all(curve[i] >= curve[i + 1] - 1e-9 for i in range(best))
    assert all(curve[i] <= curve[i + 1] + 1e-9
               for i in range(best, len(curve) - 1))


def test_ratio_moves_with_lut_bits():
    """More LUT-path bits -> slower LUT core -> fewer filters routed
    to it (the §6.2.2 behavior the agent exploits)."""
    spec = resnet18_specs()[10]
    lo = solve_split(spec, LUT, DSP, XC7Z020, 2, 2)
    hi = solve_split(spec, LUT, DSP, XC7Z020, 8, 4)
    assert hi.n_lut <= lo.n_lut
