"""Test-suite bootstrap.

Prefers the real `hypothesis` package; when it is not installed (this
container does not ship it) the deterministic shim in
`_hypothesis_compat.py` is registered under the same module names so
the property-test modules collect and run everywhere.
"""
import os
import sys

sys.path.insert(0, os.path.dirname(__file__))

try:
    import hypothesis  # noqa: F401
except ImportError:
    import _hypothesis_compat
    _hypothesis_compat.install()
