"""Cost model (Eqs. 3-5) + latency model (Eqs. 6-10) properties.

The paper validates its closed-form latency model against hardware at
<2% error (Fig. 5); offline we validate the closed form against the
event-driven instruction simulator — the Fig. 5 reproduction lives in
benchmarks/paper_fig5.py, these tests pin the agreement bound and the
structural properties the DSE relies on.
"""
import hypothesis.strategies as st
import numpy as np
import pytest
from hypothesis import given, settings

from repro.core.cost_model import (
    bram_cost_dsp_core,
    bram_cost_lut_core,
    lut_cost_lut_core,
    max_lut_core_mn,
    system_cost,
)
from repro.core.latency_model import dsp_core_latency, lut_core_latency
from repro.core.scheduler import (
    XC7Z020,
    XC7Z045,
    DspCoreConfig,
    GemmDims,
    LutCoreConfig,
    simulate_dsp_core,
    simulate_lut_core,
)


def test_lut_cost_eq4_exact():
    # Eq. 4 with the paper's coefficients at a known point
    assert lut_cost_lut_core(8, 128, 16) == pytest.approx(
        8 * 16 * (1.17 * 128 + 120.1 + 44.1) + 718)


def test_bram_cost_monotone():
    base = bram_cost_lut_core(8, 128, 16, 1024, 1024)
    assert bram_cost_lut_core(9, 128, 16, 1024, 1024) >= base
    assert bram_cost_lut_core(8, 160, 16, 1024, 1024) >= base
    assert bram_cost_lut_core(8, 128, 16, 2048, 1024) >= base


def test_dsp_bram_eq3_structure():
    # one activation buffer = ceil(R*4/32) BRAM columns
    v = bram_cost_dsp_core(13, 16, 16, 1024, 1024)
    assert v == int(np.ceil(13 * 4 / 32)) * (16 * 1 + 8 * 1)


def test_system_cost_paper_config_arithmetic():
    """Eqs. 3-5 on the paper's DA-ResNet-T35ms config (Table 3).

    NOTE: the paper's own Table 4 reports 137 BRAM for this design while
    Eqs. 3+5 as printed give 176 (> the device's 140) — the published
    equations and the published utilization are mutually inconsistent.
    We implement the equations as printed and record the discrepancy in
    EXPERIMENTS.md §Paper-repro; the DSE projects to feasibility under
    the equation-based budget, which is the conservative choice.
    """
    lut = LutCoreConfig(m=8, n=16, k=128, d_a=1024)
    dsp = DspCoreConfig(n_reg_row_a=DspCoreConfig.rows_for_device(XC7Z020),
                        d_a=2048, d_w=1024)
    rep = system_cost(lut, dsp, XC7Z020)
    assert rep.lut_core_brams == 4 * (8 * 1 + 16 * 1)       # Eq. 5
    assert rep.dsp_core_brams == 2 * (16 * 2 + 8 * 1)       # Eq. 3
    assert rep.luts < XC7Z020.luts                           # LUT fits
    assert rep.dsps == XC7Z020.dsps


def test_max_lut_core_mn_is_tight():
    for dev in (XC7Z020, XC7Z045):
        for k in (64, 128, 256):
            cap = max_lut_core_mn(dev, k)
            used = lut_cost_lut_core(cap, k, 1) + 1000
            assert used <= dev.luts
            over = lut_cost_lut_core(cap + 2, k, 1) + 1000
            assert over > dev.luts


@settings(max_examples=25, deadline=None)
@given(m=st.integers(64, 4096), k=st.integers(64, 2048),
       n=st.integers(16, 512), bw=st.integers(2, 8), ba=st.integers(2, 4))
def test_closed_form_tracks_simulator_lut(m, k, n, bw, ba):
    """Fig. 5 property: closed form within a few % of the event sim."""
    g = GemmDims(m, k, n)
    cfg = LutCoreConfig(m=8, n=16, k=128)
    sim = simulate_lut_core(g, cfg, XC7Z020, bw, ba).total_cycles
    model = float(lut_core_latency(m, k, n, cfg, XC7Z020, bw, ba))
    assert sim > 0
    rel = abs(model - sim) / sim
    # Fig. 5b: prediction error shrinks with workload size
    bound = 0.10 if sim < 50_000 else 0.03
    assert rel < bound, (m, k, n, bw, ba, model, sim)


@settings(max_examples=25, deadline=None)
@given(m=st.integers(64, 4096), k=st.integers(64, 2048),
       n=st.integers(16, 512))
def test_closed_form_tracks_simulator_dsp(m, k, n):
    g = GemmDims(m, k, n)
    cfg = DspCoreConfig(n_reg_row_a=13)
    sim = simulate_dsp_core(g, cfg, XC7Z020).total_cycles
    model = float(dsp_core_latency(m, k, n, cfg, XC7Z020))
    rel = abs(model - sim) / max(sim, 1)
    bound = 0.10 if sim < 50_000 else 0.03
    assert rel < bound, (m, k, n, model, sim)


def test_lut_latency_proportional_to_bits():
    """Bit-serial law: in the compute-bound regime latency grows
    ~linearly with bw * ba (fetch-bound shapes flatten out — that is
    physical, the fetch engine does not care about planes; a deep
    activation buffer keeps L resident so compute dominates)."""
    cfg = LutCoreConfig(m=8, n=16, k=128, d_a=64 * 1024)
    l22 = float(lut_core_latency(4096, 2048, 512, cfg, XC7Z020, 2, 2))
    l44 = float(lut_core_latency(4096, 2048, 512, cfg, XC7Z020, 4, 4))
    l88 = float(lut_core_latency(4096, 2048, 512, cfg, XC7Z020, 8, 8))
    assert l44 / l22 == pytest.approx(4.0, rel=0.30)
    assert l88 / l44 == pytest.approx(4.0, rel=0.30)


def test_dsp_latency_independent_of_bits():
    """Bit-parallel law: the DSP core has no bit-width knob at all."""
    cfg = DspCoreConfig(n_reg_row_a=13)
    l1 = float(dsp_core_latency(1024, 512, 256, cfg, XC7Z020))
    l2 = float(dsp_core_latency(1024, 512, 256, cfg, XC7Z020))
    assert l1 == l2


def test_zero_work_zero_latency():
    cfg = LutCoreConfig(m=8, n=16, k=128)
    assert float(lut_core_latency(1024, 512, 0, cfg, XC7Z020, 4, 4)) == 0.0
    dcfg = DspCoreConfig(n_reg_row_a=13)
    assert float(dsp_core_latency(1024, 512, 0, dcfg, XC7Z020)) == 0.0
