"""Fallback property-testing shim used when `hypothesis` is absent.

The tier-1 suite uses a small slice of the hypothesis API
(`given`/`settings`, `strategies.integers/floats/booleans/sampled_from`,
`extra.numpy.arrays/array_shapes`). This container does not ship
hypothesis, which used to make four test modules fail at *collection*.
`install()` registers a minimal, deterministic stand-in under the
`hypothesis` module names so those modules import and run everywhere;
when the real package is installed it is used untouched (see
``conftest.py``).

The stand-in draws pseudo-random examples from a per-test seeded
`random.Random`, so runs are reproducible; it does no shrinking and no
database — it is a sampler, not a fuzzer.
"""
from __future__ import annotations

import random
import sys
import types

import numpy as np

_DEFAULT_EXAMPLES = 20


class _Unsatisfied(Exception):
    """Raised by assume(False): skip this example."""


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def example(self, rng: random.Random):
        return self._draw(rng)

    def map(self, fn):
        return _Strategy(lambda rng: fn(self._draw(rng)))

    def filter(self, pred):
        def draw(rng):
            for _ in range(100):
                v = self._draw(rng)
                if pred(v):
                    return v
            raise _Unsatisfied
        return _Strategy(draw)


def integers(min_value, max_value):
    return _Strategy(lambda rng: rng.randint(min_value, max_value))


def booleans():
    return _Strategy(lambda rng: rng.random() < 0.5)


def floats(min_value=-1e9, max_value=1e9, allow_nan=False,
           allow_infinity=False, width=64):
    def draw(rng):
        v = rng.uniform(min_value, max_value)
        if width == 32:
            v = float(np.float32(v))
        return v
    return _Strategy(draw)


def sampled_from(elements):
    elements = list(elements)
    return _Strategy(lambda rng: rng.choice(elements))


def just(value):
    return _Strategy(lambda rng: value)


def one_of(*strategies):
    return _Strategy(lambda rng: rng.choice(strategies).example(rng))


def lists(elements, min_size=0, max_size=10):
    return _Strategy(lambda rng: [
        elements.example(rng)
        for _ in range(rng.randint(min_size, max_size))])


def tuples(*strategies):
    return _Strategy(lambda rng: tuple(s.example(rng) for s in strategies))


# -- hypothesis.extra.numpy ------------------------------------------------


def array_shapes(min_dims=1, max_dims=3, min_side=1, max_side=10):
    return _Strategy(lambda rng: tuple(
        rng.randint(min_side, max_side)
        for _ in range(rng.randint(min_dims, max_dims))))


def arrays(dtype, shape, elements=None, fill=None, unique=False):
    def draw(rng):
        shp = shape.example(rng) if isinstance(shape, _Strategy) else shape
        if isinstance(shp, int):
            shp = (shp,)
        size = int(np.prod(shp, dtype=np.int64)) if shp else 1
        if elements is not None:
            flat = [elements.example(rng) for _ in range(size)]
        elif np.issubdtype(np.dtype(dtype), np.integer):
            info = np.iinfo(dtype)
            flat = [rng.randint(info.min, info.max) for _ in range(size)]
        else:
            flat = [rng.uniform(-1e3, 1e3) for _ in range(size)]
        return np.asarray(flat, dtype=dtype).reshape(shp)
    return _Strategy(draw)


# -- given / settings / assume ---------------------------------------------


def assume(condition):
    if not condition:
        raise _Unsatisfied
    return True


def given(*args, **strategies):
    if args:
        raise TypeError(
            "the hypothesis shim only supports keyword strategies")

    def decorate(fn):
        def wrapper():
            n = getattr(wrapper, "_hypothesis_max_examples",
                        _DEFAULT_EXAMPLES)
            rng = random.Random(f"{fn.__module__}.{fn.__qualname__}")
            ran = 0
            for _ in range(n * 5):
                if ran >= n:
                    break
                try:
                    example = {k: s.example(rng)
                               for k, s in strategies.items()}
                    fn(**example)
                except _Unsatisfied:
                    continue
                except AssertionError as e:
                    raise AssertionError(
                        f"falsifying example (shim): {example!r}") from e
                ran += 1
            return None
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        wrapper.__qualname__ = fn.__qualname__
        return wrapper
    return decorate


def settings(max_examples=_DEFAULT_EXAMPLES, deadline=None, **_ignored):
    def decorate(fn):
        fn._hypothesis_max_examples = max_examples
        return fn
    return decorate


class HealthCheck:
    too_slow = "too_slow"
    data_too_large = "data_too_large"
    filter_too_much = "filter_too_much"

    @classmethod
    def all(cls):
        return [cls.too_slow, cls.data_too_large, cls.filter_too_much]


def install() -> None:
    """Register the shim under the `hypothesis` module names."""
    if "hypothesis" in sys.modules:
        return
    hyp = types.ModuleType("hypothesis")
    hyp.given = given
    hyp.settings = settings
    hyp.assume = assume
    hyp.HealthCheck = HealthCheck
    hyp.__version__ = "0.0-repro-shim"

    st = types.ModuleType("hypothesis.strategies")
    for name in ("integers", "booleans", "floats", "sampled_from", "just",
                 "one_of", "lists", "tuples"):
        setattr(st, name, globals()[name])

    extra = types.ModuleType("hypothesis.extra")
    hnp = types.ModuleType("hypothesis.extra.numpy")
    hnp.arrays = arrays
    hnp.array_shapes = array_shapes

    hyp.strategies = st
    extra.numpy = hnp
    hyp.extra = extra
    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = st
    sys.modules["hypothesis.extra"] = extra
    sys.modules["hypothesis.extra.numpy"] = hnp
