"""Elastic scaling: checkpoint written under a 4-device mesh restores
onto a 2-device mesh with different shardings (subprocess: forced host
devices, like the dry-run)."""
import os
import subprocess
import sys

SCRIPT = r"""
import os, tempfile
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.checkpoint import CheckpointManager

tmp = tempfile.mkdtemp()
mesh4 = jax.make_mesh((2, 2), ("data", "model"))
mesh2 = jax.make_mesh((1, 2), ("data", "model"))

state = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8),
         "step": jnp.int32(5)}
sh4 = {"w": NamedSharding(mesh4, P("data", "model")),
       "step": NamedSharding(mesh4, P())}
state4 = jax.tree.map(jax.device_put, state, sh4)

mgr = CheckpointManager(tmp)
mgr.save(5, state4, blocking=True)

# restore onto the *smaller* mesh with a different layout
sh2 = {"w": NamedSharding(mesh2, P(None, "model")),
       "step": NamedSharding(mesh2, P())}
got = mgr.restore(state, shardings=sh2)
np.testing.assert_array_equal(np.asarray(got["w"]), np.asarray(state["w"]))
assert got["w"].sharding == sh2["w"]
print("ELASTIC_OK")
"""


def test_elastic_mesh_rescale():
    # Inherit the parent env (a stripped env loses HOME and the XLA
    # compilation cache, which pushed cold-start past the old 300 s
    # limit on slow containers); JAX_PLATFORMS=cpu skips backend
    # probing so the forced host devices come up immediately.
    env = {**os.environ, "PYTHONPATH": "src", "JAX_PLATFORMS": "cpu"}
    res = subprocess.run([sys.executable, "-c", SCRIPT],
                         capture_output=True, text=True, timeout=900,
                         env=env)
    assert "ELASTIC_OK" in res.stdout, res.stdout + res.stderr
