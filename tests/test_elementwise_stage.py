"""Fused elementwise result tail: ISA stage, round-trips, bit-exactness.

The contract under test (ISSUE 10 tentpole surface):
  * residual adds / activations / write-back requant live *in the
    program* — ``LayerProgram.elementwise`` tails lowered as stage-6
    fetch/result records with real cycle closures, not Python-side
    glue;
  * the tail round-trips bit-exactly through text assembly (``ew=``)
    and the ``N3HPROG1`` binary image;
  * every op kind executes bit-identically on golden, pallas (fused
    jitted epilogue) and 2-device filter/pipeline bundles;
  * the tail's (codes, scale) quantizer is jit-stable: the eager and
    ``jax.jit``-ed forms agree bitwise (the reciprocal-multiply scale
    form — XLA's division-by-constant rewrite must not shift scales).
"""
import jax
import numpy as np
import pytest

import tests._hypothesis_compat as _hyp

_hyp.install()
from hypothesis import given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402
from hypothesis.extra import numpy as hnp  # noqa: E402

import jax.numpy as jnp  # noqa: E402

from repro.compiler import (  # noqa: E402
    GemmLayer,
    GoldenExecutor,
    MultiDeviceExecutor,
    PallasExecutor,
    assemble,
    bind_synthetic,
    derive_plan,
    disassemble,
    from_binary,
    lower_network,
    lower_partitioned,
    to_binary,
)
from repro.compiler.lower import EW_STAGE  # noqa: E402
from repro.compiler.program import (  # noqa: E402
    ELEMENTWISE_KINDS,
    ElementwiseOp,
)
from repro.compiler.runtime.base import elementwise_tail  # noqa: E402
from repro.core.scheduler import (  # noqa: E402
    XC7Z020,
    DspCoreConfig,
    LutCoreConfig,
)
from repro.core.workloads import ConvSpec  # noqa: E402
from repro.models.cnn import CNNConfig, specs_for  # noqa: E402
from repro.quant.uniform import qrange  # noqa: E402

LUT = LutCoreConfig(m=8, n=16, k=128)
DSP = DspCoreConfig(n_reg_row_a=13)

ACT_KINDS = ("relu", "relu6", "hswish")


def _residual_chain(act: str):
    """Three-layer chain whose last layer adds the first layer's output
    (same 8x8x12 shape) — every tail kind in one program."""
    return [ConvSpec("c0", 3, 12, 3, 1, 8, act=act),
            ConvSpec("c1", 12, 12, 3, 1, 8, act=act),
            ConvSpec("c2", 12, 12, 1, 1, 8, act=act, res_src=2)]


def _lowered(specs, **kw):
    layers = [GemmLayer.from_conv(s) for s in specs]
    return layers, lower_network("ew", layers, LUT, DSP, XC7Z020, **kw)


def _bound(cls, prog):
    ex = cls(prog)
    for lp in prog.layers:
        bind_synthetic(ex, lp, seed=lp.index)
    return ex


def _image(gl: GemmLayer, seed: int = 0) -> np.ndarray:
    return np.random.default_rng(seed).integers(
        -8, 8, gl.geometry.in_shape).astype(np.int8)


# ---------------------------------------------------------------------------
# The tail is in the program: IR ordering + stage-6 ISA records
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ["resnet18", "mobilenet_v2"])
def test_workload_tails_lowered_into_program(arch):
    cfg = CNNConfig(arch=arch, n_classes=10, in_hw=28, width=0.25)
    layers = [GemmLayer.from_conv(s) for s in specs_for(cfg)]
    prog = lower_network(arch, layers, LUT, DSP, XC7Z020)
    assert any(op.kind == "add" for lp in prog.layers
               for op in lp.elementwise)
    for lp in prog.layers[:-1]:
        # every non-final layer's tail ends in the write-back requant
        assert lp.elementwise and lp.elementwise[-1].kind == "requant"
        assert 1 <= lp.elementwise[-1].bits <= 8
        # canonical order: add -> activation -> requant
        ranks = {"add": 0, "relu": 1, "relu6": 1, "hswish": 1,
                 "requant": 2}
        seq = [ranks[op.kind] for op in lp.elementwise]
        assert seq == sorted(seq), lp.name
    # the classifier's tail carries no requant (fp32 logits out)
    assert all(op.kind != "requant"
               for op in prog.layers[-1].elementwise)


def test_tail_emits_stage6_records_with_cycles():
    specs = _residual_chain("relu")
    _, prog = _lowered(specs)
    for lp in prog.layers:
        cp = lp.lut if lp.lut is not None else lp.dsp
        ew_res = [op for op in cp.streams["result"]
                  if getattr(op.instr, "stage_ctrl", None) == EW_STAGE]
        assert len(ew_res) == 1          # one fused write-back per layer
        assert ew_res[0].cycles > 0
        # the encoded record carries the tail length
        assert ew_res[0].instr.ddr_offset == len(lp.elementwise)
        n_adds = sum(op.kind == "add" for op in lp.elementwise)
        ew_fetch = [op for op in cp.streams["fetch"]
                    if getattr(op.instr, "stage_ctrl", None) == EW_STAGE]
        assert len(ew_fetch) == n_adds   # residual operand DMA per add
        assert all(op.cycles > 0 for op in ew_fetch)


def test_elementwise_op_validation():
    assert set(ACT_KINDS) < set(ELEMENTWISE_KINDS)
    with pytest.raises(ValueError, match="unknown elementwise kind"):
        ElementwiseOp("sigmoid")
    with pytest.raises(ValueError, match="src_offset"):
        ElementwiseOp("add", src_offset=0)
    for bad in (0, 9):
        with pytest.raises(ValueError, match="bits"):
            ElementwiseOp("requant", bits=bad)


# ---------------------------------------------------------------------------
# Assembly + binary round-trips
# ---------------------------------------------------------------------------


def test_tail_round_trips_text_and_binary():
    specs = _residual_chain("hswish")
    _, prog = _lowered(specs, opt_level=1)
    text = disassemble(prog)
    assert " ew=" in text
    rt = assemble(text)
    assert rt == prog
    assert [lp.elementwise for lp in rt.layers] == \
        [lp.elementwise for lp in prog.layers]
    blob = to_binary(prog)
    rt2 = from_binary(blob)
    assert rt2 == prog
    assert to_binary(rt2) == blob
    # the tail is part of program identity
    bare = lower_network(
        "ew", [GemmLayer.from_conv(ConvSpec(s.name, s.c_in, s.c_out,
                                            s.kernel, s.stride, s.in_hw))
               for s in specs], LUT, DSP, XC7Z020, opt_level=1)
    assert bare.fingerprint() != prog.fingerprint()


# ---------------------------------------------------------------------------
# Bit-exactness: golden == pallas == multi-device, per op kind
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("act", ACT_KINDS)
def test_each_tail_kind_bit_exact_golden_vs_pallas(act):
    layers, prog = _lowered(_residual_chain(act))
    x = _image(layers[0], seed=5)
    out_g = np.asarray(_bound(GoldenExecutor, prog).run(x))
    out_p = np.asarray(_bound(PallasExecutor, prog).run(x))
    assert np.abs(out_g).sum() > 0
    assert (out_g == out_p).all()


@pytest.mark.parametrize("kind", ["filter", "pipeline"])
def test_tail_chain_bundles_bit_exact(kind):
    layers, prog = _lowered(_residual_chain("relu6"))
    x = _image(layers[0], seed=9)
    ref = np.asarray(_bound(GoldenExecutor, prog).run(x))
    plan = derive_plan(layers, 2, kind)
    mdp = lower_partitioned("ew", layers, plan, LUT, DSP, XC7Z020)
    mex = MultiDeviceExecutor(mdp)
    for gi in range(mdp.n_layers):
        mex.bind_synthetic(gi, seed=gi)
    assert (np.asarray(mex.run(x)) == ref).all()


# ---------------------------------------------------------------------------
# Property: the tail quantizer is jit-stable (eager == jit, bitwise)
# ---------------------------------------------------------------------------


@settings(max_examples=15, deadline=None)
@given(tile=hnp.arrays(np.float32, (12, 16),
                       elements=st.floats(-64.0, 64.0, width=32)),
       act=st.sampled_from(ACT_KINDS),
       bits=st.integers(2, 8),
       with_add=st.booleans())
def test_tail_eager_vs_jit_bitwise(tile, act, bits, with_add):
    """The fused Pallas epilogue jits the exact tail golden runs
    eagerly; they must agree *bitwise* on codes and scale. Guards the
    reciprocal-multiply scale form against XLA's division-by-constant
    rewrite reintroducing a 1-ulp eager/jit drift."""
    ops = ((ElementwiseOp("add", src_offset=1),) if with_add else ()) \
        + (ElementwiseOp(act), ElementwiseOp("requant", bits=bits))
    tail = elementwise_tail(ops, pool="")
    y = jnp.asarray(tile)
    res = jnp.asarray(tile[::-1]) if with_add else None
    post_e, codes_e, scale_e = tail(y, res)
    post_j, codes_j, scale_j = jax.jit(tail)(y, res)
    lo, hi = qrange(bits)
    assert int(jnp.min(codes_e)) >= lo and int(jnp.max(codes_e)) <= hi
    assert (np.asarray(codes_e) == np.asarray(codes_j)).all()
    assert np.float32(scale_e).tobytes() == np.float32(scale_j).tobytes()
    assert (np.asarray(post_e) == np.asarray(post_j)).all()
