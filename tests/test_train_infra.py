"""Optimizer, gradient compression, checkpointing, watchdog, serving."""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, StepWatchdog
from repro.parallel.compress import (
    compress_int8,
    compressed_grad_allreduce,
    decompress_int8,
    init_compression_state,
)
from repro.train.optimizer import (
    AdamWConfig,
    adamw_init,
    adamw_update,
    clip_by_global_norm,
    cosine_schedule,
)


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------


def test_adamw_reduces_quadratic():
    w = {"w": jnp.asarray([3.0, -2.0, 1.0])}
    opt = adamw_init(w)
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=0,
                      total_steps=100)
    loss = lambda p: jnp.sum(p["w"] ** 2)
    l0 = float(loss(w))
    for _ in range(50):
        g = jax.grad(loss)(w)
        w, opt, _ = adamw_update(w, g, opt, cfg)
    assert float(loss(w)) < 0.05 * l0


def test_grad_clip():
    g = {"a": jnp.full((4,), 10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(20.0)
    got = float(jnp.sqrt(jnp.sum(clipped["a"] ** 2)))
    assert got == pytest.approx(1.0, rel=1e-5)


def test_cosine_schedule_shape():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                      min_lr_ratio=0.1)
    lrs = [float(cosine_schedule(cfg, jnp.int32(s))) for s in
           (0, 5, 10, 55, 100)]
    assert lrs[0] == 0.0
    assert lrs[1] == pytest.approx(0.5)
    assert lrs[2] == pytest.approx(1.0)
    assert 0.1 < lrs[3] < 1.0
    assert lrs[4] == pytest.approx(0.1, rel=1e-3)


# ---------------------------------------------------------------------------
# gradient compression
# ---------------------------------------------------------------------------


def test_int8_roundtrip_error_bounded():
    g = jnp.asarray(np.random.default_rng(0).standard_normal(1000),
                    jnp.float32)
    codes, scale = compress_int8(g)
    err = jnp.abs(decompress_int8(codes, scale) - g)
    assert float(err.max()) <= float(scale) / 2 + 1e-7


def test_error_feedback_accumulates_to_zero_bias():
    """EF property: sum of (decompressed) over steps -> sum of true
    grads (the residual carries what was lost)."""
    rng = np.random.default_rng(1)
    grads = [jnp.asarray(rng.standard_normal(64) * 1e-3, jnp.float32)
             for _ in range(32)]
    state = init_compression_state({"g": grads[0]})
    sent_total = jnp.zeros(64)
    true_total = jnp.zeros(64)
    for g in grads:
        out, state = compressed_grad_allreduce({"g": g}, state)
        sent_total = sent_total + out["g"]
        true_total = true_total + g
    resid = jax.tree.leaves(state.residual)[0]
    np.testing.assert_allclose(np.asarray(sent_total + resid),
                               np.asarray(true_total), atol=1e-5)


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------


def _tree():
    return {"layers": {"w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
                       "b": jnp.ones((4,), jnp.bfloat16)},
            "step": jnp.int32(7)}


def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), max_to_keep=2)
    state = _tree()
    mgr.save(3, state, blocking=True)
    like = jax.tree.map(lambda x: jnp.zeros_like(x), state)
    got = mgr.restore(like)
    for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_retention_and_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), max_to_keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, _tree(), blocking=True)
    assert mgr.all_steps() == [3, 4]
    assert mgr.latest_step() == 4


def test_checkpoint_crash_safety(tmp_path):
    """A stale tmp dir (simulated crash mid-write) is invisible to
    restore and GC'd by the next manager."""
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, _tree(), blocking=True)
    fake = tmp_path / "step_0000000002.tmp-deadbeef"
    fake.mkdir()
    (fake / "manifest.json").write_text("{corrupt")
    assert mgr.latest_step() == 1          # tmp dir ignored
    mgr2 = CheckpointManager(str(tmp_path))
    assert not fake.exists()               # GC'd on construction
    assert mgr2.latest_step() == 1


def test_checkpoint_shape_mismatch_rejected(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, _tree(), blocking=True)
    bad = _tree()
    bad["layers"]["w"] = jnp.zeros((5, 5))
    with pytest.raises(ValueError):
        mgr.restore(bad)


def test_checkpoint_elastic_restore_new_sharding(tmp_path):
    """Stored arrays are mesh-agnostic: restore onto explicit (here
    single-device) shardings."""
    from jax.sharding import Mesh, NamedSharding, PartitionSpec
    mgr = CheckpointManager(str(tmp_path))
    state = _tree()
    mgr.save(5, state, blocking=True)
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))
    sh = jax.tree.map(
        lambda _: NamedSharding(mesh, PartitionSpec()), state)
    got = mgr.restore(state, shardings=sh)
    np.testing.assert_array_equal(np.asarray(got["layers"]["w"]),
                                  np.asarray(state["layers"]["w"]))


# ---------------------------------------------------------------------------
# watchdog
# ---------------------------------------------------------------------------


def test_watchdog_flags_straggler(tmp_path):
    hb = str(tmp_path / "hb.json")
    dog = StepWatchdog(heartbeat_path=hb, threshold=5.0)
    for s in range(6):
        dog.start_step(s)
        time.sleep(0.01)
        assert not dog.end_step()
    dog.start_step(6)
    time.sleep(0.2)                        # 20x the median
    assert dog.end_step()
    assert dog.stragglers == [6]
    age = StepWatchdog.heartbeat_age(hb)
    assert age is not None and age < 5.0


def test_heartbeat_age_missing():
    assert StepWatchdog.heartbeat_age("/nonexistent/hb.json") is None
