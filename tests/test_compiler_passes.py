"""Optimization-pass pipeline + executor backends: invariance suite.

The contract under test (ISSUE 2 acceptance surface):
  * optimized (-O1) programs still round-trip bit-exactly through asm
    text and the ``N3HPROG1`` binary image, for every registry smoke
    arch and the CNN workloads;
  * the golden executor produces bit-identical outputs on -O1 programs
    vs -O0 (passes change timing/instruction count, never semantics);
  * the batched Pallas backend matches the golden backend bit for bit,
    per layer on registry archs and end-to-end on FC-chained programs;
  * -O1 strictly reduces simulated total latency on registry networks
    while reducing the instruction count;
  * each pass preserves the sync-token protocol (PassPipeline
    validation) and depthwise layers execute functionally on both
    backends (grouped per-channel GEMMs on staged im2col slices).
"""
import numpy as np
import pytest

from repro.compiler import (
    DmaFusionPass,
    GemmLayer,
    GoldenExecutor,
    PallasExecutor,
    PassError,
    PassPipeline,
    SyncElisionPass,
    WeightPrefetchPass,
    assemble,
    bind_synthetic,
    compile_network,
    disassemble,
    from_binary,
    lower_network,
    optimize_program,
    to_binary,
)
from repro.compiler.cli import execute_report, main as cli_main
from repro.configs import registry
from repro.core import isa
from repro.core.scheduler import (
    XC7Z020,
    DspCoreConfig,
    GemmDims,
    LutCoreConfig,
    simulate,
    simulate_program,
)

LUT = LutCoreConfig(m=8, n=16, k=128)
DSP = DspCoreConfig(n_reg_row_a=13)
ARCHS = registry.list_archs()
SEQ = 4


def _acts(lp):
    return np.random.default_rng(1000 + lp.index).integers(
        -8, 8, (lp.dims.m, lp.dims.k)).astype(np.int8)


# ---------------------------------------------------------------------------
# (a) Optimized programs round-trip bit-exactly
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", ARCHS)
def test_optimized_registry_program_roundtrips(name):
    prog = compile_network(name, seq_len=SEQ, opt_level=1)
    assert prog.opt_stats, "-O1 must record per-pass stats"
    text = disassemble(prog)
    assert assemble(text) == prog
    assert disassemble(assemble(text)) == text       # canonical render
    blob = to_binary(prog)
    assert from_binary(blob) == prog
    assert to_binary(from_binary(blob)) == blob


def test_optimized_cnn_program_roundtrips():
    prog = compile_network("mobilenet_v2", opt_level=1)
    assert assemble(disassemble(prog)) == prog
    assert from_binary(to_binary(prog)) == prog


# ---------------------------------------------------------------------------
# (b) Golden outputs are pass-invariant; (c) Pallas matches golden
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", ARCHS)
def test_golden_invariance_and_pallas_bit_exact(name):
    p0 = compile_network(name, seq_len=SEQ)
    p1 = optimize_program(p0, 1)
    assert p1.n_instructions < p0.n_instructions
    g0, g1, pl = GoldenExecutor(p0), GoldenExecutor(p1), PallasExecutor(p1)
    nl = len(p0.layers)
    for i in sorted({0, nl // 2, nl - 1}):
        lp = p0.layers[i]
        for ex in (g0, g1, pl):
            bind_synthetic(ex, lp)
        x = _acts(lp)
        o0 = np.asarray(g0.run_layer(i, x))
        o1 = np.asarray(g1.run_layer(i, x))
        op = np.asarray(pl.run_layer(i, x))
        assert (o0 == o1).all(), f"{name} layer {i}: -O1 changed golden out"
        assert (o0 == op).all(), f"{name} layer {i}: pallas != golden"


@pytest.mark.parametrize("opt_level", [0, 1])
def test_pallas_matches_golden_on_fc_chain(opt_level):
    layers = [GemmLayer("fc1", GemmDims(24, 32, 48)),
              GemmLayer("fc2", GemmDims(24, 48, 40)),
              GemmLayer("fc3", GemmDims(24, 40, 16))]
    prog = lower_network("mlp", layers, LUT, DSP, XC7Z020,
                         bits_w_lut=5, bits_a=4, n_luts=[20, 16, 8],
                         opt_level=opt_level)
    golden, pallas = GoldenExecutor(prog), PallasExecutor(prog)
    # mode="kernel" executes the actual Pallas kernel bodies (interpret
    # mode off-TPU) instead of the jnp oracles
    kern = PallasExecutor(prog, mode="kernel")
    for lp in prog.layers:
        for ex in (golden, pallas, kern):
            bind_synthetic(ex, lp)
    x = np.random.default_rng(7).integers(-8, 8, (24, 32)).astype(np.int8)
    out_g = np.asarray(golden.run(x))
    out_p = np.asarray(pallas.run(x))
    assert out_g.shape == (24, 16)
    assert (out_g == out_p).all()
    assert (out_g == np.asarray(kern.run(x))).all()


# ---------------------------------------------------------------------------
# -O1 reduces simulated latency on registry networks (acceptance)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", ["llama3.2-1b", "qwen3-moe-235b-a22b"])
def test_o1_strictly_reduces_simulated_latency(name):
    p0 = compile_network(name, seq_len=8)
    p1 = optimize_program(p0, 1)
    s0 = simulate_program(p0)
    s1 = simulate_program(p1)
    assert s1.total_cycles < s0.total_cycles
    assert p1.n_instructions < p0.n_instructions
    # same thing through the simulate_program(opt_level=...) threading
    assert simulate_program(p0, opt_level=1).total_cycles \
        == s1.total_cycles


def test_per_layer_makespan_never_regresses():
    p0 = compile_network("gemma-7b", seq_len=8)
    p1 = optimize_program(p0, 1)
    s0 = simulate_program(p0)
    s1 = simulate_program(p1)
    for l0, l1 in zip(s0.layers, s1.layers):
        assert l1.cycles <= l0.cycles, l0.name


# ---------------------------------------------------------------------------
# Per-pass unit behavior
# ---------------------------------------------------------------------------


def _fc_program(m=16, k=48, n=96, n_lut=48, opt_level=0):
    return lower_network(
        "fc", [GemmLayer("fc", GemmDims(m, k, n))], LUT, DSP, XC7Z020,
        bits_w_lut=4, bits_a=4, n_luts=[n_lut], opt_level=opt_level)


def test_weight_prefetch_deepens_tokens_monotonically():
    prog = _fc_program()
    before = {id(cp): dict(cp.initial_tokens)
              for lp in prog.layers for cp in lp.cores()}
    detail = WeightPrefetchPass().run(prog)
    assert detail["tokens_added"] > 0
    for lp in prog.layers:
        for cp in lp.cores():
            for ch, n in before[id(cp)].items():
                assert cp.initial_tokens.get(ch, 0) >= n
            # deeper tokens can only speed the core up
            r = simulate(cp.streams, cp.sim_tokens())
            assert r.total_cycles > 0


def test_sync_elision_strips_single_tile_handshake():
    # one tile on each core: the slot-token machinery is entirely dead
    prog = lower_network(
        "tiny", [GemmLayer("fc", GemmDims(8, 16, 32))], LUT, DSP, XC7Z020,
        n_luts=[16])
    base = prog.n_instructions
    detail = SyncElisionPass().run(prog)
    assert detail["syncs_elided"] >= 2
    assert prog.n_instructions == base - detail["syncs_elided"]
    for lp in prog.layers:
        for cp in lp.cores():
            sends = [op for op in cp.ops()
                     if isinstance(op.instr, isa.SyncInstr)
                     and not op.instr.is_wait
                     and op.channel in ("lut.wslot", "dsp.aslot")]
            assert not sends
            simulate(cp.streams, cp.sim_tokens())     # still deadlock-free


def test_sync_elision_never_starves_consumed_channels():
    prog = _fc_program(n=160, n_lut=96)               # several weight tiles
    SyncElisionPass().run(prog)
    for lp in prog.layers:
        for cp in lp.cores():
            simulate(cp.streams, cp.sim_tokens())


def test_dma_fusion_emits_bursts_golden_still_exact():
    p0 = _fc_program(m=8, k=32, n=160, n_lut=96)
    p1 = optimize_program(p0, 1)
    bursts = [op.instr for lp in p1.layers for cp in lp.cores()
              for op in cp.ops()
              if isinstance(op.instr, (isa.FetchInstr, isa.ResultInstr))
              and op.instr.onchip_base >= 2]
    assert bursts, "expected at least one fused DMA burst"
    for b in bursts:
        assert b.onchip_base <= DmaFusionPass.max_burst
    g0, g1 = GoldenExecutor(p0), GoldenExecutor(p1)
    lp = p0.layers[0]
    bind_synthetic(g0, lp)
    bind_synthetic(g1, lp)
    x = _acts(lp)
    assert (np.asarray(g0.run_layer(0, x))
            == np.asarray(g1.run_layer(0, x))).all()


def test_pipeline_validation_catches_broken_pass():
    class BreakTokens:
        name = "break-tokens"

        def run(self, prog):
            for lp in prog.layers:
                for cp in lp.cores():
                    # drop every send: waits can never be satisfied
                    for e in cp.streams:
                        cp.streams[e] = [
                            op for op in cp.streams[e]
                            if not (isinstance(op.instr, isa.SyncInstr)
                                    and not op.instr.is_wait)]
            return {}

    prog = _fc_program()
    with pytest.raises(PassError, match="break-tokens"):
        PassPipeline([BreakTokens()]).run(prog)


def test_opt_level_threaded_through_lower_and_cli_entry():
    p1 = compile_network("llama3.2-1b", seq_len=SEQ, opt_level=1)
    assert [ps.name for ps in p1.opt_stats] == \
        ["weight-prefetch", "sync-elision", "dma-fusion"]
    with pytest.raises(ValueError):
        optimize_program(compile_network("llama3.2-1b", seq_len=SEQ), 7)


# ---------------------------------------------------------------------------
# Depthwise: grouped execution on both backends + CLI execute
# ---------------------------------------------------------------------------


def _dw_program(opt_level=0):
    return lower_network(
        "dwnet",
        [GemmLayer("fc0", GemmDims(64, 9, 32)),
         GemmLayer("dw", GemmDims(64, 9, 32), depthwise=True)],
        LUT, DSP, XC7Z020, n_luts=[16, 16], opt_level=opt_level)


def test_depthwise_executes_bit_exact_on_both_backends():
    # a geometry-less depthwise layer takes the pre-staged per-channel
    # im2col stack [m, k, n]; LUT and DSP partitions each consume their
    # own channels' slices and concatenate in natural channel order
    prog = _dw_program()
    golden, pallas = GoldenExecutor(prog), PallasExecutor(prog)
    lp = prog.layers[1]
    bind_synthetic(golden, lp)
    bind_synthetic(pallas, lp)
    x = np.random.default_rng(3).integers(
        -8, 8, (64, 9, 32)).astype(np.int8)
    out_g = np.asarray(golden.run_layer(1, x))
    assert out_g.shape == (64, 32)
    assert (out_g == np.asarray(pallas.run_layer(1, x))).all()
    # grouped semantics: channel c only sees slice c
    w_lut, s_lut = golden._weights[1].w_lut, golden._weights[1].s_lut
    want0 = (x[:, :, 0].astype(np.int64)
             @ np.asarray(w_lut)[:, 0].astype(np.int64))
    want0 = want0.astype(np.float32) * np.float32(np.asarray(s_lut)[0])
    assert (out_g[:, 0] == want0).all()


def test_depthwise_rejects_wrong_activation_shape():
    from repro.compiler.runtime import ExecutionError
    prog = _dw_program()
    ex = GoldenExecutor(prog)
    bind_synthetic(ex, prog.layers[1])
    with pytest.raises(ExecutionError, match="staged"):
        ex.run_layer(1, np.zeros((64, 9), np.int8))


@pytest.mark.parametrize("backend", ["golden", "pallas"])
def test_execute_report_covers_depthwise(backend):
    report = execute_report(_dw_program(), backend=backend)
    assert "executed  2/2 layers" in report
    assert "skipped" not in report


# ---------------------------------------------------------------------------
# CLI flags
# ---------------------------------------------------------------------------


def test_cli_opt_and_backend_flags(capsys):
    assert cli_main(["llama3.2-1b", "--seq-len", "4", "-O", "1",
                     "--simulate"]) == 0
    out = capsys.readouterr().out
    assert "passes    3 passes" in out
    assert "weight-prefetch" in out and "sync-elision" in out \
        and "dma-fusion" in out
    assert "simulated" in out

    assert cli_main(["llama3.2-1b", "--seq-len", "4", "-O", "1",
                     "--execute", "--backend", "pallas"]) == 0
    out = capsys.readouterr().out
    assert "executed" in out and "pallas backend" in out


def test_cli_o0_has_no_pass_block(capsys):
    assert cli_main(["llama3.2-1b", "--seq-len", "4"]) == 0
    out = capsys.readouterr().out
    assert "passes" not in out
