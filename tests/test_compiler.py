"""NN→ISA compiler toolchain: lowering, round-trips, golden execution.

Covers the acceptance surface of the compiler subsystem:
  * assembly/binary round-trips are bit-exact for all four instruction
    kinds (and canonical: re-render is byte-identical);
  * the golden executor matches `core/hetero_linear.py`'s deployed
    integer path bit-exactly on quantized layers for both core types;
  * simulating compiled programs reproduces the seed per-engine latency
    decomposition (golden numbers recorded from the pre-compiler
    scheduler) for identical GemmDims/core configs;
  * every registry arch + CNN workload compiles end to end.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import kernels
from repro.compiler import (
    GemmLayer,
    GoldenExecutor,
    assemble,
    compile_network,
    disassemble,
    from_binary,
    list_networks,
    lower_network,
    network_layers,
    to_binary,
)
from repro.compiler.runtime import ExecutionError
from repro.core.hetero_linear import (
    HeteroLinearConfig,
    apply_deploy,
    deploy,
    init_hetero_linear,
)
from repro.core.scheduler import (
    XC7Z020,
    DspCoreConfig,
    GemmDims,
    LutCoreConfig,
    dsp_core_streams,
    lut_core_streams,
    simulate,
    simulate_dsp_core,
    simulate_lut_core,
    simulate_program,
)
from repro.quant.hybrid import LayerQuantConfig
from repro.quant.uniform import fit_scale, qrange

LUT = LutCoreConfig(m=8, n=16, k=128)
DSP = DspCoreConfig(n_reg_row_a=13)


def _tiny_program(m=24, k=32, n=40, n_lut=18, bits_w=6, bits_a=4,
                  name="tiny"):
    return lower_network(name, [GemmLayer("fc", GemmDims(m, k, n))],
                         LUT, DSP, XC7Z020, bits_w_lut=bits_w,
                         bits_a=bits_a, n_luts=[n_lut])


# ---------------------------------------------------------------------------
# Round-trips
# ---------------------------------------------------------------------------


def test_asm_roundtrip_bit_exact_all_instruction_kinds():
    prog = _tiny_program()
    words = set(type(op.instr).__name__
                for lp in prog.layers for cp in lp.cores()
                for op in cp.ops())
    # the tiny program exercises all four instruction kinds
    assert words == {"FetchInstr", "ExecuteInstr", "ResultInstr",
                     "SyncInstr"}
    text = disassemble(prog)
    prog2 = assemble(text)
    assert prog2 == prog
    # canonical: assemble -> disassemble -> assemble is byte-identical
    assert disassemble(prog2) == text


def test_binary_roundtrip_bit_exact():
    prog = _tiny_program()
    blob = to_binary(prog)
    prog2 = from_binary(blob)
    assert prog2 == prog
    assert to_binary(prog2) == blob


def test_binary_matches_isa_encode():
    """Every 128-bit word in the image is the isa.py encoding."""
    prog = _tiny_program()
    blob = to_binary(prog)
    words = prog.words()
    # the packed stream section ends with the last instruction record
    tail = blob.rsplit(words[-1].to_bytes(16, "little"), 1)
    assert len(tail) == 2 and len(tail[1]) == 4  # trailing u32 cycles


def test_corrupt_binary_rejected():
    prog = _tiny_program()
    blob = to_binary(prog)
    with pytest.raises(ValueError):
        from_binary(b"XXXXXXXX" + blob[8:])
    with pytest.raises(ValueError):
        from_binary(blob + b"\x00\x00\x00\x00")


def test_multi_layer_roundtrip_with_barriers():
    layers = [GemmLayer("fc1", GemmDims(16, 24, 32)),
              GemmLayer("fc2", GemmDims(16, 32, 48)),
              GemmLayer("fc3", GemmDims(16, 48, 16))]
    prog = lower_network("mlp", layers, LUT, DSP, XC7Z020,
                         bits_w_lut=4, bits_a=4, n_luts=[16, 24, 8])
    # barrier wait opens each later layer's fetch streams
    for lp in prog.layers[1:]:
        for cp in lp.cores():
            first = cp.streams["fetch"][0]
            assert first.channel in ("lut.bar", "dsp.bar")
            assert first.instr.is_wait == 1
    assert assemble(disassemble(prog)) == prog
    assert from_binary(to_binary(prog)) == prog


# ---------------------------------------------------------------------------
# Golden executor vs hetero_linear reference
# ---------------------------------------------------------------------------


def _quantize_acts(x, bits):
    s_a = fit_scale(x, bits)
    lo, hi = qrange(bits)
    return jnp.clip(jnp.round(x / s_a), lo, hi).astype(jnp.int8), s_a


@pytest.mark.parametrize("ratio,bits_w", [(0.45, 6), (1.0, 5), (0.0, 4)])
def test_golden_executor_bit_exact_vs_hetero_linear(ratio, bits_w):
    M, K, N = 24, 32, 40
    cfg = HeteroLinearConfig(K, N, quant=LayerQuantConfig(
        w_bits_lut=bits_w, a_bits=4, ratio=ratio))
    params = init_hetero_linear(jax.random.PRNGKey(0), cfg)
    d = deploy(params, cfg)
    n_lut = d.wq_serial.shape[1]

    prog = _tiny_program(M, K, N, n_lut=n_lut, bits_w=bits_w)
    ex = GoldenExecutor(prog)
    ex.bind_deployed(0, d)

    x = jax.random.normal(jax.random.PRNGKey(1), (M, K))
    x_q, s_a = _quantize_acts(x, 4)

    got = np.asarray(ex.run_layer(0, x_q))
    want = np.asarray(kernels.hetero_matmul(
        x_q, d.wq_serial, d.s_serial, d.bits_serial,
        d.wq_parallel, d.s_parallel))
    assert (got == want).all()

    # and through the full deployed path (permutation + act scale)
    full = got[:, np.asarray(d.inv_perm)] * float(s_a)
    assert (full == np.asarray(apply_deploy(d, x))).all()


def test_golden_executor_chains_fc_network():
    layers = [GemmLayer("fc1", GemmDims(8, 16, 24)),
              GemmLayer("fc2", GemmDims(8, 24, 12))]
    prog = lower_network("mlp", layers, LUT, DSP, XC7Z020,
                         bits_w_lut=4, bits_a=4, n_luts=[12, 6])
    ex = GoldenExecutor(prog)
    rng = np.random.default_rng(0)
    for i, lp in enumerate(prog.layers):
        k, n_lut, n_dsp = lp.dims.k, lp.n_lut, lp.dims.n - lp.n_lut
        ex.bind_layer(
            i,
            w_lut=rng.integers(-8, 8, (k, n_lut)), s_lut=np.ones(n_lut),
            w_dsp=rng.integers(-8, 8, (k, n_dsp)), s_dsp=np.ones(n_dsp))
    x_q = rng.integers(-8, 8, (8, 16)).astype(np.int8)
    out = np.asarray(ex.run(x_q))
    assert out.shape == (8, 12)
    assert np.isfinite(out).all()


def test_golden_executor_validates_contract():
    prog = _tiny_program()
    ex = GoldenExecutor(prog)
    with pytest.raises(ExecutionError):
        ex.run_layer(0, jnp.zeros((24, 32), jnp.int8))  # no weights bound
    rng = np.random.default_rng(1)
    ex.bind_layer(0, w_lut=rng.integers(-32, 32, (32, 18)),
                  s_lut=np.ones(18), w_dsp=rng.integers(-8, 8, (32, 22)),
                  s_dsp=np.ones(22))
    with pytest.raises(ExecutionError):
        ex.run_layer(0, jnp.zeros((24, 99), jnp.int8))  # wrong K
    with pytest.raises(ValueError):
        ex.bind_layer(0, w_lut=np.full((32, 18), 99), s_lut=np.ones(18),
                      w_dsp=rng.integers(-8, 8, (32, 22)), s_dsp=np.ones(22))


def test_depthwise_executes_grouped():
    from repro.compiler import bind_synthetic
    prog = lower_network(
        "dw", [GemmLayer("dw", GemmDims(64, 9, 32), depthwise=True)],
        LUT, DSP, XC7Z020, n_luts=[16])
    ex = GoldenExecutor(prog)
    bind_synthetic(ex, prog.layers[0])
    x = np.random.default_rng(0).integers(-8, 8, (64, 9, 32)).astype(np.int8)
    out = np.asarray(ex.run_layer(0, x))
    assert out.shape == (64, 32)
    assert np.isfinite(out).all()


# ---------------------------------------------------------------------------
# Compiled-program simulation == seed latency decomposition
# ---------------------------------------------------------------------------

# Golden numbers recorded from the pre-compiler scheduler (seed commit),
# (total, l_wait, l_run, l_sig, l_rst, n_instructions):
SEED_GOLDEN = [
    ("lut", (3136, 576, 96), 4, 4, False,
     (375743, 166361, 206976, 4739, 84672, 9455)),
    ("lut", (784, 1152, 144), 6, 3, False,
     (215685, 64801, 149940, 1817, 30870, 3599)),
    ("lut", (12544, 9, 32), 5, 4, True,
     (128671, 12616, 62720, 6283, 112896, 12559)),
    ("dsp", (3136, 576, 160), 0, 0, False,
     (1661403, 3179, 1655280, 5808, 94380, 10891)),
    ("dsp", (196, 2304, 80), 0, 0, False,
     (221264, 2153, 218880, 382, 3120, 638)),
    ("dsp", (12544, 9, 32), 0, 0, True,
     (77306, 81, 42460, 7720, 75270, 12546)),
]


@pytest.mark.parametrize("which,dims,bw,ba,dw,expect", SEED_GOLDEN)
def test_compiled_streams_reproduce_seed_decomposition(which, dims, bw, ba,
                                                       dw, expect):
    g = GemmDims(*dims)
    if which == "lut":
        r = simulate_lut_core(g, LUT, XC7Z020, bw, ba, dw)
    else:
        r = simulate_dsp_core(g, DSP, XC7Z020, dw)
    assert (r.total_cycles, r.l_wait, r.l_run, r.l_sig, r.l_rst,
            r.n_instructions) == expect


def test_program_simulation_matches_raw_streams():
    """simulate_program over a compiled single-layer Program == simulate
    of the wrapper streams (the compiler is the single source)."""
    g = GemmDims(784, 1152, 144)
    n_lut = 60
    prog = lower_network("one", [GemmLayer("l0", g)], LUT, DSP, XC7Z020,
                         bits_w_lut=6, bits_a=3, n_luts=[n_lut])
    ps = simulate_program(prog)
    lut_raw = simulate(*lut_core_streams(
        GemmDims(g.m, g.k, n_lut), LUT, XC7Z020, 6, 3))
    dsp_raw = simulate(*dsp_core_streams(
        GemmDims(g.m, g.k, g.n - n_lut), DSP, XC7Z020))
    assert ps.layers[0].lut.total_cycles == lut_raw.total_cycles
    assert ps.layers[0].dsp.total_cycles == dsp_raw.total_cycles
    assert ps.layers[0].lut.l_wait == lut_raw.l_wait
    assert ps.layers[0].dsp.l_run == dsp_raw.l_run
    assert ps.total_cycles == max(lut_raw.total_cycles,
                                  dsp_raw.total_cycles)


def test_network_simulation_is_interlayer_synchronous():
    prog = compile_network("resnet18")
    ps = simulate_program(prog)
    assert ps.total_cycles == sum(ls.cycles for ls in ps.layers)
    assert len(ps.layers) == 21
    for core in ("lut", "dsp"):
        d = ps.decomposition(core)
        assert d["l_run"] > 0 and d["l_rst"] > 0


# ---------------------------------------------------------------------------
# Whole-registry compilation + CLI
# ---------------------------------------------------------------------------


def test_every_network_compiles():
    for name in list_networks():
        prog = compile_network(name, seq_len=8)
        assert prog.n_instructions > 0
        s = prog.stats()
        assert s.by_opcode["EXECUTE"] > 0
        assert s.by_opcode["SYNC"] > 0
        assert s.bytes_fetched > 0
        # split sanity: every layer's n_lut within range
        for lp in prog.layers:
            assert 0 <= lp.n_lut <= lp.dims.n


def test_memory_map_is_disjoint_and_aligned():
    prog = compile_network("llama3.2-1b", seq_len=8)
    segs = prog.memory.segments
    for a, b in zip(segs, segs[1:]):
        assert b.base >= a.end
        assert b.base % 64 == 0
    assert prog.memory.footprint >= sum(s.size for s in segs)


def test_cli_summary_and_asm(tmp_path, capsys):
    from repro.compiler.cli import main
    assert main(["llama3.2-1b", "--seq-len", "8"]) == 0
    out = capsys.readouterr().out
    assert "program   llama3.2-1b" in out and "instrs" in out
    asm_path = tmp_path / "p.asm"
    assert main(["llama3.2-1b", "--seq-len", "8", "--format", "asm",
                 "-o", str(asm_path)]) == 0
    prog = assemble(asm_path.read_text())
    assert prog.name == "llama3.2-1b"
    bin_path = tmp_path / "p.n3h"
    assert main(["llama3.2-1b", "--seq-len", "8", "--format", "bin",
                 "-o", str(bin_path)]) == 0
    assert from_binary(bin_path.read_bytes()) == prog


def test_fixed_ratio_override():
    prog = compile_network("llama3.2-1b", seq_len=8, ratio=0.25)
    for lp in prog.layers:
        assert lp.n_lut == int(round(0.25 * lp.dims.n))


def test_network_layers_shapes_make_sense():
    layers = network_layers("llama3.2-1b", seq_len=16)
    # 2 smoke blocks x (4 attn + 3 mlp) + lm_head
    assert len(layers) == 15
    assert all(gl.dims.m == 16 for gl in layers)
    assert layers[-1].name == "lm_head"
