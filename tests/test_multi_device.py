"""Multi-device program partitioning: plans, bundles, executors.

The contract under test (ISSUE 3 acceptance surface):
  * a 1-device plan of either kind reproduces the legacy single-program
    path bit for bit;
  * ``N3HBUND1`` bundle images round-trip bit-exactly (both plan kinds,
    -O0 and -O1);
  * per-device pass invariance: multi-device golden outputs are
    bit-identical at -O0 and -O1, and bit-identical to the
    single-device program's outputs (per layer and FC-chained);
  * cross-device token pairing is validated — a dropped or duplicated
    ``*.xdev`` sync raises ``PartitionError``;
  * the simulated 2-device pipeline makespan beats 1 device on a
    registry arch for a batched input stream;
  * a registry LM and a CNN both compile under ``--devices 2`` in both
    partition modes;
  * satellites: the PallasExecutor per-program JIT cache and the
    serving-time compiled-image LRU.
"""
import importlib
import sys

import numpy as np
import pytest

from repro.compiler import (
    GemmLayer,
    GoldenExecutor,
    MultiDeviceExecutor,
    PallasExecutor,
    PartitionError,
    bind_synthetic,
    compile_network,
    derive_plan,
    from_bundle_binary,
    kind_from_rules,
    lower_network,
    lower_partitioned,
    optimize_bundle,
    to_bundle_binary,
    validate_bundle,
)
from repro.compiler.cli import main as cli_main
from repro.compiler.program import CROSS_DEVICE_CHANNELS
from repro.core import isa
from repro.core.scheduler import (
    XC7Z020,
    DspCoreConfig,
    GemmDims,
    LutCoreConfig,
    simulate_program,
)
from repro.parallel.sharding import DEFAULT_RULES

LUT = LutCoreConfig(m=8, n=16, k=128)
DSP = DspCoreConfig(n_reg_row_a=13)
KINDS = ("pipeline", "filter")

#: FC-chained toy network (n_i == k_{i+1}) so run() exercises the
#: cross-device hand-off end to end, including boundary requantization.
CHAIN = [GemmLayer("fc0", GemmDims(24, 32, 48)),
         GemmLayer("fc1", GemmDims(24, 48, 40)),
         GemmLayer("fc2", GemmDims(24, 40, 36)),
         GemmLayer("fc3", GemmDims(24, 36, 20))]


def _chain_bundle(kind, n_devices, opt_level=0, layers=CHAIN):
    plan = derive_plan(layers, n_devices, kind)
    return lower_partitioned("toy", layers, plan, LUT, DSP, XC7Z020,
                             bits_w_lut=6, bits_a=4, opt_level=opt_level)


def _single(layers=CHAIN, opt_level=0):
    return lower_network("toy", layers, LUT, DSP, XC7Z020,
                         bits_w_lut=6, bits_a=4, opt_level=opt_level)


def _bound_single(prog):
    ex = GoldenExecutor(prog)
    for lp in prog.layers:
        bind_synthetic(ex, lp)
    return ex


def _bound_multi(mdp, backend="golden"):
    mex = MultiDeviceExecutor(mdp, backend=backend)
    for gi in range(mdp.n_layers):
        mex.bind_synthetic(gi)
    return mex


def _x(m=24, k=32, seed=0):
    return np.random.default_rng(seed).integers(
        -8, 8, (m, k)).astype(np.int8)


# ---------------------------------------------------------------------------
# Plans
# ---------------------------------------------------------------------------


def test_kind_derived_from_axis_rules():
    # stock rules shard mlp/heads over "model" -> filter-parallel
    assert kind_from_rules(DEFAULT_RULES) == "filter"
    # rules that shard the layer axis ask for pipeline stages
    assert kind_from_rules(
        DEFAULT_RULES.replace(layers=("model",))) == "pipeline"
    # no sharded axes at all -> pipeline (stage parallelism needs no
    # intra-layer splits)
    bare = DEFAULT_RULES.replace(**{n: () for n in
                                    ("mlp", "heads", "experts", "vocab")})
    assert kind_from_rules(bare) == "pipeline"


def test_pipeline_stages_balanced_and_contiguous():
    plan = derive_plan(CHAIN, 2, "pipeline")
    (a0, a1), (b0, b1) = plan.stages
    assert a0 == 0 and a1 == b0 and b1 == len(CHAIN)
    with pytest.raises(PartitionError):
        derive_plan(CHAIN, 5, "pipeline")   # more devices than layers


def test_filter_shards_cover_every_layer():
    plan = derive_plan(CHAIN, 2, "filter")
    for gl, bounds in zip(CHAIN, plan.shards):
        assert bounds[0] == 0 and bounds[-1] == gl.dims.n
        assert all(b1 > b0 for b0, b1 in zip(bounds, bounds[1:]))
    with pytest.raises(PartitionError):
        derive_plan([GemmLayer("n1", GemmDims(4, 4, 1))], 2, "filter")


# ---------------------------------------------------------------------------
# 1-device plan == legacy single-program path, bit for bit
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kind", KINDS)
def test_one_device_plan_is_legacy_program(kind):
    single = _single()
    mdp = _chain_bundle(kind, 1)
    assert mdp.n_devices == 1 and not mdp.edges
    assert mdp.devices[0] == single
    assert mdp.devices[0].words() == single.words()


def test_lower_network_plan_path():
    # lower_network's plan= kwarg is the multi-device entry point
    plan = derive_plan(CHAIN, 2, "pipeline")
    mdp = lower_network("toy", CHAIN, LUT, DSP, XC7Z020, bits_w_lut=6,
                        bits_a=4, plan=plan)
    assert mdp.n_devices == 2 and mdp.plan is plan
    assert mdp == _chain_bundle("pipeline", 2)


@pytest.mark.parametrize("kind", KINDS)
def test_one_device_plan_is_legacy_program_lm(kind):
    single = compile_network("llama3.2-1b", seq_len=4)
    mdp = compile_network("llama3.2-1b", seq_len=4, devices=1,
                          partition=kind)
    assert mdp.devices[0] == single


# ---------------------------------------------------------------------------
# Bundle image round-trips
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kind", KINDS)
@pytest.mark.parametrize("opt", (0, 1))
def test_bundle_binary_round_trip(kind, opt):
    mdp = _chain_bundle(kind, 2, opt_level=opt)
    blob = to_bundle_binary(mdp)
    assert blob[:8] == b"N3HBUND1"
    rt = from_bundle_binary(blob)
    assert rt == mdp
    assert to_bundle_binary(rt) == blob    # canonical re-pack


@pytest.mark.parametrize("kind", KINDS)
def test_bundle_round_trip_registry_lm(kind):
    mdp = compile_network("llama3.2-1b", seq_len=4, devices=2,
                          partition=kind, opt_level=1)
    rt = from_bundle_binary(to_bundle_binary(mdp))
    assert rt == mdp
    validate_bundle(rt)


def test_bundle_binary_rejects_garbage():
    with pytest.raises(ValueError):
        from_bundle_binary(b"NOTABUND" + b"\x00" * 16)
    blob = to_bundle_binary(_chain_bundle("pipeline", 2))
    with pytest.raises(ValueError):
        from_bundle_binary(blob + b"\x00")   # trailing bytes
    # structurally valid JSON header with missing keys is still a
    # ValueError, not a KeyError leak
    import struct
    with pytest.raises(ValueError):
        from_bundle_binary(b"N3HBUND1" + struct.pack("<I", 2) + b"{}"
                           + struct.pack("<I", 0))


def test_gather_dma_offsets_are_staging_ordinals():
    # a device's gather fetches index the staged peer shards 0..D-2 in
    # device order, not raw peer ids (segment-relative convention);
    # the overlap placement rides them at the *producing* layer's
    # fetch-stream tail (every layer but the last)
    mdp = _chain_bundle("filter", 3)
    for prog in mdp.devices:
        for lp in prog.layers[:-1]:
            cp = lp.lut if lp.lut is not None else lp.dsp
            offs = [op.instr.ddr_offset for op in cp.streams["fetch"]
                    if isinstance(op.instr, isa.FetchInstr)
                    and op.instr.stage_ctrl == 3]
            assert offs == [0, 1]
        last = prog.layers[-1]
        cp = last.lut if last.lut is not None else last.dsp
        assert not any(isinstance(op.instr, isa.FetchInstr)
                       and op.instr.stage_ctrl == 3
                       for op in cp.streams["fetch"])


def test_gather_overlap_beats_serialized_gathers():
    # the overlap placement strictly shortens the filter-parallel
    # makespan: link DMAs ride under the producing layer's compute
    # instead of serializing at the consuming layer's head
    layers = CHAIN
    plan = derive_plan(layers, 2, "filter")
    over = lower_partitioned("toy", layers, plan, LUT, DSP, XC7Z020,
                             bits_w_lut=6, bits_a=4)
    serial = lower_partitioned("toy", layers, plan, LUT, DSP, XC7Z020,
                               bits_w_lut=6, bits_a=4,
                               gather_overlap=False)
    c_over = simulate_program(over).latency_cycles
    c_serial = simulate_program(serial).latency_cycles
    assert c_over < c_serial


def test_boundary_bytes_use_consumer_bits():
    # link transfers are sized at the *consuming* layer's activation
    # bit-width (what its act fetches and act.in segment are sized at)
    layers = CHAIN[:2]
    plan = derive_plan(layers, 2, "pipeline")
    mdp = lower_partitioned("toy", layers, plan, LUT, DSP, XC7Z020,
                            bits_w_lut=6, bits_a=[4, 8])
    g = layers[0].dims
    assert mdp.edges[0].nbytes == g.m * g.n * 8 // 8
    fplan = derive_plan(layers, 2, "filter")
    fmdp = lower_partitioned("toy", layers, fplan, LUT, DSP, XC7Z020,
                             bits_w_lut=6, bits_a=[4, 8])
    w1 = fplan.shards[0][1] - fplan.shards[0][0]    # dev1's peer = dev0
    assert any(e.nbytes == (g.m * w1 * 8 + 7) // 8 for e in fmdp.edges)
    gather = fmdp.devices[0].memory["L0.gather"]
    assert gather.size == (g.m * (g.n - w1) * 8 + 7) // 8


# ---------------------------------------------------------------------------
# Cross-device token-pairing validation
# ---------------------------------------------------------------------------


def _first_xdev(stream_ops, want_wait):
    for i, op in enumerate(stream_ops):
        if (op.channel in CROSS_DEVICE_CHANNELS
                and isinstance(op.instr, isa.SyncInstr)
                and bool(op.instr.is_wait) == want_wait):
            return i
    raise AssertionError("no cross-device sync found")


@pytest.mark.parametrize("kind", KINDS)
def test_validate_bundle_catches_dropped_send(kind):
    mdp = _chain_bundle(kind, 2)
    validate_bundle(mdp)
    lp = mdp.devices[0].layers[mdp.edges[0].src_layer]
    cp = lp.lut if lp.lut is not None else lp.dsp
    i = _first_xdev(cp.streams["result"], want_wait=False)
    del cp.streams["result"][i]
    with pytest.raises(PartitionError, match="token pairing"):
        validate_bundle(mdp)


@pytest.mark.parametrize("kind", KINDS)
def test_validate_bundle_catches_duplicated_wait(kind):
    mdp = _chain_bundle(kind, 2)
    e = mdp.edges[0]
    lp = mdp.devices[e.dst_device].layers[e.dst_layer]
    cp = lp.lut if lp.lut is not None else lp.dsp
    i = _first_xdev(cp.streams["fetch"], want_wait=True)
    cp.streams["fetch"].insert(i, cp.streams["fetch"][i])
    with pytest.raises(PartitionError, match="token pairing"):
        validate_bundle(mdp)


def test_optimize_bundle_validates_pairing():
    # passes must never elide cross-device syncs; optimize_bundle
    # re-validates afterwards, so an -O1 bundle still pairs exactly
    for kind in KINDS:
        mdp = optimize_bundle(_chain_bundle(kind, 2), 1)
        validate_bundle(mdp)
        for prog in mdp.devices:
            assert prog.opt_stats     # pipeline actually ran per device


# ---------------------------------------------------------------------------
# Golden execution: multi-device == single-device, -O0 == -O1
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kind", KINDS)
@pytest.mark.parametrize("n_devices", (2, 3))
def test_chained_run_bit_exact_vs_single(kind, n_devices):
    ref = np.asarray(_bound_single(_single()).run(_x()))
    mex = _bound_multi(_chain_bundle(kind, n_devices))
    assert (np.asarray(mex.run(_x())) == ref).all()


@pytest.mark.parametrize("kind", KINDS)
def test_chained_run_pass_invariant(kind):
    ref = np.asarray(_bound_multi(_chain_bundle(kind, 2)).run(_x()))
    opt = _bound_multi(_chain_bundle(kind, 2, opt_level=1))
    assert (np.asarray(opt.run(_x())) == ref).all()


@pytest.mark.parametrize("kind", KINDS)
def test_registry_lm_per_layer_bit_exact(kind):
    single = compile_network("llama3.2-1b", seq_len=4)
    mdp = compile_network("llama3.2-1b", seq_len=4, devices=2,
                          partition=kind, opt_level=1)
    ex = _bound_single(single)
    mex = _bound_multi(mdp)
    for gi, lp in enumerate(single.layers):
        x = _x(lp.dims.m, lp.dims.k, seed=100 + gi)
        out_s = np.asarray(ex.run_layer(gi, x))
        out_m = np.asarray(mex.run_layer(gi, x))
        assert out_s.shape == out_m.shape
        assert (out_s == out_m).all(), f"layer {gi} ({lp.name}) diverges"


@pytest.mark.parametrize("kind", KINDS)
def test_pallas_backend_on_bundle_bit_exact(kind):
    mdp = _chain_bundle(kind, 2, opt_level=1)
    ref = np.asarray(_bound_multi(mdp).run(_x()))
    fast = _bound_multi(mdp, backend="pallas")
    assert (np.asarray(fast.run(_x())) == ref).all()


def test_multi_executor_rejects_corrupt_bundle():
    mdp = _chain_bundle("pipeline", 2)
    lp = mdp.devices[0].layers[mdp.edges[0].src_layer]
    cp = lp.lut if lp.lut is not None else lp.dsp
    del cp.streams["result"][_first_xdev(cp.streams["result"], False)]
    with pytest.raises(PartitionError):
        MultiDeviceExecutor(mdp)


# ---------------------------------------------------------------------------
# Simulation: cross-device makespan
# ---------------------------------------------------------------------------


def test_pipeline_two_devices_beat_one_on_registry_arch():
    # the ISSUE acceptance: batched 2-device pipeline makespan < 1 device
    batches = 8
    single = compile_network("llama3.2-1b", seq_len=16, opt_level=1)
    base = simulate_program(single).total_cycles * batches
    mdp = compile_network("llama3.2-1b", seq_len=16, devices=2,
                          partition="pipeline", opt_level=1)
    bs = simulate_program(mdp, batches=batches)
    assert bs.kind == "pipeline" and bs.batches == batches
    assert bs.total_cycles < base
    # first-traversal latency cannot beat a single device (it adds the
    # link hop); the win is steady-state overlap
    assert bs.interval_cycles < simulate_program(single).total_cycles


@pytest.mark.parametrize("kind", KINDS)
def test_bundle_sim_structure(kind):
    mdp = _chain_bundle(kind, 2)
    bs = simulate_program(mdp, batches=4)
    assert len(bs.device_sims) == 2
    assert bs.total_cycles == (bs.latency_cycles
                               + 3 * bs.interval_cycles)
    assert bs.n_instructions == sum(s.n_instructions
                                    for s in bs.device_sims)
    d = bs.decomposition("lut")
    assert set(d) == {"l_wait", "l_run", "l_sig", "l_rst"}


def test_simulate_program_opt_level_on_bundle():
    mdp = _chain_bundle("filter", 2)
    o0 = simulate_program(mdp, batches=1).n_instructions
    o1 = simulate_program(mdp, opt_level=1, batches=1).n_instructions
    assert o1 < o0


# ---------------------------------------------------------------------------
# CNN coverage + CLI
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kind", KINDS)
def test_cnn_compiles_two_devices(kind):
    mdp = compile_network("resnet18", devices=2, partition=kind)
    validate_bundle(mdp)
    assert mdp.n_layers == 21
    if kind == "filter":
        # every device keeps every layer, sharded on output filters
        assert all(len(p.layers) == 21 for p in mdp.devices)
        gather = [s for s in mdp.devices[0].memory.segments
                  if s.name.endswith(".gather")]
        assert len(gather) == 20       # one per layer boundary
    else:
        assert sum(len(p.layers) for p in mdp.devices) == 21


def test_cli_multi_device(capsys):
    assert cli_main(["llama3.2-1b", "--seq-len", "4", "--devices", "2",
                     "--partition", "pipeline", "--simulate"]) == 0
    out = capsys.readouterr().out
    assert "bundle" in out and "pipeline x2" in out and "simulated" in out
    assert cli_main(["llama3.2-1b", "--seq-len", "4", "--devices", "2",
                     "--partition", "filter", "-O", "1", "--execute"]) == 0
    out = capsys.readouterr().out
    assert "filter x2" in out and "executed" in out
    assert cli_main(["llama3.2-1b", "--devices", "0"]) == 2


def test_cli_bundle_bin_round_trip(tmp_path):
    path = tmp_path / "bundle.n3h"
    assert cli_main(["llama3.2-1b", "--seq-len", "4", "--devices", "2",
                     "--partition", "filter", "--format", "bin",
                     "-o", str(path)]) == 0
    mdp = from_bundle_binary(path.read_bytes())
    assert mdp.n_devices == 2
    validate_bundle(mdp)


# ---------------------------------------------------------------------------
# Satellites: executor JIT cache, serving program LRU, shim removal
# ---------------------------------------------------------------------------


def test_pallas_jit_cache_shared_across_instances():
    PallasExecutor.cache_clear()
    prog = _single()
    a = PallasExecutor(prog)
    info = PallasExecutor.cache_info()
    assert info["misses"] == 1 and info["hits"] == 0
    b = PallasExecutor(prog)
    info = PallasExecutor.cache_info()
    assert info["hits"] == 1 and info["programs"] == 1
    assert a._fns is b._fns               # same jitted-table object
    other = lower_network("toy2", CHAIN[:2], LUT, DSP, XC7Z020,
                          bits_w_lut=6, bits_a=4)
    PallasExecutor(other)
    assert PallasExecutor.cache_info()["programs"] == 2
    PallasExecutor.cache_clear()


def test_serving_program_cache_lru():
    from repro.launch.serve import (PROGRAM_CACHE, ProgramKey,
                                    compiled_program_image)
    PROGRAM_CACHE.clear()
    key = ProgramKey(arch="llama3.2-1b", seq_len=4, opt_level=0)
    img1 = compiled_program_image(key)
    assert img1[:8] == b"N3HPROG1"
    img2 = compiled_program_image(key)
    assert img1 is img2                   # cache hit, no re-lowering
    info = PROGRAM_CACHE.info()
    assert info["hits"] == 1 and info["misses"] == 1
    bkey = ProgramKey(arch="llama3.2-1b", seq_len=4, opt_level=0,
                      devices=2, partition="pipeline")
    assert compiled_program_image(bkey)[:8] == b"N3HBUND1"
    assert PROGRAM_CACHE.info()["misses"] == 2
    PROGRAM_CACHE.clear()


def test_serving_program_cache_evicts():
    from repro.launch.serve import ProgramCache, ProgramKey
    cache = ProgramCache(maxsize=1)
    k0 = ProgramKey(arch="llama3.2-1b", seq_len=4, opt_level=0)
    k1 = ProgramKey(arch="llama3.2-1b", seq_len=8, opt_level=0)
    cache.get(k0)
    cache.get(k1)                         # evicts k0
    cache.get(k0)                         # miss again
    assert cache.info() == {"programs": 1, "hits": 0, "misses": 3,
                            "maxsize": 1}


def test_executor_shim_removed():
    # the deprecated repro.compiler.executor shim is gone; the runtime
    # names live in repro.compiler.runtime (re-exported at package top)
    sys.modules.pop("repro.compiler.executor", None)
    with pytest.raises(ModuleNotFoundError):
        importlib.import_module("repro.compiler.executor")
    from repro.compiler.runtime import GoldenExecutor as G
    assert G is GoldenExecutor
