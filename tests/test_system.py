"""End-to-end system tests: train loop + resume + serve (integration)."""
import dataclasses

import jax
import pytest

from repro import configs
from repro.checkpoint import CheckpointManager
from repro.data.synthetic import SyntheticTokens, make_host_batch
from repro.serve.engine import greedy_generate
from repro.train.optimizer import AdamWConfig
from repro.train.step import init_train_state, make_train_step


def _smoke(arch_id):
    arch = configs.get(arch_id)
    return dataclasses.replace(arch, model=arch.smoke)


def test_train_loss_decreases_on_repeated_batch():
    arch = _smoke("llama3.2-1b")
    mod = arch.model_module()
    params = mod.init(arch.model, jax.random.key(0))
    state = init_train_state(params)
    step = jax.jit(make_train_step(arch, AdamWConfig(lr=1e-3,
                                                     warmup_steps=0)))
    batch = make_host_batch(configs.get("llama3.2-1b"), 4, 32)
    losses = []
    for _ in range(8):
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]


def test_train_resume_bitexact(tmp_path):
    """Checkpoint at step k, keep training to k+n; restart from the
    checkpoint and replay: identical loss trajectory (determinism +
    restore fidelity)."""
    arch = _smoke("llama3.2-1b")
    mod = arch.model_module()
    step = jax.jit(make_train_step(arch, AdamWConfig(lr=1e-3)))

    def batches():
        return SyntheticTokens(arch.model.vocab, 4, 32, seed=7)

    params = mod.init(arch.model, jax.random.key(0))
    state = init_train_state(params)
    mgr = CheckpointManager(str(tmp_path))
    data = batches()
    for i in range(3):
        state, m = step(state, data.next_batch())
    mgr.save(3, state, blocking=True)
    ref_losses = []
    for i in range(3):
        state, m = step(state, data.next_batch())
        ref_losses.append(float(m["loss"]))

    # restart
    params2 = mod.init(arch.model, jax.random.key(0))
    state2 = init_train_state(params2)
    state2 = mgr.restore(state2)
    data2 = batches()
    for i in range(3):                       # consume the pre-ckpt batches
        data2.next_batch()
    got_losses = []
    for i in range(3):
        state2, m = step(state2, data2.next_batch())
        got_losses.append(float(m["loss"]))
    assert got_losses == pytest.approx(ref_losses, rel=1e-5)


def test_greedy_generate_deterministic():
    arch = _smoke("llama3.2-1b")
    mod = arch.model_module()
    params = mod.init(arch.model, jax.random.key(0))
    prompts = jax.random.randint(jax.random.key(1), (2, 6), 0,
                                 arch.model.vocab)
    out1 = greedy_generate(arch, params, prompts, n_new=4)
    out2 = greedy_generate(arch, params, prompts, n_new=4)
    assert out1.shape == (2, 10)
    assert bool((out1 == out2).all())
    assert bool((out1[:, :6] == prompts).all())


def test_dryrun_collective_parser():
    from repro.launch.dryrun import collective_bytes
    hlo = """
  %ag = bf16[128,256]{1,0} all-gather(%x), replica_groups={}
  %ar.1 = f32[64]{0} all-reduce-start(%y), to_apply=%sum
  %ar.2 = f32[64]{0} all-reduce-done(%ar.1)
  %cp = (s8[32,32]{1,0}, s8[32,32]{1,0}) collective-permute-start(%z)
  %rs = f32[16,16]{1,0} reduce-scatter(%w), dimensions={0}
"""
    got = collective_bytes(hlo)
    assert got["all-gather"] == 128 * 256 * 2
    assert got["all-reduce"] == 64 * 4          # -done not double counted
    assert got["collective-permute"] == 2 * 32 * 32
    assert got["reduce-scatter"] == 16 * 16 * 4
