"""Sharding-rule invariants (no multi-device mesh needed: 1x1)."""
import jax
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.parallel.sharding import (
    DEFAULT_RULES,
    logical_to_spec,
    zero1_spec,
)


def _mesh(shape, axes):
    devs = np.array(jax.devices()[:1] * int(np.prod(shape))).reshape(shape)
    return Mesh(devs, axes)


MESH = _mesh((4, 2), ("data", "model"))


def test_basic_mapping():
    spec = logical_to_spec(("batch", None, "mlp"), MESH, DEFAULT_RULES,
                           shape=(8, 3, 16))
    assert spec == P("data", None, "model")


def test_divisibility_fallback():
    # 6 % 4 != 0 -> batch dim replicated
    spec = logical_to_spec(("batch", "mlp"), MESH, DEFAULT_RULES,
                           shape=(6, 16))
    assert spec == P(None, "model")


def test_mesh_axis_used_once():
    # both dims want "model": the first wins, second replicates
    spec = logical_to_spec(("mlp", "heads"), MESH, DEFAULT_RULES,
                           shape=(16, 16))
    assert spec == P("model")


def test_missing_mesh_axes_dropped():
    mesh1d = _mesh((2,), ("model",))
    spec = logical_to_spec(("batch", "mlp"), mesh1d, DEFAULT_RULES,
                           shape=(8, 16))
    assert spec == P(None, "model")     # no "data"/"pod" on this mesh


def test_rule_overrides():
    rules = DEFAULT_RULES.replace(act_heads=(), act_seq_attn=("model",))
    spec = logical_to_spec(("batch", "act_seq_attn", "act_heads", None),
                           MESH, rules, shape=(8, 16, 7, 4))
    assert spec == P("data", "model")


def test_multi_axis_dim():
    mesh3 = _mesh((2, 2, 2), ("pod", "data", "model"))
    spec = logical_to_spec(("batch", None), mesh3, DEFAULT_RULES,
                           shape=(8, 3))
    assert spec == P(("pod", "data"))


def test_partial_multi_axis_fallback():
    mesh3 = _mesh((2, 2, 2), ("pod", "data", "model"))
    # 2 divides by pod(2) but not pod*data(4): trailing axis dropped
    spec = logical_to_spec(("batch",), mesh3, DEFAULT_RULES, shape=(2,))
    assert spec == P("pod")


def test_zero1_spec_shards_replicated_dim():
    spec = zero1_spec(P(None, "model"), (8, 16), MESH)
    assert spec == P("data", "model")
    # already data-sharded -> unchanged
    spec2 = zero1_spec(P("data", None), (8, 16), MESH)
    assert spec2 == P("data", None)


def test_embed_rule_is_fsdp():
    """Weight embed dims shard over data (ZeRO-3 profile)."""
    spec = logical_to_spec(("embed", "mlp"), MESH, DEFAULT_RULES,
                           shape=(64, 32))
    assert spec == P("data", "model")
