"""Per-architecture smoke tests (deliverable f).

Every assigned architecture instantiates a REDUCED same-family config
and runs one forward + one train step on CPU, asserting output shapes
and the absence of NaNs. The FULL configs are exercised only by the
dry-run (launch/dryrun.py — ShapeDtypeStruct, no allocation).
"""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.data.synthetic import make_host_batch
from repro.train.step import init_train_state, make_train_step

ARCHS = configs.list_archs()


@pytest.mark.parametrize("arch_id", ARCHS)
def test_smoke_forward(arch_id):
    arch = configs.get(arch_id)
    smoke = dataclasses.replace(arch, model=arch.smoke)
    mod = smoke.model_module()
    params = mod.init(smoke.model, jax.random.key(0))
    batch = make_host_batch(arch, batch=2, seq=24)
    if arch.module == "encdec":
        logits, aux = mod.forward(params, batch["frames"], batch["tokens"],
                                  smoke.model)
    else:
        logits, aux = mod.forward(params, batch["tokens"], smoke.model,
                                  extra_embed=batch.get("extra_embed"))
    assert logits.shape == (2, 24, smoke.model.vocab)
    assert not bool(jnp.isnan(logits).any()), f"{arch_id}: NaN logits"
    assert jnp.isfinite(jnp.asarray(aux))


@pytest.mark.parametrize("arch_id", ARCHS)
def test_smoke_train_step(arch_id):
    arch = configs.get(arch_id)
    smoke = dataclasses.replace(arch, model=arch.smoke)
    mod = smoke.model_module()
    params = mod.init(smoke.model, jax.random.key(0))
    state = init_train_state(params)
    step = jax.jit(make_train_step(smoke))
    batch = make_host_batch(arch, batch=2, seq=24)
    state, metrics = step(state, batch)
    assert jnp.isfinite(metrics["loss"]), f"{arch_id}: non-finite loss"
    assert jnp.isfinite(metrics["grad_norm"])
    assert int(state.step) == 1


@pytest.mark.parametrize("arch_id", ARCHS)
def test_smoke_decode_step(arch_id):
    arch = configs.get(arch_id)
    smoke = dataclasses.replace(arch, model=arch.smoke)
    mod = smoke.model_module()
    params = mod.init(smoke.model, jax.random.key(0))
    if arch.module == "ssm":
        cache = mod.init_cache(smoke.model, 2, dtype=jnp.float32)
    elif arch.module == "encdec":
        frames = 0.1 * jax.random.normal(jax.random.key(1),
                                         (2, 16, smoke.model.d_model))
        memory = mod.encode(params, frames, smoke.model)
        cache = mod.init_cache(smoke.model, 2, 16, 16, jnp.float32)
        cache = mod.build_cross_cache(params, memory, smoke.model, cache,
                                      jnp.float32)
    else:
        cache = mod.init_cache(smoke.model, 2, 16, jnp.float32)
    token = jnp.zeros((2, 1), jnp.int32)
    logits, cache2 = mod.decode_step(params, token, cache, 0, smoke.model)
    assert logits.shape == (2, smoke.model.vocab)
    assert not bool(jnp.isnan(logits).any())


def test_all_ten_archs_registered():
    assert len(ARCHS) == 10
    expected = {"jamba-v0.1-52b", "seamless-m4t-large-v2", "yi-34b",
                "gemma-7b", "llama3.2-1b", "qwen3-8b", "mamba2-780m",
                "qwen3-moe-235b-a22b", "deepseek-v2-236b", "qwen2-vl-2b"}
    assert set(ARCHS) == expected


def test_published_param_counts():
    """Full configs match the published model sizes (sanity of the
    config transcription; +-10%)."""
    expected = {
        "deepseek-v2-236b": 236e9, "qwen3-moe-235b-a22b": 235e9,
        "jamba-v0.1-52b": 52e9, "yi-34b": 34.4e9, "gemma-7b": 8.5e9,
        "qwen3-8b": 8.2e9, "llama3.2-1b": 1.24e9, "mamba2-780m": 0.78e9,
        "qwen2-vl-2b": 1.5e9, "seamless-m4t-large-v2": 2.0e9,
    }
    for arch_id, want in expected.items():
        arch = configs.get(arch_id)
        n = arch.model_module().param_count(arch.model)
        assert abs(n - want) / want < 0.10, (arch_id, n, want)
