"""Dataset-scale accuracy harness: determinism, the agreement floor,
backend equivalence, and the DSE measured-accuracy hook.

The contract under test (ISSUE 10 tentpole surface):
  * ``repro.eval.accuracy.measure`` is deterministic — same seed, same
    report, with a disjoint calibration/eval sample split;
  * on the reduced networks at the documented operating point the
    compiled pipeline meets :data:`AGREEMENT_FLOOR` top-1 agreement
    against the frozen-norm fp32 reference (the CI gate);
  * golden and pallas measure the *same* agreement (they are bit-exact,
    so the reports may differ only in the backend label);
  * ``run_search(..., accuracy_fn=...)`` re-scores elites with measured
    agreement: ``reward_source == "measured"``, ``measured_acc``
    recorded, and the calibration CSV carries the column.
"""
import csv
import dataclasses

import pytest

from repro.dse.search import CALIBRATION_FIELDS, run_search
from repro.eval.accuracy import (
    AGREEMENT_FLOOR,
    make_accuracy_fn,
    measure,
)
from repro.models import cnn
from repro.models.cnn import specs_for

#: Smallest useful operating point — plumbing tests only.
TINY = dict(n_samples=32, batch=16, train_steps=30, simulate=False)
#: CI-smoke-shaped point for the floor checks: reduced eval stream,
#: full 200-step reference training (the floor is calibrated for a
#: converged reference — an undertrained one has thin margins).
SMOKE = dict(n_samples=64, batch=32, train_steps=200)


@pytest.fixture(scope="module")
def tiny_pallas():
    return measure("resnet18", backend="pallas", **TINY)


# ---------------------------------------------------------------------------
# Determinism + backend equivalence
# ---------------------------------------------------------------------------


def test_measure_is_deterministic(tiny_pallas):
    again = measure("resnet18", backend="pallas", **TINY)
    assert again == tiny_pallas


def test_golden_measures_same_agreement(tiny_pallas):
    gold = measure("resnet18", backend="golden", **TINY)
    assert gold.backend == "golden"
    assert dataclasses.replace(gold, backend="pallas") == tiny_pallas


def test_bench_row_schema(tiny_pallas):
    row = tiny_pallas.bench_row()
    assert row["BENCH"] == "accuracy.eval"
    assert row["network"] == "resnet18"
    assert row["n_samples"] == TINY["n_samples"]
    assert row["agreement_floor"] == AGREEMENT_FLOOR
    assert row["meets_floor"] == (row["agreement"] >= AGREEMENT_FLOOR)
    assert row["latency_ms"] is None        # simulate=False


# ---------------------------------------------------------------------------
# The agreement floor (reduced networks, documented operating point)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ["resnet18", "mobilenet_v2"])
def test_agreement_meets_documented_floor(arch):
    rep = measure(arch, backend="pallas", simulate=(arch == "resnet18"),
                  **SMOKE)
    assert rep.agreement >= AGREEMENT_FLOOR, rep
    # the trained reference actually separates the synthetic task —
    # otherwise agreement would measure coin flips, not quant damage
    assert rep.top1_ref >= 0.9
    if arch == "resnet18":
        assert rep.sim_cycles and rep.sim_cycles > 0
        assert rep.latency_ms and rep.latency_ms > 0


# ---------------------------------------------------------------------------
# DSE hook: elites re-scored by measured accuracy
# ---------------------------------------------------------------------------


def test_dse_elites_rescored_with_measured_accuracy(tmp_path):
    cfg = cnn.reduced_config("resnet18")
    fn = make_accuracy_fn(cfg, n_samples=16, batch=16, train_steps=15)
    res = run_search("resnet18", specs=specs_for(cfg), episodes=4,
                     sim_every=2, top_k=2, simulate_elites=True,
                     accuracy_fn=fn, target_latency_ms=50.0, seed=0)
    assert res.reward_source == "measured"
    assert res.elites
    for row in res.elites:
        assert row["reward_source"] == "measured"
        assert row["measured_acc"] is not None
        assert 0.0 <= row["measured_acc"] <= 100.0
    assert res.best_info["measured_acc"] is not None
    # the frontier column rides in the calibration CSV
    assert "measured_acc" in CALIBRATION_FIELDS
    path = tmp_path / "calib.csv"
    res.write_calibration_csv(str(path))
    rows = list(csv.DictReader(path.open()))
    assert all(r["reward_source"] == "measured" and r["measured_acc"]
               for r in rows)


def test_search_without_accuracy_fn_keeps_simulated_source():
    cfg = cnn.reduced_config("resnet18")
    res = run_search("resnet18", specs=specs_for(cfg), episodes=2,
                     sim_every=2, top_k=1, simulate_elites=True,
                     target_latency_ms=50.0, seed=0)
    assert res.reward_source == "simulated"
    assert all(r["measured_acc"] is None for r in res.elites)
