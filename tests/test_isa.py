"""ISA encode/decode round-trip — bit-exact property tests."""
import hypothesis.strategies as st
from hypothesis import given, settings

from repro.core import isa


def _field(bits):
    return st.integers(0, (1 << bits) - 1)


@settings(max_examples=200, deadline=None)
@given(core=st.sampled_from(list(isa.CoreSel)), onchip=_field(16),
       stage=_field(3), rng=_field(1), base=_field(32), off=_field(24),
       length=_field(16), is_result=st.booleans())
def test_fetch_result_roundtrip(core, onchip, stage, rng, base, off,
                                length, is_result):
    cls = isa.ResultInstr if is_result else isa.FetchInstr
    instr = cls(core=core, onchip_base=onchip, stage_ctrl=stage,
                onchip_range=rng, ddr_base=base, ddr_offset=off,
                ddr_range=length)
    word = instr.encode()
    assert 0 <= word < (1 << isa.WORD_BITS)
    assert isa.decode(word) == instr


@settings(max_examples=200, deadline=None)
@given(core=st.sampled_from(list(isa.CoreSel)), a=_field(16), w=_field(16),
       m=_field(12), k=_field(16), n=_field(12), bw=_field(4), ba=_field(4),
       acc=_field(1))
def test_execute_roundtrip(core, a, w, m, k, n, bw, ba, acc):
    instr = isa.ExecuteInstr(core=core, buf_addr_a=a, buf_addr_w=w,
                             tile_m=m, tile_k=k, tile_n=n, bits_w=bw,
                             bits_a=ba, accumulate=acc)
    assert isa.decode(instr.encode()) == instr


@settings(max_examples=100, deadline=None)
@given(core=st.sampled_from(list(isa.CoreSel)),
       src=st.sampled_from(list(isa.Engine)),
       dst=st.sampled_from(list(isa.Engine)),
       cur=_field(1), nxt=_field(2), flag=_field(3), wait=_field(1))
def test_sync_roundtrip(core, src, dst, cur, nxt, flag, wait):
    instr = isa.SyncInstr(core=core, src_engine=src, dst_engine=dst,
                          cur_state=cur, next_state=nxt, token_flag=flag,
                          is_wait=wait)
    assert isa.decode(instr.encode()) == instr


def test_field_overflow_rejected():
    import pytest
    with pytest.raises(ValueError):
        isa.FetchInstr(isa.CoreSel.LUT, 1 << 16, 0, 0, 0, 0, 0).encode()


def test_distinct_instructions_distinct_words():
    a = isa.ExecuteInstr(isa.CoreSel.LUT, 0, 0, 1, 1, 1, 2, 2, 0).encode()
    b = isa.ExecuteInstr(isa.CoreSel.DSP, 0, 0, 1, 1, 1, 2, 2, 0).encode()
    assert a != b
