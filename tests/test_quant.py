"""Quantizer (Eq. 2) + hybrid filter-wise scheme (§4) properties."""
import hypothesis.extra.numpy as hnp
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings

from repro.quant.hybrid import (
    LayerQuantConfig,
    hybrid_fake_quant_weight,
    hybrid_quantize_weight,
    kl_filter_allocation,
)
from repro.quant.uniform import (
    dequantize,
    fake_quant_per_channel,
    fit_scale,
    qrange,
    quant_snr_db,
    quantize,
)

finite = st.floats(-100.0, 100.0, allow_nan=False, width=32)


@settings(max_examples=50, deadline=None)
@given(x=hnp.arrays(np.float32, hnp.array_shapes(min_dims=1, max_dims=3,
                                                 max_side=16),
                    elements=finite),
       bits=st.integers(2, 8))
def test_codes_in_range(x, bits):
    s = fit_scale(jnp.asarray(x), bits)
    q = quantize(jnp.asarray(x), s, bits)
    lo, hi = qrange(bits)
    assert int(q.min()) >= lo and int(q.max()) <= hi


@settings(max_examples=50, deadline=None)
@given(x=hnp.arrays(np.float32, (8, 8), elements=finite),
       bits=st.integers(2, 8))
def test_quantization_error_bounded(x, bits):
    """|x - dq(q(x))| <= s/2 inside the clip range."""
    xj = jnp.asarray(x)
    s = fit_scale(xj, bits)
    deq = dequantize(quantize(xj, s, bits), s)
    err = np.abs(np.asarray(deq) - x)
    assert (err <= float(s) / 2 + 1e-6).all()


def test_more_bits_higher_snr():
    x = jnp.asarray(np.random.default_rng(0).standard_normal((64, 64)),
                    jnp.float32)
    snrs = []
    for bits in (2, 4, 6, 8):
        s = fit_scale(x, bits)
        deq = dequantize(quantize(x, s, bits), s)
        snrs.append(float(quant_snr_db(x, deq)))
    assert snrs == sorted(snrs)
    assert snrs[-1] - snrs[0] > 20.0       # ~6 dB/bit


def test_ste_gradient_identity_in_range():
    x = jnp.linspace(-0.5, 0.5, 11)
    g = jax.grad(lambda v: fake_quant_per_channel(v[None], 8)[0].sum())(x)
    np.testing.assert_allclose(np.asarray(g), np.ones(11), atol=1e-6)


def test_hybrid_roundtrip_preserves_order():
    rng = np.random.default_rng(1)
    w = jnp.asarray(rng.standard_normal((32, 9)), jnp.float32)
    cfg = LayerQuantConfig(w_bits_lut=8, a_bits=4, ratio=0.5)
    hq = hybrid_quantize_weight(w, cfg)
    deq = hq.dequantize()
    assert deq.shape == w.shape
    # 8-bit lut + 4-bit dsp: everything within the coarser (4-bit) step
    assert float(jnp.abs(deq - w).max()) < float(jnp.abs(w).max()) / 7


def test_kl_allocation_is_valid_permutation():
    rng = np.random.default_rng(2)
    w = jnp.asarray(rng.standard_normal((32, 128)), jnp.float32)
    cfg = LayerQuantConfig(w_bits_lut=8, a_bits=4, ratio=0.5)
    perm = np.asarray(kl_filter_allocation(w, cfg))
    assert sorted(perm.tolist()) == list(range(32))


def test_mse_allocation_routes_damaged_filters_to_high_bits():
    """Beyond-paper "mse" metric: filters with the worst quantization
    damage land on the flexible (high-bit) core."""
    rng = np.random.default_rng(2)
    # outlier-laden filters (crushed by max-abs int4) vs benign gaussians
    hostile = rng.standard_normal((16, 256)) * 0.05
    hostile[:, 0] = 4.0                          # one huge outlier each
    benign = rng.standard_normal((16, 256))
    w = jnp.asarray(np.concatenate([hostile, benign]), jnp.float32)
    cfg = LayerQuantConfig(w_bits_lut=8, a_bits=4, ratio=0.5,
                           alloc_metric="mse")
    perm = np.asarray(kl_filter_allocation(w, cfg))
    lut_half = set(perm[:16].tolist())
    assert len(lut_half & set(range(16))) >= 14


def test_hybrid_fake_quant_grad_finite():
    w = jnp.asarray(np.random.default_rng(3).standard_normal((16, 8)),
                    jnp.float32)
    cfg = LayerQuantConfig(w_bits_lut=6, a_bits=4, ratio=0.4)
    g = jax.grad(lambda w: hybrid_fake_quant_weight(w, cfg).sum())(w)
    assert bool(jnp.isfinite(g).all())


def test_bad_config_rejected():
    with pytest.raises(ValueError):
        LayerQuantConfig(ratio=1.5)
    with pytest.raises(ValueError):
        LayerQuantConfig(w_bits_lut=9)
