"""Documentation suite checks: every intra-repo markdown link resolves.

Runs in tier-1 and in the CI ``docs`` job. External links (http/https/
mailto) are out of scope; anchors are stripped before the existence
check. Inline-code and fenced-code spans are ignored so ISA syntax
examples don't false-positive.
"""
import re
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent

#: every tracked markdown file that carries intra-repo links
DOC_FILES = sorted(
    p for p in list(REPO.glob("*.md")) + list((REPO / "docs").glob("*.md"))
)

_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_FENCE = re.compile(r"```.*?```", re.DOTALL)
_INLINE_CODE = re.compile(r"`[^`]*`")


def _links(md_path: Path) -> list[str]:
    text = _FENCE.sub("", md_path.read_text())
    text = _INLINE_CODE.sub("", text)
    return _LINK.findall(text)


def test_doc_files_exist():
    names = {p.name for p in DOC_FILES}
    assert {"README.md", "ROADMAP.md"} <= names
    assert any(p.parent.name == "docs" for p in DOC_FILES)


@pytest.mark.parametrize("md_path", DOC_FILES, ids=lambda p: str(
    p.relative_to(REPO)))
def test_intra_repo_links_resolve(md_path: Path):
    broken = []
    for target in _links(md_path):
        if target.startswith(("http://", "https://", "mailto:", "#")):
            continue
        rel = target.split("#", 1)[0]
        if not rel:
            continue
        resolved = (md_path.parent / rel).resolve()
        if not resolved.exists():
            broken.append(target)
    assert not broken, (f"{md_path.relative_to(REPO)}: broken intra-repo "
                        f"links {broken}")
