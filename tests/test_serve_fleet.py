"""Distributed serving fleet: the ISSUE-9 acceptance surface.

  * wire protocol: frame round-trip (every kind), structural-defect
    rejection, deterministic array packing, byte-for-byte
    ``N3HBUND1`` section splitting;
  * per-slot decode: ``step_slots`` is bit-exact vs scalar ``step`` at
    batch 1, and a request admitted mid-flight at a step boundary
    (continuous batching) matches a dedicated batch-1 session;
  * the fleet itself: worker registration + heartbeat, end-to-end
    tokens bit-exact vs the single-process
    ``greedy_generate_compiled`` oracle, overlapped continuous
    admission, per-tenant in-flight and program-cache admission;
  * failure containment: a crashed subprocess worker and a step
    timeout both surface as :class:`RequestFailed` on the request
    futures while the server stays up;
  * 2-worker bundle fleet: the ``*.xdev`` hand-shake over real
    transport is bit-exact vs ``MultiDeviceExecutor.run`` for both
    plan kinds.
"""
import concurrent.futures
import time

import numpy as np
import pytest

from repro.compiler import (
    ExecutionError,
    ExecutorSession,
    GemmLayer,
    MultiDeviceExecutor,
    asm,
    compile_decode_network,
    derive_plan,
    from_bundle_binary,
    lower_partitioned,
    to_bundle_binary,
)
from repro.core.scheduler import (
    XC7Z020,
    DspCoreConfig,
    GemmDims,
    LutCoreConfig,
)
from repro.obs import METRICS
from repro.serve import protocol
from repro.serve.engine import greedy_generate_compiled
from repro.serve.fleet import (
    AdmissionError,
    BundleFleet,
    FleetServer,
    RequestFailed,
    TenantPolicy,
    _Request,
    _Slot,
)
from repro.serve.protocol import (
    ProtocolError,
    decode_frame,
    encode_frame,
    pack_arrays,
    split_bundle_image,
    unpack_arrays,
)

ARCH = "llama3.2-1b"
MAX_SEQ = 8
SLOTS = 2
SEED = 0

LUT = LutCoreConfig(m=8, n=16, k=128)
DSP = DspCoreConfig(n_reg_row_a=13)
CHAIN = [GemmLayer("fc0", GemmDims(24, 32, 48)),
         GemmLayer("fc1", GemmDims(24, 48, 40)),
         GemmLayer("fc2", GemmDims(24, 40, 36)),
         GemmLayer("fc3", GemmDims(24, 36, 20))]


def _chain_bundle(kind):
    plan = derive_plan(CHAIN, 2, kind)
    return lower_partitioned("toy", CHAIN, plan, LUT, DSP, XC7Z020,
                             bits_w_lut=6, bits_a=4, opt_level=1)


# ---------------------------------------------------------------------------
# Wire protocol
# ---------------------------------------------------------------------------


def test_frame_roundtrip_every_kind():
    for kind in protocol.KINDS:
        hdr = {"seq": 7, "slot": 1, "channel": "L2.xdev"}
        payload = bytes(range(64))
        k, h, p = decode_frame(encode_frame(kind, hdr, payload))
        assert (k, h, p) == (kind, hdr, payload)
    # empty header / payload defaults
    assert decode_frame(encode_frame("ping")) == ("ping", {}, b"")
    # canonical JSON: identical inputs yield identical bytes
    assert (encode_frame("step", {"b": 1, "a": 2})
            == encode_frame("step", {"a": 2, "b": 1}))


def test_frame_rejects_structural_defects():
    with pytest.raises(ProtocolError):
        encode_frame("warp_cores")          # unknown kind
    good = encode_frame("result", {"seq": 1}, b"xyz")
    with pytest.raises(ProtocolError):
        decode_frame(b"NOPE" + good[4:])    # bad magic
    with pytest.raises(ProtocolError):
        decode_frame(good[:8])              # short frame
    with pytest.raises(ProtocolError):
        decode_frame(good + b"\x00")        # trailing bytes
    bad_ver = bytearray(good)
    bad_ver[4] = 99
    with pytest.raises(ProtocolError):
        decode_frame(bytes(bad_ver))        # unsupported version
    bad_kind = bytearray(good)
    bad_kind[5] = 200
    with pytest.raises(ProtocolError):
        decode_frame(bytes(bad_kind))       # unknown kind code


def test_pack_arrays_roundtrip_and_determinism():
    rng = np.random.default_rng(0)
    arrays = {
        "L0.w_lut": rng.integers(-8, 8, (16, 12)).astype(np.int8),
        "L0.s_lut": rng.random(12).astype(np.float32),
        "embed": rng.random((4, 3, 2)),
        "scalar": np.float64(2.5),
        "big_endian": np.arange(5, dtype=">i4"),
    }
    blob = pack_arrays(arrays)
    back = unpack_arrays(blob)
    assert sorted(back) == sorted(arrays)
    for name in arrays:
        np.testing.assert_array_equal(back[name], arrays[name])
    # big-endian inputs are normalized on the wire
    assert back["big_endian"].dtype == np.dtype("<i4")
    # deterministic: dict insertion order never changes the bytes
    reordered = {k: arrays[k] for k in reversed(list(arrays))}
    assert pack_arrays(reordered) == blob


def test_unpack_arrays_rejects_corrupt_payloads():
    blob = pack_arrays({"x": np.arange(4, dtype=np.int32)})
    with pytest.raises(ProtocolError):
        unpack_arrays(blob + b"\x00")       # trailing bytes
    with pytest.raises(ProtocolError):
        unpack_arrays(blob[:-3])            # truncated data
    with pytest.raises(ProtocolError):
        unpack_arrays(b"\xff\xff\xff\xff")  # absurd count, no data


def test_split_bundle_image_sections_byte_exact():
    mdp = _chain_bundle("pipeline")
    image = to_bundle_binary(mdp)
    meta, sections = split_bundle_image(image)
    # sections are the per-device N3HPROG1 images, byte for byte
    assert sections == [asm.to_binary(p) for p in mdp.devices]
    assert meta["bundle"] == mdp.name
    assert len(meta["edges"]) == len(mdp.edges)
    with pytest.raises(ProtocolError):
        split_bundle_image(b"BOGUS123" + image[8:])
    with pytest.raises(ProtocolError):
        split_bundle_image(image + b"\x00")


# ---------------------------------------------------------------------------
# Per-slot decode sessions (the continuous-batching substrate)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def oracle():
    """Single-process batch-1 golden session: the fleet's hard
    bit-exactness reference."""
    prog = compile_decode_network(ARCH, batch=1, max_seq=MAX_SEQ,
                                  opt_level=1)
    session = ExecutorSession(prog, backend="golden")
    session.bind_synthetic_all(seed=SEED)
    return prog, session


def _oracle_tokens(session, prompt, n_new):
    row = greedy_generate_compiled(
        session, np.asarray(prompt, np.int32)[None, :], n_new)
    return np.asarray(row)[0]


def test_step_slots_matches_scalar_step_at_batch1(oracle):
    prog, _ = oracle
    scalar = ExecutorSession(prog, backend="golden")
    scalar.bind_synthetic_all(seed=SEED)
    scalar.reset()
    slots = ExecutorSession(prog, backend="golden")
    slots.bind_synthetic_all(seed=SEED)
    slots.reset(per_slot=True)
    for pos, tok in enumerate([3, 7, 11, 2]):
        ref = np.asarray(scalar.step(tok, pos))
        got = np.asarray(slots.step_slots([tok], [pos]))
        np.testing.assert_array_equal(got, ref)
    # scalar step() is refused on a per-slot session
    with pytest.raises(ExecutionError):
        slots.step(0, 0)
    # and reset_slot is refused outside per-slot mode
    with pytest.raises(ExecutionError):
        scalar.reset_slot(0)


def _mk_slot(prompt, n_new):
    return _Slot(_Request(0, "t", np.asarray(prompt, np.int32), n_new,
                          concurrent.futures.Future(), 0.0))


def test_staggered_admission_is_bit_exact(oracle, fleet):
    """Admit request B into slot 1 at a step boundary while request A
    is mid-flight on slot 0 — both token rows must match dedicated
    batch-1 sessions (the continuous-batching correctness gate)."""
    from repro.launch.serve import compiled_program_image
    prog = asm.from_binary(compiled_program_image(fleet.key))
    sess = ExecutorSession(prog, backend="golden")
    sess.bind_synthetic_all(seed=SEED)
    sess.reset(per_slot=True)
    a = _mk_slot([5, 9], 3)
    b = None
    for step in range(4 + 3):               # a: 4 steps, b: 3, staggered by 2
        if step == 2:
            sess.reset_slot(1)
            b = _mk_slot([7, 3], 2)
        toks = [a.next_token() if not a.done else 0,
                b.next_token() if b and not b.done else 0]
        pos = [a.pos, b.pos if b else 0]
        logits = np.asarray(sess.step_slots(toks, pos))
        if not a.done:
            a.advance(int(np.argmax(logits[0])))
        if b is not None and not b.done:
            b.advance(int(np.argmax(logits[1])))
    _, osess = oracle
    np.testing.assert_array_equal(
        np.asarray(a.out), _oracle_tokens(osess, [5, 9], 3)[2:])
    np.testing.assert_array_equal(
        np.asarray(b.out), _oracle_tokens(osess, [7, 3], 2)[2:])


# ---------------------------------------------------------------------------
# FleetServer end to end
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def fleet():
    server = FleetServer(
        ARCH,
        [("w0", "golden", "thread"), ("w1", "golden", "thread")],
        batch_slots=SLOTS, max_seq=MAX_SEQ, seed=SEED,
        tenants={"small": TenantPolicy(max_inflight=1, max_programs=1)})
    with server as f:
        yield f


def test_worker_registration_and_heartbeat(fleet):
    assert fleet.live_workers() == ["w0", "w1"]
    assert fleet.ping("w0") >= 0.0
    assert fleet.ping("w1") >= 0.0
    assert METRICS.counter("serve.fleet.workers.registered") >= 2
    with pytest.raises(RequestFailed):
        fleet.ping("w99")


def test_fleet_tokens_bit_exact_vs_single_process(fleet, oracle):
    _, osess = oracle
    reqs = [([5], 2), ([3, 11], 3), ([1, 2, 3], 4), ([9, 8], 2)]
    futs = [fleet.submit(p, n) for p, n in reqs]
    for (p, n), fut in zip(reqs, futs):
        np.testing.assert_array_equal(
            np.asarray(fut.result(600)), _oracle_tokens(osess, p, n))


def test_continuous_admission_overlaps_requests(fleet):
    steps0 = METRICS.counter("serve.fleet.steps")
    admitted0 = METRICS.counter("serve.fleet.admitted")
    reqs = [([2, 4], 3)] * 4                # 4 steps each served alone
    futs = [fleet.submit(p, n) for p, n in reqs]
    for fut in futs:
        fut.result(600)
    assert METRICS.counter("serve.fleet.admitted") - admitted0 == 4
    # batching: strictly fewer fleet steps than 4 back-to-back solo
    # requests would take (4 requests x 4 steps)
    assert METRICS.counter("serve.fleet.steps") - steps0 < 16


def test_submit_validates_request_shape(fleet):
    with pytest.raises(ValueError):
        fleet.submit([], 2)                 # empty prompt
    with pytest.raises(ValueError):
        fleet.submit([1, 2], 0)             # no new tokens
    with pytest.raises(ValueError):
        fleet.submit([1] * MAX_SEQ, 1)      # exceeds the cache window


def test_tenant_inflight_admission(fleet):
    fut = fleet.submit([1, 2], 5, tenant="small")
    with pytest.raises(AdmissionError):     # budget: 1 in flight
        fleet.submit([1], 1, tenant="small")
    assert np.asarray(fut.result(600)).shape == (7,)
    # completing the request releases the budget
    fleet.submit([1], 1, tenant="small").result(600)


def test_tenant_program_admission(fleet):
    rejected0 = METRICS.counter("serve.fleet.admission.rejected")
    # re-admitting an already-pinned program is free
    fleet.admit_program("small", fleet.key)
    with pytest.raises(AdmissionError):     # budget: 1 distinct program
        fleet.admit_program("small", ("decode", "other-arch", 4, 4))
    assert (METRICS.counter("serve.fleet.admission.rejected")
            > rejected0)


# ---------------------------------------------------------------------------
# Failure containment
# ---------------------------------------------------------------------------


def _wait_until(predicate, timeout_s=10.0):
    deadline = time.perf_counter() + timeout_s
    while time.perf_counter() < deadline:
        if predicate():
            return True
        time.sleep(0.05)
    return predicate()


def test_worker_crash_fails_request_server_stays_up():
    server = FleetServer(ARCH, [("w0", "golden", "subprocess")],
                         batch_slots=SLOTS, max_seq=MAX_SEQ, seed=SEED)
    with server:
        fut = server.submit([1, 2, 3], 4)
        time.sleep(2.0)                     # let the worker admit it
        server.processes["w0"].kill()
        with pytest.raises(RequestFailed):
            fut.result(120)
        # the server survives the crash: loop thread still running,
        # the dead worker dropped from the roster
        assert server._thread.is_alive()
        assert _wait_until(lambda: server.live_workers() == [])
        with pytest.raises(RequestFailed):
            server.submit([1], 1)           # no live workers left


def test_step_timeout_fails_request_server_stays_up():
    server = FleetServer(ARCH, [("w0", "golden", "thread")],
                         batch_slots=SLOTS, max_seq=MAX_SEQ, seed=SEED,
                         step_timeout_s=0.001)
    with server:
        fut = server.submit([1, 2], 3)
        with pytest.raises(RequestFailed):
            fut.result(120)
        assert server._thread.is_alive()
        assert _wait_until(lambda: server.live_workers() == [])
        with pytest.raises(RequestFailed):
            server.submit([1], 1)


# ---------------------------------------------------------------------------
# Bundle fleet: xdev hand-shake over real transport
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kind", ["pipeline", "filter"])
def test_bundle_fleet_bit_exact_vs_in_process(kind):
    mdp = _chain_bundle(kind)
    image = to_bundle_binary(mdp)
    mex = MultiDeviceExecutor(from_bundle_binary(image), backend="golden")
    for gi in range(mdp.n_layers):
        mex.bind_synthetic(gi)
    x = np.random.default_rng(0).integers(-8, 8, (24, 32)).astype(np.int8)
    ref = np.asarray(mex.run(x))
    with BundleFleet(image, seed=None) as bf:
        assert len(bf.sections) == 2
        got = np.asarray(bf.run(x))
    np.testing.assert_array_equal(got, ref)
