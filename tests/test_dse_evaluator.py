"""Simulator-in-the-loop DSE: config→program round-trip, determinism,
elite re-ranking, the LRU program cache, and the two-tier search."""
import numpy as np
import pytest

from repro.core.scheduler import XC7Z020, DspCoreConfig, LutCoreConfig
from repro.core.workloads import ConvSpec
from repro.dse.env import AccuracyProxy, evaluate_config
from repro.dse.evaluator import (
    SIM_GAP_TOL_PCT,
    EliteSet,
    ProgramEvaluator,
    gemm_specs,
    sim_gap_report,
)
from repro.dse.search import run_search

DEV = XC7Z020
LUT = LutCoreConfig(m=8, n=16, k=128)
DSP = DspCoreConfig(n_reg_row_a=DspCoreConfig.rows_for_device(DEV))
PROXY = AccuracyProxy(baseline_acc=70.0)

#: small FC chain — episodes and compiles stay milliseconds
SPECS = [ConvSpec(f"g{i}", 256, 128, 1, 1, 4) for i in range(4)]


def _info(bw, ba, target_ms=1e9):
    _r, info = evaluate_config(SPECS, LUT, DSP, DEV, bw, ba, PROXY,
                               target_ms, 0.01)
    return info


def _evaluator(target_ms=1e9, **kw):
    return ProgramEvaluator(SPECS, DEV, target_ms, proxy=PROXY, **kw)


# ---------------------------------------------------------------------------
# config → program round trip
# ---------------------------------------------------------------------------


def test_program_honors_per_layer_bits_and_splits():
    """The compiled program realizes exactly the searched design point:
    per-layer bit-widths and the env's Eq.-12 neuron splits, untouched."""
    bw = [2, 4, 6, 8]
    ba = [2, 3, 4, 2]
    info = _info(bw, ba)
    prog = _evaluator().compile(info)
    assert [lp.bits_w_lut for lp in prog.layers] == bw
    assert [lp.bits_a for lp in prog.layers] == ba
    assert [lp.n_lut for lp in prog.layers] == info["n_luts"]
    assert [lp.dims for lp in prog.layers] == [s.gemm() for s in SPECS]


def test_ratios_roundtrip_without_n_luts():
    """Legacy info dicts that only carry ratio fractions recover the
    exact integer splits (every ratio is n_lut / c_out)."""
    info = _info([4] * 4, [4] * 4)
    legacy = {k: v for k, v in info.items() if k != "n_luts"}
    ev = _evaluator()
    prog = ev.compile(legacy)
    assert [lp.n_lut for lp in prog.layers] == info["n_luts"]
    assert ev.config_key(legacy) == ev.config_key(info)


def test_conv_specs_keep_geometry_lm_specs_do_not():
    from repro.dse.evaluator import specs_to_layers
    conv = [ConvSpec("c0", 3, 8, 3, 1, 8)]
    assert specs_to_layers(conv)[0].geometry is not None
    assert all(gl.geometry is None for gl in specs_to_layers(SPECS))


# ---------------------------------------------------------------------------
# determinism + cache
# ---------------------------------------------------------------------------


def test_simulated_reward_deterministic_and_cached():
    ev = _evaluator(target_ms=0.05)
    info = _info([4] * 4, [4] * 4, target_ms=0.05)
    r1 = ev.evaluate(info)
    r2 = ev.evaluate(info)
    assert r1.simulated_ms == r2.simulated_ms
    assert r1.reward_simulated == r2.reward_simulated
    assert not r1.cached and r2.cached
    ci = ev.cache_info()
    assert ci["hits"] == 1 and ci["misses"] == 1 and ci["size"] == 1


def test_cache_keys_differ_per_config_and_lru_evicts():
    ev = _evaluator(cache_size=1)
    a = _info([4] * 4, [4] * 4)
    b = _info([8] * 4, [4] * 4)
    assert ev.config_key(a) != ev.config_key(b)
    ev.evaluate(a)
    ev.evaluate(b)          # evicts a (maxsize 1)
    ev.evaluate(a)          # miss again
    ci = ev.cache_info()
    assert ci["misses"] == 3 and ci["hits"] == 0 and ci["size"] == 1


def test_correct_retags_reward_source():
    ev = _evaluator()
    info = _info([4] * 4, [4] * 4)
    assert info["reward_source"] == "analytical"
    r_sim, corrected = ev.correct(info)
    assert corrected["reward_source"] == "simulated"
    assert corrected["simulated_latency_ms"] == pytest.approx(
        DEV.cycles_to_ms(ev.evaluate(info).sim_cycles))
    assert info["reward_source"] == "analytical"   # original untouched


# ---------------------------------------------------------------------------
# elite re-ranking
# ---------------------------------------------------------------------------


def test_elite_rerank_changes_winner_on_crafted_case():
    """Analytical ranking prefers the fast low-accuracy config when the
    accurate one looks latency-infeasible — but the compiled ``-O1``
    program is faster than the closed form predicts, so the simulator
    flips the winner (the exact failure mode the two-tier loop fixes).
    """
    probe = _evaluator()
    ana_a = _info([8] * 4, [4] * 4)
    sim_a = probe.evaluate(ana_a)
    # the closed form over-estimates the -O1 program on this workload
    assert sim_a.simulated_ms < ana_a["latency_ms"]
    target = 0.5 * (sim_a.simulated_ms + ana_a["latency_ms"])

    ev = _evaluator(target_ms=target)
    r_a, info_a = evaluate_config(SPECS, LUT, DSP, DEV, [8] * 4, [4] * 4,
                                  PROXY, target, 0.01)
    r_b, info_b = evaluate_config(SPECS, LUT, DSP, DEV, [2] * 4, [2] * 4,
                                  PROXY, target, 0.01)
    assert r_a <= -1.0 < r_b        # analytically: A infeasible, B wins

    elites = EliteSet(2)
    elites.add(r_a, info_a, key=ev.config_key(info_a))
    elites.add(r_b, info_b, key=ev.config_key(info_b))
    assert elites.best.info is info_b

    for e in elites.uncorrected():
        r_sim, corrected = ev.correct(e.info)
        elites.apply_correction(e, r_sim, corrected)
    # simulated: A fits the target and has the better accuracy -> wins
    assert elites.best.info["bw_lut"] == [8] * 4
    assert elites.best.reward > -1.0
    assert elites.best.info["reward_source"] == "simulated"


def test_elite_set_dedups_on_key_and_caps_k():
    es = EliteSet(2)
    assert es.add(0.1, {"cfg": 1}, key="k1")
    assert not es.add(0.1, {"cfg": 1}, key="k1")      # duplicate config
    assert es.add(0.3, {"cfg": 2}, key="k2")
    assert es.add(0.2, {"cfg": 3}, key="k3")          # evicts 0.1
    assert len(es.elites) == 2
    assert not es.add(0.05, {"cfg": 4}, key="k4")     # below the floor
    assert [e.reward for e in es.elites] == [0.3, 0.2]


def test_elite_admission_floor_stays_analytical_after_correction():
    """Corrections usually lift rewards (the -O1 program beats the
    closed form), so admission must keep comparing a new candidate's
    analytical reward against the pool's *analytical* floor — not the
    corrected rewards — or tier 2 never sees late near-target configs.
    The corrected best is protected from eviction."""
    es = EliteSet(3)
    for r, k in ((0.3, "ka"), (0.2, "kc"), (-0.5, "kb")):
        es.add(r, {"k": k}, key=k)
    for e in list(es.elites):       # simulator lifts every elite
        es.apply_correction(e, e.reward_analytical + 1.0, dict(e.info))
    # new candidate below every corrected reward but above the
    # analytical floor (-0.5): must be admitted, evicting that floor
    assert es.add(0.25, {"k": "kd"}, key="kd")
    keys = {e.key for e in es.elites}
    assert "kb" not in keys and "kd" in keys
    assert es.best.key == "ka"      # corrected best survived


def test_elite_confirmed_best_never_evicted():
    """The best simulator-confirmed elite is eviction-proof: at k=1 a
    confirmed winner rejects analytical churn outright, and at k>1 it
    is protected even when an uncorrected elite holds a higher
    (over-estimated) analytical reward."""
    es = EliteSet(1)
    es.add(0.0, {"k": "a"}, key="a")
    es.apply_correction(es.elites[0], 5.0, {"k": "a"})
    assert not es.add(0.1, {"k": "b"}, key="b")
    assert es.best.key == "a" and es.best.reward == 5.0

    es = EliteSet(2)
    es.add(1.0, {"k": "a"}, key="a")
    es.add(6.0, {"k": "b"}, key="b")      # uncorrected, over-estimated
    a = next(e for e in es.elites if e.key == "a")
    es.apply_correction(a, 5.0, {"k": "a"})
    # the only evictable elite is b (analytical 6.0); a is protected
    assert not es.add(1.5, {"k": "c"}, key="c")
    assert {e.key for e in es.elites} == {"a", "b"}
    assert es.add(6.5, {"k": "d"}, key="d")     # beats b's analytical
    assert {e.key for e in es.elites} == {"a", "d"}


# ---------------------------------------------------------------------------
# functional verification (golden backend bit-exactness)
# ---------------------------------------------------------------------------


def test_winning_program_executes_bit_exactly_on_golden():
    ev = _evaluator()
    info = _info([5, 3, 4, 6], [4, 3, 2, 4])
    assert ev.verify(info)


# ---------------------------------------------------------------------------
# network plumbing + whole-search smoke
# ---------------------------------------------------------------------------


def test_gemm_specs_match_compiler_layers():
    from repro.compiler.networks import network_layers
    specs = gemm_specs("llama3.2-1b", seq_len=16)
    layers = network_layers("llama3.2-1b", seq_len=16)
    assert [s.gemm() for s in specs] == [gl.dims for gl in layers]
    assert [s.name for s in specs] == [gl.name for gl in layers]
    with pytest.raises(ValueError):
        gemm_specs("llama3.2-1b", seq_len=12)          # not a square
    assert gemm_specs("resnet18")[0].kernel == 7       # zoo passthrough


def test_run_search_simulate_elites_smoke(tmp_path):
    res = run_search(specs=SPECS, target_latency_ms=0.2, episodes=6,
                     simulate_elites=True, top_k=2, sim_every=3,
                     baseline_acc=70.0, seed=0)
    assert res.reward_source == "simulated"
    assert res.analytical_latency_ms > 0
    assert res.simulated_latency_ms > 0
    assert res.best_info["reward_source"] == "simulated"
    row = res.table3_row()
    assert "sim_latency_ms" in row and "latency_ms" in row
    assert res.elites and res.elites[0]["rank"] == 1
    assert abs(res.sim_gap_pct) <= SIM_GAP_TOL_PCT
    # deterministic for a fixed seed/config: the winner's simulated
    # latency reproduces
    res2 = run_search(specs=SPECS, target_latency_ms=0.2, episodes=6,
                      simulate_elites=True, top_k=2, sim_every=3,
                      baseline_acc=70.0, seed=0)
    assert res2.simulated_latency_ms == res.simulated_latency_ms
    csv_path = tmp_path / "cal.csv"
    res.write_calibration_csv(str(csv_path))
    header = csv_path.read_text().splitlines()[0]
    assert header.startswith("rank,") and "simulated_ms" in header


def test_run_search_registry_network_end_to_end():
    """The acceptance path: a registry smoke network searched two-tier
    reports both latency columns, and the winning config's compiled
    program executes bit-exactly on the golden backend."""
    res = run_search(network="llama3.2-1b", seq_len=16,
                     target_latency_ms=1.0, episodes=4,
                     simulate_elites=True, top_k=2, sim_every=2, seed=0)
    assert res.reward_source == "simulated"
    assert res.analytical_latency_ms > 0 and res.simulated_latency_ms > 0
    assert abs(res.sim_gap_pct) <= SIM_GAP_TOL_PCT
    ev = ProgramEvaluator(gemm_specs("llama3.2-1b", seq_len=16), DEV, 1.0)
    assert ev.verify(res.best_info)


def test_run_search_analytical_unchanged():
    """The legacy single-tier path still reports analytical-only."""
    res = run_search(specs=SPECS, target_latency_ms=0.2, episodes=4,
                     baseline_acc=70.0, seed=0)
    assert res.reward_source == "analytical"
    assert res.simulated_latency_ms is None
    assert "sim_latency_ms" not in res.table3_row()


def test_sim_gap_report_within_documented_tolerance():
    rep = sim_gap_report("tiny", specs=SPECS)
    assert rep["BENCH"] == "dse.sim_gap"
    assert rep["within_tol"] and abs(rep["gap_pct"]) <= SIM_GAP_TOL_PCT
    assert rep["simulated_ms"] > 0 and rep["analytical_ms"] > 0


def test_shaped_reward_regimes():
    from repro.dse.env import shaped_reward
    assert shaped_reward(2.0, 1.0, 70.0, 70.0, 0.01) <= -1.0
    r = shaped_reward(0.5, 1.0, 69.0, 70.0, 0.01)
    assert r == pytest.approx(-0.01)
    # the evaluator prices the same config differently only via latency
    assert shaped_reward(0.9, 1.0, 69.0, 70.0, 0.01) == r


def test_replay_correction_reaches_buffer():
    from repro.dse.ddpg import DDPGAgent, DDPGConfig
    from repro.dse.env import STATE_DIM
    agent = DDPGAgent(DDPGConfig(state_dim=STATE_DIM), seed=0)
    s = np.zeros(STATE_DIM, np.float32)
    a = np.zeros(1, np.float32)
    transitions = [(s, a, 0.0, s, False), (s, a, -2.0, s, True)]
    agent.remember_episode(transitions, -2.0)     # analytical
    agent.remember_episode(transitions, 0.5)      # simulator-corrected
    assert agent.buffer.n == 4
    assert agent.buffer.r[:4].tolist() == [-2.0, -2.0, 0.5, 0.5]
