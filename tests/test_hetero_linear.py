"""HeteroLinear: fp / QAT / deployed-integer agreement + CNN smoke."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.hetero_linear import (
    HeteroLinearConfig,
    apply_deploy,
    apply_fp,
    apply_qat,
    column_allocation,
    deploy,
    init_hetero_linear,
)
from repro.models import cnn
from repro.quant.hybrid import LayerQuantConfig


def _cfg(ratio=0.4, bits=8, a_bits=8):
    return HeteroLinearConfig(
        64, 48, LayerQuantConfig(w_bits_lut=bits, a_bits=a_bits,
                                 ratio=ratio))


def test_deploy_matches_qat_bit_exactly():
    cfg = _cfg()
    p = init_hetero_linear(jax.random.key(0), cfg)
    x = 0.5 * jax.random.normal(jax.random.key(1), (10, 64))
    y_qat = apply_qat(p, x, cfg)
    y_dep = apply_deploy(deploy(p, cfg), x)
    rel = float(jnp.abs(y_dep - y_qat).max() / jnp.abs(y_qat).max())
    assert rel < 1e-4, rel


@pytest.mark.parametrize("ratio", [0.0, 0.25, 0.75, 1.0])
def test_deploy_all_ratios(ratio):
    cfg = _cfg(ratio=ratio)
    p = init_hetero_linear(jax.random.key(2), cfg)
    x = 0.5 * jax.random.normal(jax.random.key(3), (6, 64))
    y = apply_deploy(deploy(p, cfg), x)
    assert y.shape == (6, 48)
    assert bool(jnp.isfinite(y).all())


def test_higher_bits_closer_to_fp():
    errs = []
    for bits in (2, 4, 8):
        cfg = _cfg(ratio=1.0, bits=bits)      # everything on flex path
        p = init_hetero_linear(jax.random.key(4), cfg)
        x = 0.5 * jax.random.normal(jax.random.key(5), (20, 64))
        y_fp = apply_fp(p, x)
        y = apply_deploy(deploy(p, cfg), x)
        errs.append(float(jnp.abs(y - y_fp).mean()))
    assert errs[0] > errs[1] > errs[2]


def test_column_allocation_is_permutation():
    cfg = _cfg()
    p = init_hetero_linear(jax.random.key(6), cfg)
    perm = np.asarray(column_allocation(p["w"], cfg))
    assert sorted(perm.tolist()) == list(range(48))


def test_qat_gradients_flow():
    cfg = _cfg()
    p = init_hetero_linear(jax.random.key(7), cfg)
    x = jax.random.normal(jax.random.key(8), (4, 64))
    g = jax.grad(lambda p: apply_qat(p, x, cfg).sum())(p)
    assert float(jnp.abs(g["w"]).sum()) > 0
    assert bool(jnp.isfinite(g["w"]).all())


# ---------------------------------------------------------------------------
# CNN workloads (the paper's networks) under hybrid quantization
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ["resnet18", "mobilenet_v2"])
def test_cnn_quantized_smoke(arch):
    cfg = cnn.reduced_config(arch)
    specs = cnn.specs_for(cfg)
    p = cnn.init(cfg, jax.random.key(0))
    x = jax.random.normal(jax.random.key(1), (2, 32, 32, 3))
    qcfgs = [LayerQuantConfig(w_bits_lut=6, a_bits=4, ratio=0.5)
             for _ in specs]
    y = cnn.forward(p, x, cfg, qcfgs)
    assert y.shape == (2, 10)
    assert not bool(jnp.isnan(y).any())


def test_cnn_qat_improves_on_synthetic():
    """A few QAT steps on separable synthetic data reduce the loss."""
    from repro.data.synthetic import SyntheticImages
    cfg = cnn.reduced_config("resnet18")
    specs = cnn.specs_for(cfg)
    qcfgs = [LayerQuantConfig(w_bits_lut=8, a_bits=8, ratio=0.5)
             for _ in specs]
    p = cnn.init(cfg, jax.random.key(0))
    data = SyntheticImages(10, 16, 32, seed=0)

    @jax.jit
    def step(p, images, labels):
        def loss(p):
            return cnn.cross_entropy(cnn.forward(p, images, cfg, qcfgs),
                                     labels)
        l, g = jax.value_and_grad(loss)(p)
        p = jax.tree.map(lambda w, gw: w - 0.05 * gw, p, g)
        return p, l

    batch = data.next_batch()
    losses = []
    for _ in range(6):
        p, l = step(p, batch["images"], batch["labels"])
        losses.append(float(l))
    assert losses[-1] < losses[0]
