"""Decode-resident serving: the ISSUE-8 acceptance surface.

  * per-family (lm / ssm / hybrid) decode-step parity: a compiled
    executor session's ``step()`` is bit-exact against the plain-jax
    ``decode_step`` reference, on the golden interpreter AND the
    batched Pallas fast path;
  * residency classes + the ``.step`` invocation header round-trip
    through text asm and the ``N3HPROG1`` binary (fixed-sequence
    programs stay step-free and all-``io``);
  * steady-state weight elision: after warm-up, no fetch into a
    ``weights``-resident segment is ever re-issued, and the steady
    image moves strictly fewer DDR bytes;
  * ``simulate_program`` reports warm-up vs steady-state decode
    cycles (``DecodeSim``) with the n-token closed form;
  * the serving factories: ``make_compiled_session`` /
    ``greedy_generate_compiled`` and the launcher's decode-mode
    ``ProgramKey``;
  * satellite: the lm branch of ``greedy_generate`` runs one real
    prefill (not S0 single-token decode steps).
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.compiler import (
    ExecutorSession,
    ReferenceSession,
    asm,
    compile_decode_network,
    compile_network,
    steady_program,
)
from repro.configs import registry
from repro.core import isa
from repro.core.scheduler import simulate_program

FAMILIES = [("llama3.2-1b", "lm"), ("mamba2-780m", "ssm"),
            ("jamba-v0.1-52b", "hybrid")]


def _decode_prog(name, **kw):
    kw.setdefault("batch", 1)
    kw.setdefault("max_seq", 8)
    kw.setdefault("opt_level", 1)
    return compile_decode_network(name, **kw)


def _weight_fetches(prog) -> int:
    """Stage-0 fetches that target a ``weights``-resident segment."""
    wbases = {s.base for s in prog.memory.segments
              if s.residency == "weights"}
    n = 0
    for lp in prog.layers:
        for cp in (lp.lut, lp.dsp):
            if cp is None:
                continue
            for op in cp.streams["fetch"]:
                if (isinstance(op.instr, isa.FetchInstr)
                        and op.instr.stage_ctrl == 0
                        and op.instr.ddr_base in wbases):
                    n += 1
    return n


# ---------------------------------------------------------------------------
# session parity vs the plain-jax decode_step reference
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["golden", "pallas"])
@pytest.mark.parametrize("name,family", FAMILIES)
def test_session_step_matches_reference(name, family, backend):
    prog = _decode_prog(name)
    assert prog.step is not None and prog.step.family == family
    ref = ReferenceSession(prog)
    ref.bind_synthetic_all(seed=0)
    sess = ExecutorSession(prog, backend=backend)
    sess.bind_synthetic_all(seed=0)
    for pos, t in enumerate([3, 5, 1]):
        tok = np.array([t], np.int32)
        want = np.asarray(ref.step(tok, pos))
        got = np.asarray(sess.step(tok, pos))
        assert want.shape == got.shape
        np.testing.assert_array_equal(want, got)


def test_multi_device_decode_session_matches_single():
    # a filter-partitioned decode bundle decodes bit-identically to the
    # single-device reference — residency decoration survives the split
    single = _decode_prog("llama3.2-1b")
    bundle = _decode_prog("llama3.2-1b", devices=2, partition="filter")
    ref = ReferenceSession(single)
    ref.bind_synthetic_all(seed=0)
    sess = ExecutorSession(bundle, backend="golden")
    sess.bind_synthetic_all(seed=0)
    for pos, t in enumerate([2, 7]):
        tok = np.array([t], np.int32)
        np.testing.assert_array_equal(np.asarray(ref.step(tok, pos)),
                                      np.asarray(sess.step(tok, pos)))


# ---------------------------------------------------------------------------
# residency + step header round-trips
# ---------------------------------------------------------------------------


def test_step_header_and_residency_roundtrip_text():
    prog = _decode_prog("llama3.2-1b")
    text = asm.disassemble(prog)
    assert ".step" in text
    assert "residency=weights" in text
    assert "residency=kv" in text
    rt = asm.assemble(text)
    assert rt == prog
    assert rt.step == prog.step


def test_step_header_and_residency_roundtrip_binary():
    prog = _decode_prog("mamba2-780m")
    rt = asm.from_binary(asm.to_binary(prog))
    assert rt == prog
    assert rt.step == prog.step
    kinds = {s.residency for s in rt.memory.segments}
    assert {"io", "weights", "state"} <= kinds


def test_fixed_program_stays_stepless_and_io():
    # the legacy fixed-sequence path is untouched: no step header, all
    # segments io, binary round-trip intact
    prog = compile_network("llama3.2-1b", seq_len=8, opt_level=1)
    assert prog.step is None
    assert {s.residency for s in prog.memory.segments} == {"io"}
    rt = asm.from_binary(asm.to_binary(prog))
    assert rt == prog and rt.step is None


# ---------------------------------------------------------------------------
# steady-state weight elision
# ---------------------------------------------------------------------------


def test_steady_program_elides_weight_fetches():
    warm = _decode_prog("llama3.2-1b")
    steady = steady_program(warm)
    assert _weight_fetches(warm) > 0
    assert _weight_fetches(steady) == 0
    assert steady.stats().bytes_fetched < warm.stats().bytes_fetched


def test_session_multi_step_never_refetches_weights():
    # the session swaps to the steady image after the first invocation:
    # across a 4-token generation only the warm-up program carries
    # weight fetches, so total weight-fetch issues == warm-up's count
    sess = ExecutorSession(_decode_prog("llama3.2-1b"), backend="golden")
    sess.bind_synthetic_all(seed=0)
    assert not sess._warmed
    for pos in range(4):
        sess.step(np.array([1], np.int32), pos)
        assert sess._warmed
    assert _weight_fetches(sess.warm) > 0
    assert _weight_fetches(sess.steady) == 0


def test_decode_sim_reports_warmup_and_steady():
    ds = simulate_program(_decode_prog("mamba2-780m"))
    assert ds.steady_cycles < ds.warmup_cycles
    assert ds.total_cycles == ds.warmup_cycles
    assert ds.tokens_cycles(1) == ds.warmup_cycles
    assert ds.tokens_cycles(4) == ds.warmup_cycles + 3 * ds.steady_cycles


# ---------------------------------------------------------------------------
# serving factories + launcher key
# ---------------------------------------------------------------------------


def test_greedy_generate_compiled_roundtrip():
    from repro.serve.engine import (greedy_generate_compiled,
                                    make_compiled_session)
    sess = make_compiled_session("llama3.2-1b", backend="golden",
                                 max_seq=8, seed=0)
    prompts = np.array([[3, 1, 4]], np.int32)
    out = np.asarray(greedy_generate_compiled(sess, prompts, 3))
    assert out.shape == (1, 6)
    assert (out[:, :3] == prompts).all()
    # deterministic: a fresh generation over the reset caches matches
    out2 = np.asarray(greedy_generate_compiled(sess, prompts, 3))
    np.testing.assert_array_equal(out, out2)
    with pytest.raises(ValueError):
        greedy_generate_compiled(sess, prompts, 64)   # exceeds max_seq


def test_program_cache_decode_mode_key():
    from repro.launch.serve import ProgramCache, ProgramKey
    cache = ProgramCache(maxsize=4)
    key = ProgramKey(arch="llama3.2-1b", mode="decode", batch=1,
                     max_seq=8, opt_level=1)
    image = cache.get(key)
    assert image[:8] == b"N3HPROG1"
    rt = asm.from_binary(image)
    assert rt.step is not None and rt.step.max_seq == 8
    assert cache.get(key) == image          # LRU hit, not a recompile
    assert cache.info()["hits"] == 1
    # decode keys never collide with the fixed-seq image of the same arch
    fixed = cache.get(ProgramKey(arch="llama3.2-1b", seq_len=8,
                                 opt_level=1))
    assert fixed != image and asm.from_binary(fixed).step is None


def test_greedy_generate_lm_uses_prefill(monkeypatch):
    # satellite regression: the lm branch runs ONE real prefill over
    # the whole prompt instead of S0 single-token decode steps
    import repro.serve.engine as eng
    arch = registry.get("llama3.2-1b")
    arch = dataclasses.replace(arch, model=arch.smoke)
    mod = arch.model_module()
    params = mod.init(arch.model, jax.random.key(0))
    prompts = jax.random.randint(jax.random.key(1), (2, 6), 0,
                                 arch.model.vocab)
    calls = {"prefill": 0}
    real = eng.make_prefill_fn

    def spy(*a, **kw):
        calls["prefill"] += 1
        return real(*a, **kw)

    monkeypatch.setattr(eng, "make_prefill_fn", spy)
    out = eng.greedy_generate(arch, params, prompts, n_new=2)
    assert calls["prefill"] == 1
    assert out.shape == (2, 8)
