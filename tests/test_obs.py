"""Observability layer: cycle-accounting closure, trace format,
determinism, null-tracer fast path, metrics round-trips.

The load-bearing contract: for every core track of a traced program,
busy + sync + stall + idle cycles sum *exactly* to the makespan
``simulate_program`` reports — the trace decomposes the existing
number, it is not a second opinion. Checked on single-device programs
and on 2-device pipeline/filter bundles, at -O0 and -O1.
"""
import json

import numpy as np
import pytest

from repro.compiler import GoldenExecutor, bind_synthetic, compile_network
from repro.core.scheduler import simulate_program
from repro.obs import (
    METRICS,
    MetricsRegistry,
    NULL_TRACER,
    Tracer,
    profile_report,
    validate_chrome_trace,
)

NET = "llama3.2-1b"
SEQ = 16


@pytest.fixture(scope="module")
def single_prog():
    return compile_network(NET, seq_len=SEQ)


@pytest.fixture(scope="module", params=["pipeline", "filter"])
def bundle(request):
    return compile_network(NET, seq_len=SEQ, devices=2,
                           partition=request.param)


# ---------------------------------------------------------------------------
# cycle-accounting closure
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("opt", [0, 1])
def test_closure_single_device(single_prog, opt):
    tracer = Tracer()
    ps = simulate_program(single_prog, opt_level=opt, tracer=tracer)
    c = tracer.counters
    assert c.makespan == ps.total_cycles
    assert c.closure_errors() == []
    # 2 cores x 3 engines on one device
    assert len(c.tracks) == 6
    for tc in c.tracks.values():
        assert tc.busy + tc.sync + tc.stall + tc.idle == ps.total_cycles


@pytest.mark.parametrize("opt", [0, 1])
def test_closure_bundle(bundle, opt):
    tracer = Tracer()
    bs = simulate_program(bundle, opt_level=opt, batches=1, tracer=tracer)
    c = tracer.counters
    # batches=1: one traversal, latency == total makespan
    assert bs.total_cycles == bs.latency_cycles
    assert c.makespan == bs.total_cycles
    assert c.closure_errors() == []
    assert len(c.tracks) == 12          # 2 devices x 2 cores x 3 engines


def test_tracing_does_not_change_makespan(single_prog, bundle):
    for prog in (single_prog, bundle):
        plain = simulate_program(prog, opt_level=1)
        traced = simulate_program(prog, opt_level=1, tracer=Tracer())
        assert traced.total_cycles == plain.total_cycles


def test_closure_is_a_real_check(single_prog):
    # corrupting any one term must break closure — guards against the
    # decomposition degenerating into makespan-minus-the-rest
    tracer = Tracer()
    simulate_program(single_prog, tracer=tracer)
    tc = next(iter(tracer.counters.tracks.values()))
    tc.idle += 1
    assert tracer.counters.closure_errors() != []


def test_closure_covers_elementwise_stage():
    """Conv chains carry stage-6 fused-tail fetch/result records; their
    cycles must be inside the accounting (closure holds with the tail
    present), and corrupting an elementwise-bearing track's busy span
    must break closure."""
    from repro.compiler.lower import EW_STAGE
    prog = compile_network("resnet18", in_hw=28, width=0.25)
    assert any(lp.elementwise for lp in prog.layers)
    tracer = Tracer()
    ps = simulate_program(prog, tracer=tracer)
    c = tracer.counters
    assert c.makespan == ps.total_cycles
    assert c.closure_errors() == []
    # busy cycles of the stage-6 records are nonzero, so a corrupted
    # tail span cannot hide in the idle remainder
    lp = next(lp for lp in prog.layers if lp.elementwise)
    cp = lp.lut if lp.lut is not None else lp.dsp
    ew_cycles = sum(op.cycles for s in ("fetch", "result")
                    for op in cp.streams[s]
                    if getattr(op.instr, "stage_ctrl", None) == EW_STAGE)
    assert ew_cycles > 0
    track = f"dev0:{'lut' if cp is lp.lut else 'dsp'}/result"
    tc = c.tracks[track] if track in c.tracks else \
        next(iter(c.tracks.values()))
    tc.busy += ew_cycles
    assert c.closure_errors() != []


# ---------------------------------------------------------------------------
# trace JSON: schema + determinism
# ---------------------------------------------------------------------------


def test_trace_schema_valid(single_prog):
    tracer = Tracer()
    simulate_program(single_prog, tracer=tracer)
    obj = json.loads(tracer.to_json())
    assert validate_chrome_trace(obj) == []
    events = obj["traceEvents"]
    # per-instruction complete events on core/engine tracks
    cats = {e.get("cat") for e in events if e["ph"] == "X"}
    assert {"busy", "sync"} <= cats
    names = {e["args"]["name"] for e in events if e["ph"] == "M"}
    assert any(n.startswith("dev0:") for n in names)
    assert "lut/execute" in names and "dsp/fetch" in names
    # accounting summary rides in the file
    counters = obj["otherData"]["counters"]
    assert counters["closure_errors"] == []
    assert counters["makespan_cycles"] > 0


def test_bundle_trace_has_link_track(bundle):
    tracer = Tracer()
    simulate_program(bundle, batches=1, tracer=tracer)
    obj = tracer.to_chrome()
    assert validate_chrome_trace(obj) == []
    pids = {e["pid"] for e in obj["traceEvents"]}
    assert {0, 1} <= pids
    if bundle.plan.kind == "pipeline":
        link_events = [e for e in obj["traceEvents"]
                       if e.get("cat") == "link"]
        assert link_events
        assert all(e["args"]["nbytes"] > 0 for e in link_events)


def test_trace_deterministic(single_prog, bundle):
    for prog in (single_prog, bundle):
        blobs = []
        for _ in range(2):
            tracer = Tracer()
            simulate_program(prog, opt_level=1, tracer=tracer)
            blobs.append(tracer.to_json())
        assert blobs[0] == blobs[1]     # byte-identical


def test_validate_rejects_malformed():
    assert validate_chrome_trace([]) != []
    assert validate_chrome_trace({}) != []
    assert validate_chrome_trace(
        {"traceEvents": [{"ph": "X", "pid": 0, "tid": 0, "name": "x",
                          "ts": -1, "dur": 2}]}) != []
    assert validate_chrome_trace(
        {"traceEvents": [{"ph": "B", "pid": 0, "tid": 0, "name": "x"}]}
    ) != []


# ---------------------------------------------------------------------------
# null tracer / profile report / executor timing
# ---------------------------------------------------------------------------


def test_null_tracer_is_noop(single_prog):
    assert NULL_TRACER.enabled is False
    # every hook swallows; measure yields
    NULL_TRACER.record_layer(0, 0, "x", 0, 1, {})
    NULL_TRACER.set_makespan(5)
    NULL_TRACER.finalize()
    with NULL_TRACER.measure("t", "n"):
        pass
    assert list(NULL_TRACER.measured_spans) == []
    # simulate_program treats it as tracing-off (same result object)
    ps = simulate_program(single_prog, tracer=NULL_TRACER)
    assert ps.total_cycles == simulate_program(single_prog).total_cycles


def test_profile_report_renders(single_prog):
    tracer = Tracer()
    simulate_program(single_prog, tracer=tracer)
    text = profile_report(tracer)
    assert "cycle accounting: closed" in text
    assert "dev0 lut/execute" in text
    assert "top stall causes" in text
    assert profile_report(NULL_TRACER).startswith("profile: no trace data")


def test_executor_measured_spans(single_prog):
    tracer = Tracer()
    ex = GoldenExecutor(single_prog, tracer=tracer)
    lp = single_prog.layers[0]
    bind_synthetic(ex, lp)
    x = np.zeros((lp.dims.m, lp.dims.k), np.int8)
    ex.run_layer(lp.index, x)
    tracks = {s["track"] for s in tracer.measured_spans}
    assert "exec.golden.lut" in tracks and "exec.golden.dsp" in tracks
    obj = tracer.to_chrome()
    assert validate_chrome_trace(obj) == []
    assert any(e["pid"] == 901 for e in obj["traceEvents"])


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------


def test_metrics_json_roundtrip():
    reg = MetricsRegistry()
    reg.incr("x.count", 2)
    reg.incr("x.count")
    reg.gauge("x.gauge", 1.25)
    for v in (1.0, 3.0, 2.0):
        reg.observe("x.lat_ms", v)
    back = MetricsRegistry.from_json(reg.to_json())
    assert back.snapshot() == reg.snapshot()
    snap = back.snapshot()
    assert snap["counters"]["x.count"] == 3
    assert snap["observations"]["x.lat_ms"]["count"] == 3
    assert snap["observations"]["x.lat_ms"]["mean"] == 2.0


def test_metrics_csv_export(tmp_path):
    reg = MetricsRegistry()
    reg.incr("a.hits")
    reg.observe("b.ms", 4.0)
    path = tmp_path / "m.csv"
    reg.save(str(path))
    lines = path.read_text().splitlines()
    assert lines[0] == "kind,name,field,value"
    assert "counter,a.hits,value,1" in lines
    assert "observation,b.ms,mean,4.0" in lines


def test_serve_program_cache_metrics():
    from repro.launch.serve import ProgramCache, ProgramKey
    METRICS.clear()
    cache = ProgramCache()
    key = ProgramKey(arch=NET, seq_len=SEQ)
    img1 = cache.get(key)
    img2 = cache.get(key)
    assert img1 == img2
    assert METRICS.counter("serve.program_cache.miss") == 1
    assert METRICS.counter("serve.program_cache.hit") == 1
    assert METRICS.snapshot()["observations"][
        "serve.program_cache.compile_ms"]["count"] == 1


def test_dse_search_metrics():
    from repro.core.workloads import resnet18_specs
    from repro.dse.search import run_search
    res = run_search(specs=resnet18_specs()[:4], episodes=3, seed=0)
    m = res.metrics
    assert m is not None
    assert m["counters"]["dse.episodes"] == 3
    assert m["observations"]["dse.episode.reward"]["count"] == 3
    assert "dse.best_reward" in m["gauges"]
    # the snapshot itself round-trips through the registry export
    back = MetricsRegistry.from_json(json.dumps(m))
    assert back.snapshot() == m
