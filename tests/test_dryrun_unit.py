"""Dry-run machinery unit tests (no 512-device mesh needed)."""
import pytest

from repro import configs
from repro.launch.dryrun import _reduced_model, collective_bytes
from repro.launch.mesh import make_host_mesh


def test_reduced_model_trip_counts():
    for arch_id, want_real in [("qwen3-8b", 36), ("deepseek-v2-236b", 59),
                               ("jamba-v0.1-52b", 4), ("mamba2-780m", 48),
                               ("seamless-m4t-large-v2", 24)]:
        arch = configs.get(arch_id)
        small, real, small_trips = _reduced_model(arch)
        assert real == want_real, (arch_id, real)
        assert small_trips == 2
        assert small.model.scan_unroll is True


def test_two_point_fit_algebra():
    """total = F1 + (L-1)(F2-F1) is exact for homogeneous stacks."""
    c_body, c_out, L = 7.0, 3.0, 36
    f1 = c_body + c_out                 # scanned: body counted once
    f2 = 2 * c_body + c_out             # 2-layer unrolled
    fitted = f1 + (L - 1) * (f2 - f1)
    assert fitted == pytest.approx(L * c_body + c_out)


def test_collective_parser_variants():
    hlo = """
  %a = bf16[8,4]{1,0} all-gather(%x)
  %b = (f32[2,2]{1,0}, f32[2,2]{1,0}) all-to-all(%y, %z)
  %c = f32[4]{0} all-reduce-start(%w)
  %d = f32[4]{0} all-reduce-done(%c)
  %e = u8[100]{0} collective-permute(%v)
"""
    got = collective_bytes(hlo)
    assert got["all-gather"] == 8 * 4 * 2
    assert got["all-to-all"] == 2 * 2 * 2 * 4
    assert got["all-reduce"] == 16            # -done skipped
    assert got["collective-permute"] == 100


def test_make_host_mesh_shape():
    mesh = make_host_mesh()
    assert mesh.axis_names == ("data", "model")
    assert mesh.devices.size >= 1
