"""Fused split-aware whole-layer kernels (ISSUE 7 acceptance surface).

The contract under test:
  * kernel-level bit-exactness: the fused oracles equal the two-call
    ``hetero_gemm_ref`` path at mixed (bits, split-ratio) corners —
    including one-sided splits — and the actual Pallas kernel bodies
    (interpret mode) equal the oracles for dense and in-kernel-im2col
    conv variants;
  * executor-level bit-exactness: ``PallasExecutor`` (fused default)
    equals ``GoldenExecutor`` per layer (dense conv, depthwise, 1x1 LM
    GEMMs) and end to end on resnet18 / mobilenet_v2 / llama3.2-1b
    smoke programs, at -O0 and -O1, single- and 2-device
    (filter-parallel bundle);
  * the conv DDR map has no ``L{i}.col`` staging segment (pinned in
    ``test_conv_exec.py``) and the spatial input path feeds the fused
    conv call directly;
  * the per-program JIT cache builds fn tables atomically (threaded
    regression for the old lazy-mutation race), its capacity is
    configurable (constructor / env), and hits/misses land in
    ``obs.metrics.METRICS`` as ``pallas.jit_cache.*``;
  * fused layer executions appear as ``exec.pallas.fused`` tracer
    spans.
"""
import threading

import numpy as np
import jax.numpy as jnp
import pytest

from repro.compiler import (
    GemmLayer,
    GoldenExecutor,
    MultiDeviceExecutor,
    PallasExecutor,
    bind_synthetic,
    compile_network,
    derive_plan,
    lower_network,
    lower_partitioned,
)
from repro.core.scheduler import XC7Z020, DspCoreConfig, LutCoreConfig
from repro.core.workloads import ConvSpec
from repro.kernels import ops, ref
from repro.kernels.fused_hetero_gemm import fused_conv_gemm
from repro.models.cnn import CNNConfig, specs_for

LUT = LutCoreConfig(m=8, n=16, k=128)
DSP = DspCoreConfig(n_reg_row_a=13)


def _cnn_layers(arch: str, in_hw: int = 28, width: float = 0.25):
    cfg = CNNConfig(arch=arch, n_classes=10, in_hw=in_hw, width=width)
    return [GemmLayer.from_conv(s) for s in specs_for(cfg)]


def _bound(cls, prog, **kw):
    ex = cls(prog, **kw)
    for lp in prog.layers:
        bind_synthetic(ex, lp, seed=lp.index)
    return ex


def _split_weights(rng, k, n_lut, n_dsp, bits):
    w_lut = jnp.asarray(rng.integers(-(2 ** (bits - 1)), 2 ** (bits - 1),
                                     (k, n_lut)), jnp.int32) if n_lut else None
    w_dsp = jnp.asarray(rng.integers(-8, 8, (k, n_dsp)),
                        jnp.int32) if n_dsp else None
    s_lut = jnp.asarray(rng.uniform(0.5, 2.0, n_lut),
                        jnp.float32) if n_lut else None
    s_dsp = jnp.asarray(rng.uniform(0.5, 2.0, n_dsp),
                        jnp.float32) if n_dsp else None
    return w_lut, s_lut, w_dsp, s_dsp


# ---------------------------------------------------------------------------
# Kernel-level: fused oracle / fused Pallas kernel vs the two-call path
# ---------------------------------------------------------------------------

SPLIT_CORNERS = [
    # (bits, n_lut, n_dsp): mixed ratios incl. one-sided splits
    (2, 24, 40), (4, 16, 48), (6, 40, 24), (8, 62, 2),
    (4, 0, 64), (4, 64, 0), (3, 2, 62),
]


@pytest.mark.parametrize("bits,n_lut,n_dsp", SPLIT_CORNERS)
def test_fused_ref_equals_two_call_path(bits, n_lut, n_dsp):
    rng = np.random.default_rng(bits * 100 + n_lut)
    m, k = 24, 96
    x = jnp.asarray(rng.integers(-128, 128, (m, k)), jnp.int8)
    w_lut, s_lut, w_dsp, s_dsp = _split_weights(rng, k, n_lut, n_dsp, bits)
    outs = []
    if n_lut:
        outs.append(ref.bitserial_gemm_ref(x, w_lut, s_lut, bits))
    if n_dsp:
        outs.append(ref.int4_gemm_ref(x, ref.pack_int4(w_dsp), s_dsp))
    want = outs[0] if len(outs) == 1 else jnp.concatenate(outs, axis=1)
    got = ref.fused_hetero_gemm_ref(x, w_lut, s_lut, bits, w_dsp, s_dsp)
    assert (np.asarray(want) == np.asarray(got)).all()


@pytest.mark.parametrize("bits,n_lut,n_dsp", SPLIT_CORNERS)
def test_fused_kernel_interpret_equals_ref(bits, n_lut, n_dsp):
    """The actual Pallas kernel body (interpret mode on CPU), via the
    ops wrapper's padding/splicing, on non-block-multiple extents."""
    rng = np.random.default_rng(bits * 100 + n_dsp)
    m, k = 13, 72
    x = jnp.asarray(rng.integers(-128, 128, (m, k)), jnp.int8)
    w_lut, s_lut, w_dsp, s_dsp = _split_weights(rng, k, n_lut, n_dsp, bits)
    want = ref.fused_hetero_gemm_ref(x, w_lut, s_lut, bits, w_dsp, s_dsp)
    got = ops.fused_matmul(x, w_lut, s_lut, bits, w_dsp, s_dsp,
                           mode="kernel", block=(8, 32, 16))
    assert (np.asarray(want) == np.asarray(got)).all()


@pytest.mark.parametrize("kernel,stride,pad", [(3, 1, 1), (3, 2, 0),
                                               (1, 1, 0), (5, 2, 2)])
def test_fused_conv_kernel_in_kernel_im2col_equals_staged(kernel, stride,
                                                          pad):
    """In-kernel patch generation == out-of-kernel staging + dense
    fused GEMM, through the actual conv kernel body in interpret mode."""
    bits, n_lut, n_dsp, in_hw, c_in, bn = 5, 16, 24, 9, 4, 8
    out_hw = (in_hw + 2 * pad - kernel) // stride + 1
    rng = np.random.default_rng(kernel * 10 + stride)
    x_sp = jnp.asarray(rng.integers(-128, 128, (in_hw, in_hw, c_in)),
                       jnp.int8)
    k = kernel * kernel * c_in
    w_lut, s_lut, w_dsp, s_dsp = _split_weights(rng, k, n_lut, n_dsp, bits)
    col = ref.conv_patches_ref(x_sp, kernel, stride, pad,
                               out_hw).reshape(out_hw * out_hw, k)
    want = ref.fused_hetero_gemm_ref(col, w_lut, s_lut, bits, w_dsp, s_dsp)

    planes = ops._pad_to(ref.bitplane_decompose(w_lut, bits), 2, bn)
    packed = ops._pad_to(ref.pack_int4(w_dsp), 1, bn // 2)
    sp = jnp.concatenate([ops._pad_to(s_lut, 0, bn),
                          ops._pad_to(s_dsp, 0, bn)])
    xp = jnp.pad(x_sp, ((pad, pad), (pad, pad), (0, 0)))
    out = fused_conv_gemm(xp, planes, packed, sp, bits,
                          planes.shape[2] // bn, packed.shape[1] * 2 // bn,
                          kernel, stride, out_hw, bn=bn, interpret=True)
    nlp = planes.shape[2]
    got = jnp.concatenate([out[:, :n_lut], out[:, nlp:nlp + n_dsp]], axis=1)
    assert (np.asarray(want) == np.asarray(got)).all()


def test_fused_grouped_ref_equals_per_partition():
    bits, m, kk, n_lut, n_dsp = 6, 18, 9, 7, 13
    rng = np.random.default_rng(3)
    x_col = jnp.asarray(rng.integers(-128, 128, (m, kk, n_lut + n_dsp)),
                        jnp.int8)
    w_lut, s_lut, w_dsp, s_dsp = _split_weights(rng, kk, n_lut, n_dsp, bits)
    want = jnp.concatenate([
        ref.bitserial_grouped_gemm_ref(x_col[:, :, :n_lut], w_lut, s_lut,
                                       bits),
        ref.int4_grouped_gemm_ref(x_col[:, :, n_lut:], w_dsp, s_dsp)],
        axis=1)
    got = ops.fused_grouped_matmul(x_col, w_lut, s_lut, bits, w_dsp, s_dsp)
    assert (np.asarray(want) == np.asarray(got)).all()


def test_fused_conv_vmem_fallback_is_bit_exact():
    """Over-budget spatial inputs fall back to the jnp path — same
    bits, still one fused jit call."""
    bits, n_lut, n_dsp, in_hw, c_in = 4, 8, 8, 6, 3
    kernel = stride = 1
    out_hw = in_hw
    rng = np.random.default_rng(9)
    x_sp = jnp.asarray(rng.integers(-128, 128, (in_hw, in_hw, c_in)),
                       jnp.int8)
    w_lut, s_lut, w_dsp, s_dsp = _split_weights(rng, c_in, n_lut, n_dsp,
                                                bits)
    a = ops.fused_conv_matmul(x_sp, kernel, stride, 0, out_hw, w_lut,
                              s_lut, bits, w_dsp, s_dsp, mode="ref")
    b = ops.fused_conv_matmul(x_sp, kernel, stride, 0, out_hw, w_lut,
                              s_lut, bits, w_dsp, s_dsp, mode="kernel",
                              vmem_budget=1)     # force the fallback
    assert (np.asarray(a) == np.asarray(b)).all()


# ---------------------------------------------------------------------------
# Executor-level: fused PallasExecutor vs GoldenExecutor
# ---------------------------------------------------------------------------

LAYER_CASES = [
    # dense conv, depthwise conv, pointwise (the 1x1 LM-GEMM shape)
    ConvSpec("k3s1", 5, 24, 3, 1, 10),
    ConvSpec("k7s2", 3, 18, 7, 2, 16),
    ConvSpec("dw3s1", 20, 20, 3, 1, 8, depthwise=True),
    ConvSpec("k1s1", 12, 30, 1, 1, 6),
]


@pytest.mark.parametrize("spec", LAYER_CASES, ids=lambda s: s.name)
@pytest.mark.parametrize("bits", [2, 4, 8])
def test_fused_layer_bit_exact_vs_golden(spec, bits):
    gl = GemmLayer.from_conv(spec)
    n_lut = gl.dims.n // 3
    prog = lower_network("one", [gl], LUT, DSP, XC7Z020, n_luts=[n_lut],
                         bits_w_lut=bits)
    golden = _bound(GoldenExecutor, prog)
    fused = _bound(PallasExecutor, prog)
    assert fused.fused
    x = np.random.default_rng(7).integers(
        -8, 8, gl.geometry.in_shape).astype(np.int8)
    assert (np.asarray(golden.run_layer(0, x))
            == np.asarray(fused.run_layer(0, x))).all()


@pytest.mark.parametrize("n_lut_frac", [0.0, 0.3, 1.0])
def test_fused_layer_split_ratio_corners(n_lut_frac):
    gl = GemmLayer.from_conv(ConvSpec("c", 6, 20, 3, 1, 12))
    n_lut = int(gl.dims.n * n_lut_frac)
    prog = lower_network("one", [gl], LUT, DSP, XC7Z020, n_luts=[n_lut])
    golden = _bound(GoldenExecutor, prog)
    fused = _bound(PallasExecutor, prog)
    x = np.random.default_rng(1).integers(
        -8, 8, gl.geometry.in_shape).astype(np.int8)
    assert (np.asarray(golden.run_layer(0, x))
            == np.asarray(fused.run_layer(0, x))).all()


@pytest.mark.parametrize("opt_level", [0, 1])
def test_lm_program_fused_bit_exact_mixed_bits(opt_level):
    """1x1 LM GEMMs at per-layer mixed (bits, split) through -O0/-O1:
    fused == split == golden, layer by layer."""
    prog = compile_network("llama3.2-1b", seq_len=8)
    bw = [2 + (lp.index % 4) for lp in prog.layers]
    n_luts = [lp.dims.n * (lp.index % 3) // 4 for lp in prog.layers]
    layers = [GemmLayer(name=lp.name, dims=lp.dims) for lp in prog.layers]
    prog = lower_network("lm-mixed", layers, LUT, DSP, XC7Z020,
                         bits_w_lut=bw, n_luts=n_luts,
                         opt_level=opt_level)
    golden = _bound(GoldenExecutor, prog)
    fused = _bound(PallasExecutor, prog)
    split = _bound(PallasExecutor, prog, fused=False)
    for lp in prog.layers:
        x = np.random.default_rng(100 + lp.index).integers(
            -8, 8, (lp.dims.m, lp.dims.k)).astype(np.int8)
        g = np.asarray(golden.run_layer(lp.index, x))
        assert (g == np.asarray(fused.run_layer(lp.index, x))).all()
        assert (g == np.asarray(split.run_layer(lp.index, x))).all()


@pytest.mark.parametrize("arch", ["resnet18", "mobilenet_v2"])
@pytest.mark.parametrize("opt_level", [0, 1])
def test_cnn_e2e_fused_bit_exact(arch, opt_level):
    layers = _cnn_layers(arch)
    prog = lower_network(arch, layers, LUT, DSP, XC7Z020,
                         opt_level=opt_level)
    golden = _bound(GoldenExecutor, prog)
    fused = _bound(PallasExecutor, prog)
    x = np.random.default_rng(0).integers(
        -8, 8, layers[0].geometry.in_shape).astype(np.int8)
    assert (np.asarray(golden.run(x)) == np.asarray(fused.run(x))).all()


def test_two_device_filter_bundle_fused_bit_exact():
    layers = _cnn_layers("mobilenet_v2")
    prog = lower_network("mb2", layers, LUT, DSP, XC7Z020)
    x = np.random.default_rng(0).integers(
        -8, 8, layers[0].geometry.in_shape).astype(np.int8)
    ref_out = np.asarray(_bound(GoldenExecutor, prog).run(x))
    plan = derive_plan(layers, 2, "filter")
    mdp = lower_partitioned("mb2", layers, plan, LUT, DSP, XC7Z020)
    mex = MultiDeviceExecutor(mdp, backend="pallas")
    for gi in range(mdp.n_layers):
        mex.bind_synthetic(gi, seed=gi)
    assert all(isinstance(e, PallasExecutor) and e.fused
               for e in mex.executors)
    assert (np.asarray(mex.run(x)) == ref_out).all()


def test_prestaged_input_still_works_under_fused():
    """A conv layer handed the pre-staged [m, k] matrix (not the
    spatial tensor) takes the dense fused entry, same bits."""
    gl = GemmLayer.from_conv(ConvSpec("c", 5, 24, 3, 1, 10))
    prog = lower_network("one", [gl], LUT, DSP, XC7Z020,
                         n_luts=[gl.dims.n // 2])
    golden = _bound(GoldenExecutor, prog)
    fused = _bound(PallasExecutor, prog)
    x_sp = np.random.default_rng(2).integers(
        -8, 8, gl.geometry.in_shape).astype(np.int8)
    col = ref.conv_patches_ref(jnp.asarray(x_sp, jnp.int8), 3, 1, 1,
                               gl.geometry.out_hw)
    x_col = np.asarray(col).reshape(gl.dims.m, gl.dims.k)
    want = np.asarray(golden.run_layer(0, x_sp))
    assert (want == np.asarray(fused.run_layer(0, x_col))).all()
    assert (want == np.asarray(fused.run_layer(0, x_sp))).all()


# ---------------------------------------------------------------------------
# JIT cache: atomic tables, configurable capacity, metrics, spans
# ---------------------------------------------------------------------------


def test_program_fn_tables_built_atomically_threaded():
    """Regression for the lazy per-key mutation race: many threads
    constructing executors and running layers concurrently must agree
    bit for bit and never hit a partially-built table (KeyError)."""
    PallasExecutor.cache_clear()
    prog = lower_network(
        "tiny", [GemmLayer.from_conv(ConvSpec("c", 5, 16, 3, 1, 8))],
        LUT, DSP, XC7Z020, n_luts=[8])
    x = np.random.default_rng(0).integers(
        -8, 8, prog.layers[0].geometry.in_shape).astype(np.int8)
    want = np.asarray(_bound(PallasExecutor, prog).run_layer(0, x))

    errs, outs = [], []
    barrier = threading.Barrier(8)

    def worker():
        try:
            barrier.wait()
            ex = _bound(PallasExecutor, prog)
            outs.append(np.asarray(ex.run_layer(0, x)))
        except Exception as e:          # noqa: BLE001 — collect to assert
            errs.append(e)

    threads = [threading.Thread(target=worker) for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errs, errs
    assert all((o == want).all() for o in outs)
    # one shared table: every constructor after the first was a hit
    info = PallasExecutor.cache_info()
    assert info["programs"] == 1
    assert info["misses"] >= 1 and info["hits"] + info["misses"] >= 9


def test_jit_cache_max_constructor_and_env(monkeypatch):
    prog = lower_network(
        "tiny", [GemmLayer.from_conv(ConvSpec("c", 5, 16, 3, 1, 8))],
        LUT, DSP, XC7Z020, n_luts=[8])
    old = PallasExecutor._jit_cache_max
    try:
        PallasExecutor(prog, jit_cache_max=3)
        assert PallasExecutor.cache_info()["maxsize"] == 3
    finally:
        PallasExecutor._jit_cache_max = old
    # env var seeds the class default at import time
    import importlib
    import repro.compiler.runtime.pallas as rtp
    monkeypatch.setenv("REPRO_PALLAS_JIT_CACHE_MAX", "5")
    try:
        mod = importlib.reload(rtp)
        assert mod.PallasExecutor._jit_cache_max == 5
    finally:
        monkeypatch.delenv("REPRO_PALLAS_JIT_CACHE_MAX")
        importlib.reload(rtp)


def test_jit_cache_metrics_published():
    from repro.obs.metrics import METRICS
    PallasExecutor.cache_clear()
    prog = lower_network(
        "tiny", [GemmLayer.from_conv(ConvSpec("c", 5, 16, 3, 1, 8))],
        LUT, DSP, XC7Z020, n_luts=[8])
    before = METRICS.snapshot()["counters"]
    PallasExecutor(prog)
    PallasExecutor(prog)
    after = METRICS.snapshot()["counters"]
    assert after.get("pallas.jit_cache.miss", 0) \
        - before.get("pallas.jit_cache.miss", 0) == 1
    assert after.get("pallas.jit_cache.hit", 0) \
        - before.get("pallas.jit_cache.hit", 0) == 1
    assert METRICS.snapshot()["gauges"]["pallas.jit_cache.programs"] >= 1


def test_fused_layer_emits_tracer_span():
    from repro.obs import Tracer
    tr = Tracer()
    gl = GemmLayer.from_conv(ConvSpec("c", 5, 16, 3, 1, 8))
    prog = lower_network("one", [gl], LUT, DSP, XC7Z020, n_luts=[8])
    ex = PallasExecutor(prog, tracer=tr)
    bind_synthetic(ex, prog.layers[0], seed=0)
    x = np.random.default_rng(0).integers(
        -8, 8, gl.geometry.in_shape).astype(np.int8)
    ex.run_layer(0, x)
    spans = tr.measured_spans
    fused = [s for s in spans if s["track"] == "exec.pallas.fused"]
    assert fused and fused[0]["name"] == "c"
    # no per-core lut/dsp spans on the fused path — one span per layer
    assert not any(s["track"].endswith((".lut", ".dsp")) for s in spans)
