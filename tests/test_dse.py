"""DSE: environment mechanics, discretization, reward, agent learning."""
import numpy as np

from repro.core.cost_model import system_cost
from repro.core.scheduler import XC7Z020
from repro.core.workloads import resnet18_specs
from repro.dse.ddpg import DDPGAgent, DDPGConfig
from repro.dse.env import (
    STATE_DIM,
    AccuracyProxy,
    N3HEnv,
    N3HEnvConfig,
    TpuHeteroEnv,
    _discretize,
)

SPECS = resnet18_specs()[:6]     # short net keeps episodes fast


def _run_episode(env, actions):
    s = env.reset()
    assert s.shape == (STATE_DIM,)
    done = False
    i = 0
    while not done:
        s, r, done, info = env.step(actions(i))
        assert s.shape == (STATE_DIM,)
        assert np.all(s >= -1e-6) and np.all(s <= 1.0 + 1e-6)
        i += 1
    return r, info, i


def test_episode_length_and_state_bounds():
    env = N3HEnv(SPECS, N3HEnvConfig(target_latency_ms=50.0))
    r, info, steps = _run_episode(env, lambda i: 0.5)
    assert steps == 6 + 2 * len(SPECS)
    assert "latency_ms" in info and info["latency_ms"] > 0


def test_discretize_bounds():
    assert _discretize(0.0, 2, 8) == 2
    assert _discretize(1.0, 2, 8) == 8
    assert _discretize(0.5, 2, 8) == 5
    assert _discretize(-3.0, 1, 50) == 1      # clipped


def test_projected_config_always_feasible():
    env = N3HEnv(SPECS, N3HEnvConfig(target_latency_ms=50.0))
    _, info, _ = _run_episode(env, lambda i: 1.0)   # max everything
    rep = system_cost(info["lut_cfg"], info["dsp_cfg"], XC7Z020)
    assert rep.fits(XC7Z020)


def test_reward_regimes():
    """Eq. 18: infeasible latency -> reward <= -1; feasible -> (-1, 1)."""
    tight = N3HEnv(SPECS, N3HEnvConfig(target_latency_ms=0.001))
    r, _, _ = _run_episode(tight, lambda i: 0.5)
    assert r <= -1.0
    loose = N3HEnv(SPECS, N3HEnvConfig(target_latency_ms=1e6))
    r, _, _ = _run_episode(loose, lambda i: 0.9)
    assert -1.0 < r < 1.0


def test_first_last_layers_pinned_8bit():
    specs = resnet18_specs()
    env = N3HEnv(specs, N3HEnvConfig(target_latency_ms=1e6))
    _, info, _ = _run_episode(env, lambda i: 0.0)   # ask for min bits
    assert info["bw_lut"][0] == 8 and info["ba"][0] == 8
    assert info["bw_lut"][-1] == 8 and info["ba"][-1] == 8
    assert all(b == 2 for b in info["bw_lut"][1:-1])


def test_accuracy_proxy_monotone_in_bits():
    proxy = AccuracyProxy(baseline_acc=70.0, kappa=1.0)
    accs = [proxy.evaluate(SPECS, [b] * len(SPECS), [4] * len(SPECS),
                           [0.5] * len(SPECS)) for b in (2, 4, 6, 8)]
    assert accs == sorted(accs)
    assert accs[-1] <= 70.0


def test_tpu_env_episode():
    gemms = [(4096, 1024, 1024)] * 4
    env = TpuHeteroEnv(gemms, target_latency_ms=1e6)
    s = env.reset()
    done = False
    while not done:
        s, r, done, info = env.step(0.5)
    assert "latency_ms" in info and info["latency_ms"] > 0
    assert len(info["ratios"]) == 4


def test_ddpg_learns_bandit():
    """Sanity: DDPG moves toward the rewarded action on a 1-step task."""
    cfg = DDPGConfig(state_dim=STATE_DIM, noise_sigma=0.4,
                     batch_size=32, hidden=(32, 32), noise_decay=0.995)
    agent = DDPGAgent(cfg, seed=0)
    target = 0.8
    s = np.zeros(STATE_DIM, np.float32)
    for _ in range(500):
        a = agent.act(s)
        r = -abs(float(a[0]) - target)
        agent.remember(s, a, r, s, True)
        agent.learn(2)
        agent.decay_noise()
    final = float(agent.act(s, explore=False)[0])
    # starts at ~0.5; must move decisively toward the rewarded action
    assert final > 0.65, final
