"""Pipeline parallelism (GPipe over the pod axis): pipelined == serial.

Needs >1 device, so the check runs in a subprocess with forced host
devices (the same mechanism as the dry-run)."""
import os
import subprocess
import sys

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh
from repro.parallel.pipeline import gpipe, stage_params_from_stack

mesh = jax.make_mesh((4,), ("pod",))
L, D, B = 8, 16, 12

def layer(w, x):
    return jnp.tanh(x @ w)

def stage_body(params_local, x):          # params_local: [L/4, D, D]
    def step(x, w):
        return layer(w, x), None
    y, _ = jax.lax.scan(step, x, params_local)
    return y

key = jax.random.key(0)
ws = jax.random.normal(key, (L, D, D)) * 0.5
x = jax.random.normal(jax.random.key(1), (B, D))

# serial reference
y_ref = x
for i in range(L):
    y_ref = layer(ws[i], y_ref)

pipelined = gpipe(stage_body, mesh, "pod", n_micro=6)
y_pipe = jax.jit(pipelined)(stage_params_from_stack(ws, 4), x)
np.testing.assert_allclose(np.asarray(y_pipe), np.asarray(y_ref),
                           rtol=2e-5, atol=2e-5)
print("PIPELINE_OK")
"""


def test_gpipe_matches_serial():
    # Inherit the parent env (a stripped env loses HOME and the XLA
    # compilation cache, which pushed cold-start past the old 300 s
    # limit on slow containers); JAX_PLATFORMS=cpu skips backend
    # probing so the forced host devices come up immediately.
    env = {**os.environ, "PYTHONPATH": "src", "JAX_PLATFORMS": "cpu"}
    res = subprocess.run([sys.executable, "-c", SCRIPT],
                         capture_output=True, text=True, timeout=900,
                         env=env)
    assert "PIPELINE_OK" in res.stdout, res.stdout + res.stderr
