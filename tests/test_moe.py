"""MoE dispatch invariants (GShard-style grouped top-k with capacity)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers as L
from repro.models.layers import MoEConfig, _top_k_dispatch


def _probs(g=2, s=32, e=8, seed=0):
    logits = jax.random.normal(jax.random.key(seed), (g, s, e))
    return jax.nn.softmax(logits, axis=-1)


def test_dispatch_capacity_respected():
    probs = _probs()
    dispatch, _ = _top_k_dispatch(probs, top_k=2, capacity=4)
    per_expert = np.asarray(jnp.sum(dispatch, axis=(1, 3)))   # [G, E]
    # sum over tokens & capacity slots == tokens kept per expert <= C
    assert (per_expert <= 4 + 1e-6).all()


def test_dispatch_one_position_per_assignment():
    probs = _probs()
    dispatch, _ = _top_k_dispatch(probs, top_k=2, capacity=64)
    # with ample capacity every token is dispatched exactly top_k times
    per_token = np.asarray(jnp.sum(dispatch, axis=(2, 3)))
    np.testing.assert_allclose(per_token, 2.0, atol=1e-6)
    # each (expert, slot) holds at most one token
    per_slot = np.asarray(jnp.sum(dispatch, axis=1))
    assert (per_slot <= 1 + 1e-6).all()


def test_combine_weights_match_router_probs():
    probs = _probs()
    dispatch, combine = _top_k_dispatch(probs, top_k=2, capacity=64)
    # combine = dispatch weighted by the token's router prob for that expert
    got = np.asarray(jnp.sum(combine, axis=3))      # [G, S, E]
    topv, topi = jax.lax.top_k(probs, 2)
    want = np.zeros_like(got)
    g, s, _ = probs.shape
    for gi in range(g):
        for si in range(s):
            for j in range(2):
                want[gi, si, int(topi[gi, si, j])] += float(topv[gi, si, j])
    np.testing.assert_allclose(got, want, atol=1e-5)


def test_moe_apply_zero_capacity_drops_gracefully():
    cfg = MoEConfig(n_experts=4, top_k=1, d_ff=16, group_size=8,
                    capacity_factor=0.25)
    specs = L.moe_specs(16, cfg, jnp.float32)
    p = L.init_params(specs, jax.random.key(0))
    x = jax.random.normal(jax.random.key(1), (2, 16, 16))
    y, aux = L.moe_apply(p, x, cfg)
    assert y.shape == x.shape
    assert bool(jnp.isfinite(y).all())


def test_moe_tail_tokens_preserved():
    """Token count not divisible by group_size still returns all rows."""
    cfg = MoEConfig(n_experts=4, top_k=2, d_ff=16, group_size=10,
                    capacity_factor=8.0)
    specs = L.moe_specs(16, cfg, jnp.float32)
    p = L.init_params(specs, jax.random.key(0))
    x = jax.random.normal(jax.random.key(1), (3, 9, 16))   # 27 tokens
    y, _ = L.moe_apply(p, x, cfg)
    assert y.shape == (3, 9, 16)
    assert bool(jnp.isfinite(y).all())


def test_shared_expert_always_active():
    cfg = MoEConfig(n_experts=4, top_k=1, d_ff=16, n_shared=1,
                    group_size=8, capacity_factor=0.01)
    specs = L.moe_specs(16, cfg, jnp.float32)
    p = L.init_params(specs, jax.random.key(0))
    x = jax.random.normal(jax.random.key(1), (2, 8, 16))
    y, _ = L.moe_apply(p, x, cfg)
    # with capacity ~0 every routed expert drops; shared path remains
    assert float(jnp.abs(y).sum()) > 0
