"""The CI bench-table renderer (``benchmarks/summarize_bench.py``).

The one-elif-table contract: every bench kind the repo emits has a
``describe`` branch that renders its key metrics, unknown kinds fall
back to wall time, and ``summarize``/``main`` produce the markdown
table CI appends to ``$GITHUB_STEP_SUMMARY``.
"""
import csv
import importlib.util
import json
import os

import pytest

_PATH = os.path.join(os.path.dirname(__file__), os.pardir,
                     "benchmarks", "summarize_bench.py")
_spec = importlib.util.spec_from_file_location("summarize_bench", _PATH)
summarize_bench = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(summarize_bench)

#: one minimal blob per bench kind, with a fragment the rendered line
#: must contain — adding a bench kind means adding a row here
KIND_BLOBS = {
    "compiler": (
        {"layers": 16, "instructions": 900, "instructions_o1": 700},
        "16 layers"),
    "compiler.backends": (
        {"golden_s": 4.0, "pallas_s": 1.0, "speedup_x": 4.0,
         "bit_exact": True},
        "bit_exact=True"),
    "compiler.cnn_execute": (
        {"in_hw": 32, "layers": 21, "depthwise_layers": 0,
         "pallas_s": 2.0},
        "e2e @32px"),
    "compiler.multi_device": (
        {"plans": {"pipeline_x2": {"speedup_x": 1.7}},
         "pipeline_x2_beats_1dev": True},
        "pipeline_x2 1.7x"),
    "obs.overhead": (
        {"sim_on_s": 1.1, "sim_off_s": 1.0, "overhead_pct": 10.0,
         "trace_events": 500, "closure_ok": True},
        "closure_ok=True"),
    "kernels.fused": (
        {"fused_s": 1.0, "split_s": 2.0, "speedup_x": 2.0,
         "launches_fused": 3, "launches_split": 9,
         "col_staging_bytes_removed": 4096, "bit_exact": True},
        "launches 3 vs 9"),
    "dse.sim_gap": (
        {"analytical_ms": 9.0, "simulated_ms": 10.0, "gap_pct": 10.0,
         "within_tol": True},
        "within_tol=True"),
    "compiler.gather_overlap": (
        {"latency_overlap": 800, "latency_serialized": 1000,
         "gain_pct": 20.0},
        "gather overlap"),
    "serve.decode": (
        {"family": "lm", "steady_cycles": 100, "warmup_cycles": 400,
         "naive_fixed_seq_cycles_per_token": 900,
         "resident_vs_naive_x": 9.0, "host_tok_per_s": 5.0},
        "lm: steady 100"),
    "serve.fleet": (
        {"policy": "continuous", "req_per_s": 0.9, "completed": 8,
         "requests": 8, "failed": 0, "p50_ms": 1200.0,
         "p99_ms": 2400.0, "workers": 2, "utilization_pct": 91.0,
         "bit_exact": True},
        "continuous: 0.9 req/s"),
    "accuracy.eval": (
        {"network": "resnet18", "backend": "pallas", "n_samples": 10000,
         "agreement": 0.9932, "top1_compiled": 0.97, "top1_ref": 0.98,
         "agreement_floor": 0.95, "meets_floor": True,
         "latency_ms": 0.39},
        "99.32% top-1 agreement"),
    "serve.fleet.compare": (
        {"continuous_req_per_s": 0.9, "serial_req_per_s": 0.3,
         "speedup_x": 3.0, "continuous_beats_serial": True},
        "beats=True"),
}


@pytest.mark.parametrize("kind", sorted(KIND_BLOBS))
def test_describe_covers_kind(kind):
    blob, fragment = KIND_BLOBS[kind]
    line = summarize_bench.describe(kind, blob, 1e6)
    assert fragment in line


def test_unknown_kind_falls_back_to_wall_time():
    assert summarize_bench.describe("future.bench", {}, 2_500_000) \
        == "2.50s"
    assert summarize_bench.describe("", {}, 0.0) == "0.00s"


def test_summarize_renders_markdown_table():
    blob, fragment = KIND_BLOBS["serve.fleet"]
    rows = [
        ("serve.fleet.continuous.llama3.2-1b", 9.3e6,
         json.dumps(dict(blob, BENCH="serve.fleet"))),
        ("mystery.row", 1e6, json.dumps({"BENCH": "mystery"})),
    ]
    out = summarize_bench.summarize(rows, "serving fleet (smoke)")
    lines = out.splitlines()
    assert lines[0] == "### serving fleet (smoke)"
    assert "| row | key metrics |" in lines
    assert any("`serve.fleet.continuous.llama3.2-1b`" in ln
               and fragment in ln for ln in lines)
    assert any("`mystery.row`" in ln and "1.00s" in ln for ln in lines)


def test_cli_main_reads_csv_files(tmp_path, capsys):
    p = tmp_path / "rows.csv"
    with open(p, "w", newline="") as fh:
        w = csv.writer(fh)
        blob, _ = KIND_BLOBS["serve.fleet.compare"]
        w.writerow(("serve.fleet.compare.llama3.2-1b", 0.0,
                    json.dumps(dict(blob, BENCH="serve.fleet.compare"))))
    summarize_bench.main([str(p), str(p), "--title", "compare"])
    out = capsys.readouterr().out
    assert out.startswith("### compare")
    # both input files contribute rows
    assert out.count("`serve.fleet.compare.llama3.2-1b`") == 2
