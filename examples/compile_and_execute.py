"""End-to-end compiler walkthrough: compile → optimize (-O1) →
inspect → simulate → execute on the golden model → verify against the
deployed integer path → re-execute on the batched Pallas backend.

    PYTHONPATH=src python examples/compile_and_execute.py
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import kernels
from repro.compiler import (
    GemmLayer,
    GoldenExecutor,
    PallasExecutor,
    bind_synthetic,
    compile_network,
    disassemble,
    lower_network,
    optimize_program,
    to_binary,
)
from repro.core.hetero_linear import (
    HeteroLinearConfig,
    deploy,
    init_hetero_linear,
)
from repro.core.scheduler import (
    XC7Z020,
    DspCoreConfig,
    GemmDims,
    LutCoreConfig,
    simulate_program,
)
from repro.quant.hybrid import LayerQuantConfig
from repro.quant.uniform import fit_scale, qrange


def main() -> None:
    # 1. Compile a registry arch and look at the program-level numbers.
    prog = compile_network("llama3.2-1b", seq_len=32)
    s = prog.stats()
    print(f"[compile] {prog.name}: {len(prog.layers)} layers, "
          f"{s.n_instructions} instrs, image {s.image_bytes} B, "
          f"{s.bytes_moved / 1e6:.2f} MB DDR traffic")
    print(f"[compile] binary image: {len(to_binary(prog))} B; "
          f"first asm lines:")
    print("\n".join(disassemble(prog).splitlines()[:8]))

    # 2. Optimize: the -O1 pass pipeline (weight-tile prefetch
    #    reordering, sync elision, fused result/fetch DMA pairs).
    opt = optimize_program(prog, 1)
    for pstat in opt.opt_stats:
        print(f"[optimize] {pstat.render()}")

    # 3. Simulate both — the Fig. 5 decomposition from the same
    #    streams; optimized streams are what gets timed at -O1.
    ps = simulate_program(prog)
    ps1 = simulate_program(opt)
    gain = 100.0 * (ps.total_cycles - ps1.total_cycles) / ps.total_cycles
    print(f"[simulate] -O0 {ps.total_cycles} cycles = "
          f"{prog.device.cycles_to_ms(ps.total_cycles):.3f} ms @ "
          f"{prog.device.freq_mhz:.0f} MHz")
    print(f"[simulate] -O1 {ps1.total_cycles} cycles "
          f"({gain:+.2f}% latency gain)")
    for core in ("lut", "dsp"):
        print(f"[simulate]   {core}: {ps1.decomposition(core)}")

    # 4. Golden-execute one quantized layer and check bit-exactness
    #    against the deployed HeteroLinear integer path.
    M, K, N = 32, 48, 64
    cfg = HeteroLinearConfig(K, N, quant=LayerQuantConfig(
        w_bits_lut=6, a_bits=4, ratio=0.5))
    d = deploy(init_hetero_linear(jax.random.PRNGKey(0), cfg), cfg)
    n_lut = d.wq_serial.shape[1]

    layer_prog = lower_network(
        "hetero_fc", [GemmLayer("fc", GemmDims(M, K, N))],
        LutCoreConfig(m=8, n=16, k=128),
        DspCoreConfig(n_reg_row_a=DspCoreConfig.rows_for_device(XC7Z020)),
        XC7Z020, bits_w_lut=6, bits_a=4, n_luts=[n_lut])
    ex = GoldenExecutor(layer_prog)
    ex.bind_deployed(0, d)

    x = jax.random.normal(jax.random.PRNGKey(1), (M, K))
    s_a = fit_scale(x, 4)
    lo, hi = qrange(4)
    x_q = jnp.clip(jnp.round(x / s_a), lo, hi).astype(jnp.int8)

    got = np.asarray(ex.run_layer(0, x_q))
    want = np.asarray(kernels.hetero_matmul(
        x_q, d.wq_serial, d.s_serial, d.bits_serial,
        d.wq_parallel, d.s_parallel))
    exact = (got == want).all()
    print(f"[execute] golden model vs hetero_matmul on [{M},{K}]x[{K},{N}] "
          f"(n_lut={n_lut}): bit-exact={bool(exact)}")
    assert exact

    # 5. Same layer on the batched Pallas backend: one
    #    bitserial_matmul/int4_matmul call per partition instead of the
    #    interpreter's per-tile Python loop — bit-identical output.
    fast = PallasExecutor(layer_prog)
    fast.bind_deployed(0, d)
    t0 = time.time()
    got_fast = np.asarray(fast.run_layer(0, x_q))
    dt_fast = time.time() - t0
    t0 = time.time()
    ex.run_layer(0, x_q)
    dt_golden = time.time() - t0
    assert (got_fast == got).all()
    print(f"[execute] pallas backend bit-exact vs golden; "
          f"{dt_golden * 1e3:.1f} ms golden -> {dt_fast * 1e3:.1f} ms "
          f"pallas on one layer")

    # 6. Whole-CNN inference: a reduced mobilenet_v2 (depthwise layers
    #    included) chained end to end through the spatial im2col path —
    #    grouped per-channel GEMMs, pool glue, inter-layer requant.
    cnn_prog = compile_network("mobilenet_v2", in_hw=28, width=0.25)
    golden_cnn = GoldenExecutor(cnn_prog)
    fast_cnn = PallasExecutor(cnn_prog)
    for lp in cnn_prog.layers:
        bind_synthetic(golden_cnn, lp, seed=lp.index)
        bind_synthetic(fast_cnn, lp, seed=lp.index)
    geo0 = cnn_prog.layers[0].geometry
    img = np.random.default_rng(0).integers(
        -8, 8, geo0.in_shape).astype(np.int8)
    logits_g = np.asarray(golden_cnn.run(img))
    logits_p = np.asarray(fast_cnn.run(img))
    n_dw = sum(lp.depthwise for lp in cnn_prog.layers)
    assert (logits_g == logits_p).all()
    print(f"[execute] mobilenet_v2@{geo0.in_hw}px end to end: "
          f"{len(cnn_prog.layers)} layers ({n_dw} depthwise) -> logits "
          f"{logits_g.shape}, golden == pallas bit-exact")


if __name__ == "__main__":
    main()
