"""Quickstart: the paper's heterogeneous split GEMM in five minutes.

1. Solve the neuron-based workload split (Eq. 12) for a ResNet layer on
   the FPGA cost model — the paper's core co-design loop.
2. Run the same idea on the TPU adaptation: a HeteroLinear layer whose
   columns split between a packed-int4 path and a flexible bitplane
   path, executed through the Pallas kernel wrappers.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core.hetero_linear import (
    HeteroLinearConfig, apply_deploy, apply_fp, deploy, init_hetero_linear)
from repro.core.scheduler import XC7Z020, DspCoreConfig, LutCoreConfig
from repro.core.split import solve_split
from repro.core.workloads import resnet18_specs
from repro.quant.hybrid import LayerQuantConfig

# --- 1. the paper's split solver on its own workload ----------------------
layer = resnet18_specs()[13]
lut = LutCoreConfig(m=8, n=16, k=128)
dsp = DspCoreConfig(n_reg_row_a=DspCoreConfig.rows_for_device(XC7Z020),
                    d_a=2048, d_w=1024)
sol = solve_split(layer, lut, dsp, XC7Z020, bits_w_lut=4, bits_a=4,
                  keep_curve=True)
print(f"[FPGA] layer {layer.name}: optimal split ratio {sol.ratio:.2f} "
      f"({sol.n_lut}/{layer.gemm().n} filters on the LUT-core), "
      f"{XC7Z020.cycles_to_ms(sol.cycles):.2f} ms "
      f"(all-DSP {XC7Z020.cycles_to_ms(float(sol.curve[0])):.2f} ms, "
      f"all-LUT {XC7Z020.cycles_to_ms(float(sol.curve[-1])):.2f} ms)")

# --- 2. the TPU adaptation: HeteroLinear --------------------------------
cfg = HeteroLinearConfig(
    in_features=256, out_features=192,
    quant=LayerQuantConfig(w_bits_lut=6, a_bits=8, ratio=0.4))
params = init_hetero_linear(jax.random.key(0), cfg)
x = 0.5 * jax.random.normal(jax.random.key(1), (16, 256))

y_fp = apply_fp(params, x)
deployed = deploy(params, cfg)            # integer codes, two paths
y_int = apply_deploy(deployed, x)         # bitplane + int4 kernels

rel = float(jnp.linalg.norm(y_int - y_fp) / jnp.linalg.norm(y_fp))
print(f"[TPU] HeteroLinear 256->192, ratio 0.4, w6/a8: "
      f"integer path vs fp32 rel err {rel:.4f}")
print(f"      serial path columns: {deployed.wq_serial.shape[1]}, "
      f"parallel path columns: {deployed.wq_parallel.shape[1]}")
