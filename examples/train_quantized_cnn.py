"""End-to-end QAT of the paper's workload family with hybrid quantization.

Trains a reduced ResNet-18 on synthetic class-conditioned images under
three quantization settings (fp32 / hybrid 6-4 / hybrid 3-2) and reports
the accuracy each reaches — the offline stand-in for the paper's
accuracy-vs-bit-width trade-off (Table 5).

  PYTHONPATH=src python examples/train_quantized_cnn.py --steps 60
"""
import argparse

import jax
import jax.numpy as jnp

from repro.data.synthetic import SyntheticImages
from repro.models import cnn
from repro.quant.hybrid import LayerQuantConfig


def train(quant, steps, lr=0.03, seed=0):
    cfg = cnn.reduced_config("resnet18")
    specs = cnn.specs_for(cfg)
    qcfgs = (None if quant is None else
             [LayerQuantConfig(w_bits_lut=quant[0], a_bits=quant[1],
                               ratio=0.5) for _ in specs])
    params = cnn.init(cfg, jax.random.key(seed))
    data = SyntheticImages(10, 32, 32, seed=seed)

    @jax.jit
    def step(p, images, labels):
        def loss(p):
            return cnn.cross_entropy(cnn.forward(p, images, cfg, qcfgs),
                                     labels)
        l, g = jax.value_and_grad(loss)(p)
        gn = jnp.sqrt(sum(jnp.sum(x * x) for x in jax.tree.leaves(g)))
        scale = jnp.minimum(1.0, 1.0 / (gn + 1e-9))       # clip: low-bit
        return jax.tree.map(lambda w, gw: w - lr * scale * gw, p, g), l

    for i in range(steps):
        b = data.next_batch()
        params, l = step(params, b["images"], b["labels"])

    test = SyntheticImages(10, 256, 32, seed=seed,
                           sample_seed=seed + 777).next_batch()
    logits = cnn.forward(params, test["images"], cfg, qcfgs)
    acc = float(jnp.mean(jnp.argmax(logits, -1) == test["labels"]))
    return float(l), acc


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    args = ap.parse_args()
    for name, quant in [("fp32", None), ("hybrid w6/a4", (6, 4)),
                        ("hybrid w3/a2", (3, 2))]:
        loss, acc = train(quant, args.steps)
        print(f"{name:14s} final loss {loss:.3f}  test acc {acc:.3f}")


if __name__ == "__main__":
    main()
