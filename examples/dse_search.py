"""Run the paper's RL-based design-space exploration end to end.

Searches the N3H-Core configuration (hardware knobs + per-layer
bit-widths; split ratios solved analytically per Eq. 12) for ResNet-18
on XC7Z020 under a latency target, then prints the Table-3-style row
and the per-layer bit-width/ratio profile (the Fig. 9 analogue).

  PYTHONPATH=src python examples/dse_search.py --episodes 60 --target 35
"""
import argparse

from repro.dse.search import run_search


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--network", default="resnet18")
    ap.add_argument("--device", default="XC7Z020")
    ap.add_argument("--target", type=float, default=35.0)
    ap.add_argument("--episodes", type=int, default=60)
    args = ap.parse_args()

    res = run_search(network=args.network, device=args.device,
                     target_latency_ms=args.target,
                     episodes=args.episodes, verbose=True)
    print("\nsearched configuration (Table 3 row):")
    for k, v in res.table3_row().items():
        print(f"  {k:12s} {v}")
    info = res.best_info
    print("\nper-layer profile (Fig. 9 analogue):")
    print(f"  {'layer':>5s} {'B_w-L':>6s} {'B_a':>4s} {'ratio':>6s}")
    for i, (bw, ba, r) in enumerate(zip(info["bw_lut"], info["ba"],
                                        info["ratios"])):
        print(f"  {i:5d} {bw:6d} {ba:4d} {r:6.2f}")


if __name__ == "__main__":
    main()
