"""Run the paper's RL-based design-space exploration end to end.

Searches the N3H-Core configuration (hardware knobs + per-layer
bit-widths; split ratios solved analytically per Eq. 12) for a network
on an FPGA device under a latency target, then prints the
Table-3-style row and the per-layer bit-width/ratio profile (the
Fig. 9 analogue).

With ``--simulate-elites`` the search runs two-tier (docs/dse.md): the
agent explores on the closed-form latency model, while the top
``--top-k`` elite configurations are compiled through the NN→ISA
toolchain and re-scored on the event-driven simulator — the script
then prints the analytical-vs-simulated latency delta for the winning
config plus the full calibration report (``--calibration-csv`` writes
it as CSV — the artifact the CI docs job uploads).

  PYTHONPATH=src python examples/dse_search.py --episodes 60 --target 35
  PYTHONPATH=src python examples/dse_search.py --network llama3.2-1b \
      --seq-len 16 --target 1.0 --episodes 12 --simulate-elites --top-k 3
"""
import argparse

from repro.dse.search import run_search


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--network", default="resnet18",
                    help="CNN workload or registry arch id")
    ap.add_argument("--device", default="XC7Z020")
    ap.add_argument("--target", type=float, default=35.0)
    ap.add_argument("--episodes", type=int, default=60)
    ap.add_argument("--seq-len", type=int, default=64,
                    help="token count for registry archs (perfect square)")
    ap.add_argument("--simulate-elites", action="store_true",
                    help="re-score elite configs on compiled programs "
                         "(the two-tier loop of docs/dse.md)")
    ap.add_argument("--top-k", type=int, default=4,
                    help="elite pool size for --simulate-elites")
    ap.add_argument("--sim-every", type=int, default=20,
                    help="episodes between elite re-scoring rounds")
    ap.add_argument("--calibration-csv", default=None,
                    help="write the calibration report to this CSV path")
    args = ap.parse_args()

    res = run_search(network=args.network, device=args.device,
                     target_latency_ms=args.target,
                     episodes=args.episodes, seq_len=args.seq_len,
                     simulate_elites=args.simulate_elites,
                     top_k=args.top_k, sim_every=args.sim_every,
                     verbose=True)
    print(f"\nsearched configuration (Table 3 row, "
          f"reward source: {res.reward_source}):")
    for k, v in res.table3_row().items():
        print(f"  {k:14s} {v}")
    if res.simulated_latency_ms is not None:
        delta = res.analytical_latency_ms - res.simulated_latency_ms
        print("\nanalytical vs simulated latency (winning config):")
        print(f"  analytical   {res.analytical_latency_ms:.4f} ms")
        print(f"  simulated    {res.simulated_latency_ms:.4f} ms")
        print(f"  delta        {delta:+.4f} ms ({res.sim_gap_pct:+.2f}%)")
        print()
        print(res.calibration_report())
    if args.calibration_csv:
        res.write_calibration_csv(args.calibration_csv)
        print(f"\ncalibration CSV written to {args.calibration_csv}")
    info = res.best_info
    print("\nper-layer profile (Fig. 9 analogue):")
    print(f"  {'layer':>5s} {'B_w-L':>6s} {'B_a':>4s} {'ratio':>6s}")
    for i, (bw, ba, r) in enumerate(zip(info["bw_lut"], info["ba"],
                                        info["ratios"])):
        print(f"  {i:5d} {bw:6d} {ba:4d} {r:6.2f}")


if __name__ == "__main__":
    main()
