"""Serve a small LM with batched requests — the paper's kind of driver.

The paper is an inference accelerator, so the dictated end-to-end
driver is serving: this example initializes a llama-family model,
enables the paper's hybrid quantization on every projection, and runs
batched prefill + greedy decode over a synthetic request queue,
reporting latency & throughput per phase (and comparing quantized vs
fp output agreement).

  PYTHONPATH=src python examples/serve_quantized_lm.py --batch 8
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.data.synthetic import SyntheticTokens
from repro.models.lm import HeteroQuantConfig
from repro.serve.engine import make_cache, make_decode_fn, make_prefill_fn


def build(arch_id, quantize):
    arch = configs.get(arch_id)
    arch = dataclasses.replace(arch, model=arch.smoke)
    if quantize:
        arch = dataclasses.replace(arch, model=dataclasses.replace(
            arch.model,
            hetero_quant=HeteroQuantConfig(w_bits_lut=6, a_bits=8,
                                           ratio=0.5)))
    return arch


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--new-tokens", type=int, default=16)
    args = ap.parse_args()

    outs = {}
    for quantize in (False, True):
        arch = build(args.arch, quantize)
        mod = arch.model_module()
        params = mod.init(arch.model, jax.random.key(0))
        data = SyntheticTokens(arch.model.vocab, args.batch,
                               args.prompt_len, seed=0)
        prompts = data.next_batch()["tokens"]
        max_seq = args.prompt_len + args.new_tokens
        cache = make_cache(arch, args.batch, max_seq, jnp.float32)
        prefill = jax.jit(make_prefill_fn(arch))
        decode = jax.jit(make_decode_fn(arch))

        t0 = time.time()
        logits, cache = prefill(params, {"tokens": prompts}, cache)
        logits = jax.block_until_ready(logits)
        t_pre = time.time() - t0

        tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
        toks = [tok]
        t0 = time.time()
        for i in range(args.new_tokens - 1):
            logits, cache = decode(params, tok, cache,
                                   jnp.int32(args.prompt_len + i))
            tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
            toks.append(tok)
        jax.block_until_ready(tok)
        t_dec = time.time() - t0

        outs[quantize] = jnp.concatenate(toks, axis=1)
        tag = "hybrid w6/a8" if quantize else "fp32        "
        print(f"{tag}: prefill {t_pre * 1e3:7.1f} ms | decode "
              f"{t_dec * 1e3 / max(args.new_tokens - 1, 1):6.1f} ms/tok | "
              f"{args.batch * args.new_tokens / max(t_dec, 1e-9):6.0f} tok/s")

    agree = float(jnp.mean(outs[False] == outs[True]))
    print(f"quantized/fp greedy-token agreement: {agree:.2%} "
          f"(same random init; 6-bit hybrid tracks fp closely)")


if __name__ == "__main__":
    main()
