"""Seeded synthetic batches for every model family.

Offline container = no ImageNet / no web corpora, so training and
serving examples run on deterministic synthetic data:

  * ``SyntheticTokens`` — Zipf-distributed token streams with a
    repeated-bigram structure (so a real LM loss signal exists: models
    that learn the bigram table beat the unigram entropy floor).
  * ``SyntheticImages`` — class-conditioned Gaussian blobs (linearly
    separable at high SNR; quantization noise measurably hurts, which is
    what the paper-reproduction accuracy proxies need).
  * ``make_batch_specs`` — ShapeDtypeStruct stand-ins of the same batch
    for the dry-run (arch x shape), including frontend-stub embeddings.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import ArchConfig, ShapeSpec


class SyntheticTokens:
    """Deterministic LM batch stream: p(next | cur) is a fixed sparse
    bigram table over a Zipf unigram prior."""

    def __init__(self, vocab: int, batch: int, seq: int, seed: int = 0,
                 bigram_peak: float = 0.8):
        self.vocab, self.batch, self.seq = vocab, batch, seq
        rng = np.random.default_rng(seed)
        self._succ = rng.integers(0, vocab, size=vocab)   # bigram successor
        self._peak = bigram_peak
        ranks = np.arange(1, vocab + 1, dtype=np.float64)
        p = 1.0 / ranks
        self._unigram = p / p.sum()
        self._rng = np.random.default_rng(seed + 1)

    def next_batch(self) -> dict:
        b, s = self.batch, self.seq
        out = np.empty((b, s), np.int32)
        cur = self._rng.choice(self.vocab, size=b, p=self._unigram)
        out[:, 0] = cur
        for t in range(1, s):
            use_bigram = self._rng.random(b) < self._peak
            nxt = np.where(use_bigram, self._succ[cur],
                           self._rng.choice(self.vocab, size=b,
                                            p=self._unigram))
            out[:, t] = nxt
            cur = nxt
        return {"tokens": jnp.asarray(out)}

    def __iter__(self):
        while True:
            yield self.next_batch()


class SyntheticImages:
    """Class-conditioned Gaussian images for the CNN QAT experiments."""

    def __init__(self, n_classes: int, batch: int, hw: int, seed: int = 0,
                 snr: float = 3.0, sample_seed: int | None = None):
        """``seed`` fixes the class prototypes (the task); ``sample_seed``
        varies the noise/draws — train and test streams share ``seed``
        but use different ``sample_seed`` values."""
        self.n_classes, self.batch, self.hw = n_classes, batch, hw
        rng = np.random.default_rng(seed)
        self._proto = rng.standard_normal(
            (n_classes, hw, hw, 3)).astype(np.float32)
        self._snr = snr
        self._rng = np.random.default_rng(
            seed + 1 if sample_seed is None else sample_seed)

    def next_batch(self) -> dict:
        labels = self._rng.integers(0, self.n_classes, self.batch)
        noise = self._rng.standard_normal(
            (self.batch, self.hw, self.hw, 3)).astype(np.float32)
        x = self._snr * self._proto[labels] + noise
        return {"images": jnp.asarray(x),
                "labels": jnp.asarray(labels.astype(np.int32))}

    def __iter__(self):
        while True:
            yield self.next_batch()


# ---------------------------------------------------------------------------
# Dry-run batch specs (arch x shape -> abstract inputs)
# ---------------------------------------------------------------------------

VISION_PATCHES = 1024       # stub frontend: patches per sample (qwen2-vl)


def make_batch_specs(arch: ArchConfig, shape: ShapeSpec) -> dict:
    """ShapeDtypeStruct inputs for (arch, shape): the train/prefill batch
    or the decode-step token. Frontend stubs included per the task spec."""
    b, s = shape.global_batch, shape.seq_len
    d = arch.model.d_model
    if shape.kind in ("train", "prefill"):
        specs = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}
        if arch.module == "encdec":
            specs["frames"] = jax.ShapeDtypeStruct((b, s, d), jnp.bfloat16)
        elif arch.frontend == "vision":
            specs["extra_embed"] = jax.ShapeDtypeStruct(
                (b, s, d), jnp.bfloat16)
        return specs
    # decode: one new token against a cache of length s
    return {"token": jax.ShapeDtypeStruct((b, 1), jnp.int32)}


def make_host_batch(arch: ArchConfig, batch: int, seq: int, seed: int = 0
                    ) -> dict:
    """Small concrete batch for smoke tests (reduced configs)."""
    vocab = arch.smoke.vocab if arch.smoke is not None else arch.model.vocab
    stream = SyntheticTokens(vocab, batch, seq, seed)
    out = stream.next_batch()
    d = (arch.smoke or arch.model).d_model
    if arch.module == "encdec":
        out["frames"] = 0.1 * jax.random.normal(
            jax.random.key(seed), (batch, seq, d), jnp.float32)
    elif arch.frontend == "vision":
        out["extra_embed"] = 0.1 * jax.random.normal(
            jax.random.key(seed), (batch, seq, d), jnp.float32)
    return out
