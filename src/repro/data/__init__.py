"""Deterministic synthetic data pipelines (no external datasets offline)."""
from repro.data.synthetic import (
    SyntheticImages,
    SyntheticTokens,
    make_batch_specs,
    make_host_batch,
)

__all__ = ["SyntheticImages", "SyntheticTokens", "make_batch_specs",
           "make_host_batch"]
