"""Serving substrate: prefill/decode step factories, batched
generation, and the distributed fleet (async program server + executor
workers over the ``serve/protocol.py`` wire)."""
from repro.serve.engine import (
    ServeState,
    greedy_generate,
    make_decode_fn,
    make_prefill_fn,
)

__all__ = ["ServeState", "greedy_generate", "make_decode_fn",
           "make_prefill_fn"]
