"""Wire protocol for the serving fleet: length-prefixed frames.

One frame = a fixed 14-byte header (``N3HF`` magic, version, kind
byte, header length, payload length) + a canonical-JSON header dict +
an opaque payload. The JSON half carries control fields (sequence
numbers, slot indices, channel names); the payload carries bulk bytes
(``N3HPROG1`` program sections shipped byte-for-byte out of the
``ProgramCache`` images, packed weight arrays, activation tiles for
the ``*.xdev`` channel hand-shake).

The same frame codec backs both transports: the blocking
:class:`FrameStream` used by worker processes/threads over a socket,
and the ``asyncio`` reader/writer helpers the :class:`fleet.FleetServer`
event loop uses. Array payloads use :func:`pack_arrays` — a
deterministic little-endian packing (sorted names, C-order bytes) so
the bytes a worker binds are a pure function of the arrays, which is
what the fleet's bit-exactness gate transports over the wire.
"""
from __future__ import annotations

import json
import struct

import numpy as np

MAGIC = b"N3HF"
VERSION = 1

_HDR = struct.Struct("<4sBBII")

#: frame kinds, u8 on the wire. Control plane: hello/ready/ping/pong/
#: error/shutdown. Data plane: load_program & bind_arrays (resident
#: decode sessions), load_section (one bundle device section),
#: step/reset_slot/result (slot-batched decode), run_layer/chan (the
#: cross-device hand-shake for bundle programs).
KINDS = (
    "hello", "ready", "ping", "pong", "error", "shutdown",
    "load_program", "load_section", "bind_arrays",
    "step", "reset_slot", "result", "run_layer", "chan",
)
_KIND_CODE = {k: i for i, k in enumerate(KINDS)}


class ProtocolError(RuntimeError):
    """Malformed frame / unknown kind / bad magic on the fleet wire."""


def encode_frame(kind: str, header: dict | None = None,
                 payload: bytes = b"") -> bytes:
    """Render one frame to bytes (canonical JSON header, so identical
    (kind, header, payload) always yields identical bytes)."""
    if kind not in _KIND_CODE:
        raise ProtocolError(f"unknown frame kind {kind!r}")
    blob = json.dumps(header or {}, sort_keys=True,
                      separators=(",", ":")).encode("utf-8")
    return _HDR.pack(MAGIC, VERSION, _KIND_CODE[kind], len(blob),
                     len(payload)) + blob + bytes(payload)


def decode_frame(data: bytes) -> tuple[str, dict, bytes]:
    """Parse one complete frame; raises :class:`ProtocolError` on any
    structural defect (bad magic/version/kind, truncation, trailing
    bytes)."""
    if len(data) < _HDR.size:
        raise ProtocolError(f"short frame ({len(data)} bytes)")
    magic, ver, code, hlen, plen = _HDR.unpack_from(data)
    if magic != MAGIC:
        raise ProtocolError(f"bad magic {magic!r}")
    if ver != VERSION:
        raise ProtocolError(f"unsupported protocol version {ver}")
    if code >= len(KINDS):
        raise ProtocolError(f"unknown kind code {code}")
    if len(data) != _HDR.size + hlen + plen:
        raise ProtocolError(
            f"frame length mismatch: header says {_HDR.size + hlen + plen},"
            f" got {len(data)}")
    try:
        header = json.loads(data[_HDR.size:_HDR.size + hlen])
    except json.JSONDecodeError as e:
        raise ProtocolError(f"bad frame header JSON: {e}") from e
    return KINDS[code], header, data[_HDR.size + hlen:]


# -- blocking transport (worker side) ------------------------------------


class FrameStream:
    """Blocking frame codec over a connected socket."""

    def __init__(self, sock):
        self.sock = sock

    def send(self, kind: str, header: dict | None = None,
             payload: bytes = b"") -> None:
        self.sock.sendall(encode_frame(kind, header, payload))

    def recv(self) -> tuple[str, dict, bytes]:
        """Read exactly one frame; raises :class:`ProtocolError` on a
        closed or corrupt stream."""
        head = self._read_exact(_HDR.size)
        magic, ver, code, hlen, plen = _HDR.unpack_from(head)
        if magic != MAGIC:
            raise ProtocolError(f"bad magic {magic!r}")
        body = self._read_exact(hlen + plen)
        return decode_frame(head + body)

    def close(self) -> None:
        try:
            self.sock.close()
        except OSError:
            pass

    def _read_exact(self, n: int) -> bytes:
        chunks = []
        got = 0
        while got < n:
            chunk = self.sock.recv(min(n - got, 1 << 20))
            if not chunk:
                raise ProtocolError("stream closed mid-frame")
            chunks.append(chunk)
            got += len(chunk)
        return b"".join(chunks)


# -- asyncio transport (server side) -------------------------------------


async def read_frame(reader) -> tuple[str, dict, bytes]:
    """Read one frame from an ``asyncio.StreamReader``; raises
    :class:`ProtocolError` at EOF / corruption."""
    import asyncio

    try:
        head = await reader.readexactly(_HDR.size)
        magic, ver, code, hlen, plen = _HDR.unpack_from(head)
        if magic != MAGIC:
            raise ProtocolError(f"bad magic {magic!r}")
        body = await reader.readexactly(hlen + plen)
    except (asyncio.IncompleteReadError, ConnectionError) as e:
        raise ProtocolError(f"stream closed mid-frame: {e!r}") from e
    return decode_frame(head + body)


def write_frame(writer, kind: str, header: dict | None = None,
                payload: bytes = b"") -> None:
    """Queue one frame on an ``asyncio.StreamWriter`` (caller drains)."""
    writer.write(encode_frame(kind, header, payload))


# -- array payloads -------------------------------------------------------


def pack_arrays(arrays: dict) -> bytes:
    """Pack a name->ndarray dict into deterministic bytes: sorted
    names, little-endian dtype descriptors, C-order data. The inverse
    of :func:`unpack_arrays` (exact round-trip incl. dtypes/shapes)."""
    parts = [struct.pack("<I", len(arrays))]
    for name in sorted(arrays):
        arr = np.ascontiguousarray(arrays[name])
        if arr.dtype.byteorder == ">":
            arr = arr.astype(arr.dtype.newbyteorder("<"))
        nb = name.encode("utf-8")
        db = arr.dtype.str.encode("ascii")
        parts.append(struct.pack("<H", len(nb)))
        parts.append(nb)
        parts.append(struct.pack("<B", len(db)))
        parts.append(db)
        parts.append(struct.pack("<B", arr.ndim))
        parts.append(struct.pack(f"<{arr.ndim}I", *arr.shape)
                     if arr.ndim else b"")
        raw = arr.tobytes()
        parts.append(struct.pack("<Q", len(raw)))
        parts.append(raw)
    return b"".join(parts)


def unpack_arrays(data: bytes) -> dict:
    """Inverse of :func:`pack_arrays`."""
    try:
        (count,) = struct.unpack_from("<I", data, 0)
        pos = 4
        out = {}
        for _ in range(count):
            (nlen,) = struct.unpack_from("<H", data, pos)
            pos += 2
            name = data[pos:pos + nlen].decode("utf-8")
            pos += nlen
            (dlen,) = struct.unpack_from("<B", data, pos)
            pos += 1
            dtype = np.dtype(data[pos:pos + dlen].decode("ascii"))
            pos += dlen
            (ndim,) = struct.unpack_from("<B", data, pos)
            pos += 1
            shape = struct.unpack_from(f"<{ndim}I", data, pos)
            pos += 4 * ndim
            (nbytes,) = struct.unpack_from("<Q", data, pos)
            pos += 8
            out[name] = np.frombuffer(
                data[pos:pos + nbytes], dtype).reshape(shape).copy()
            pos += nbytes
        if pos != len(data):
            raise ProtocolError(
                f"trailing bytes in array payload ({len(data) - pos})")
        return out
    except (struct.error, UnicodeDecodeError, TypeError,
            ValueError) as e:
        if isinstance(e, ProtocolError):
            raise
        raise ProtocolError(f"corrupt array payload: {e!r}") from e


# -- bundle distribution --------------------------------------------------


def split_bundle_image(image: bytes) -> tuple[dict, list[bytes]]:
    """Split an ``N3HBUND1`` image into its JSON meta dict and the
    per-device ``N3HPROG1`` sections *byte-for-byte* (slices of the
    original buffer, no re-serialization) — what the fleet server
    ships each worker from the ``ProgramCache``."""
    from repro.compiler.asm import MAGIC_BUNDLE

    if image[:8] != MAGIC_BUNDLE:
        raise ProtocolError("not an N3HBUND1 image")
    try:
        (meta_len,) = struct.unpack_from("<I", image, 8)
        pos = 12
        meta = json.loads(image[pos:pos + meta_len].decode("utf-8"))
        pos += meta_len
        (n_devices,) = struct.unpack_from("<I", image, pos)
        pos += 4
        sections = []
        for _ in range(n_devices):
            (plen,) = struct.unpack_from("<I", image, pos)
            pos += 4
            sections.append(bytes(image[pos:pos + plen]))
            pos += plen
        if pos != len(image):
            raise ProtocolError(
                f"trailing bytes in bundle image ({len(image) - pos})")
    except (struct.error, json.JSONDecodeError, UnicodeDecodeError) as e:
        raise ProtocolError(f"corrupt N3HBUND1 image: {e!r}") from e
    return meta, sections
