"""Inference engine: prefill / decode step factories + generation loop.

``make_prefill_fn`` / ``make_decode_fn`` adapt the per-family model APIs
to one uniform signature so the launcher, the dry-run and the examples
never branch on the architecture family:

    prefill_fn(params, batch, cache)       -> (logits, cache)
    decode_fn(params, token, cache, pos)   -> (logits, cache)

Family notes:
  * lm      — real prefill (scores prompt AND fills the KV cache).
  * ssm     — decode carries the recurrent state; "prefill" scores the
              prompt with the scan forward (state building for
              generation happens token-by-token in greedy_generate).
  * hybrid  — like ssm for the Mamba sublayers + KV for attention.
  * encdec  — prefill = encode(frames) + build the static cross-cache;
              decode = one decoder token.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.registry import ArchConfig
from repro.parallel.sharding import AxisRules, DEFAULT_RULES


@dataclasses.dataclass
class ServeState:
    cache: Any
    pos: int


def make_cache(arch: ArchConfig, batch: int, max_seq: int,
               dtype=jnp.bfloat16) -> Any:
    mod = arch.model_module()
    if arch.module == "ssm":
        return mod.init_cache(arch.model, batch, dtype=dtype)
    if arch.module == "encdec":
        return mod.init_cache(arch.model, batch, max_tgt=max_seq,
                              src=max_seq, dtype=dtype)
    return mod.init_cache(arch.model, batch, max_seq, dtype)


def make_prefill_fn(arch: ArchConfig, rules: AxisRules = DEFAULT_RULES
                    ) -> Callable:
    mod = arch.model_module()
    cfg = arch.model

    if arch.module == "lm":
        def prefill_fn(params, batch, cache):
            return mod.prefill(params, batch["tokens"], cache, cfg, rules,
                               extra_embed=batch.get("extra_embed"))
        return prefill_fn

    if arch.module == "encdec":
        def prefill_fn(params, batch, cache):
            memory = mod.encode(params, batch["frames"], cfg, rules)
            cache = mod.build_cross_cache(params, memory, cfg, cache)
            logits, _ = mod.forward(params, batch["frames"],
                                    batch["tokens"], cfg, rules)
            return logits, cache
        return prefill_fn

    # ssm / hybrid: forward scores the prompt; recurrent state accrues
    # during generation (see greedy_generate).
    def prefill_fn(params, batch, cache):
        logits, _ = mod.forward(params, batch["tokens"], cfg, rules,
                                extra_embed=batch.get("extra_embed"))
        return logits, cache
    return prefill_fn


def make_decode_fn(arch: ArchConfig, rules: AxisRules = DEFAULT_RULES
                   ) -> Callable:
    mod = arch.model_module()
    cfg = arch.model

    def decode_fn(params, token, cache, pos):
        return mod.decode_step(params, token, cache, pos, cfg, rules)

    return decode_fn


def greedy_generate(arch: ArchConfig, params: Any, prompts: jax.Array,
                    n_new: int, max_seq: int | None = None,
                    dtype=jnp.float32,
                    rules: AxisRules = DEFAULT_RULES) -> jax.Array:
    """Greedy batched generation (the end-to-end serving path).

    prompts: [B, S0] int32. Returns [B, S0 + n_new]. For the recurrent
    families the prompt is consumed token-by-token to build the state
    (simple and correct; chunked prefill is a recorded follow-up).
    """
    b, s0 = prompts.shape
    max_seq = max_seq or (s0 + n_new)
    cache = make_cache(arch, b, max_seq, dtype)
    decode_fn = jax.jit(make_decode_fn(arch, rules))

    recurrent = arch.module in ("ssm", "hybrid")
    out = [prompts]
    if arch.module == "lm":
        # real prefill: one call scores the whole prompt and fills the
        # KV cache (S0 single-token steps would re-pay the attention
        # window per token for nothing)
        prefill_fn = jax.jit(make_prefill_fn(arch, rules))
        logits, cache = prefill_fn(params, {"tokens": prompts}, cache)
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        pos = s0
    elif recurrent:
        # the recurrent families build state token-by-token: their
        # prefill scores the prompt but does not advance the state
        for t in range(s0):
            logits, cache = decode_fn(params, prompts[:, t:t + 1], cache,
                                      jnp.int32(t))
        tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        pos = s0
    else:  # encdec: encode once, then decode from BOS
        raise NotImplementedError(
            "encdec generation uses examples/serve_encdec.py")

    new = [tok]
    for i in range(n_new - 1):
        logits, cache = decode_fn(params, tok, cache, jnp.int32(pos))
        tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        new.append(tok)
        pos += 1
    return jnp.concatenate(out + new, axis=1)


# ---------------------------------------------------------------------------
# Compiled (quantized) serving path: decode-resident executor sessions
# ---------------------------------------------------------------------------


def make_compiled_session(arch_id: str, *, backend: str = "golden",
                          batch: int = 1, max_seq: int = 64,
                          bits_w: int = 4, bits_a: int = 4,
                          opt_level: int = 1, device: str = "XC7Z020",
                          seed: int | None = None, tracer=None):
    """Build a decode-resident :class:`~repro.compiler.runtime.session.
    ExecutorSession` for a registry arch: compile the decode step
    program (weights resident, KV/state persistent), bind synthetic
    quantized weights once, and report the simulator's warm-up vs
    steady-state step cycles into ``obs.METRICS``
    (``serve.decode.warmup_cycles`` / ``serve.decode.steady_cycles``).
    """
    from repro.obs import METRICS
    from repro.core.scheduler import simulate_program
    from repro.compiler import compile_decode_network
    from repro.compiler.runtime import ExecutorSession
    prog = compile_decode_network(arch_id, batch=batch, max_seq=max_seq,
                                  bits_w=bits_w, bits_a=bits_a,
                                  opt_level=opt_level, device=device)
    ds = simulate_program(prog)
    METRICS.gauge("serve.decode.warmup_cycles", ds.warmup_cycles)
    METRICS.gauge("serve.decode.steady_cycles", ds.steady_cycles)
    session = ExecutorSession(prog, backend=backend, tracer=tracer)
    session.bind_synthetic_all(seed=seed)
    return session


def make_compiled_decode_fn(session) -> Callable:
    """Adapt an ``ExecutorSession`` to the uniform decode signature.
    ``params`` and ``cache`` pass through untouched — the session owns
    the resident weights and the live cache buffers."""
    def decode_fn(params, token, cache, pos):
        logits = session.step(jnp.asarray(token, jnp.int32).reshape(-1),
                              int(pos))
        return logits, cache
    return decode_fn


def greedy_generate_compiled(session, prompts: jax.Array,
                             n_new: int) -> jax.Array:
    """Greedy generation through a compiled decode session: the prompt
    is consumed step by step (warm-up program on the first token,
    steady-state program after), then ``n_new`` greedy tokens follow —
    every step against the session's resident weights and live caches.
    """
    prompts = jnp.asarray(prompts, jnp.int32)
    b, s0 = prompts.shape
    if b != session.spec.batch:
        raise ValueError(f"session is compiled for batch="
                         f"{session.spec.batch}, prompts have {b}")
    if s0 + n_new > session.spec.max_seq:
        raise ValueError(f"{s0} prompt + {n_new} new tokens exceed the "
                         f"session's max_seq={session.spec.max_seq}")
    session.reset()
    logits = None
    for t in range(s0):
        logits = session.step(prompts[:, t], t)
    new = []
    for i in range(n_new):
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        new.append(tok[:, None])
        if i + 1 < n_new:
            logits = session.step(tok, s0 + i)
    return jnp.concatenate([prompts] + new, axis=1)
