"""Inference engine: prefill / decode step factories + generation loop.

``make_prefill_fn`` / ``make_decode_fn`` adapt the per-family model APIs
to one uniform signature so the launcher, the dry-run and the examples
never branch on the architecture family:

    prefill_fn(params, batch, cache)       -> (logits, cache)
    decode_fn(params, token, cache, pos)   -> (logits, cache)

Family notes:
  * lm      — real prefill (scores prompt AND fills the KV cache).
  * ssm     — decode carries the recurrent state; "prefill" scores the
              prompt with the scan forward (state building for
              generation happens token-by-token in greedy_generate).
  * hybrid  — like ssm for the Mamba sublayers + KV for attention.
  * encdec  — prefill = encode(frames) + build the static cross-cache;
              decode = one decoder token.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.registry import ArchConfig
from repro.parallel.sharding import AxisRules, DEFAULT_RULES


@dataclasses.dataclass
class ServeState:
    cache: Any
    pos: int


def make_cache(arch: ArchConfig, batch: int, max_seq: int,
               dtype=jnp.bfloat16) -> Any:
    mod = arch.model_module()
    if arch.module == "ssm":
        return mod.init_cache(arch.model, batch, dtype=dtype)
    if arch.module == "encdec":
        return mod.init_cache(arch.model, batch, max_tgt=max_seq,
                              src=max_seq, dtype=dtype)
    return mod.init_cache(arch.model, batch, max_seq, dtype)


def make_prefill_fn(arch: ArchConfig, rules: AxisRules = DEFAULT_RULES
                    ) -> Callable:
    mod = arch.model_module()
    cfg = arch.model

    if arch.module == "lm":
        def prefill_fn(params, batch, cache):
            return mod.prefill(params, batch["tokens"], cache, cfg, rules,
                               extra_embed=batch.get("extra_embed"))
        return prefill_fn

    if arch.module == "encdec":
        def prefill_fn(params, batch, cache):
            memory = mod.encode(params, batch["frames"], cfg, rules)
            cache = mod.build_cross_cache(params, memory, cfg, cache)
            logits, _ = mod.forward(params, batch["frames"],
                                    batch["tokens"], cfg, rules)
            return logits, cache
        return prefill_fn

    # ssm / hybrid: forward scores the prompt; recurrent state accrues
    # during generation (see greedy_generate).
    def prefill_fn(params, batch, cache):
        logits, _ = mod.forward(params, batch["tokens"], cfg, rules,
                                extra_embed=batch.get("extra_embed"))
        return logits, cache
    return prefill_fn


def make_decode_fn(arch: ArchConfig, rules: AxisRules = DEFAULT_RULES
                   ) -> Callable:
    mod = arch.model_module()
    cfg = arch.model

    def decode_fn(params, token, cache, pos):
        return mod.decode_step(params, token, cache, pos, cfg, rules)

    return decode_fn


def greedy_generate(arch: ArchConfig, params: Any, prompts: jax.Array,
                    n_new: int, max_seq: int | None = None,
                    dtype=jnp.float32,
                    rules: AxisRules = DEFAULT_RULES) -> jax.Array:
    """Greedy batched generation (the end-to-end serving path).

    prompts: [B, S0] int32. Returns [B, S0 + n_new]. For the recurrent
    families the prompt is consumed token-by-token to build the state
    (simple and correct; chunked prefill is a recorded follow-up).
    """
    b, s0 = prompts.shape
    max_seq = max_seq or (s0 + n_new)
    cache = make_cache(arch, b, max_seq, dtype)
    decode_fn = jax.jit(make_decode_fn(arch, rules))

    recurrent = arch.module in ("ssm", "hybrid")
    out = [prompts]
    if recurrent or arch.module == "lm":
        # feed prompt through decode steps (lm could use prefill; the
        # uniform path keeps this reference loop simple)
        tok = None
        for t in range(s0):
            logits, cache = decode_fn(params, prompts[:, t:t + 1], cache,
                                      jnp.int32(t))
        tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        pos = s0
    else:  # encdec: encode once, then decode from BOS
        raise NotImplementedError(
            "encdec generation uses examples/serve_encdec.py")

    new = [tok]
    for i in range(n_new - 1):
        logits, cache = decode_fn(params, tok, cache, jnp.int32(pos))
        tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        new.append(tok)
        pos += 1
    return jnp.concatenate(out + new, axis=1)
