"""Serving fleet: async program server + executor workers over the wire.

The production-shaped tier above ``serve/engine.py``: a
:class:`FleetServer` registers N executor workers (golden or pallas,
in-process threads or subprocesses — both speak the same
length-prefixed socket protocol, ``serve/protocol.py``), ships each
worker the compiled decode program image byte-for-byte from the
``launch/serve.py`` :class:`ProgramCache` plus its weight arrays, and
multiplexes many concurrent requests over decode-resident
``ExecutorSession`` slots:

* **continuous batching** — each worker hosts a ``batch``-slot
  per-slot decode session (``DecodeSession.step_slots``); new requests
  are admitted into free slots at step boundaries without draining the
  in-flight batch. Slot math is per-row bit-exact, so every request's
  tokens match a dedicated single-request session — the fleet's hard
  correctness gate.
* **serial dispatch** — the no-batching baseline (one request in
  service fleet-wide at a time, slot 0 only); the traffic generator's
  hard assert is that continuous beats this on requests/sec.
* **per-tenant admission** — :class:`TenantPolicy` caps a tenant's
  in-flight requests and the distinct compiled programs it may pin in
  the shared ``PROGRAM_CACHE``; violations raise
  :class:`AdmissionError` at submit time.
* **failure containment** — a crashed worker or a step timeout fails
  that worker's in-flight requests (:class:`RequestFailed`) and drops
  the worker; the server and the other workers keep serving.

:class:`BundleFleet` is the multi-device sibling: it splits an
``N3HBUND1`` image into its per-device ``N3HPROG1`` sections
byte-for-byte, ships one section per worker, shards full-layer weights
onto the owners, and drives the bundle's ``*.xdev`` channel hand-shake
over real transport (``chan`` frames carry the boundary activations,
named by the bundle's channel-edge table).

CLI: ``python -m repro.serve.fleet --worker --connect HOST:PORT --id
W --backend golden`` is the worker entry (what subprocess mode
spawns); ``python -m repro.serve.fleet --demo`` runs a tiny
self-contained fleet.
"""
from __future__ import annotations

import argparse
import asyncio
import collections
import concurrent.futures
import dataclasses
import os
import socket
import subprocess
import sys
import threading
import time

import numpy as np

from repro.obs import METRICS
from repro.serve.protocol import (
    FrameStream,
    ProtocolError,
    pack_arrays,
    read_frame,
    split_bundle_image,
    unpack_arrays,
    write_frame,
)


class FleetError(RuntimeError):
    """Base class for serving-fleet failures."""


class RequestFailed(FleetError):
    """A request could not be completed (worker crash, step timeout,
    or no live workers); surfaced on the request's future."""


class AdmissionError(FleetError):
    """Per-tenant admission rejected the request (in-flight or
    program-cache budget exceeded)."""


@dataclasses.dataclass(frozen=True)
class TenantPolicy:
    """Admission budget for one tenant: concurrent in-flight requests
    and distinct compiled programs pinned in the shared cache."""
    max_inflight: int = 64
    max_programs: int = 4


@dataclasses.dataclass
class _Request:
    rid: int
    tenant: str
    prompt: np.ndarray          # [s0] int32
    n_new: int
    future: concurrent.futures.Future
    submitted_at: float


class _Slot:
    """Per-slot decode state machine mirroring
    ``engine.greedy_generate_compiled``: feed prompt tokens one per
    step, then greedy-feed the argmax back; the request is done after
    ``s0 + n_new - 1`` steps with ``n_new`` collected tokens."""

    def __init__(self, req: _Request):
        self.req = req
        self.fed = 0
        self.pos = 0
        self.out: list[int] = []

    def next_token(self) -> int:
        if self.fed < len(self.req.prompt):
            return int(self.req.prompt[self.fed])
        return self.out[-1]

    def advance(self, argmax_tok: int) -> None:
        self.fed += 1
        self.pos += 1
        if self.fed >= len(self.req.prompt):
            self.out.append(int(argmax_tok))

    @property
    def done(self) -> bool:
        return len(self.out) >= self.req.n_new


class _Worker:
    """Server-side view of one registered worker connection."""

    def __init__(self, wid: str, backend: str, reader, writer):
        self.id = wid
        self.backend = backend
        self.reader = reader
        self.writer = writer
        self.alive = True
        self.ready = False
        self._seq = 0
        self.waiters: dict[int, asyncio.Future] = {}

    def next_seq(self) -> int:
        self._seq += 1
        return self._seq


class FleetServer:
    """Async program server for decode-resident serving.

    ``workers`` is a list of ``(worker_id, backend, mode)`` triples
    with ``mode`` in ``{"thread", "subprocess"}``. All workers serve
    the same compiled decode program (``batch_slots`` per-slot batch,
    ``max_seq`` cache window) shipped from the launcher's
    ``ProgramCache`` image.
    """

    def __init__(self, arch: str, workers, *, batch_slots: int = 4,
                 max_seq: int = 16, bits_w: int = 4, bits_a: int = 4,
                 opt_level: int = 1, seed: int = 0,
                 policy: str = "continuous", step_timeout_s: float = 120.0,
                 load_timeout_s: float = 300.0,
                 heartbeat_s: float = 10.0,
                 tenants: dict[str, TenantPolicy] | None = None,
                 default_tenant_policy: TenantPolicy | None = None):
        if policy not in ("continuous", "serial"):
            raise ValueError(f"unknown scheduling policy {policy!r}")
        self.arch = arch
        self.worker_specs = [tuple(w) for w in workers]
        self.slots = int(batch_slots)
        self.max_seq = int(max_seq)
        self.policy = policy
        self.step_timeout_s = step_timeout_s
        self.load_timeout_s = load_timeout_s
        self.heartbeat_s = heartbeat_s
        self.seed = seed
        self._tenants = dict(tenants or {})
        self._default_policy = default_tenant_policy or TenantPolicy()
        self._tenant_lock = threading.Lock()
        self._tenant_inflight: dict[str, int] = {}
        self._tenant_programs: dict[str, set] = {}

        from repro.launch.serve import ProgramKey, compiled_program_image
        self.key = ProgramKey(arch=arch, bits_w=bits_w, bits_a=bits_a,
                              opt_level=opt_level, mode="decode",
                              batch=self.slots, max_seq=self.max_seq)
        self._image = compiled_program_image(self.key)
        from repro.compiler import asm
        prog = asm.from_binary(self._image)
        from repro.compiler.runtime.session import synthetic_decode_arrays
        self.spec = prog.step
        self._weights = pack_arrays(
            synthetic_decode_arrays(prog.layers, prog.step, seed))

        self._loop: asyncio.AbstractEventLoop | None = None
        self._thread: threading.Thread | None = None
        self._server: asyncio.AbstractServer | None = None
        self._running = False
        self.port: int | None = None
        self._workers: dict[str, _Worker] = {}
        self._queue: collections.deque[_Request] = collections.deque()
        self._work_event: asyncio.Event | None = None
        self._serial_lock: asyncio.Lock | None = None
        self._registered: dict[str, concurrent.futures.Future] = {}
        self._rid = 0
        self.threads: dict[str, threading.Thread] = {}
        self.processes: dict[str, subprocess.Popen] = {}

    # -- lifecycle -----------------------------------------------------------

    def start(self) -> "FleetServer":
        """Start the event loop + listener, spawn the worker roster,
        and block until every worker has registered and loaded its
        program image (or raise :class:`FleetError`)."""
        self._running = True
        started = concurrent.futures.Future()
        self._thread = threading.Thread(
            target=self._loop_main, args=(started,), daemon=True,
            name="fleet-server")
        self._thread.start()
        self.port = started.result(timeout=30)
        for wid, backend, mode in self.worker_specs:
            self._registered[wid] = concurrent.futures.Future()
            self._spawn_worker(wid, backend, mode)
        for wid, fut in self._registered.items():
            try:
                fut.result(timeout=self.load_timeout_s)
            except concurrent.futures.TimeoutError:
                self.stop()
                raise FleetError(
                    f"worker {wid} did not register within "
                    f"{self.load_timeout_s}s") from None
        return self

    def _loop_main(self, started: concurrent.futures.Future) -> None:
        loop = asyncio.new_event_loop()
        asyncio.set_event_loop(loop)
        self._loop = loop
        self._work_event = asyncio.Event()
        self._serial_lock = asyncio.Lock()

        async def _boot():
            self._server = await asyncio.start_server(
                self._handle_conn, "127.0.0.1", 0)
            return self._server.sockets[0].getsockname()[1]

        try:
            port = loop.run_until_complete(_boot())
        except Exception as e:              # pragma: no cover - boot failure
            started.set_exception(e)
            return
        started.set_result(port)
        loop.create_task(self._heartbeat_task())
        try:
            loop.run_forever()
        finally:
            tasks = asyncio.all_tasks(loop)
            for task in tasks:
                task.cancel()
            if tasks:
                loop.run_until_complete(
                    asyncio.gather(*tasks, return_exceptions=True))
            loop.run_until_complete(loop.shutdown_asyncgens())
            loop.close()

    def _spawn_worker(self, wid: str, backend: str, mode: str) -> None:
        if mode == "thread":
            t = threading.Thread(
                target=_worker_entry,
                args=("127.0.0.1", self.port, wid, backend),
                daemon=True, name=f"fleet-worker-{wid}")
            t.start()
            self.threads[wid] = t
        elif mode == "subprocess":
            env = dict(os.environ)
            src = os.path.dirname(os.path.dirname(os.path.dirname(
                os.path.abspath(__file__))))
            env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
            self.processes[wid] = subprocess.Popen(
                [sys.executable, "-m", "repro.serve.fleet", "--worker",
                 "--connect", f"127.0.0.1:{self.port}", "--id", wid,
                 "--backend", backend], env=env)
        else:
            raise ValueError(f"unknown worker mode {mode!r}")

    def stop(self) -> None:
        """Shut the fleet down: stop scheduling, close worker
        connections, stop the loop, reap subprocesses."""
        if not self._running:
            return
        self._running = False
        loop = self._loop
        if loop is not None and loop.is_running():
            async def _shutdown():
                for w in list(self._workers.values()):
                    if w.alive:
                        try:
                            write_frame(w.writer, "shutdown",
                                        {"seq": w.next_seq()})
                            await w.writer.drain()
                        except (ConnectionError, OSError):
                            pass
                        w.writer.close()
                if self._server is not None:
                    self._server.close()
                loop.stop()
            asyncio.run_coroutine_threadsafe(_shutdown(), loop)
        if self._thread is not None:
            self._thread.join(timeout=10)
        for proc in self.processes.values():
            try:
                proc.wait(timeout=5)
            except subprocess.TimeoutExpired:
                proc.kill()
                proc.wait(timeout=5)
        for req in list(self._queue):
            self._fail(req, RequestFailed("fleet stopped"))
        self._queue.clear()

    def __enter__(self) -> "FleetServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # -- connection handling -------------------------------------------------

    async def _handle_conn(self, reader, writer) -> None:
        try:
            kind, hdr, _ = await read_frame(reader)
        except ProtocolError:
            writer.close()
            return
        if kind != "hello":
            writer.close()
            return
        w = _Worker(hdr.get("worker", "?"), hdr.get("backend", "?"),
                    reader, writer)
        self._workers[w.id] = w
        METRICS.incr("serve.fleet.workers.registered")
        METRICS.gauge("serve.fleet.workers", self._live_count())
        asyncio.get_running_loop().create_task(self._reader_task(w))
        try:
            await self._rpc(w, "load_program", {"per_slot": True},
                            self._image, timeout=self.load_timeout_s)
            await self._rpc(w, "bind_arrays", {}, self._weights,
                            timeout=self.load_timeout_s)
        except FleetError as e:
            self._drop_worker(w, e)
            return
        w.ready = True
        reg = self._registered.get(w.id)
        if reg is not None and not reg.done():
            reg.set_result(w.id)
        asyncio.get_running_loop().create_task(self._worker_loop(w))

    async def _reader_task(self, w: _Worker) -> None:
        try:
            while w.alive:
                kind, hdr, payload = await read_frame(w.reader)
                fut = w.waiters.pop(hdr.get("seq"), None)
                if fut is not None and not fut.done():
                    fut.set_result((kind, hdr, payload))
        except ProtocolError as e:
            self._drop_worker(w, RequestFailed(
                f"worker {w.id} connection lost: {e}"))

    def _drop_worker(self, w: _Worker, exc: Exception) -> None:
        if not w.alive:
            return
        w.alive = False
        w.ready = False
        for fut in list(w.waiters.values()):
            if not fut.done():
                fut.set_exception(RequestFailed(str(exc)))
        w.waiters.clear()
        try:
            w.writer.close()
        except (ConnectionError, OSError):
            pass
        METRICS.incr("serve.fleet.workers.dropped")
        METRICS.gauge("serve.fleet.workers", self._live_count())

    def _live_count(self) -> int:
        return sum(1 for w in self._workers.values() if w.alive)

    def live_workers(self) -> list[str]:
        return sorted(w.id for w in self._workers.values()
                      if w.alive and w.ready)

    # -- RPC -----------------------------------------------------------------

    async def _rpc(self, w: _Worker, kind: str, header: dict,
                   payload: bytes = b"",
                   timeout: float | None = None):
        if not w.alive:
            raise RequestFailed(f"worker {w.id} is dead")
        seq = w.next_seq()
        hdr = dict(header, seq=seq)
        fut = asyncio.get_running_loop().create_future()
        w.waiters[seq] = fut
        try:
            write_frame(w.writer, kind, hdr, payload)
            await w.writer.drain()
            rkind, rhdr, rpayload = await asyncio.wait_for(
                fut, timeout if timeout is not None
                else self.step_timeout_s)
        except asyncio.TimeoutError:
            raise RequestFailed(
                f"worker {w.id} {kind} timed out after "
                f"{timeout if timeout is not None else self.step_timeout_s}"
                f"s") from None
        except (ConnectionError, OSError) as e:
            raise RequestFailed(f"worker {w.id} send failed: {e}") from e
        finally:
            w.waiters.pop(seq, None)
        if rkind == "error":
            raise RequestFailed(
                f"worker {w.id}: {rhdr.get('message', 'remote error')}")
        return rhdr, rpayload

    async def _ping(self, w: _Worker) -> float:
        t0 = time.perf_counter()
        await self._rpc(w, "ping", {}, timeout=self.step_timeout_s)
        METRICS.incr("serve.fleet.heartbeats")
        return time.perf_counter() - t0

    def ping(self, worker_id: str) -> float:
        """Synchronous heartbeat to one worker; returns RTT seconds."""
        w = self._workers.get(worker_id)
        if w is None or not w.alive:
            raise RequestFailed(f"worker {worker_id} is not live")
        return asyncio.run_coroutine_threadsafe(
            self._ping(w), self._loop).result(self.step_timeout_s + 5)

    async def _heartbeat_task(self) -> None:
        while self._running:
            await asyncio.sleep(self.heartbeat_s)
            for w in list(self._workers.values()):
                if not (w.alive and w.ready):
                    continue
                try:
                    await self._ping(w)
                except FleetError as e:
                    self._drop_worker(w, e)

    # -- admission + submission ----------------------------------------------

    def tenant_policy(self, tenant: str) -> TenantPolicy:
        return self._tenants.get(tenant, self._default_policy)

    def admit_program(self, tenant: str, key) -> None:
        """Count ``key`` against the tenant's program-cache budget
        (and warm it in the shared cache); raises
        :class:`AdmissionError` over budget."""
        policy = self.tenant_policy(tenant)
        with self._tenant_lock:
            progs = self._tenant_programs.setdefault(tenant, set())
            if key not in progs and len(progs) >= policy.max_programs:
                METRICS.incr("serve.fleet.admission.rejected")
                raise AdmissionError(
                    f"tenant {tenant!r} exceeds its program budget "
                    f"({policy.max_programs})")
            progs.add(key)
        from repro.launch.serve import compiled_program_image
        compiled_program_image(key)

    def submit(self, prompt, n_new: int, tenant: str = "default"
               ) -> concurrent.futures.Future:
        """Enqueue one request; the future resolves to the full token
        row ``[s0 + n_new] int32`` (prompt + greedy continuation,
        matching ``engine.greedy_generate_compiled``) or raises
        :class:`RequestFailed` / :class:`AdmissionError`."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if prompt.size < 1 or n_new < 1:
            raise ValueError("need a non-empty prompt and n_new >= 1")
        if prompt.size + n_new > self.max_seq:
            raise ValueError(
                f"{prompt.size} prompt + {n_new} new tokens exceed the "
                f"fleet's max_seq={self.max_seq}")
        if not self.live_workers():
            METRICS.incr("serve.fleet.requests.failed")
            raise RequestFailed("no live workers")
        self.admit_program(tenant, self.key)
        policy = self.tenant_policy(tenant)
        with self._tenant_lock:
            if self._tenant_inflight.get(tenant, 0) >= policy.max_inflight:
                METRICS.incr("serve.fleet.admission.rejected")
                raise AdmissionError(
                    f"tenant {tenant!r} exceeds its in-flight budget "
                    f"({policy.max_inflight})")
            self._tenant_inflight[tenant] = \
                self._tenant_inflight.get(tenant, 0) + 1
        fut: concurrent.futures.Future = concurrent.futures.Future()
        with self._tenant_lock:
            self._rid += 1
            req = _Request(self._rid, tenant, prompt, int(n_new), fut,
                           time.perf_counter())
        METRICS.incr("serve.fleet.requests.submitted")
        self._loop.call_soon_threadsafe(self._enqueue, req)
        return fut

    def _enqueue(self, req: _Request) -> None:
        self._queue.append(req)
        self._work_event.set()

    def _finish(self, req: _Request, tokens: np.ndarray) -> None:
        with self._tenant_lock:
            self._tenant_inflight[req.tenant] = max(
                0, self._tenant_inflight.get(req.tenant, 1) - 1)
        METRICS.incr("serve.fleet.requests.completed")
        METRICS.observe(
            "serve.fleet.request_ms",
            (time.perf_counter() - req.submitted_at) * 1e3)
        if not req.future.done():
            req.future.set_result(tokens)

    def _fail(self, req: _Request, exc: Exception) -> None:
        with self._tenant_lock:
            self._tenant_inflight[req.tenant] = max(
                0, self._tenant_inflight.get(req.tenant, 1) - 1)
        METRICS.incr("serve.fleet.requests.failed")
        if not req.future.done():
            req.future.set_exception(
                exc if isinstance(exc, FleetError)
                else RequestFailed(str(exc)))

    # -- scheduling ----------------------------------------------------------

    async def _wait_for_work(self) -> None:
        self._work_event.clear()
        if self._queue:
            return
        try:
            await asyncio.wait_for(self._work_event.wait(), 0.05)
        except asyncio.TimeoutError:
            pass

    async def _worker_loop(self, w: _Worker) -> None:
        slots: list[_Slot | None] = [None] * self.slots
        try:
            while self._running and w.alive:
                if self.policy == "serial":
                    if not self._queue:
                        await self._wait_for_work()
                        continue
                    req = self._queue.popleft()
                    async with self._serial_lock:
                        await self._serve_serial(w, req)
                    continue
                for j in range(self.slots):
                    if slots[j] is None and self._queue:
                        # claim the slot before the reset RPC so a
                        # worker failure mid-admission fails the
                        # request instead of losing it
                        slots[j] = _Slot(self._queue.popleft())
                        await self._rpc(w, "reset_slot", {"slot": j})
                        METRICS.incr("serve.fleet.admitted")
                if not any(slots):
                    await self._wait_for_work()
                    continue
                logits = await self._step(
                    w,
                    [s.next_token() if s else 0 for s in slots],
                    [s.pos if s else 0 for s in slots])
                for j, s in enumerate(slots):
                    if s is None:
                        continue
                    s.advance(int(np.argmax(logits[j])))
                    if s.done:
                        self._finish(s.req, np.concatenate(
                            [s.req.prompt,
                             np.asarray(s.out, np.int32)]))
                        slots[j] = None
        except (FleetError, ProtocolError) as e:
            for s in slots:
                if s is not None:
                    self._fail(s.req, e)
            self._drop_worker(w, e)

    async def _serve_serial(self, w: _Worker, req: _Request) -> None:
        """The baseline: one request alone on slot 0, run to
        completion before the fleet admits the next."""
        try:
            await self._rpc(w, "reset_slot", {"slot": 0})
            slot = _Slot(req)
            while not slot.done:
                logits = await self._step(
                    w, [slot.next_token()] + [0] * (self.slots - 1),
                    [slot.pos] + [0] * (self.slots - 1))
                slot.advance(int(np.argmax(logits[0])))
            self._finish(req, np.concatenate(
                [req.prompt, np.asarray(slot.out, np.int32)]))
        except (FleetError, ProtocolError) as e:
            self._fail(req, e)
            raise

    async def _step(self, w: _Worker, tokens: list[int],
                    pos: list[int]) -> np.ndarray:
        t0 = time.perf_counter()
        _, payload = await self._rpc(
            w, "step", {"tokens": tokens, "pos": pos},
            timeout=self.step_timeout_s)
        dt = time.perf_counter() - t0
        METRICS.observe(f"serve.fleet.worker.{w.id}.busy_ms", dt * 1e3)
        METRICS.incr("serve.fleet.steps")
        return unpack_arrays(payload)["logits"]


# ---------------------------------------------------------------------------
# Worker side
# ---------------------------------------------------------------------------


def _worker_entry(host: str, port: int, worker_id: str,
                  backend: str) -> None:
    """Worker main: connect back to the server and serve frames until
    shutdown. Runs identically as an in-process thread or a
    subprocess (``--worker`` CLI) — same socket, same frames."""
    from repro.compiler import asm
    from repro.compiler.runtime import (ExecutorSession, get_backend,
                                        requantize)

    sock = socket.create_connection((host, port))
    sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
    fs = FrameStream(sock)
    fs.send("hello", {"worker": worker_id, "backend": backend,
                      "pid": os.getpid()})
    session = None
    executor = None
    chans: dict[str, np.ndarray] = {}
    prev_out = None
    try:
        while True:
            kind, hdr, payload = fs.recv()
            seq = hdr.get("seq")
            try:
                if kind == "ping":
                    fs.send("pong", {"seq": seq})
                elif kind == "shutdown":
                    break
                elif kind == "load_program":
                    prog = asm.from_binary(payload)
                    session = ExecutorSession(prog, backend=backend)
                    session.reset(per_slot=bool(hdr.get("per_slot", True)))
                    fs.send("ready", {"seq": seq})
                elif kind == "load_section":
                    prog = asm.from_binary(payload)
                    executor = get_backend(backend)(prog)
                    fs.send("ready", {"seq": seq})
                elif kind == "bind_arrays":
                    arrays = unpack_arrays(payload)
                    if session is not None:
                        session.bind_arrays(arrays)
                    else:
                        for li in sorted({int(k.split(".")[0][1:])
                                          for k in arrays}):
                            executor.bind_layer(
                                li,
                                w_lut=arrays.get(f"L{li}.w_lut"),
                                s_lut=arrays.get(f"L{li}.s_lut"),
                                w_dsp=arrays.get(f"L{li}.w_dsp"),
                                s_dsp=arrays.get(f"L{li}.s_dsp"))
                    fs.send("ready", {"seq": seq})
                elif kind == "step":
                    logits = session.step_slots(hdr["tokens"], hdr["pos"])
                    fs.send("result", {"seq": seq},
                            pack_arrays({"logits": np.asarray(logits)}))
                elif kind == "reset_slot":
                    session.reset_slot(int(hdr["slot"]))
                    fs.send("ready", {"seq": seq})
                elif kind == "chan":
                    chans[hdr["channel"]] = unpack_arrays(payload)["x"]
                    fs.send("ready", {"seq": seq})
                elif kind == "run_layer":
                    if hdr.get("in_chan"):
                        x = chans.pop(hdr["in_chan"])
                    else:
                        # intra-stage chaining: requantize the held
                        # activation exactly like runtime.chain_layers
                        x = requantize(prev_out, int(hdr["requant_bits"]))
                    prev_out = executor.run_layer(int(hdr["layer"]), x)
                    if hdr.get("return_out"):
                        fs.send("result", {"seq": seq},
                                pack_arrays({"x": np.asarray(prev_out)}))
                    else:
                        fs.send("ready", {"seq": seq})
                else:
                    fs.send("error", {"seq": seq,
                                      "message": f"unexpected {kind}"})
            except Exception as e:  # surfaced server-side as RequestFailed
                fs.send("error", {"seq": seq,
                                  "message": f"{type(e).__name__}: {e}"})
    except ProtocolError:
        pass  # server went away
    finally:
        fs.close()


# ---------------------------------------------------------------------------
# Bundle fleet: one worker per device section, xdev hand-shake on the wire
# ---------------------------------------------------------------------------


class BundleFleet:
    """Distribute an ``N3HBUND1`` bundle across per-device workers.

    The server splits the cached bundle image into per-device
    ``N3HPROG1`` sections byte-for-byte, ships one section per worker,
    shards full-layer weights onto the owners (same column math as
    ``MultiDeviceExecutor.bind_layer``), and drives the chain with the
    bundle's ``*.xdev`` channel hand-shake over the socket: boundary
    activations travel as ``chan`` frames named by the channel-edge
    table, intra-stage layers chain locally on the worker.
    ``run(x)`` is bit-exact vs ``MultiDeviceExecutor.run`` on the same
    bundle (FC programs).
    """

    def __init__(self, image: bytes, *, backends=None,
                 worker_mode: str = "thread", seed: int | None = 0,
                 timeout_s: float = 300.0):
        from repro.compiler import asm
        from repro.compiler.runtime.multi import global_layers
        self.meta, self.sections = split_bundle_image(image)
        self.bundle = asm.from_bundle_binary(image)
        self.glayers = global_layers(self.bundle)
        if any(gl.geometry is not None for gl in self.glayers):
            raise FleetError(
                "BundleFleet drives FC bundles; conv bundles run "
                "in-process via MultiDeviceExecutor")
        n = len(self.sections)
        self.backends = list(backends or ["golden"] * n)
        if len(self.backends) != n:
            raise ValueError(
                f"{n}-device bundle needs {n} backends, got "
                f"{len(self.backends)}")
        self.worker_mode = worker_mode
        self.seed = seed
        self.timeout_s = timeout_s
        self._edges_in = {(e.dst_device, e.dst_layer): e
                          for e in self.bundle.edges}
        self._streams: dict[int, FrameStream] = {}
        self._seq = 0
        self._listener: socket.socket | None = None
        self.threads: list[threading.Thread] = []
        self.processes: list[subprocess.Popen] = []

    def start(self) -> "BundleFleet":
        self._listener = socket.socket()
        self._listener.bind(("127.0.0.1", 0))
        self._listener.listen(len(self.sections))
        port = self._listener.getsockname()[1]
        for d, backend in enumerate(self.backends):
            wid = f"dev{d}"
            if self.worker_mode == "thread":
                t = threading.Thread(
                    target=_worker_entry,
                    args=("127.0.0.1", port, wid, backend),
                    daemon=True, name=f"bundle-worker-{wid}")
                t.start()
                self.threads.append(t)
            else:
                env = dict(os.environ)
                src = os.path.dirname(os.path.dirname(os.path.dirname(
                    os.path.abspath(__file__))))
                env["PYTHONPATH"] = (src + os.pathsep
                                     + env.get("PYTHONPATH", ""))
                self.processes.append(subprocess.Popen(
                    [sys.executable, "-m", "repro.serve.fleet",
                     "--worker", "--connect", f"127.0.0.1:{port}",
                     "--id", wid, "--backend", backend], env=env))
        self._listener.settimeout(self.timeout_s)
        for _ in range(len(self.sections)):
            conn, _addr = self._listener.accept()
            conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            fs = FrameStream(conn)
            kind, hdr, _ = fs.recv()
            if kind != "hello":
                raise FleetError(f"expected hello, got {kind}")
            self._streams[int(hdr["worker"][3:])] = fs
        for d, fs in sorted(self._streams.items()):
            self._call(fs, "load_section", {"device": d},
                       self.sections[d])
        self._bind_synthetic()
        return self

    def _call(self, fs: FrameStream, kind: str, header: dict,
              payload: bytes = b"") -> tuple[dict, bytes]:
        self._seq += 1
        fs.send(kind, dict(header, seq=self._seq), payload)
        rkind, rhdr, rpayload = fs.recv()
        if rkind == "error":
            raise FleetError(rhdr.get("message", "remote error"))
        return rhdr, rpayload

    def _bind_synthetic(self) -> None:
        """Full-layer synthetic weights sharded onto the owners —
        identical RNG streams and column split as
        ``MultiDeviceExecutor.bind_synthetic``."""
        from repro.compiler.runtime import synthetic_weights
        per_worker: dict[int, dict] = {d: {} for d in self._streams}
        for gl in self.glayers:
            w_lut, s_lut, w_dsp, s_dsp = synthetic_weights(
                gl.index, gl.dims.k, gl.n_lut, gl.dims.n - gl.n_lut,
                gl.bits_w_lut, self.seed)
            L = gl.n_lut
            w_lut = None if w_lut is None else np.asarray(w_lut)
            s_lut = None if s_lut is None else np.asarray(s_lut).reshape(-1)
            w_dsp = None if w_dsp is None else np.asarray(w_dsp)
            s_dsp = None if s_dsp is None else np.asarray(s_dsp).reshape(-1)
            for d, li, lo, hi in gl.placements:
                l0, l1 = min(lo, L), min(hi, L)
                d0, d1 = max(lo, L) - L, max(hi, L) - L
                shard = per_worker[d]
                if l1 > l0:
                    shard[f"L{li}.w_lut"] = w_lut[:, l0:l1]
                    shard[f"L{li}.s_lut"] = s_lut[l0:l1]
                if d1 > d0:
                    shard[f"L{li}.w_dsp"] = w_dsp[:, d0:d1]
                    shard[f"L{li}.s_dsp"] = s_dsp[d0:d1]
        for d, arrays in sorted(per_worker.items()):
            self._call(self._streams[d], "bind_arrays", {},
                       pack_arrays(arrays))

    def _chan_name(self, gl, d: int, li: int) -> str:
        edge = self._edges_in.get((d, li))
        suffix = edge.dst_channel if edge is not None else "in"
        return f"L{gl.index}.{suffix}"

    def run(self, x_q) -> np.ndarray:
        """Run the full chain over the fleet; returns the final fp32
        output (bit-exact vs the in-process bundle executor)."""
        from repro.compiler.runtime import requantize
        x = np.asarray(x_q, np.int8)
        prev_d: int | None = None
        out = None
        n = len(self.glayers)
        for gi, gl in enumerate(self.glayers):
            placements = [p for p in gl.placements if p[3] > p[2]]
            if out is not None:
                # server-side inter-layer requant (chain_layers rule)
                x = np.asarray(requantize(out, gl.bits_a))
            if len(placements) == 1:
                d, li, _lo, _hi = placements[0]
                local = prev_d == d and out is None
                nxt_own = (self.glayers[gi + 1].placements
                           if gi + 1 < n else None)
                boundary = (gi == n - 1 or nxt_own is None
                            or len(nxt_own) != 1 or nxt_own[0][0] != d)
                fs = self._streams[d]
                hdr = {"layer": li, "return_out": boundary}
                if local:
                    hdr["requant_bits"] = gl.bits_a
                else:
                    chan = self._chan_name(gl, d, li)
                    self._call(fs, "chan", {"channel": chan},
                               pack_arrays({"x": x}))
                    hdr["in_chan"] = chan
                _, payload = self._call(fs, "run_layer", hdr)
                out = (unpack_arrays(payload)["x"] if boundary else None)
                prev_d = d
            else:
                # filter shards: scatter the activation, gather the
                # column shards in device order (the gather core role)
                shards = []
                for d, li, _lo, _hi in placements:
                    chan = self._chan_name(gl, d, li)
                    self._call(self._streams[d], "chan",
                               {"channel": chan}, pack_arrays({"x": x}))
                    _, payload = self._call(
                        self._streams[d], "run_layer",
                        {"layer": li, "in_chan": chan,
                         "return_out": True})
                    shards.append(unpack_arrays(payload)["x"])
                out = np.concatenate(shards, axis=1)
                prev_d = None
        return out

    def stop(self) -> None:
        for fs in self._streams.values():
            try:
                self._seq += 1
                fs.send("shutdown", {"seq": self._seq})
            except (ProtocolError, OSError):
                pass
            fs.close()
        if self._listener is not None:
            self._listener.close()
        for t in self.threads:
            t.join(timeout=10)
        for p in self.processes:
            try:
                p.wait(timeout=5)
            except subprocess.TimeoutExpired:
                p.kill()

    def __enter__(self) -> "BundleFleet":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(
        description="serving-fleet worker / demo entry")
    ap.add_argument("--worker", action="store_true",
                    help="run as a fleet worker (connect back to the "
                         "server)")
    ap.add_argument("--connect", default=None, metavar="HOST:PORT")
    ap.add_argument("--id", default="w0")
    ap.add_argument("--backend", default="golden",
                    choices=("golden", "pallas"))
    ap.add_argument("--demo", action="store_true",
                    help="run a tiny 2-worker fleet end to end")
    ap.add_argument("--arch", default="llama3.2-1b")
    args = ap.parse_args(argv)

    if args.worker:
        if not args.connect:
            raise SystemExit("--worker needs --connect HOST:PORT")
        host, port = args.connect.rsplit(":", 1)
        _worker_entry(host, int(port), args.id, args.backend)
        return

    if args.demo:
        with FleetServer(args.arch,
                         [("w0", "golden", "thread"),
                          ("w1", "golden", "thread")],
                         batch_slots=2, max_seq=8) as fleet:
            futs = [fleet.submit([3, 11], 3) for _ in range(4)]
            for i, f in enumerate(futs):
                print(f"request {i}: {f.result(300).tolist()}")
        return

    ap.print_help()


if __name__ == "__main__":
    main()
