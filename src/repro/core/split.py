"""Neuron-based workload split — paper §5.3, Eqs. (11)-(12).

For layer i with quantization (B_i^{w-L}, B_i^a) fixed by the agent, the
split ratio is chosen to minimize the layer's makespan:

    argmin_ratio max( L_LUT(..., ratio), L_DSP(..., ratio) )

L_LUT is nondecreasing and L_DSP nonincreasing in the number of LUT
filters, so the minimum sits where the two curves cross; we solve it
*exactly* by evaluating the vectorized closed-form over every feasible
integer filter count (c_out <= a few thousand for all workloads), which
is both faster and more robust than bisection on the stepwise curves.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.latency_model import dsp_core_latency, lut_core_latency
from repro.core.scheduler import DspCoreConfig, FPGADevice, LutCoreConfig
from repro.core.workloads import ConvSpec


@dataclasses.dataclass(frozen=True)
class SplitResult:
    n_lut: int
    ratio: float
    cycles: float
    cycles_lut: float
    cycles_dsp: float
    curve: np.ndarray | None = None   # makespan per candidate (for Fig. 7)


def split_curves(g, depthwise: bool, lut_cfg: LutCoreConfig,
                 dsp_cfg: DspCoreConfig, dev: FPGADevice,
                 bits_w_lut: int, bits_a: int
                 ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Per-candidate (c_lut, c_dsp, makespan) curves over n_lut in
    {0..n} — the Eq.-(12) inner terms on raw GEMM dims. Shared by this
    module's ConvSpec-facing solver and the compiler's lowering pass."""
    cand = np.arange(0, g.n + 1, dtype=np.float64)
    c_lut = lut_core_latency(g.m, g.k, cand, lut_cfg, dev,
                             bits_w_lut, bits_a, depthwise)
    c_dsp = dsp_core_latency(g.m, g.k, g.n - cand, dsp_cfg, dev, depthwise)
    return c_lut, c_dsp, np.maximum(c_lut, c_dsp)


def solve_split(spec: ConvSpec, lut_cfg: LutCoreConfig, dsp_cfg: DspCoreConfig,
                dev: FPGADevice, bits_w_lut: int, bits_a: int,
                keep_curve: bool = False) -> SplitResult:
    """Exact Eq.-(12) solver over n_lut in {0..c_out}."""
    g = spec.gemm()
    c_lut, c_dsp, makespan = split_curves(g, spec.depthwise, lut_cfg,
                                          dsp_cfg, dev, bits_w_lut, bits_a)
    best = int(np.argmin(makespan))
    return SplitResult(
        n_lut=best,
        ratio=best / max(g.n, 1),
        cycles=float(makespan[best]),
        cycles_lut=float(c_lut[best]),
        cycles_dsp=float(c_dsp[best]),
        curve=makespan if keep_curve else None,
    )


def solve_network_splits(specs: list[ConvSpec], lut_cfg: LutCoreConfig,
                         dsp_cfg: DspCoreConfig, dev: FPGADevice,
                         bits_w_lut: list[int], bits_a: list[int]
                         ) -> list[SplitResult]:
    return [solve_split(s, lut_cfg, dsp_cfg, dev, bw, ba)
            for s, bw, ba in zip(specs, bits_w_lut, bits_a)]


def brute_force_split(spec: ConvSpec, lut_cfg: LutCoreConfig,
                      dsp_cfg: DspCoreConfig, dev: FPGADevice,
                      bits_w_lut: int, bits_a: int) -> SplitResult:
    """Reference scalar-loop solver (used by property tests to pin the
    vectorized path)."""
    g = spec.gemm()
    best_n, best_c = 0, float("inf")
    best_l = best_d = 0.0
    for n in range(g.n + 1):
        cl = float(lut_core_latency(g.m, g.k, n, lut_cfg, dev,
                                    bits_w_lut, bits_a, spec.depthwise))
        cd = float(dsp_core_latency(g.m, g.k, g.n - n, dsp_cfg, dev,
                                    spec.depthwise))
        c = max(cl, cd)
        if c < best_c:
            best_n, best_c, best_l, best_d = n, c, cl, cd
    return SplitResult(best_n, best_n / max(g.n, 1), best_c, best_l, best_d)
