"""TPU-side cost model — the hardware-adaptation of Eqs. (6)-(10).

The paper's framework "can adapt to different [hardware] by changing the
available resources in the cost model" (§7). Here the resource pool is a
TPU v5e chip instead of a Zynq FPGA:

  * bit-parallel path (the DSP-core analogue): packed-int4 weights fed
    to the MXU's int8 pipeline; latency independent of weight bit-width.
  * bitplane path (the LUT-core analogue): weights decomposed into
    ``B_w`` binary planes, one int8 MXU matmul per plane, shifted and
    accumulated (paper Eq. 1); latency proportional to ``B_w`` (and to
    ``B_w * B_a`` if activations are also serialized, the faithful FPGA
    composition).

Each path's latency is a two-term roofline max(compute, memory); paths
compose *temporally* (sum — both time-share the single MXU) or
*spatially* (max — the partitions are placed on disjoint mesh sub-axes,
restoring the paper's Eq. 10 form at the cluster level).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class TPUChip:
    """TPU v5e-class constants (task-specified)."""
    name: str = "tpu-v5e"
    bf16_flops: float = 197e12        # MXU bf16 FLOP/s
    int8_ops: float = 394e12          # MXU int8 OP/s (2x bf16)
    hbm_bw: float = 819e9             # bytes/s
    ici_bw: float = 50e9              # bytes/s per link
    vmem_bytes: int = 128 * 2 ** 20   # ~128 MiB VMEM
    mxu_dim: int = 128


V5E = TPUChip()


@dataclasses.dataclass(frozen=True)
class HeteroGemmCost:
    t_parallel: float        # int4/MXU path seconds
    t_bitplane: float        # bitplane path seconds
    t_temporal: float        # sum (single-core time sharing)
    t_spatial: float         # max (disjoint sub-mesh placement)
    bytes_weights: float
    bytes_act: float
    flops: float


def _roofline(flops, op_rate, bytes_moved, bw):
    return np.maximum(flops / op_rate, bytes_moved / bw)


def hetero_gemm_cost(m, k, n, ratio, bits_w_serial, bits_a,
                     chip: TPUChip = V5E, serialize_activations: bool = False,
                     bits_w_parallel: int = 4):
    """Cost of out[m,n] = act[m,k] @ w[k,n] split column-wise by ``ratio``.

    ``ratio`` of the n columns take the bitplane (flexible-precision)
    path; the rest take the packed-int4 path. All inputs may be numpy
    arrays (vectorized for the DSE loops).
    """
    m, k, n = np.asarray(m, np.float64), np.asarray(k, np.float64), np.asarray(n, np.float64)
    ratio = np.asarray(ratio, np.float64)
    bits_w_serial = np.asarray(bits_w_serial, np.float64)
    bits_a = np.asarray(bits_a, np.float64)

    n_serial = np.round(n * ratio)
    n_par = n - n_serial

    # --- bit-parallel path: one int8 matmul over n_par columns
    flops_par = 2.0 * m * k * n_par
    bytes_w_par = k * n_par * bits_w_parallel / 8.0
    bytes_a_par = m * k * 1.0            # int8 activations
    bytes_o_par = m * n_par * 4.0        # int32 accumulators out
    t_par = _roofline(flops_par, chip.int8_ops,
                      bytes_w_par + bytes_a_par + bytes_o_par, chip.hbm_bw)

    # --- bitplane path: B_w (x B_a) binary-plane matmuls
    planes = bits_w_serial * np.where(serialize_activations, bits_a, 1.0)
    flops_ser = 2.0 * m * k * n_serial * planes
    bytes_w_ser = k * n_serial * bits_w_serial / 8.0   # planes are 1-bit each
    bytes_a_ser = m * k * np.where(serialize_activations, bits_a / 8.0, 1.0)
    bytes_o_ser = m * n_serial * 4.0
    t_ser = _roofline(flops_ser, chip.int8_ops,
                      bytes_w_ser + bytes_a_ser + bytes_o_ser, chip.hbm_bw)

    flops = 2.0 * m * k * n
    return HeteroGemmCost(
        t_parallel=t_par, t_bitplane=t_ser,
        t_temporal=t_par + t_ser,
        t_spatial=np.maximum(t_par, t_ser),
        bytes_weights=bytes_w_par + bytes_w_ser,
        bytes_act=bytes_a_par + bytes_a_ser,
        flops=flops,
    )


def solve_tpu_split(m, k, n, bits_w_serial, bits_a, chip: TPUChip = V5E,
                    spatial: bool = False, serialize_activations: bool = False):
    """TPU analogue of Eq. (12): pick the ratio minimizing the composed
    latency. In temporal mode the optimum is a boundary (whichever path
    is cheaper per column) unless precision constraints force a mix; in
    spatial mode an interior optimum re-emerges exactly as on the FPGA.
    Returns (best_ratio, best_seconds, curve)."""
    cand = np.linspace(0.0, 1.0, int(n) + 1) if n <= 4096 else np.linspace(0, 1, 513)
    cost = hetero_gemm_cost(m, k, n, cand, bits_w_serial, bits_a, chip,
                            serialize_activations)
    curve = cost.t_spatial if spatial else cost.t_temporal
    i = int(np.argmin(curve))
    return float(cand[i]), float(curve[i]), curve


# ---------------------------------------------------------------------------
# Roofline terms for the dry-run analysis (§Roofline)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)


def roofline_terms(hlo_flops: float, hlo_bytes: float, collective_bytes: float,
                   n_chips: int, chip: TPUChip = V5E,
                   flops_dtype: str = "bf16") -> RooflineTerms:
    """Three-term roofline from compiled-HLO statistics.

    compute    = HLO_FLOPs / (chips * peak)
    memory     = HLO_bytes / (chips * HBM_bw)
    collective = collective_bytes / (chips * link_bw)

    ``hlo_flops``/``hlo_bytes`` are totals across chips when the compiled
    computation is SPMD (XLA reports per-program = per-chip numbers; the
    caller says which convention it uses via n_chips=1).
    """
    rate = chip.bf16_flops if flops_dtype == "bf16" else chip.int8_ops
    return RooflineTerms(
        compute_s=hlo_flops / (n_chips * rate),
        memory_s=hlo_bytes / (n_chips * chip.hbm_bw),
        collective_s=collective_bytes / (n_chips * chip.ici_bw),
    )
