"""The paper's primary contribution: heterogeneous-core GEMM co-design.

Layers:
  isa            — the unified 128-bit instruction set (§3.1)
  scheduler      — instruction streams + event-driven pipeline sim (Fig. 3)
  cost_model     — LUT/BRAM/DSP resource models (Eqs. 3-5)
  latency_model  — closed-form + simulated latency (Eqs. 6-10)
  split          — neuron-based workload split solver (Eqs. 11-12)
  workloads      — im2col GEMM lowering of ResNet-18 / MobileNet-V2
  tpu_cost       — the TPU hardware adaptation of the cost model
  hetero_linear  — the TPU HeteroLinear module (split + hybrid quant GEMM)
"""
from repro.core.scheduler import (
    DEVICES,
    XC7Z020,
    XC7Z045,
    DspCoreConfig,
    FPGADevice,
    GemmDims,
    LutCoreConfig,
)
from repro.core.cost_model import ResourceReport, system_cost
from repro.core.latency_model import (
    LayerLatency,
    dsp_core_latency,
    layer_latency,
    lut_core_latency,
    network_latency,
)
from repro.core.split import SplitResult, solve_network_splits, solve_split
from repro.core.tpu_cost import (
    V5E,
    HeteroGemmCost,
    RooflineTerms,
    TPUChip,
    hetero_gemm_cost,
    roofline_terms,
    solve_tpu_split,
)

__all__ = [
    "DEVICES", "XC7Z020", "XC7Z045", "DspCoreConfig", "FPGADevice",
    "GemmDims", "LutCoreConfig", "ResourceReport", "system_cost",
    "LayerLatency", "dsp_core_latency", "layer_latency", "lut_core_latency",
    "network_latency", "SplitResult", "solve_network_splits", "solve_split",
    "V5E", "HeteroGemmCost", "RooflineTerms", "TPUChip", "hetero_gemm_cost",
    "roofline_terms", "solve_tpu_split",
]
