"""HeteroLinear — the paper's heterogeneous-core split GEMM as a module.

A linear layer whose output columns (= the paper's "filters"/neurons)
are split between two execution paths (§3.1 + §5.3):

  * **parallel path** (DSP-core analogue): fixed int4 weights, packed,
    executed on the MXU int8 pipeline — latency rigid w.r.t. precision;
  * **serial path** (LUT-core analogue): flexible 2-8 bit weights,
    executed as shifted bitplane matmuls — latency ∝ bit-width.

Column→path allocation follows the paper's KL-divergence rule (filters
whose weight distribution is most damaged by quantization go to the
higher-bit-width path). The split ratio per layer either comes from the
config or is solved with the TPU cost model (`solve_tpu_split`, the
Eq. 12 analogue).

Three operating modes:
  * ``apply_fp``    — plain fp matmul (quantization off; baseline).
  * ``apply_qat``   — fake-quantized STE forward for training (the
    hybrid scheme of §4: per-column bit-widths by core assignment,
    layer-wise activation quantization).
  * ``apply_deploy``— integer inference through the Pallas kernels on
    a prepared ``DeployedHeteroLinear`` (int codes in HBM).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro import kernels
from repro.core.tpu_cost import TPUChip, V5E, solve_tpu_split
from repro.quant.hybrid import LayerQuantConfig, kl_filter_allocation
from repro.quant.uniform import (
    fake_quant_per_channel,
    fit_scale,
    fit_scale_per_channel,
    qrange,
    quantize,
)


@dataclasses.dataclass(frozen=True)
class HeteroLinearConfig:
    """Per-layer knobs (the DSE action space projected onto one layer)."""
    in_features: int
    out_features: int
    quant: LayerQuantConfig = LayerQuantConfig()
    enabled: bool = True           # False -> plain fp linear
    solve_ratio: bool = False      # override quant.ratio with Eq.-12 solve
    spatial: bool = False          # spatial (max) vs temporal (sum) compose
    chip: TPUChip = V5E

    def resolved_ratio(self, m_tokens: int = 4096) -> float:
        if not self.solve_ratio:
            return self.quant.ratio
        r, _, _ = solve_tpu_split(m_tokens, self.in_features,
                                  self.out_features, self.quant.w_bits_lut,
                                  self.quant.a_bits, self.chip,
                                  spatial=self.spatial)
        return r


def init_hetero_linear(rng: jax.Array, cfg: HeteroLinearConfig,
                       dtype=jnp.float32) -> dict:
    """fp master weights + the (static) column->path permutation."""
    k = 1.0 / (cfg.in_features ** 0.5)
    w = jax.random.uniform(rng, (cfg.in_features, cfg.out_features),
                           dtype, -k, k)
    return {"w": w}


def _split_sizes(cfg: HeteroLinearConfig) -> tuple[int, int]:
    n_serial = int(round(cfg.resolved_ratio() * cfg.out_features))
    return n_serial, cfg.out_features - n_serial


def column_allocation(w: jax.Array, cfg: HeteroLinearConfig) -> jax.Array:
    """Permutation of output columns: first n_serial slots -> serial path.

    Uses the paper's KL rule on the transposed view (filters = columns).
    """
    n_serial, _ = _split_sizes(cfg)
    qcfg = dataclasses.replace(cfg.quant, ratio=n_serial / max(cfg.out_features, 1))
    return kl_filter_allocation(w.T, qcfg)  # [out] filter indices


# ---------------------------------------------------------------------------
# fp + QAT forwards
# ---------------------------------------------------------------------------


def apply_fp(params: dict, x: jax.Array) -> jax.Array:
    return x @ params["w"]


def apply_qat(params: dict, x: jax.Array, cfg: HeteroLinearConfig) -> jax.Array:
    """STE fake-quant forward: per-column weight bits by core assignment,
    layer-wise activation quantization at ``a_bits`` (paper §4)."""
    if not cfg.enabled:
        return apply_fp(params, x)
    w = params["w"]
    perm = column_allocation(jax.lax.stop_gradient(w), cfg)
    n_serial, _ = _split_sizes(cfg)
    is_serial_slot = jnp.arange(cfg.out_features) < n_serial
    is_serial = is_serial_slot[jnp.argsort(perm)]     # original column order

    fq_serial = fake_quant_per_channel(w, cfg.quant.w_bits_lut, axis=1)
    fq_parallel = fake_quant_per_channel(w, cfg.quant.w_bits_dsp, axis=1)
    w_q = jnp.where(is_serial[None, :], fq_serial, fq_parallel)

    # layer-wise activation fake quant (shared by both paths)
    s_a = fit_scale(jax.lax.stop_gradient(x), cfg.quant.a_bits)
    lo, hi = qrange(cfg.quant.a_bits)
    x_q = jnp.clip(jnp.round(x / s_a), lo, hi) * s_a
    x_q = x + jax.lax.stop_gradient(x_q - x)          # STE
    return x_q @ w_q


# ---------------------------------------------------------------------------
# Deployment (integer path through the Pallas kernels)
# ---------------------------------------------------------------------------


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class DeployedHeteroLinear:
    """Integer codes ready for the kernels; static split boundary."""
    wq_serial: jax.Array       # [in, n_serial] int32 codes
    s_serial: jax.Array        # [n_serial] fp32
    wq_parallel: jax.Array     # [in, n_parallel] int32 codes (int4 range)
    s_parallel: jax.Array      # [n_parallel] fp32
    perm: jax.Array            # [out] column allocation
    inv_perm: jax.Array        # [out]
    bits_serial: int = dataclasses.field(metadata=dict(static=True), default=4)
    a_bits: int = dataclasses.field(metadata=dict(static=True), default=4)


def deploy(params: dict, cfg: HeteroLinearConfig) -> DeployedHeteroLinear:
    """Quantize fp master weights into the two-path integer layout."""
    w = params["w"]
    perm = column_allocation(w, cfg)
    n_serial, _ = _split_sizes(cfg)
    w_sorted = w[:, perm]
    w_ser, w_par = w_sorted[:, :n_serial], w_sorted[:, n_serial:]

    s_ser = fit_scale_per_channel(w_ser, cfg.quant.w_bits_lut, axis=1)
    s_par = fit_scale_per_channel(w_par, cfg.quant.w_bits_dsp, axis=1)
    return DeployedHeteroLinear(
        wq_serial=quantize(w_ser, s_ser, cfg.quant.w_bits_lut),
        s_serial=s_ser.reshape(-1),
        wq_parallel=quantize(w_par, s_par, cfg.quant.w_bits_dsp),
        s_parallel=s_par.reshape(-1),
        perm=perm,
        inv_perm=jnp.argsort(perm),
        bits_serial=cfg.quant.w_bits_lut,
        a_bits=cfg.quant.a_bits,
    )


def apply_deploy(d: DeployedHeteroLinear, x: jax.Array,
                 mode: str = "auto") -> jax.Array:
    """Integer inference: quantize activations, run both paths, restore
    the original column order, dequantize."""
    shape = x.shape
    x2 = x.reshape(-1, shape[-1])
    s_a = fit_scale(x2, d.a_bits)
    lo, hi = qrange(d.a_bits)
    x_q = jnp.clip(jnp.round(x2 / s_a), lo, hi).astype(jnp.int8)

    out = kernels.hetero_matmul(x_q, d.wq_serial, d.s_serial, d.bits_serial,
                                d.wq_parallel, d.s_parallel, mode=mode)
    out = out[:, d.inv_perm] * s_a
    return out.reshape(*shape[:-1], out.shape[-1]).astype(x.dtype)
