"""Instruction scheduling & event-driven pipeline simulation (Fig. 3).

N3H-Core is *intra-layer asynchronous*: three engines (Fetch, Execute,
Result) per core run their own instruction streams and handshake through
sync tokens (SE = sync-execute, WF = wait-fetch, WE = wait-execute).
This module:

  1. generates the per-layer instruction streams for the LUT-core
     (bit-serial, BISMO-backbone) and the DSP-core (bit-parallel),
     following the schedule of Fig. 3 (weight tiles double-buffered,
     activations resident, result write-back overlapped); and
  2. simulates the streams with an event-driven engine model, yielding
     the latency decomposition of Eqs. (6) and (8):
     L = sum(L_wait) + sum(L_run) + sum(L_sig) + sum(L_rst).

The simulator is the ground-truth latency model; `latency_model.py`
derives closed-form approximations from the same pipeline structure and
is validated against this simulator (<2% — the Fig. 5 reproduction).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable

from repro.core import isa

# ---------------------------------------------------------------------------
# Hardware descriptions
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class FPGADevice:
    """Resource pool + board-level constants of a target device.

    DMA constants are calibration parameters (the paper does not publish
    them); defaults model the Zynq AXI-HP ports at 100 MHz and were
    calibrated so the end-to-end model lands in the ballpark of the
    paper's Table 5 (see EXPERIMENTS.md §Paper-repro).
    """
    name: str
    luts: int
    dsps: int
    bram36: int
    dma_bytes_per_cycle: float = 16.0
    dma_setup_cycles: int = 32
    freq_mhz: float = 100.0

    def cycles_to_ms(self, cycles: float) -> float:
        return cycles / (self.freq_mhz * 1e3)


XC7Z020 = FPGADevice("XC7Z020", luts=53200, dsps=220, bram36=140)
XC7Z045 = FPGADevice("XC7Z045", luts=218600, dsps=900, bram36=545)

DEVICES = {d.name: d for d in (XC7Z020, XC7Z045)}


@dataclasses.dataclass(frozen=True)
class LutCoreConfig:
    """LUT-core knobs of Table 1 (BISMO-style M x N DPU array)."""
    m: int            # DPU rows
    n: int            # DPU columns
    k: int            # bits consumed per DPU per cycle
    d_a: int = 1024   # activation buffer depth
    d_w: int = 1024   # weight buffer depth (latency-insensitive, Eq. 9)
    pipeline_fill: int = 8  # DPU array fill/drain cycles per tile
    # Depthwise mode: channels map to array columns but the K-dim
    # reduction is only kh*kw taps, so the DPU bit-parallelism is mostly
    # idle; effective MAC rate = dense rate * dw_efficiency. The paper
    # observes exactly this ("LUT-Core is not efficient to compute
    # depth-wise layers", §6.2.2).
    dw_efficiency: float = 0.125


@dataclasses.dataclass(frozen=True)
class DspCoreConfig:
    """DSP-core knobs of Table 1. Per §3.3 the register array columns are
    fixed at 16 so the DSP budget pins n_reg_row_a = floor(DSP / 16)."""
    n_reg_row_a: int
    n_reg_col_a: int = 16
    n_reg_col_w: int = 16
    d_a: int = 1024
    d_w: int = 1024
    w_fill_cycles: int = 2    # two columns per buffer per cycle
    a_fill_cycles: int = 1    # one row per buffer per cycle
    # Depthwise: per-tap diagonal weight mode; better than the LUT-core
    # (the paper routes most depthwise layers to the DSP-core).
    dw_efficiency: float = 0.5

    @staticmethod
    def rows_for_device(dev: FPGADevice) -> int:
        return max(1, dev.dsps // 16)


@dataclasses.dataclass(frozen=True)
class GemmDims:
    """GEMM extents in *elements*: out[m, n] = act[m, k] @ wgt[k, n]."""
    m: int
    k: int
    n: int

    def macs(self) -> int:
        return self.m * self.k * self.n


# ---------------------------------------------------------------------------
# Event-driven engine simulator
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Op:
    """One scheduled instruction with its timing closure."""
    instr: isa.Instr
    cycles: int                  # busy cycles once runnable (0 for waits)
    channel: str | None = None   # sync channel (send or wait)


@dataclasses.dataclass
class EngineTrace:
    busy: int = 0
    wait: int = 0
    sync: int = 0
    finish: int = 0


@dataclasses.dataclass
class SimResult:
    total_cycles: int
    traces: dict[str, EngineTrace]
    n_instructions: int

    @property
    def l_wait(self) -> int:
        return self.traces["execute"].wait

    @property
    def l_run(self) -> int:
        return self.traces["execute"].busy

    @property
    def l_sig(self) -> int:
        return sum(t.sync for t in self.traces.values())

    @property
    def l_rst(self) -> int:
        return self.traces["result"].busy


class DeadlockError(RuntimeError):
    pass


def simulate(streams: dict[str, list[Op]],
             initial_tokens: dict[str, int] | None = None) -> SimResult:
    """Run the three engine streams to completion.

    Channels are FIFOs of token post-times. A wait op blocks until a
    token with post_time <= infinity exists; the engine resumes at
    max(own_clock, post_time). Initial tokens (e.g. free buffer slots
    for double buffering) are available at t=0.
    """
    tokens: dict[str, list[int]] = {}
    for ch, cnt in (initial_tokens or {}).items():
        tokens[ch] = [0] * cnt

    idx = {e: 0 for e in streams}
    clock = {e: 0 for e in streams}
    traces = {e: EngineTrace() for e in streams}
    n_instr = sum(len(s) for s in streams.values())

    def runnable(e: str) -> bool:
        i = idx[e]
        if i >= len(streams[e]):
            return False
        op = streams[e][i]
        if op.channel is not None and _is_wait(op):
            return bool(tokens.get(op.channel))
        return True

    progressed = True
    while progressed:
        progressed = False
        for e, stream in streams.items():
            while runnable(e):
                op = stream[idx[e]]
                t = traces[e]
                if op.channel is not None and _is_wait(op):
                    post = tokens[op.channel].pop(0)
                    start = max(clock[e], post)
                    t.wait += start - clock[e]
                    t.sync += op.cycles
                    clock[e] = start + op.cycles
                elif op.channel is not None:  # send
                    t.sync += op.cycles
                    clock[e] += op.cycles
                    tokens.setdefault(op.channel, []).append(clock[e])
                else:
                    t.busy += op.cycles
                    clock[e] += op.cycles
                idx[e] += 1
                progressed = True

    if any(idx[e] < len(streams[e]) for e in streams):
        stuck = {e: (idx[e], len(streams[e])) for e in streams}
        raise DeadlockError(f"engines deadlocked at {stuck}")

    for e in streams:
        traces[e].finish = clock[e]
    total = max(clock.values()) if clock else 0
    return SimResult(total_cycles=total, traces=traces, n_instructions=n_instr)


def _is_wait(op: Op) -> bool:
    return isinstance(op.instr, isa.SyncInstr) and op.instr.is_wait == 1


def _send(core: isa.CoreSel, src: isa.Engine, dst: isa.Engine, ch: str,
          flag: int = 0) -> Op:
    return Op(
        isa.SyncInstr(core=core, src_engine=src, dst_engine=dst, cur_state=0,
                      next_state=min(3, flag), token_flag=flag & 0x7, is_wait=0),
        cycles=1, channel=ch)


def _wait(core: isa.CoreSel, src: isa.Engine, dst: isa.Engine, ch: str,
          flag: int = 0) -> Op:
    return Op(
        isa.SyncInstr(core=core, src_engine=src, dst_engine=dst, cur_state=1,
                      next_state=min(3, flag), token_flag=flag & 0x7, is_wait=1),
        cycles=1, channel=ch)


def _dma_cycles(n_bytes: float, dev: FPGADevice) -> int:
    return int(math.ceil(n_bytes / dev.dma_bytes_per_cycle)) + dev.dma_setup_cycles


# ---------------------------------------------------------------------------
# LUT-core schedule (bit-serial, Fig. 3)
# ---------------------------------------------------------------------------


def lut_core_streams(g: GemmDims, cfg: LutCoreConfig, dev: FPGADevice,
                     bits_w: int, bits_a: int, depthwise: bool = False
                     ) -> tuple[dict[str, list[Op]], dict[str, int]]:
    """Instruction streams for one layer partition on the LUT-core.

    Schedule (per Fig. 3): the whole (bit-serialized) activation matrix L
    is resident on chip; weight column-tiles R_j are streamed through a
    double-buffered weight buffer; output tiles are drained by the
    result engine as they complete.

    Cycle model: a (m x n) output tile accumulates over ceil(K_g/K)
    K-bit beats per binary plane pair; there are bits_w*bits_a plane
    pairs; plus a fixed array fill/drain per tile. Result tiles are
    written back to DDR *requantized* to the next layer's activation
    bit-width (§3.1: "written to DDR as the activation of the next
    layer"), which we approximate with bits_a.
    """
    C = isa.CoreSel.LUT
    nt_m = math.ceil(g.m / cfg.m)
    nt_n = math.ceil(g.n / cfg.n)
    if depthwise:
        # channels across columns, K = kh*kw taps, derated MAC rate
        nt_k = 1
        tile_exec = math.ceil(g.k * bits_w * bits_a /
                              (cfg.k * cfg.dw_efficiency)) + cfg.pipeline_fill
        bytes_l = g.m * g.n * bits_a / 8.0      # NHWC, no channel reuse
        bytes_r_tile = g.k * cfg.n * bits_w / 8.0
    else:
        nt_k = math.ceil(g.k / cfg.k)
        tile_exec = nt_k * bits_w * bits_a + cfg.pipeline_fill
        bytes_l = g.m * g.k * bits_a / 8.0      # serialized activation planes
        bytes_r_tile = cfg.n * g.k * bits_w / 8.0   # one weight column-tile
    bytes_out_tile = cfg.m * cfg.n * bits_a / 8.0   # requantized write-back

    # Activation residency: the activation buffer pool holds M x D_a x K
    # bits. When the (serialized) L matrix exceeds it, L is re-streamed
    # for every weight column tile — the paper's schedule only avoids
    # this when "the activation buffers possess the capacity of the
    # activation matrix L" (§3.1).
    a_capacity_bits = cfg.m * cfg.d_a * cfg.k
    a_resident = bytes_l * 8 <= a_capacity_bits

    fetch: list[Op] = []
    execu: list[Op] = []
    result: list[Op] = []

    # R0 first, then L (paper: "R0 is fetched ... then L0 is fetched as well").
    fetch.append(Op(isa.FetchInstr(C, 0, 0, 0, 0, 0, min(65535, int(bytes_r_tile))),
                    cycles=_dma_cycles(bytes_r_tile, dev)))
    fetch.append(_send(C, isa.Engine.FETCH, isa.Engine.EXECUTE, "lut.wtile", 1))
    fetch.append(Op(isa.FetchInstr(C, 0, 1, 0, 0, 0, min(65535, int(bytes_l))),
                    cycles=_dma_cycles(bytes_l, dev)))
    fetch.append(_send(C, isa.Engine.FETCH, isa.Engine.EXECUTE, "lut.act", 1))
    for j in range(1, nt_n):
        # Wait for a free slot in the double-buffered weight buffer (WE).
        fetch.append(_wait(C, isa.Engine.EXECUTE, isa.Engine.FETCH, "lut.wslot", 2))
        fetch.append(Op(isa.FetchInstr(C, 0, 0, j % 2, 0, j,
                                       min(65535, int(bytes_r_tile))),
                        cycles=_dma_cycles(bytes_r_tile, dev)))
        fetch.append(_send(C, isa.Engine.FETCH, isa.Engine.EXECUTE, "lut.wtile", 1))
        if not a_resident:
            # re-stream the activation matrix for this column tile
            fetch.append(Op(isa.FetchInstr(C, 0, 1, j % 2, 0, j,
                                           min(65535, int(bytes_l))),
                            cycles=_dma_cycles(bytes_l, dev)))
            fetch.append(_send(C, isa.Engine.FETCH, isa.Engine.EXECUTE,
                               "lut.act", 1))

    execu.append(_wait(C, isa.Engine.FETCH, isa.Engine.EXECUTE, "lut.act", 1))
    for j in range(nt_n):
        execu.append(_wait(C, isa.Engine.FETCH, isa.Engine.EXECUTE, "lut.wtile", 1))
        if not a_resident and j > 0:
            execu.append(_wait(C, isa.Engine.FETCH, isa.Engine.EXECUTE,
                               "lut.act", 1))
        for i in range(nt_m):
            execu.append(Op(isa.ExecuteInstr(
                C, buf_addr_a=(i * nt_k) & 0xFFFF, buf_addr_w=(j * nt_k) & 0xFFFF,
                tile_m=min(4095, cfg.m), tile_k=min(65535, g.k),
                tile_n=min(4095, cfg.n), bits_w=bits_w, bits_a=bits_a,
                accumulate=0), cycles=tile_exec))
            execu.append(_send(C, isa.Engine.EXECUTE, isa.Engine.RESULT, "lut.res", 3))
        # Free this weight-buffer slot for the fetch engine (SE).
        execu.append(_send(C, isa.Engine.EXECUTE, isa.Engine.FETCH, "lut.wslot", 2))

    for j in range(nt_n):
        for i in range(nt_m):
            result.append(_wait(C, isa.Engine.EXECUTE, isa.Engine.RESULT, "lut.res", 3))
            result.append(Op(isa.ResultInstr(C, 0, 2, 0, 0, (j * nt_m + i) & 0xFFFFFF,
                                             min(65535, int(bytes_out_tile))),
                             cycles=_dma_cycles(bytes_out_tile, dev)))

    streams = {"fetch": fetch, "execute": execu, "result": result}
    # One weight-buffer slot is free at t=0 (the other is filled by the
    # un-gated first fetch) => effective double buffering.
    return streams, {"lut.wslot": 1}


# ---------------------------------------------------------------------------
# DSP-core schedule (bit-parallel)
# ---------------------------------------------------------------------------


def dsp_core_streams(g: GemmDims, cfg: DspCoreConfig, dev: FPGADevice,
                     depthwise: bool = False
                     ) -> tuple[dict[str, list[Op]], dict[str, int]]:
    """Instruction streams for one layer partition on the DSP-core.

    The register arrays compute an [R x 16] x [16 x 16] product per
    K-step: 2 cycles to fill the weight registers (two columns per
    buffer per cycle), then 16 systolic MAC cycles. Activation row-tiles
    are double buffered; weight column-tiles are cached on chip when the
    weight buffer capacity allows, else re-fetched per row-tile.
    """
    C = isa.CoreSel.DSP
    R = cfg.n_reg_row_a
    kstep = cfg.w_fill_cycles + cfg.n_reg_col_w + cfg.a_fill_cycles
    nt_m = math.ceil(g.m / R)
    nt_n = math.ceil(g.n / cfg.n_reg_col_w)
    bits_a_stored = 4  # activations are zero-padded to 4 bits in buffers
    if depthwise:
        # per-tap diagonal weight mode: 16 channels per pass, derated
        tile_exec = math.ceil(g.k * kstep /
                              (cfg.n_reg_col_a * cfg.dw_efficiency))
        bytes_a_tile = R * cfg.n_reg_col_w * bits_a_stored / 8.0
        bytes_w_tile = g.k * cfg.n_reg_col_w * 4 / 8.0
    else:
        nt_k = math.ceil(g.k / cfg.n_reg_col_a)
        tile_exec = nt_k * kstep
        bytes_a_tile = R * g.k * bits_a_stored / 8.0
        bytes_w_tile = g.k * cfg.n_reg_col_w * 4 / 8.0  # int4 weights
    bytes_out_tile = R * cfg.n_reg_col_w * bits_a_stored / 8.0

    # Weight resident if every column tile fits the weight buffer pool.
    w_capacity_bits = (cfg.n_reg_col_w // 2) * cfg.d_w * (cfg.n_reg_col_a * 4)
    w_resident = nt_n * bytes_w_tile * 8 <= w_capacity_bits

    fetch: list[Op] = []
    execu: list[Op] = []
    result: list[Op] = []

    if w_resident:
        fetch.append(Op(isa.FetchInstr(C, 0, 0, 0, 0, 0,
                                       min(65535, int(nt_n * bytes_w_tile))),
                        cycles=_dma_cycles(nt_n * bytes_w_tile, dev)))
        fetch.append(_send(C, isa.Engine.FETCH, isa.Engine.EXECUTE, "dsp.wall", 1))

    for i in range(nt_m):
        if i >= 2:
            fetch.append(_wait(C, isa.Engine.EXECUTE, isa.Engine.FETCH, "dsp.aslot", 2))
        fetch.append(Op(isa.FetchInstr(C, 0, 1, i % 2, 0, i,
                                       min(65535, int(bytes_a_tile))),
                        cycles=_dma_cycles(bytes_a_tile, dev)))
        fetch.append(_send(C, isa.Engine.FETCH, isa.Engine.EXECUTE, "dsp.atile", 1))
        if not w_resident:
            for j in range(nt_n):
                fetch.append(Op(isa.FetchInstr(C, 0, 0, j % 2, 0, j,
                                               min(65535, int(bytes_w_tile))),
                                cycles=_dma_cycles(bytes_w_tile, dev)))
                fetch.append(_send(C, isa.Engine.FETCH, isa.Engine.EXECUTE, "dsp.wtile", 1))

    if w_resident:
        execu.append(_wait(C, isa.Engine.FETCH, isa.Engine.EXECUTE, "dsp.wall", 1))
    for i in range(nt_m):
        execu.append(_wait(C, isa.Engine.FETCH, isa.Engine.EXECUTE, "dsp.atile", 1))
        for j in range(nt_n):
            if not w_resident:
                execu.append(_wait(C, isa.Engine.FETCH, isa.Engine.EXECUTE, "dsp.wtile", 1))
            execu.append(Op(isa.ExecuteInstr(
                C, buf_addr_a=i & 0xFFFF, buf_addr_w=j & 0xFFFF,
                tile_m=min(4095, R), tile_k=min(65535, g.k),
                tile_n=cfg.n_reg_col_w, bits_w=4, bits_a=4,
                accumulate=0), cycles=tile_exec))
            execu.append(_send(C, isa.Engine.EXECUTE, isa.Engine.RESULT, "dsp.res", 3))
        execu.append(_send(C, isa.Engine.EXECUTE, isa.Engine.FETCH, "dsp.aslot", 2))

    for i in range(nt_m):
        for j in range(nt_n):
            result.append(_wait(C, isa.Engine.EXECUTE, isa.Engine.RESULT, "dsp.res", 3))
            result.append(Op(isa.ResultInstr(C, 0, 2, 0, 0, (i * nt_n + j) & 0xFFFFFF,
                                             min(65535, int(bytes_out_tile))),
                             cycles=_dma_cycles(bytes_out_tile, dev)))

    streams = {"fetch": fetch, "execute": execu, "result": result}
    return streams, {"dsp.aslot": 1}


# ---------------------------------------------------------------------------
# Entry points used by the latency model
# ---------------------------------------------------------------------------


def simulate_lut_core(g: GemmDims, cfg: LutCoreConfig, dev: FPGADevice,
                      bits_w: int, bits_a: int, depthwise: bool = False) -> SimResult:
    if g.n == 0 or g.m == 0 or g.k == 0:
        return SimResult(0, {"fetch": EngineTrace(), "execute": EngineTrace(),
                             "result": EngineTrace()}, 0)
    streams, init = lut_core_streams(g, cfg, dev, bits_w, bits_a, depthwise)
    return simulate(streams, init)


def simulate_dsp_core(g: GemmDims, cfg: DspCoreConfig, dev: FPGADevice,
                      depthwise: bool = False) -> SimResult:
    if g.n == 0 or g.m == 0 or g.k == 0:
        return SimResult(0, {"fetch": EngineTrace(), "execute": EngineTrace(),
                             "result": EngineTrace()}, 0)
    streams, init = dsp_core_streams(g, cfg, dev, depthwise)
    return simulate(streams, init)
