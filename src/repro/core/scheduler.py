"""Event-driven pipeline simulation (Fig. 3).

N3H-Core is *intra-layer asynchronous*: three engines (Fetch, Execute,
Result) per core run their own instruction streams and handshake through
sync tokens (SE = sync-execute, WF = wait-fetch, WE = wait-execute).
This module simulates those streams with an event-driven engine model,
yielding the latency decomposition of Eqs. (6) and (8):
L = sum(L_wait) + sum(L_run) + sum(L_sig) + sum(L_rst).

Instruction generation lives in ``repro.compiler.lower`` — the NN→ISA
compiler is the single source of truth for streams, and this simulator
consumes its output: either raw per-layer streams (the historical
``lut_core_streams`` / ``dsp_core_streams`` entry points, now thin
wrappers over the compiler) or a whole compiled ``Program`` via
:func:`simulate_program`.

The simulator is the ground-truth latency model; `latency_model.py`
derives closed-form approximations from the same pipeline structure and
is validated against this simulator (<2% — the Fig. 5 reproduction).
"""
from __future__ import annotations

import dataclasses
import math
from repro.core import isa

# ---------------------------------------------------------------------------
# Hardware descriptions
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class FPGADevice:
    """Resource pool + board-level constants of a target device.

    DMA constants are calibration parameters (the paper does not publish
    them); defaults model the Zynq AXI-HP ports at 100 MHz and were
    calibrated so the end-to-end model lands in the ballpark of the
    paper's Table 5 (see EXPERIMENTS.md §Paper-repro).
    """
    name: str
    luts: int
    dsps: int
    bram36: int
    dma_bytes_per_cycle: float = 16.0
    dma_setup_cycles: int = 32
    freq_mhz: float = 100.0

    def cycles_to_ms(self, cycles: float) -> float:
        return cycles / (self.freq_mhz * 1e3)


XC7Z020 = FPGADevice("XC7Z020", luts=53200, dsps=220, bram36=140)
XC7Z045 = FPGADevice("XC7Z045", luts=218600, dsps=900, bram36=545)

DEVICES = {d.name: d for d in (XC7Z020, XC7Z045)}


@dataclasses.dataclass(frozen=True)
class LutCoreConfig:
    """LUT-core knobs of Table 1 (BISMO-style M x N DPU array)."""
    m: int            # DPU rows
    n: int            # DPU columns
    k: int            # bits consumed per DPU per cycle
    d_a: int = 1024   # activation buffer depth
    d_w: int = 1024   # weight buffer depth (latency-insensitive, Eq. 9)
    pipeline_fill: int = 8  # DPU array fill/drain cycles per tile
    # Depthwise mode: channels map to array columns but the K-dim
    # reduction is only kh*kw taps, so the DPU bit-parallelism is mostly
    # idle; effective MAC rate = dense rate * dw_efficiency. The paper
    # observes exactly this ("LUT-Core is not efficient to compute
    # depth-wise layers", §6.2.2).
    dw_efficiency: float = 0.125


@dataclasses.dataclass(frozen=True)
class DspCoreConfig:
    """DSP-core knobs of Table 1. Per §3.3 the register array columns are
    fixed at 16 so the DSP budget pins n_reg_row_a = floor(DSP / 16)."""
    n_reg_row_a: int
    n_reg_col_a: int = 16
    n_reg_col_w: int = 16
    d_a: int = 1024
    d_w: int = 1024
    w_fill_cycles: int = 2    # two columns per buffer per cycle
    a_fill_cycles: int = 1    # one row per buffer per cycle
    # Depthwise: per-tap diagonal weight mode; better than the LUT-core
    # (the paper routes most depthwise layers to the DSP-core).
    dw_efficiency: float = 0.5

    @staticmethod
    def rows_for_device(dev: FPGADevice) -> int:
        return max(1, dev.dsps // 16)


@dataclasses.dataclass(frozen=True)
class GemmDims:
    """GEMM extents in *elements*: out[m, n] = act[m, k] @ wgt[k, n]."""
    m: int
    k: int
    n: int

    def macs(self) -> int:
        return self.m * self.k * self.n


# ---------------------------------------------------------------------------
# Event-driven engine simulator
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Op:
    """One scheduled instruction with its timing closure."""
    instr: isa.Instr
    cycles: int                  # busy cycles once runnable (0 for waits)
    channel: str | None = None   # sync channel (send or wait)


@dataclasses.dataclass
class EngineTrace:
    busy: int = 0
    wait: int = 0
    sync: int = 0
    finish: int = 0


@dataclasses.dataclass
class SimResult:
    total_cycles: int
    traces: dict[str, EngineTrace]
    n_instructions: int

    @property
    def l_wait(self) -> int:
        return self.traces["execute"].wait

    @property
    def l_run(self) -> int:
        return self.traces["execute"].busy

    @property
    def l_sig(self) -> int:
        return sum(t.sync for t in self.traces.values())

    @property
    def l_rst(self) -> int:
        return self.traces["result"].busy


class DeadlockError(RuntimeError):
    pass


def simulate(streams: dict[str, list[Op]],
             initial_tokens: dict[str, int] | None = None) -> SimResult:
    """Run the three engine streams to completion.

    Channels are FIFOs of token post-times. A wait op blocks until a
    token with post_time <= infinity exists; the engine resumes at
    max(own_clock, post_time). Initial tokens (e.g. free buffer slots
    for double buffering) are available at t=0.
    """
    tokens: dict[str, list[int]] = {}
    for ch, cnt in (initial_tokens or {}).items():
        tokens[ch] = [0] * cnt

    idx = {e: 0 for e in streams}
    clock = {e: 0 for e in streams}
    traces = {e: EngineTrace() for e in streams}
    n_instr = sum(len(s) for s in streams.values())

    def runnable(e: str) -> bool:
        i = idx[e]
        if i >= len(streams[e]):
            return False
        op = streams[e][i]
        if op.channel is not None and _is_wait(op):
            return bool(tokens.get(op.channel))
        return True

    progressed = True
    while progressed:
        progressed = False
        for e, stream in streams.items():
            while runnable(e):
                op = stream[idx[e]]
                t = traces[e]
                if op.channel is not None and _is_wait(op):
                    post = tokens[op.channel].pop(0)
                    start = max(clock[e], post)
                    t.wait += start - clock[e]
                    t.sync += op.cycles
                    clock[e] = start + op.cycles
                elif op.channel is not None:  # send
                    t.sync += op.cycles
                    clock[e] += op.cycles
                    tokens.setdefault(op.channel, []).append(clock[e])
                else:
                    t.busy += op.cycles
                    clock[e] += op.cycles
                idx[e] += 1
                progressed = True

    if any(idx[e] < len(streams[e]) for e in streams):
        stuck = {e: (idx[e], len(streams[e])) for e in streams}
        raise DeadlockError(f"engines deadlocked at {stuck}")

    for e in streams:
        traces[e].finish = clock[e]
    total = max(clock.values()) if clock else 0
    return SimResult(total_cycles=total, traces=traces, n_instructions=n_instr)


def _is_wait(op: Op) -> bool:
    return isinstance(op.instr, isa.SyncInstr) and op.instr.is_wait == 1


def _dma_cycles(n_bytes: float, dev: FPGADevice) -> int:
    return int(math.ceil(n_bytes / dev.dma_bytes_per_cycle)) + dev.dma_setup_cycles


# ---------------------------------------------------------------------------
# Stream generation — thin wrappers over the NN→ISA compiler
# ---------------------------------------------------------------------------


def lut_core_streams(g: GemmDims, cfg: LutCoreConfig, dev: FPGADevice,
                     bits_w: int, bits_a: int, depthwise: bool = False
                     ) -> tuple[dict[str, list[Op]], dict[str, int]]:
    """Instruction streams for one layer partition on the LUT-core.

    Delegates to ``repro.compiler.lower.lower_lut_layer`` — the compiler
    owns the Fig.-3 schedule; this wrapper keeps the historical
    (streams, initial_tokens) shape the simulator entry points consume.
    """
    from repro.compiler.lower import lower_lut_layer
    cp = lower_lut_layer(g, cfg, dev, bits_w, bits_a, depthwise)
    return cp.streams, cp.initial_tokens


def dsp_core_streams(g: GemmDims, cfg: DspCoreConfig, dev: FPGADevice,
                     depthwise: bool = False
                     ) -> tuple[dict[str, list[Op]], dict[str, int]]:
    """Instruction streams for one layer partition on the DSP-core.

    Delegates to ``repro.compiler.lower.lower_dsp_layer`` (see
    ``lut_core_streams``).
    """
    from repro.compiler.lower import lower_dsp_layer
    cp = lower_dsp_layer(g, cfg, dev, depthwise)
    return cp.streams, cp.initial_tokens


# ---------------------------------------------------------------------------
# Entry points used by the latency model
# ---------------------------------------------------------------------------


def simulate_lut_core(g: GemmDims, cfg: LutCoreConfig, dev: FPGADevice,
                      bits_w: int, bits_a: int, depthwise: bool = False) -> SimResult:
    if g.n == 0 or g.m == 0 or g.k == 0:
        return SimResult(0, {"fetch": EngineTrace(), "execute": EngineTrace(),
                             "result": EngineTrace()}, 0)
    streams, init = lut_core_streams(g, cfg, dev, bits_w, bits_a, depthwise)
    return simulate(streams, init)


def simulate_dsp_core(g: GemmDims, cfg: DspCoreConfig, dev: FPGADevice,
                      depthwise: bool = False) -> SimResult:
    if g.n == 0 or g.m == 0 or g.k == 0:
        return SimResult(0, {"fetch": EngineTrace(), "execute": EngineTrace(),
                             "result": EngineTrace()}, 0)
    streams, init = dsp_core_streams(g, cfg, dev, depthwise)
    return simulate(streams, init)


# ---------------------------------------------------------------------------
# Compiled-Program simulation
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class LayerSim:
    """Per-layer simulation of a compiled program layer: both cores run
    concurrently, the layer's makespan is their max (Eq. 10 inner term)."""
    name: str
    lut: SimResult | None
    dsp: SimResult | None

    @property
    def cycles(self) -> int:
        return max((r.total_cycles for r in (self.lut, self.dsp)
                    if r is not None), default=0)


@dataclasses.dataclass
class ProgramSim:
    layers: list[LayerSim]

    @property
    def total_cycles(self) -> int:
        """Eq. (10): inter-layer synchronous sum of per-layer makespans."""
        return sum(ls.cycles for ls in self.layers)

    @property
    def n_instructions(self) -> int:
        return sum(r.n_instructions for ls in self.layers
                   for r in (ls.lut, ls.dsp) if r is not None)

    def decomposition(self, core: str) -> dict[str, int]:
        """Aggregate Eq. (6)/(8) terms over layers for one core."""
        agg = {"l_wait": 0, "l_run": 0, "l_sig": 0, "l_rst": 0}
        for ls in self.layers:
            r = getattr(ls, core)
            if r is None:
                continue
            agg["l_wait"] += r.l_wait
            agg["l_run"] += r.l_run
            agg["l_sig"] += r.l_sig
            agg["l_rst"] += r.l_rst
        return agg


def simulate_program(prog, opt_level: int | None = None,
                     batches: int = 1) -> "ProgramSim":
    """Run a compiled ``repro.compiler.Program`` through the event-driven
    engine model, layer by layer (inter-layer synchronous, §3.1): the
    compiler is the single source of truth for the streams; this is the
    same Fig. 5 ground-truth model the closed forms validate against.

    ``opt_level`` (None = time the program as given) first runs the
    ``repro.compiler.passes`` pipeline at that level, so optimized
    streams are exactly what gets timed — `-O0` vs `-O1` latency deltas
    come from this one entry point.

    A ``repro.compiler.partition.MultiDeviceProgram`` dispatches to the
    cross-device makespan aggregation instead (per-device event-driven
    sims + the plan's link-latency model), returning a ``BundleSim``;
    ``batches`` then sets how many back-to-back inputs the makespan
    covers (pipeline plans overlap them across stages); for a plain
    single-device program ``batches`` is ignored (its makespan for B
    inputs is just ``B * total_cycles``).
    """
    if hasattr(prog, "devices"):     # MultiDeviceProgram bundle
        from repro.compiler.partition import optimize_bundle, simulate_bundle
        if opt_level is not None:
            prog = optimize_bundle(prog, opt_level, validate=False)
        return simulate_bundle(prog, batches=batches)
    if opt_level is not None:
        from repro.compiler.passes import optimize_program
        prog = optimize_program(prog, opt_level, validate=False)
    layers = []
    for lp in prog.layers:
        sims = {}
        for attr in ("lut", "dsp"):
            cp = getattr(lp, attr)
            # sim_tokens() arms inter-layer barrier waits at t=0: under
            # the Eq.-10 synchronous chain the previous layer has drained.
            sims[attr] = (simulate(cp.streams, cp.sim_tokens())
                          if cp is not None else None)
        layers.append(LayerSim(lp.name, sims["lut"], sims["dsp"]))
    return ProgramSim(layers)
