"""Event-driven pipeline simulation (Fig. 3).

N3H-Core is *intra-layer asynchronous*: three engines (Fetch, Execute,
Result) per core run their own instruction streams and handshake through
sync tokens (SE = sync-execute, WF = wait-fetch, WE = wait-execute).
This module simulates those streams with an event-driven engine model,
yielding the latency decomposition of Eqs. (6) and (8):
L = sum(L_wait) + sum(L_run) + sum(L_sig) + sum(L_rst).

Instruction generation lives in ``repro.compiler.lower`` — the NN→ISA
compiler is the single source of truth for streams, and this simulator
consumes its output: either raw per-layer streams (the historical
``lut_core_streams`` / ``dsp_core_streams`` entry points, now thin
wrappers over the compiler) or a whole compiled ``Program`` via
:func:`simulate_program`.

The simulator is the ground-truth latency model; `latency_model.py`
derives closed-form approximations from the same pipeline structure and
is validated against this simulator (<2% — the Fig. 5 reproduction).
"""
from __future__ import annotations

import dataclasses
import math
from repro.core import isa

# ---------------------------------------------------------------------------
# Hardware descriptions
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class FPGADevice:
    """Resource pool + board-level constants of a target device.

    DMA constants are calibration parameters (the paper does not publish
    them); defaults model the Zynq AXI-HP ports at 100 MHz and were
    calibrated so the end-to-end model lands in the ballpark of the
    paper's Table 5 (see EXPERIMENTS.md §Paper-repro).
    """
    name: str
    luts: int
    dsps: int
    bram36: int
    dma_bytes_per_cycle: float = 16.0
    dma_setup_cycles: int = 32
    freq_mhz: float = 100.0

    def cycles_to_ms(self, cycles: float) -> float:
        return cycles / (self.freq_mhz * 1e3)


XC7Z020 = FPGADevice("XC7Z020", luts=53200, dsps=220, bram36=140)
XC7Z045 = FPGADevice("XC7Z045", luts=218600, dsps=900, bram36=545)

DEVICES = {d.name: d for d in (XC7Z020, XC7Z045)}


@dataclasses.dataclass(frozen=True)
class LutCoreConfig:
    """LUT-core knobs of Table 1 (BISMO-style M x N DPU array)."""
    m: int            # DPU rows
    n: int            # DPU columns
    k: int            # bits consumed per DPU per cycle
    d_a: int = 1024   # activation buffer depth
    d_w: int = 1024   # weight buffer depth (latency-insensitive, Eq. 9)
    pipeline_fill: int = 8  # DPU array fill/drain cycles per tile
    # Depthwise mode: channels map to array columns but the K-dim
    # reduction is only kh*kw taps, so the DPU bit-parallelism is mostly
    # idle; effective MAC rate = dense rate * dw_efficiency. The paper
    # observes exactly this ("LUT-Core is not efficient to compute
    # depth-wise layers", §6.2.2).
    dw_efficiency: float = 0.125


@dataclasses.dataclass(frozen=True)
class DspCoreConfig:
    """DSP-core knobs of Table 1. Per §3.3 the register array columns are
    fixed at 16 so the DSP budget pins n_reg_row_a = floor(DSP / 16)."""
    n_reg_row_a: int
    n_reg_col_a: int = 16
    n_reg_col_w: int = 16
    d_a: int = 1024
    d_w: int = 1024
    w_fill_cycles: int = 2    # two columns per buffer per cycle
    a_fill_cycles: int = 1    # one row per buffer per cycle
    # Depthwise: per-tap diagonal weight mode; better than the LUT-core
    # (the paper routes most depthwise layers to the DSP-core).
    dw_efficiency: float = 0.5

    @staticmethod
    def rows_for_device(dev: FPGADevice) -> int:
        return max(1, dev.dsps // 16)


@dataclasses.dataclass(frozen=True)
class GemmDims:
    """GEMM extents in *elements*: out[m, n] = act[m, k] @ wgt[k, n]."""
    m: int
    k: int
    n: int

    def macs(self) -> int:
        return self.m * self.k * self.n


# ---------------------------------------------------------------------------
# Event-driven engine simulator
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Op:
    """One scheduled instruction with its timing closure."""
    instr: isa.Instr
    cycles: int                  # busy cycles once runnable (0 for waits)
    channel: str | None = None   # sync channel (send or wait)


@dataclasses.dataclass
class EngineTrace:
    busy: int = 0
    wait: int = 0
    sync: int = 0
    finish: int = 0


@dataclasses.dataclass
class SimResult:
    total_cycles: int
    traces: dict[str, EngineTrace]
    n_instructions: int

    @property
    def l_wait(self) -> int:
        return self.traces["execute"].wait

    @property
    def l_run(self) -> int:
        return self.traces["execute"].busy

    @property
    def l_sig(self) -> int:
        return sum(t.sync for t in self.traces.values())

    @property
    def l_rst(self) -> int:
        return self.traces["result"].busy


class DeadlockError(RuntimeError):
    pass


@dataclasses.dataclass
class SimTrace:
    """Raw per-instruction spans of one :func:`simulate` call (one core
    in one layer window), consumed by ``repro.obs``.

    ``spans`` holds ``(engine, kind, start, dur, channel, instr)``
    tuples — start/dur in cycles relative to the window start, kind is
    ``"busy"``/``"sync"``/``"stall"``, instr the raw instruction object
    (names resolve at export) — in issue order, which is deterministic
    for a fixed program. ``queue_peak`` is the maximum token-queue
    depth observed per channel (buffer-slot occupancy for the
    ``*slot`` channels).
    """
    spans: list = dataclasses.field(default_factory=list)
    queue_peak: dict = dataclasses.field(default_factory=dict)


class LazySimTrace:
    """Deferred span capture for one core's layer window.

    Holds the stream refs and replays the (deterministic) simulation
    with span recording on first access. This is what keeps tracer-on
    ``simulate_program`` within the <15% overhead budget: the timed
    simulation runs the plain hot loop, and the per-instruction span
    cost lands in the export step (``Tracer.to_chrome``), where it
    belongs. Replay equals the live run instruction for instruction
    because :func:`simulate` is deterministic for fixed streams.
    """

    __slots__ = ("_streams", "_tokens", "_st")

    def __init__(self, streams, initial_tokens):
        self._streams = streams
        self._tokens = initial_tokens
        self._st = None

    def _force(self) -> SimTrace:
        if self._st is None:
            st = SimTrace()
            simulate(self._streams, self._tokens, trace=st)
            self._st = st
        return self._st

    @property
    def spans(self) -> list:
        return self._force().spans

    @property
    def queue_peak(self) -> dict:
        return self._force().queue_peak


def simulate(streams: dict[str, list[Op]],
             initial_tokens: dict[str, int] | None = None,
             trace: SimTrace | None = None) -> SimResult:
    """Run the three engine streams to completion.

    Channels are FIFOs of token post-times. A wait op blocks until a
    token with post_time <= infinity exists; the engine resumes at
    max(own_clock, post_time). Initial tokens (e.g. free buffer slots
    for double buffering) are available at t=0.

    ``trace`` (optional) collects per-instruction spans into a
    :class:`SimTrace`; the default ``None`` keeps the hot loop on the
    historical no-bookkeeping path.
    """
    tokens: dict[str, list[int]] = {}
    for ch, cnt in (initial_tokens or {}).items():
        tokens[ch] = [0] * cnt

    spans = trace.spans if trace is not None else None
    peaks = trace.queue_peak if trace is not None else None
    if peaks is not None:
        for ch, q in tokens.items():
            peaks[ch] = len(q)

    idx = {e: 0 for e in streams}
    clock = {e: 0 for e in streams}
    traces = {e: EngineTrace() for e in streams}
    n_instr = sum(len(s) for s in streams.values())

    def runnable(e: str) -> bool:
        i = idx[e]
        if i >= len(streams[e]):
            return False
        op = streams[e][i]
        if op.channel is not None and _is_wait(op):
            return bool(tokens.get(op.channel))
        return True

    progressed = True
    while progressed:
        progressed = False
        for e, stream in streams.items():
            while runnable(e):
                op = stream[idx[e]]
                t = traces[e]
                # span tuples carry the raw instr object; opcode names
                # resolve lazily at trace export (enum .name lookups in
                # the hot loop would dominate the traced-sim cost)
                if op.channel is not None and _is_wait(op):
                    post = tokens[op.channel].pop(0)
                    start = max(clock[e], post)
                    if spans is not None:
                        if start > clock[e]:
                            spans.append((e, "stall", clock[e],
                                          start - clock[e], op.channel,
                                          None))
                        if op.cycles:
                            spans.append((e, "sync", start, op.cycles,
                                          op.channel, op.instr))
                    t.wait += start - clock[e]
                    t.sync += op.cycles
                    clock[e] = start + op.cycles
                elif op.channel is not None:  # send
                    if spans is not None and op.cycles:
                        spans.append((e, "sync", clock[e], op.cycles,
                                      op.channel, op.instr))
                    t.sync += op.cycles
                    clock[e] += op.cycles
                    q = tokens.setdefault(op.channel, [])
                    q.append(clock[e])
                    if peaks is not None and len(q) > peaks.get(op.channel, 0):
                        peaks[op.channel] = len(q)
                else:
                    if spans is not None and op.cycles:
                        spans.append((e, "busy", clock[e], op.cycles,
                                      None, op.instr))
                    t.busy += op.cycles
                    clock[e] += op.cycles
                idx[e] += 1
                progressed = True

    if any(idx[e] < len(streams[e]) for e in streams):
        stuck = {e: (idx[e], len(streams[e])) for e in streams}
        raise DeadlockError(f"engines deadlocked at {stuck}")

    for e in streams:
        traces[e].finish = clock[e]
    total = max(clock.values()) if clock else 0
    return SimResult(total_cycles=total, traces=traces, n_instructions=n_instr)


def _is_wait(op: Op) -> bool:
    return isinstance(op.instr, isa.SyncInstr) and op.instr.is_wait == 1


def _dma_cycles(n_bytes: float, dev: FPGADevice) -> int:
    return int(math.ceil(n_bytes / dev.dma_bytes_per_cycle)) + dev.dma_setup_cycles


# ---------------------------------------------------------------------------
# Stream generation — thin wrappers over the NN→ISA compiler
# ---------------------------------------------------------------------------


def lut_core_streams(g: GemmDims, cfg: LutCoreConfig, dev: FPGADevice,
                     bits_w: int, bits_a: int, depthwise: bool = False
                     ) -> tuple[dict[str, list[Op]], dict[str, int]]:
    """Instruction streams for one layer partition on the LUT-core.

    Delegates to ``repro.compiler.lower.lower_lut_layer`` — the compiler
    owns the Fig.-3 schedule; this wrapper keeps the historical
    (streams, initial_tokens) shape the simulator entry points consume.
    """
    from repro.compiler.lower import lower_lut_layer
    cp = lower_lut_layer(g, cfg, dev, bits_w, bits_a, depthwise)
    return cp.streams, cp.initial_tokens


def dsp_core_streams(g: GemmDims, cfg: DspCoreConfig, dev: FPGADevice,
                     depthwise: bool = False
                     ) -> tuple[dict[str, list[Op]], dict[str, int]]:
    """Instruction streams for one layer partition on the DSP-core.

    Delegates to ``repro.compiler.lower.lower_dsp_layer`` (see
    ``lut_core_streams``).
    """
    from repro.compiler.lower import lower_dsp_layer
    cp = lower_dsp_layer(g, cfg, dev, depthwise)
    return cp.streams, cp.initial_tokens


# ---------------------------------------------------------------------------
# Entry points used by the latency model
# ---------------------------------------------------------------------------


def simulate_lut_core(g: GemmDims, cfg: LutCoreConfig, dev: FPGADevice,
                      bits_w: int, bits_a: int, depthwise: bool = False) -> SimResult:
    if g.n == 0 or g.m == 0 or g.k == 0:
        return SimResult(0, {"fetch": EngineTrace(), "execute": EngineTrace(),
                             "result": EngineTrace()}, 0)
    streams, init = lut_core_streams(g, cfg, dev, bits_w, bits_a, depthwise)
    return simulate(streams, init)


def simulate_dsp_core(g: GemmDims, cfg: DspCoreConfig, dev: FPGADevice,
                      depthwise: bool = False) -> SimResult:
    if g.n == 0 or g.m == 0 or g.k == 0:
        return SimResult(0, {"fetch": EngineTrace(), "execute": EngineTrace(),
                             "result": EngineTrace()}, 0)
    streams, init = dsp_core_streams(g, cfg, dev, depthwise)
    return simulate(streams, init)


# ---------------------------------------------------------------------------
# Compiled-Program simulation
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class LayerSim:
    """Per-layer simulation of a compiled program layer: both cores run
    concurrently, the layer's makespan is their max (Eq. 10 inner term)."""
    name: str
    lut: SimResult | None
    dsp: SimResult | None
    # per-core SimTrace objects when the sim ran with tracing on
    traces: dict | None = dataclasses.field(
        default=None, compare=False, repr=False)

    @property
    def cycles(self) -> int:
        return max((r.total_cycles for r in (self.lut, self.dsp)
                    if r is not None), default=0)


@dataclasses.dataclass
class ProgramSim:
    layers: list[LayerSim]

    @property
    def total_cycles(self) -> int:
        """Eq. (10): inter-layer synchronous sum of per-layer makespans."""
        return sum(ls.cycles for ls in self.layers)

    @property
    def n_instructions(self) -> int:
        return sum(r.n_instructions for ls in self.layers
                   for r in (ls.lut, ls.dsp) if r is not None)

    def decomposition(self, core: str) -> dict[str, int]:
        """Aggregate Eq. (6)/(8) terms over layers for one core."""
        agg = {"l_wait": 0, "l_run": 0, "l_sig": 0, "l_rst": 0}
        for ls in self.layers:
            r = getattr(ls, core)
            if r is None:
                continue
            agg["l_wait"] += r.l_wait
            agg["l_run"] += r.l_run
            agg["l_sig"] += r.l_sig
            agg["l_rst"] += r.l_rst
        return agg


@dataclasses.dataclass
class DecodeSim:
    """Decode-mode timing of a step program (``Program.step`` set).

    One generated token costs ``warmup_cycles`` on the first invocation
    (weights stream in from DDR) and ``steady_cycles`` afterwards (the
    ``weights``-resident segments stay on chip; only the new token's
    activations and the persistent kv/state rows move). ``total_cycles``
    is the warm-up invocation so fixed-seq comparisons stay meaningful;
    :meth:`tokens_cycles` scores an ``n``-token generation.
    """
    warmup: ProgramSim
    steady: ProgramSim

    @property
    def warmup_cycles(self) -> int:
        return self.warmup.total_cycles

    @property
    def steady_cycles(self) -> int:
        return self.steady.total_cycles

    @property
    def total_cycles(self) -> int:
        return self.warmup.total_cycles

    def tokens_cycles(self, n_tokens: int) -> int:
        """Cycles to generate ``n_tokens`` (warm-up + steady steps)."""
        return (self.warmup_cycles
                + max(0, n_tokens - 1) * self.steady_cycles)

    # ProgramSim-compatible surface (reports describe the warm-up pass)
    @property
    def layers(self) -> list[LayerSim]:
        return self.warmup.layers

    @property
    def n_instructions(self) -> int:
        return self.warmup.n_instructions

    def decomposition(self, core: str) -> dict[str, int]:
        return self.warmup.decomposition(core)


def simulate_layers(prog, collect_traces: bool = False) -> list[LayerSim]:
    """Event-driven sim of every layer of a single-device program.

    With ``collect_traces`` each :class:`LayerSim` carries per-core
    :class:`LazySimTrace` handles (``repro.obs`` consumes them); the
    timed sim itself stays on the plain fast path — span capture
    replays on first access.
    """
    layers = []
    for lp in prog.layers:
        sims, traces = {}, {}
        for attr in ("lut", "dsp"):
            cp = getattr(lp, attr)
            if cp is None:
                sims[attr] = None
                continue
            # sim_tokens() arms inter-layer barrier waits at t=0: under
            # the Eq.-10 synchronous chain the previous layer has drained.
            tokens = cp.sim_tokens()
            sims[attr] = simulate(cp.streams, tokens)
            if collect_traces:
                traces[attr] = LazySimTrace(cp.streams, tokens)
        layers.append(LayerSim(lp.name, sims["lut"], sims["dsp"],
                               traces=traces or None))
    return layers


def record_program_trace(tracer, device: int, name: str, prog, layers,
                         offset: int = 0,
                         windows: list[int] | None = None) -> int:
    """Feed simulated layers into a ``repro.obs.Tracer``.

    One ``record_layer`` call per placement window; ``windows``
    overrides the per-layer window cycles (bundle *filter* plans share
    the cross-device max per layer, §multi-FPGA), otherwise each
    layer's own makespan is its window. Returns the device-local end
    offset so callers can chain stages.
    """
    tracer.begin_device(device, name)
    for i, (lp, ls) in enumerate(zip(prog.layers, layers)):
        window = ls.cycles if windows is None else windows[i]
        core_results = {}
        for attr in ("lut", "dsp"):
            sim = getattr(ls, attr)
            if sim is None:
                continue
            st = (ls.traces or {}).get(attr)
            core_results[attr] = (sim, st)
            cp = getattr(lp, attr)
            tracer.record_dma(device, attr, cp.bytes_fetched,
                              cp.bytes_written)
        tracer.record_layer(device, lp.index, lp.name, offset, window,
                            core_results)
        offset += window
    return offset


def simulate_program(prog, opt_level: int | None = None,
                     batches: int = 1, tracer=None) -> "ProgramSim":
    """Run a compiled ``repro.compiler.Program`` through the event-driven
    engine model, layer by layer (inter-layer synchronous, §3.1): the
    compiler is the single source of truth for the streams; this is the
    same Fig. 5 ground-truth model the closed forms validate against.

    ``opt_level`` (None = time the program as given) first runs the
    ``repro.compiler.passes`` pipeline at that level, so optimized
    streams are exactly what gets timed — `-O0` vs `-O1` latency deltas
    come from this one entry point.

    A ``repro.compiler.partition.MultiDeviceProgram`` dispatches to the
    cross-device makespan aggregation instead (per-device event-driven
    sims + the plan's link-latency model), returning a ``BundleSim``;
    ``batches`` then sets how many back-to-back inputs the makespan
    covers (pipeline plans overlap them across stages); for a plain
    single-device program ``batches`` is ignored (its makespan for B
    inputs is just ``B * total_cycles``).

    ``tracer`` (a ``repro.obs.Tracer``; default off) records
    per-instruction spans and cycle-accounted counters while
    simulating — the trace *decomposes* the returned makespan, it never
    changes it.
    """
    tracing = tracer is not None and getattr(tracer, "enabled", False)
    if hasattr(prog, "devices"):     # MultiDeviceProgram bundle
        from repro.compiler.partition import optimize_bundle, simulate_bundle
        if opt_level is not None:
            prog = optimize_bundle(prog, opt_level, validate=False)
        return simulate_bundle(prog, batches=batches,
                               tracer=tracer if tracing else None)
    if opt_level is not None:
        from repro.compiler.passes import optimize_program
        prog = optimize_program(prog, opt_level, validate=False)
    if getattr(prog, "step", None) is not None:
        # decode-mode step program: report warm-up vs steady state; the
        # trace lays the two invocations back to back on the timeline
        from repro.compiler.lower import steady_program
        steady = steady_program(prog)
        warm = ProgramSim(simulate_layers(prog, collect_traces=tracing))
        ssim = ProgramSim(simulate_layers(steady, collect_traces=tracing))
        ds = DecodeSim(warmup=warm, steady=ssim)
        if tracing:
            end = record_program_trace(tracer, 0, prog.device.name, prog,
                                       warm.layers)
            end = record_program_trace(tracer, 0, prog.device.name, steady,
                                       ssim.layers, offset=end)
            tracer.set_makespan(end)
        return ds
    ps = ProgramSim(simulate_layers(prog, collect_traces=tracing))
    if tracing:
        record_program_trace(tracer, 0, prog.device.name, prog, ps.layers)
        tracer.set_makespan(ps.total_cycles)
    return ps
