"""DNN workload descriptions for the cost/latency models.

Each parametric layer (conv / depthwise-conv / fully-connected) is
lowered to GEMM dimensions via im2col (§3.2.1): the computation of one
layer is ``out[M_g, N_g] = act[M_g, K_g] @ wgt[K_g, N_g]`` with

    M_g = OH * OW (batch 1),  K_g = C_in * kh * kw,  N_g = C_out.

Depthwise layers have no input-channel reuse: each output channel is an
independent (OH*OW, kh*kw) x (kh*kw, 1) GEMM, which both cores execute
with only one active output column — this is what makes the LUT-core
"not efficient to compute depth-wise layers" (§6.2.2) and the model
reproduces it structurally.

Workload zoo: ResNet-18 and MobileNet-V2 at 224x224 (the paper's two
evaluation networks) plus helpers to derive layer lists for the LM
architectures (used by the TPU-side cost model).
"""
from __future__ import annotations

import dataclasses

from repro.core.scheduler import GemmDims


def pooled_hw(out_hw: int, pool: str) -> int:
    """Feature-map size after a layer's pooling glue — the single
    shape rule shared by ``ConvSpec``, the compiler's ``ConvGeometry``
    and the executors' ``apply_pool`` data transform. ``"max"`` is the
    ResNet stem's 3x3 stride-2 SAME max pool; ``"gap"`` the global
    average pool; ``""`` the identity."""
    if pool == "max":
        return (out_hw + 1) // 2
    if pool == "gap":
        return 1
    return out_hw


@dataclasses.dataclass(frozen=True)
class ConvSpec:
    """One parametric layer. ``depthwise`` implies groups == c_in == c_out."""
    name: str
    c_in: int
    c_out: int
    kernel: int
    stride: int
    in_hw: int                  # square input feature map size
    depthwise: bool = False
    is_first: bool = False
    is_last: bool = False
    shortcut: bool = False      # 1x1 downsample projection (ResNet)
    # Spatial glue applied to this layer's *output* before the next
    # layer reads it: "" (none), "max" (3x3 stride-2 SAME max pool, the
    # ResNet stem) or "gap" (global average pool before the classifier).
    pool: str = ""
    # Elementwise tail the layer applies to its own output: activation
    # kind ("", "relu", "relu6", "hswish") and, for residual layers,
    # the distance back to the add operand's producer (0 = no residual;
    # ResNet conv_b adds 2 back, MobileNet pw adds 3 back). These lower
    # into the program's fused elementwise stage.
    act: str = ""
    res_src: int = 0

    @property
    def out_hw(self) -> int:
        if self.kernel == 1 and self.in_hw == 1:
            return 1
        pad = self.kernel // 2
        return (self.in_hw + 2 * pad - self.kernel) // self.stride + 1

    @property
    def pooled_out_hw(self) -> int:
        """Feature-map size the *next* layer reads (after ``pool``)."""
        return pooled_hw(self.out_hw, self.pool)

    def gemm(self) -> GemmDims:
        m = self.out_hw * self.out_hw
        if self.depthwise:
            return GemmDims(m=m, k=self.kernel * self.kernel, n=self.c_out)
        return GemmDims(m=m, k=self.c_in * self.kernel * self.kernel, n=self.c_out)

    def macs(self) -> int:
        g = self.gemm()
        if self.depthwise:
            return g.m * g.k * g.n  # each column only sees its own k*k
        return g.macs()

    @property
    def n_params(self) -> int:
        if self.depthwise:
            return self.c_out * self.kernel * self.kernel
        return self.c_in * self.c_out * self.kernel * self.kernel


def resnet18_specs() -> list[ConvSpec]:
    """ResNet-18 @224. Layer indices match the paper's Fig. 9/10 numbering
    (downsample projections land at layers 8, 13, 18)."""
    specs: list[ConvSpec] = [
        ConvSpec("conv1", 3, 64, 7, 2, 224, is_first=True, pool="max",
                 act="relu"),
    ]

    def block(idx, c_in, c_out, stride, hw, ds=False):
        # conv_a applies relu; conv_b carries the residual add + relu,
        # unless a downsample projection follows the block — then the
        # projection carries them (relu(conv_b + ds(x))) and conv_b
        # writes its raw pre-activation output.
        out = [
            ConvSpec(f"conv{idx}", c_in, c_out, 3, stride, hw, act="relu"),
            ConvSpec(f"conv{idx+1}", c_out, c_out, 3, 1, hw // stride,
                     act="" if ds else "relu", res_src=0 if ds else 2),
        ]
        return out

    # layer1: 56x56, 64ch
    specs += block(2, 64, 64, 1, 56)
    specs += block(4, 64, 64, 1, 56)
    # layer2: 64 -> 128, stride 2; downsample at index 8
    specs += block(6, 64, 128, 2, 56, ds=True)
    specs.append(ConvSpec("conv8_ds", 64, 128, 1, 2, 56, shortcut=True,
                          act="relu", res_src=1))
    specs += block(9, 128, 128, 1, 28)
    # layer3: 128 -> 256; downsample at index 13
    specs += block(11, 128, 256, 2, 28, ds=True)
    specs.append(ConvSpec("conv13_ds", 128, 256, 1, 2, 28, shortcut=True,
                          act="relu", res_src=1))
    specs += block(14, 256, 256, 1, 14)
    # layer4: 256 -> 512; downsample at index 18
    specs += block(16, 256, 512, 2, 14, ds=True)
    specs.append(ConvSpec("conv18_ds", 256, 512, 1, 2, 14, shortcut=True,
                          act="relu", res_src=1))
    specs += block(19, 512, 512, 1, 7)
    # global average pool feeds the classifier, a 1x1 "conv" on a 1x1 map
    specs[-1] = dataclasses.replace(specs[-1], pool="gap")
    specs.append(ConvSpec("fc", 512, 1000, 1, 1, 1, is_last=True))
    return specs


def mobilenet_v2_specs() -> list[ConvSpec]:
    """MobileNet-V2 @224 (width 1.0): 52 convs + classifier."""
    specs: list[ConvSpec] = [ConvSpec("conv0", 3, 32, 3, 2, 224,
                                      is_first=True, act="relu")]
    hw = 112

    # t=1 bottleneck
    specs.append(ConvSpec("b0_dw", 32, 32, 3, 1, hw, depthwise=True,
                          act="relu6"))
    specs.append(ConvSpec("b0_pw", 32, 16, 1, 1, hw))

    cfg = [  # (expansion t, c_out, repeats, stride)
        (6, 24, 2, 2),
        (6, 32, 3, 2),
        (6, 64, 4, 2),
        (6, 96, 3, 1),
        (6, 160, 3, 2),
        (6, 320, 1, 1),
    ]
    c_in = 16
    bi = 1
    for t, c, n, s in cfg:
        for r in range(n):
            stride = s if r == 0 else 1
            hidden = c_in * t
            specs.append(ConvSpec(f"b{bi}_exp", c_in, hidden, 1, 1, hw,
                                  act="relu"))
            specs.append(ConvSpec(f"b{bi}_dw", hidden, hidden, 3, stride, hw,
                                  depthwise=True, act="relu6"))
            hw = hw // stride
            # Linear bottleneck: no activation after the projection; the
            # inverted residual adds the block input (3 layers back) on
            # the repeats where stride == 1 and channels match.
            specs.append(ConvSpec(f"b{bi}_pw", hidden, c, 1, 1, hw,
                                  res_src=3 if r > 0 else 0))
            c_in = c
            bi += 1

    specs.append(ConvSpec("conv_last", 320, 1280, 1, 1, hw, pool="gap",
                          act="relu"))
    specs.append(ConvSpec("fc", 1280, 1000, 1, 1, 1, is_last=True))
    return specs


WORKLOADS = {
    "resnet18": resnet18_specs,
    "mobilenet_v2": mobilenet_v2_specs,
}


def total_macs(specs: list[ConvSpec]) -> int:
    return sum(s.macs() for s in specs)


def total_gops(specs: list[ConvSpec]) -> float:
    """GOPs counting one MAC as 2 ops (the convention of Table 4)."""
    return 2.0 * total_macs(specs) / 1e9


def split_gemm(spec: ConvSpec, n_lut: int) -> tuple[GemmDims, GemmDims]:
    """Partition a layer's GEMM along output filters (Eq. 11): the first
    ``n_lut`` filters to the LUT-core, the rest to the DSP-core."""
    g = spec.gemm()
    n_lut = int(min(max(n_lut, 0), g.n))
    lut = GemmDims(m=g.m, k=g.k, n=n_lut)
    dsp = GemmDims(m=g.m, k=g.k, n=g.n - n_lut)
    return lut, dsp
