"""Latency models — paper Eqs. (6)-(10).

Two levels of fidelity, both derived from the same Fig.-3 pipeline:

  * ``simulate_*`` (in scheduler.py) — event-driven instruction-stream
    simulation; the ground truth (the paper validates its model against
    hardware at <2% error; we validate the closed form against this
    simulator — the Fig. 5 reproduction).
  * ``lut_core_latency`` / ``dsp_core_latency`` — closed-form cycle
    counts, vectorizable over candidate workload splits, used inside the
    DSE loops (Eq. 7 / Eq. 9 simplifications).

Network latency is inter-layer synchronous (Eq. 10):

    Latency = sum_i max(L_LUT^i, L_DSP^i)
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.scheduler import (
    DspCoreConfig,
    FPGADevice,
    LutCoreConfig,
    simulate_dsp_core,
    simulate_lut_core,
)
from repro.core.workloads import ConvSpec, split_gemm


def _dma(n_bytes, dev: FPGADevice):
    return np.ceil(n_bytes / dev.dma_bytes_per_cycle) + dev.dma_setup_cycles


# ---------------------------------------------------------------------------
# Closed-form LUT-core latency — Eq. (9):
#   L_LUT = f(B_a, B_wL, M, K, N, D_L,buf^a)
# ---------------------------------------------------------------------------

def lut_core_latency(g_m, g_k, g_n, cfg: LutCoreConfig, dev: FPGADevice,
                     bits_w, bits_a, depthwise: bool = False):
    """Closed-form cycles for the LUT-core partition. Vectorized: any of
    the GEMM dims / bit-widths may be numpy arrays."""
    g_m, g_k, g_n = np.asarray(g_m), np.asarray(g_k), np.asarray(g_n)
    bits_w, bits_a = np.asarray(bits_w), np.asarray(bits_a)

    nt_m = np.ceil(g_m / cfg.m)
    nt_n = np.ceil(g_n / cfg.n)
    if depthwise:
        tile_exec = np.ceil(g_k * bits_w * bits_a /
                            (cfg.k * cfg.dw_efficiency)) + cfg.pipeline_fill
        bytes_l = g_m * g_n * bits_a / 8.0          # NHWC, no channel reuse
        bytes_r_tile = g_k * cfg.n * bits_w / 8.0
    else:
        nt_k = np.ceil(g_k / cfg.k)
        tile_exec = nt_k * bits_w * bits_a + cfg.pipeline_fill
        bytes_l = g_m * g_k * bits_a / 8.0
        bytes_r_tile = cfg.n * g_k * bits_w / 8.0
    bytes_out_tile = cfg.m * cfg.n * bits_a / 8.0   # requantized write-back

    # Activation residency (see scheduler.lut_core_streams): when the
    # serialized L matrix exceeds the M x D_a x K-bit buffer pool it is
    # re-streamed once per weight column tile.
    a_capacity_bits = cfg.m * cfg.d_a * cfg.k
    a_resident = bytes_l * 8 <= a_capacity_bits

    dma_r = _dma(bytes_r_tile, dev)
    dma_l = _dma(bytes_l, dev)
    dma_out = _dma(bytes_out_tile, dev)

    t_start = dma_r + dma_l + 4
    per_col_exec = nt_m * (tile_exec + 2) + 2
    exec_span = nt_n * per_col_exec
    # Fetch engine must move every byte; when it is the bottleneck the
    # makespan is its total footprint plus the last column's compute tail.
    per_col_fetch = dma_r + 2 + np.where(a_resident, 0.0, dma_l + 2)
    fetch_total = t_start + np.maximum(nt_n - 1, 0) * per_col_fetch \
        + per_col_exec
    res_span = nt_m * nt_n * (dma_out + 2)
    total = np.maximum(
        t_start + np.maximum(exec_span, res_span),
        fetch_total,
    ) + dma_out + 2
    return np.where(g_n <= 0, 0.0, total)


# ---------------------------------------------------------------------------
# Closed-form DSP-core latency — Eq. (7):
#   L_DSP = g(N_reg,row^a, D_D,buf^a, D_D,buf^w)
# ---------------------------------------------------------------------------

def dsp_core_latency(g_m, g_k, g_n, cfg: DspCoreConfig, dev: FPGADevice,
                     depthwise: bool = False):
    """Closed-form cycles for the DSP-core partition (int4 fixed)."""
    g_m, g_k, g_n = np.asarray(g_m), np.asarray(g_k), np.asarray(g_n)
    R = cfg.n_reg_row_a
    kstep = cfg.w_fill_cycles + cfg.n_reg_col_w + cfg.a_fill_cycles

    nt_m = np.ceil(g_m / R)
    nt_n = np.ceil(g_n / cfg.n_reg_col_w)
    if depthwise:
        tile_exec = np.ceil(g_k * kstep /
                            (cfg.n_reg_col_a * cfg.dw_efficiency))
        bytes_a_tile = R * cfg.n_reg_col_w * 4 / 8.0
        bytes_w_tile = g_k * cfg.n_reg_col_w * 4 / 8.0
    else:
        nt_k = np.ceil(g_k / cfg.n_reg_col_a)
        tile_exec = nt_k * kstep
        bytes_a_tile = R * g_k * 4 / 8.0
        bytes_w_tile = g_k * cfg.n_reg_col_w * 4 / 8.0
    bytes_out_tile = R * cfg.n_reg_col_w * 4 / 8.0

    w_capacity_bits = (cfg.n_reg_col_w // 2) * cfg.d_w * (cfg.n_reg_col_a * 4)
    w_resident = nt_n * bytes_w_tile * 8 <= w_capacity_bits

    dma_a = _dma(bytes_a_tile, dev)
    dma_w = _dma(bytes_w_tile, dev)
    dma_out = _dma(bytes_out_tile, dev)

    dma_wall = _dma(nt_n * bytes_w_tile, dev)
    w_resident = np.asarray(w_resident)
    per_mtile_exec = nt_n * (tile_exec + 2) + np.where(w_resident, 2, 2 + nt_n)
    t_start = np.where(w_resident, dma_wall + dma_a + 4, dma_a + 2)
    per_mtile_fetch = np.where(w_resident, dma_a + 2,
                               dma_a + 2 + nt_n * (dma_w + 2))

    exec_span = nt_m * per_mtile_exec
    fetch_total = t_start + np.maximum(nt_m - 1, 0) * per_mtile_fetch \
        + per_mtile_exec
    res_span = nt_m * nt_n * (dma_out + 2)
    total = np.maximum(
        t_start + np.maximum(exec_span, res_span),
        fetch_total,
    ) + dma_out + 2
    return np.where(g_n <= 0, 0.0, total)


# ---------------------------------------------------------------------------
# Layer / network latency (Eq. 10)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class LayerLatency:
    name: str
    cycles_lut: float
    cycles_dsp: float
    n_lut: int
    n_total: int

    @property
    def cycles(self) -> float:
        return max(self.cycles_lut, self.cycles_dsp)

    @property
    def ratio(self) -> float:
        return self.n_lut / max(self.n_total, 1)


def layer_latency(spec: ConvSpec, n_lut: int, lut_cfg: LutCoreConfig,
                  dsp_cfg: DspCoreConfig, dev: FPGADevice,
                  bits_w_lut: int, bits_a: int,
                  use_simulator: bool = False) -> LayerLatency:
    """Latency of one layer under a filter split (Eq. 12 inner term)."""
    g_lut, g_dsp = split_gemm(spec, n_lut)
    if use_simulator:
        c_lut = simulate_lut_core(g_lut, lut_cfg, dev, bits_w_lut, bits_a,
                                  spec.depthwise).total_cycles
        c_dsp = simulate_dsp_core(g_dsp, dsp_cfg, dev,
                                  spec.depthwise).total_cycles
    else:
        c_lut = float(lut_core_latency(g_lut.m, g_lut.k, g_lut.n, lut_cfg, dev,
                                       bits_w_lut, bits_a, spec.depthwise))
        c_dsp = float(dsp_core_latency(g_dsp.m, g_dsp.k, g_dsp.n, dsp_cfg, dev,
                                       spec.depthwise))
    return LayerLatency(spec.name, c_lut, c_dsp, n_lut, spec.gemm().n)


def network_latency(specs: list[ConvSpec], n_luts: list[int],
                    bits_w_lut: list[int], bits_a: list[int],
                    lut_cfg: LutCoreConfig, dsp_cfg: DspCoreConfig,
                    dev: FPGADevice) -> tuple[float, list[LayerLatency]]:
    """Eq. (10): sum over layers of max(L_LUT, L_DSP). Returns (ms, per-layer)."""
    per_layer = []
    cycles = 0.0
    for spec, n_lut, bw, ba in zip(specs, n_luts, bits_w_lut, bits_a):
        ll = layer_latency(spec, n_lut, lut_cfg, dsp_cfg, dev, bw, ba)
        per_layer.append(ll)
        cycles += ll.cycles
    return dev.cycles_to_ms(cycles), per_layer
