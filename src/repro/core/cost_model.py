"""Resource cost models — paper Eqs. (3)-(5).

The cost model maps an architecture configuration (Table 1 knobs) to
{LUT, BRAM, DSP} utilization, and checks it against the device pool.
Coefficients for the LUT-core are the paper's fitted values
{a, b, c, d} = {1.17, 120.1, 44.1, 718}.
"""
from __future__ import annotations

import dataclasses
import math

from repro.core.scheduler import DspCoreConfig, FPGADevice, LutCoreConfig

# Paper's fitted coefficients for Eq. (4).
LUT_COEF_A = 1.17
LUT_COEF_B = 120.1
LUT_COEF_C = 44.1
LUT_COEF_D = 718

# LUT budget of the DSP-core control/instruction logic (constant, §3.3).
LUT_DSP_CORE = 1000

BRAM_DEPTH = 1024   # BRAMs are 1024-deep
BRAM_WIDTH = 32     # 36-bit wide, 32 used


def lut_cost_lut_core(m: int, k: int, n: int) -> float:
    """Eq. (4): LUT_L-core(M, K, N) = M*N*(aK + b + c) + d."""
    return m * n * (LUT_COEF_A * k + LUT_COEF_B + LUT_COEF_C) + LUT_COEF_D


def bram_cost_lut_core(m: int, k: int, n: int, d_a: int, d_w: int) -> int:
    """Eq. (5): BRAM_L-core = ceil(K/32) * (M*ceil(Da/1024) + N*ceil(Dw/1024))."""
    return math.ceil(k / BRAM_WIDTH) * (
        m * math.ceil(d_a / BRAM_DEPTH) + n * math.ceil(d_w / BRAM_DEPTH))


def bram_cost_dsp_core(n_reg_row_a: int, n_reg_col_a: int, n_reg_col_w: int,
                       d_a: int, d_w: int) -> int:
    """Eq. (3). One activation buffer spans ceil(Nrow_a*4/32) BRAM columns
    (4-bit padded activations); there are Ncol_a activation buffers and
    Ncol_w/2 weight buffers (one buffer feeds two register columns)."""
    width_brams = math.ceil(n_reg_row_a * 4 / BRAM_WIDTH)
    act = n_reg_col_a * math.ceil(d_a / BRAM_DEPTH)
    wgt = (n_reg_col_w // 2) * math.ceil(d_w / BRAM_DEPTH)
    return width_brams * (act + wgt)


@dataclasses.dataclass(frozen=True)
class ResourceReport:
    luts: float
    brams: int
    dsps: int
    lut_core_luts: float
    lut_core_brams: int
    dsp_core_brams: int

    def fits(self, dev: FPGADevice) -> bool:
        return (self.luts <= dev.luts and self.brams <= dev.bram36
                and self.dsps <= dev.dsps)

    def utilization(self, dev: FPGADevice) -> dict[str, float]:
        return {"lut": self.luts / dev.luts,
                "bram": self.brams / dev.bram36,
                "dsp": self.dsps / dev.dsps}


def system_cost(lut_cfg: LutCoreConfig, dsp_cfg: DspCoreConfig,
                dev: FPGADevice) -> ResourceReport:
    """Whole-accelerator resource utilization.

    Per §3.3: the DSP-core takes all DSPs (DSP_D-core = DSP_available)
    plus a ~constant 1000 LUTs for control; everything else is LUT-core.
    """
    l_lut = lut_cost_lut_core(lut_cfg.m, lut_cfg.k, lut_cfg.n)
    b_lut = bram_cost_lut_core(lut_cfg.m, lut_cfg.k, lut_cfg.n,
                               lut_cfg.d_a, lut_cfg.d_w)
    b_dsp = bram_cost_dsp_core(dsp_cfg.n_reg_row_a, dsp_cfg.n_reg_col_a,
                               dsp_cfg.n_reg_col_w, dsp_cfg.d_a, dsp_cfg.d_w)
    return ResourceReport(
        luts=l_lut + LUT_DSP_CORE,
        brams=b_lut + b_dsp,
        dsps=dev.dsps,  # fully allocated at design time
        lut_core_luts=l_lut,
        lut_core_brams=b_lut,
        dsp_core_brams=b_dsp,
    )


def max_lut_core_mn(dev: FPGADevice, k: int) -> int:
    """Largest M*N product the LUT budget admits for a given K (used to
    prune the DSE action space)."""
    per_dpu = LUT_COEF_A * k + LUT_COEF_B + LUT_COEF_C
    budget = dev.luts - LUT_DSP_CORE - LUT_COEF_D
    return max(0, int(budget // per_dpu))
