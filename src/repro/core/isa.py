"""The unified 128-bit Instruction Set Architecture of N3H-Core (§3.1).

Both the DSP- and LUT-core execute the same four instruction kinds:

  * ``Fetch``   — DMA a region from DDR into an on-chip buffer.
  * ``Execute`` — run a GEMM tile on the core's compute array.
  * ``Result``  — DMA a finished output tile from the result buffer to DDR.
  * ``Sync``    — post/await a synchronization token between engines
                  (intra-layer asynchronous, inter-layer synchronous).

Per the paper, every instruction is 128 bits. Fetch/Result carry
{on-chip base (16b), stage control (3b), on-chip r/w range (1b)} and
{DDR base (32b), DDR offset (24b), DDR r/w range (16b)}. Execute carries
the on-chip operand addresses plus the GEMM-core tile parameters of
Table 1. Sync carries the current state (1b), next state (2b) of each
engine and a 3-bit token flag.

This module gives a bit-exact encode/decode used by the scheduler and
covered by round-trip property tests.
"""
from __future__ import annotations

import dataclasses
import enum


WORD_BITS = 128


class Opcode(enum.IntEnum):
    FETCH = 0
    EXECUTE = 1
    RESULT = 2
    SYNC = 3


class Engine(enum.IntEnum):
    FETCH = 0
    EXECUTE = 1
    RESULT = 2


class CoreSel(enum.IntEnum):
    LUT = 0
    DSP = 1


# ---------------------------------------------------------------------------
# Bit-packing helpers
# ---------------------------------------------------------------------------

class _Packer:
    """LSB-first field packer for a fixed-width word."""

    def __init__(self):
        self.value = 0
        self.pos = 0

    def put(self, v: int, width: int, name: str = "") -> "_Packer":
        if v < 0 or v >= (1 << width):
            raise ValueError(f"field {name!r}={v} does not fit in {width} bits")
        self.value |= (v & ((1 << width) - 1)) << self.pos
        self.pos += width
        if self.pos > WORD_BITS:
            raise ValueError("instruction overflows 128 bits")
        return self


class _Unpacker:
    def __init__(self, word: int):
        self.word = word
        self.pos = 0

    def get(self, width: int) -> int:
        v = (self.word >> self.pos) & ((1 << width) - 1)
        self.pos += width
        return v


# ---------------------------------------------------------------------------
# Instruction dataclasses
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class FetchInstr:
    """DMA DDR -> on-chip buffer."""
    core: CoreSel
    onchip_base: int      # 16b — target buffer word address
    stage_ctrl: int       # 3b  — which pipeline stage the data feeds
    onchip_range: int     # 1b  — buffer half-select (double buffering)
    ddr_base: int         # 32b
    ddr_offset: int       # 24b
    ddr_range: int        # 16b — transfer length (beats)

    opcode = Opcode.FETCH

    def encode(self) -> int:
        p = _Packer()
        p.put(int(Opcode.FETCH), 2, "opcode")
        p.put(int(self.core), 1, "core")
        p.put(self.onchip_base, 16, "onchip_base")
        p.put(self.stage_ctrl, 3, "stage_ctrl")
        p.put(self.onchip_range, 1, "onchip_range")
        p.put(self.ddr_base, 32, "ddr_base")
        p.put(self.ddr_offset, 24, "ddr_offset")
        p.put(self.ddr_range, 16, "ddr_range")
        return p.value


@dataclasses.dataclass(frozen=True)
class ResultInstr:
    """DMA result buffer -> DDR."""
    core: CoreSel
    onchip_base: int
    stage_ctrl: int
    onchip_range: int
    ddr_base: int
    ddr_offset: int
    ddr_range: int

    opcode = Opcode.RESULT

    def encode(self) -> int:
        p = _Packer()
        p.put(int(Opcode.RESULT), 2, "opcode")
        p.put(int(self.core), 1, "core")
        p.put(self.onchip_base, 16, "onchip_base")
        p.put(self.stage_ctrl, 3, "stage_ctrl")
        p.put(self.onchip_range, 1, "onchip_range")
        p.put(self.ddr_base, 32, "ddr_base")
        p.put(self.ddr_offset, 24, "ddr_offset")
        p.put(self.ddr_range, 16, "ddr_range")
        return p.value


@dataclasses.dataclass(frozen=True)
class ExecuteInstr:
    """Run one GEMM tile. Tile params mirror Table 1 knobs."""
    core: CoreSel
    buf_addr_a: int   # 16b — activation buffer read base
    buf_addr_w: int   # 16b — weight buffer read base
    tile_m: int       # 12b
    tile_k: int       # 16b
    tile_n: int       # 12b
    bits_w: int       # 4b  — weight bit-width (LUT-core serial passes)
    bits_a: int       # 4b  — activation bit-width
    accumulate: int   # 1b  — accumulate onto existing partial sum

    opcode = Opcode.EXECUTE

    def encode(self) -> int:
        p = _Packer()
        p.put(int(Opcode.EXECUTE), 2, "opcode")
        p.put(int(self.core), 1, "core")
        p.put(self.buf_addr_a, 16, "buf_addr_a")
        p.put(self.buf_addr_w, 16, "buf_addr_w")
        p.put(self.tile_m, 12, "tile_m")
        p.put(self.tile_k, 16, "tile_k")
        p.put(self.tile_n, 12, "tile_n")
        p.put(self.bits_w, 4, "bits_w")
        p.put(self.bits_a, 4, "bits_a")
        p.put(self.accumulate, 1, "accumulate")
        return p.value


@dataclasses.dataclass(frozen=True)
class SyncInstr:
    """Token-based engine handshake (SE / WF / WE of Fig. 3)."""
    core: CoreSel
    src_engine: Engine
    dst_engine: Engine
    cur_state: int     # 1b
    next_state: int    # 2b
    token_flag: int    # 3b
    is_wait: int       # 1b — 1: consume token (wait), 0: produce token (send)

    opcode = Opcode.SYNC

    def encode(self) -> int:
        p = _Packer()
        p.put(int(Opcode.SYNC), 2, "opcode")
        p.put(int(self.core), 1, "core")
        p.put(int(self.src_engine), 2, "src_engine")
        p.put(int(self.dst_engine), 2, "dst_engine")
        p.put(self.cur_state, 1, "cur_state")
        p.put(self.next_state, 2, "next_state")
        p.put(self.token_flag, 3, "token_flag")
        p.put(self.is_wait, 1, "is_wait")
        return p.value


Instr = FetchInstr | ResultInstr | ExecuteInstr | SyncInstr


def decode(word: int) -> Instr:
    """Decode a 128-bit word back into its instruction dataclass."""
    if word < 0 or word >= (1 << WORD_BITS):
        raise ValueError("not a 128-bit word")
    u = _Unpacker(word)
    op = Opcode(u.get(2))
    core = CoreSel(u.get(1))
    if op in (Opcode.FETCH, Opcode.RESULT):
        cls = FetchInstr if op == Opcode.FETCH else ResultInstr
        return cls(
            core=core,
            onchip_base=u.get(16),
            stage_ctrl=u.get(3),
            onchip_range=u.get(1),
            ddr_base=u.get(32),
            ddr_offset=u.get(24),
            ddr_range=u.get(16),
        )
    if op == Opcode.EXECUTE:
        return ExecuteInstr(
            core=core,
            buf_addr_a=u.get(16),
            buf_addr_w=u.get(16),
            tile_m=u.get(12),
            tile_k=u.get(16),
            tile_n=u.get(12),
            bits_w=u.get(4),
            bits_a=u.get(4),
            accumulate=u.get(1),
        )
    return SyncInstr(
        core=core,
        src_engine=Engine(u.get(2)),
        dst_engine=Engine(u.get(2)),
        cur_state=u.get(1),
        next_state=u.get(2),
        token_flag=u.get(3),
        is_wait=u.get(1),
    )
