"""Shared structured-metrics registry for serving and DSE drivers.

A :class:`MetricsRegistry` holds three primitive kinds:

* **counters** — monotonically increasing integers
  (``serve.program_cache.hit``);
* **gauges** — last-write-wins values (``dse.best_reward``);
* **observations** — value series with derived count/sum/min/max/mean
  (``serve.request.prefill_ms``, ``dse.episode.latency_ms``).

All operations are thread-safe (serving uses the registry from the
cache and request paths concurrently). Export is CSV or JSON, and
``from_json`` round-trips a snapshot — the tested contract that lets
``SearchResult.metrics`` and serve summaries be persisted and diffed.
"""
from __future__ import annotations

import io
import json
import math
import threading


class MetricsRegistry:
    """Named counters / gauges / observation series with CSV+JSON export."""

    def __init__(self):
        self._lock = threading.Lock()
        self._counters: dict[str, int] = {}
        self._gauges: dict[str, float] = {}
        self._series: dict[str, list[float]] = {}

    # -- write side ---------------------------------------------------------

    def incr(self, name: str, delta: int = 1) -> None:
        with self._lock:
            self._counters[name] = self._counters.get(name, 0) + delta

    def gauge(self, name: str, value: float) -> None:
        with self._lock:
            self._gauges[name] = float(value)

    def observe(self, name: str, value: float) -> None:
        with self._lock:
            self._series.setdefault(name, []).append(float(value))

    def clear(self) -> None:
        with self._lock:
            self._counters.clear()
            self._gauges.clear()
            self._series.clear()

    # -- read side ----------------------------------------------------------

    def counter(self, name: str) -> int:
        with self._lock:
            return self._counters.get(name, 0)

    def series(self, name: str) -> list[float]:
        with self._lock:
            return list(self._series.get(name, ()))

    def percentile(self, name: str, q: float) -> float:
        """Nearest-rank percentile of an observation series (``q`` in
        [0, 100]); 0.0 for an empty series. Used for the serving
        fleet's p50/p99 latency rows."""
        vals = sorted(self.series(name))
        if not vals:
            return 0.0
        rank = max(1, math.ceil(q / 100.0 * len(vals)))
        return vals[min(rank, len(vals)) - 1]

    def snapshot(self) -> dict:
        """Point-in-time JSON-serializable view (sorted keys throughout)."""
        with self._lock:
            out = {
                "counters": dict(sorted(self._counters.items())),
                "gauges": dict(sorted(self._gauges.items())),
                "observations": {},
            }
            for name in sorted(self._series):
                vals = self._series[name]
                out["observations"][name] = {
                    "count": len(vals),
                    "sum": sum(vals),
                    "min": min(vals),
                    "max": max(vals),
                    "mean": sum(vals) / len(vals),
                    "values": list(vals),
                }
        return out

    # -- export / import ----------------------------------------------------

    def to_json(self) -> str:
        return json.dumps(self.snapshot(), sort_keys=True, indent=2) + "\n"

    def to_csv(self) -> str:
        """Flat ``kind,name,field,value`` rows — one schema for all
        three metric kinds so downstream tooling needs a single parser."""
        buf = io.StringIO()
        buf.write("kind,name,field,value\n")
        snap = self.snapshot()
        for name, v in snap["counters"].items():
            buf.write(f"counter,{name},value,{v}\n")
        for name, v in snap["gauges"].items():
            buf.write(f"gauge,{name},value,{v!r}\n")
        for name, stats in snap["observations"].items():
            for field in ("count", "sum", "min", "max", "mean"):
                buf.write(f"observation,{name},{field},{stats[field]!r}\n")
        return buf.getvalue()

    @classmethod
    def from_json(cls, text: str) -> "MetricsRegistry":
        """Rebuild a registry from :meth:`to_json` output (round-trip:
        ``from_json(r.to_json()).snapshot() == r.snapshot()``)."""
        snap = json.loads(text)
        reg = cls()
        for name, v in snap.get("counters", {}).items():
            reg._counters[name] = int(v)
        for name, v in snap.get("gauges", {}).items():
            reg._gauges[name] = float(v)
        for name, stats in snap.get("observations", {}).items():
            reg._series[name] = [float(x) for x in stats.get("values", ())]
        return reg

    def save(self, path: str) -> None:
        text = self.to_csv() if path.endswith(".csv") else self.to_json()
        with open(path, "w") as fh:
            fh.write(text)


#: process-wide registry — serving and DSE code records here by default
#: so one export captures the whole run.
METRICS = MetricsRegistry()
