"""Chrome trace-event tracer for the N3H-Core stack (Perfetto-loadable).

``Tracer`` is the single sink every layer of the stack writes into:

* the event-driven simulator records per-instruction spans in *cycles*
  (:meth:`record_layer` consumes one ``(SimResult, SimTrace)`` pair per
  core placement window);
* executor backends and the serving/DSE drivers record wall-clock
  spans via :meth:`measure`, so simulated and measured timelines land
  in one file side by side.

The export format is the Chrome trace-event JSON object form
(``{"traceEvents": [...], ...}``) using only ``"X"`` complete events
and ``"M"`` metadata events — the subset every trace viewer
(Perfetto, ``chrome://tracing``) accepts. Track mapping:

* ``pid`` = accelerator device index (one process group per FPGA);
  wall-clock measurements live in the reserved ``pid`` 901 and
  inter-device links in 900;
* ``tid`` = ``core_index * 3 + engine_index`` so each device shows six
  rows: lut/fetch, lut/execute, lut/result, dsp/fetch, … — one track
  per engine per core per device;
* ``ts``/``dur`` are raw simulator cycles for simulated tracks
  (open Perfetto with "µs" read as "cycles") and microseconds for
  measured tracks.

Determinism: span records are kept in issue order, the JSON is dumped
with ``sort_keys=True`` and no timestamps or ids beyond the cycle
numbers themselves, so tracing the same program twice produces
byte-identical files (tested, and safe to check in as goldens).

``NULL_TRACER`` is the shared no-op used when tracing is off: every
hook is a ``pass``/fast-path, so the disabled overhead is the cost of
an attribute check.
"""
from __future__ import annotations

import contextlib
import json
import time

from .counters import CORES, ENGINES, Counters

#: reserved track groups (outside any plausible device count)
LINK_PID = 900       # inter-device channel transfers (pipeline edges)
MEASURED_PID = 901   # wall-clock executor / driver spans

_SPAN_CAT = {"busy": "busy", "sync": "sync", "stall": "stall"}


class Tracer:
    """Collects simulator cycle spans + wall-clock spans, aggregates
    :class:`~repro.obs.counters.Counters`, exports Chrome trace JSON."""

    enabled = True

    def __init__(self):
        self._counters = Counters()
        # ordered accounting-op log: the hooks the timed simulation
        # drives ("layer"/"dma"/"pad") only *append* here — all
        # aggregation (counter sums, span-derived stall causes, queue
        # peaks) replays in finalize(), so the timed path pays a few
        # appends per placement window, nothing per instruction.
        # Op order matters: pad_idle applies to the tracks that exist
        # when it fires, so the replay preserves issue order.
        self._ops: list[tuple] = []
        # (device, core, layer_index, layer_name, offset, SimTrace-like)
        # — span lists are lazy replay handles consumed by to_chrome()
        self._layer_records: list[tuple] = []
        self._link_records: list[dict] = []
        self._measured: list[dict] = []
        self._device_names: dict[int, str] = {}
        self._t0 = time.perf_counter()

    @property
    def counters(self) -> Counters:
        """Aggregated counters; first access finalizes pending records."""
        self.finalize()
        return self._counters

    # -- simulator side (cycles) -------------------------------------------

    def begin_device(self, device: int, name: str) -> None:
        self._device_names.setdefault(device, name)

    def record_layer(self, device: int, layer_index: int, layer_name: str,
                     offset: int, window: int, core_results: dict) -> None:
        """Account one placement window (one layer on one device).

        ``core_results`` maps core name -> ``(SimResult, SimTrace)``
        for the cores present in the layer; ``offset`` is the absolute
        start cycle of the window on this device's timeline.
        """
        self._ops.append(("layer", device, layer_index, layer_name,
                          offset, window, core_results))

    def record_dma(self, device: int, core: str, fetched: int,
                   written: int) -> None:
        self._ops.append(("dma", device, core, fetched, written))

    def record_link(self, src_device: int, dst_device: int, offset: int,
                    cycles: int, nbytes: int, label: str) -> None:
        """One inter-device channel transfer (pipeline bundle edge)."""
        self._link_records.append({
            "src": src_device, "dst": dst_device, "offset": offset,
            "cycles": cycles, "nbytes": nbytes, "label": label})

    def pad_idle(self, device: int, cycles: int) -> None:
        self._ops.append(("pad", device, cycles))

    def set_makespan(self, cycles: int) -> None:
        self._counters.makespan = cycles

    def _finalize_layer(self, device, layer_index, layer_name, offset,
                        window, core_results) -> None:
        c = self._counters
        summary = {"device": device, "layer": layer_index,
                   "name": layer_name, "offset": offset, "window": window}
        for core in CORES:
            pair = core_results.get(core)
            if pair is None:
                c.add_layer_window(device, core, window, None)
                summary[f"{core}_cycles"] = 0
                continue
            sim, st = pair
            c.add_layer_window(device, core, window, sim.traces)
            summary[f"{core}_cycles"] = sim.total_cycles
            if st is not None:
                self._layer_records.append(
                    (device, core, layer_index, layer_name, offset, st))
                # span-derived aggregates (forces the lazy replay —
                # exactly the cost the timed sim avoided)
                for (_, kind, _, dur, channel, _) in st.spans:
                    if kind == "stall" and channel:
                        c.add_wait(device, channel, dur)
                c.merge_queue_peak(device, st.queue_peak)
        lut_c, dsp_c = summary["lut_cycles"], summary["dsp_cycles"]
        hi = max(lut_c, dsp_c)
        summary["split_balance"] = round(min(lut_c, dsp_c) / hi, 4) \
            if hi else 1.0
        c.layers.append(summary)

    def finalize(self) -> None:
        """Replay the accounting-op log into :class:`Counters`.

        Idempotent by draining — pending ops are consumed, so records
        arriving after a finalize are picked up by the next call.
        Exports, the profile report and the ``counters`` property all
        route through here."""
        ops, self._ops = self._ops, []
        for op in ops:
            kind = op[0]
            if kind == "layer":
                self._finalize_layer(*op[1:])
            elif kind == "dma":
                self._counters.add_dma(*op[1:])
            else:   # "pad"
                self._counters.pad_idle(*op[1:])

    # -- wall-clock side (executors, serving, DSE) --------------------------

    @contextlib.contextmanager
    def measure(self, track: str, name: str, **args):
        """Wall-clock span on the measured timeline (µs resolution)."""
        start = time.perf_counter()
        try:
            yield
        finally:
            end = time.perf_counter()
            self._measured.append({
                "track": track, "name": name,
                "ts_us": (start - self._t0) * 1e6,
                "dur_us": (end - start) * 1e6,
                "args": dict(args)})

    @property
    def measured_spans(self) -> list[dict]:
        return list(self._measured)

    # -- export -------------------------------------------------------------

    def to_chrome(self) -> dict:
        """Chrome trace-event object (``json.dump``-ready)."""
        self.finalize()
        events: list[dict] = []
        seen_tracks: set[tuple[int, int]] = set()

        def meta(pid, name):
            events.append({"ph": "M", "pid": pid, "tid": 0,
                           "name": "process_name",
                           "args": {"name": name}})

        for device in sorted(self._device_names):
            meta(device, f"dev{device}:{self._device_names[device]}")

        for (device, core, layer, lname, offset, st) in self._layer_records:
            core_i = CORES.index(core)
            for (engine, kind, start, dur, channel, instr) in st.spans:
                tid = core_i * 3 + ENGINES.index(engine)
                if (device, tid) not in seen_tracks:
                    seen_tracks.add((device, tid))
                    events.append({"ph": "M", "pid": device, "tid": tid,
                                   "name": "thread_name",
                                   "args": {"name": f"{core}/{engine}"}})
                # spans carry raw instr objects (the sim hot loop must
                # not pay enum lookups); resolve names once, here
                if instr is None or isinstance(instr, str):
                    iname = instr
                else:
                    iname = instr.opcode.name
                args = {"kind": kind, "layer": layer, "layer_name": lname,
                        "core": core}
                if channel:
                    args["channel"] = channel
                if iname:
                    args["instr"] = iname
                events.append({
                    "ph": "X", "pid": device, "tid": tid,
                    "cat": _SPAN_CAT[kind],
                    "name": iname or kind,
                    "ts": offset + start, "dur": dur, "args": args})

        if self._link_records:
            meta(LINK_PID, "links")
            for i, rec in enumerate(self._link_records):
                events.append({
                    "ph": "X", "pid": LINK_PID,
                    "tid": rec["src"] * 64 + rec["dst"],
                    "cat": "link", "name": rec["label"],
                    "ts": rec["offset"], "dur": rec["cycles"],
                    "args": {"src_device": rec["src"],
                             "dst_device": rec["dst"],
                             "nbytes": rec["nbytes"]}})

        if self._measured:
            meta(MEASURED_PID, "measured")
            tracks = sorted({m["track"] for m in self._measured})
            tid_of = {t: i for i, t in enumerate(tracks)}
            for t in tracks:
                events.append({"ph": "M", "pid": MEASURED_PID,
                               "tid": tid_of[t], "name": "thread_name",
                               "args": {"name": t}})
            for m in self._measured:
                events.append({
                    "ph": "X", "pid": MEASURED_PID,
                    "tid": tid_of[m["track"]], "cat": "measured",
                    "name": m["name"],
                    "ts": round(m["ts_us"], 3),
                    "dur": round(m["dur_us"], 3),
                    "args": dict(m["args"])})

        return {"traceEvents": events,
                "displayTimeUnit": "ns",
                "otherData": {"generator": "repro.obs",
                              "time_unit": "cycles",
                              "counters": self.counters.to_dict()}}

    def to_json(self) -> str:
        """Deterministic serialization: same program -> same bytes."""
        return json.dumps(self.to_chrome(), sort_keys=True,
                          separators=(",", ":")) + "\n"

    def save(self, path: str) -> None:
        with open(path, "w") as fh:
            fh.write(self.to_json())


class NullTracer:
    """No-op tracer: the off-by-default fast path.

    Shares the ``Tracer`` surface so call sites never branch; every
    hook returns immediately. ``enabled`` lets hot loops skip even the
    call (``if tracer.enabled: ...``).
    """

    enabled = False
    counters = None

    def begin_device(self, device, name):
        pass

    def record_layer(self, device, layer_index, layer_name, offset,
                     window, core_results):
        pass

    def record_dma(self, device, core, fetched, written):
        pass

    def record_link(self, src_device, dst_device, offset, cycles,
                    nbytes, label):
        pass

    def pad_idle(self, device, cycles):
        pass

    def set_makespan(self, cycles):
        pass

    def finalize(self):
        pass

    @contextlib.contextmanager
    def measure(self, track, name, **args):
        yield

    measured_spans = ()


#: shared singleton — ``tracer=NULL_TRACER`` default keeps hooks alive
#: but free when tracing is off.
NULL_TRACER = NullTracer()


def validate_chrome_trace(obj: dict) -> list[str]:
    """Structural validation of a Chrome trace-event object.

    Returns a list of problems (empty == valid): used by tests and the
    CI smoke job to gate the uploaded artifact. Checks the object form,
    the per-event required fields for ``"X"``/``"M"`` phases, and that
    durations/timestamps are non-negative numbers.
    """
    problems = []
    if not isinstance(obj, dict):
        return [f"trace must be a JSON object, got {type(obj).__name__}"]
    events = obj.get("traceEvents")
    if not isinstance(events, list):
        return ["missing/invalid 'traceEvents' array"]
    if not events:
        problems.append("empty traceEvents")
    for i, ev in enumerate(events):
        where = f"traceEvents[{i}]"
        if not isinstance(ev, dict):
            problems.append(f"{where}: not an object")
            continue
        ph = ev.get("ph")
        if ph not in ("X", "M"):
            problems.append(f"{where}: unsupported phase {ph!r}")
            continue
        for field in ("pid", "tid", "name"):
            if field not in ev:
                problems.append(f"{where}: missing {field!r}")
        if ph == "X":
            for field in ("ts", "dur"):
                v = ev.get(field)
                if not isinstance(v, (int, float)):
                    problems.append(f"{where}: {field!r} not numeric")
                elif v < 0:
                    problems.append(f"{where}: {field!r} negative ({v})")
        if "args" in ev and not isinstance(ev["args"], dict):
            problems.append(f"{where}: 'args' not an object")
    return problems
