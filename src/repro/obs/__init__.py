"""repro.obs — zero-dependency tracing & metrics for the N3H-Core stack.

Three pieces, one contract:

* :class:`Tracer` / :data:`NULL_TRACER` — Chrome trace-event (Perfetto)
  span collection from the cycle-accurate simulator and wall-clock
  executor/driver timings; off by default via the null-object fast
  path.
* :class:`Counters` — derived per-core cycle accounting whose
  decomposition must *close*: busy + sync + stall + idle == the
  ``simulate_program`` makespan on every core track.
* :class:`MetricsRegistry` / :data:`METRICS` — structured
  counters/gauges/observations for serving and DSE with CSV/JSON
  export.

See ``docs/observability.md`` for usage.
"""
from .counters import Counters, TrackCounters
from .metrics import METRICS, MetricsRegistry
from .report import profile_report
from .trace import NULL_TRACER, NullTracer, Tracer, validate_chrome_trace

__all__ = [
    "Counters", "TrackCounters",
    "METRICS", "MetricsRegistry",
    "profile_report",
    "NULL_TRACER", "NullTracer", "Tracer", "validate_chrome_trace",
]
