"""Profile report: human-readable utilization breakdown of a trace.

``profile_report(tracer)`` renders the counters of a traced run as the
table the FPGA-accelerator literature keeps asking for (utilization
breakdown as the primary design-feedback signal): per-core/per-engine
busy/sync/stall/idle as % of the program makespan (the roofline-style
"% of peak" — an engine busy 100% of the makespan is at its
issue-rate peak), the Eq.-12 split balance per layer, DMA traffic,
top stall causes by sync channel, and the closure check verdict.

Surfaced by ``python -m repro.compiler ... --trace out.json --profile``
and importable for benchmarks/tests.
"""
from __future__ import annotations

from .counters import CORES, ENGINES


def _fmt_bytes(n: int) -> str:
    for unit in ("B", "KiB", "MiB", "GiB"):
        if n < 1024 or unit == "GiB":
            return f"{n:.0f} {unit}" if unit == "B" else f"{n:.1f} {unit}"
        n /= 1024.0
    return f"{n:.1f} GiB"


def profile_report(tracer, top_stalls: int = 5,
                   max_layer_rows: int = 24) -> str:
    """Render the utilization/profile table for a completed trace."""
    c = tracer.counters
    if c is None or not c.tracks:
        return "profile: no trace data (tracing disabled or nothing ran)\n"
    tracer.finalize()   # stall causes / queue peaks are span-derived
    makespan = c.makespan
    lines = []
    lines.append(f"== profile: makespan {makespan} cycles ==")

    # per-core / per-engine utilization (% of makespan == % of peak)
    lines.append("")
    lines.append(f"{'track':<22}{'busy%':>8}{'sync%':>8}{'stall%':>8}"
                 f"{'idle%':>8}{'busy cycles':>14}")
    devices = sorted({d for (d, _, _) in c.tracks})
    for device in devices:
        for core in CORES:
            for engine in ENGINES:
                tc = c.tracks.get((device, core, engine))
                if tc is None:
                    continue
                lines.append(
                    f"dev{device} {core}/{engine:<12}"
                    f"{tc.pct('busy', makespan):>8.1f}"
                    f"{tc.pct('sync', makespan):>8.1f}"
                    f"{tc.pct('stall', makespan):>8.1f}"
                    f"{tc.pct('idle', makespan):>8.1f}"
                    f"{tc.busy:>14}")

    # per-layer table: window, per-core cycles, Eq.-12 split balance
    if c.layers:
        lines.append("")
        lines.append(f"{'layer':<26}{'dev':>4}{'window':>10}{'lut':>10}"
                     f"{'dsp':>10}{'balance':>9}")
        shown = c.layers[:max_layer_rows]
        for row in shown:
            lines.append(
                f"{row['name'][:25]:<26}{row['device']:>4}"
                f"{row['window']:>10}{row['lut_cycles']:>10}"
                f"{row['dsp_cycles']:>10}{row['split_balance']:>9.2f}")
        if len(c.layers) > len(shown):
            lines.append(f"... ({len(c.layers) - len(shown)} more layers)")

    # DMA traffic
    if c.dma:
        lines.append("")
        lines.append("DMA bytes moved:")
        for (device, core), agg in sorted(c.dma.items()):
            lines.append(f"  dev{device} {core}: "
                         f"fetch {_fmt_bytes(agg['bytes_fetched'])}, "
                         f"write {_fmt_bytes(agg['bytes_written'])}")

    # top stall causes
    if c.wait_by_channel:
        lines.append("")
        lines.append(f"top stall causes (of {top_stalls}):")
        ranked = sorted(c.wait_by_channel.items(),
                        key=lambda kv: (-kv[1], kv[0]))[:top_stalls]
        for (device, channel), cycles in ranked:
            pct = 100.0 * cycles / makespan if makespan else 0.0
            lines.append(f"  dev{device} {channel}: {cycles} cycles "
                         f"({pct:.1f}% of makespan)")

    # buffer-slot occupancy peaks
    slot_peaks = {k: v for k, v in c.queue_peak.items()
                  if k[1].endswith(("wslot", "aslot"))}
    if slot_peaks:
        lines.append("")
        lines.append("peak buffer-slot occupancy:")
        for (device, channel), depth in sorted(slot_peaks.items()):
            lines.append(f"  dev{device} {channel}: {depth}")

    # the contract
    errors = c.closure_errors()
    lines.append("")
    if errors:
        lines.append("cycle accounting: FAILED to close")
        lines.extend(f"  {e}" for e in errors)
    else:
        lines.append("cycle accounting: closed "
                     "(busy+sync+stall+idle == makespan on every track)")
    return "\n".join(lines) + "\n"
