"""Cycle-accounted per-core counters derived from simulator traces.

The accounting contract (the reason these are more than logging): for
every core track ``(device, core, engine)`` of a traced program,

    busy + sync + stall + idle == makespan

where ``busy`` are compute/DMA cycles, ``sync`` token hand-shake
cycles, ``stall`` cycles blocked on an un-posted token, and ``idle``
the remainder of each layer/stage window the engine did not occupy.
``busy``/``sync``/``stall`` come from the event-driven simulation of
the instruction streams; ``idle`` is accumulated *incrementally* per
placement window (never derived as ``makespan - rest``), so
:meth:`Counters.closure_errors` is a genuine cross-check of the
decomposition against the independently aggregated program makespan —
the trace decomposes the existing ``simulate_program`` number instead
of producing a second opinion.

Everything here is stdlib-only (the ``repro.obs`` subsystem has zero
dependencies); simulator objects are consumed duck-typed.
"""
from __future__ import annotations

import dataclasses

#: engine order of every core track (matches ``compiler.program.ENGINES``)
ENGINES = ("fetch", "execute", "result")
#: core order of the heterogeneous pair (Eq. 12 split: LUT first)
CORES = ("lut", "dsp")


@dataclasses.dataclass
class TrackCounters:
    """Cycle decomposition of one ``(device, core, engine)`` track."""
    busy: int = 0     # compute / DMA cycles
    sync: int = 0     # token send/consume hand-shake cycles
    stall: int = 0    # blocked waiting for an un-posted token
    idle: int = 0     # window remainder (layer drained / other stage)

    @property
    def accounted(self) -> int:
        """Total cycles this track accounts for; closure requires this
        to equal the program makespan exactly."""
        return self.busy + self.sync + self.stall + self.idle

    def pct(self, field: str, makespan: int) -> float:
        return 100.0 * getattr(self, field) / makespan if makespan else 0.0

    def to_dict(self) -> dict:
        return {"busy": self.busy, "sync": self.sync,
                "stall": self.stall, "idle": self.idle}


class Counters:
    """Aggregated observability counters of one traced run.

    * ``tracks`` — :class:`TrackCounters` per ``(device, core, engine)``;
    * ``dma`` — bytes moved per ``(device, core)`` (summed from the
      Fetch/Result instruction ``ddr_range`` fields, i.e. exactly what
      the traced DMA instructions declared);
    * ``wait_by_channel`` — stall cycles per ``(device, channel)``:
      the top stall causes of the profile report;
    * ``queue_peak`` — peak token-queue depth per ``(device, channel)``
      (buffer-slot occupancy for the ``*.wslot``/``*.aslot`` channels);
    * ``layers`` — one placement row per (device, layer): window
      cycles, per-core makespans and the Eq.-12 split balance
      ``min(lut, dsp) / max(lut, dsp)``.
    """

    def __init__(self):
        self.tracks: dict[tuple[int, str, str], TrackCounters] = {}
        self.dma: dict[tuple[int, str], dict[str, int]] = {}
        self.wait_by_channel: dict[tuple[int, str], int] = {}
        self.queue_peak: dict[tuple[int, str], int] = {}
        self.layers: list[dict] = []
        self.makespan: int = 0

    def track(self, device: int, core: str, engine: str) -> TrackCounters:
        key = (device, core, engine)
        tc = self.tracks.get(key)
        if tc is None:
            tc = self.tracks[key] = TrackCounters()
        return tc

    # -- accounting entry points (driven by the Tracer) ---------------------

    def add_layer_window(self, device: int, core: str, window: int,
                         engine_traces: dict | None) -> None:
        """Account one placement window for one core.

        ``engine_traces`` maps engine name -> the per-engine trace of
        the event-driven sim (duck-typed: ``busy``/``sync``/``wait``
        cycle sums and the ``finish`` clock); ``None`` means the core
        is absent in this layer — the whole window is idle for all
        three of its tracks.
        """
        for engine in ENGINES:
            tc = self.track(device, core, engine)
            if engine_traces is None:
                tc.idle += window
                continue
            et = engine_traces[engine]
            tc.busy += et.busy
            tc.sync += et.sync
            tc.stall += et.wait
            tc.idle += window - et.finish

    def pad_idle(self, device: int, cycles: int) -> None:
        """Account cycles a whole device spends outside its own stage
        window (pipeline bundles: the other stages + link edges)."""
        if cycles <= 0:
            return
        for (d, _, _), tc in self.tracks.items():
            if d == device:
                tc.idle += cycles

    def add_dma(self, device: int, core: str, fetched: int,
                written: int) -> None:
        agg = self.dma.setdefault((device, core),
                                  {"bytes_fetched": 0, "bytes_written": 0})
        agg["bytes_fetched"] += fetched
        agg["bytes_written"] += written

    def add_wait(self, device: int, channel: str, cycles: int) -> None:
        key = (device, channel)
        self.wait_by_channel[key] = self.wait_by_channel.get(key, 0) + cycles

    def merge_queue_peak(self, device: int, peaks: dict[str, int]) -> None:
        for ch, depth in peaks.items():
            key = (device, ch)
            if depth > self.queue_peak.get(key, 0):
                self.queue_peak[key] = depth

    # -- the closure contract ----------------------------------------------

    def closure_errors(self) -> list[str]:
        """Tracks whose cycle accounting does not sum to the makespan.

        Empty iff the decomposition closes — the acceptance gate of the
        tracing layer (asserted in ``tests/test_obs.py`` and CI smoke).
        """
        errors = []
        for (d, core, engine), tc in sorted(self.tracks.items()):
            if tc.accounted != self.makespan:
                errors.append(
                    f"dev{d} {core}/{engine}: busy {tc.busy} + sync "
                    f"{tc.sync} + stall {tc.stall} + idle {tc.idle} = "
                    f"{tc.accounted} != makespan {self.makespan}")
        return errors

    def to_dict(self) -> dict:
        """JSON-serializable summary (embedded in the trace file's
        ``otherData`` so a saved trace carries its own accounting)."""
        return {
            "makespan_cycles": self.makespan,
            "tracks": {f"dev{d}.{c}.{e}": tc.to_dict()
                       for (d, c, e), tc in sorted(self.tracks.items())},
            "dma": {f"dev{d}.{c}": dict(v)
                    for (d, c), v in sorted(self.dma.items())},
            "wait_by_channel": {f"dev{d}.{ch}": v for (d, ch), v in
                                sorted(self.wait_by_channel.items())},
            "queue_peak": {f"dev{d}.{ch}": v for (d, ch), v in
                           sorted(self.queue_peak.items())},
            "layers": list(self.layers),
            "closure_errors": self.closure_errors(),
        }
