"""Public jit'd wrappers around the Pallas kernels.

Responsibilities the raw kernels don't take:
  * shape padding to block multiples (and un-padding the result);
  * backend dispatch — on TPU the Pallas kernel runs compiled; on CPU
    (tests, this container) the call automatically falls back to the
    pure-jnp oracle, with ``interpret=True`` available to execute the
    actual kernel body for validation;
  * GQA head broadcasting for flash attention.

These wrappers are the only entry points the model zoo uses.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.bitserial_gemm import bitserial_gemm as _bitserial_kernel
from repro.kernels.int4_gemm import int4_gemm as _int4_kernel
from repro.kernels.flash_attention import flash_attention as _flash_kernel
from repro.kernels.fused_hetero_gemm import (
    fused_conv_gemm as _fused_conv_kernel,
    fused_conv_vmem_bytes,
    fused_hetero_gemm as _fused_kernel,
)

#: VMEM working-set ceiling (bytes) above which the fused conv kernel
#: falls back to the vectorized jnp path (whole spatial input must fit
#: on chip for in-kernel im2col).
FUSED_CONV_VMEM_BUDGET = 12 * 1024 * 1024


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_to(x: jax.Array, axis: int, mult: int) -> jax.Array:
    rem = x.shape[axis] % mult
    if rem == 0:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, mult - rem)
    return jnp.pad(x, pads)


def bitserial_matmul(x_q: jax.Array, w_q: jax.Array, w_scale: jax.Array,
                     bits: int, *, block: tuple[int, int, int] = (128, 128, 128),
                     mode: str = "auto") -> jax.Array:
    """Bitplane-path GEMM: int8 activations x ``bits``-bit weight codes.

    x_q: [M, K] int8; w_q: [K, N] int32 codes; w_scale: [N] fp32.
    mode: "auto" (kernel on TPU, oracle elsewhere), "kernel" (interpret
    off-TPU), or "ref".
    """
    if mode == "ref" or (mode == "auto" and not _on_tpu()):
        return ref.bitserial_gemm_ref(x_q, w_q, w_scale, bits)
    bm, bk, bn = block
    m, k = x_q.shape
    n = w_q.shape[1]
    planes = ref.bitplane_decompose(w_q, bits)
    xp = _pad_to(_pad_to(x_q, 0, bm), 1, bk)
    pp = _pad_to(_pad_to(planes, 1, bk), 2, bn)
    sp = _pad_to(w_scale, 0, bn)
    out = _bitserial_kernel(xp, pp, sp, bits, bm=bm, bn=bn, bk=bk,
                            interpret=not _on_tpu())
    return out[:m, :n]


def int4_matmul(x_q: jax.Array, w_q: jax.Array, w_scale: jax.Array, *,
                block: tuple[int, int, int] = (128, 128, 128),
                mode: str = "auto") -> jax.Array:
    """Packed-int4-path GEMM: int8 activations x int4 weight codes.

    x_q: [M, K] int8; w_q: [K, N] int32 codes in [-8, 7]; w_scale: [N].
    """
    if mode == "ref" or (mode == "auto" and not _on_tpu()):
        n = w_q.shape[1]
        packed = ref.pack_int4(_pad_to(w_q, 1, 2))
        return ref.int4_gemm_ref(x_q, packed, _pad_to(w_scale, 0, 2))[:, :n]
    bm, bk, bn = block
    m, k = x_q.shape
    n = w_q.shape[1]
    packed = ref.pack_int4(_pad_to(w_q, 1, 2))
    xp = _pad_to(_pad_to(x_q, 0, bm), 1, bk)
    wp = _pad_to(_pad_to(packed, 0, bk), 1, bn // 2)
    sp = _pad_to(_pad_to(w_scale, 0, 2), 0, bn)
    out = _int4_kernel(xp, wp, sp, bm=bm, bn=bn, bk=bk,
                       interpret=not _on_tpu())
    return out[:m, :n]


def bitserial_grouped_matmul(x_col: jax.Array, w_q: jax.Array,
                             w_scale: jax.Array, bits: int, *,
                             mode: str = "auto") -> jax.Array:
    """Depthwise (grouped) bitplane GEMM: each output channel contracts
    only its own [M, K] im2col slice of ``x_col`` [M, K, N].

    No dedicated Pallas kernel: the per-channel contraction is K=kh*kw
    taps, far below the MXU tile, so the vectorized jnp path (an exact
    int32 ``einsum``) is the kernel on every backend. ``mode`` is
    accepted for interface symmetry with :func:`bitserial_matmul`.
    """
    del mode
    return ref.bitserial_grouped_gemm_ref(x_col, w_q, w_scale, bits)


def int4_grouped_matmul(x_col: jax.Array, w_q: jax.Array,
                        w_scale: jax.Array, *, mode: str = "auto"
                        ) -> jax.Array:
    """Depthwise (grouped) int4 GEMM over per-channel im2col slices."""
    del mode
    return ref.int4_grouped_gemm_ref(x_col, w_q, w_scale)


def attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
              causal: bool = True, kv_offset: int = 0,
              block: tuple[int, int] = (128, 128),
              mode: str = "auto") -> jax.Array:
    """Flash attention with GQA broadcast.

    q: [B, Hq, Sq, D]; k, v: [B, Hkv, Skv, D] with Hq % Hkv == 0.
    """
    hq, hkv = q.shape[1], k.shape[1]
    if hq != hkv:
        rep = hq // hkv
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    if mode == "ref" or (mode == "auto" and not _on_tpu()):
        return ref.flash_attention_ref(q, k, v, causal=causal,
                                       kv_offset=kv_offset)
    bq, bkv = block
    sq, skv = q.shape[2], k.shape[2]
    bq = min(bq, sq) if sq % bq else bq
    qp = _pad_to(q, 2, bq)
    kp = _pad_to(k, 2, bkv)
    vp = _pad_to(v, 2, bkv)
    out = _flash_kernel(qp, kp, vp, causal=causal, kv_offset=kv_offset,
                        bq=bq, bkv=bkv, interpret=not _on_tpu())
    return out[:, :, :sq]


def _norm_side(w_q: jax.Array | None, w_scale: jax.Array | None
               ) -> tuple[jax.Array | None, jax.Array | None]:
    """An absent split side may arrive as None or as a 0-column array."""
    if w_q is None or w_q.shape[-1] == 0:
        return None, None
    return w_q, w_scale


def fused_matmul(x_q: jax.Array, w_lut: jax.Array | None,
                 s_lut: jax.Array | None, bits: int,
                 w_dsp: jax.Array | None, s_dsp: jax.Array | None, *,
                 block: tuple[int, int, int] = (128, 128, 128),
                 mode: str = "auto") -> jax.Array:
    """Fused split GEMM — both sides of the Eq.-12 split in ONE launch.

    x_q: [M, K] int8; w_lut: [K, n_lut] codes within ``bits`` bits (the
    LUT partition; None or 0 columns when absent); w_dsp: [K, n_dsp]
    int32 codes in [-8, 7]; s_*: per-column fp32 scales. Returns fp32
    [M, n_lut + n_dsp] in split column order, bit-identical to
    :func:`hetero_matmul`. A one-sided split still takes a single
    launch through the matching single-path kernel.
    """
    w_lut, s_lut = _norm_side(w_lut, s_lut)
    w_dsp, s_dsp = _norm_side(w_dsp, s_dsp)
    if w_lut is None and w_dsp is None:
        raise ValueError("fused_matmul: both split sides are empty")
    if mode == "ref" or (mode == "auto" and not _on_tpu()):
        return ref.fused_hetero_gemm_ref(x_q, w_lut, s_lut, bits,
                                         w_dsp, s_dsp)
    if w_lut is None:
        return int4_matmul(x_q, w_dsp, s_dsp, block=block, mode=mode)
    if w_dsp is None:
        return bitserial_matmul(x_q, w_lut, s_lut, bits, block=block,
                                mode=mode)
    bm, bk, bn = block
    m, _ = x_q.shape
    n_lut, n_dsp = w_lut.shape[1], w_dsp.shape[1]
    planes = ref.bitplane_decompose(w_lut, bits)
    pp = _pad_to(_pad_to(planes, 1, bk), 2, bn)
    packed = ref.pack_int4(_pad_to(w_dsp, 1, 2))
    wp = _pad_to(_pad_to(packed, 0, bk), 1, bn // 2)
    n_lut_pad = pp.shape[2]
    sp = jnp.concatenate([_pad_to(s_lut, 0, bn),
                          _pad_to(_pad_to(s_dsp, 0, 2), 0, bn)])
    xp = _pad_to(_pad_to(x_q, 0, bm), 1, bk)
    out = _fused_kernel(xp, pp, wp, sp, bits, n_lut_pad // bn,
                        bm=bm, bn=bn, bk=bk, interpret=not _on_tpu())
    if n_lut_pad == n_lut:
        return out[:m, :n_lut + n_dsp]
    # column padding landed between the regions; splice it out
    return jnp.concatenate(
        [out[:m, :n_lut], out[:m, n_lut_pad:n_lut_pad + n_dsp]], axis=1)


def fused_conv_matmul(x_sp: jax.Array, kernel: int, stride: int, pad: int,
                      out_hw: int, w_lut: jax.Array | None,
                      s_lut: jax.Array | None, bits: int,
                      w_dsp: jax.Array | None, s_dsp: jax.Array | None, *,
                      block: tuple[int, int, int] = (128, 128, 128),
                      mode: str = "auto",
                      vmem_budget: int | None = None) -> jax.Array:
    """Fused im2col-free conv GEMM: one launch from the raw spatial
    activation block — patches are generated inside the kernel, so no
    column matrix is staged in DDR or materialized on host.

    x_sp: [H, W, C] int8 spatial activations (*unpadded*; zero padding
    happens here); weights/scales as :func:`fused_matmul` with K =
    ``kernel**2 * C`` rows in (kh, kw, c) order. Falls back to the
    vectorized jnp path (still a single fused jit call) when the
    spatial working set exceeds ``vmem_budget``.
    """
    w_lut, s_lut = _norm_side(w_lut, s_lut)
    w_dsp, s_dsp = _norm_side(w_dsp, s_dsp)
    if w_lut is None and w_dsp is None:
        raise ValueError("fused_conv_matmul: both split sides are empty")
    m = out_hw * out_hw
    k = kernel * kernel * x_sp.shape[2]
    budget = FUSED_CONV_VMEM_BUDGET if vmem_budget is None else vmem_budget
    fits = fused_conv_vmem_bytes(x_sp.shape[0], x_sp.shape[2], kernel, pad,
                                 m, k, bits) <= budget
    if mode == "ref" or (mode == "auto" and not _on_tpu()) or not fits:
        x_col = ref.conv_patches_ref(x_sp, kernel, stride, pad, out_hw)
        return ref.fused_hetero_gemm_ref(x_col.reshape(m, k), w_lut, s_lut,
                                         bits, w_dsp, s_dsp)
    _, _, bn = block
    xp = jnp.pad(x_sp, ((pad, pad), (pad, pad), (0, 0)))
    n_lut = 0 if w_lut is None else w_lut.shape[1]
    n_dsp = 0 if w_dsp is None else w_dsp.shape[1]
    if w_lut is None:      # dummy never-consumed block keeps specs in-bounds
        planes = jnp.zeros((max(bits, 1), k, bn), jnp.int8)
        n_lut_pad, s_l = 0, None
    else:
        planes = _pad_to(ref.bitplane_decompose(w_lut, bits), 2, bn)
        n_lut_pad = planes.shape[2]
        s_l = _pad_to(s_lut, 0, bn)
    if w_dsp is None:
        packed = jnp.zeros((k, bn // 2), jnp.int8)
        n_dsp_pad, s_d = 0, None
    else:
        packed = _pad_to(ref.pack_int4(_pad_to(w_dsp, 1, 2)), 1, bn // 2)
        n_dsp_pad = packed.shape[1] * 2
        s_d = _pad_to(_pad_to(s_dsp, 0, 2), 0, bn)
    sp = jnp.concatenate([s for s in (s_l, s_d) if s is not None])
    out = _fused_conv_kernel(xp, planes, packed, sp, bits,
                             n_lut_pad // bn, n_dsp_pad // bn, kernel,
                             stride, out_hw, bn=bn,
                             interpret=not _on_tpu())
    if n_lut_pad == n_lut:
        return out[:, :n_lut + n_dsp]
    return jnp.concatenate(
        [out[:, :n_lut], out[:, n_lut_pad:n_lut_pad + n_dsp]], axis=1)


def fused_grouped_matmul(x_col: jax.Array, w_lut: jax.Array | None,
                         s_lut: jax.Array | None, bits: int,
                         w_dsp: jax.Array | None, s_dsp: jax.Array | None,
                         *, mode: str = "auto") -> jax.Array:
    """Fused depthwise split GEMM over per-channel im2col slices.

    x_col: [M, K, N] over *all* N output channels in split order; the
    first n_lut channels contract bit-serially, the rest as int4. Like
    the single-path grouped ops, the vectorized jnp contraction is the
    kernel on every backend (K = kh*kw taps is far below the MXU tile).
    """
    del mode
    w_lut, s_lut = _norm_side(w_lut, s_lut)
    w_dsp, s_dsp = _norm_side(w_dsp, s_dsp)
    return ref.fused_hetero_grouped_gemm_ref(x_col, w_lut, s_lut, bits,
                                             w_dsp, s_dsp)


def fused_depthwise_matmul(x_sp: jax.Array, kernel: int, stride: int,
                           pad: int, out_hw: int, w_lut: jax.Array | None,
                           s_lut: jax.Array | None, bits: int,
                           w_dsp: jax.Array | None,
                           s_dsp: jax.Array | None, *,
                           mode: str = "auto") -> jax.Array:
    """Fused depthwise conv from the raw spatial block: in-jit patch
    generation (no staged column matrix) feeding the fused grouped
    contraction."""
    x_col = ref.conv_patches_ref(x_sp, kernel, stride, pad, out_hw)
    return fused_grouped_matmul(x_col, w_lut, s_lut, bits, w_dsp, s_dsp,
                                mode=mode)


def hetero_matmul(x_q: jax.Array, w_q_serial: jax.Array, s_serial: jax.Array,
                  bits_serial: int, w_q_parallel: jax.Array,
                  s_parallel: jax.Array, *, mode: str = "auto") -> jax.Array:
    """The paper's split GEMM: serial-path columns then int4 columns."""
    outs = []
    if w_q_serial.shape[1]:
        outs.append(bitserial_matmul(x_q, w_q_serial, s_serial, bits_serial,
                                     mode=mode))
    if w_q_parallel.shape[1]:
        outs.append(int4_matmul(x_q, w_q_parallel, s_parallel, mode=mode))
    return outs[0] if len(outs) == 1 else jnp.concatenate(outs, axis=-1)
