"""Public jit'd wrappers around the Pallas kernels.

Responsibilities the raw kernels don't take:
  * shape padding to block multiples (and un-padding the result);
  * backend dispatch — on TPU the Pallas kernel runs compiled; on CPU
    (tests, this container) the call automatically falls back to the
    pure-jnp oracle, with ``interpret=True`` available to execute the
    actual kernel body for validation;
  * GQA head broadcasting for flash attention.

These wrappers are the only entry points the model zoo uses.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.bitserial_gemm import bitserial_gemm as _bitserial_kernel
from repro.kernels.int4_gemm import int4_gemm as _int4_kernel
from repro.kernels.flash_attention import flash_attention as _flash_kernel


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_to(x: jax.Array, axis: int, mult: int) -> jax.Array:
    rem = x.shape[axis] % mult
    if rem == 0:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, mult - rem)
    return jnp.pad(x, pads)


def bitserial_matmul(x_q: jax.Array, w_q: jax.Array, w_scale: jax.Array,
                     bits: int, *, block: tuple[int, int, int] = (128, 128, 128),
                     mode: str = "auto") -> jax.Array:
    """Bitplane-path GEMM: int8 activations x ``bits``-bit weight codes.

    x_q: [M, K] int8; w_q: [K, N] int32 codes; w_scale: [N] fp32.
    mode: "auto" (kernel on TPU, oracle elsewhere), "kernel" (interpret
    off-TPU), or "ref".
    """
    if mode == "ref" or (mode == "auto" and not _on_tpu()):
        return ref.bitserial_gemm_ref(x_q, w_q, w_scale, bits)
    bm, bk, bn = block
    m, k = x_q.shape
    n = w_q.shape[1]
    planes = ref.bitplane_decompose(w_q, bits)
    xp = _pad_to(_pad_to(x_q, 0, bm), 1, bk)
    pp = _pad_to(_pad_to(planes, 1, bk), 2, bn)
    sp = _pad_to(w_scale, 0, bn)
    out = _bitserial_kernel(xp, pp, sp, bits, bm=bm, bn=bn, bk=bk,
                            interpret=not _on_tpu())
    return out[:m, :n]


def int4_matmul(x_q: jax.Array, w_q: jax.Array, w_scale: jax.Array, *,
                block: tuple[int, int, int] = (128, 128, 128),
                mode: str = "auto") -> jax.Array:
    """Packed-int4-path GEMM: int8 activations x int4 weight codes.

    x_q: [M, K] int8; w_q: [K, N] int32 codes in [-8, 7]; w_scale: [N].
    """
    if mode == "ref" or (mode == "auto" and not _on_tpu()):
        n = w_q.shape[1]
        packed = ref.pack_int4(_pad_to(w_q, 1, 2))
        return ref.int4_gemm_ref(x_q, packed, _pad_to(w_scale, 0, 2))[:, :n]
    bm, bk, bn = block
    m, k = x_q.shape
    n = w_q.shape[1]
    packed = ref.pack_int4(_pad_to(w_q, 1, 2))
    xp = _pad_to(_pad_to(x_q, 0, bm), 1, bk)
    wp = _pad_to(_pad_to(packed, 0, bk), 1, bn // 2)
    sp = _pad_to(_pad_to(w_scale, 0, 2), 0, bn)
    out = _int4_kernel(xp, wp, sp, bm=bm, bn=bn, bk=bk,
                       interpret=not _on_tpu())
    return out[:m, :n]


def bitserial_grouped_matmul(x_col: jax.Array, w_q: jax.Array,
                             w_scale: jax.Array, bits: int, *,
                             mode: str = "auto") -> jax.Array:
    """Depthwise (grouped) bitplane GEMM: each output channel contracts
    only its own [M, K] im2col slice of ``x_col`` [M, K, N].

    No dedicated Pallas kernel: the per-channel contraction is K=kh*kw
    taps, far below the MXU tile, so the vectorized jnp path (an exact
    int32 ``einsum``) is the kernel on every backend. ``mode`` is
    accepted for interface symmetry with :func:`bitserial_matmul`.
    """
    del mode
    return ref.bitserial_grouped_gemm_ref(x_col, w_q, w_scale, bits)


def int4_grouped_matmul(x_col: jax.Array, w_q: jax.Array,
                        w_scale: jax.Array, *, mode: str = "auto"
                        ) -> jax.Array:
    """Depthwise (grouped) int4 GEMM over per-channel im2col slices."""
    del mode
    return ref.int4_grouped_gemm_ref(x_col, w_q, w_scale)


def attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
              causal: bool = True, kv_offset: int = 0,
              block: tuple[int, int] = (128, 128),
              mode: str = "auto") -> jax.Array:
    """Flash attention with GQA broadcast.

    q: [B, Hq, Sq, D]; k, v: [B, Hkv, Skv, D] with Hq % Hkv == 0.
    """
    hq, hkv = q.shape[1], k.shape[1]
    if hq != hkv:
        rep = hq // hkv
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    if mode == "ref" or (mode == "auto" and not _on_tpu()):
        return ref.flash_attention_ref(q, k, v, causal=causal,
                                       kv_offset=kv_offset)
    bq, bkv = block
    sq, skv = q.shape[2], k.shape[2]
    bq = min(bq, sq) if sq % bq else bq
    qp = _pad_to(q, 2, bq)
    kp = _pad_to(k, 2, bkv)
    vp = _pad_to(v, 2, bkv)
    out = _flash_kernel(qp, kp, vp, causal=causal, kv_offset=kv_offset,
                        bq=bq, bkv=bkv, interpret=not _on_tpu())
    return out[:, :, :sq]


def hetero_matmul(x_q: jax.Array, w_q_serial: jax.Array, s_serial: jax.Array,
                  bits_serial: int, w_q_parallel: jax.Array,
                  s_parallel: jax.Array, *, mode: str = "auto") -> jax.Array:
    """The paper's split GEMM: serial-path columns then int4 columns."""
    outs = []
    if w_q_serial.shape[1]:
        outs.append(bitserial_matmul(x_q, w_q_serial, s_serial, bits_serial,
                                     mode=mode))
    if w_q_parallel.shape[1]:
        outs.append(int4_matmul(x_q, w_q_parallel, s_parallel, mode=mode))
    return outs[0] if len(outs) == 1 else jnp.concatenate(outs, axis=-1)
