"""Flash-attention Pallas kernel (forward) for serving hot paths.

Online-softmax attention with KV streamed through VMEM in blocks:
running row-max ``m``, normalizer ``l`` and the un-normalized output
accumulator live in VMEM scratch across the KV sweep. This is the
standard TPU flash schedule: grid (batch*heads, q-blocks, kv-blocks)
with the kv dimension "arbitrary" (sequential, carries scratch).

Causal masking is block-sparse: kv-blocks entirely above the diagonal
are skipped arithmetically (masked to -inf) — Pallas on TPU still
visits the block, so the win is numerical only in this kernel; the
grid-pruned variant is a recorded §Perf follow-up.

Used for prefill (Sq = Skv) and decode (Sq = 1 with a kv_offset); the
pure-JAX blockwise fallback in ``repro.models.layers`` computes the
same schedule with ``lax.scan`` for CPU/dry-run paths.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


DEFAULT_BQ = 128
DEFAULT_BKV = 128

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, out_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, causal: bool, kv_offset: int, bq: int,
                  bkv: int, nkv: int):
    kb = pl.program_id(2)

    @pl.when(kb == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)                  # [bq, d]
    k = k_ref[0].astype(jnp.float32)                  # [bkv, d]
    v = v_ref[0].astype(jnp.float32)                  # [bkv, d]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    if causal:
        qb = pl.program_id(1)
        qpos = qb * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0) \
            + kv_offset
        kpos = kb * bkv + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
        s = jnp.where(kpos <= qpos, s, NEG_INF)

    m_prev = m_ref[...]                               # [bq, 1]
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)                            # [bq, bkv]

    l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = alpha * acc_ref[...] + jax.lax.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(kb == nkv - 1)
    def _done():
        l = jnp.maximum(l_ref[...], 1e-30)
        out_ref[0, ...] = (acc_ref[...] / l).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "scale", "kv_offset",
                                             "bq", "bkv", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, scale: float | None = None,
                    kv_offset: int = 0, bq: int = DEFAULT_BQ,
                    bkv: int = DEFAULT_BKV, interpret: bool = False
                    ) -> jax.Array:
    """q: [B, H, Sq, D]; k, v: [B, H, Skv, D] -> [B, H, Sq, D].

    H is the *query* head count; callers repeat/broadcast GQA KV heads
    before the kernel (ops.py does this). Sq % bq == 0, Skv % bkv == 0.
    """
    b, h, sq, d = q.shape
    _, _, skv, _ = k.shape
    scale = float(scale if scale is not None else d ** -0.5)
    if sq % bq or skv % bkv:
        raise ValueError(f"seq ({sq},{skv}) not divisible by blocks "
                         f"({bq},{bkv}); pad first")
    bh = b * h
    qf = q.reshape(bh, sq, d)
    kf = k.reshape(bh, skv, d)
    vf = v.reshape(bh, skv, d)
    nq, nkv = sq // bq, skv // bkv

    kwargs = {}
    if not interpret:
        kwargs["compiler_params"] = pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"))

    out = pl.pallas_call(
        functools.partial(_flash_kernel, scale=scale, causal=causal,
                          kv_offset=kv_offset, bq=bq, bkv=bkv, nkv=nkv),
        grid=(bh, nq, nkv),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda g, i, j: (g, i, 0)),
            pl.BlockSpec((1, bkv, d), lambda g, i, j: (g, j, 0)),
            pl.BlockSpec((1, bkv, d), lambda g, i, j: (g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda g, i, j: (g, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),     # running max
            pltpu.VMEM((bq, 1), jnp.float32),     # normalizer
            pltpu.VMEM((bq, d), jnp.float32),     # output accumulator
        ],
        interpret=interpret,
        **kwargs,
    )(qf, kf, vf)
    return out.reshape(b, h, sq, d)
