"""Fused split-aware whole-layer Pallas kernels.

The N3H-Core split (Eq. 12) makes a layer's GEMM *one* heterogeneous
computation: the first ``n_lut`` output columns run on the LUT core
(bit-serial, latency ∝ weight bits), the rest on the DSP core
(packed-int4, fixed latency). The batched executor used to mirror that
as two kernel launches plus a host-side concat per layer; these kernels
consume both sides of the split in a *single* launch.

``fused_hetero_gemm`` — one grid whose column-block axis spans the
LUT-region blocks followed by the DSP-region blocks. Per column block
the kernel picks its path with ``pl.when`` on the block index: LUT
blocks accumulate the bitplane decomposition (one int8 MXU matmul per
plane, shifted partial sums — exactly ``bitserial_gemm``'s scheme), DSP
blocks unpack two-int4-per-byte weights in-register and issue one int8
matmul (``int4_gemm``'s scheme). Both paths share one int32 VMEM
accumulator per output tile and one fp32 per-column dequant epilogue,
so the per-layer concat disappears: the output lands as a single
[M, N] tile in split column order.

``fused_conv_gemm`` — the im2col-free conv variant: the kernel reads
the raw zero-padded NHWC activation block and generates im2col patches
*inside* the launch, contracting tap by tap ((kh, kw) static unroll;
each tap is a [M, C] x [C, bn] matmul against the matching weight
rows). No column matrix is ever materialized — not in DDR (the
``L{i}.col`` staging copy is gone from compiled programs) and not in
VMEM. The whole spatial input must fit on chip; the ``ops.py`` wrapper
falls back to the vectorized jnp path when it does not (see
``fused_conv_vmem_bytes``).

Both kernels are validated in interpret mode against the pure-jnp
oracles (``ref.fused_hetero_gemm_ref``); on CPU the wrappers dispatch
the oracles directly, still as one jitted call per layer.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


DEFAULT_BM = 128
DEFAULT_BN = 128
DEFAULT_BK = 128


def _plane_weights(bits: int) -> list[int]:
    """Python-int two's-complement plane weights (jnp constants cannot
    be captured in-kernel)."""
    return [2 ** b for b in range(bits - 1)] + [-(2 ** (bits - 1))]


def _unpack_int4_block(p: jax.Array) -> jax.Array:
    """[bk, bn//2] int8 packed -> [bk, bn] int8 (sign-extended nibbles)."""
    lo = jnp.left_shift(p, 4) >> 4          # arithmetic shift sign-extends
    hi = p >> 4
    out = jnp.stack([lo, hi], axis=-1)      # [bk, bn//2, 2]
    return out.reshape(p.shape[0], p.shape[1] * 2)


# ---------------------------------------------------------------------------
# Dense fused kernel: [M, K] x (LUT planes | packed int4) -> [M, N]
# ---------------------------------------------------------------------------


def _fused_kernel(x_ref, planes_ref, packed_ref, scale_ref, out_ref,
                  acc_ref, *, bits: int, nk: int, nn_lut: int):
    """One (m, col, k) grid step. Column blocks j < nn_lut take the
    bitplane path; blocks j >= nn_lut take the packed-int4 path. Both
    land in the same int32 accumulator and fp32 dequant epilogue."""
    j = pl.program_id(1)
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...]                                   # [bm, bk] int8

    @pl.when(j < nn_lut)
    def _lut():
        s = _plane_weights(bits)
        acc = acc_ref[...]
        for b in range(bits):                        # static unroll: planes
            part = jax.lax.dot(x, planes_ref[b],
                               preferred_element_type=jnp.int32)
            acc = acc + s[b] * part
        acc_ref[...] = acc

    @pl.when(j >= nn_lut)
    def _dsp():
        w = _unpack_int4_block(packed_ref[...])      # [bk, bn] int8
        acc_ref[...] += jax.lax.dot(x, w, preferred_element_type=jnp.int32)

    @pl.when(k == nk - 1)
    def _done():
        out_ref[...] = acc_ref[...].astype(jnp.float32) \
            * scale_ref[...][None, :]


@functools.partial(jax.jit, static_argnames=("bits", "n_lut_blocks", "bm",
                                             "bn", "bk", "interpret"))
def fused_hetero_gemm(x: jax.Array, planes: jax.Array, packed: jax.Array,
                      w_scale: jax.Array, bits: int, n_lut_blocks: int, *,
                      bm: int = DEFAULT_BM, bn: int = DEFAULT_BN,
                      bk: int = DEFAULT_BK,
                      interpret: bool = False) -> jax.Array:
    """Single-launch split GEMM over pre-padded operands.

    x: [M, K] int8; planes: [bits, K, N_lut] int8 {0, 1} plane stack of
    the LUT columns; packed: [K, N_dsp//2] int8 ``ref.pack_int4`` bytes
    of the DSP columns; w_scale: [N_lut + N_dsp] fp32. N_lut must be
    ``n_lut_blocks * bn``; every extent must divide by its block (pad at
    the ops.py layer). Returns fp32 [M, N_lut + N_dsp] in split column
    order.
    """
    m, k = x.shape
    _, _, n_lut = planes.shape
    n_dsp = packed.shape[1] * 2
    n = n_lut + n_dsp
    if planes.shape[0] != bits:
        raise ValueError(
            f"planes leading dim {planes.shape[0]} != bits {bits}")
    if n_lut != n_lut_blocks * bn:
        raise ValueError(f"LUT columns {n_lut} != n_lut_blocks*bn "
                         f"({n_lut_blocks}x{bn})")
    if m % bm or k % bk or n_dsp % bn:
        raise ValueError(f"shape ({m},{k},{n_lut}+{n_dsp}) not divisible "
                         f"by blocks ({bm},{bk},{bn}); pad first")
    nm, nn, nk = m // bm, n // bn, k // bk

    kwargs = {}
    if not interpret:
        kwargs["compiler_params"] = pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"))

    nl = n_lut_blocks
    return pl.pallas_call(
        functools.partial(_fused_kernel, bits=bits, nk=nk, nn_lut=nl),
        grid=(nm, nn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            # clamp each region's block index so the other region's
            # blocks read a valid (ignored) block instead of OOB
            pl.BlockSpec((bits, bk, bn),
                         lambda i, j, kk: (0, kk, jnp.minimum(j, nl - 1)
                                           if nl else 0)),
            pl.BlockSpec((bk, bn // 2),
                         lambda i, j, kk: (kk, jnp.maximum(j - nl, 0))),
            pl.BlockSpec((bn,), lambda i, j, kk: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=interpret,
        **kwargs,
    )(x, planes, packed, w_scale)


# ---------------------------------------------------------------------------
# Conv fused kernel: in-kernel im2col from the NHWC activation block
# ---------------------------------------------------------------------------


def fused_conv_vmem_bytes(in_hw: int, c_in: int, kernel: int, pad: int,
                          m: int, k: int, bits: int,
                          bn: int = DEFAULT_BN) -> int:
    """Rough VMEM working set of one ``fused_conv_gemm`` grid step: the
    padded spatial block, the per-column-block weight stack (planes are
    the worst case), the int32 accumulator and the fp32 output tile.
    The ops.py wrapper falls back to the vectorized jnp path when this
    exceeds the budget."""
    hp = in_hw + 2 * pad
    x_bytes = hp * hp * c_in
    w_bytes = max(bits, 1) * k * bn
    acc_bytes = 2 * m * bn * 4
    return x_bytes + w_bytes + acc_bytes


def _fused_conv_kernel(x_ref, planes_ref, packed_ref, scale_ref, out_ref, *,
                       bits: int, nn_lut: int, kernel: int, stride: int,
                       out_hw: int, c_in: int, m_pad: int):
    """One column-block grid step: generate im2col patches in-kernel
    (tap-by-tap static unroll over the (kh, kw) window) and contract
    them against this block's weight rows — LUT blocks through the
    bitplane path, DSP blocks through packed int4."""
    j = pl.program_id(0)
    x = x_ref[...]                           # [H+2p, W+2p, C] int8
    m = out_hw * out_hw
    span = stride * (out_hw - 1) + 1

    def taps():
        for t, (dh, dw) in enumerate(
                (a, b) for a in range(kernel) for b in range(kernel)):
            xt = jax.lax.slice(x, (dh, dw, 0),
                               (dh + span, dw + span, c_in),
                               (stride, stride, 1))  # [oh, oh, C]
            xt = xt.reshape(m, c_in)
            if m_pad != m:
                xt = jnp.pad(xt, ((0, m_pad - m), (0, 0)))
            yield t, xt

    @pl.when(j < nn_lut)
    def _lut():
        s = _plane_weights(bits)
        acc = jnp.zeros(out_ref.shape, jnp.int32)
        for t, xt in taps():
            rows = slice(t * c_in, (t + 1) * c_in)
            for b in range(bits):
                part = jax.lax.dot(xt, planes_ref[b, rows],
                                   preferred_element_type=jnp.int32)
                acc = acc + s[b] * part
        out_ref[...] = acc.astype(jnp.float32) * scale_ref[...][None, :]

    @pl.when(j >= nn_lut)
    def _dsp():
        acc = jnp.zeros(out_ref.shape, jnp.int32)
        for t, xt in taps():
            w = _unpack_int4_block(
                packed_ref[t * c_in:(t + 1) * c_in, :])
            acc = acc + jax.lax.dot(xt, w,
                                    preferred_element_type=jnp.int32)
        out_ref[...] = acc.astype(jnp.float32) * scale_ref[...][None, :]


@functools.partial(jax.jit, static_argnames=(
    "bits", "n_lut_blocks", "n_dsp_blocks", "kernel", "stride", "out_hw",
    "bn", "bm", "interpret"))
def fused_conv_gemm(x_sp: jax.Array, planes: jax.Array, packed: jax.Array,
                    w_scale: jax.Array, bits: int, n_lut_blocks: int,
                    n_dsp_blocks: int, kernel: int, stride: int,
                    out_hw: int, *, bm: int = 8, bn: int = DEFAULT_BN,
                    interpret: bool = False) -> jax.Array:
    """Single-launch im2col-free conv GEMM.

    x_sp: [H+2p, W+2p, C] int8 — the *already zero-padded* spatial
    activation block (code 0 is real 0.0 under the symmetric
    quantizer); planes: [bits, kernel**2*C, >=bn] LUT plane stack in
    (kh, kw, c) row order (the HWIO flattening); packed:
    [kernel**2*C, >=bn//2] int4-pair bytes; w_scale:
    [(n_lut_blocks + n_dsp_blocks) * bn] fp32 in split region order.
    The grid covers ``n_lut_blocks`` LUT column blocks then
    ``n_dsp_blocks`` DSP blocks; a region with zero blocks still needs
    one (dummy, never-consumed) weight block so its BlockSpec stays
    in-bounds. The m extent is padded to ``bm`` sublanes in-kernel.
    Returns fp32 [out_hw**2, N] in split column order.
    """
    c_in = x_sp.shape[2]
    nn = n_lut_blocks + n_dsp_blocks
    n = nn * bn
    if nn == 0:
        raise ValueError("grid needs at least one column block")
    k = kernel * kernel * c_in
    if planes.shape[2] < bn or packed.shape[1] < bn // 2:
        raise ValueError("each region needs at least one weight block "
                         "(use a dummy when the region is empty)")
    if w_scale.shape[0] < n:
        raise ValueError(f"scales {w_scale.shape[0]} < grid columns {n}")
    m = out_hw * out_hw
    m_pad = (m + bm - 1) // bm * bm

    kwargs = {}
    if not interpret:
        kwargs["compiler_params"] = pltpu.CompilerParams(
            dimension_semantics=("arbitrary",))

    nl = n_lut_blocks
    out = pl.pallas_call(
        functools.partial(
            _fused_conv_kernel, bits=bits, nn_lut=nl, kernel=kernel,
            stride=stride, out_hw=out_hw, c_in=c_in, m_pad=m_pad),
        grid=(nn,),
        in_specs=[
            pl.BlockSpec(x_sp.shape, lambda j: (0, 0, 0)),
            pl.BlockSpec((max(bits, 1), k, bn),
                         lambda j: (0, 0, jnp.minimum(j, nl - 1)
                                    if nl else 0)),
            pl.BlockSpec((k, bn // 2),
                         lambda j: (0, jnp.maximum(j - nl, 0))),
            pl.BlockSpec((bn,), lambda j: (j,)),
        ],
        out_specs=pl.BlockSpec((m_pad, bn), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((m_pad, n), jnp.float32),
        interpret=interpret,
        **kwargs,
    )(x_sp, planes, packed, w_scale)
    return out[:m]
