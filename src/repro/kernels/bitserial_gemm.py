"""Bitplane GEMM Pallas kernel — the TPU adaptation of the LUT-core.

The paper's LUT-core executes an ``a``-bit x ``w``-bit GEMM as a weighted
sum of binary GEMMs (Eq. 1), one XNOR-popcount pass per plane pair, so
latency scales with the operand bit-width. A literal bit-serial port
would waste the MXU (a 128x128 systolic array with native int8 support),
so we *keep the decomposition but parallelize each plane*: every binary
weight plane is an int8 MXU matmul; shifted partial sums accumulate in
an int32 VMEM scratch accumulator. Compute cost remains proportional to
the number of planes — exactly the cost-model structure the paper's DSE
relies on (L_LUT ∝ B_w) — while each plane runs at full MXU rate.

Tiling: grid (nm, nn, nk), K innermost ("arbitrary" dimension semantics:
the accumulator carries across the K sweep). Block shapes are the VMEM
working set: x-block [bm, bk] int8, one weight block per plane
[bits, bk, bn] int8, accumulator [bm, bn] int32 — choose bm/bn/bk as
multiples of the 128-lane MXU dims (the defaults are).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu




DEFAULT_BM = 128
DEFAULT_BN = 128
DEFAULT_BK = 128


def _bitserial_kernel(x_ref, planes_ref, scale_ref, out_ref, acc_ref, *,
                      bits: int, nk: int):
    """One (m, n, k) grid step: acc += sum_b s_b * (x_blk @ plane_b)."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...]                                   # [bm, bk] int8
    # Python-int plane weights (jnp constants cannot be captured in-kernel).
    s = [2 ** b for b in range(bits - 1)] + [-(2 ** (bits - 1))]
    acc = acc_ref[...]
    for b in range(bits):                            # static unroll: planes
        part = jax.lax.dot(x, planes_ref[b],
                           preferred_element_type=jnp.int32)
        acc = acc + s[b] * part
    acc_ref[...] = acc

    @pl.when(k == nk - 1)
    def _done():
        out_ref[...] = acc_ref[...].astype(jnp.float32) * scale_ref[...][None, :]


@functools.partial(jax.jit, static_argnames=("bits", "bm", "bn", "bk",
                                             "interpret"))
def bitserial_gemm(x: jax.Array, planes: jax.Array, w_scale: jax.Array,
                   bits: int, *, bm: int = DEFAULT_BM, bn: int = DEFAULT_BN,
                   bk: int = DEFAULT_BK, interpret: bool = False) -> jax.Array:
    """out[M, N] (fp32) = (x int8 @ reconstruct(planes)) * w_scale.

    x: [M, K] int8; planes: [bits, K, N] int8 in {0, 1}
    (``ref.bitplane_decompose`` layout); w_scale: [N] fp32.
    M, K, N must divide by the block shape (pad at the ops.py layer).
    """
    m, k = x.shape
    _, _, n = planes.shape
    if planes.shape[0] != bits:
        raise ValueError(f"planes leading dim {planes.shape[0]} != bits {bits}")
    if m % bm or n % bn or k % bk:
        raise ValueError(f"shape ({m},{k},{n}) not divisible by blocks "
                         f"({bm},{bk},{bn}); pad first")
    nm, nn, nk = m // bm, n // bn, k // bk

    kwargs = {}
    if not interpret:
        kwargs["compiler_params"] = pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"))

    return pl.pallas_call(
        functools.partial(_bitserial_kernel, bits=bits, nk=nk),
        grid=(nm, nn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bits, bk, bn), lambda i, j, kk: (0, kk, j)),
            pl.BlockSpec((bn,), lambda i, j, kk: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=interpret,
        **kwargs,
    )(x, planes, w_scale)
