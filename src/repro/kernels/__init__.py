"""Pallas TPU kernels for the perf-critical compute paths.

  bitserial_gemm — bitplane GEMM (the LUT-core adaptation; latency ∝ bits)
  int4_gemm      — packed-int4 GEMM (the DSP-core adaptation; fixed latency)
  fused_hetero_gemm — both sides of the Eq.-12 split in ONE launch
                     (dense + im2col-free conv variants)
  flash_attention — online-softmax attention for serving hot paths

Each kernel has a pure-jnp oracle in ``ref.py`` and is validated against
it in interpret mode by the test suite. ``ops.py`` holds the public
wrappers (padding, backend dispatch, split-side normalization, GQA
broadcast).
"""
from repro.kernels.ops import (
    attention,
    bitserial_matmul,
    fused_conv_matmul,
    fused_depthwise_matmul,
    fused_grouped_matmul,
    fused_matmul,
    hetero_matmul,
    int4_matmul,
)
from repro.kernels.ref import (
    bitplane_decompose,
    bitplane_reconstruct,
    conv_patches_ref,
    pack_int4,
    plane_scales,
    unpack_int4,
)

__all__ = [
    "attention", "bitserial_matmul", "fused_conv_matmul",
    "fused_depthwise_matmul", "fused_grouped_matmul", "fused_matmul",
    "hetero_matmul", "int4_matmul",
    "bitplane_decompose", "bitplane_reconstruct", "conv_patches_ref",
    "pack_int4", "plane_scales", "unpack_int4",
]
