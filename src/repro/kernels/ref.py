"""Pure-jnp oracles for the Pallas kernels.

Every kernel in this package has its reference here; the per-kernel
tests sweep shapes/dtypes and ``assert_allclose`` kernel-vs-oracle
(kernels run in ``interpret=True`` mode on CPU).

Also hosts the representation helpers shared by oracle and kernel:

  * ``bitplane_decompose`` — paper Eq. (1): an ``bits``-bit signed
    integer tensor becomes ``bits`` binary planes with per-plane signed
    weights (two's complement: MSB plane weight is -2^(bits-1)).
  * ``pack_int4`` / ``unpack_int4`` — two int4 codes per int8 byte
    along the last axis (the DSP-core-analogue packed layout).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Representation helpers
# ---------------------------------------------------------------------------


def plane_scales(bits: int) -> jax.Array:
    """Signed per-plane weights of a two's-complement decomposition."""
    s = [2 ** b for b in range(bits - 1)] + [-(2 ** (bits - 1))]
    return jnp.asarray(s, dtype=jnp.int32)


def bitplane_decompose(q: jax.Array, bits: int) -> jax.Array:
    """Signed integer codes -> ``[bits, ...]`` binary planes (int8 0/1).

    Reconstruction: ``q == sum_b plane_scales(bits)[b] * planes[b]``.
    """
    u = jnp.asarray(q, jnp.int32) & ((1 << bits) - 1)  # two's complement bits
    shifts = jnp.arange(bits, dtype=jnp.int32).reshape((bits,) + (1,) * q.ndim)
    return ((u[None] >> shifts) & 1).astype(jnp.int8)


def bitplane_reconstruct(planes: jax.Array) -> jax.Array:
    bits = planes.shape[0]
    s = plane_scales(bits).reshape((bits,) + (1,) * (planes.ndim - 1))
    return jnp.sum(planes.astype(jnp.int32) * s, axis=0)


def pack_int4(q: jax.Array) -> jax.Array:
    """Pack signed int4 codes pairwise along the last axis: [..., N] ->
    [..., N//2] int8 with even index in the low nibble."""
    if q.shape[-1] % 2 != 0:
        raise ValueError("last axis must be even to pack int4 pairs")
    lo = jnp.asarray(q[..., 0::2], jnp.int32) & 0xF
    hi = jnp.asarray(q[..., 1::2], jnp.int32) & 0xF
    return ((hi << 4) | lo).astype(jnp.int8)


def unpack_int4(p: jax.Array) -> jax.Array:
    """Inverse of ``pack_int4`` (sign-extended)."""
    b = jnp.asarray(p, jnp.int8)
    lo = jnp.left_shift(b, 4) >> 4          # arithmetic shift sign-extends
    hi = b >> 4
    out = jnp.stack([lo, hi], axis=-1)
    return out.reshape(*p.shape[:-1], p.shape[-1] * 2).astype(jnp.int8)


# ---------------------------------------------------------------------------
# Oracles
# ---------------------------------------------------------------------------


def bitserial_gemm_ref(x: jax.Array, w_q: jax.Array, w_scale: jax.Array,
                       bits: int) -> jax.Array:
    """Bitplane GEMM oracle.

    x: [M, K] int8 activations (already quantized, symmetric).
    w_q: [K, N] signed integer weight codes within ``bits`` bits.
    w_scale: [N] fp32 per-column dequantization scales.
    Returns fp32 [M, N] = (x @ w_q) * w_scale, computed through the
    bitplane decomposition so the oracle exercises the same numerics.
    """
    planes = bitplane_decompose(w_q, bits)                # [B, K, N]
    s = plane_scales(bits)
    acc = jnp.zeros((x.shape[0], w_q.shape[1]), jnp.int32)
    for b in range(bits):
        part = jax.lax.dot(x.astype(jnp.int8), planes[b],
                           preferred_element_type=jnp.int32)
        acc = acc + s[b] * part
    return acc.astype(jnp.float32) * w_scale[None, :]


def bitserial_grouped_gemm_ref(x_col: jax.Array, w_q: jax.Array,
                               w_scale: jax.Array, bits: int) -> jax.Array:
    """Grouped (depthwise) bitplane GEMM oracle.

    x_col: [M, K, N] int8 — one im2col slice per output channel (K is
    the kh*kw tap count; channel c only sees its own slice).
    w_q: [K, N] signed codes within ``bits`` bits; w_scale: [N] fp32.
    Returns fp32 [M, N] with out[m, c] = (sum_k x_col[m,k,c] *
    w_q[k,c]) * w_scale[c], accumulated exactly in int32 through the
    bitplane decomposition (same numerics as the dense oracle).
    """
    planes = bitplane_decompose(w_q, bits)                # [B, K, N]
    s = plane_scales(bits)
    acc = jnp.zeros((x_col.shape[0], w_q.shape[1]), jnp.int32)
    xc = x_col.astype(jnp.int32)
    for b in range(bits):
        part = jnp.einsum("mkc,kc->mc", xc, planes[b].astype(jnp.int32))
        acc = acc + s[b] * part
    return acc.astype(jnp.float32) * w_scale[None, :]


def int4_grouped_gemm_ref(x_col: jax.Array, w_q: jax.Array,
                          w_scale: jax.Array) -> jax.Array:
    """Grouped (depthwise) int4 GEMM oracle.

    x_col: [M, K, N] int8 per-channel im2col slices; w_q: [K, N] int32
    codes in [-8, 7]; w_scale: [N] fp32. Exact int32 accumulation.
    """
    acc = jnp.einsum("mkc,kc->mc", x_col.astype(jnp.int32),
                     jnp.asarray(w_q, jnp.int32))
    return acc.astype(jnp.float32) * w_scale[None, :]


def int4_gemm_ref(x: jax.Array, w_packed: jax.Array, w_scale: jax.Array
                  ) -> jax.Array:
    """Packed-int4 GEMM oracle.

    x: [M, K] int8; w_packed: [K, N//2] int8 (pack_int4 layout);
    w_scale: [N] fp32. Returns fp32 [M, N].
    """
    w = unpack_int4(w_packed)                              # [K, N] int8
    acc = jax.lax.dot(x.astype(jnp.int8), w,
                      preferred_element_type=jnp.int32)
    return acc.astype(jnp.float32) * w_scale[None, :]


def conv_patches_ref(x_sp: jax.Array, kernel: int, stride: int, pad: int,
                     out_hw: int) -> jax.Array:
    """Im2col patch generation from a spatial [H, W, C] tensor:
    returns [out_hw*out_hw, kernel*kernel, C] (output positions
    row-major, taps in (kh, kw) order). Zero padding — code 0 is real
    0.0 under the symmetric quantizer.

    The single source for the patch layout: the executors' staging
    helper and the fused conv kernels' oracles both delegate here, so
    the (kh, kw, c) column order matches the HWIO weight flattening
    ``w.reshape(k, n)`` everywhere.
    """
    x = jnp.pad(x_sp, ((pad, pad), (pad, pad), (0, 0)))
    span = stride * (out_hw - 1) + 1
    taps = [x[dh:dh + span:stride, dw:dw + span:stride, :]
            for dh in range(kernel) for dw in range(kernel)]
    pat = jnp.stack(taps, axis=2)              # [oh, oh, kk*kk, C]
    return pat.reshape(out_hw * out_hw, kernel * kernel, x_sp.shape[2])


def fused_hetero_gemm_ref(x: jax.Array, w_lut: jax.Array | None,
                          s_lut: jax.Array | None, bits: int,
                          w_dsp: jax.Array | None,
                          s_dsp: jax.Array | None) -> jax.Array:
    """Fused split-GEMM oracle: one int32 accumulation pass over both
    sides of the Eq.-12 split, one per-column dequant.

    x: [M, K] int8; w_lut: [K, n_lut] codes within ``bits`` bits (or
    None); w_dsp: [K, n_dsp] int32 codes in [-8, 7] (or None); s_*:
    per-column fp32 scales. Returns fp32 [M, n_lut + n_dsp] in split
    column order — bit-identical to ``hetero_gemm_ref`` (both paths
    accumulate exactly in int32; the fp32 dequant is per output
    element, so fusing the concat cannot change a single bit).
    """
    accs, scales = [], []
    if w_lut is not None and w_lut.shape[1]:
        planes = bitplane_decompose(w_lut, bits)
        s = plane_scales(bits)
        acc = jnp.zeros((x.shape[0], w_lut.shape[1]), jnp.int32)
        for b in range(bits):
            part = jax.lax.dot(x.astype(jnp.int8), planes[b],
                               preferred_element_type=jnp.int32)
            acc = acc + s[b] * part
        accs.append(acc)
        scales.append(s_lut)
    if w_dsp is not None and w_dsp.shape[1]:
        accs.append(jax.lax.dot(x.astype(jnp.int8),
                                jnp.asarray(w_dsp, jnp.int8),
                                preferred_element_type=jnp.int32))
        scales.append(s_dsp)
    acc = accs[0] if len(accs) == 1 else jnp.concatenate(accs, axis=1)
    sc = scales[0] if len(scales) == 1 else jnp.concatenate(scales)
    return acc.astype(jnp.float32) * sc[None, :]


def fused_hetero_grouped_gemm_ref(x_col: jax.Array,
                                  w_lut: jax.Array | None,
                                  s_lut: jax.Array | None, bits: int,
                                  w_dsp: jax.Array | None,
                                  s_dsp: jax.Array | None) -> jax.Array:
    """Fused grouped (depthwise) split-GEMM oracle.

    x_col: [M, K, N] int8 per-channel im2col slices over *all* N
    channels in split order — the first n_lut channels contract
    bit-serially, the rest through the int4 path. Bit-identical to the
    two grouped oracles run per partition and concatenated.
    """
    outs = []
    n_lut = 0 if w_lut is None else w_lut.shape[1]
    if n_lut:
        outs.append(bitserial_grouped_gemm_ref(
            x_col[:, :, :n_lut], w_lut, s_lut, bits))
    if w_dsp is not None and w_dsp.shape[1]:
        outs.append(int4_grouped_gemm_ref(
            x_col[:, :, n_lut:], w_dsp, s_dsp))
    return outs[0] if len(outs) == 1 else jnp.concatenate(outs, axis=1)


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                        causal: bool = True, scale: float | None = None,
                        kv_offset: int = 0) -> jax.Array:
    """Plain softmax attention oracle.

    q: [B, H, Sq, D]; k, v: [B, H, Skv, D]. ``kv_offset`` positions the
    query block inside the KV sequence (decode: Sq=1, offset=Skv-1).
    """
    d = q.shape[-1]
    scale = scale if scale is not None else d ** -0.5
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if causal:
        sq, skv = q.shape[2], k.shape[2]
        qpos = jnp.arange(sq)[:, None] + kv_offset
        kpos = jnp.arange(skv)[None, :]
        logits = jnp.where(kpos <= qpos, logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32)
                      ).astype(q.dtype)


def hetero_gemm_ref(x: jax.Array, w_q_serial: jax.Array, s_serial: jax.Array,
                    bits_serial: int, w_packed_parallel: jax.Array,
                    s_parallel: jax.Array) -> jax.Array:
    """The paper's heterogeneous split GEMM: first columns via the
    bitplane path, remaining via the packed-int4 path, concatenated."""
    lo = bitserial_gemm_ref(x, w_q_serial, s_serial, bits_serial)
    hi = int4_gemm_ref(x, w_packed_parallel, s_parallel)
    return jnp.concatenate([lo, hi], axis=-1)
