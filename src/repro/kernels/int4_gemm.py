"""Packed-int4 GEMM Pallas kernel — the TPU adaptation of the DSP-core.

The paper's DSP-core is a bit-parallel fixed-precision (int4 weight)
engine: latency is independent of weight bit-width because the DSP48
slices always run full-width MACs. The MXU analogue is an int8 matmul
over weights stored *packed* two-int4-per-byte in HBM (halving weight
bandwidth — the DSP-core's reason to exist was exactly this rigidity/
efficiency trade) and unpacked to int8 in VMEM right before the MXU.

Tiling mirrors ``bitserial_gemm``: grid (nm, nn, nk) with K innermost
and an int32 VMEM accumulator; the weight block is [bk, bn//2] packed
bytes, unpacked in-register to [bk, bn]. Per-column fp32 scales are
applied in the epilogue on the last K step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


DEFAULT_BM = 128
DEFAULT_BN = 128
DEFAULT_BK = 128


def _unpack_int4_block(p: jax.Array) -> jax.Array:
    """[bk, bn//2] int8 packed -> [bk, bn] int8 (sign-extended nibbles)."""
    lo = jnp.left_shift(p, 4) >> 4          # arithmetic shift sign-extends
    hi = p >> 4
    out = jnp.stack([lo, hi], axis=-1)      # [bk, bn//2, 2]
    return out.reshape(p.shape[0], p.shape[1] * 2)


def _int4_kernel(x_ref, w_ref, scale_ref, out_ref, acc_ref, *, nk: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    w = _unpack_int4_block(w_ref[...])               # [bk, bn] int8
    acc_ref[...] += jax.lax.dot(x_ref[...], w,
                                preferred_element_type=jnp.int32)

    @pl.when(k == nk - 1)
    def _done():
        out_ref[...] = acc_ref[...].astype(jnp.float32) * scale_ref[...][None, :]


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def int4_gemm(x: jax.Array, w_packed: jax.Array, w_scale: jax.Array, *,
              bm: int = DEFAULT_BM, bn: int = DEFAULT_BN,
              bk: int = DEFAULT_BK, interpret: bool = False) -> jax.Array:
    """out[M, N] (fp32) = (x int8 @ unpack(w_packed)) * w_scale.

    x: [M, K] int8; w_packed: [K, N//2] int8 (``ref.pack_int4`` layout);
    w_scale: [N] fp32. Shapes must divide by blocks (pad in ops.py).
    """
    m, k = x.shape
    kw, n_half = w_packed.shape
    n = n_half * 2
    if kw != k:
        raise ValueError(f"K mismatch: x has {k}, w_packed has {kw}")
    if m % bm or n % bn or k % bk:
        raise ValueError(f"shape ({m},{k},{n}) not divisible by blocks "
                         f"({bm},{bk},{bn}); pad first")
    nm, nn, nk = m // bm, n // bn, k // bk

    kwargs = {}
    if not interpret:
        kwargs["compiler_params"] = pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"))

    return pl.pallas_call(
        functools.partial(_int4_kernel, nk=nk),
        grid=(nm, nn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn // 2), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bn,), lambda i, j, kk: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=interpret,
        **kwargs,
    )(x, w_packed, w_scale)
