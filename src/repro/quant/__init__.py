"""Quantization substrate for N3H-Core.

Implements the paper's uniform quantizer (Eq. 2), the filter-wise hybrid
mixed-precision scheme (Fig. 6), the KL-divergence filter->core
allocation, and quantization-aware-training (STE) utilities.
"""
from repro.quant.uniform import (
    qrange,
    quantize,
    dequantize,
    fake_quant,
    fake_quant_per_channel,
    fit_scale,
    fit_scale_per_channel,
    quant_snr_db,
)
from repro.quant.hybrid import (
    LayerQuantConfig,
    HybridQuantizedWeight,
    hybrid_quantize_weight,
    hybrid_fake_quant_weight,
    kl_filter_allocation,
)

__all__ = [
    "qrange",
    "quantize",
    "dequantize",
    "fake_quant",
    "fake_quant_per_channel",
    "fit_scale",
    "fit_scale_per_channel",
    "quant_snr_db",
    "LayerQuantConfig",
    "HybridQuantizedWeight",
    "hybrid_quantize_weight",
    "hybrid_fake_quant_weight",
    "kl_filter_allocation",
]
