"""Uniform symmetric quantizer — paper Eq. (2).

    x_hat = f_q(x, s) = clip(round(x / s), alpha_hat, beta_hat)

with ``alpha_hat = -2^(N_bits-1)`` and ``beta_hat = 2^(N_bits-1) - 1``.
(The paper's printed clip() swaps min/max arguments; we implement the
standard clamp.)

All functions are jit-safe; ``bits`` is static.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


def qrange(bits: int) -> tuple[int, int]:
    """Integer range (alpha_hat, beta_hat) of a signed ``bits``-bit code."""
    if bits < 1:
        raise ValueError(f"bits must be >= 1, got {bits}")
    return -(2 ** (bits - 1)), 2 ** (bits - 1) - 1


def quantize(x: jax.Array, s: jax.Array, bits: int) -> jax.Array:
    """Eq. (2): real tensor -> integer codes (round-to-nearest-even)."""
    lo, hi = qrange(bits)
    q = jnp.clip(jnp.round(x / s), lo, hi)
    return q.astype(jnp.int32)


def dequantize(q: jax.Array, s: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * s


def _inv_hi(bits: int) -> jnp.float32:
    """Pre-rounded f32 reciprocal of beta_hat. Scales multiply by this
    instead of dividing by ``hi``: XLA rewrites division-by-constant
    into multiplication by the f32-rounded reciprocal under jit, so the
    divide form computes *different* scales eagerly vs jitted (a 1-ulp
    drift that compounds across a chained network). The explicit
    reciprocal-multiply is what jit produces anyway, and a multiply of
    identical operands is bit-identical in both modes."""
    _, hi = qrange(bits)
    return jnp.float32(1.0 / hi)


def fit_scale(x: jax.Array, bits: int, eps: float = 1e-8) -> jax.Array:
    """Symmetric max-abs scale: s = max|x| / beta_hat (per tensor)."""
    return jnp.maximum(jnp.max(jnp.abs(x)), eps) * _inv_hi(bits)


def fit_scale_per_channel(x: jax.Array, bits: int, axis: int = 0,
                          eps: float = 1e-8) -> jax.Array:
    """Per-channel (filter-wise) scales along ``axis``; keepdims for broadcast."""
    reduce_axes = tuple(i for i in range(x.ndim) if i != axis)
    m = jnp.max(jnp.abs(x), axis=reduce_axes, keepdims=True)
    return jnp.maximum(m, eps) * _inv_hi(bits)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def fake_quant(x: jax.Array, s: jax.Array, bits: int) -> jax.Array:
    """Quantize-dequantize with a straight-through estimator.

    Forward: dequantize(quantize(x, s, bits), s).
    Backward: identity for x within the clip range, zero outside
    (the standard STE used in quantization-aware training).
    """
    return dequantize(quantize(x, s, bits), s)


def _fake_quant_fwd(x, s, bits):
    lo, hi = qrange(bits)
    in_range = jnp.logical_and(x / s >= lo, x / s <= hi)
    return fake_quant(x, s, bits), in_range


def _fake_quant_bwd(bits, res, g):
    in_range = res
    gx = jnp.where(in_range, g, 0.0)
    # scale is treated as a calibration constant (no gradient), matching
    # the paper's max-abs calibrated uniform quantizer.
    return gx, jnp.zeros(())


fake_quant.defvjp(_fake_quant_fwd, _fake_quant_bwd)


def fake_quant_per_channel(x: jax.Array, bits: int, axis: int = 0) -> jax.Array:
    """Per-channel fake quantization with on-the-fly max-abs scales (STE)."""
    s = fit_scale_per_channel(jax.lax.stop_gradient(x), bits, axis=axis)
    q = jnp.clip(jnp.round(x / s), *qrange(bits))
    deq = q * s
    # STE: forward uses deq, gradient flows as identity.
    return x + jax.lax.stop_gradient(deq - x)


def quant_snr_db(x: jax.Array, x_hat: jax.Array, eps: float = 1e-12) -> jax.Array:
    """Signal-to-quantization-noise ratio in dB (accuracy proxy when no
    labelled dataset is available offline)."""
    sig = jnp.sum(jnp.square(x))
    err = jnp.sum(jnp.square(x - x_hat))
    return 10.0 * jnp.log10((sig + eps) / (err + eps))
