"""Filter-wise hybrid quantization — paper Section 4 and Fig. 6.

A layer's weight tensor ``W`` (viewed as c_out filters) is split between
the two heterogeneous cores:

  * DSP-core filters: fixed ``B_DSP`` = 4-bit uniform quantization.
  * LUT-core filters: flexible ``B_wL`` in 2..8 bits (per layer, chosen
    by the DSE framework).

Which filters go where is decided by the KL divergence between each
filter's fp32 weight distribution and its quantized counterpart: filters
with the *largest* divergence (i.e. most damaged by quantization) are
allocated to the core with the higher bit-width.

Activations are quantized layer-wise with a shared ``B_a`` (2..4 bits;
8-bit for first/last layers) since both cores consume the same
activation stream.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.quant.uniform import (
    dequantize,
    fake_quant_per_channel,
    fit_scale_per_channel,
    quantize,
)

DSP_WEIGHT_BITS = 4  # the paper's DSP-core is designed for int4 weights


@dataclasses.dataclass(frozen=True)
class LayerQuantConfig:
    """Per-layer knobs searched by the DSE framework (Table 2)."""
    w_bits_lut: int = 4      # B^{w-L} in 2..8
    a_bits: int = 4          # B^{a}   in 2..4 (8 for first/last layers)
    ratio: float = 0.5       # Eq. (11): Filter_LUT / Filter_all
    w_bits_dsp: int = DSP_WEIGHT_BITS
    alloc_metric: str = "kl"  # "kl" (paper) | "mse" (beyond-paper)

    def __post_init__(self):
        if not (0.0 <= self.ratio <= 1.0):
            raise ValueError(f"ratio must be in [0,1], got {self.ratio}")
        if not (1 <= self.w_bits_lut <= 8):
            raise ValueError(f"w_bits_lut out of range: {self.w_bits_lut}")
        if not (1 <= self.a_bits <= 8):
            raise ValueError(f"a_bits out of range: {self.a_bits}")

    def n_lut_filters(self, c_out: int) -> int:
        return int(round(self.ratio * c_out))


@dataclasses.dataclass
class HybridQuantizedWeight:
    """Integer codes + scales + core assignment for one layer.

    ``perm`` maps sorted position -> original filter index; the first
    ``n_lut`` entries are the LUT-core filters (highest KL divergence
    when w_bits_lut > 4, lowest otherwise).
    """
    q_lut: jax.Array        # [n_lut, ...] integer codes (int32)
    q_dsp: jax.Array        # [n_dsp, ...] integer codes (int32)
    s_lut: jax.Array        # [n_lut, 1...] per-filter scales
    s_dsp: jax.Array        # [n_dsp, 1...]
    perm: jax.Array         # [c_out] original filter index per sorted slot
    cfg: LayerQuantConfig

    @property
    def n_lut(self) -> int:
        return self.q_lut.shape[0]

    def dequantize(self) -> jax.Array:
        """Reconstruct the fake-quantized weight in original filter order."""
        w_lut = dequantize(self.q_lut, self.s_lut)
        w_dsp = dequantize(self.q_dsp, self.s_dsp)
        w_sorted = jnp.concatenate([w_lut, w_dsp], axis=0)
        inv = jnp.argsort(self.perm)
        return w_sorted[inv]


def _filter_kl_divergence(w: jax.Array, bits: int, n_bins: int = 64) -> jax.Array:
    """Per-filter KL(P_fp32 || P_quant) over weight-value histograms.

    ``w``: [c_out, K] flattened filters. Histograms share per-filter bin
    edges spanning [-max|w|, max|w|]; the quantized histogram is built
    from the dequantized codes (calibration as in the paper, which uses
    one batch of images — weights need no data).
    """
    s = fit_scale_per_channel(w, bits, axis=0)
    deq = dequantize(quantize(w, s, bits), s)

    lo = -jnp.max(jnp.abs(w), axis=1, keepdims=True) - 1e-6
    hi = -lo
    edges = jnp.linspace(0.0, 1.0, n_bins + 1)[None, :]  # [1, n_bins+1]

    def hist(x):
        # normalized positions in [0,1], hard-binned, then smoothed with a
        # small triangular kernel — the raw histogram KL is dominated by
        # per-bin sampling noise at realistic filter sizes otherwise.
        t = (x - lo) / (hi - lo)
        idx = jnp.clip((t * n_bins).astype(jnp.int32), 0, n_bins - 1)
        one_hot = jax.nn.one_hot(idx, n_bins, dtype=jnp.float32)
        h = jnp.sum(one_hot, axis=1)  # [c_out, n_bins]
        h = (h
             + 0.5 * jnp.pad(h[:, 1:], ((0, 0), (0, 1)))
             + 0.5 * jnp.pad(h[:, :-1], ((0, 0), (1, 0))))
        return h / jnp.maximum(jnp.sum(h, axis=1, keepdims=True), 1.0)

    del edges
    p = hist(w)
    q = hist(deq)
    eps = 1e-8
    return jnp.sum(p * (jnp.log(p + eps) - jnp.log(q + eps)), axis=1)


def _filter_rel_mse(w: jax.Array, bits: int) -> jax.Array:
    """Per-filter relative quantization MSE — a *beyond-paper* allocation
    metric. On mixed filter ensembles the histogram KL of the paper
    correlates only weakly with actual quantization damage (outlier-
    laden filters get LOW KL but HIGH damage); relative MSE ranks by the
    damage itself. Selected with ``LayerQuantConfig.alloc_metric``."""
    s = fit_scale_per_channel(w, bits, axis=0)
    deq = dequantize(quantize(w, s, bits), s)
    num = jnp.sum(jnp.square(deq - w), axis=1)
    den = jnp.maximum(jnp.sum(jnp.square(w), axis=1), 1e-12)
    return num / den


def kl_filter_allocation(w: jax.Array, cfg: LayerQuantConfig) -> jax.Array:
    """Return a permutation of filter indices: first n_lut slots -> LUT core.

    Paper rule: filters with greater KL divergence go to the core with
    the *higher* bit-width. When ``w_bits_lut >= w_bits_dsp`` the LUT
    core is the high-precision one so it takes the top-KL filters;
    otherwise the DSP core (fixed int4) takes them.
    ``cfg.alloc_metric`` picks the sensitivity metric: "kl" (paper) or
    "mse" (beyond-paper; tracks damage more faithfully).
    """
    c_out = w.shape[0]
    flat = w.reshape(c_out, -1)
    # Divergence at the *lower* of the two bit-widths: that is the one
    # that damages sensitive filters, so rank by it.
    probe_bits = min(cfg.w_bits_lut, cfg.w_bits_dsp)
    if cfg.alloc_metric == "mse":
        kl = _filter_rel_mse(flat, probe_bits)
    else:
        kl = _filter_kl_divergence(flat, probe_bits)
    order_desc = jnp.argsort(-kl)  # highest divergence first
    n_lut = cfg.n_lut_filters(c_out)
    if cfg.w_bits_lut >= cfg.w_bits_dsp:
        # LUT core is high precision: it takes the most sensitive filters.
        lut_idx = order_desc[:n_lut]
        dsp_idx = order_desc[n_lut:]
    else:
        dsp_idx = order_desc[: c_out - n_lut]
        lut_idx = order_desc[c_out - n_lut:]
    return jnp.concatenate([lut_idx, dsp_idx], axis=0)


def hybrid_quantize_weight(w: jax.Array, cfg: LayerQuantConfig,
                           perm: jax.Array | None = None) -> HybridQuantizedWeight:
    """Quantize filters into the two-core hybrid representation.

    ``w``: [c_out, ...]. Returns integer codes for both partitions with
    per-filter scales.
    """
    c_out = w.shape[0]
    if perm is None:
        perm = kl_filter_allocation(w, cfg)
    n_lut = cfg.n_lut_filters(c_out)
    w_sorted = w[perm]
    w_lut, w_dsp = w_sorted[:n_lut], w_sorted[n_lut:]

    s_lut = fit_scale_per_channel(w_lut, cfg.w_bits_lut, axis=0)
    s_dsp = fit_scale_per_channel(w_dsp, cfg.w_bits_dsp, axis=0)
    return HybridQuantizedWeight(
        q_lut=quantize(w_lut, s_lut, cfg.w_bits_lut),
        q_dsp=quantize(w_dsp, s_dsp, cfg.w_bits_dsp),
        s_lut=s_lut,
        s_dsp=s_dsp,
        perm=perm,
        cfg=cfg,
    )


def hybrid_fake_quant_weight(w: jax.Array, cfg: LayerQuantConfig,
                             perm: jax.Array | None = None) -> jax.Array:
    """Differentiable (STE) hybrid fake-quantization, for QAT.

    Keeps original filter order; each filter is fake-quantized at the
    bit-width of the core it is allocated to.
    """
    c_out = w.shape[0]
    if perm is None:
        perm = kl_filter_allocation(jax.lax.stop_gradient(w), cfg)
    n_lut = cfg.n_lut_filters(c_out)
    is_lut_slot = jnp.arange(c_out) < n_lut
    inv = jnp.argsort(perm)
    is_lut = is_lut_slot[inv]  # [c_out] in original order

    fq_lut = fake_quant_per_channel(w, cfg.w_bits_lut, axis=0)
    fq_dsp = fake_quant_per_channel(w, cfg.w_bits_dsp, axis=0)
    mask_shape = (c_out,) + (1,) * (w.ndim - 1)
    m = is_lut.reshape(mask_shape)
    return jnp.where(m, fq_lut, fq_dsp)


def model_size_bits(layer_shapes: list[tuple[int, int]],
                    cfgs: list[LayerQuantConfig]) -> int:
    """Total weight footprint in bits under a hybrid scheme.

    ``layer_shapes``: (c_out, fan_in) per layer.
    """
    total = 0
    for (c_out, fan_in), cfg in zip(layer_shapes, cfgs):
        n_lut = cfg.n_lut_filters(c_out)
        total += n_lut * fan_in * cfg.w_bits_lut
        total += (c_out - n_lut) * fan_in * cfg.w_bits_dsp
    return total
