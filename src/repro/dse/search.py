"""End-to-end DSE driver — the paper's Table 3 generator.

``run_search(network, device, target_latency_ms, episodes)`` runs the
DDPG agent over the N3H environment and returns the best feasible
configuration found (hardware knobs + per-layer bit-widths + split
ratios), exactly the artifact the paper's framework emits.

The paper explores 900 episodes; the default here is smaller so the
benchmark suite stays fast — pass ``episodes=900`` to match.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Sequence

import numpy as np

from repro.core.scheduler import DEVICES, FPGADevice
from repro.core.workloads import WORKLOADS, ConvSpec
from repro.dse.ddpg import DDPGAgent, DDPGConfig
from repro.dse.env import STATE_DIM, AccuracyProxy, N3HEnv, N3HEnvConfig


@dataclasses.dataclass
class SearchResult:
    best_reward: float
    best_info: dict
    rewards: list[float]
    episodes: int
    wall_s: float

    def table3_row(self) -> dict:
        """The paper's Table 3 columns."""
        info = self.best_info
        lut = info["lut_cfg"]
        dsp = info["dsp_cfg"]
        return {
            "K": lut.k, "M": lut.m, "N": lut.n,
            "D_L_buf_a": lut.d_a,
            "D_D_buf_a": dsp.d_a,
            "D_D_buf_w": dsp.d_w,
            "latency_ms": round(info["latency_ms"], 2),
            "acc_proxy": round(info["acc"], 2),
        }


def run_search(network: str = "resnet18", device: str = "XC7Z020",
               target_latency_ms: float = 35.0, episodes: int = 120,
               seed: int = 0, baseline_acc: float = 69.76,
               specs: Sequence[ConvSpec] | None = None,
               verbose: bool = False) -> SearchResult:
    dev: FPGADevice = DEVICES[device]
    layer_specs = list(specs) if specs is not None \
        else WORKLOADS[network]()
    env = N3HEnv(layer_specs, N3HEnvConfig(
        device=dev, target_latency_ms=target_latency_ms,
        proxy=AccuracyProxy(baseline_acc=baseline_acc)))
    agent = DDPGAgent(DDPGConfig(state_dim=STATE_DIM), seed=seed)

    best_reward = -np.inf
    best_info: dict = {}
    rewards = []
    t0 = time.time()
    for ep in range(episodes):
        s = env.reset()
        transitions = []
        done = False
        while not done:
            a = agent.act(s, explore=True)
            s2, r, done, info = env.step(float(a[0]))
            transitions.append((s, a, r, s2, done))
            s = s2
        # sparse terminal reward -> propagate to every step (the paper's
        # episode-level reward assignment)
        final_r = transitions[-1][2]
        for (st, at, _, st2, dn) in transitions:
            agent.remember(st, at, final_r, st2, dn)
        agent.learn(n_updates=len(transitions))
        agent.decay_noise()
        rewards.append(final_r)
        if final_r > best_reward:
            best_reward, best_info = final_r, info
        if verbose and (ep + 1) % 10 == 0:
            print(f"  ep {ep + 1:4d}  reward {final_r:+.4f}  "
                  f"best {best_reward:+.4f}  "
                  f"lat {info.get('latency_ms', float('nan')):.2f} ms")
    return SearchResult(best_reward=float(best_reward), best_info=best_info,
                        rewards=rewards, episodes=episodes,
                        wall_s=time.time() - t0)
