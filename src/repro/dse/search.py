"""End-to-end DSE driver — the paper's Table 3 generator, now two-tier.

``run_search(network, device, target_latency_ms, episodes)`` runs the
DDPG agent over the N3H environment and returns the best feasible
configuration found (hardware knobs + per-layer bit-widths + split
ratios), exactly the artifact the paper's framework emits.

With ``simulate_elites=True`` the loop is *two-tier*
(simulator-in-the-loop, see ``docs/dse.md``): the agent keeps
exploring on the closed-form latency model for speed, but every
``sim_every`` episodes the top-``top_k`` elite configurations are
compiled through the NN→ISA toolchain and re-scored with
``core/scheduler.simulate_program`` at ``opt_level`` — elites are
re-ranked by the corrected reward, and each corrected episode is
re-injected into the replay buffer so the critic learns from the
program that would actually ship. ``network`` may be a CNN workload
*or* any registry arch id (scored at ``seq_len`` tokens; must be a
perfect square — see ``dse.evaluator.gemm_specs``).

Passing ``accuracy_fn`` (e.g. ``repro.eval.accuracy.make_accuracy_fn``)
upgrades elite correction to a *third* signal: each elite's compiled
program is run over a held-out eval stream and its **measured** top-1
agreement replaces the ``AccuracyProxy`` term in the corrected reward
(``reward_source == "measured"``). The calibration rows then trace an
accuracy-vs-latency frontier over the elite set — see ``docs/dse.md``.

The paper explores 900 episodes; the default here is smaller so the
benchmark suite stays fast — pass ``episodes=900`` to match.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Sequence

import numpy as np

from repro.core.scheduler import DEVICES, FPGADevice
from repro.core.workloads import ConvSpec
from repro.dse.ddpg import DDPGAgent, DDPGConfig
from repro.dse.env import STATE_DIM, AccuracyProxy, N3HEnv, N3HEnvConfig
from repro.dse.evaluator import (
    EliteSet,
    ProgramEvaluator,
    config_fingerprint,
    gemm_specs,
)
from repro.obs import MetricsRegistry


#: calibration-report columns, in CSV order
CALIBRATION_FIELDS = (
    "rank", "key", "reward_source", "reward_analytical",
    "reward_simulated", "analytical_ms", "simulated_ms", "gap_pct",
    "acc", "measured_acc", "mean_bw", "mean_ba", "mean_ratio",
)


@dataclasses.dataclass
class SearchResult:
    best_reward: float
    best_info: dict
    rewards: list[float]
    episodes: int
    wall_s: float
    # two-tier columns (None / "analytical" when simulate_elites is off)
    reward_source: str = "analytical"
    analytical_latency_ms: float | None = None
    simulated_latency_ms: float | None = None
    sim_gap_pct: float | None = None
    elites: list[dict] = dataclasses.field(default_factory=list)
    evaluator_cache: dict | None = None
    # repro.obs.MetricsRegistry snapshot of the run (per-episode
    # reward/latency series, elite sim-gap observations, cache counters)
    metrics: dict | None = None

    def table3_row(self) -> dict:
        """The paper's Table 3 columns (+ the simulated-latency column
        when the two-tier loop ran)."""
        info = self.best_info
        lut = info["lut_cfg"]
        dsp = info["dsp_cfg"]
        row = {
            "K": lut.k, "M": lut.m, "N": lut.n,
            "D_L_buf_a": lut.d_a,
            "D_D_buf_a": dsp.d_a,
            "D_D_buf_w": dsp.d_w,
            "latency_ms": round(info["latency_ms"], 2),
            "acc_proxy": round(info["acc"], 2),
        }
        if self.simulated_latency_ms is not None:
            row["sim_latency_ms"] = round(self.simulated_latency_ms, 2)
        return row

    # -- calibration report ---------------------------------------------------

    def calibration_rows(self) -> list[dict]:
        """One row per elite: analytical vs simulated latency/reward and
        the signed gap — how far the closed form was from the compiled
        program on the configs that mattered."""
        return self.elites

    def calibration_report(self) -> str:
        """Human-readable calibration table (see docs/dse.md for how to
        read it)."""
        if not self.elites:
            return "calibration: no elites recorded " \
                   "(simulate_elites was off or no episode finished)"
        lines = [
            "calibration (analytical vs simulated, per elite):",
            f"  {'rank':>4} {'ana_ms':>10} {'sim_ms':>10} {'gap%':>7} "
            f"{'r_ana':>8} {'r_sim':>8} {'acc':>7}",
        ]
        for e in self.elites:
            sim = e.get("simulated_ms")
            lines.append(
                f"  {e['rank']:>4} {e['analytical_ms']:>10.4f} "
                + (f"{sim:>10.4f}" if sim is not None else f"{'-':>10}")
                + (f" {e['gap_pct']:>6.2f}%" if e.get("gap_pct") is not None
                   else f" {'-':>7}")
                + f" {e['reward_analytical']:>+8.4f}"
                + (f" {e['reward_simulated']:>+8.4f}"
                   if e.get("reward_simulated") is not None else f" {'-':>8}")
                + f" {e['acc']:>7.2f}")
        if self.evaluator_cache:
            c = self.evaluator_cache
            lines.append(f"  program cache: {c['hits']} hits / "
                         f"{c['misses']} misses (size {c['size']})")
        return "\n".join(lines)

    def write_calibration_csv(self, path: str) -> None:
        import csv
        with open(path, "w", newline="") as f:
            w = csv.DictWriter(f, fieldnames=CALIBRATION_FIELDS,
                               extrasaction="ignore")
            w.writeheader()
            w.writerows(self.elites)


def _calibration_row(rank: int, elite) -> dict:
    info = elite.info
    return {
        "rank": rank,
        "key": elite.key,
        "reward_source": info.get("reward_source", "analytical"),
        "reward_analytical": elite.reward_analytical,
        "reward_simulated": elite.reward_simulated,
        "analytical_ms": info.get("analytical_latency_ms",
                                  info["latency_ms"]),
        "simulated_ms": info.get("simulated_latency_ms"),
        "gap_pct": info.get("sim_gap_pct"),
        "acc": info["acc"],
        "measured_acc": info.get("measured_acc"),
        "mean_bw": float(np.mean(info["bw_lut"])),
        "mean_ba": float(np.mean(info["ba"])),
        "mean_ratio": float(np.mean(info["ratios"])),
    }


def _correct_elites(elites: EliteSet, evaluator: ProgramEvaluator,
                    agent: DDPGAgent, verbose: bool = False) -> int:
    """Re-score every not-yet-corrected elite on its compiled program,
    re-rank, and feed the corrected episodes back into the replay
    buffer. Returns how many elites were corrected."""
    pending = elites.uncorrected()
    for e in pending:
        r_sim, corrected_info = evaluator.correct(e.info)
        if verbose:
            print(f"  [sim] elite {e.key}: reward "
                  f"{e.reward_analytical:+.4f} -> {r_sim:+.4f}  "
                  f"({corrected_info['analytical_latency_ms']:.3f} ms "
                  f"analytical vs "
                  f"{corrected_info['simulated_latency_ms']:.3f} ms "
                  f"simulated)")
        elites.apply_correction(e, r_sim, corrected_info)
        if e.transitions:
            agent.remember_episode(e.transitions, r_sim)
    if pending:
        agent.learn(n_updates=sum(len(e.transitions or ())
                                  for e in pending))
    return len(pending)


def run_search(network: str = "resnet18", device: str = "XC7Z020",
               target_latency_ms: float = 35.0, episodes: int = 120,
               seed: int = 0, baseline_acc: float = 69.76,
               specs: Sequence[ConvSpec] | None = None,
               verbose: bool = False,
               simulate_elites: bool = False, top_k: int = 4,
               sim_every: int = 20, opt_level: int = 1,
               cache_size: int = 32, seq_len: int = 64,
               accuracy_fn=None,
               metrics: MetricsRegistry | None = None) -> SearchResult:
    reg = metrics if metrics is not None else MetricsRegistry()
    dev: FPGADevice = DEVICES[device]
    layer_specs = list(specs) if specs is not None \
        else gemm_specs(network, seq_len=seq_len)
    proxy = AccuracyProxy(baseline_acc=baseline_acc)
    env_cfg = N3HEnvConfig(device=dev, target_latency_ms=target_latency_ms,
                           proxy=proxy)
    env = N3HEnv(layer_specs, env_cfg)
    agent = DDPGAgent(DDPGConfig(state_dim=STATE_DIM), seed=seed)
    evaluator = ProgramEvaluator(
        layer_specs, dev, target_latency_ms, proxy=proxy,
        reward_lambda=env_cfg.reward_lambda, opt_level=opt_level,
        cache_size=cache_size, name=network,
        accuracy_fn=accuracy_fn) if simulate_elites else None
    elites = EliteSet(top_k)

    best_reward = -np.inf
    best_info: dict = {}
    rewards = []
    t0 = time.time()
    for ep in range(episodes):
        s = env.reset()
        transitions = []
        done = False
        while not done:
            a = agent.act(s, explore=True)
            s2, r, done, info = env.step(float(a[0]))
            transitions.append((s, a, r, s2, done))
            s = s2
        # sparse terminal reward -> propagate to every step (the paper's
        # episode-level reward assignment)
        final_r = transitions[-1][2]
        agent.remember_episode(transitions, final_r)
        agent.learn(n_updates=len(transitions))
        agent.decay_noise()
        rewards.append(final_r)
        reg.incr("dse.episodes")
        reg.observe("dse.episode.reward", final_r)
        if "latency_ms" in info:
            reg.observe("dse.episode.latency_ms", info["latency_ms"])
        # fingerprint in both modes: the single-tier calibration rows
        # deduplicate too (a converged agent re-emits its best config)
        elites.add(final_r, info, transitions=transitions,
                   key=config_fingerprint(
                       dev, info["lut_cfg"], info["dsp_cfg"],
                       info["bw_lut"], info["ba"], info["n_luts"],
                       opt_level))
        if final_r > best_reward:
            best_reward, best_info = final_r, info
        if evaluator and (ep + 1) % max(sim_every, 1) == 0:
            reg.incr("dse.elites.corrected",
                     _correct_elites(elites, evaluator, agent,
                                     verbose=verbose))
        if verbose and (ep + 1) % 10 == 0:
            print(f"  ep {ep + 1:4d}  reward {final_r:+.4f}  "
                  f"best {best_reward:+.4f}  "
                  f"lat {info.get('latency_ms', float('nan')):.2f} ms")

    result = SearchResult(best_reward=float(best_reward),
                          best_info=best_info, rewards=rewards,
                          episodes=episodes, wall_s=time.time() - t0)
    if evaluator:
        reg.incr("dse.elites.corrected",
                 _correct_elites(elites, evaluator, agent, verbose=verbose))
        winner = elites.best
        if winner is not None:
            result.best_reward = float(winner.reward)
            result.best_info = winner.info
            result.reward_source = winner.info.get("reward_source",
                                                   "simulated")
            result.analytical_latency_ms = \
                winner.info["analytical_latency_ms"]
            result.simulated_latency_ms = \
                winner.info["simulated_latency_ms"]
            result.sim_gap_pct = winner.info["sim_gap_pct"]
        result.elites = [_calibration_row(i + 1, e)
                         for i, e in enumerate(elites.elites)]
        result.evaluator_cache = evaluator.cache_info()
        result.wall_s = time.time() - t0
    elif best_info:
        result.analytical_latency_ms = best_info["latency_ms"]
        result.elites = [_calibration_row(i + 1, e)
                         for i, e in enumerate(elites.elites)]
    reg.gauge("dse.best_reward", result.best_reward)
    if result.sim_gap_pct is not None:
        reg.gauge("dse.best.sim_gap_pct", result.sim_gap_pct)
    for row in result.elites:
        if row.get("gap_pct") is not None:
            reg.observe("dse.elite.sim_gap_pct", row["gap_pct"])
    if result.evaluator_cache:
        reg.gauge("dse.program_cache.hits",
                  result.evaluator_cache["hits"])
        reg.gauge("dse.program_cache.misses",
                  result.evaluator_cache["misses"])
    result.metrics = reg.snapshot()
    return result
