"""The paper's RL environment (§5.4) — and its TPU-adapted variant.

Episode = 6 hardware actions (Eq. 13: K, M, N, D_L,buf^a, D_D,buf^a,
D_D,buf^w) followed by 2N software actions (Eq. 14: per-layer B^{w-L}
then B^a). State = Eq. 17, every dimension normalized to [0, 1].
Reward = Eq. 18 at episode end, zero elsewhere (the paper's sparse
terminal reward):

    R = (L_t - L_m)/L_t - 1            if L_m > L_t   (in (-inf, -1])
    R = (acc_q - acc_b) * lambda       otherwise

Accuracy term: the paper retrains the quantized DNN for one epoch on
(sub-sampled) ImageNet. Offline we use a calibrated quantization-noise
surrogate (``AccuracyProxy``): per-layer accuracy damage proportional
to the quantization MSE ~ 4^-bits, weighted by parameter share and the
filter-wise LUT/DSP mix of §4. The surrogate is monotone in every
bit-width, which is the property the search needs; the reduced-CNN QAT
benchmark cross-checks its ranking on synthetic data.

Feasibility: hardware actions are projected onto the device's LUT/BRAM
budget (Eqs. 3-5) — shrink M, N, then buffer depths, until it fits.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.core.cost_model import max_lut_core_mn, system_cost
from repro.core.scheduler import (
    DspCoreConfig,
    FPGADevice,
    LutCoreConfig,
    XC7Z020,
)
from repro.core.split import solve_split
from repro.core.workloads import ConvSpec
from repro.core.tpu_cost import TPUChip, V5E


# ---------------------------------------------------------------------------
# Accuracy surrogate
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class AccuracyProxy:
    """acc_q = acc_b - kappa * sum_i share_i * (mse_w_i + mse_a_i).

    mse_w mixes the two cores filter-wise: ratio at B^{w-L} bits, rest at
    int4 (§4). kappa calibrated so uniform 4/4 costs ~0.3-0.5 points (the
    paper's manual 4/4 ResNet-18 loses 0.11, MobileNet-V2 loses ~6.7 —
    we sit in between; the *ordering* across configs is what matters).
    """
    baseline_acc: float = 69.76
    kappa: float = 1.0          # 4/4 uniform ResNet-18 -> ~0.5pt drop
    dw_sensitivity: float = 8.0  # depthwise layers are quant-hostile
                                 # (the paper's MobileNet drops 6.7pt)

    def evaluate(self, specs: Sequence[ConvSpec], bw_lut: Sequence[int],
                 ba: Sequence[int], ratios: Sequence[float]) -> float:
        total = sum(s.n_params for s in specs)
        drop = 0.0
        for s, bw, a, r in zip(specs, bw_lut, ba, ratios):
            share = s.n_params / total
            if s.depthwise:
                share *= self.dw_sensitivity
            if s.is_first or s.is_last:
                bw_eff_mse = 4.0 ** -8
                a_mse = 4.0 ** -8
            else:
                bw_eff_mse = r * 4.0 ** -bw + (1 - r) * 4.0 ** -4
                a_mse = 4.0 ** -a
            drop += share * (bw_eff_mse + a_mse)
        return self.baseline_acc - self.kappa * drop * 100.0


# ---------------------------------------------------------------------------
# Reward shaping (Eq. 18) — shared by both environments and the
# simulator-in-the-loop elite re-scoring (dse/evaluator.py)
# ---------------------------------------------------------------------------


def shaped_reward(latency_ms: float, target_latency_ms: float, acc: float,
                  baseline_acc: float, reward_lambda: float) -> float:
    """Eq. 18: latency-infeasible configs score ``<= -1`` proportionally
    to the violation; feasible configs score the accuracy delta scaled
    by lambda. One definition, three consumers: ``N3HEnv``,
    ``TpuHeteroEnv`` and ``ProgramEvaluator`` (which re-applies it with
    the *simulated* latency of the compiled program)."""
    if latency_ms > target_latency_ms:
        return (target_latency_ms - latency_ms) / target_latency_ms - 1.0
    return (acc - baseline_acc) * reward_lambda


# ---------------------------------------------------------------------------
# Design-factor ranges (Table 2)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class FactorRanges:
    k_v: tuple[int, int]            # K = 64 * v
    mn: tuple[int, int]             # M, N
    d_l_a_v: tuple[int, int]        # D_L,buf^a = 1024 * v
    d_d_a_v: tuple[int, int]        # D_D,buf^a = 1024 * v
    d_d_w_v: tuple[int, int]        # D_D,buf^w = 1024 * v
    bw: tuple[int, int] = (2, 8)
    ba: tuple[int, int] = (2, 4)


RANGES = {
    "XC7Z020": FactorRanges(k_v=(1, 4), mn=(1, 50), d_l_a_v=(1, 50),
                            d_d_a_v=(1, 25), d_d_w_v=(1, 4)),
    "XC7Z045": FactorRanges(k_v=(1, 8), mn=(1, 252), d_l_a_v=(1, 252),
                            d_d_a_v=(1, 126), d_d_w_v=(1, 16)),
}


def _discretize(a: float, lo: int, hi: int) -> int:
    """Eq. 13/14: round(a * (hi - lo) + lo)."""
    return int(round(float(np.clip(a, 0.0, 1.0)) * (hi - lo) + lo))


# ---------------------------------------------------------------------------
# FPGA environment (paper-faithful)
# ---------------------------------------------------------------------------


STATE_DIM = 12  # Eq. 17


@dataclasses.dataclass(frozen=True)
class N3HEnvConfig:
    device: FPGADevice = XC7Z020
    target_latency_ms: float = 35.0
    reward_lambda: float = 0.01     # paper's lambda
    proxy: AccuracyProxy = AccuracyProxy()


class N3HEnv:
    """Sequential-decision wrapper over the cost/latency models."""

    def __init__(self, specs: Sequence[ConvSpec], cfg: N3HEnvConfig):
        self.specs = list(specs)
        self.cfg = cfg
        self.ranges = RANGES[cfg.device.name]
        self.n_layers = len(self.specs)
        self.episode_len = 6 + 2 * self.n_layers
        self._max_params = max(s.n_params for s in self.specs)
        self.reset()

    # -- episode bookkeeping ------------------------------------------------

    def reset(self) -> np.ndarray:
        self.t = 0
        self.hw: dict[str, int] = {}
        self.bw_lut: list[int] = []
        self.ba: list[int] = []
        self.last_action = 0.0
        return self._state()

    def _state(self) -> np.ndarray:
        """Eq. 17, normalized."""
        s = np.zeros(STATE_DIM, np.float32)
        if self.t < 6:
            s[0] = 0.0                                   # id_func
            s[11] = self.last_action
            s[1] = self.t / 6.0
            return s
        qt = self.t - 6
        i = min(qt // 2, self.n_layers - 1)
        spec = self.specs[i]
        s[0] = 1.0
        s[1] = i / max(self.n_layers - 1, 1)
        s[2] = spec.c_in / 2048.0
        s[3] = spec.c_out / 2048.0
        s[4] = spec.kernel / 7.0
        s[5] = spec.stride / 2.0
        s[6] = spec.in_hw / 224.0
        s[7] = spec.n_params / self._max_params
        s[8] = 1.0 if (spec.shortcut or spec.depthwise) else 0.0
        s[9] = float(qt % 2)                             # id_m/n
        s[10] = 0.0                                      # ratio (filled at end)
        s[11] = self.last_action
        return s

    # -- dynamics -----------------------------------------------------------

    def step(self, action: float) -> tuple[np.ndarray, float, bool, dict]:
        a = float(np.clip(action, 0.0, 1.0))
        self.last_action = a
        r = self.ranges
        if self.t == 0:
            self.hw["k"] = 64 * _discretize(a, *r.k_v)
        elif self.t == 1:
            self.hw["m"] = _discretize(a, *r.mn)
        elif self.t == 2:
            self.hw["n"] = _discretize(a, *r.mn)
        elif self.t == 3:
            self.hw["d_l_a"] = 1024 * _discretize(a, *r.d_l_a_v)
        elif self.t == 4:
            self.hw["d_d_a"] = 1024 * _discretize(a, *r.d_d_a_v)
        elif self.t == 5:
            self.hw["d_d_w"] = 1024 * _discretize(a, *r.d_d_w_v)
        else:
            qt = self.t - 6
            if qt % 2 == 0:
                self.bw_lut.append(_discretize(a, *r.bw))
            else:
                self.ba.append(_discretize(a, *r.ba))
        self.t += 1
        done = self.t >= self.episode_len
        if not done:
            return self._state(), 0.0, False, {}
        reward, info = self._evaluate()
        return self._state(), reward, True, info

    # -- terminal evaluation --------------------------------------------------

    def _project_feasible(self) -> tuple[LutCoreConfig, DspCoreConfig]:
        dev = self.cfg.device
        k = self.hw["k"]
        m, n = self.hw["m"], self.hw["n"]
        cap = max_lut_core_mn(dev, k)
        while m * n > cap and (m > 1 or n > 1):
            if m >= n:
                m = max(1, m - 1)
            else:
                n = max(1, n - 1)
        d_l_a, d_d_a, d_d_w = (self.hw["d_l_a"], self.hw["d_d_a"],
                               self.hw["d_d_w"])
        while True:
            lut_cfg = LutCoreConfig(m=m, n=n, k=k, d_a=d_l_a)
            dsp_cfg = DspCoreConfig(
                n_reg_row_a=DspCoreConfig.rows_for_device(dev),
                d_a=d_d_a, d_w=d_d_w)
            rep = system_cost(lut_cfg, dsp_cfg, dev)
            if rep.fits(dev):
                return lut_cfg, dsp_cfg
            # shrink the largest memory consumer first
            if d_d_a > 1024:
                d_d_a -= 1024
            elif d_l_a > 1024:
                d_l_a -= 1024
            elif d_d_w > 1024:
                d_d_w -= 1024
            elif m * n > 1:
                if m >= n:
                    m = max(1, m - 1)
                else:
                    n = max(1, n - 1)
            else:
                return lut_cfg, dsp_cfg    # smallest possible; accept

    def _evaluate(self) -> tuple[float, dict]:
        cfg = self.cfg
        lut_cfg, dsp_cfg = self._project_feasible()
        # first/last layers: 8-bit (paper §4); env actions cover the rest
        bw = list(self.bw_lut)
        ba = list(self.ba)
        for i, s in enumerate(self.specs):
            if s.is_first or s.is_last:
                bw[i] = 8
                ba[i] = 8
        return evaluate_config(self.specs, lut_cfg, dsp_cfg, cfg.device,
                               bw, ba, cfg.proxy, cfg.target_latency_ms,
                               cfg.reward_lambda)


def evaluate_config(specs: Sequence[ConvSpec], lut_cfg: LutCoreConfig,
                    dsp_cfg: DspCoreConfig, device: FPGADevice,
                    bw: Sequence[int], ba: Sequence[int],
                    proxy: AccuracyProxy, target_latency_ms: float,
                    reward_lambda: float) -> tuple[float, dict]:
    """Analytical (closed-form) scoring of one *complete* configuration.

    The terminal-evaluation half of :class:`N3HEnv` factored out so the
    benchmarks and the simulator-in-the-loop evaluator
    (``dse/evaluator.py``) can score a hand-built config without
    driving an episode. The returned ``info`` dict is the full config
    artifact the compiler needs to reproduce the design point:
    core knobs (``lut_cfg``/``dsp_cfg``), per-layer bit-widths
    (``bw_lut``/``ba``) and the exact Eq.-12 neuron splits
    (``n_luts``, with ``ratios`` as the derived fractions).
    ``reward_source`` tags which latency model priced the reward —
    ``"analytical"`` here; ``ProgramEvaluator`` re-tags corrected
    copies as ``"simulated"``.
    """
    cycles = 0.0
    ratios: list[float] = []
    n_luts: list[int] = []
    for spec, bwi, bai in zip(specs, bw, ba):
        sol = solve_split(spec, lut_cfg, dsp_cfg, device, bwi, bai)
        ratios.append(sol.ratio)
        n_luts.append(sol.n_lut)
        cycles += sol.cycles
    latency_ms = device.cycles_to_ms(cycles)
    acc = proxy.evaluate(specs, bw, ba, ratios)
    reward = shaped_reward(latency_ms, target_latency_ms, acc,
                           proxy.baseline_acc, reward_lambda)
    info = {
        "latency_ms": latency_ms,
        "acc": acc,
        "lut_cfg": lut_cfg,
        "dsp_cfg": dsp_cfg,
        "bw_lut": list(bw),
        "ba": list(ba),
        "ratios": ratios,
        "n_luts": n_luts,
        "reward_source": "analytical",
    }
    return float(reward), info


# ---------------------------------------------------------------------------
# TPU-adapted environment (hardware-adaptation of the framework)
# ---------------------------------------------------------------------------


class TpuHeteroEnv:
    """Same agent, TPU cost model: per-layer actions pick {B^{w-L}, B^a};
    the split ratio is solved per layer against the v5e roofline (the
    Eq. 12 analogue in tpu_cost). No hardware actions — the 'resource
    pool' of a fixed TPU chip is not configurable, which is exactly the
    adaptation noted in DESIGN.md §6."""

    def __init__(self, gemms: Sequence[tuple[int, int, int]],
                 target_latency_ms: float, chip: TPUChip = V5E,
                 proxy: AccuracyProxy = AccuracyProxy(),
                 spatial: bool = False, reward_lambda: float = 0.01):
        self.gemms = list(gemms)          # (m_tokens, k, n) per layer
        self.chip = chip
        self.target = target_latency_ms
        self.proxy = proxy
        self.spatial = spatial
        self.reward_lambda = reward_lambda
        self.n_layers = len(self.gemms)
        self.episode_len = 2 * self.n_layers
        self.reset()

    def reset(self) -> np.ndarray:
        self.t = 0
        self.bw: list[int] = []
        self.ba: list[int] = []
        self.last_action = 0.0
        return self._state()

    def _state(self) -> np.ndarray:
        s = np.zeros(STATE_DIM, np.float32)
        i = self.t // 2
        m, k, n = self.gemms[min(i, self.n_layers - 1)]
        s[0] = 1.0
        s[1] = i / max(self.n_layers - 1, 1)
        s[2] = k / 8192.0
        s[3] = n / 8192.0
        s[6] = m / 65536.0
        s[7] = (k * n) / (8192.0 * 8192.0)
        s[9] = float(self.t % 2)
        s[11] = self.last_action
        return s

    def step(self, action: float):
        a = float(np.clip(action, 0.0, 1.0))
        self.last_action = a
        if self.t % 2 == 0:
            self.bw.append(_discretize(a, 2, 8))
        else:
            self.ba.append(_discretize(a, 2, 4))
        self.t += 1
        done = self.t >= self.episode_len
        if not done:
            return self._state(), 0.0, False, {}

        total_s = 0.0
        ratios = []
        from repro.core.tpu_cost import solve_tpu_split
        for (m, k, n), bw, ba in zip(self.gemms, self.bw, self.ba):
            r, sec, _ = solve_tpu_split(m, k, n, bw, ba, self.chip,
                                        spatial=self.spatial)
            ratios.append(r)
            total_s += sec
        latency_ms = total_s * 1e3
        specs_like = [ConvSpec(f"g{i}", k, n, 1, 1, 1)
                      for i, (m, k, n) in enumerate(self.gemms)]
        acc = self.proxy.evaluate(specs_like, self.bw, self.ba, ratios)
        reward = shaped_reward(latency_ms, self.target, acc,
                               self.proxy.baseline_acc, self.reward_lambda)
        info = {"latency_ms": latency_ms, "acc": acc, "bw": self.bw,
                "ba": self.ba, "ratios": ratios,
                "reward_source": "analytical"}
        return self._state(), float(reward), True, info
