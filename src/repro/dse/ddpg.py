"""DDPG (Lillicrap et al.) in pure JAX — the paper's §5.4 search agent.

Actor: state -> action in [0, 1] (sigmoid head — the paper discretizes
continuous actions into the design-factor ranges, Eqs. 13-14).
Critic: (state, action) -> Q. Target networks track with soft updates.
Exploration: truncated Gaussian noise with exponential decay (the HAQ /
AMC recipe the paper builds on).

Everything jit-compiled; the replay buffer is host-side numpy (cheap,
episode lengths are tens of steps).
"""
from __future__ import annotations

import dataclasses
import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DDPGConfig:
    state_dim: int
    action_dim: int = 1
    hidden: tuple[int, ...] = (64, 64)
    actor_lr: float = 1e-3
    critic_lr: float = 1e-3
    gamma: float = 0.99
    tau: float = 0.01              # soft target update
    buffer_size: int = 20000
    batch_size: int = 64
    noise_sigma: float = 0.5
    noise_decay: float = 0.99
    noise_min: float = 0.02


def _mlp_init(rng, sizes):
    params = []
    keys = jax.random.split(rng, len(sizes) - 1)
    for k, (a, b) in zip(keys, zip(sizes[:-1], sizes[1:])):
        w = jax.random.normal(k, (a, b)) * (2.0 / a) ** 0.5
        params.append({"w": w, "b": jnp.zeros((b,))})
    return params


def _mlp_apply(params, x, final_sigmoid=False):
    for i, layer in enumerate(params):
        x = x @ layer["w"] + layer["b"]
        if i < len(params) - 1:
            x = jax.nn.relu(x)
    return jax.nn.sigmoid(x) if final_sigmoid else x


class ReplayBuffer:
    def __init__(self, size: int, state_dim: int, action_dim: int):
        self.size = size
        self.s = np.zeros((size, state_dim), np.float32)
        self.a = np.zeros((size, action_dim), np.float32)
        self.r = np.zeros((size,), np.float32)
        self.s2 = np.zeros((size, state_dim), np.float32)
        self.done = np.zeros((size,), np.float32)
        self.n = 0
        self.ptr = 0

    def add(self, s, a, r, s2, done):
        i = self.ptr
        self.s[i], self.a[i], self.r[i] = s, a, r
        self.s2[i], self.done[i] = s2, float(done)
        self.ptr = (self.ptr + 1) % self.size
        self.n = min(self.n + 1, self.size)

    def sample(self, rng: np.random.Generator, batch: int):
        idx = rng.integers(0, self.n, batch)
        return (self.s[idx], self.a[idx], self.r[idx], self.s2[idx],
                self.done[idx])


class DDPGAgent:
    def __init__(self, cfg: DDPGConfig, seed: int = 0):
        self.cfg = cfg
        rng = jax.random.key(seed)
        ra, rc = jax.random.split(rng)
        actor_sizes = (cfg.state_dim, *cfg.hidden, cfg.action_dim)
        critic_sizes = (cfg.state_dim + cfg.action_dim, *cfg.hidden, 1)
        self.actor = _mlp_init(ra, actor_sizes)
        self.critic = _mlp_init(rc, critic_sizes)
        self.actor_t = jax.tree.map(jnp.copy, self.actor)
        self.critic_t = jax.tree.map(jnp.copy, self.critic)
        self.buffer = ReplayBuffer(cfg.buffer_size, cfg.state_dim,
                                   cfg.action_dim)
        self.np_rng = np.random.default_rng(seed)
        self.sigma = cfg.noise_sigma
        self._step = self._build_update()

    # -- acting ------------------------------------------------------------

    def act(self, state: np.ndarray, explore: bool = True) -> np.ndarray:
        a = np.asarray(_mlp_apply(self.actor, jnp.asarray(state)[None],
                                  final_sigmoid=True))[0]
        if explore:
            a = a + self.np_rng.normal(0.0, self.sigma, a.shape)
        return np.clip(a, 0.0, 1.0)

    def decay_noise(self):
        self.sigma = max(self.cfg.noise_min,
                         self.sigma * self.cfg.noise_decay)

    # -- learning ----------------------------------------------------------

    def _build_update(self):
        cfg = self.cfg

        def critic_loss(critic, actor_t, critic_t, s, a, r, s2, done):
            a2 = _mlp_apply(actor_t, s2, final_sigmoid=True)
            q2 = _mlp_apply(critic_t, jnp.concatenate([s2, a2], -1))[:, 0]
            target = r + cfg.gamma * (1.0 - done) * q2
            q = _mlp_apply(critic, jnp.concatenate([s, a], -1))[:, 0]
            return jnp.mean(jnp.square(q - jax.lax.stop_gradient(target)))

        def actor_loss(actor, critic, s):
            a = _mlp_apply(actor, s, final_sigmoid=True)
            q = _mlp_apply(critic, jnp.concatenate([s, a], -1))[:, 0]
            return -jnp.mean(q)

        def adam(params, grads, m, v, t, lr):
            b1, b2, eps = 0.9, 0.999, 1e-8
            m = jax.tree.map(lambda mm, g: b1 * mm + (1 - b1) * g, m, grads)
            v = jax.tree.map(lambda vv, g: b2 * vv + (1 - b2) * g * g,
                             v, grads)
            tf = t.astype(jnp.float32)
            params = jax.tree.map(
                lambda p, mm, vv: p - lr * (mm / (1 - b1 ** tf))
                / (jnp.sqrt(vv / (1 - b2 ** tf)) + eps), params, m, v)
            return params, m, v

        @jax.jit
        def step(actor, critic, actor_t, critic_t, opt, batch):
            s, a, r, s2, done = batch
            t = opt["t"] + 1
            gc = jax.grad(critic_loss)(critic, actor_t, critic_t,
                                       s, a, r, s2, done)
            critic, mc, vc = adam(critic, gc, opt["mc"], opt["vc"], t,
                                  cfg.critic_lr)
            ga = jax.grad(actor_loss)(actor, critic, s)
            actor, ma, va = adam(actor, ga, opt["ma"], opt["va"], t,
                                 cfg.actor_lr)
            soft = lambda tgt, p: jax.tree.map(
                lambda tt, pp: (1 - cfg.tau) * tt + cfg.tau * pp, tgt, p)
            opt = {"t": t, "ma": ma, "va": va, "mc": mc, "vc": vc}
            return (actor, critic, soft(actor_t, actor),
                    soft(critic_t, critic), opt)

        return step

    def _init_opt(self):
        zeros = lambda tree: jax.tree.map(jnp.zeros_like, tree)
        return {"t": jnp.zeros((), jnp.int32),
                "ma": zeros(self.actor), "va": zeros(self.actor),
                "mc": zeros(self.critic), "vc": zeros(self.critic)}

    def remember(self, s, a, r, s2, done):
        self.buffer.add(s, a, r, s2, done)

    def remember_episode(self, transitions, reward: float):
        """Store a whole episode under one terminal reward (the paper's
        episode-level sparse reward assignment): every transition gets
        ``reward``. Also the elite-correction hook of the two-tier DSE
        loop — when the simulator re-scores an elite config, its episode
        is re-injected with the corrected reward, so the critic learns
        from the compiled program's latency, not just the closed form.
        """
        for (s, a, _r, s2, done) in transitions:
            self.buffer.add(s, a, reward, s2, done)

    def learn(self, n_updates: int = 1):
        if self.buffer.n < self.cfg.batch_size:
            return
        if not hasattr(self, "_opt"):
            self._opt = self._init_opt()
        for _ in range(n_updates):
            batch = self.buffer.sample(self.np_rng, self.cfg.batch_size)
            batch = tuple(jnp.asarray(x) for x in batch)
            (self.actor, self.critic, self.actor_t, self.critic_t,
             self._opt) = self._step(
                self.actor, self.critic, self.actor_t, self.critic_t,
                self._opt, batch)
