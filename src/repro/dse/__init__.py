"""Design-space exploration: the paper's DDPG-based co-design framework.

  ddpg    — actor/critic/targets/replay/exploration noise, pure JAX
  env     — the §5 environment: 6 hardware actions + 2N quantization
            actions, Eq. 17 state, Eq. 13/14 discretization, Eq. 18 reward
  search  — end-to-end search driver (paper Table 3 reproduction) with
            both the FPGA cost model and the TPU-adapted cost model
"""
from repro.dse.ddpg import DDPGAgent, DDPGConfig
from repro.dse.env import AccuracyProxy, N3HEnv, N3HEnvConfig
from repro.dse.search import SearchResult, run_search

__all__ = ["DDPGAgent", "DDPGConfig", "AccuracyProxy", "N3HEnv",
           "N3HEnvConfig", "SearchResult", "run_search"]
