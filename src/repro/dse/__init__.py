"""Design-space exploration: the paper's DDPG-based co-design framework.

  ddpg      — actor/critic/targets/replay/exploration noise, pure JAX
  env       — the §5 environment: 6 hardware actions + 2N quantization
              actions, Eq. 17 state, Eq. 13/14 discretization, Eq. 18
              reward (``shaped_reward``, shared by every scorer)
  evaluator — simulator-in-the-loop tier: elite configs compiled
              through the NN→ISA toolchain and re-scored on
              ``simulate_program`` (LRU program cache, EliteSet
              re-ranking, the ``dse.sim_gap.*`` bench payloads)
  search    — end-to-end two-tier search driver (paper Table 3
              reproduction + the calibration report of docs/dse.md)
"""
from repro.dse.ddpg import DDPGAgent, DDPGConfig
from repro.dse.env import (
    AccuracyProxy,
    N3HEnv,
    N3HEnvConfig,
    evaluate_config,
    shaped_reward,
)
from repro.dse.evaluator import (
    SIM_GAP_TOL_PCT,
    EliteSet,
    EvalResult,
    ProgramEvaluator,
    gemm_specs,
    sim_gap_report,
)
from repro.dse.search import SearchResult, run_search

__all__ = ["DDPGAgent", "DDPGConfig", "AccuracyProxy", "N3HEnv",
           "N3HEnvConfig", "evaluate_config", "shaped_reward",
           "SIM_GAP_TOL_PCT", "EliteSet", "EvalResult",
           "ProgramEvaluator", "gemm_specs", "sim_gap_report",
           "SearchResult", "run_search"]
