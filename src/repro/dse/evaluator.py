"""Simulator-in-the-loop evaluation: score DSE configs on *compiled*
programs.

The RL agent explores with the closed-form latency model (Eqs. 9-11) —
vectorizable, microseconds per config. That model is validated against
the event-driven instruction-stream simulator at a few percent (the
Fig. 5 reproduction), but the simulator times the *actual* program the
compiler emits, including whatever the ``-O1`` pass pipeline did to
the streams. :class:`ProgramEvaluator` closes that gap inside the
search loop (the last compiler ROADMAP item): the top-K elite
configurations of a search are compiled through the full NN→ISA
toolchain — honoring the searched core knobs, per-layer bit-widths and
exact Eq.-12 neuron splits — and re-scored with
``core/scheduler.simulate_program``, so the elites are *ranked by the
program that would actually ship*.

Pieces:

  * :func:`gemm_specs` — config-to-``ConvSpec`` plumbing: any
    compilable network (the CNN workload zoo *or* a registry LM arch)
    as the spec list both the analytical env and the evaluator share;
  * :class:`ProgramEvaluator` — config → ``Program`` → simulated
    latency → corrected Eq.-18 reward, behind an LRU cache keyed by a
    config fingerprint (elite re-scoring revisits the same configs
    round after round, so hot elites cost one dict lookup);
  * :class:`EliteSet` — the top-K pool with two-tier re-ranking
    (analytical reward until corrected, simulated after);
  * :func:`sim_gap_report` — the ``dse.sim_gap.*`` benchmark rows:
    analytical-vs-simulated latency for a fixed config per
    architecture, with the documented agreement tolerance.

The documented agreement tolerance between the two tiers is
:data:`SIM_GAP_TOL_PCT` (see ``docs/dse.md`` — the closed form tracks
the canonical ``-O0`` schedule within a few percent; ``-O1`` stream
optimization widens the gap, which is exactly why elites are re-scored
on the compiled program).
"""
from __future__ import annotations

import collections
import dataclasses
import hashlib
import math
from typing import Sequence

import numpy as np

from repro.core.scheduler import (
    DspCoreConfig,
    FPGADevice,
    LutCoreConfig,
    simulate_program,
)
from repro.core.workloads import WORKLOADS, ConvSpec
from repro.dse.env import AccuracyProxy, shaped_reward

#: Documented analytical-vs-simulated agreement tolerance (percent) for
#: the ``dse.sim_gap.*`` benchmark rows: |analytical - simulated| /
#: simulated * 100 must stay below this for the two-tier loop to be
#: meaningful (the correction should *refine* the ranking, not
#: contradict the model wholesale).
SIM_GAP_TOL_PCT = 25.0


# ---------------------------------------------------------------------------
# Config-to-spec plumbing
# ---------------------------------------------------------------------------


def gemm_specs(network: str, seq_len: int = 64) -> list[ConvSpec]:
    """``ConvSpec`` list for any compilable network name.

    CNN workloads come straight from the zoo. Registry LM archs are
    walked by ``compiler/networks.lm_gemm_layers`` (smoke config) and
    each projection GEMM becomes a 1x1 "conv" spec with
    ``in_hw = sqrt(seq_len)`` so that ``spec.gemm()`` reproduces the
    exact GEMM extents the compiler lowers — the analytical env and the
    program evaluator then score the *same* shapes. ``seq_len`` must be
    a perfect square for that identity to hold.
    """
    if network in WORKLOADS:
        return WORKLOADS[network]()
    from repro.compiler.networks import network_layers
    hw = math.isqrt(seq_len)
    if hw * hw != seq_len:
        raise ValueError(
            f"seq_len must be a perfect square to map token rows onto a "
            f"ConvSpec feature map, got {seq_len}")
    specs = []
    for gl in network_layers(network, seq_len=seq_len):
        spec = ConvSpec(gl.name, c_in=gl.dims.k, c_out=gl.dims.n,
                        kernel=1, stride=1, in_hw=hw)
        assert spec.gemm() == gl.dims
        specs.append(spec)
    return specs


def specs_to_layers(specs: Sequence[ConvSpec]):
    """Lowerable ``GemmLayer`` list for a spec list.

    Real CNNs (any spatial kernel, depthwise, pooling or shortcut glue)
    keep their ``ConvGeometry`` so the compiled program stages im2col
    exactly like the deployed path; an all-1x1 FC chain (the
    :func:`gemm_specs` view of an LM arch) lowers as plain GEMM layers,
    matching how ``compiler/networks.py`` treats LM frontends.
    """
    from repro.compiler.program import GemmLayer
    conv_like = any(s.kernel > 1 or s.depthwise or s.pool or s.shortcut
                    for s in specs)
    if conv_like:
        return [GemmLayer.from_conv(s) for s in specs]
    return [GemmLayer(s.name, s.gemm()) for s in specs]


def config_fingerprint(device: FPGADevice, lut_cfg: LutCoreConfig,
                       dsp_cfg: DspCoreConfig, bw: Sequence[int],
                       ba: Sequence[int], n_luts: Sequence[int],
                       opt_level: int) -> str:
    """Stable key over everything that determines the compiled program.

    Cheaper than ``Program.fingerprint()`` (no lowering needed), which
    is the point: the LRU is consulted *before* compiling.
    """
    h = hashlib.sha256()
    h.update(repr((device.name, lut_cfg, dsp_cfg, tuple(bw), tuple(ba),
                   tuple(n_luts), opt_level)).encode())
    return h.hexdigest()[:16]


def _info_n_luts(info: dict, specs: Sequence[ConvSpec]) -> list[int]:
    """Exact per-layer LUT filter counts from an env ``info`` dict.

    ``N3HEnv`` records them directly (``n_luts``); older callers only
    carry the ``ratios`` fractions, which round back exactly because
    every ratio is ``n_lut / c_out``.
    """
    if "n_luts" in info:
        return [int(v) for v in info["n_luts"]]
    return [int(round(r * s.gemm().n))
            for r, s in zip(info["ratios"], specs)]


# ---------------------------------------------------------------------------
# The evaluator
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class EvalResult:
    """One config scored by both tiers."""
    key: str                     # config fingerprint (the cache key)
    analytical_ms: float         # closed-form latency (from the env)
    simulated_ms: float          # simulate_program on the compiled stream
    sim_cycles: int
    acc: float                   # accuracy proxy (shared by both tiers)
    reward_analytical: float
    reward_simulated: float      # Eq. 18 re-applied at the simulated latency
    n_instructions: int
    cached: bool                 # served from the program LRU

    @property
    def gap_pct(self) -> float:
        """Signed model-vs-program gap: positive = the closed form
        over-estimates the compiled program's latency."""
        return 100.0 * (self.analytical_ms - self.simulated_ms) \
            / max(self.simulated_ms, 1e-12)


class ProgramEvaluator:
    """Re-score configurations on real compiled programs.

    One instance per search: it pins the workload (``specs``), device,
    latency target and reward shaping, and keeps an LRU of
    ``(Program, simulated cycles)`` keyed by config fingerprint so that
    re-scoring a returning elite costs a dict lookup instead of a
    compile + simulate.
    """

    def __init__(self, specs: Sequence[ConvSpec], device: FPGADevice,
                 target_latency_ms: float,
                 proxy: AccuracyProxy | None = None,
                 reward_lambda: float = 0.01, opt_level: int = 1,
                 cache_size: int = 32, name: str = "dse",
                 accuracy_fn=None, measured_baseline: float = 100.0):
        self.specs = list(specs)
        self.device = device
        self.target_latency_ms = target_latency_ms
        self.proxy = proxy if proxy is not None else AccuracyProxy()
        self.reward_lambda = reward_lambda
        self.opt_level = opt_level
        self.name = name
        #: optional measured-accuracy hook (``fn(program) -> percent``,
        #: e.g. ``repro.eval.accuracy.make_accuracy_fn``): elite
        #: correction then swaps the analytical AccuracyProxy term for
        #: the agreement the compiled program actually measures —
        #: against ``measured_baseline`` (100 = fp32 parity) instead of
        #: the proxy's paper baseline.
        self.accuracy_fn = accuracy_fn
        self.measured_baseline = measured_baseline
        self._layers = specs_to_layers(self.specs)
        self._cache: collections.OrderedDict[str, tuple] = \
            collections.OrderedDict()
        self._cache_size = max(int(cache_size), 1)
        self._hits = 0
        self._misses = 0

    # -- cache ---------------------------------------------------------------

    def config_key(self, info: dict) -> str:
        return config_fingerprint(
            self.device, info["lut_cfg"], info["dsp_cfg"], info["bw_lut"],
            info["ba"], _info_n_luts(info, self.specs), self.opt_level)

    def cache_info(self) -> dict:
        return {"hits": self._hits, "misses": self._misses,
                "size": len(self._cache), "maxsize": self._cache_size}

    def _entry(self, key: str, info: dict) -> tuple[list, bool]:
        """LRU entry ``[program, sim_cycles | None, measured_acc |
        None]`` for a config.

        Cycles and measured accuracy are computed lazily (``_cycles`` /
        ``_measured``): :meth:`verify` only needs the program, and a
        full-size CNN simulation is minutes-long — functional
        verification must not pay for it.
        """
        if key in self._cache:
            self._cache.move_to_end(key)
            self._hits += 1
            return self._cache[key], True
        self._misses += 1
        entry = [self.compile(info), None, None]
        self._cache[key] = entry
        while len(self._cache) > self._cache_size:
            self._cache.popitem(last=False)
        return entry, False

    def _cycles(self, entry: list) -> int:
        if entry[1] is None:
            entry[1] = int(simulate_program(entry[0]).total_cycles)
        return entry[1]

    def _measured(self, entry: list) -> float:
        if entry[2] is None:
            entry[2] = float(self.accuracy_fn(entry[0]))
        return entry[2]

    # -- config -> program ---------------------------------------------------

    def compile(self, info: dict):
        """Lower the config to a :class:`~repro.compiler.Program`,
        honoring the searched core knobs, per-layer bit-widths and the
        exact Eq.-12 neuron splits (``n_luts`` — *not* re-solved, so
        the program realizes precisely the design point the agent
        scored)."""
        from repro.compiler.lower import lower_network
        return lower_network(
            self.name, self._layers, info["lut_cfg"], info["dsp_cfg"],
            self.device, bits_w_lut=list(info["bw_lut"]),
            bits_a=list(info["ba"]),
            n_luts=_info_n_luts(info, self.specs),
            opt_level=self.opt_level)

    # -- scoring -------------------------------------------------------------

    def evaluate(self, info: dict) -> EvalResult:
        """Compile (or fetch) the config's program, simulate it, and
        re-apply the Eq.-18 reward at the simulated latency. The
        accuracy term is untouched — only the latency model changes
        between the tiers."""
        key = self.config_key(info)
        entry, cached = self._entry(key, info)
        cycles = self._cycles(entry)
        sim_ms = self.device.cycles_to_ms(cycles)
        acc = float(info["acc"])
        r_ana = shaped_reward(info["latency_ms"], self.target_latency_ms,
                              acc, self.proxy.baseline_acc,
                              self.reward_lambda)
        r_sim = shaped_reward(sim_ms, self.target_latency_ms, acc,
                              self.proxy.baseline_acc, self.reward_lambda)
        return EvalResult(
            key=key, analytical_ms=float(info["latency_ms"]),
            simulated_ms=sim_ms, sim_cycles=cycles, acc=acc,
            reward_analytical=float(r_ana), reward_simulated=float(r_sim),
            n_instructions=entry[0].n_instructions, cached=cached)

    def correct(self, info: dict) -> tuple[float, dict]:
        """Elite-correction entry point: returns the corrected reward
        plus a *new* info dict carrying both latency columns.

        Without an ``accuracy_fn`` the correction swaps the latency
        tier only (``reward_source="simulated"``). With one, the
        accuracy term is swapped too: the compiled program is executed
        over the validation stream and the Eq.-18 reward is re-applied
        at (simulated latency, **measured** agreement) —
        ``reward_source="measured"``, ``measured_acc`` recorded — so
        elite re-ranking trades off latency against accuracy the
        program actually delivers, not the proxy's monotone estimate.
        """
        res = self.evaluate(info)
        corrected = dict(info)
        corrected.update({
            "reward_source": "simulated",
            "analytical_latency_ms": res.analytical_ms,
            "simulated_latency_ms": res.simulated_ms,
            "sim_gap_pct": res.gap_pct,
            "sim_cycles": res.sim_cycles,
        })
        reward = res.reward_simulated
        if self.accuracy_fn is not None:
            key = self.config_key(info)
            entry, _cached = self._entry(key, info)
            acc_m = self._measured(entry)
            reward = shaped_reward(res.simulated_ms,
                                   self.target_latency_ms, acc_m,
                                   self.measured_baseline,
                                   self.reward_lambda)
            corrected.update({
                "reward_source": "measured",
                "measured_acc": acc_m,
            })
        return reward, corrected

    # -- functional verification ----------------------------------------------

    def verify(self, info: dict, seed: int = 0) -> bool:
        """Execute the config's compiled program functionally and check
        golden-vs-pallas bit-exactness layer by layer (the repo's
        standing cross-check for a program that "actually runs"):
        synthetic weights, synthetic quantized activations, exact
        integer comparison of every layer output."""
        from repro.compiler.runtime import (
            GoldenExecutor,
            PallasExecutor,
            bind_synthetic,
        )
        from repro.quant.uniform import qrange
        entry, _cached = self._entry(self.config_key(info), info)
        prog = entry[0]           # no simulation — verify is functional
        golden, pallas = GoldenExecutor(prog), PallasExecutor(prog)
        rng = np.random.default_rng(seed)
        for lp in prog.layers:
            bind_synthetic(golden, lp, seed=seed + lp.index)
            bind_synthetic(pallas, lp, seed=seed + lp.index)
            lo, hi = qrange(lp.bits_a)
            shape = (lp.dims.m, lp.dims.k, lp.dims.n) if lp.depthwise \
                else (lp.dims.m, lp.dims.k)
            x_q = rng.integers(lo, hi + 1, shape).astype(np.int8)
            out_g = np.asarray(golden.run_layer(lp.index, x_q))
            out_p = np.asarray(pallas.run_layer(lp.index, x_q))
            if not (out_g == out_p).all():
                return False
        return True


# ---------------------------------------------------------------------------
# Elite pool with two-tier re-ranking
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Elite:
    reward: float                       # current ranking reward
    reward_analytical: float
    info: dict
    transitions: list | None = None     # episode, for replay correction
    key: str | None = None              # config fingerprint (dedup)
    reward_simulated: float | None = None

    @property
    def corrected(self) -> bool:
        return self.reward_simulated is not None


class EliteSet:
    """Top-K configurations of a search, ranked by the best reward
    known for each: analytical until :meth:`rerank` applies a
    simulator correction, simulated afterwards. Deduplicates on the
    config fingerprint — the agent frequently re-emits a good config,
    and re-scoring it twice would waste a cache slot *and* bias the
    replay buffer."""

    def __init__(self, k: int):
        self.k = max(int(k), 1)
        self.elites: list[Elite] = []

    def add(self, reward: float, info: dict,
            transitions: list | None = None, key: str | None = None) -> bool:
        """Offer one episode's terminal config; returns True if kept.

        Admission and eviction compare *analytical* rewards — the only
        tier a new candidate has been scored on. Comparing a fresh
        analytical reward against simulator-corrected pool rewards
        would reject exactly the near-target configs whose ranking the
        correction can flip (analytically just-infeasible, simulated
        feasible) before tier 2 ever sees them. The best
        *simulator-confirmed* elite (highest ``reward_simulated``) is
        never evicted — not even when an uncorrected elite holds a
        higher analytical reward — so a confirmed winner survives
        analytical churn until the next correction round re-ranks.
        """
        if key is not None and any(e.key == key for e in self.elites):
            return False
        if len(self.elites) >= self.k:
            corrected = [e for e in self.elites if e.corrected]
            protected = max(corrected, key=lambda e: e.reward_simulated) \
                if corrected else None
            evictable = [e for e in self.elites if e is not protected]
            if not evictable:      # k == 1 and the winner is confirmed
                return False
            floor = min(evictable, key=lambda e: e.reward_analytical)
            if reward <= floor.reward_analytical:
                return False
            self.elites.remove(floor)
        self.elites.append(Elite(reward=reward, reward_analytical=reward,
                                 info=info, transitions=transitions,
                                 key=key))
        self.rerank()
        return True

    def uncorrected(self) -> list[Elite]:
        return [e for e in self.elites if not e.corrected]

    def apply_correction(self, elite: Elite, reward_simulated: float,
                         corrected_info: dict) -> None:
        elite.reward_simulated = float(reward_simulated)
        elite.reward = float(reward_simulated)
        elite.info = corrected_info
        self.rerank()

    def rerank(self) -> None:
        self.elites.sort(key=lambda e: e.reward, reverse=True)

    @property
    def best(self) -> Elite | None:
        return self.elites[0] if self.elites else None


# ---------------------------------------------------------------------------
# Benchmark rows: the model-vs-program gap per architecture
# ---------------------------------------------------------------------------


def sim_gap_report(network: str, specs: Sequence[ConvSpec] | None = None,
                   device: FPGADevice | None = None,
                   lut_cfg: LutCoreConfig | None = None,
                   dsp_cfg: DspCoreConfig | None = None,
                   bits_w: int = 4, bits_a: int = 4,
                   target_latency_ms: float = 1e9,
                   opt_level: int = 1, seq_len: int = 64) -> dict:
    """Analytical-vs-simulated latency for one fixed configuration of
    ``network`` — the payload of the ``dse.sim_gap.*`` benchmark rows.

    Scores the uniform ``bits_w``/``bits_a`` config through both tiers
    (Eq.-12 splits solved analytically, then the identical splits
    compiled and simulated) and reports the signed gap plus whether it
    sits inside the documented :data:`SIM_GAP_TOL_PCT` tolerance.
    """
    from repro.core.scheduler import XC7Z020
    from repro.dse.env import evaluate_config
    device = device or XC7Z020
    lut_cfg = lut_cfg or LutCoreConfig(m=8, n=16, k=128)
    dsp_cfg = dsp_cfg or DspCoreConfig(
        n_reg_row_a=DspCoreConfig.rows_for_device(device))
    if specs is None:
        specs = gemm_specs(network, seq_len=seq_len)
    proxy = AccuracyProxy()
    _r, info = evaluate_config(specs, lut_cfg, dsp_cfg, device,
                               [bits_w] * len(specs), [bits_a] * len(specs),
                               proxy, target_latency_ms, 0.01)
    ev = ProgramEvaluator(specs, device, target_latency_ms, proxy=proxy,
                          opt_level=opt_level, name=network)
    res = ev.evaluate(info)
    return {
        "BENCH": "dse.sim_gap",
        "network": network,
        "layers": len(specs),
        "opt_level": opt_level,
        "bits_w": bits_w,
        "bits_a": bits_a,
        "analytical_ms": round(res.analytical_ms, 6),
        "simulated_ms": round(res.simulated_ms, 6),
        "sim_cycles": res.sim_cycles,
        "gap_pct": round(res.gap_pct, 3),
        "tol_pct": SIM_GAP_TOL_PCT,
        "within_tol": bool(abs(res.gap_pct) <= SIM_GAP_TOL_PCT),
        "n_instructions": res.n_instructions,
    }
