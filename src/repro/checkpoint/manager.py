"""Async, atomic, mesh-agnostic checkpointing.

Requirements at 1000+ node scale, and how each is met here:

  * **No step-time stall** — ``save`` snapshots the state to host memory
    synchronously (cheap) and serializes on a background thread;
    ``wait()`` joins before the next save or at shutdown. Serialization
    errors surface on the next call rather than being dropped.
  * **Crash-safe** — writes go to ``step_XXXX.tmp-<nonce>/`` and are
    published with one atomic ``os.rename``; a reader never sees a
    partial checkpoint, and stale tmp dirs from a killed process are
    garbage-collected on manager construction.
  * **Mesh-agnostic / elastic** — leaves are stored as *full* host
    arrays keyed by pytree path, with a JSON manifest (step, shapes,
    dtypes). ``restore`` device_puts onto whatever mesh/sharding the
    new job uses — restarting 512-chip state onto 256 chips (or a
    differently-shaped mesh) is the same code path.
  * **Retention** — keep the newest ``max_to_keep`` checkpoints.
  * **Auto-resume** — ``latest_step()`` scans published directories.

The format is plain ``.npy`` per leaf + ``manifest.json`` — no pickle,
no framework lock-in, directly inspectable.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import uuid
from typing import Any

import jax
import numpy as np

try:
    import ml_dtypes
    _EXT_DTYPES = {"bfloat16": ml_dtypes.bfloat16,
                   "float8_e4m3fn": ml_dtypes.float8_e4m3fn,
                   "float8_e5m2": ml_dtypes.float8_e5m2}
except ImportError:  # pragma: no cover
    _EXT_DTYPES = {}

_SEP = "//"


def _to_storable(arr: np.ndarray) -> np.ndarray:
    """npy cannot round-trip ml_dtypes extension dtypes portably —
    store them as a raw same-width uint view (logical dtype lives in
    the manifest)."""
    if arr.dtype.name in _EXT_DTYPES:
        return arr.view({2: np.uint16, 1: np.uint8}[arr.dtype.itemsize])
    return arr


def _from_storable(arr: np.ndarray, dtype_name: str) -> np.ndarray:
    if dtype_name in _EXT_DTYPES:
        return arr.view(_EXT_DTYPES[dtype_name])
    return arr


def _flatten_with_paths(tree: Any) -> dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        flat[key] = leaf
    return flat


class CheckpointManager:
    def __init__(self, directory: str, max_to_keep: int = 3):
        self.dir = os.path.abspath(directory)
        self.max_to_keep = max_to_keep
        os.makedirs(self.dir, exist_ok=True)
        self._thread: threading.Thread | None = None
        self._error: BaseException | None = None
        # GC stale tmp dirs from a previous crashed process.
        for name in os.listdir(self.dir):
            if ".tmp-" in name:
                shutil.rmtree(os.path.join(self.dir, name),
                              ignore_errors=True)

    # -- paths ---------------------------------------------------------------

    def _step_dir(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:010d}")

    def all_steps(self) -> list[int]:
        steps = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and ".tmp-" not in name:
                try:
                    steps.append(int(name.split("_")[1]))
                except ValueError:
                    continue
        return sorted(steps)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    # -- save ----------------------------------------------------------------

    def save(self, step: int, state: Any, blocking: bool = False) -> None:
        """Snapshot now, write in the background (atomic publish)."""
        self.wait()                                   # one in flight at a time
        flat = _flatten_with_paths(state)
        host = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}

        def work():
            try:
                self._write(step, host)
                self._retain()
            except BaseException as e:  # noqa: BLE001 — surfaced by wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()
        if blocking:
            self.wait()

    def _write(self, step: int, host: dict[str, np.ndarray]) -> None:
        final = self._step_dir(step)
        tmp = f"{final}.tmp-{uuid.uuid4().hex[:8]}"
        os.makedirs(tmp)
        manifest = {"step": step, "leaves": {}}
        for i, (key, arr) in enumerate(sorted(host.items())):
            fname = f"leaf_{i:05d}.npy"
            np.save(os.path.join(tmp, fname), _to_storable(arr))
            manifest["leaves"][key] = {
                "file": fname,
                "shape": list(arr.shape),
                "dtype": str(arr.dtype),
            }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):                     # overwrite same step
            shutil.rmtree(final)
        os.rename(tmp, final)                          # atomic publish

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise RuntimeError("async checkpoint write failed") from err

    def _retain(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.max_to_keep]:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    # -- restore ---------------------------------------------------------------

    def restore(self, like: Any, step: int | None = None,
                shardings: Any = None) -> Any:
        """Restore into the structure of ``like`` (a pytree of arrays or
        ShapeDtypeStructs). ``shardings`` (optional, congruent pytree of
        jax.sharding.Sharding) performs the elastic device_put — the
        stored arrays are mesh-agnostic full arrays."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = self._step_dir(step)
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)

        flat_like = _flatten_with_paths(like)
        missing = set(flat_like) - set(manifest["leaves"])
        if missing:
            raise KeyError(f"checkpoint step {step} missing leaves "
                           f"{sorted(missing)[:5]}...")
        flat_sh = (_flatten_with_paths(shardings)
                   if shardings is not None else {})

        restored = {}
        for key in flat_like:
            meta = manifest["leaves"][key]
            arr = _from_storable(np.load(os.path.join(d, meta["file"])),
                                 meta["dtype"])
            want = flat_like[key]
            if tuple(arr.shape) != tuple(want.shape):
                raise ValueError(
                    f"shape mismatch for {key}: checkpoint "
                    f"{arr.shape} vs expected {tuple(want.shape)}")
            if key in flat_sh and flat_sh[key] is not None:
                restored[key] = jax.device_put(arr, flat_sh[key])
            else:
                restored[key] = jax.numpy.asarray(arr, dtype=want.dtype)

        leaves_paths = jax.tree_util.tree_flatten_with_path(like)
        treedef = jax.tree_util.tree_structure(like)
        ordered = []
        for path, _ in leaves_paths[0]:
            key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                            for p in path)
            ordered.append(restored[key])
        return jax.tree_util.tree_unflatten(treedef, ordered)
