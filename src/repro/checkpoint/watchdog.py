"""Step watchdog: straggler detection + liveness heartbeat.

At fleet scale a hung host rarely crashes loudly — it just stops making
progress, or makes it 10x slower than its peers. The watchdog gives the
training loop two cheap defenses:

  * **Straggler detection** — records per-step wall times and flags any
    step slower than ``threshold`` x the trailing median. The launcher
    logs the flag; an external supervisor (or the elastic-restart path)
    decides whether to evict the host. A real deployment feeds this
    per-host; here it guards the single-process loop and is exercised
    by failure-injection tests.
  * **Heartbeat file** — atomically rewritten every step with
    {step, time}; an external process-level supervisor declares the job
    dead when the heartbeat goes stale and restarts from the newest
    checkpoint (CheckpointManager.latest_step + restore — the auto-
    resume path in launch/train.py).
"""
from __future__ import annotations

import json
import os
import statistics
import tempfile
import time


class StepWatchdog:
    def __init__(self, heartbeat_path: str | None = None,
                 threshold: float = 3.0, window: int = 32):
        self.heartbeat_path = heartbeat_path
        self.threshold = threshold
        self.window = window
        self.times: list[float] = []
        self.stragglers: list[int] = []
        self._t0: float | None = None
        self._step = 0

    def start_step(self, step: int) -> None:
        self._step = step
        self._t0 = time.monotonic()

    def end_step(self) -> bool:
        """Returns True if this step was a straggler."""
        if self._t0 is None:
            return False
        dt = time.monotonic() - self._t0
        self._t0 = None
        straggler = False
        if len(self.times) >= 5:
            med = statistics.median(self.times[-self.window:])
            straggler = dt > self.threshold * med
        self.times.append(dt)
        if straggler:
            self.stragglers.append(self._step)
        self._heartbeat()
        return straggler

    def _heartbeat(self) -> None:
        if not self.heartbeat_path:
            return
        payload = json.dumps({"step": self._step, "time": time.time()})
        d = os.path.dirname(os.path.abspath(self.heartbeat_path)) or "."
        fd, tmp = tempfile.mkstemp(dir=d)
        with os.fdopen(fd, "w") as f:
            f.write(payload)
        os.replace(tmp, self.heartbeat_path)          # atomic

    @staticmethod
    def heartbeat_age(path: str) -> float | None:
        """Seconds since the last heartbeat, or None if absent/corrupt.
        The external supervisor's liveness probe."""
        try:
            with open(path) as f:
                return time.time() - json.load(f)["time"]
        except (OSError, ValueError, KeyError):
            return None

    def median_step_s(self) -> float | None:
        return statistics.median(self.times) if self.times else None
