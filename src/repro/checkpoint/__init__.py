"""Fault-tolerance substrate: async checkpointing + step watchdog."""
from repro.checkpoint.manager import CheckpointManager
from repro.checkpoint.watchdog import StepWatchdog

__all__ = ["CheckpointManager", "StepWatchdog"]
