"""Gradient compression for cross-pod data parallelism.

At 1000+ node scale the inter-pod (DCN / slow-link) all-reduce of
gradients dominates step time for small per-pod batches. We implement
int8 uniform compression with **error feedback** (EF-SGD style): the
quantization residual of step t is added back to the gradient of step
t+1 before compression, which provably preserves SGD convergence for
unbiased-ish compressors.

Design:
  * per-leaf symmetric int8 codes with a single fp32 max-abs scale
    (scale exchange is O(1) per leaf — negligible);
  * compression happens *before* the pod-axis reduction and
    decompression after, so the slow link moves 1/4 the bytes of bf16
    (1/2 of fp8, 1/4 of fp32);
  * the intra-pod (fast ICI) reduction stays full precision.

All functions are jit-safe pytree transforms; the collective itself is
expressed with ``jax.lax.psum`` under ``shard_map`` or left to GSPMD
when used inside ``pjit`` (we compress, constrain sharding, reduce,
decompress).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class CompressionState:
    """Error-feedback residual, congruent with the grad tree."""
    residual: Any


def init_compression_state(grads: Any) -> CompressionState:
    return CompressionState(
        residual=jax.tree.map(lambda g: jnp.zeros_like(g, dtype=jnp.float32),
                              grads))


def compress_int8(g: jax.Array, eps: float = 1e-12) -> tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8 quantization: returns (codes, scale)."""
    scale = jnp.maximum(jnp.max(jnp.abs(g)), eps) / 127.0
    codes = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return codes, scale.astype(jnp.float32)


def decompress_int8(codes: jax.Array, scale: jax.Array) -> jax.Array:
    return codes.astype(jnp.float32) * scale


def compressed_grad_allreduce(grads: Any, state: CompressionState,
                              axis_name: str | None = None,
                              n_replicas: int | None = None
                              ) -> tuple[Any, CompressionState]:
    """Error-feedback int8 all-reduce over ``axis_name``.

    Inside ``shard_map`` pass the pod axis name; ``n_replicas`` overrides
    the averaging divisor (defaults to the axis size). Outside shard_map
    (axis_name=None) this degrades to pure compress/decompress with
    error feedback — GSPMD then reduces the decompressed values; the
    error-feedback residual math is identical either way, which is what
    the unit tests pin down.
    """
    leaves, treedef = jax.tree.flatten(grads)
    res_leaves = jax.tree.leaves(state.residual)

    new_grads = []
    new_res = []
    for g, r in zip(leaves, res_leaves):
        g32 = g.astype(jnp.float32) + r
        codes, scale = compress_int8(g32)
        if axis_name is not None:
            summed = jax.lax.psum(codes.astype(jnp.int32), axis_name)
            scale_sum = jax.lax.psum(scale, axis_name)
            n = n_replicas or jax.lax.psum(1, axis_name)
            # codes were scaled per-replica; use the mean scale for the
            # sum of codes (exact when scales match, tight otherwise).
            reduced = summed.astype(jnp.float32) * (scale_sum / n) / n
        else:
            reduced = decompress_int8(codes, scale)
        local_deq = decompress_int8(codes, scale)
        new_res.append(g32 - local_deq)            # error feedback
        new_grads.append(reduced.astype(g.dtype))

    return (jax.tree.unflatten(treedef, new_grads),
            CompressionState(jax.tree.unflatten(treedef, new_res)))


def compression_ratio(grads: Any) -> float:
    """Bytes(int8 codes + scales) / bytes(original) for a grad tree."""
    orig = sum(g.size * g.dtype.itemsize for g in jax.tree.leaves(grads))
    comp = sum(g.size + 4 for g in jax.tree.leaves(grads))
    return comp / max(orig, 1)
