"""Logical-axis sharding rules.

Every parameter and activation in the model zoo carries a tuple of
logical axis names (one per dimension, ``None`` for "no preference").
``AxisRules`` maps logical names to mesh axis names; ``logical_to_spec``
resolves a logical tuple into a ``PartitionSpec`` under a concrete mesh,
enforcing two invariants:

  1. a mesh axis is consumed at most once per spec (first logical dim
     that claims it wins; later claims fall back to replication);
  2. a dimension is only sharded if its size divides evenly by the
     product of the mesh axes assigned to it (uneven shards silently
     fall back to replication — robustness over maximal sharding).

The default rules implement the baseline distribution plan:
batch -> (pod, data); heads / mlp / experts / vocab -> model; everything
else replicated. ZeRO-1 additionally shards optimizer state over "data"
(``zero1_spec``).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec


MeshAxes = tuple[str, ...]


@dataclasses.dataclass(frozen=True)
class AxisRules:
    """Ordered logical-name -> mesh-axes mapping."""
    rules: tuple[tuple[str, MeshAxes], ...]

    def lookup(self, name: str) -> MeshAxes:
        for n, axes in self.rules:
            if n == name:
                return axes
        return ()

    def replace(self, **overrides: MeshAxes | None) -> "AxisRules":
        """Return a copy with some logical names remapped (None removes)."""
        out = []
        seen = set()
        for n, axes in self.rules:
            if n in overrides:
                seen.add(n)
                if overrides[n] is not None:
                    out.append((n, tuple(overrides[n])))
            else:
                out.append((n, axes))
        for n, axes in overrides.items():
            if n not in seen and axes is not None:
                out.append((n, tuple(axes)))
        return AxisRules(tuple(out))


# Baseline rules. "pod" only exists on the multi-pod mesh; mesh axes not
# present in the mesh are dropped at resolution time.
DEFAULT_RULES = AxisRules((
    ("batch", ("pod", "data")),
    ("expert_group", ("pod", "data")),   # MoE dispatch group dim
    ("vocab", ("model",)),
    ("heads", ("model",)),
    ("kv_heads", ("model",)),
    ("mlp", ("model",)),
    ("experts", ("model",)),
    # --- activation names (model code constraints). A name MISSING from
    # this table silently means "replicate": an absent "vocab_act" rule
    # cost a 67 GB/step fp32 logits all-gather on gemma train_4k before
    # these entries existed. Keep every constraint name listed.
    ("vocab_act", ("model",)),
    ("act_heads", ("model",)),
    ("act_kv_heads", ("model",)),
    ("act_seq_attn", ()),                # bound to ("model",) for archs
                                         # whose heads don't divide the mesh
    ("act_mlp", ("model",)),
    ("act_experts", ("model",)),
    ("kv_seq", ()),                      # decode KV cache seq: replicated in
                                         # baseline; hillclimb shards it
    ("act_res", ("model",)),             # Megatron-style sequence-parallel
                                         # residual stream: layer-boundary
                                         # activations sharded over model —
                                         # shrinks saved scan carries 16x
    ("embed", ("data",)),                # FSDP/ZeRO-3: weight embed dims
                                         # sharded over data; XLA all-gathers
                                         # per layer and frees after use
    ("seq", ()),
    ("layers", ()),
    ("head_dim", ()),
    ("state", ()),
    ("capacity", ()),
))

# Sequence-parallel variant used by the hillclimb configs: long KV caches
# sharded over the model axis, combined with an online-softmax reduction.
KV_SHARDED_RULES = DEFAULT_RULES.replace(kv_seq=("model",))

#: Logical axes whose sharding means "split output filters/columns".
#: Single source of truth shared with the NN→ISA compiler: rule tables
#: that map any of these onto a mesh axis translate to filter-parallel
#: (shard-N) multi-device plans in ``repro.compiler.partition``, while
#: a sharded "layers" axis translates to pipeline stages.
FILTER_PARALLEL_AXES = ("mlp", "heads", "experts", "vocab")


def _mesh_axis_sizes(mesh: Mesh) -> dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def logical_to_spec(axes: Sequence[str | None] | None,
                    mesh: Mesh,
                    rules: AxisRules = DEFAULT_RULES,
                    shape: Sequence[int] | None = None) -> PartitionSpec:
    """Resolve logical axis names into a PartitionSpec for ``mesh``.

    ``shape`` (optional) enables the divisibility fallback: a dim whose
    size is not divisible by its assigned mesh axes is replicated.
    """
    if axes is None:
        return PartitionSpec()
    sizes = _mesh_axis_sizes(mesh)
    used: set[str] = set()
    dims: list[Any] = []
    for d, name in enumerate(axes):
        if name is None:
            dims.append(None)
            continue
        want = [a for a in rules.lookup(name) if a in sizes and a not in used]
        if not want:
            dims.append(None)
            continue
        if shape is not None:
            prod = int(np.prod([sizes[a] for a in want]))
            while want and shape[d] % prod != 0:
                # Drop trailing mesh axes until the dim divides evenly.
                want = want[:-1]
                prod = int(np.prod([sizes[a] for a in want])) if want else 1
        if not want:
            dims.append(None)
            continue
        used.update(want)
        dims.append(tuple(want) if len(want) > 1 else want[0])
    # Trim trailing Nones for a tidy spec (semantically identical).
    while dims and dims[-1] is None:
        dims.pop()
    return PartitionSpec(*dims)


def spec_tree_for(axes_tree: Any, mesh: Mesh,
                  rules: AxisRules = DEFAULT_RULES,
                  shape_tree: Any = None) -> Any:
    """Map ``logical_to_spec`` over a pytree of logical-axes tuples.

    ``axes_tree`` leaves are tuples of axis names (or None); it must be
    structure-congruent with ``shape_tree`` when given.
    """
    is_leaf = lambda x: x is None or (isinstance(x, tuple) and
                                      all(isinstance(e, (str, type(None))) for e in x))
    if shape_tree is None:
        return jax.tree.map(lambda a: logical_to_spec(a, mesh, rules),
                            axes_tree, is_leaf=is_leaf)
    return jax.tree.map(
        lambda a, s: logical_to_spec(a, mesh, rules, shape=s),
        axes_tree, shape_tree, is_leaf=is_leaf)


def with_logical_constraint(x: jax.Array, axes: Sequence[str | None],
                            mesh: Mesh | None = None,
                            rules: AxisRules = DEFAULT_RULES) -> jax.Array:
    """``lax.with_sharding_constraint`` via logical names. No-op outside
    a mesh context (so model code runs unchanged on a single device)."""
    mesh = mesh or _current_mesh()
    if mesh is None or mesh.empty:
        return x
    spec = logical_to_spec(axes, mesh, rules, shape=x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def _current_mesh() -> Mesh | None:
    try:
        from jax._src.mesh import thread_resources
        m = thread_resources.env.physical_mesh
        if m is not None and not m.empty:
            return m
    except Exception:
        pass
    return None


def zero1_spec(spec: PartitionSpec, shape: Sequence[int], mesh: Mesh,
               axis: str = "data") -> PartitionSpec:
    """ZeRO-1 rule: additionally shard the first replicated dim of an
    optimizer-state leaf over the data axis (when it divides evenly)."""
    sizes = _mesh_axis_sizes(mesh)
    if axis not in sizes:
        return spec
    dims = list(spec) + [None] * (len(shape) - len(spec))
    used = {a for d in dims if d is not None
            for a in ((d,) if isinstance(d, str) else d)}
    if axis in used:
        return spec
    for i, d in enumerate(dims):
        if d is None and shape[i] % sizes[axis] == 0 and shape[i] >= sizes[axis]:
            dims[i] = axis
            return PartitionSpec(*dims)
    return spec


def shard_params_tree(params: Any, axes_tree: Any, mesh: Mesh,
                      rules: AxisRules = DEFAULT_RULES) -> Any:
    """Device-put a materialized param tree onto the mesh per the rules."""
    shapes = jax.tree.map(lambda p: p.shape, params)
    specs = spec_tree_for(axes_tree, mesh, rules, shape_tree=shapes)
    return jax.tree.map(
        lambda p, s: jax.device_put(p, NamedSharding(mesh, s)), params, specs)
