"""Distribution substrate: meshes, logical-axis sharding, compression.

The framework describes every parameter/activation with *logical* axis
names ("batch", "embed", "heads", "experts", ...). A rule table maps
logical axes onto mesh axes (("pod",) "data", "model"), with automatic
divisibility fallback (an axis that does not divide evenly is left
replicated rather than unevenly sharded). This is the same design as
MaxText/T5X logical axis rules, reimplemented minimally.
"""
from repro.parallel.sharding import (
    DEFAULT_RULES,
    AxisRules,
    logical_to_spec,
    shard_params_tree,
    spec_tree_for,
    with_logical_constraint,
    zero1_spec,
)
from repro.parallel.compress import (
    CompressionState,
    compress_int8,
    decompress_int8,
    init_compression_state,
    compressed_grad_allreduce,
)
from repro.parallel.pipeline import gpipe, stage_params_from_stack

__all__ = [
    "DEFAULT_RULES",
    "AxisRules",
    "logical_to_spec",
    "shard_params_tree",
    "spec_tree_for",
    "with_logical_constraint",
    "zero1_spec",
    "CompressionState",
    "compress_int8",
    "decompress_int8",
    "init_compression_state",
    "compressed_grad_allreduce",
    "gpipe",
    "stage_params_from_stack",
]
