"""Opt-in pipeline parallelism over the "pod" axis (GPipe schedule).

The baseline multi-pod plan treats "pod" as pure data parallelism (the
DCN link only carries the gradient all-reduce, optionally int8-
compressed). For models whose *weights* exceed one pod's aggregate HBM,
this module provides the alternative: the layer stack is split into
``n_stages`` contiguous stages (one per pod), micro-batches stream
through the stages, and only stage-boundary activations cross the slow
link — O(micro_batch x d_model) per tick instead of O(grad bytes).

Implementation: ``shard_map`` over the pipeline axis. Each stage holds
its layer shard; a GPipe schedule runs ``n_micro + n_stages - 1`` ticks
with ``lax.ppermute`` moving boundary activations stage -> stage+1.
Bubble fraction = (n_stages - 1) / (n_micro + n_stages - 1) — choose
n_micro >> n_stages. Forward-only here (inference / evaluation path);
training through the pipeline composes with jax.grad because every op
(ppermute included) is differentiable, at the cost of storing per-tick
activations (use remat around ``body`` for long pipelines).
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map


def gpipe(body: Callable, mesh: Mesh, axis: str, n_micro: int):
    """Build a pipelined apply: (stage_params, x) -> y.

    ``body(stage_params, x_mb) -> y_mb`` is one stage's computation on
    one micro-batch (same output shape as input). ``stage_params``
    leaves must have a leading stage dimension of size
    ``mesh.shape[axis]``; ``x``'s leading batch dim must divide by
    ``n_micro``.
    """
    n_stages = mesh.shape[axis]

    def pipelined(stage_params, x):
        b = x.shape[0]
        mb = b // n_micro
        mbs = x.reshape(n_micro, mb, *x.shape[1:])

        def local(params_local, mbs_local):
            # params_local: this stage's shard (leading dim 1) -> squeeze
            params_local = jax.tree.map(lambda p: p[0], params_local)
            stage = jax.lax.axis_index(axis)
            fwd = [(i, i + 1) for i in range(n_stages - 1)]

            carry = jnp.zeros_like(mbs_local[0])
            outs = jnp.zeros_like(mbs_local)
            for t in range(n_micro + n_stages - 1):
                # stage 0 injects micro-batch t; others take the wire
                inject = mbs_local[jnp.minimum(t, n_micro - 1)]
                inp = jnp.where(stage == 0, inject, carry)
                out = body(params_local, inp)
                # last stage commits micro-batch t - (n_stages - 1)
                oi = t - (n_stages - 1)
                commit = jnp.logical_and(stage == n_stages - 1, oi >= 0)
                outs = jax.lax.cond(
                    commit,
                    lambda o: o.at[jnp.maximum(oi, 0)].set(out),
                    lambda o: o,
                    outs)
                carry = jax.lax.ppermute(out, axis, fwd)
            # broadcast the last stage's outputs to every stage member
            mask = (stage == n_stages - 1).astype(outs.dtype)
            return jax.lax.psum(outs * mask, axis)

        spec_params = jax.tree.map(lambda _: P(axis), stage_params)
        y = shard_map(local, mesh=mesh,
                      in_specs=(spec_params, P()),
                      out_specs=P(),
                      check_rep=False)(stage_params, mbs)
        return y.reshape(b, *x.shape[1:])

    return pipelined


def stage_params_from_stack(params_stacked, n_stages: int):
    """[L, ...] layer-stacked params -> [n_stages, L/n_stages, ...]."""
    def split(p):
        l = p.shape[0]
        if l % n_stages:
            raise ValueError(f"layers {l} % stages {n_stages} != 0")
        return p.reshape(n_stages, l // n_stages, *p.shape[1:])
    return jax.tree.map(split, params_stacked)
