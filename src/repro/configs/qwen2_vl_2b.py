"""qwen2-vl-2b [vlm] — 28L d_model=1536 12H (GQA kv=2) d_ff=8960
vocab=151936, M-RoPE. [arXiv:2409.12191; hf]

The vision frontend is a STUB per the task spec: ``input_specs``
provides precomputed patch embeddings added to the token embeddings.
M-RoPE splits the 64 frequency bands (head_dim 128) into (t, h, w) =
(16, 24, 24) sections. 12 heads do not divide the 16-way model axis ->
sequence-parallel attention (like yi-34b).
"""
import jax.numpy as jnp

from repro.configs.registry import ArchConfig, register
from repro.models.lm import LMConfig

CONFIG = register(ArchConfig(
    arch_id="qwen2-vl-2b",
    family="vlm",
    module="lm",
    model=LMConfig(
        name="qwen2-vl-2b",
        n_layers=28, d_model=1536, n_heads=12, n_kv_heads=2, head_dim=128,
        d_ff=8960, vocab=151936, rope_theta=1000000.0,
        mrope_sections=(16, 24, 24), remat="full",
        tie_embeddings=True,
    ),
    rule_overrides={"act_heads": (), "act_seq_attn": ("model",)},
    frontend="vision",
    smoke=LMConfig(
        name="qwen2-vl-smoke",
        n_layers=2, d_model=48, n_heads=3, n_kv_heads=1, head_dim=16,
        d_ff=96, vocab=512, vocab_pad_multiple=16,
        mrope_sections=(4, 2, 2),
        param_dtype=jnp.float32,
    ),
    notes="M-RoPE; 12 heads !% 16 -> seq-parallel attention; "
          "vision frontend stubbed; long_500k skipped",
))
