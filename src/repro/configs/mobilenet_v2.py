"""MobileNet-V2 @224 (ImageNet) — the paper's second evaluation workload."""
from repro.models.cnn import CNNConfig, reduced_config

CONFIG = CNNConfig(arch="mobilenet_v2", n_classes=1000, in_hw=224)
SMOKE = reduced_config("mobilenet_v2")
