"""seamless-m4t-large-v2 [audio] — 24L d_model=1024 16H (GQA kv=16)
d_ff=8192 vocab=256206, encoder-decoder. [arXiv:2308.11596; hf]

24 encoder + 24 decoder layers. The speech frontend is a STUB per the
task spec: ``input_specs`` provides precomputed frame embeddings
[B, S_src, d_model]. vocab 256206 is padded to 256256 for even 16-way
sharding of the embedding/logit matrices (logits sliced back).
"""
import jax.numpy as jnp

from repro.configs.registry import ArchConfig, register
from repro.models.encdec import EncDecConfig

CONFIG = register(ArchConfig(
    arch_id="seamless-m4t-large-v2",
    family="audio",
    module="encdec",
    model=EncDecConfig(
        name="seamless-m4t-large-v2",
        n_enc_layers=24, n_dec_layers=24, d_model=1024, n_heads=16,
        n_kv_heads=16, head_dim=64, d_ff=8192, vocab=256206,
        remat="full",
    ),
    frontend="audio",
    smoke=EncDecConfig(
        name="seamless-smoke",
        n_enc_layers=2, n_dec_layers=2, d_model=48, n_heads=4, n_kv_heads=2,
        head_dim=12, d_ff=96, vocab=512, vocab_pad_multiple=16,
        param_dtype=jnp.float32,
    ),
    notes="enc-dec; audio frontend stubbed; decode = decoder step with "
          "cross-attention to encoder memory; long_500k skipped",
))
