"""qwen3-8b [dense] — 36L d_model=4096 32H (GQA kv=8) d_ff=12288
vocab=151936, qk_norm. [hf:Qwen/Qwen3-8B; hf]"""
import jax.numpy as jnp

from repro.configs.registry import ArchConfig, register
from repro.models.lm import LMConfig

CONFIG = register(ArchConfig(
    arch_id="qwen3-8b",
    family="dense",
    module="lm",
    model=LMConfig(
        name="qwen3-8b",
        n_layers=36, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
        d_ff=12288, vocab=151936, rope_theta=1000000.0, qk_norm=True,
        remat="full",
    ),
    smoke=LMConfig(
        name="qwen3-8b-smoke",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=512, vocab_pad_multiple=16, qk_norm=True,
        param_dtype=jnp.float32,
    ),
    notes="qk_norm after head split; full attention -> long_500k skipped",
))
