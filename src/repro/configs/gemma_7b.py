"""gemma-7b [dense] — 28L d_model=3072 16H (GQA kv=16) d_ff=24576
vocab=256000, GeGLU, head_dim=256. [arXiv:2403.08295; hf]"""
import jax.numpy as jnp

from repro.configs.registry import ArchConfig, register
from repro.models.lm import LMConfig

CONFIG = register(ArchConfig(
    arch_id="gemma-7b",
    family="dense",
    module="lm",
    model=LMConfig(
        name="gemma-7b",
        n_layers=28, d_model=3072, n_heads=16, n_kv_heads=16, head_dim=256,
        d_ff=24576, vocab=256000, act="gelu", remat="full",
        tie_embeddings=True,
    ),
    smoke=LMConfig(
        name="gemma-7b-smoke",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=32,
        d_ff=192, vocab=512, vocab_pad_multiple=16, act="gelu",
        param_dtype=jnp.float32,
    ),
    notes="GeGLU MLP, MHA (kv=16); full attention -> long_500k skipped",
))
