"""deepseek-v2-236b [moe] — 60L d_model=5120 128H, MLA kv_lora=512,
d_ff=1536 (per expert), 2 shared + 160 routed top-6, vocab=102400.
[arXiv:2405.04434; hf]

MLA decode uses the absorbed compressed-cache form (cache is
[B, S, kv_lora + rope] per layer — the MLA memory win). First layer is
dense (d_ff 12288), remaining 59 are MoE, as in the released model.
"""
import jax.numpy as jnp

from repro.configs.registry import ArchConfig, register
from repro.models.layers import MoEConfig
from repro.models.lm import LMConfig, MLAConfig

CONFIG = register(ArchConfig(
    arch_id="deepseek-v2-236b",
    family="moe",
    module="lm",
    model=LMConfig(
        name="deepseek-v2-236b",
        n_layers=60, d_model=5120, n_heads=128, n_kv_heads=128, head_dim=128,
        d_ff=1536, vocab=102400,
        mla=MLAConfig(kv_lora=512, q_lora=1536, qk_nope_dim=128,
                      qk_rope_dim=64, v_dim=128),
        moe=MoEConfig(n_experts=160, top_k=6, d_ff=1536, n_shared=2,
                      group_size=512),
        n_dense_prefix=1, d_ff_dense=12288,
        remat="full",
    ),
    smoke=LMConfig(
        name="deepseek-v2-smoke",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=96, vocab=512, vocab_pad_multiple=16,
        mla=MLAConfig(kv_lora=32, q_lora=48, qk_nope_dim=16, qk_rope_dim=8,
                      v_dim=16),
        moe=MoEConfig(n_experts=8, top_k=2, d_ff=96, n_shared=1,
                      group_size=64),
        n_dense_prefix=1, d_ff_dense=128,
        param_dtype=jnp.float32,
    ),
    notes="MLA + 2 shared + 160 routed top-6; long_500k skipped",
))
