"""qwen3-moe-235b-a22b [moe] — 94L d_model=4096 64H (GQA kv=4)
d_ff=1536 (per expert) vocab=151936, MoE 128e top-8.
[hf:Qwen/Qwen3-30B-A3B (scaled); hf]"""
import jax.numpy as jnp

from repro.configs.registry import ArchConfig, register
from repro.models.layers import MoEConfig
from repro.models.lm import LMConfig

CONFIG = register(ArchConfig(
    arch_id="qwen3-moe-235b-a22b",
    family="moe",
    module="lm",
    model=LMConfig(
        name="qwen3-moe-235b-a22b",
        n_layers=94, d_model=4096, n_heads=64, n_kv_heads=4, head_dim=128,
        d_ff=1536, vocab=151936, rope_theta=1000000.0, qk_norm=True,
        moe=MoEConfig(n_experts=128, top_k=8, d_ff=1536, group_size=512),
        remat="full",
    ),
    smoke=LMConfig(
        name="qwen3-moe-smoke",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=96, vocab=512, vocab_pad_multiple=16, qk_norm=True,
        moe=MoEConfig(n_experts=8, top_k=2, d_ff=96, group_size=64),
        param_dtype=jnp.float32,
    ),
    notes="all layers MoE (128e top-8); full attention -> long_500k skipped",
))
