"""ResNet-18 @224 (ImageNet) — the paper's primary evaluation workload.

Not part of the LM arch pool; used by the paper-reproduction benchmarks
(Tables 3-5, Figs. 5-12) and by the end-to-end QAT training example.
"""
from repro.models.cnn import CNNConfig, reduced_config

CONFIG = CNNConfig(arch="resnet18", n_classes=1000, in_hw=224)
SMOKE = reduced_config("resnet18")
