"""yi-34b [dense] — 60L d_model=7168 56H (GQA kv=8) d_ff=20480
vocab=64000, llama-arch GQA. [arXiv:2403.04652; hf]

56 heads do not divide the 16-way model axis, so attention activations
use *sequence parallelism* instead of head sharding (the projection
weights stay 2-D sharded over (data, model) — only the score compute is
partitioned along the query sequence). See DESIGN.md §5.
"""
import jax.numpy as jnp

from repro.configs.registry import ArchConfig, register
from repro.models.lm import LMConfig

CONFIG = register(ArchConfig(
    arch_id="yi-34b",
    family="dense",
    module="lm",
    model=LMConfig(
        name="yi-34b",
        n_layers=60, d_model=7168, n_heads=56, n_kv_heads=8, head_dim=128,
        d_ff=20480, vocab=64000, rope_theta=5000000.0, remat="full",
    ),
    rule_overrides={"act_heads": (), "act_seq_attn": ("model",)},
    smoke=LMConfig(
        name="yi-34b-smoke",
        n_layers=2, d_model=56, n_heads=7, n_kv_heads=1, head_dim=8,
        d_ff=160, vocab=512, vocab_pad_multiple=16,
        param_dtype=jnp.float32,
    ),
    notes="56 heads !% 16 -> sequence-parallel attention; long_500k skipped",
))
