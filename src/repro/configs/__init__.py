"""Architecture configs (one module per assigned arch) + registry."""
from repro.configs.registry import (
    SHAPES,
    ArchConfig,
    ShapeSpec,
    get,
    list_archs,
    register,
)

__all__ = ["SHAPES", "ArchConfig", "ShapeSpec", "get", "list_archs",
           "register"]
