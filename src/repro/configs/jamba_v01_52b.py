"""jamba-v0.1-52b [hybrid] — 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=65536, MoE 16e top-2, Mamba+attn 1:7 interleave.
[arXiv:2403.19887; hf]

Sub-quadratic (only 4 of 32 layers carry a KV cache): runs long_500k.
SSM head layout: d_inner = 2*d_model = 8192, head_dim 64 -> 128 SSD
heads, d_state 64 (Jamba v0.1 uses Mamba-1 with N=16; we keep the SSD
formulation of this framework with a larger state — noted in DESIGN.md).
"""
import jax.numpy as jnp

from repro.configs.registry import ArchConfig, register
from repro.models.hybrid import HybridConfig
from repro.models.layers import MoEConfig
from repro.models.ssm import SSMConfig

CONFIG = register(ArchConfig(
    arch_id="jamba-v0.1-52b",
    family="hybrid",
    module="hybrid",
    model=HybridConfig(
        name="jamba-v0.1-52b",
        n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
        d_ff=14336, vocab=65536,
        ssm=SSMConfig(d_model=4096, d_inner=8192, head_dim=64, d_state=64,
                      n_groups=1, conv_kernel=4, chunk=256),
        moe=MoEConfig(n_experts=16, top_k=2, d_ff=14336, group_size=512),
        remat="full",
    ),
    skip_shapes=(),                      # sub-quadratic: runs long_500k
    smoke=HybridConfig(
        name="jamba-smoke",
        n_layers=8, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=512, vocab_pad_multiple=16,
        ssm=SSMConfig(d_model=64, d_inner=128, head_dim=16, d_state=16,
                      n_groups=1, chunk=32),
        moe=MoEConfig(n_experts=4, top_k=2, d_ff=96, group_size=64),
        param_dtype=jnp.float32,
    ),
    notes="1:7 attn:mamba, MoE every 2nd layer; runs long_500k",
))
