"""Architecture registry: configs, shapes, sharding-rule overrides.

Every assigned architecture registers an ``ArchConfig`` binding its
exact published model config to one of the model-zoo modules, the shape
set it runs, per-arch sharding-rule overrides, and a reduced same-family
smoke config for CPU tests.

Shape semantics (task spec):
  train_4k     seq 4096,   global_batch 256  -> train_step
  prefill_32k  seq 32768,  global_batch 32   -> prefill (forward, no loss)
  decode_32k   seq 32768,  global_batch 128  -> serve_step (1 new token,
                                                KV cache of seq_len)
  long_500k    seq 524288, global_batch 1    -> serve_step; only for the
               sub-quadratic archs (jamba, mamba2); the eight pure
               full-attention archs skip it (recorded in DESIGN.md).
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Any


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                          # train | prefill | decode
    rule_overrides: dict = dataclasses.field(default_factory=dict)


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec(
        "decode_32k", 32768, 128, "decode",
        rule_overrides={"kv_seq": ("model",), "act_kv_heads": ()}),
    "long_500k": ShapeSpec(
        "long_500k", 524288, 1, "decode",
        rule_overrides={"kv_seq": ("data", "model"), "act_kv_heads": ()}),
}


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    arch_id: str
    family: str                        # dense | moe | ssm | hybrid | audio | vlm
    model: Any                         # LMConfig / SSMLMConfig / ...
    module: str                        # repro.models.{lm,ssm,hybrid,encdec}
    rule_overrides: dict = dataclasses.field(default_factory=dict)
    frontend: str | None = None        # audio | vision (stubbed embeddings)
    skip_shapes: tuple[str, ...] = ("long_500k",)
    smoke: Any = None                  # reduced same-family config
    notes: str = ""

    def model_module(self):
        return importlib.import_module(f"repro.models.{self.module}")

    def shapes(self) -> list[ShapeSpec]:
        return [s for n, s in SHAPES.items() if n not in self.skip_shapes]


_REGISTRY: dict[str, ArchConfig] = {}


def register(cfg: ArchConfig) -> ArchConfig:
    if cfg.arch_id in _REGISTRY:
        raise ValueError(f"duplicate arch {cfg.arch_id}")
    _REGISTRY[cfg.arch_id] = cfg
    return cfg


def get(arch_id: str) -> ArchConfig:
    _load_all()
    if arch_id not in _REGISTRY:
        raise KeyError(f"unknown arch {arch_id!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[arch_id]


def list_archs() -> list[str]:
    _load_all()
    return sorted(_REGISTRY)


_ARCH_MODULES = [
    "jamba_v01_52b",
    "seamless_m4t_large_v2",
    "yi_34b",
    "gemma_7b",
    "llama32_1b",
    "qwen3_8b",
    "mamba2_780m",
    "qwen3_moe_235b_a22b",
    "deepseek_v2_236b",
    "qwen2_vl_2b",
]

_loaded = False


def _load_all():
    global _loaded
    if _loaded:
        return
    _loaded = True
    for m in _ARCH_MODULES:
        importlib.import_module(f"repro.configs.{m}")
