"""llama3.2-1b [dense] — 16L d_model=2048 32H (GQA kv=8) d_ff=8192
vocab=128256. [hf:meta-llama/Llama-3.2-1B; unverified]"""
import jax.numpy as jnp

from repro.configs.registry import ArchConfig, register
from repro.models.lm import LMConfig

CONFIG = register(ArchConfig(
    arch_id="llama3.2-1b",
    family="dense",
    module="lm",
    model=LMConfig(
        name="llama3.2-1b",
        n_layers=16, d_model=2048, n_heads=32, n_kv_heads=8, head_dim=64,
        d_ff=8192, vocab=128256, rope_theta=500000.0, remat="full",
        tie_embeddings=True,
    ),
    smoke=LMConfig(
        name="llama3.2-1b-smoke",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=512, vocab_pad_multiple=16, rope_theta=500000.0,
        param_dtype=jnp.float32,
    ),
    notes="small llama3; full attention -> long_500k skipped",
))
