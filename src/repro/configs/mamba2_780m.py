"""mamba2-780m [ssm] — 48L d_model=1536 (attention-free) vocab=50280,
ssm_state=128, SSD. [arXiv:2405.21060; unverified]

Attention-free: runs long_500k (O(1) per-token decode state). The
paper's technique applies to the in/out projections (GEMM-level, not
attention-level); the SSD scan itself is not a GEMM and is not split —
recorded in DESIGN.md §Arch-applicability.
"""
import jax.numpy as jnp

from repro.configs.registry import ArchConfig, register
from repro.models.ssm import SSMConfig, SSMLMConfig

CONFIG = register(ArchConfig(
    arch_id="mamba2-780m",
    family="ssm",
    module="ssm",
    model=SSMLMConfig(
        name="mamba2-780m",
        n_layers=48, d_model=1536, vocab=50280,
        ssm=SSMConfig(d_model=1536, d_inner=3072, head_dim=64, d_state=128,
                      n_groups=1, conv_kernel=4, chunk=256),
        tie_embeddings=True, remat="full",
    ),
    skip_shapes=(),                      # sub-quadratic: runs long_500k
    smoke=SSMLMConfig(
        name="mamba2-780m-smoke",
        n_layers=2, d_model=64, vocab=512, vocab_pad_multiple=16,
        ssm=SSMConfig(d_model=64, d_inner=128, head_dim=16, d_state=32,
                      n_groups=1, chunk=32),
        param_dtype=jnp.float32,
    ),
    notes="attention-free SSD; runs all four shapes incl. long_500k",
))
