"""Dataset-scale accuracy validation of compiled CNN programs.

Closes the accuracy loop the per-batch bit-exactness tests leave open:
a compiled program being bit-identical across backends says nothing
about how far the *quantized pipeline itself* drifts from the fp32
network. This module evaluates that drift at dataset scale:

  1. an fp32 reference with **frozen norms**
     (``models.cnn.calibrate_norms`` — the data-dependent RMS statistic
     pinned on one calibration batch, so the reference is a per-sample
     function like the accelerator);
  2. the frozen norm **folded into effective weights**
     (``models.cnn.fold_inference_weights`` — the BN-fold the deployed
     accelerator applies, since compiled programs carry no norm op);
  3. the folded weights quantized with the paper's filter-wise hybrid
     split (first ``n_lut`` output columns at the layer's LUT
     bit-width, the rest int4) and bound to a compiled executor;
  4. both evaluated over ``data.SyntheticImages`` and compared by
     **top-1 agreement** — the fraction of samples where the compiled
     int pipeline picks the same class as the fp32 reference.

Filter allocation note: the KL-divergence permutation of
``quant.hybrid.kl_filter_allocation`` reorders a layer's *output
channels*. The compiled chain's spatial staging and fused elementwise
residual adds read producer segments in natural channel order, so a
permuted layer would need every consumer's input channels (and both
operands of every residual add) permuted to match. Deployment
therefore uses the **identity allocation** — the Eq.-12 split still
holds (first ``n_lut`` filters are LUT-core), only the sensitivity
ordering inside the split is forfeited.

``make_accuracy_fn`` packages the whole loop as a
``fn(program) -> agreement_pct`` callable for the DSE evaluator, which
re-scores elite configurations with *measured* accuracy instead of the
analytical :class:`~repro.dse.env.AccuracyProxy`.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.scheduler import (
    XC7Z020,
    DspCoreConfig,
    FPGADevice,
    LutCoreConfig,
    simulate_program,
)
from repro.core.workloads import ConvSpec
from repro.data.synthetic import SyntheticImages
from repro.models import cnn
from repro.models.cnn import CNNConfig, specs_for
from repro.quant.uniform import fit_scale, fit_scale_per_channel, qrange

#: Documented top-1 agreement floor for the default harness operating
#: point (reduced-geometry nets, 8-bit activations, 8-bit first/last
#: layers, hybrid w4-LUT/int4-DSP middle layers, SNR-3 synthetic data).
#: The CI ``accuracy`` job gates on it; ``accuracy_eval.py`` exits
#: nonzero below it.
AGREEMENT_FLOOR = 0.95


@dataclasses.dataclass(frozen=True)
class AccuracyReport:
    """One dataset-scale agreement measurement."""
    arch: str
    backend: str
    n_samples: int
    agreement: float            # fraction in [0, 1]
    top1_compiled: float        # vs the synthetic labels
    top1_ref: float
    latency_ms: float | None    # simulated, single sample
    sim_cycles: int | None
    w_bits: int
    a_bits: int
    ratio: float

    def bench_row(self) -> dict:
        """The ``accuracy.eval`` BENCH blob (Table 4/5 companion row:
        measured agreement next to the simulated latency)."""
        return {
            "BENCH": "accuracy.eval",
            "network": self.arch,
            "backend": self.backend,
            "n_samples": self.n_samples,
            "agreement": round(self.agreement, 4),
            "top1_compiled": round(self.top1_compiled, 4),
            "top1_ref": round(self.top1_ref, 4),
            "agreement_floor": AGREEMENT_FLOOR,
            "meets_floor": bool(self.agreement >= AGREEMENT_FLOOR),
            "latency_ms": None if self.latency_ms is None
            else round(self.latency_ms, 4),
            "sim_cycles": self.sim_cycles,
            "w_bits": self.w_bits,
            "a_bits": self.a_bits,
            "ratio": self.ratio,
        }


# ---------------------------------------------------------------------------
# Reference model
# ---------------------------------------------------------------------------


def train_params(cfg: CNNConfig, steps: int = 200, batch: int = 64,
                 lr: float = 0.05, momentum: float = 0.9, seed: int = 0,
                 snr: float = 3.0) -> dict:
    """Train the fp32 network on the synthetic task (SGD + momentum).

    Agreement between a compiled quantized pipeline and an *untrained*
    network is meaningless: random-init logits have near-zero margins,
    so even sub-percent quantization noise flips argmax on most
    samples. A short training run saturates the separable synthetic
    task and opens real margins — then agreement measures quantization
    damage, not coin flips.

    Norm biases are pinned at zero throughout so the trained norm stays
    foldable into pure weight gains
    (:func:`~repro.models.cnn.fold_inference_weights`).
    """
    params = cnn.init(cfg, jax.random.PRNGKey(seed))
    ds = SyntheticImages(cfg.n_classes, batch, cfg.in_hw, seed=seed,
                         snr=snr, sample_seed=seed)
    vel = jax.tree_util.tree_map(jnp.zeros_like, params)

    @jax.jit
    def step(params, vel, x, y):
        loss, g = jax.value_and_grad(
            lambda p: cnn.cross_entropy(cnn.forward(p, x, cfg), y))(params)
        vel = jax.tree_util.tree_map(
            lambda v, gg: momentum * v + gg, vel, g)
        params = jax.tree_util.tree_map(
            lambda p, v: p - lr * v, params, vel)
        for name in params:                      # keep the fold exact
            params[name]["bias"] = jnp.zeros_like(params[name]["bias"])
        return params, vel, loss

    for _ in range(steps):
        b = ds.next_batch()
        params, vel, _loss = step(params, vel, b["images"], b["labels"])
    return params


def build_reference(cfg: CNNConfig, seed: int = 0, calib_batch: int = 64,
                    snr: float = 3.0, train_steps: int = 200):
    """(params, frozen norms, jitted fp32 forward) for one config.

    Trains for ``train_steps`` SGD steps first (``train_steps=0`` skips
    — random init, only useful for plumbing tests). The calibration
    batch comes from the *train*-side sample stream (``sample_seed =
    seed``); evaluation uses a disjoint stream, so the frozen
    statistics are genuinely out-of-sample for the eval set.
    """
    if train_steps:
        params = train_params(cfg, steps=train_steps, seed=seed, snr=snr)
    else:
        params = cnn.init(cfg, jax.random.PRNGKey(seed))
    calib = SyntheticImages(cfg.n_classes, calib_batch, cfg.in_hw,
                            seed=seed, snr=snr, sample_seed=seed)
    norms = cnn.calibrate_norms(params, calib.next_batch()["images"], cfg)
    ref_fn = jax.jit(lambda x: cnn.forward(params, x, cfg, norms=norms))
    return params, norms, ref_fn


# ---------------------------------------------------------------------------
# Folded weights -> quantized [k, n] bindings
# ---------------------------------------------------------------------------


def fold_to_matrix(w_eff: jax.Array, spec: ConvSpec) -> jax.Array:
    """HWIO effective weight -> the [k, n] GEMM matrix the executor
    binds: rows in im2col ``(kh, kw, c_in)`` patch order (dense) or
    ``(kh, kw)`` per channel (depthwise), columns = output filters."""
    if spec.depthwise:
        return jnp.reshape(w_eff, (spec.kernel * spec.kernel, spec.c_out))
    return jnp.reshape(
        w_eff, (spec.kernel * spec.kernel * spec.c_in, spec.c_out))


def quantize_folded_matrix(w_mat: jax.Array, n_lut: int, w_bits_lut: int):
    """Identity-allocation hybrid quantization of one [k, n] matrix:
    first ``n_lut`` columns at ``w_bits_lut``, the rest int4, each with
    per-column max-abs scales. Returns the ``bind_layer`` quadruple
    (``None`` for an empty partition)."""
    n = w_mat.shape[1]

    def _part(cols, bits):
        if cols.shape[1] == 0:
            return None, None
        s = fit_scale_per_channel(cols, bits, axis=1)
        lo, hi = qrange(bits)
        codes = jnp.clip(jnp.round(cols / s), lo, hi).astype(jnp.int32)
        return codes, s.reshape(-1)

    w_lut, s_lut = _part(w_mat[:, :n_lut], w_bits_lut)
    w_dsp, s_dsp = _part(w_mat[:, n_lut:n], 4)
    return w_lut, s_lut, w_dsp, s_dsp


def bind_folded_weights(ex, program, folded: dict,
                        specs: list[ConvSpec]) -> None:
    """Quantize the folded weights to each layer's compiled split
    (``n_lut`` / LUT bit-width come from the program, so the binding
    realizes exactly the design point that was lowered) and bind."""
    for lp, spec in zip(program.layers, specs):
        w_mat = fold_to_matrix(folded[spec.name], spec)
        w_lut, s_lut, w_dsp, s_dsp = quantize_folded_matrix(
            w_mat, lp.n_lut, lp.bits_w_lut)
        ex.bind_layer(lp.index, w_lut=w_lut, s_lut=s_lut,
                      w_dsp=w_dsp, s_dsp=s_dsp)


# ---------------------------------------------------------------------------
# Compile + evaluate
# ---------------------------------------------------------------------------


def compile_quantized_cnn(cfg: CNNConfig, w_bits: int = 4, a_bits: int = 8,
                          ratio: float = 0.5,
                          device: FPGADevice = XC7Z020,
                          lut_cfg: LutCoreConfig | None = None,
                          dsp_cfg: DspCoreConfig | None = None,
                          opt_level: int = 1):
    """Lower ``cfg``'s network at the paper's quantization policy:
    first/last layers 8-bit (all-LUT, so the 8-bit weights fit a
    partition — the DSP core is fixed int4), middle layers hybrid
    ``w_bits``-LUT / int4-DSP at ``ratio``, activations ``a_bits``
    (8-bit first/last). Returns ``(program, specs)``."""
    from repro.compiler.lower import lower_network
    from repro.compiler.program import GemmLayer
    lut_cfg = lut_cfg or LutCoreConfig(m=8, n=16, k=128)
    dsp_cfg = dsp_cfg or DspCoreConfig(
        n_reg_row_a=DspCoreConfig.rows_for_device(device))
    specs = specs_for(cfg)
    layers = [GemmLayer.from_conv(s) for s in specs]
    edge = [s.is_first or s.is_last for s in specs]
    bw = [8 if e else w_bits for e in edge]
    ba = [8 if e else a_bits for e in edge]
    n_luts = [gl.dims.n if e else int(round(ratio * gl.dims.n))
              for gl, e in zip(layers, edge)]
    prog = lower_network(cfg.arch, layers, lut_cfg, dsp_cfg, device,
                         bits_w_lut=bw, bits_a=ba, n_luts=n_luts,
                         opt_level=opt_level)
    return prog, specs


def _batched_runner(ex):
    """jit(vmap) over the executor chain: quantize each image to 8-bit
    codes with its own max-abs scale, run the compiled chain, return
    logits. One trace per program (the per-layer kernels inside are
    already program-cached jits)."""
    lo, hi = qrange(8)

    def one(img):
        s = fit_scale(img, 8)
        x_q = jnp.clip(jnp.round(img / s), lo, hi).astype(jnp.int8)
        return ex.run(x_q, x_scale=s).reshape(-1)   # [1, classes] -> flat

    return jax.jit(jax.vmap(one))


def evaluate_agreement(ex, ref_fn, cfg: CNNConfig, n_samples: int,
                       batch: int = 64, seed: int = 0,
                       snr: float = 3.0) -> dict:
    """Stream ``n_samples`` synthetic images through the compiled
    executor and the fp32 reference; returns raw counts
    (``agree`` / ``correct_compiled`` / ``correct_ref`` / ``total``).

    Deterministic: the eval stream is seeded (``sample_seed = seed +
    10_000``, disjoint from the calibration stream) and both networks
    are pure functions of the sample.
    """
    ds = SyntheticImages(cfg.n_classes, batch, cfg.in_hw, seed=seed,
                         snr=snr, sample_seed=seed + 10_000)
    runner = _batched_runner(ex)
    agree = correct_c = correct_r = total = 0
    while total < n_samples:
        b = ds.next_batch()
        x, labels = b["images"], np.asarray(b["labels"])
        take = min(batch, n_samples - total)
        pred_c = np.asarray(jnp.argmax(runner(x), axis=-1))[:take]
        pred_r = np.asarray(jnp.argmax(ref_fn(x), axis=-1))[:take]
        labels = labels[:take]
        agree += int((pred_c == pred_r).sum())
        correct_c += int((pred_c == labels).sum())
        correct_r += int((pred_r == labels).sum())
        total += take
    return {"agree": agree, "correct_compiled": correct_c,
            "correct_ref": correct_r, "total": total}


def measure(arch: str, n_samples: int = 10_000, batch: int = 64,
            backend: str = "pallas", w_bits: int = 4, a_bits: int = 8,
            ratio: float = 0.5, seed: int = 0, snr: float = 3.0,
            reduced: bool = True, opt_level: int = 1,
            simulate: bool = True, train_steps: int = 200,
            device: FPGADevice = XC7Z020) -> AccuracyReport:
    """End-to-end dataset-scale measurement for one architecture:
    train + freeze the fp32 reference, compile + bind the quantized
    network, evaluate agreement over ``n_samples``, and (optionally)
    simulate the program for the companion latency column."""
    from repro.compiler.runtime import get_backend
    cfg = cnn.reduced_config(arch) if reduced \
        else CNNConfig(arch=arch)
    params, norms, ref_fn = build_reference(cfg, seed=seed, snr=snr,
                                            train_steps=train_steps)
    folded = cnn.fold_inference_weights(params, cfg, norms)
    prog, specs = compile_quantized_cnn(
        cfg, w_bits=w_bits, a_bits=a_bits, ratio=ratio, device=device,
        opt_level=opt_level)
    ex = get_backend(backend)(prog)
    bind_folded_weights(ex, prog, folded, specs)
    counts = evaluate_agreement(ex, ref_fn, cfg, n_samples, batch=batch,
                                seed=seed, snr=snr)
    cycles = latency_ms = None
    if simulate:
        cycles = int(simulate_program(prog).total_cycles)
        latency_ms = device.cycles_to_ms(cycles)
    t = counts["total"]
    return AccuracyReport(
        arch=arch, backend=backend, n_samples=t,
        agreement=counts["agree"] / t,
        top1_compiled=counts["correct_compiled"] / t,
        top1_ref=counts["correct_ref"] / t,
        latency_ms=latency_ms, sim_cycles=cycles,
        w_bits=w_bits, a_bits=a_bits, ratio=ratio)


# ---------------------------------------------------------------------------
# DSE hook
# ---------------------------------------------------------------------------


def make_accuracy_fn(cfg: CNNConfig, n_samples: int = 256,
                     batch: int = 32, seed: int = 0, snr: float = 3.0,
                     backend: str = "pallas", train_steps: int = 200):
    """Package the harness as ``fn(program) -> agreement_pct`` for
    :class:`~repro.dse.evaluator.ProgramEvaluator`: the reference,
    frozen norms and folded fp32 weights are built **once** (they do
    not depend on the searched config); each elite's compiled program
    is then bound with its own quantization of those folded weights
    and scored by measured top-1 agreement (percent, so it slots into
    the Eq.-18 reward where the proxy's accuracy term went).
    """
    from repro.compiler.runtime import get_backend
    cls = get_backend(backend)
    params, norms, ref_fn = build_reference(cfg, seed=seed, snr=snr,
                                            train_steps=train_steps)
    folded = cnn.fold_inference_weights(params, cfg, norms)
    specs = specs_for(cfg)

    def accuracy_fn(program) -> float:
        ex = cls(program)
        bind_folded_weights(ex, program, folded, specs)
        counts = evaluate_agreement(ex, ref_fn, cfg, n_samples,
                                    batch=batch, seed=seed, snr=snr)
        return 100.0 * counts["agree"] / counts["total"]

    return accuracy_fn
