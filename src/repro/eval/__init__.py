"""Dataset-scale validation of compiled programs against their models."""
from repro.eval.accuracy import (
    AGREEMENT_FLOOR,
    AccuracyReport,
    bind_folded_weights,
    build_reference,
    compile_quantized_cnn,
    evaluate_agreement,
    fold_to_matrix,
    make_accuracy_fn,
    quantize_folded_matrix,
)

__all__ = [
    "AGREEMENT_FLOOR",
    "AccuracyReport",
    "bind_folded_weights",
    "build_reference",
    "compile_quantized_cnn",
    "evaluate_agreement",
    "fold_to_matrix",
    "make_accuracy_fn",
    "quantize_folded_matrix",
]
