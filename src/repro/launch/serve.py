"""Serving launcher — the paper's kind of end-to-end driver (inference).

Initializes a model, optionally deploys the paper's hetero-quantization
on every projection (QAT fake-quant path), and serves batched synthetic
requests through prefill + greedy decode, reporting per-phase latency
and token throughput.

  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b \
      --smoke --batch 8 --prompt-len 64 --new-tokens 32

Accelerator program cache: serving hot paths that ship compiled ISA
programs to accelerator workers reuse serialized ``N3HPROG1`` /
``N3HBUND1`` images from an in-process LRU keyed by the full compile
key (arch, device, bits, ratio, opt level, seq len, partition plan)
instead of re-lowering the network per request —
:func:`compiled_program_image` is the single entry point.
"""
from __future__ import annotations

import argparse
import collections
import dataclasses
import threading
import time

import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.data.synthetic import SyntheticTokens
from repro.launch.mesh import make_host_mesh
from repro.models.lm import HeteroQuantConfig
from repro.obs import METRICS
from repro.parallel.sharding import DEFAULT_RULES
from repro.serve.engine import make_cache, make_decode_fn, make_prefill_fn


# ---------------------------------------------------------------------------
# Compiled-program LRU (serving-time N3HPROG1/N3HBUND1 reuse)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ProgramKey:
    """Full compile identity of a servable accelerator program.

    ``mode="fixed"`` is the classic fixed-sequence program;
    ``mode="decode"`` is the decode-resident step program (weights
    resident across invocations, KV/state segments persistent), keyed
    additionally by ``batch`` and ``max_seq``.
    """
    arch: str
    device: str = "XC7Z020"
    bits_w: int = 4
    bits_a: int = 4
    ratio: float | None = None
    opt_level: int = 1
    seq_len: int = 64
    devices: int = 1
    partition: str | None = None
    mode: str = "fixed"
    batch: int = 1
    max_seq: int = 0


class ProgramCache:
    """Thread-safe LRU of compiled program images.

    Values are the serialized images (``N3HPROG1`` for single-device
    keys, ``N3HBUND1`` for multi-device plans) — deterministic and
    bit-exact, so they can be shipped to workers byte-for-byte. A miss
    lowers the network through ``repro.compiler`` once; every further
    request under the same key is a dictionary hit.
    """

    def __init__(self, maxsize: int = 16):
        self.maxsize = maxsize
        self._images: "collections.OrderedDict[ProgramKey, bytes]" = \
            collections.OrderedDict()
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0

    def get(self, key: ProgramKey) -> bytes:
        with self._lock:
            image = self._images.get(key)
            if image is not None:
                self._images.move_to_end(key)
                self.hits += 1
                METRICS.incr("serve.program_cache.hit")
                return image
        t0 = time.time()
        image = self._compile(key)
        METRICS.observe("serve.program_cache.compile_ms",
                        (time.time() - t0) * 1e3)
        with self._lock:
            self.misses += 1
            METRICS.incr("serve.program_cache.miss")
            self._images[key] = image
            while len(self._images) > self.maxsize:
                self._images.popitem(last=False)
        return image

    @staticmethod
    def _compile(key: ProgramKey) -> bytes:
        from repro.compiler import (asm, compile_decode_network,
                                    compile_network)
        if key.mode == "decode":
            prog = compile_decode_network(
                key.arch, batch=key.batch,
                max_seq=key.max_seq or key.seq_len, device=key.device,
                bits_w=key.bits_w, bits_a=key.bits_a, ratio=key.ratio,
                opt_level=key.opt_level, devices=key.devices,
                partition=key.partition)
        else:
            prog = compile_network(
                key.arch, device=key.device, bits_w=key.bits_w,
                bits_a=key.bits_a, ratio=key.ratio, seq_len=key.seq_len,
                opt_level=key.opt_level, devices=key.devices,
                partition=key.partition)
        if hasattr(prog, "devices"):
            return asm.to_bundle_binary(prog)
        return asm.to_binary(prog)

    def info(self) -> dict:
        with self._lock:
            return {"programs": len(self._images), "hits": self.hits,
                    "misses": self.misses, "maxsize": self.maxsize}

    def clear(self) -> None:
        with self._lock:
            self._images.clear()
            self.hits = self.misses = 0


#: process-wide cache; serving code and tests share it.
PROGRAM_CACHE = ProgramCache()


def compiled_program_image(key: ProgramKey) -> bytes:
    """Serialized accelerator program for ``key`` (LRU-cached)."""
    return PROGRAM_CACHE.get(key)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--quantize", action="store_true",
                    help="enable the paper's hybrid quantization on all "
                         "projections (w: 4b LUT-path ratio 0.5, a: 8b)")
    ap.add_argument("--w-bits", type=int, default=4)
    ap.add_argument("--ratio", type=float, default=0.5)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--accel-devices", type=int, default=1,
                    help="accelerator count for the compiled ISA program "
                         "image shipped to workers (--quantize path)")
    ap.add_argument("--accel-partition", choices=("pipeline", "filter"),
                    default=None,
                    help="partition plan for --accel-devices > 1")
    ap.add_argument("--accel-backend", choices=("golden", "pallas"),
                    default="golden",
                    help="executor backend for the compiled decode "
                         "session demo (--quantize path)")
    ap.add_argument("--accel-decode-tokens", type=int, default=4,
                    help="tokens to generate through the compiled "
                         "decode-resident session (--quantize path; "
                         "0 disables the session demo)")
    ap.add_argument("--fleet", type=int, default=0, metavar="N",
                    help="also serve through the distributed fleet: N "
                         "in-process golden workers behind the async "
                         "program server with continuous batching "
                         "(repro.serve.fleet); fleet request/worker "
                         "counters land in the same --metrics export")
    ap.add_argument("--metrics", default=None, metavar="PATH",
                    help="export the run's metrics registry (.json or "
                         ".csv) on exit")
    args = ap.parse_args()

    arch = registry.get(args.arch)
    if args.smoke:
        arch = dataclasses.replace(arch, model=arch.smoke)
    if args.quantize:
        if arch.module != "lm":
            raise SystemExit("--quantize drives the lm family here; other "
                             "families quantize via HeteroLinear directly")
        arch = dataclasses.replace(
            arch, model=dataclasses.replace(
                arch.model, hetero_quant=HeteroQuantConfig(
                    w_bits_lut=args.w_bits, a_bits=8, ratio=args.ratio)))
    mod = arch.model_module()
    rules = DEFAULT_RULES.replace(**arch.rule_overrides)
    mesh = make_host_mesh()
    max_seq = args.prompt_len + args.new_tokens

    with mesh:
        params = mod.init(arch.model, jax.random.key(args.seed))
        data = SyntheticTokens(arch.model.vocab, args.batch,
                               args.prompt_len, seed=args.seed)
        prompts = data.next_batch()["tokens"]
        cache = make_cache(arch, args.batch, max_seq,
                           dtype=arch.model.param_dtype)
        prefill_fn = jax.jit(make_prefill_fn(arch, rules))
        decode_fn = jax.jit(make_decode_fn(arch, rules))

        batch = {"tokens": prompts}
        if arch.module == "encdec":
            batch["frames"] = 0.1 * jax.random.normal(
                jax.random.key(1), (args.batch, args.prompt_len,
                                    arch.model.d_model))
        t0 = time.time()
        logits, cache = prefill_fn(params, batch, cache)
        logits = jax.block_until_ready(logits)
        t_prefill = time.time() - t0
        METRICS.observe("serve.request.prefill_ms", t_prefill * 1e3)

        tok = jnp.argmax(logits[:, -1] if logits.ndim == 3 else logits,
                         axis=-1)[:, None].astype(jnp.int32)
        out = [tok]
        t0 = time.time()
        for i in range(args.new_tokens - 1):
            logits, cache = decode_fn(params, tok, cache,
                                      jnp.int32(args.prompt_len + i))
            tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
            out.append(tok)
        jax.block_until_ready(tok)
        t_decode = time.time() - t0
        METRICS.observe("serve.request.decode_ms", t_decode * 1e3)
        METRICS.observe("serve.request.decode_ms_per_step",
                        t_decode * 1e3 / max(args.new_tokens - 1, 1))

        total_new = args.batch * args.new_tokens
        METRICS.gauge("serve.request.decode_tok_per_s",
                      total_new / max(t_decode, 1e-9))
        if args.quantize:
            # the deployable ISA program for this serving config — the
            # LRU means repeat requests under the same key ship the
            # cached image instead of re-lowering the network
            key = ProgramKey(
                arch=args.arch, bits_w=args.w_bits, bits_a=8,
                ratio=args.ratio, opt_level=1, seq_len=args.prompt_len,
                devices=args.accel_devices,
                partition=args.accel_partition)
            t0 = time.time()
            image = compiled_program_image(key)
            t_img = time.time() - t0
            print(f"# accel program {image[:8].decode()} "
                  f"{len(image)} B in {t_img * 1e3:.1f} ms "
                  f"(cache {PROGRAM_CACHE.info()})")
            # decode-resident step image for the same serving config
            # (weights resident, KV persistent) + a live session demo
            dkey = dataclasses.replace(
                key, mode="decode", batch=1,
                max_seq=min(max_seq, 16), bits_a=4)
            dimage = compiled_program_image(dkey)
            print(f"# accel decode program {dimage[:8].decode()} "
                  f"{len(dimage)} B (batch={dkey.batch} "
                  f"max_seq={dkey.max_seq})")
            if args.accel_decode_tokens > 0:
                from repro.serve.engine import (greedy_generate_compiled,
                                                make_compiled_session)
                session = make_compiled_session(
                    args.arch, backend=args.accel_backend, batch=1,
                    max_seq=dkey.max_seq, bits_w=args.w_bits,
                    seed=args.seed)
                s0 = min(4, dkey.max_seq - args.accel_decode_tokens)
                t0 = time.time()
                toks = greedy_generate_compiled(
                    session, prompts[:1, :s0], args.accel_decode_tokens)
                n_steps = s0 + args.accel_decode_tokens - 1
                t_sess = time.time() - t0
                warm = METRICS.snapshot()["gauges"].get(
                    "serve.decode.warmup_cycles", 0)
                steady = METRICS.snapshot()["gauges"].get(
                    "serve.decode.steady_cycles", 0)
                print(f"# accel decode session [{args.accel_backend}]: "
                      f"{n_steps} steps in {t_sess * 1e3:.1f} ms "
                      f"({n_steps / max(t_sess, 1e-9):.1f} tok/s host), "
                      f"sim {warm:.0f} warm-up / {steady:.0f} steady "
                      f"cycles/token, tokens "
                      f"{list(map(int, toks[0, s0:]))}")
        print(f"# arch={arch.model.name} quantized={args.quantize}")
        print(f"prefill: {t_prefill * 1e3:8.1f} ms "
              f"({args.batch * args.prompt_len / max(t_prefill, 1e-9):.0f} tok/s)")
        print(f"decode:  {t_decode * 1e3:8.1f} ms total, "
              f"{t_decode * 1e3 / max(args.new_tokens - 1, 1):.1f} ms/step, "
              f"{total_new / max(t_decode, 1e-9):.0f} tok/s")
        sample = jnp.concatenate(out, axis=1)[0, :16]
        print("sample tokens:", list(map(int, sample)))
        if args.fleet > 0:
            # distributed-fleet demo: the same decode-resident program,
            # served by N workers with continuous batching. Runs before
            # the --metrics export so the serve.fleet.* request/worker
            # counters land in the same registry file.
            from repro.serve.fleet import FleetServer
            workers = [(f"w{i}", "golden", "thread")
                       for i in range(args.fleet)]
            n_req = 2 * args.fleet + 2
            t0 = time.time()
            with FleetServer(args.arch, workers, batch_slots=2,
                             max_seq=8, seed=args.seed) as fleet:
                rows = [f.result(600) for f in
                        [fleet.submit([3, 11], 3) for _ in range(n_req)]]
            t_fleet = time.time() - t0
            print(f"# fleet[{args.fleet} workers]: {n_req} requests in "
                  f"{t_fleet:.1f} s "
                  f"({n_req / max(t_fleet, 1e-9):.2f} req/s), "
                  f"{METRICS.counter('serve.fleet.steps')} fleet steps, "
                  f"tokens {rows[0].tolist()}")
        if args.metrics:
            METRICS.save(args.metrics)
            print(f"# metrics written to {args.metrics}")


if __name__ == "__main__":
    main()
