"""Serving launcher — the paper's kind of end-to-end driver (inference).

Initializes a model, optionally deploys the paper's hetero-quantization
on every projection (QAT fake-quant path), and serves batched synthetic
requests through prefill + greedy decode, reporting per-phase latency
and token throughput.

  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b \
      --smoke --batch 8 --prompt-len 64 --new-tokens 32
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.data.synthetic import SyntheticTokens
from repro.launch.mesh import make_host_mesh
from repro.models.lm import HeteroQuantConfig
from repro.parallel.sharding import DEFAULT_RULES
from repro.serve.engine import make_cache, make_decode_fn, make_prefill_fn


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--quantize", action="store_true",
                    help="enable the paper's hybrid quantization on all "
                         "projections (w: 4b LUT-path ratio 0.5, a: 8b)")
    ap.add_argument("--w-bits", type=int, default=4)
    ap.add_argument("--ratio", type=float, default=0.5)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    arch = registry.get(args.arch)
    if args.smoke:
        arch = dataclasses.replace(arch, model=arch.smoke)
    if args.quantize:
        if arch.module != "lm":
            raise SystemExit("--quantize drives the lm family here; other "
                             "families quantize via HeteroLinear directly")
        arch = dataclasses.replace(
            arch, model=dataclasses.replace(
                arch.model, hetero_quant=HeteroQuantConfig(
                    w_bits_lut=args.w_bits, a_bits=8, ratio=args.ratio)))
    mod = arch.model_module()
    rules = DEFAULT_RULES.replace(**arch.rule_overrides)
    mesh = make_host_mesh()
    max_seq = args.prompt_len + args.new_tokens

    with mesh:
        params = mod.init(arch.model, jax.random.key(args.seed))
        data = SyntheticTokens(arch.model.vocab, args.batch,
                               args.prompt_len, seed=args.seed)
        prompts = data.next_batch()["tokens"]
        cache = make_cache(arch, args.batch, max_seq,
                           dtype=arch.model.param_dtype)
        prefill_fn = jax.jit(make_prefill_fn(arch, rules))
        decode_fn = jax.jit(make_decode_fn(arch, rules))

        batch = {"tokens": prompts}
        if arch.module == "encdec":
            batch["frames"] = 0.1 * jax.random.normal(
                jax.random.key(1), (args.batch, args.prompt_len,
                                    arch.model.d_model))
        t0 = time.time()
        logits, cache = prefill_fn(params, batch, cache)
        logits = jax.block_until_ready(logits)
        t_prefill = time.time() - t0

        tok = jnp.argmax(logits[:, -1] if logits.ndim == 3 else logits,
                         axis=-1)[:, None].astype(jnp.int32)
        out = [tok]
        t0 = time.time()
        for i in range(args.new_tokens - 1):
            logits, cache = decode_fn(params, tok, cache,
                                      jnp.int32(args.prompt_len + i))
            tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
            out.append(tok)
        jax.block_until_ready(tok)
        t_decode = time.time() - t0

        total_new = args.batch * args.new_tokens
        print(f"# arch={arch.model.name} quantized={args.quantize}")
        print(f"prefill: {t_prefill * 1e3:8.1f} ms "
              f"({args.batch * args.prompt_len / max(t_prefill, 1e-9):.0f} tok/s)")
        print(f"decode:  {t_decode * 1e3:8.1f} ms total, "
              f"{t_decode * 1e3 / max(args.new_tokens - 1, 1):.1f} ms/step, "
              f"{total_new / max(t_decode, 1e-9):.0f} tok/s")
        sample = jnp.concatenate(out, axis=1)[0, :16]
        print("sample tokens:", list(map(int, sample)))


if __name__ == "__main__":
    main()
