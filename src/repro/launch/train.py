"""Training launcher: data -> train_step -> checkpoints, fault-tolerant.

The full production path (auto-resume, async checkpoints, straggler
watchdog, gradient compression) on whatever devices exist — the same
code drives a smoke config on this CPU container and the production
mesh on a real pod (the dry-run proves the latter compiles).

  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b \
      --smoke --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax

from repro.checkpoint import CheckpointManager, StepWatchdog
from repro.configs import registry
from repro.data.synthetic import SyntheticTokens
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.parallel.sharding import DEFAULT_RULES
from repro.train.optimizer import AdamWConfig
from repro.train.step import init_train_state, make_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--production-mesh", action="store_true",
                    help="(16,16) mesh — requires 256 devices")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    arch = registry.get(args.arch)
    if args.smoke:
        arch = dataclasses.replace(arch, model=arch.smoke)
    mod = arch.model_module()
    rules = DEFAULT_RULES.replace(**arch.rule_overrides)

    mesh = (make_production_mesh() if args.production_mesh
            else make_host_mesh())
    opt_cfg = AdamWConfig(lr=args.lr, total_steps=args.steps)
    train_step = jax.jit(make_train_step(arch, opt_cfg, rules,
                                         compress_grads=args.compress_grads))

    data = SyntheticTokens(arch.model.vocab, args.batch, args.seq,
                           seed=args.seed)
    mgr = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    dog = StepWatchdog(
        heartbeat_path=(f"{args.ckpt_dir}/heartbeat.json"
                        if args.ckpt_dir else None))

    with mesh:
        params = mod.init(arch.model, jax.random.key(args.seed))
        state = init_train_state(params, compress_grads=args.compress_grads)
        start = 0
        if mgr is not None and mgr.latest_step() is not None:
            start = mgr.latest_step()
            state = mgr.restore(state, step=start)
            print(f"# resumed from checkpoint step {start}")

        t0 = time.time()
        for step in range(start, args.steps):
            dog.start_step(step)
            batch = data.next_batch()
            state, metrics = train_step(state, batch)
            if dog.end_step():
                print(f"# straggler flagged at step {step} "
                      f"({dog.times[-1]:.2f}s vs median "
                      f"{dog.median_step_s():.2f}s)")
            if (step + 1) % args.log_every == 0:
                print(f"step {step + 1:5d}  loss {float(metrics['loss']):.4f}"
                      f"  |g| {float(metrics['grad_norm']):.3f}"
                      f"  lr {float(metrics['lr']):.2e}")
            if mgr is not None and (step + 1) % args.ckpt_every == 0:
                mgr.save(step + 1, state)
        if mgr is not None:
            mgr.save(args.steps, state, blocking=True)
        dt = time.time() - t0
        n = args.steps - start
        print(f"# {n} steps in {dt:.1f}s "
              f"({n * args.batch * args.seq / max(dt, 1e-9):.0f} tok/s)")


if __name__ == "__main__":
    main()
