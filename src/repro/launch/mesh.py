"""Production meshes.

Single pod: (16, 16) over ("data", "model") — 256 chips (one v5e pod).
Multi-pod:  (2, 16, 16) over ("pod", "data", "model") — 512 chips; the
"pod" axis is pure data parallelism across the slow (DCN) links in the
baseline; gradient compression (parallel/compress.py) targets exactly
that axis.

``make_production_mesh`` is a FUNCTION so importing this module never
touches jax device state — only the dry-run (which sets
xla_force_host_platform_device_count=512 before any jax import) and the
real launchers call it.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh() -> jax.sharding.Mesh:
    """Degenerate 1x1 mesh over whatever devices exist (tests, examples)."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))
