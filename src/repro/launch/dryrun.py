import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

This is the proof that the distribution config is coherent without real
hardware: 512 placeholder CPU devices stand in for 2 pods x 256 chips;
``jax.jit(step).lower(**abstract_inputs).compile()`` must succeed for
every cell, and the compiled artifact yields

  * ``memory_analysis()``  — bytes per device (proves it fits HBM),
  * ``cost_analysis()``    — HLO FLOPs / bytes for the roofline terms,
  * the post-SPMD HLO text — collective operand bytes (all-gather /
    all-reduce / reduce-scatter / all-to-all / collective-permute).

Usage:
  python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k
  python -m repro.launch.dryrun --arch all --multi-pod --out dryrun.json
"""
import argparse
import json
import re
import sys
import time
import traceback
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding

from repro.configs import registry
from repro.data.synthetic import make_batch_specs
from repro.launch.mesh import make_production_mesh
from repro.models import layers as mlayers
from repro.parallel.sharding import DEFAULT_RULES, AxisRules, logical_to_spec
from repro.train.optimizer import OptState
from repro.train.step import TrainState, make_train_step


# ---------------------------------------------------------------------------
# Abstract (ShapeDtypeStruct) inputs with shardings attached
# ---------------------------------------------------------------------------


def resolve_rules(arch: registry.ArchConfig,
                  shape: registry.ShapeSpec) -> AxisRules:
    return DEFAULT_RULES.replace(**arch.rule_overrides,
                                 **shape.rule_overrides)


def _shard_struct(spec_tree: Any, mesh, rules: AxisRules) -> Any:
    """ParamSpec tree -> ShapeDtypeStruct tree with NamedShardings."""
    def one(s: mlayers.ParamSpec):
        pspec = logical_to_spec(s.axes, mesh, rules, shape=s.shape)
        return jax.ShapeDtypeStruct(s.shape, s.dtype,
                                    sharding=NamedSharding(mesh, pspec))
    return jax.tree.map(one, spec_tree,
                        is_leaf=lambda x: isinstance(x, mlayers.ParamSpec))


def _shard_batch(batch_specs: dict, mesh, rules: AxisRules) -> dict:
    out = {}
    for k, s in batch_specs.items():
        axes = ("batch",) + (None,) * (len(s.shape) - 1)
        pspec = logical_to_spec(axes, mesh, rules, shape=s.shape)
        out[k] = jax.ShapeDtypeStruct(s.shape, s.dtype,
                                      sharding=NamedSharding(mesh, pspec))
    return out


def abstract_train_state(arch: registry.ArchConfig, mesh, rules: AxisRules
                         ) -> TrainState:
    mod = arch.model_module()
    pspecs = mod.param_specs(arch.model)
    params = _shard_struct(pspecs, mesh, rules)
    f32 = jax.tree.map(
        lambda s: mlayers.ParamSpec(s.shape, s.axes, jnp.float32, s.init,
                                    s.fan_in),
        pspecs, is_leaf=lambda x: isinstance(x, mlayers.ParamSpec))
    moments = _shard_struct(f32, mesh, rules)
    scalar = jax.ShapeDtypeStruct(
        (), jnp.int32, sharding=NamedSharding(
            mesh, logical_to_spec((), mesh, rules)))
    return TrainState(
        params=params,
        opt=OptState(m=moments,
                     v=jax.tree.map(lambda x: x, moments),
                     count=scalar),
        step=scalar, compress=None)


def abstract_cache(arch: registry.ArchConfig, shape: registry.ShapeSpec,
                   mesh, rules: AxisRules) -> Any:
    mod = arch.model_module()
    b, s = shape.global_batch, shape.seq_len
    if arch.module == "ssm":
        cspecs = mod.cache_specs(arch.model, b)
    elif arch.module == "encdec":
        cspecs = mod.cache_specs(arch.model, b, max_tgt=s, src=s)
    else:
        cspecs = mod.cache_specs(arch.model, b, s)
    return _shard_struct(cspecs, mesh, rules)


# ---------------------------------------------------------------------------
# Step builders per shape kind
# ---------------------------------------------------------------------------


def build_step(arch: registry.ArchConfig, shape: registry.ShapeSpec,
               mesh, rules: AxisRules):
    """Returns (fn, abstract_args) ready for jit(fn).lower(*args)."""
    mod = arch.model_module()
    cfg = arch.model

    if shape.kind == "train":
        step = make_train_step(arch, rules=rules)
        state = abstract_train_state(arch, mesh, rules)
        batch = _shard_batch(make_batch_specs(arch, shape), mesh, rules)
        return step, (state, batch)

    if shape.kind == "prefill":
        batch = _shard_batch(make_batch_specs(arch, shape), mesh, rules)
        if arch.module == "lm":
            cache = abstract_cache(arch, shape, mesh, rules)

            def prefill_step(params, batch, cache):
                logits, cache = mod.prefill(
                    params, batch["tokens"], cache, cfg, rules,
                    extra_embed=batch.get("extra_embed"), last_only=True)
                return logits, cache

            mparams = _shard_struct(mod.param_specs(cfg), mesh, rules)
            return prefill_step, (mparams, batch, cache)

        def fwd_step(params, batch):
            if arch.module == "encdec":
                logits, _ = mod.forward(params, batch["frames"],
                                        batch["tokens"], cfg, rules,
                                        last_only=True)
            else:
                logits, _ = mod.forward(params, batch["tokens"], cfg, rules,
                                        extra_embed=batch.get("extra_embed"),
                                        last_only=True)
            return logits

        mparams = _shard_struct(mod.param_specs(cfg), mesh, rules)
        return fwd_step, (mparams, batch)

    # decode: one token against a cache of seq_len
    batch = _shard_batch(make_batch_specs(arch, shape), mesh, rules)
    cache = abstract_cache(arch, shape, mesh, rules)
    pos = jax.ShapeDtypeStruct((), jnp.int32, sharding=NamedSharding(
        mesh, logical_to_spec((), mesh, rules)))

    def serve_step(params, token, cache, pos):
        return mod.decode_step(params, token, cache, pos, cfg, rules)

    mparams = _shard_struct(mod.param_specs(cfg), mesh, rules)
    return serve_step, (mparams, batch["token"], cache, pos)


# ---------------------------------------------------------------------------
# Collective-bytes HLO parser
# ---------------------------------------------------------------------------

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1,
                "f8e5m2": 1, "s64": 8, "u64": 8, "s32": 4, "u32": 4,
                "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
                "c64": 8, "c128": 16}

_COLL_RE = re.compile(
    r"=\s*(\([^)]*\)|\S+)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(sig: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(sig):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Sum result-shape bytes of every collective op in post-SPMD HLO.
    ``-done`` ops are skipped (the ``-start`` carries the shape)."""
    out: dict[str, int] = {}
    for m in _COLL_RE.finditer(hlo_text):
        sig, op, _ = m.group(1), m.group(2), m.group(3)
        out[op] = out.get(op, 0) + _shape_bytes(sig)
    return out


# ---------------------------------------------------------------------------
# One cell
# ---------------------------------------------------------------------------


def _reduced_model(arch: registry.ArchConfig, n_scan: int = 2):
    """Same config with the layer scan shortened to ``n_scan`` steps and
    fully unrolled — the second point of the two-point cost fit."""
    import dataclasses as _dc
    m = arch.model
    if arch.module == "hybrid":
        small = _dc.replace(m, n_layers=n_scan * 8, scan_unroll=True)
        real_trips, small_trips = m.n_periods, n_scan
    elif arch.module == "encdec":
        small = _dc.replace(m, n_enc_layers=n_scan, n_dec_layers=n_scan,
                            scan_unroll=True)
        # enc and dec scale together; use the (equal) layer counts
        real_trips, small_trips = m.n_enc_layers, n_scan
    else:
        prefix = getattr(m, "n_dense_prefix", 0)
        small = _dc.replace(m, n_layers=prefix + n_scan, scan_unroll=True)
        real_trips = m.n_layers - prefix
        small_trips = n_scan
    return _dc.replace(arch, model=small), real_trips, small_trips


def _compile_once(arch, shape, mesh, rules):
    with mesh:
        fn, args = build_step(arch, shape, mesh, rules)
        t0 = time.time()
        lowered = jax.jit(fn).lower(*args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    coll = collective_bytes(compiled.as_text())
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "coll": coll,
        "mem": compiled.memory_analysis(),
        "t_lower": t_lower,
        "t_compile": t_compile,
    }


def run_cell(arch_id: str, shape_name: str, multi_pod: bool = False,
             verbose: bool = True, fit_costs: bool = True) -> dict:
    """Lower+compile one (arch, shape, mesh) cell and derive its costs.

    XLA HLO cost analysis visits a while-loop body ONCE regardless of
    trip count, so a scanned L-layer model reports ~1/L of its FLOPs.
    With ``fit_costs`` we therefore compile twice — the full scanned
    program (F1 = C_body + C_outside, and the *real* memory picture)
    and a 2-layer fully-unrolled variant (F2 = 2*C_body + C_outside) —
    and report  total = F1 + (L_scan - 1) * (F2 - F1),  which is exact
    for per-layer-homogeneous stacks. Collective bytes are fitted the
    same way (the while body's collectives also appear once).
    """
    arch = registry.get(arch_id)
    shape = registry.SHAPES[shape_name]
    if shape_name in arch.skip_shapes:
        return {"arch": arch_id, "shape": shape_name, "status": "skipped",
                "reason": "full-attention arch skips long_500k"}

    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = resolve_rules(arch, shape)
    n_chips = mesh.devices.size

    if shape.kind == "decode":
        # decode graphs are small: compile fully unrolled — exact costs,
        # no extrapolation (the two-point fit amplifies XLA noise when
        # per-layer FLOPs are tiny).
        import dataclasses as _dc
        arch = _dc.replace(arch, model=_dc.replace(arch.model,
                                                   scan_unroll=True))
        fit_costs = False

    full = _compile_once(arch, shape, mesh, rules)

    rec = {
        "arch": arch_id,
        "shape": shape_name,
        "mesh": list(mesh.devices.shape),
        "status": "ok",
        "n_chips": n_chips,
        "lower_s": round(full["t_lower"], 1),
        "compile_s": round(full["t_compile"], 1),
        "flops_per_device_scanned": full["flops"],
        "bytes_per_device_scanned": full["bytes"],
    }

    if fit_costs:
        small_arch, trips, small_trips = _reduced_model(arch)
        small = _compile_once(small_arch, shape, mesh, rules)
        scale = (trips - small_trips + 1)  # F1 + (L-1)(F2-F1) when small=2
        d_flops = small["flops"] - full["flops"]
        d_bytes = small["bytes"] - full["bytes"]
        rec["flops_per_device"] = full["flops"] + (trips - 1) * d_flops
        rec["bytes_per_device"] = full["bytes"] + (trips - 1) * d_bytes
        coll = {}
        keys = set(full["coll"]) | set(small["coll"])
        for k in keys:
            f1 = full["coll"].get(k, 0)
            f2 = small["coll"].get(k, 0)
            coll[k] = int(max(0, f1 + (trips - 1) * (f2 - f1)))
        rec["collective_bytes_per_device"] = coll
        rec["collective_bytes_total"] = int(sum(coll.values()))
        del scale
    else:
        rec["flops_per_device"] = full["flops"]
        rec["bytes_per_device"] = full["bytes"]
        rec["collective_bytes_per_device"] = {
            k: int(v) for k, v in full["coll"].items()}
        rec["collective_bytes_total"] = int(sum(full["coll"].values()))

    mem = full["mem"]
    if mem is not None:
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes"):
            v = getattr(mem, k, None)
            if v is not None:
                rec[f"mem_{k}"] = int(v)
    if verbose:
        print(json.dumps(rec))
        sys.stdout.flush()
    return rec


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    archs = registry.list_archs() if args.arch == "all" else [args.arch]
    shapes = (list(registry.SHAPES) if args.shape == "all"
              else [args.shape])

    records = []
    failures = 0
    for a in archs:
        for s in shapes:
            try:
                records.append(run_cell(a, s, multi_pod=args.multi_pod))
            except Exception as e:  # noqa: BLE001 — report, keep going
                failures += 1
                traceback.print_exc()
                records.append({"arch": a, "shape": s, "status": "error",
                                "error": f"{type(e).__name__}: {e}"})
    if args.out:
        with open(args.out, "w") as f:
            json.dump(records, f, indent=1)
    ok = sum(1 for r in records if r["status"] == "ok")
    sk = sum(1 for r in records if r["status"] == "skipped")
    print(f"# dry-run: {ok} ok, {sk} skipped, {failures} failed "
          f"(mesh={'2x16x16' if args.multi_pod else '16x16'})")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
