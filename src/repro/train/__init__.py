"""Training substrate: optimizer, loss, train-step factory."""
from repro.train.optimizer import (
    AdamWConfig,
    OptState,
    adamw_init,
    adamw_update,
    clip_by_global_norm,
    cosine_schedule,
)
from repro.train.step import TrainState, make_train_step, train_state_axes

__all__ = [
    "AdamWConfig", "OptState", "adamw_init", "adamw_update",
    "clip_by_global_norm", "cosine_schedule",
    "TrainState", "make_train_step", "train_state_axes",
]
