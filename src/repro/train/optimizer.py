"""AdamW with ZeRO-sharded state, in pure JAX (no optax dependency).

Moments are allocated congruent with the parameters, so under the
FSDP-style sharding rules (big weights sharded over both the data and
model axes) the optimizer state is fully sharded — the ZeRO-3 memory
profile falls out of the logical-axis rules with no extra machinery.
``zero1_spec`` (parallel/sharding.py) additionally shards the moments of
any leaf that is replicated over the data axis.

Moments are fp32 regardless of parameter dtype (bf16 Adam moments
diverge); the parameter update happens in fp32 and is cast back.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_ratio: float = 0.1


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class OptState:
    m: Any
    v: Any
    count: jax.Array


def adamw_init(params: Any) -> OptState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return OptState(m=jax.tree.map(zeros, params),
                    v=jax.tree.map(zeros, params),
                    count=jnp.zeros((), jnp.int32))


def cosine_schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    decay_steps = jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1)
    frac = jnp.clip((step - cfg.warmup_steps) / decay_steps, 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * \
        (1.0 + jnp.cos(jnp.pi * frac))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def global_norm(tree: Any) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads: Any, max_norm: float
                        ) -> tuple[Any, jax.Array]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), grads), norm


def adamw_update(params: Any, grads: Any, opt: OptState, cfg: AdamWConfig
                 ) -> tuple[Any, OptState, dict]:
    """One AdamW step. Returns (new_params, new_opt, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    count = opt.count + 1
    lr = cosine_schedule(cfg, count)
    b1c = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** count.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m_new = cfg.b1 * m + (1 - cfg.b1) * g32
        v_new = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g32)
        mhat = m_new / b1c
        vhat = v_new / b2c
        step = mhat / (jnp.sqrt(vhat) + cfg.eps)
        # decoupled weight decay on matrices only (ndim >= 2)
        if p.ndim >= 2:
            step = step + cfg.weight_decay * p.astype(jnp.float32)
        p_new = (p.astype(jnp.float32) - lr * step).astype(p.dtype)
        return p_new, m_new, v_new

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(opt.m)
    flat_v = jax.tree.leaves(opt.v)
    out = [upd(p, g, m, v) for p, g, m, v
           in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, OptState(m=new_m, v=new_v, count=count), metrics
