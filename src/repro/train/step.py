"""Train-step factory: loss, grads, compression, AdamW — one jit-able.

``make_train_step(arch_cfg)`` builds a function

    train_step(state: TrainState, batch: dict) -> (TrainState, metrics)

that works for every model family in the zoo (the batch dict carries
whatever the family needs: tokens, frames, patch embeddings). The loss
is next-token cross entropy plus the MoE auxiliary losses.

Optional distributed-optimization features (all jit-safe):
  * gradient compression with error feedback (int8, cross-pod) —
    ``compress_grads=True`` threads a residual through TrainState;
  * remat comes from the model config (scan-level checkpointing);
  * ZeRO/FSDP sharding falls out of the logical-axis rules.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.registry import ArchConfig
from repro.parallel.compress import (
    CompressionState,
    compressed_grad_allreduce,
    init_compression_state,
)
from repro.parallel.sharding import AxisRules, DEFAULT_RULES
from repro.train.optimizer import AdamWConfig, OptState, adamw_init, adamw_update


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TrainState:
    params: Any
    opt: OptState
    step: jax.Array
    compress: CompressionState | None = None


def init_train_state(params: Any, compress_grads: bool = False) -> TrainState:
    return TrainState(
        params=params,
        opt=adamw_init(params),
        step=jnp.zeros((), jnp.int32),
        compress=init_compression_state(params) if compress_grads else None)


def train_state_axes(param_axes: Any) -> TrainState:
    """Logical-axes tree congruent with TrainState (for shardings)."""
    scalar = ()
    return TrainState(
        params=param_axes,
        opt=OptState(m=param_axes, v=param_axes, count=scalar),
        step=scalar,
        compress=None)


def next_token_loss(logits: jax.Array, tokens: jax.Array,
                    vocab: int | None = None) -> jax.Array:
    """Mean CE of logits[:, :-1] predicting tokens[:, 1:].

    Sharding-aware formulation (two measured fixes on gemma train_4k):
      * slicing padded logits[..., :vocab] over a GSPMD-sharded vocab
        dim all-gathers the FULL fp32 logits (67 GB/step/device) — the
        caller passes PADDED logits and ``vocab``; padded columns are
        masked with an elementwise where (shard-local);
      * ``take_along_axis`` over the sharded vocab also gathers — the
        one-hot einsum form stays sharded (XLA fuses the iota compare
        into the reduction; nothing materializes).
    """
    lg = logits[:, :-1].astype(jnp.float32)
    if vocab is not None and vocab < lg.shape[-1]:
        pad_mask = jnp.arange(lg.shape[-1]) < vocab
        lg = jnp.where(pad_mask[None, None], lg, -1e30)
    tgt = tokens[:, 1:]
    log_z = jax.nn.logsumexp(lg, axis=-1)                 # sharded reduce
    one_hot = jax.nn.one_hot(tgt, lg.shape[-1], dtype=jnp.float32)
    correct = jnp.sum(lg * one_hot, axis=-1)              # fused, sharded
    return jnp.mean(log_z - correct)


def make_loss_fn(arch: ArchConfig, rules: AxisRules = DEFAULT_RULES
                 ) -> Callable:
    mod = arch.model_module()
    cfg = arch.model

    def loss_fn(params, batch):
        if arch.module == "encdec":
            logits, aux = mod.forward(params, batch["frames"],
                                      batch["tokens"], cfg, rules,
                                      slice_vocab=False)
        else:
            extra = batch.get("extra_embed")
            logits, aux = mod.forward(params, batch["tokens"], cfg, rules,
                                      extra_embed=extra, slice_vocab=False)
        loss = next_token_loss(logits, batch["tokens"], vocab=cfg.vocab)
        return loss + 0.01 * aux, {"ce": loss, "aux": aux}

    return loss_fn


def make_train_step(arch: ArchConfig, opt_cfg: AdamWConfig = AdamWConfig(),
                    rules: AxisRules = DEFAULT_RULES,
                    compress_grads: bool = False) -> Callable:
    loss_fn = make_loss_fn(arch, rules)

    def train_step(state: TrainState, batch: dict
                   ) -> tuple[TrainState, dict]:
        (loss, parts), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(state.params, batch)

        compress_state = state.compress
        if compress_grads and compress_state is not None:
            grads, compress_state = compressed_grad_allreduce(
                grads, compress_state)

        params, opt, opt_metrics = adamw_update(state.params, grads,
                                                state.opt, opt_cfg)
        metrics = {"loss": loss, **parts, **opt_metrics}
        new_state = TrainState(params=params, opt=opt,
                               step=state.step + 1,
                               compress=compress_state)
        return new_state, metrics

    return train_step
