"""Golden-model interpreter for compiled Programs.

Executes a :class:`~repro.compiler.program.Program` *functionally*: the
instruction streams drive real data movement and tile GEMMs against the
reference numerics of ``kernels/ref.py`` — bitplane (bit-serial)
arithmetic for LUT-core partitions, packed-int4 for DSP-core partitions
— so the result is bit-exact against ``core/hetero_linear.py``'s
deployed integer path on the same codes/scales.

The interpreter enforces the ISA contract along the way:

  * Fetch instructions must address the layer's DDR segments from the
    program's memory map (weights at ``L{i}.wgt.{core}``, activations
    at the previous layer's output segment);
  * every Execute must only consume weight tiles a prior Fetch brought
    on chip, and the tile count must cover the partition exactly;
  * Result instructions place output tiles by their DDR offset and must
    tile the output without overlap;
  * the sync-token protocol is validated by running the event-driven
    scheduler over the same streams (a deadlock there is an executor
    error here).

Depthwise layers are latency-modeled by the scheduler but have no
functional GEMM semantics in the executor yet (each output channel sees
a different im2col slice); ``run_layer`` raises for them.
"""
from __future__ import annotations

import dataclasses
import math

import jax.numpy as jnp

from repro.core import isa
from repro.core.scheduler import simulate
from repro.kernels import ops as kops
from repro.kernels import ref as kref
from repro.quant.uniform import fit_scale, qrange
from repro.compiler.program import (
    CORE_NAMES,
    CoreProgram,
    LayerProgram,
    Program,
)


class ExecutionError(RuntimeError):
    """An instruction stream violated the ISA/program contract."""


@dataclasses.dataclass
class LayerWeights:
    """Integer weight codes + per-column dequant scales for one layer,
    already split: LUT (bit-serial) columns first, DSP (int4) columns
    after — the same column order ``hetero_gemm_ref`` concatenates."""
    w_lut: jnp.ndarray | None      # [k, n_lut] int32 codes
    s_lut: jnp.ndarray | None      # [n_lut] fp32
    w_dsp: jnp.ndarray | None      # [k, n_dsp] int32 codes (int4 range)
    s_dsp: jnp.ndarray | None      # [n_dsp] fp32


class GoldenExecutor:
    """Functional interpreter over a compiled program."""

    def __init__(self, program: Program, check_timing: bool = True):
        self.program = program
        self.check_timing = check_timing
        self._weights: dict[int, LayerWeights] = {}

    # -- weight binding ----------------------------------------------------

    def bind_layer(self, index: int, w_lut=None, s_lut=None,
                   w_dsp=None, s_dsp=None) -> None:
        lp = self.program.layers[index]
        k, n_lut, n_dsp = lp.dims.k, lp.n_lut, lp.dims.n - lp.n_lut

        def _chk(w, s, n, what, bits):
            if n == 0:
                if w is not None:
                    raise ValueError(f"layer {index} has no {what} partition")
                return None, None
            w = jnp.asarray(w, jnp.int32)
            s = jnp.asarray(s, jnp.float32).reshape(-1)
            if w.shape != (k, n) or s.shape != (n,):
                raise ValueError(
                    f"layer {index} {what} weights must be [{k},{n}] "
                    f"(+[{n}] scales), got {w.shape}/{s.shape}")
            lo, hi = qrange(bits)
            if int(w.min()) < lo or int(w.max()) > hi:
                raise ValueError(f"layer {index} {what} codes exceed "
                                 f"{bits}-bit range [{lo},{hi}]")
            return w, s

        w_lut, s_lut = _chk(w_lut, s_lut, n_lut, "lut", lp.bits_w_lut)
        w_dsp, s_dsp = _chk(w_dsp, s_dsp, n_dsp, "dsp", 4)
        self._weights[index] = LayerWeights(w_lut, s_lut, w_dsp, s_dsp)

    def bind_deployed(self, index: int, deployed) -> None:
        """Bind from a ``hetero_linear.DeployedHeteroLinear`` (its column
        order is already LUT-first, matching the program split)."""
        lp = self.program.layers[index]
        self.bind_layer(
            index,
            w_lut=deployed.wq_serial if lp.n_lut else None,
            s_lut=deployed.s_serial if lp.n_lut else None,
            w_dsp=deployed.wq_parallel if lp.n_dsp else None,
            s_dsp=deployed.s_parallel if lp.n_dsp else None)

    # -- execution ---------------------------------------------------------

    def run_layer(self, index: int, x_q) -> jnp.ndarray:
        """Execute one layer's streams on int8 activations ``x_q`` [m, k].

        Returns fp32 [m, n] in split column order (LUT partition first),
        i.e. exactly ``kernels.ref.hetero_gemm_ref``'s layout.
        """
        lp = self.program.layers[index]
        if lp.depthwise:
            raise NotImplementedError(
                "depthwise layers have no functional executor semantics")
        if index not in self._weights:
            raise ExecutionError(f"layer {index} has no bound weights")
        x_q = jnp.asarray(x_q, jnp.int8)
        if x_q.shape != (lp.dims.m, lp.dims.k):
            raise ExecutionError(
                f"activations must be [{lp.dims.m},{lp.dims.k}], "
                f"got {x_q.shape}")
        wts = self._weights[index]

        outs = []
        if lp.lut is not None:
            outs.append(self._run_core(lp, lp.lut, x_q, wts.w_lut, wts.s_lut))
        if lp.dsp is not None:
            outs.append(self._run_core(lp, lp.dsp, x_q, wts.w_dsp, wts.s_dsp))
        return jnp.concatenate(outs, axis=1) if len(outs) > 1 else outs[0]

    def run(self, x_q) -> jnp.ndarray:
        """Chain all layers (FC-style networks whose GEMMs compose:
        n_i == k_{i+1}). Activations are requantized to each layer's
        ``bits_a`` between layers, as the hardware writes them back."""
        out = None
        for lp in self.program.layers:
            if out is not None:
                if out.shape[1] != lp.dims.k or out.shape[0] != lp.dims.m:
                    raise ExecutionError(
                        f"layer {lp.index} expects [{lp.dims.m},{lp.dims.k}] "
                        f"activations but layer {lp.index - 1} produced "
                        f"{tuple(out.shape)}; run_layer() drives "
                        f"non-chaining (conv) programs layer by layer")
                s_a = fit_scale(out, lp.bits_a)
                lo, hi = qrange(lp.bits_a)
                x_q = jnp.clip(jnp.round(out / s_a), lo, hi).astype(jnp.int8)
            out = self.run_layer(lp.index, x_q)
        return out

    # -- core interpretation ----------------------------------------------

    def _segments(self, lp: LayerProgram, core_name: str):
        mem = self.program.memory
        wgt = mem[f"L{lp.index}.wgt.{core_name}"]
        act = mem["act.in"] if lp.index == 0 else mem[f"L{lp.index - 1}.out"]
        out = mem[f"L{lp.index}.out"]
        return wgt, act, out

    def _run_core(self, lp: LayerProgram, cp: CoreProgram, x_q,
                  w_codes, w_scales) -> jnp.ndarray:
        if self.check_timing:
            try:
                simulate(cp.streams, cp.sim_tokens())
            except RuntimeError as e:
                raise ExecutionError(
                    f"layer {lp.index} {CORE_NAMES[cp.core]} streams deadlock: {e}"
                ) from e

        core_name = CORE_NAMES[cp.core]
        g_n = w_codes.shape[1]
        if core_name == "lut":
            tm, tn = self.program.lut_cfg.m, self.program.lut_cfg.n
            bits = lp.bits_w_lut
        else:
            tm, tn = self.program.dsp_cfg.n_reg_row_a, \
                self.program.dsp_cfg.n_reg_col_w
            bits = 4
        m = lp.dims.m
        nt_m = math.ceil(m / tm)
        nt_n = math.ceil(g_n / tn)
        wgt_seg, act_seg, out_seg = self._segments(lp, core_name)

        # 1. Fetch stream: record what lands on chip, check addressing.
        fetched_wtiles: set[int] = set()
        n_wgt_fetches = 0
        act_loaded = False
        for op in cp.streams["fetch"]:
            i = op.instr
            if not isinstance(i, isa.FetchInstr):
                continue
            if i.stage_ctrl == 0:                    # weight tile / wall
                if i.ddr_base != wgt_seg.base:
                    raise ExecutionError(
                        f"L{lp.index} {core_name}: weight fetch addresses "
                        f"{i.ddr_base:#x}, expected segment "
                        f"{wgt_seg.name}@{wgt_seg.base:#x}")
                n_wgt_fetches += 1
                fetched_wtiles.add(i.ddr_offset)
            elif i.stage_ctrl == 1:                  # activations
                if i.ddr_base != act_seg.base:
                    raise ExecutionError(
                        f"L{lp.index} {core_name}: activation fetch addresses "
                        f"{i.ddr_base:#x}, expected segment "
                        f"{act_seg.name}@{act_seg.base:#x}")
                act_loaded = True
            else:
                raise ExecutionError(
                    f"L{lp.index} {core_name}: fetch stage_ctrl="
                    f"{i.stage_ctrl} is not a defined buffer stage")
        if not act_loaded:
            raise ExecutionError(
                f"L{lp.index} {core_name}: no activation fetch in stream")
        # DSP whole-weight residency: a single stage-0 fetch at offset 0
        # DMAs the entire weight matrix, covering every column tile.
        if core_name == "dsp" and n_wgt_fetches == 1 and 0 in fetched_wtiles:
            fetched_wtiles.update(range(nt_n))

        # 2. Execute stream: tile GEMMs through the reference numerics.
        tiles: dict[int, jnp.ndarray] = {}
        t = 0
        for op in cp.streams["execute"]:
            i = op.instr
            if not isinstance(i, isa.ExecuteInstr):
                continue
            if core_name == "lut":
                j, ti = divmod(t, nt_m)              # column-major schedule
            else:
                ti, j = divmod(t, nt_n)              # row-major schedule
            if j not in fetched_wtiles:
                raise ExecutionError(
                    f"L{lp.index} {core_name}: execute consumes weight tile "
                    f"{j} before any fetch brought it on chip")
            r0, r1 = ti * tm, min((ti + 1) * tm, m)
            c0, c1 = j * tn, min((j + 1) * tn, g_n)
            if core_name == "lut":
                tile = kref.bitserial_gemm_ref(
                    x_q[r0:r1], w_codes[:, c0:c1], w_scales[c0:c1], bits)
            else:
                tile = kops.int4_matmul(
                    x_q[r0:r1], w_codes[:, c0:c1], w_scales[c0:c1],
                    mode="ref")
            tiles[(j * nt_m + ti) if core_name == "lut"
                  else (ti * nt_n + j)] = tile
            t += 1
        if t != nt_m * nt_n:
            raise ExecutionError(
                f"L{lp.index} {core_name}: {t} execute instructions do not "
                f"tile the [{m},{g_n}] partition ({nt_m}x{nt_n} expected)")

        # 3. Result stream: drain tiles to the output DDR segment.
        out = jnp.zeros((m, g_n), jnp.float32)
        placed: set[int] = set()
        for op in cp.streams["result"]:
            i = op.instr
            if not isinstance(i, isa.ResultInstr):
                continue
            if i.ddr_base != out_seg.base:
                raise ExecutionError(
                    f"L{lp.index} {core_name}: result writes {i.ddr_base:#x},"
                    f" expected segment {out_seg.name}@{out_seg.base:#x}")
            off = i.ddr_offset
            if off in placed:
                raise ExecutionError(
                    f"L{lp.index} {core_name}: result tile {off} written "
                    f"twice")
            if off not in tiles:
                raise ExecutionError(
                    f"L{lp.index} {core_name}: result drains tile {off} "
                    f"which was never executed")
            placed.add(off)
            if core_name == "lut":
                j, ti = divmod(off, nt_m)
            else:
                ti, j = divmod(off, nt_n)
            r0, r1 = ti * tm, min((ti + 1) * tm, m)
            c0, c1 = j * tn, min((j + 1) * tn, g_n)
            out = out.at[r0:r1, c0:c1].set(tiles[off])
        if len(placed) != nt_m * nt_n:
            raise ExecutionError(
                f"L{lp.index} {core_name}: result stream drained "
                f"{len(placed)}/{nt_m * nt_n} tiles")
        return out
