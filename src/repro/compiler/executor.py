"""Back-compat shim — the executor moved to ``repro.compiler.runtime``.

The golden interpreter now lives in ``runtime/golden.py`` behind the
:class:`~repro.compiler.runtime.base.ExecutorBackend` interface, next
to the batched Pallas fast path (``runtime/pallas.py``). Import from
``repro.compiler.runtime`` (or ``repro.compiler``) in new code; this
module keeps the historical import path working but warns on import
and will be removed once external callers have migrated.
"""
import warnings

warnings.warn(
    "repro.compiler.executor is deprecated; import from "
    "repro.compiler.runtime (or repro.compiler) instead",
    DeprecationWarning, stacklevel=2)

from repro.compiler.runtime import (
    BACKENDS,
    ExecutionError,
    ExecutorBackend,
    GoldenExecutor,
    LayerWeights,
    PallasExecutor,
    UnsupportedLayerError,
    get_backend,
)

__all__ = [
    "BACKENDS", "ExecutionError", "ExecutorBackend", "GoldenExecutor",
    "LayerWeights", "PallasExecutor", "UnsupportedLayerError",
    "get_backend",
]
