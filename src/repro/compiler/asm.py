"""Assembler / disassembler: Program ↔ text assembly ↔ binary image.

Both directions are bit-exact: ``assemble(disassemble(p)) == p`` and
``from_binary(to_binary(p)) == p``, and re-assembling a disassembled
text (or re-packing a parsed binary) is byte-identical because both
renderers are canonical.

Text syntax (one instruction per line, ``@N`` is the timing closure in
cycles — the scheduler's cycle model evaluated at lowering time):

    .program resnet18
    .device name=XC7Z020 luts=53200 ... freq_mhz=100.0
    .lutcfg m=8 n=16 k=128 ...
    .dspcfg n_reg_row_a=13 ...
    .segment L0.wgt.lut base=0x40 size=1176
    .layer 0 name=conv1 m=12544 k=147 n=64 n_lut=16 bits_w=4 bits_a=4 dw=0
    .core lut tokens=lut.wslot:1 fetched=2352.0 written=50176.0
    .stream fetch
        FETCH  lut buf=0x0 stage=0 half=0 ddr=0x40 off=0 len=1176 @106
        SEND   lut fetch->execute lut.wtile @1
    .stream execute
        WAIT   lut fetch->execute lut.act @1
        EXEC   lut a=0x0 w=0x0 m=8 k=147 n=16 bw=4 ba=4 acc=0 @84
    .stream result
        WAIT   lut execute->result lut.res @1
        RESULT lut buf=0x0 stage=2 half=0 ddr=0x4c0 off=0 len=8 @33

Sync channel names never need to be stated redundantly — they are
recoverable from the 3-bit ``token_flag`` via the per-core tables in
``program.py`` — but the text spells them out for readability.

Fused DMA bursts (``passes.DmaFusionPass``, -O1) carry their tile
count in the ``buf`` (``onchip_base``) operand of Fetch/Result lines —
canonical streams render ``buf=0x0`` there, a fused pair ``buf=0x2`` —
so optimized programs round-trip through both renderers unchanged.

The binary image is ``N3HPROG1`` + a canonical-JSON metadata section
(program/device/core configs, memory map, per-layer metadata) followed
by the packed streams: per (layer, core, engine) a u32 instruction
count then ``count`` records of 16-byte little-endian ISA word + u32
cycles.

Multi-device bundles (``compiler/partition.py``) pack as ``N3HBUND1``:
a canonical-JSON header (name, partition plan, cross-device channel
edge table) followed by one length-prefixed ``N3HPROG1`` section per
device, so a bundle round-trips bit-exactly iff every per-device
program does.
"""
from __future__ import annotations

import dataclasses
import json
import struct

from repro.core import isa
from repro.core.scheduler import (
    DspCoreConfig,
    FPGADevice,
    GemmDims,
    LutCoreConfig,
    Op,
)
from repro.compiler.program import (
    CHANNEL_FLAGS,
    CORE_NAMES,
    ENGINES,
    ConvGeometry,
    CoreProgram,
    ElementwiseOp,
    LayerProgram,
    MemoryMap,
    Program,
    StepSpec,
    channel_of,
)

MAGIC = b"N3HPROG1"
MAGIC_BUNDLE = b"N3HBUND1"

_ENGINE_BY_NAME = {"fetch": isa.Engine.FETCH, "execute": isa.Engine.EXECUTE,
                   "result": isa.Engine.RESULT}
_CORE_BY_NAME = {"lut": isa.CoreSel.LUT, "dsp": isa.CoreSel.DSP}


# ---------------------------------------------------------------------------
# Instruction <-> text line
# ---------------------------------------------------------------------------


def format_instr(op: Op) -> str:
    """One canonical assembly line for a timed instruction."""
    i = op.instr
    cn = CORE_NAMES[i.core]
    if isinstance(i, (isa.FetchInstr, isa.ResultInstr)):
        mn = "FETCH " if isinstance(i, isa.FetchInstr) else "RESULT"
        body = (f"{mn} {cn} buf={i.onchip_base:#x} stage={i.stage_ctrl} "
                f"half={i.onchip_range} ddr={i.ddr_base:#x} "
                f"off={i.ddr_offset} len={i.ddr_range}")
    elif isinstance(i, isa.ExecuteInstr):
        body = (f"EXEC   {cn} a={i.buf_addr_a:#x} w={i.buf_addr_w:#x} "
                f"m={i.tile_m} k={i.tile_k} n={i.tile_n} "
                f"bw={i.bits_w} ba={i.bits_a} acc={i.accumulate}")
    elif isinstance(i, isa.SyncInstr):
        mn = "WAIT  " if i.is_wait else "SEND  "
        src = i.src_engine.name.lower()
        dst = i.dst_engine.name.lower()
        body = f"{mn} {cn} {src}->{dst} {channel_of(i)}"
    else:  # pragma: no cover
        raise TypeError(f"unknown instruction {i!r}")
    return f"{body} @{op.cycles}"


def _kv(tokens: list[str]) -> dict[str, str]:
    out = {}
    for t in tokens:
        k, _, v = t.partition("=")
        out[k] = v
    return out


def parse_instr(line: str) -> Op:
    """Inverse of :func:`format_instr`."""
    body, _, cyc = line.rpartition("@")
    cycles = int(cyc)
    toks = body.split()
    mn = toks[0]
    core = _CORE_BY_NAME[toks[1]]
    if mn in ("FETCH", "RESULT"):
        kv = _kv(toks[2:])
        cls = isa.FetchInstr if mn == "FETCH" else isa.ResultInstr
        return Op(cls(core=core, onchip_base=int(kv["buf"], 0),
                      stage_ctrl=int(kv["stage"]), onchip_range=int(kv["half"]),
                      ddr_base=int(kv["ddr"], 0), ddr_offset=int(kv["off"]),
                      ddr_range=int(kv["len"])), cycles=cycles)
    if mn == "EXEC":
        kv = _kv(toks[2:])
        return Op(isa.ExecuteInstr(
            core=core, buf_addr_a=int(kv["a"], 0), buf_addr_w=int(kv["w"], 0),
            tile_m=int(kv["m"]), tile_k=int(kv["k"]), tile_n=int(kv["n"]),
            bits_w=int(kv["bw"]), bits_a=int(kv["ba"]),
            accumulate=int(kv["acc"])), cycles=cycles)
    if mn in ("SEND", "WAIT"):
        src, _, dst = toks[2].partition("->")
        ch = toks[3]
        flag = CHANNEL_FLAGS[ch]
        is_wait = 1 if mn == "WAIT" else 0
        return Op(isa.SyncInstr(
            core=core, src_engine=_ENGINE_BY_NAME[src],
            dst_engine=_ENGINE_BY_NAME[dst], cur_state=is_wait,
            next_state=min(3, flag), token_flag=flag, is_wait=is_wait),
            cycles=cycles, channel=ch)
    raise ValueError(f"unparseable instruction line: {line!r}")


# ---------------------------------------------------------------------------
# Conv geometry (de)serialization (shared by text and binary forms)
# ---------------------------------------------------------------------------

#: positional field order of the compact geometry record
_GEOM_FIELDS = ("kernel", "stride", "pad", "in_hw", "out_hw", "c_in",
                "c_out", "src_offset", "pool")


def _geom_record(geom: ConvGeometry | None) -> list | None:
    if geom is None:
        return None
    return [getattr(geom, f) for f in _GEOM_FIELDS]


def _geom_from_record(rec) -> ConvGeometry | None:
    if rec is None:
        return None
    vals = dict(zip(_GEOM_FIELDS, rec))
    vals["pool"] = str(vals["pool"])
    return ConvGeometry(**{f: (int(v) if f != "pool" else v)
                           for f, v in vals.items()})


def _fmt_geom(geom: ConvGeometry) -> str:
    """Compact comma-joined positional form for the ``.layer`` line;
    an empty pool renders as ``-``."""
    rec = _geom_record(geom)
    rec[-1] = rec[-1] or "-"
    return ",".join(str(v) for v in rec)


def _parse_geom(text: str) -> ConvGeometry:
    parts = text.split(",")
    if len(parts) != len(_GEOM_FIELDS):
        raise ValueError(f"geometry record needs {len(_GEOM_FIELDS)} "
                         f"fields, got {len(parts)}")
    parts[-1] = "" if parts[-1] == "-" else parts[-1]
    return _geom_from_record(parts)


# ---------------------------------------------------------------------------
# Elementwise tail (de)serialization (shared by text and binary forms)
# ---------------------------------------------------------------------------


def _fmt_ew(ops: tuple) -> str:
    """Compact space-free form for the ``.layer`` line and the binary
    metadata: ``add:2,relu,requant:4`` (the arg is ``src_offset`` for
    ``add`` and ``bits`` for ``requant``)."""
    parts = []
    for op in ops:
        if op.kind == "add":
            parts.append(f"add:{op.src_offset}")
        elif op.kind == "requant":
            parts.append(f"requant:{op.bits}")
        else:
            parts.append(op.kind)
    return ",".join(parts)


def _parse_ew(text: str) -> tuple:
    if not text:
        return ()
    ops = []
    for part in text.split(","):
        kind, _, arg = part.partition(":")
        if kind == "add":
            ops.append(ElementwiseOp("add", src_offset=int(arg)))
        elif kind == "requant":
            ops.append(ElementwiseOp("requant", bits=int(arg)))
        else:
            ops.append(ElementwiseOp(kind))
    return tuple(ops)


# ---------------------------------------------------------------------------
# Config (de)serialization helpers
# ---------------------------------------------------------------------------


def _cfg_fields(cfg) -> dict:
    return {f.name: getattr(cfg, f.name) for f in dataclasses.fields(cfg)}


def _fmt_fields(cfg) -> str:
    return " ".join(f"{k}={v!r}" if isinstance(v, float) else f"{k}={v}"
                    for k, v in _cfg_fields(cfg).items())


def _parse_fields(cls, kv: dict[str, str]):
    args = {}
    for f in dataclasses.fields(cls):
        if f.name not in kv:
            continue
        v = kv[f.name]
        args[f.name] = (v if f.type == "str"
                        else float(v) if "." in v or "e" in v.lower()
                        else int(v))
    return cls(**args)


# ---------------------------------------------------------------------------
# Disassembler
# ---------------------------------------------------------------------------


def disassemble(prog: Program) -> str:
    """Canonical text assembly of a compiled program."""
    out = ["; n3h-core unified-ISA program (repro.compiler)",
           f".program {prog.name}",
           f".device {_fmt_fields(prog.device)}",
           f".lutcfg {_fmt_fields(prog.lut_cfg)}",
           f".dspcfg {_fmt_fields(prog.dsp_cfg)}"]
    if prog.step is not None:
        out.append(f".step {_fmt_fields(prog.step)}")
    for seg in prog.memory.segments:
        res = "" if seg.residency == "io" else f" residency={seg.residency}"
        out.append(f".segment {seg.name} base={seg.base:#x} "
                   f"size={seg.size}{res}")
    for lp in prog.layers:
        geom = "" if lp.geometry is None \
            else f" geom={_fmt_geom(lp.geometry)}"
        ew = "" if not lp.elementwise else f" ew={_fmt_ew(lp.elementwise)}"
        out.append(f".layer {lp.index} name={lp.name} m={lp.dims.m} "
                   f"k={lp.dims.k} n={lp.dims.n} n_lut={lp.n_lut} "
                   f"bits_w={lp.bits_w_lut} bits_a={lp.bits_a} "
                   f"dw={int(lp.depthwise)}{geom}{ew}")
        for cp in lp.cores():
            toks = ",".join(f"{ch}:{n}" for ch, n
                            in sorted(cp.initial_tokens.items()))
            out.append(f".core {CORE_NAMES[cp.core]} tokens={toks} "
                       f"fetched={cp.bytes_fetched!r} "
                       f"written={cp.bytes_written!r}")
            for engine in ENGINES:
                out.append(f".stream {engine}")
                for op in cp.streams[engine]:
                    out.append("    " + format_instr(op))
    return "\n".join(out) + "\n"


# ---------------------------------------------------------------------------
# Assembler
# ---------------------------------------------------------------------------


def assemble(text: str) -> Program:
    """Parse canonical text assembly back into a :class:`Program`."""
    name = "unnamed"
    device = lut_cfg = dsp_cfg = step = None
    memory = MemoryMap()
    layers: list[LayerProgram] = []
    cur_core: CoreProgram | None = None
    cur_stream: list[Op] | None = None

    for ln, raw in enumerate(text.splitlines(), 1):
        line = raw.split(";", 1)[0].strip() if raw.lstrip().startswith(";") \
            else raw.strip()
        if not line:
            continue
        try:
            if line.startswith(".program"):
                name = line.split(None, 1)[1]
            elif line.startswith(".device"):
                device = _parse_fields(FPGADevice, _kv(line.split()[1:]))
            elif line.startswith(".lutcfg"):
                lut_cfg = _parse_fields(LutCoreConfig, _kv(line.split()[1:]))
            elif line.startswith(".dspcfg"):
                dsp_cfg = _parse_fields(DspCoreConfig, _kv(line.split()[1:]))
            elif line.startswith(".step"):
                step = _parse_fields(StepSpec, _kv(line.split()[1:]))
            elif line.startswith(".segment"):
                toks = line.split()
                kv = _kv(toks[2:])
                memory.alloc(toks[1], int(kv["size"]),
                             residency=kv.get("residency", "io"))
                if memory[toks[1]].base != int(kv["base"], 0):
                    raise ValueError(
                        f"segment {toks[1]} base {kv['base']} does not match "
                        f"the canonical bump-allocation order")
            elif line.startswith(".layer"):
                toks = line.split()
                kv = _kv(toks[2:])
                layers.append(LayerProgram(
                    index=int(toks[1]), name=kv["name"],
                    dims=GemmDims(int(kv["m"]), int(kv["k"]), int(kv["n"])),
                    n_lut=int(kv["n_lut"]), bits_w_lut=int(kv["bits_w"]),
                    bits_a=int(kv["bits_a"]), depthwise=bool(int(kv["dw"])),
                    lut=None, dsp=None,
                    geometry=_parse_geom(kv["geom"])
                    if "geom" in kv else None,
                    elementwise=_parse_ew(kv.get("ew", ""))))
                cur_core = cur_stream = None
            elif line.startswith(".core"):
                toks = line.split()
                kv = _kv(toks[2:])
                tokens = {}
                if kv.get("tokens"):
                    for part in kv["tokens"].split(","):
                        ch, _, cnt = part.partition(":")
                        tokens[ch] = int(cnt)
                core = _CORE_BY_NAME[toks[1]]
                cur_core = CoreProgram(
                    core=core, streams={e: [] for e in ENGINES},
                    initial_tokens=tokens,
                    bytes_fetched=float(kv["fetched"]),
                    bytes_written=float(kv["written"]))
                setattr(layers[-1], toks[1], cur_core)
                cur_stream = None
            elif line.startswith(".stream"):
                engine = line.split()[1]
                if cur_core is None:
                    raise ValueError(".stream before .core")
                cur_stream = cur_core.streams[engine]
            else:
                if cur_stream is None:
                    raise ValueError("instruction outside a .stream block")
                cur_stream.append(parse_instr(line))
        except (KeyError, IndexError, ValueError) as e:
            raise ValueError(f"assembly parse error at line {ln}: "
                             f"{raw.strip()!r}: {e}") from e

    if device is None or lut_cfg is None or dsp_cfg is None:
        raise ValueError("assembly is missing .device/.lutcfg/.dspcfg")
    return Program(name=name, device=device, lut_cfg=lut_cfg,
                   dsp_cfg=dsp_cfg, layers=layers, memory=memory,
                   step=step)


# ---------------------------------------------------------------------------
# Binary image
# ---------------------------------------------------------------------------


def to_binary(prog: Program) -> bytes:
    """Pack a program into the ``N3HPROG1`` binary image."""
    meta = {
        "program": prog.name,
        "device": _cfg_fields(prog.device),
        "lut_cfg": _cfg_fields(prog.lut_cfg),
        "dsp_cfg": _cfg_fields(prog.dsp_cfg),
        "segments": [[s.name, s.base, s.size, s.residency]
                     for s in prog.memory.segments],
        "layers": [{
            "index": lp.index, "name": lp.name,
            "dims": [lp.dims.m, lp.dims.k, lp.dims.n],
            "n_lut": lp.n_lut, "bits_w": lp.bits_w_lut, "bits_a": lp.bits_a,
            "dw": int(lp.depthwise),
            "geom": _geom_record(lp.geometry),
            "ew": _fmt_ew(lp.elementwise),
            "cores": [{
                "core": CORE_NAMES[cp.core],
                "tokens": dict(sorted(cp.initial_tokens.items())),
                "fetched": cp.bytes_fetched, "written": cp.bytes_written,
            } for cp in lp.cores()],
        } for lp in prog.layers],
    }
    if prog.step is not None:
        meta["step"] = prog.step.to_meta()
    blob = json.dumps(meta, sort_keys=True,
                      separators=(",", ":")).encode("utf-8")
    parts = [MAGIC, struct.pack("<I", len(blob)), blob]
    for lp in prog.layers:
        for cp in lp.cores():
            for engine in ENGINES:
                ops = cp.streams[engine]
                parts.append(struct.pack("<I", len(ops)))
                for op in ops:
                    parts.append(op.instr.encode().to_bytes(16, "little"))
                    parts.append(struct.pack("<I", op.cycles))
    return b"".join(parts)


def from_binary(data: bytes) -> Program:
    """Unpack an ``N3HPROG1`` image back into a :class:`Program`."""
    try:
        return _parse_binary(data)
    except (struct.error, UnicodeDecodeError) as e:
        raise ValueError(f"corrupt N3HPROG1 image: {e}") from e


def _parse_binary(data: bytes) -> Program:
    if data[:8] != MAGIC:
        raise ValueError("not an N3HPROG1 image")
    (meta_len,) = struct.unpack_from("<I", data, 8)
    pos = 12
    meta = json.loads(data[pos:pos + meta_len].decode("utf-8"))
    pos += meta_len

    device = FPGADevice(**meta["device"])
    lut_cfg = LutCoreConfig(**meta["lut_cfg"])
    dsp_cfg = DspCoreConfig(**meta["dsp_cfg"])
    memory = MemoryMap()
    for rec in meta["segments"]:
        # pre-residency images carry 3-element records; default to "io"
        sname, base, size = rec[:3]
        seg = memory.alloc(sname, size,
                           residency=rec[3] if len(rec) > 3 else "io")
        if seg.base != base:
            raise ValueError(f"segment {sname} base mismatch in image")

    layers = []
    for lm in meta["layers"]:
        lp = LayerProgram(
            index=lm["index"], name=lm["name"],
            dims=GemmDims(*lm["dims"]), n_lut=lm["n_lut"],
            bits_w_lut=lm["bits_w"], bits_a=lm["bits_a"],
            depthwise=bool(lm["dw"]), lut=None, dsp=None,
            geometry=_geom_from_record(lm.get("geom")),
            elementwise=_parse_ew(lm.get("ew", "")))
        for cm in lm["cores"]:
            streams = {}
            for engine in ENGINES:
                (count,) = struct.unpack_from("<I", data, pos)
                pos += 4
                ops = []
                for _ in range(count):
                    word = int.from_bytes(data[pos:pos + 16], "little")
                    pos += 16
                    (cycles,) = struct.unpack_from("<I", data, pos)
                    pos += 4
                    instr = isa.decode(word)
                    ch = (channel_of(instr)
                          if isinstance(instr, isa.SyncInstr) else None)
                    ops.append(Op(instr, cycles=cycles, channel=ch))
                streams[engine] = ops
            cp = CoreProgram(core=_CORE_BY_NAME[cm["core"]], streams=streams,
                             initial_tokens={k: int(v) for k, v
                                             in cm["tokens"].items()},
                             bytes_fetched=float(cm["fetched"]),
                             bytes_written=float(cm["written"]))
            setattr(lp, cm["core"], cp)
        layers.append(lp)
    if pos != len(data):
        raise ValueError(f"trailing bytes in image ({len(data) - pos})")
    step = (StepSpec.from_meta(meta["step"])
            if meta.get("step") is not None else None)
    return Program(name=meta["program"], device=device, lut_cfg=lut_cfg,
                   dsp_cfg=dsp_cfg, layers=layers, memory=memory,
                   step=step)


# ---------------------------------------------------------------------------
# Multi-device bundle image (N3HBUND1)
# ---------------------------------------------------------------------------


def _plan_meta(plan) -> dict:
    return {
        "kind": plan.kind,
        "n_devices": plan.n_devices,
        "stages": [list(s) for s in plan.stages]
        if plan.stages is not None else None,
        "shards": [list(s) for s in plan.shards]
        if plan.shards is not None else None,
        "link": {"latency_cycles": plan.link.latency_cycles,
                 "bytes_per_cycle": plan.link.bytes_per_cycle},
    }


def _plan_from_meta(meta: dict):
    from repro.compiler.partition import LinkModel, PartitionPlan
    return PartitionPlan(
        kind=meta["kind"], n_devices=meta["n_devices"],
        stages=tuple(tuple(s) for s in meta["stages"])
        if meta["stages"] is not None else None,
        shards=tuple(tuple(s) for s in meta["shards"])
        if meta["shards"] is not None else None,
        link=LinkModel(latency_cycles=meta["link"]["latency_cycles"],
                       bytes_per_cycle=meta["link"]["bytes_per_cycle"]))


def to_bundle_binary(mdp) -> bytes:
    """Pack a ``MultiDeviceProgram`` into the ``N3HBUND1`` image."""
    meta = {
        "bundle": mdp.name,
        "plan": _plan_meta(mdp.plan),
        "edges": [[e.src_device, e.src_layer, e.dst_device, e.dst_layer,
                   e.src_channel, e.dst_channel, e.nbytes]
                  for e in mdp.edges],
    }
    blob = json.dumps(meta, sort_keys=True,
                      separators=(",", ":")).encode("utf-8")
    parts = [MAGIC_BUNDLE, struct.pack("<I", len(blob)), blob,
             struct.pack("<I", len(mdp.devices))]
    for prog in mdp.devices:
        image = to_binary(prog)
        parts.append(struct.pack("<I", len(image)))
        parts.append(image)
    return b"".join(parts)


def from_bundle_binary(data: bytes):
    """Unpack an ``N3HBUND1`` image back into a ``MultiDeviceProgram``."""
    from repro.compiler.partition import ChannelEdge, MultiDeviceProgram
    try:
        if data[:8] != MAGIC_BUNDLE:
            raise ValueError("not an N3HBUND1 image")
        (meta_len,) = struct.unpack_from("<I", data, 8)
        pos = 12
        meta = json.loads(data[pos:pos + meta_len].decode("utf-8"))
        pos += meta_len
        (n_devices,) = struct.unpack_from("<I", data, pos)
        pos += 4
        devices = []
        for _ in range(n_devices):
            (plen,) = struct.unpack_from("<I", data, pos)
            pos += 4
            devices.append(from_binary(data[pos:pos + plen]))
            pos += plen
        if pos != len(data):
            raise ValueError(
                f"trailing bytes in bundle ({len(data) - pos})")
        edges = [ChannelEdge(src_device=e[0], src_layer=e[1],
                             dst_device=e[2], dst_layer=e[3],
                             src_channel=e[4], dst_channel=e[5],
                             nbytes=e[6]) for e in meta["edges"]]
        return MultiDeviceProgram(name=meta["bundle"],
                                  plan=_plan_from_meta(meta["plan"]),
                                  devices=devices, edges=edges)
    except (struct.error, UnicodeDecodeError, KeyError, IndexError,
            TypeError) as e:
        raise ValueError(f"corrupt N3HBUND1 image: {e!r}") from e


def disassemble_bundle(mdp) -> str:
    """Readable text of a bundle: plan header + per-device assembly.

    Informational (the per-device sections are each valid ``assemble``
    input, but the concatenation is not re-assemblable as a bundle —
    use the ``N3HBUND1`` binary for bit-exact round-trips).
    """
    out = [f"; n3h-core multi-device bundle {mdp.name}",
           f"; plan {mdp.plan.describe()}",
           f"; link latency={mdp.plan.link.latency_cycles} cycles, "
           f"{mdp.plan.link.bytes_per_cycle} B/cycle"]
    for e in mdp.edges:
        out.append(f"; edge dev{e.src_device}.L{e.src_layer} "
                   f"({e.src_channel}) -> dev{e.dst_device}."
                   f"L{e.dst_layer} ({e.dst_channel}) {e.nbytes}B")
    for d, prog in enumerate(mdp.devices):
        out.append(f"; ===== device {d}/{len(mdp.devices)} =====")
        out.append(disassemble(prog).rstrip("\n"))
    return "\n".join(out) + "\n"
