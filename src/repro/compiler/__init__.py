"""NN→ISA compiler toolchain (§3.1's unified 128-bit ISA, end to end).

Pipeline::

    configs/registry + core/workloads      (what to run)
        └─ networks.network_layers          → GEMM layer list
            └─ lower.lower_network          → Program (streams + DDR map)
                └─ passes.PassPipeline      → optimized Program (-O1:
                                              prefetch reorder, sync
                                              elision, fused result DMA)
                    ├─ asm.disassemble/assemble → text assembly (bit-exact)
                    ├─ asm.to_binary/from_binary→ packed image (bit-exact)
                    ├─ core.scheduler.simulate_program → Fig. 5 latency
                    └─ runtime.ExecutorBackend  → functional outputs:
                         runtime.GoldenExecutor   (bit-exact interpreter)
                         runtime.PallasExecutor   (batched fast path)

Multi-device plans (``--devices N``): partition.derive_plan splits the
network (pipeline stages or filter-parallel shards, derived from the
``parallel/`` axis rules) and partition.lower_partitioned emits a
MultiDeviceProgram — per-device Programs wired by cross-device
``*.xdev`` Sync channels — consumed by asm.to_bundle_binary
(``N3HBUND1``), simulate_program (cross-device makespan under the
plan's LinkModel) and runtime.MultiDeviceExecutor (bit-exact vs the
single-device program).
"""
from repro.compiler.asm import (
    assemble,
    disassemble,
    disassemble_bundle,
    from_binary,
    from_bundle_binary,
    to_binary,
    to_bundle_binary,
)
from repro.compiler.cli import compile_decode_network, compile_network
from repro.compiler.partition import (
    BundleSim,
    ChannelEdge,
    LinkModel,
    MultiDeviceProgram,
    PartitionError,
    PartitionPlan,
    decorate_decode_bundle,
    derive_plan,
    kind_from_rules,
    lower_partitioned,
    optimize_bundle,
    simulate_bundle,
    steady_bundle,
    validate_bundle,
)
from repro.compiler.passes import (
    O1_PASSES,
    Pass,
    PassError,
    PassPipeline,
    PassStats,
    DmaFusionPass,
    SyncElisionPass,
    WeightPrefetchPass,
    optimize_program,
    pipeline_for,
)
from repro.compiler.runtime import (
    BACKENDS,
    DecodeSession,
    ExecutionError,
    ExecutorBackend,
    ExecutorSession,
    GoldenExecutor,
    LayerWeights,
    MultiDeviceExecutor,
    PallasExecutor,
    ReferenceSession,
    apply_pool,
    bind_synthetic,
    chain_layers,
    decode_step_ref,
    get_backend,
    im2col_patches,
    requantize,
    spatialize,
    synthetic_weights,
)
from repro.compiler.lower import (
    KV_APPEND_STAGE,
    KV_READ_STAGE,
    LayerAddrs,
    decorate_decode,
    lower_dsp_layer,
    lower_lut_layer,
    lower_network,
    solve_split_dims,
    steady_program,
)
from repro.compiler.networks import (
    decode_step_layers,
    list_networks,
    lm_gemm_layers,
    network_layers,
)
from repro.compiler.program import (
    ConvGeometry,
    CoreProgram,
    GemmLayer,
    LayerProgram,
    MemoryMap,
    Program,
    ProgramStats,
    RESIDENCY_CLASSES,
    Segment,
    StepSpec,
    channel_of,
)

__all__ = [
    "assemble", "disassemble", "disassemble_bundle", "from_binary",
    "from_bundle_binary", "to_binary", "to_bundle_binary",
    "compile_decode_network", "compile_network",
    "BundleSim", "ChannelEdge", "LinkModel", "MultiDeviceProgram",
    "PartitionError", "PartitionPlan", "decorate_decode_bundle",
    "derive_plan", "kind_from_rules", "lower_partitioned",
    "optimize_bundle", "simulate_bundle", "steady_bundle",
    "validate_bundle",
    "O1_PASSES", "Pass", "PassError", "PassPipeline", "PassStats",
    "DmaFusionPass", "SyncElisionPass", "WeightPrefetchPass",
    "optimize_program", "pipeline_for",
    "BACKENDS", "DecodeSession", "ExecutionError", "ExecutorBackend",
    "ExecutorSession", "GoldenExecutor", "LayerWeights",
    "MultiDeviceExecutor", "PallasExecutor", "ReferenceSession",
    "apply_pool", "bind_synthetic", "chain_layers", "decode_step_ref",
    "get_backend", "im2col_patches", "requantize", "spatialize",
    "synthetic_weights",
    "KV_APPEND_STAGE", "KV_READ_STAGE", "LayerAddrs", "decorate_decode",
    "lower_dsp_layer", "lower_lut_layer", "lower_network",
    "solve_split_dims", "steady_program",
    "decode_step_layers", "list_networks", "lm_gemm_layers",
    "network_layers",
    "ConvGeometry", "CoreProgram", "GemmLayer", "LayerProgram", "MemoryMap",
    "Program", "ProgramStats", "RESIDENCY_CLASSES", "Segment", "StepSpec",
    "channel_of",
]
