"""Program-level optimization passes over compiled :class:`Program`s.

The lowering pass (``lower.py``) emits the canonical Fig.-3 schedule:
double-buffered weight tiles, one Result DMA per output tile, the full
slot-token machinery even where it synchronizes nothing. These passes
rewrite the emitted instruction streams for latency, the way the paper's
instruction-level overlap (Fig. 3) and latency decomposition (Eqs. 6/8)
say the wins should land:

  * :class:`WeightPrefetchPass` — weight-tile prefetch reordering: the
    canonical schedule gates every weight-tile fetch behind a
    double-buffer free-slot token, but the on-chip buffer pools
    (``d_w``/``d_a`` of Table 1) usually hold many more tiles. The pass
    arms the true slot count as initial tokens, so gated fetches issue
    ahead of the canonical double-buffer order and the fetch engine
    streams instead of stalling (L_wait of Eq. 6 drops on DMA-bound
    layers).
  * :class:`SyncElisionPass` — removes sync sends whose tokens are
    provably never consumed (trailing surplus on a channel). For
    single-tile layers this strips the entire free-slot hand-shake; it
    also deletes the sends made dead by the prefetch pass.
  * :class:`DmaFusionPass` — fused result/fetch DMA pairs: adjacent
    Result instructions draining consecutive output tiles merge into a
    single burst, saving one DMA setup per pair. Fusion is profitable
    only when the result engine is the layer bottleneck, so the pass
    keeps a fusion only if the event-driven simulator confirms the
    layer-core makespan does not regress.

Every pass must preserve the ISA contract that the event-driven
scheduler validates:

  * streams stay deadlock-free (every Sync wait remains satisfiable
    from initial tokens plus earlier sends);
  * Execute instructions keep their count and order (the golden
    executor derives tile coordinates from execute ordinals);
  * Fetch/Result instructions keep addressing the layer's DDR segments
    and tiling the partition exactly (fused Results carry their burst
    length in ``onchip_base``; see ``runtime/golden.py``);
  * inter-layer barrier channels (``lut.bar``/``dsp.bar``) are never
    touched — they carry the Eq.-10 synchronous chain.

On-chip buffer addressing is deliberately *out of model*: the 1-bit
``onchip_range`` half-select emitted by the lowering is a ping-pong
write cursor, and slot occupancy is metered by tokens, not by the
encoded buffer address. A prefetch-deepened schedule keeps the cursor
alternating over a pool that holds ``slots`` tiles, and a fused
2-tile burst fills both halves starting at its ``onchip_range``; real
hardware would derive buffer write addresses from the tile index, as
BISMO does, not from this field. The timing model and the golden
executor never read on-chip addresses, so the contract above is the
full contract the passes must keep.

:class:`PassPipeline` re-simulates every layer-core stream after each
pass and raises :class:`PassError` on any deadlock, so a broken rewrite
can never silently ship.
"""
from __future__ import annotations

import copy
import dataclasses
from typing import Protocol, runtime_checkable

from repro.core import isa
from repro.core.scheduler import Op, _dma_cycles, simulate
from repro.compiler.program import (
    CROSS_DEVICE_CHANNELS,
    CoreProgram,
    LayerProgram,
    Program,
)

#: Channels that carry the inter-layer synchronous chain (Eq. 10).
#: No pass may add, remove or reorder syncs on these.
BARRIER_CHANNELS = frozenset({"lut.bar", "dsp.bar"})

#: Channels no pass may touch: barriers plus the cross-device hand-off
#: channels (``*.xdev``), whose matching sync lives in *another*
#: device's program — eliding or reordering one corrupts a hand-off
#: the per-device deadlock check cannot see
#: (``partition.validate_bundle`` re-checks the pairing post-pass).
PROTECTED_CHANNELS = BARRIER_CHANNELS | CROSS_DEVICE_CHANNELS

#: Result-drain channels (execute -> result handshake).
RESULT_CHANNELS = frozenset({"lut.res", "dsp.res"})


class PassError(RuntimeError):
    """A pass produced a program that violates the ISA contract."""


@dataclasses.dataclass(frozen=True)
class PassStats:
    """Per-pass accounting surfaced by the CLI and benchmarks."""
    name: str
    instrs_before: int
    instrs_after: int
    detail: dict

    @property
    def removed(self) -> int:
        return self.instrs_before - self.instrs_after

    def render(self) -> str:
        extra = " ".join(f"{k}={v}" for k, v in self.detail.items())
        return (f"{self.name:<18} {self.instrs_before} -> "
                f"{self.instrs_after} instrs" + (f"  ({extra})" if extra
                                                 else ""))


@runtime_checkable
class Pass(Protocol):
    """One Program rewrite. ``run`` mutates ``prog`` in place and
    returns a detail dict for :class:`PassStats`."""
    name: str

    def run(self, prog: Program) -> dict: ...


# ---------------------------------------------------------------------------
# Pass 1: weight-tile prefetch reordering (buffer-capacity deepening)
# ---------------------------------------------------------------------------


class WeightPrefetchPass:
    """Issue gated tile fetches ahead of the canonical double-buffer
    order by arming the true on-chip slot count as initial tokens.

    The lowering emits slot channels (``lut.wslot`` weight tiles,
    ``dsp.aslot`` activation row tiles) with one initial token — strict
    double buffering. The buffer pools of Table 1 are deeper: the pass
    computes how many tiles actually fit (pool bits // tile bits) and
    raises the initial token count to ``min(slots - 1, #gated fetches)``.
    Waits and sends are untouched, so steady-state metering beyond the
    pool capacity is preserved and the rewrite can only move fetch issue
    times earlier (token monotonicity of the event-driven model) —
    never later.
    """
    name = "weight-prefetch"

    def run(self, prog: Program) -> dict:
        tokens_added = 0
        cores_deepened = 0
        for lp in prog.layers:
            for cp in lp.cores():
                ch, slots = self._capacity(prog, lp, cp)
                if ch is None or slots <= 2:
                    continue
                waits = sum(1 for op in cp.ops()
                            if op.channel == ch
                            and isinstance(op.instr, isa.SyncInstr)
                            and op.instr.is_wait)
                cur = cp.initial_tokens.get(ch, 0)
                new = max(cur, min(slots - 1, waits))
                if new > cur:
                    cp.initial_tokens[ch] = new
                    tokens_added += new - cur
                    cores_deepened += 1
        return {"tokens_added": tokens_added,
                "cores_deepened": cores_deepened}

    @staticmethod
    def _capacity(prog: Program, lp: LayerProgram,
                  cp: CoreProgram) -> tuple[str | None, int]:
        """(slot channel, tile slots the on-chip pool holds) for a core.

        Pool models mirror the residency checks in ``lower.py``: the
        LUT weight pool is N lanes x D_w deep x K bits; the DSP
        activation pool is D_a deep x N_reg_col_a lanes x 4 bits.
        """
        k = lp.dims.k
        if cp.core == isa.CoreSel.LUT:
            cfg = prog.lut_cfg
            tile_bits = cfg.n * k * lp.bits_w_lut
            pool_bits = cfg.n * cfg.d_w * cfg.k
            return ("lut.wslot", pool_bits // tile_bits) if tile_bits \
                else (None, 0)
        cfg = prog.dsp_cfg
        if lp.depthwise:
            tile_bits = cfg.n_reg_row_a * cfg.n_reg_col_w * 4
        else:
            tile_bits = cfg.n_reg_row_a * k * 4
        pool_bits = cfg.d_a * cfg.n_reg_col_a * 4
        return ("dsp.aslot", pool_bits // tile_bits) if tile_bits \
            else (None, 0)


# ---------------------------------------------------------------------------
# Pass 2: sync elision (dead token sends, single-tile layer hand-shakes)
# ---------------------------------------------------------------------------


class SyncElisionPass:
    """Remove Sync sends whose tokens are provably never consumed.

    Per core and channel, waits consume tokens in post order: the
    initial tokens first, then the earliest sends. With ``S`` sends,
    ``W`` waits and ``I`` initial tokens, the trailing
    ``S - max(0, W - I)`` sends post tokens nobody ever pops — pure
    L_sig overhead on the sending engine (Eq. 6). Dropping them cannot
    affect any wait and only moves the sender's later instructions
    earlier.

    Single-tile layers are the flagship case: their entire free-slot
    machinery (``lut.wslot``/``dsp.aslot``) is dead because no gated
    fetch exists. The pass also collects the sends that
    :class:`WeightPrefetchPass` made dead by arming deeper initial
    tokens. Barrier channels are never elided — their sends are
    consumed by the *next* layer's fetch stream.
    """
    name = "sync-elision"

    def run(self, prog: Program) -> dict:
        removed = 0
        single_tile_layers = 0
        for lp in prog.layers:
            layer_removed = 0
            for cp in lp.cores():
                layer_removed += self._elide_core(cp)
            removed += layer_removed
            if layer_removed and lp.n_instructions <= 12:
                single_tile_layers += 1
        return {"syncs_elided": removed,
                "single_tile_layers": single_tile_layers}

    @staticmethod
    def _elide_core(cp: CoreProgram) -> int:
        sends: dict[str, list[tuple[str, int]]] = {}
        waits: dict[str, int] = {}
        for engine, stream in cp.streams.items():
            for idx, op in enumerate(stream):
                if not isinstance(op.instr, isa.SyncInstr):
                    continue
                if op.instr.is_wait:
                    waits[op.channel] = waits.get(op.channel, 0) + 1
                else:
                    sends.setdefault(op.channel, []).append((engine, idx))

        drop: dict[str, set[int]] = {}
        removed = 0
        for ch, slist in sends.items():
            if ch in PROTECTED_CHANNELS:
                continue
            if len({e for e, _ in slist}) != 1:
                # multiple sender engines: cross-engine post order is
                # dynamic, the trailing-surplus argument does not apply
                continue
            consumed = max(0, waits.get(ch, 0)
                           - cp.initial_tokens.get(ch, 0))
            surplus = len(slist) - consumed
            if surplus <= 0:
                continue
            for engine, idx in slist[len(slist) - surplus:]:
                drop.setdefault(engine, set()).add(idx)
                removed += 1
        for engine, idxs in drop.items():
            cp.streams[engine] = [op for i, op
                                  in enumerate(cp.streams[engine])
                                  if i not in idxs]
        return removed


# ---------------------------------------------------------------------------
# Pass 3: fused result/fetch DMA pairs
# ---------------------------------------------------------------------------


class DmaFusionPass:
    """Fuse adjacent DMA pairs moving consecutive tiles into single
    bursts, saving one DMA setup (``dma_setup_cycles``) per pair — on
    both the result and the fetch side of the pipeline.

    Result side: the canonical result stream is
    ``[wait res, RESULT(t)] * n_tiles``; a fused pair becomes
    ``wait res, wait res, RESULT(t, burst=2)``. Both tiles' tokens are
    still consumed before the burst issues, so the execute→result
    ordering contract is intact.

    Fetch side: weight-tile fetch groups
    ``[wait slot?, FETCH(w_j), send wtile]`` for consecutive ``j``
    merge into ``waits..., FETCH(w_j burst=2), send, send``. Both
    wtile tokens post when the burst lands; the slot waits still gate
    the buffer space. Small LM layers are DMA-setup-bound on the fetch
    engine, which makes this the pass that moves their critical path.

    The burst length rides in the otherwise-unused ``onchip_base``
    field of the Fetch/Result word (canonical streams encode 0 there),
    which keeps the asm/binary round-trips bit-exact; the golden
    executor expands ``max(1, onchip_base)`` consecutive tiles per DMA.

    Fusion delays the first tile of each pair, which *hurts* when the
    consumer engine is the bottleneck. The pass therefore simulates
    each layer-core over the (fetch x result x pairing-direction)
    variant cross-product — at most 9 isolated per-layer-core sims,
    usually fewer — and keeps the jointly best one, so a fusion that
    would regress the core makespan is never applied. The joint search
    matters: on DMA-setup-bound LM layers only fetch+result fusion
    *together* beats the baseline. Measured cost: ~3 s for resnet18's
    85k-instruction program, ~9 s for mobilenet_v2 (per-layer streams
    are simulated in isolation, never the whole program).
    """
    name = "dma-fusion"
    max_burst = 2

    def run(self, prog: Program) -> dict:
        result_pairs = fetch_pairs = 0
        cores_reverted = 0
        for lp in prog.layers:
            for cp in lp.cores():
                rp, fp, had_candidates = self._fuse_core(cp, prog.device)
                result_pairs += rp
                fetch_pairs += fp
                if had_candidates and rp == fp == 0:
                    cores_reverted += 1
        return {"result_pairs": result_pairs,
                "fetch_pairs": fetch_pairs,
                "cores_unprofitable": cores_reverted}

    def _fuse_core(self, cp: CoreProgram, dev) -> tuple[int, int, bool]:
        """Pick the jointly best (result x fetch) fusion variant for one
        core by simulated makespan; ties prefer more fused pairs (fewer
        instructions at equal latency). Returns (kept result pairs,
        kept fetch pairs, whether any fusion candidate existed)."""
        f_vars = self._variants(cp.streams["fetch"], self._fuse_fetches, dev)
        r_vars = self._variants(cp.streams["result"], self._fuse_results,
                                dev)
        if len(f_vars) == 1 and len(r_vars) == 1:
            return 0, 0, False
        tokens = cp.sim_tokens()
        best = None          # (total, -pairs, fetch_var, result_var)
        for fs, fn in f_vars:
            for rs, rn in r_vars:
                trial = dict(cp.streams)
                trial["fetch"], trial["result"] = fs, rs
                try:
                    total = simulate(trial, tokens).total_cycles
                except RuntimeError:
                    # a deadlocking candidate is infeasible, not fatal —
                    # the unfused (fn == rn == 0) variant always simulates
                    continue
                key = (total, -(fn + rn))
                if best is None or key < best[0]:
                    best = (key, fs, fn, rs, rn)
        _, fs, fn, rs, rn = best
        cp.streams["fetch"], cp.streams["result"] = fs, rs
        return rn, fn, True

    @classmethod
    def _variants(cls, stream: list[Op], fuser, dev):
        """[(stream, n_pairs)]: unfused plus distinct fwd/tail pairings."""
        out = [(stream, 0)]
        for direction in ("fwd", "tail"):
            fused, n = fuser(stream, dev, direction)
            if n and all(fused != s for s, _ in out):
                out.append((fused, n))
        return out

    # -- result stream ----------------------------------------------------

    @staticmethod
    def _is_result_wait(op: Op) -> bool:
        return (isinstance(op.instr, isa.SyncInstr) and op.instr.is_wait
                and op.channel in RESULT_CHANNELS)

    @classmethod
    def _fusable(cls, a, b) -> int:
        """Burst length if DMAs ``a``/``b`` (same instr kind) fuse, else 0."""
        ca = max(1, a.onchip_base)
        cb = max(1, b.onchip_base)
        nbytes = a.ddr_range + b.ddr_range
        ok = (a.ddr_base == b.ddr_base
              and a.stage_ctrl == b.stage_ctrl
              # never fuse gather (3) or persistent kv/state (4/5) DMAs:
              # their offsets are peer ranks / step positions, not
              # consecutive output tiles
              and a.stage_ctrl < 3
              and b.ddr_offset == a.ddr_offset + ca
              and ca + cb <= cls.max_burst
              # clamped lengths hide the true byte count: don't fuse
              and a.ddr_range < 0xFFFF
              and b.ddr_range < 0xFFFF
              and nbytes <= 0xFFFF)
        return ca + cb if ok else 0

    @classmethod
    def _fuse_results(cls, stream: list[Op], dev,
                      direction: str = "fwd") -> tuple[list[Op], int]:
        def match(i):
            if (i + 1 < len(stream) and cls._is_result_wait(stream[i])
                    and isinstance(stream[i + 1].instr, isa.ResultInstr)):
                return i + 2, (stream[i],), stream[i + 1]
            return None
        return cls._pair_fuse(stream, match, dev, direction)

    @classmethod
    def _fuse_fetches(cls, stream: list[Op], dev,
                      direction: str = "fwd") -> tuple[list[Op], int]:
        def match(i):
            """``[wait slot]? FETCH(stage 0) SEND wtile``"""
            waits = ()
            if (i < len(stream)
                    and isinstance(stream[i].instr, isa.SyncInstr)
                    and stream[i].instr.is_wait
                    and stream[i].channel not in PROTECTED_CHANNELS):
                waits = (stream[i],)
                i += 1
            if (i + 1 < len(stream)
                    and isinstance(stream[i].instr, isa.FetchInstr)
                    and stream[i].instr.stage_ctrl == 0
                    and isinstance(stream[i + 1].instr, isa.SyncInstr)
                    and not stream[i + 1].instr.is_wait):
                return i + 2, waits, stream[i]
            return None
        return cls._pair_fuse(stream, match, dev, direction)

    # -- shared machinery --------------------------------------------------

    @classmethod
    def _pair_fuse(cls, stream: list[Op], match, dev,
                   direction: str) -> tuple[list[Op], int]:
        """Parse ``stream`` into (waits, DMA, sends) groups via ``match``
        and fuse adjacent fusable groups pairwise.

        ``direction`` picks which DMA stays unpaired when a fusable run
        has odd length: ``"fwd"`` pairs head-first (last tile unfused —
        right when the consumer paces the stream and the final token
        must not wait on a longer burst), ``"tail"`` pairs tail-first
        (first tile unfused — right when the engine itself is
        DMA-setup-bound and the critical path ends at the last tile).
        The caller simulates both and keeps the better one.
        """
        # 1. Segment: ('group', waits, dma_op, trailing_ops) | ('op', op)
        items: list[tuple] = []
        i = 0
        while i < len(stream):
            g = match(i)
            if g is not None:
                nxt, waits, dma = g
                items.append(("group", waits, dma,
                              tuple(stream[i + len(waits) + 1:nxt])))
                i = nxt
            else:
                items.append(("op", stream[i]))
                i += 1

        def fuse_pair(first, second):
            _, w_a, dma_a, tail_a = first
            _, w_b, dma_b, tail_b = second
            a, b = dma_a.instr, dma_b.instr
            if {op.channel for op in tail_a} != {op.channel
                                                 for op in tail_b}:
                return None
            burst = cls._fusable(a, b)
            if not burst:
                return None
            nbytes = a.ddr_range + b.ddr_range
            fused = dataclasses.replace(a, onchip_base=burst,
                                        ddr_range=nbytes)
            return ("ops", w_a + w_b
                    + (Op(fused, cycles=_dma_cycles(nbytes, dev)),)
                    + tail_a + tail_b)

        def flat(it):
            return ("ops", it[1] + (it[2],) + it[3]) if it[0] == "group" \
                else ("ops", (it[1],))

        # 2. Pair adjacent groups, head-first or tail-first.
        n_fused = 0
        picked: list[tuple] = []
        if direction == "tail":
            i = len(items) - 1
            while i >= 0:
                merged = (fuse_pair(items[i - 1], items[i])
                          if i >= 1 and items[i][0] == items[i - 1][0]
                          == "group" else None)
                if merged is not None:
                    picked.append(merged)
                    n_fused += 1
                    i -= 2
                else:
                    picked.append(flat(items[i]))
                    i -= 1
            picked.reverse()
        else:
            i = 0
            while i < len(items):
                merged = (fuse_pair(items[i], items[i + 1])
                          if i + 1 < len(items) and items[i][0]
                          == items[i + 1][0] == "group" else None)
                if merged is not None:
                    picked.append(merged)
                    n_fused += 1
                    i += 2
                else:
                    picked.append(flat(items[i]))
                    i += 1

        out: list[Op] = []
        for _, ops in picked:
            out.extend(ops)
        return out, n_fused


# ---------------------------------------------------------------------------
# Pipeline
# ---------------------------------------------------------------------------


class PassPipeline:
    """Run a pass sequence over a Program with post-pass validation.

    After every pass each layer-core stream bundle is re-run through the
    event-driven scheduler (with the layer's isolation tokens): a
    deadlock there means the pass broke the token protocol and raises
    :class:`PassError` naming the pass and layer.
    """

    def __init__(self, passes: list[Pass], validate: bool = True):
        self.passes = list(passes)
        self.validate = validate

    def run(self, prog: Program,
            copy_program: bool = True) -> tuple[Program, list[PassStats]]:
        if copy_program:
            prog = copy.deepcopy(prog)
        stats: list[PassStats] = []
        for p in self.passes:
            before = prog.n_instructions
            detail = p.run(prog)
            stats.append(PassStats(p.name, before, prog.n_instructions,
                                   dict(detail)))
            if self.validate:
                self._check(prog, p.name)
        prog.opt_stats = list(stats)
        return prog, stats

    @staticmethod
    def _check(prog: Program, pass_name: str) -> None:
        from repro.compiler.program import CORE_NAMES
        for lp in prog.layers:
            for cp in lp.cores():
                try:
                    simulate(cp.streams, cp.sim_tokens())
                except RuntimeError as e:
                    raise PassError(
                        f"pass {pass_name!r} broke layer {lp.index} "
                        f"({lp.name}) {CORE_NAMES[cp.core]} streams: {e}"
                    ) from e


#: Pass roster per optimization level. -O0 is the canonical schedule.
O1_PASSES: tuple[type, ...] = (WeightPrefetchPass, SyncElisionPass,
                               DmaFusionPass)
OPT_LEVELS = (0, 1)


def pipeline_for(opt_level: int, validate: bool = True) -> PassPipeline:
    if opt_level not in OPT_LEVELS:
        raise ValueError(f"opt_level must be one of {OPT_LEVELS}, "
                         f"got {opt_level!r}")
    passes = [cls() for cls in O1_PASSES] if opt_level >= 1 else []
    return PassPipeline(passes, validate=validate)


def optimize_program(prog: Program, opt_level: int = 1, *,
                     validate: bool = True,
                     copy_program: bool = True) -> Program:
    """Apply the ``opt_level`` pipeline; per-pass accounting lands on
    ``prog.opt_stats``. ``opt_level=0`` returns the program unchanged."""
    pipeline = pipeline_for(opt_level, validate=validate)
    if not pipeline.passes:
        return prog
    out, _ = pipeline.run(prog, copy_program=copy_program)
    return out
