"""Lowering pass: network layers → unified-ISA instruction streams.

This is the single source of truth for instruction generation. The
per-layer schedules implement Fig. 3 of the paper:

  * LUT-core (bit-serial, BISMO backbone): the serialized activation
    matrix L is resident on chip when it fits; weight column tiles R_j
    stream through a double-buffered weight buffer gated by free-slot
    tokens (WE); result tiles drain as they complete.
  * DSP-core (bit-parallel): activation row tiles double-buffered;
    the weight matrix is cached whole on chip when the weight buffer
    pool allows, else re-fetched per row tile.

``core/scheduler.py``'s ``lut_core_streams`` / ``dsp_core_streams`` are
thin wrappers over :func:`lower_lut_layer` / :func:`lower_dsp_layer`,
so the event-driven simulator, the golden executor and the serialized
program images all consume the exact same streams.

``lower_network`` walks a whole layer list through the neuron split
(Eq. 12) and packages everything as a :class:`Program` with a DDR
memory map and inter-layer barrier tokens (inter-layer synchronous,
intra-layer asynchronous — §3.1). It emits the *canonical* Fig.-3
schedule; ``opt_level >= 1`` then runs the program-level optimization
pipeline of ``passes.py`` (weight-tile prefetch reordering, sync
elision, fused result DMA pairs) over the lowered streams.
"""
from __future__ import annotations

import copy
import dataclasses
import math

import numpy as np

from repro.core import isa
from repro.core.split import split_curves
from repro.core.scheduler import (
    DspCoreConfig,
    FPGADevice,
    GemmDims,
    LutCoreConfig,
    Op,
    _dma_cycles,
)
from repro.compiler.program import (
    CHANNEL_FLAGS,
    CoreProgram,
    ElementwiseOp,
    GemmLayer,
    LayerProgram,
    MemoryMap,
    Program,
    StepSpec,
)

#: ``stage_ctrl`` values of the persistent-segment DMAs emitted by the
#: decode decoration (0=weights, 1=acts, 2=result, 3=gather are taken
#: by the fixed-seq lowering and the filter-parallel partitioner).
#: A stage-4 Result appends one row to a ``kv``/``state`` segment at
#: ``base + pos * row_bytes`` (``pos`` is the step-position register
#: supplied per invocation); a stage-5 Fetch reads the persistent
#: window back (timed at the worst-case ``max_seq`` footprint).
KV_APPEND_STAGE = 4
KV_READ_STAGE = 5
PERSISTENT_STAGES = (KV_APPEND_STAGE, KV_READ_STAGE)

#: ``stage_ctrl`` of the fused elementwise result tail (conv chains):
#: a stage-6 Fetch reads the residual-add operand from the producer's
#: output segment; a stage-6 Result applies the tail (add / activation
#: / pool / requant) over the layer's fp32 result and writes the
#: requantized codes back to ``L{i}.out``. The stage is sequential in
#: the result stream — no new sync channel (both cores' flag spaces
#: are full), the tail simply runs after the last result drain and
#: before the inter-layer barrier send.
EW_STAGE = 6
#: Elementwise throughput model: lanes applied per cycle per op pass.
EW_LANES = 16

#: Channels whose tokens are posted by the fetch engine strictly after
#: weight fetches — the sends that go away with the fetches when a
#: steady-state decode program elides resident-weight loads.
_WEIGHT_FETCH_SENDS = frozenset({"lut.wtile", "dsp.wall", "dsp.wtile"})
#: Fetch-engine waits that exist only to gate weight-tile fetches.
_WEIGHT_FETCH_WAITS = frozenset({"lut.wslot"})


@dataclasses.dataclass(frozen=True)
class LayerAddrs:
    """DDR bases the layer's DMA instructions address (all 32-bit)."""
    wgt_base: int = 0
    act_base: int = 0
    out_base: int = 0


def _send(core: isa.CoreSel, src: isa.Engine, dst: isa.Engine,
          ch: str) -> Op:
    flag = CHANNEL_FLAGS[ch]
    return Op(
        isa.SyncInstr(core=core, src_engine=src, dst_engine=dst, cur_state=0,
                      next_state=min(3, flag), token_flag=flag, is_wait=0),
        cycles=1, channel=ch)


def _wait(core: isa.CoreSel, src: isa.Engine, dst: isa.Engine,
          ch: str) -> Op:
    flag = CHANNEL_FLAGS[ch]
    return Op(
        isa.SyncInstr(core=core, src_engine=src, dst_engine=dst, cur_state=1,
                      next_state=min(3, flag), token_flag=flag, is_wait=1),
        cycles=1, channel=ch)


def _clamp16(v: float) -> int:
    return min(65535, int(v))


# ---------------------------------------------------------------------------
# LUT-core layer lowering (bit-serial schedule of Fig. 3)
# ---------------------------------------------------------------------------


def lower_lut_layer(g: GemmDims, cfg: LutCoreConfig, dev: FPGADevice,
                    bits_w: int, bits_a: int, depthwise: bool = False,
                    addrs: LayerAddrs = LayerAddrs(),
                    act_bytes: float | None = None) -> CoreProgram:
    """Lower one layer partition onto the LUT-core.

    Cycle model: a (m x n) output tile accumulates over ceil(K_g/K)
    K-bit beats per binary plane pair; there are bits_w*bits_a plane
    pairs; plus a fixed array fill/drain per tile. Result tiles are
    written back to DDR requantized to the next layer's activation
    bit-width (§3.1), approximated with ``bits_a``.

    ``act_bytes`` overrides the activation-fetch footprint: conv layers
    pass the raw spatial NHWC source size (the fused kernels generate
    im2col patches on chip, so DMA never moves the kh*kw-duplicated
    column matrix).
    """
    C = isa.CoreSel.LUT
    nt_m = math.ceil(g.m / cfg.m)
    nt_n = math.ceil(g.n / cfg.n)
    if depthwise:
        # channels across columns, K = kh*kw taps, derated MAC rate
        nt_k = 1
        tile_exec = math.ceil(g.k * bits_w * bits_a /
                              (cfg.k * cfg.dw_efficiency)) + cfg.pipeline_fill
        bytes_l = g.m * g.n * bits_a / 8.0      # NHWC, no channel reuse
        bytes_r_tile = g.k * cfg.n * bits_w / 8.0
    else:
        nt_k = math.ceil(g.k / cfg.k)
        tile_exec = nt_k * bits_w * bits_a + cfg.pipeline_fill
        bytes_l = g.m * g.k * bits_a / 8.0      # serialized activation planes
        bytes_r_tile = cfg.n * g.k * bits_w / 8.0   # one weight column-tile
    if act_bytes is not None:
        bytes_l = float(act_bytes)              # spatial source, no im2col dup
    bytes_out_tile = cfg.m * cfg.n * bits_a / 8.0   # requantized write-back

    # Activation residency: the activation buffer pool holds M x D_a x K
    # bits. When the (serialized) L matrix exceeds it, L is re-streamed
    # for every weight column tile (§3.1).
    a_capacity_bits = cfg.m * cfg.d_a * cfg.k
    a_resident = bytes_l * 8 <= a_capacity_bits

    fetch: list[Op] = []
    execu: list[Op] = []
    result: list[Op] = []
    fetched = written = 0.0

    def fetch_wtile(j: int) -> Op:
        nonlocal fetched
        fetched += bytes_r_tile
        return Op(isa.FetchInstr(C, 0, 0, j % 2, addrs.wgt_base, j,
                                 _clamp16(bytes_r_tile)),
                  cycles=_dma_cycles(bytes_r_tile, dev))

    def fetch_act(half: int) -> Op:
        nonlocal fetched
        fetched += bytes_l
        return Op(isa.FetchInstr(C, 0, 1, half, addrs.act_base, 0,
                                 _clamp16(bytes_l)),
                  cycles=_dma_cycles(bytes_l, dev))

    # R0 first, then L (paper: "R0 is fetched ... then L0 is fetched").
    fetch.append(fetch_wtile(0))
    fetch.append(_send(C, isa.Engine.FETCH, isa.Engine.EXECUTE, "lut.wtile"))
    fetch.append(fetch_act(0))
    fetch.append(_send(C, isa.Engine.FETCH, isa.Engine.EXECUTE, "lut.act"))
    for j in range(1, nt_n):
        # Wait for a free slot in the double-buffered weight buffer (WE).
        fetch.append(_wait(C, isa.Engine.EXECUTE, isa.Engine.FETCH, "lut.wslot"))
        fetch.append(fetch_wtile(j))
        fetch.append(_send(C, isa.Engine.FETCH, isa.Engine.EXECUTE, "lut.wtile"))
        if not a_resident:
            # re-stream the activation matrix for this column tile
            fetch.append(fetch_act(j % 2))
            fetch.append(_send(C, isa.Engine.FETCH, isa.Engine.EXECUTE,
                               "lut.act"))

    execu.append(_wait(C, isa.Engine.FETCH, isa.Engine.EXECUTE, "lut.act"))
    for j in range(nt_n):
        execu.append(_wait(C, isa.Engine.FETCH, isa.Engine.EXECUTE, "lut.wtile"))
        if not a_resident and j > 0:
            execu.append(_wait(C, isa.Engine.FETCH, isa.Engine.EXECUTE,
                               "lut.act"))
        for i in range(nt_m):
            execu.append(Op(isa.ExecuteInstr(
                C, buf_addr_a=(i * nt_k) & 0xFFFF, buf_addr_w=(j * nt_k) & 0xFFFF,
                tile_m=min(4095, cfg.m), tile_k=min(65535, g.k),
                tile_n=min(4095, cfg.n), bits_w=bits_w, bits_a=bits_a,
                accumulate=0), cycles=tile_exec))
            execu.append(_send(C, isa.Engine.EXECUTE, isa.Engine.RESULT, "lut.res"))
        # Free this weight-buffer slot for the fetch engine (SE).
        execu.append(_send(C, isa.Engine.EXECUTE, isa.Engine.FETCH, "lut.wslot"))

    for j in range(nt_n):
        for i in range(nt_m):
            result.append(_wait(C, isa.Engine.EXECUTE, isa.Engine.RESULT, "lut.res"))
            written += bytes_out_tile
            result.append(Op(isa.ResultInstr(C, 0, 2, 0, addrs.out_base,
                                             (j * nt_m + i) & 0xFFFFFF,
                                             _clamp16(bytes_out_tile)),
                             cycles=_dma_cycles(bytes_out_tile, dev)))

    # One weight-buffer slot is free at t=0 (the other is filled by the
    # un-gated first fetch) => effective double buffering.
    return CoreProgram(
        core=C,
        streams={"fetch": fetch, "execute": execu, "result": result},
        initial_tokens={"lut.wslot": 1},
        bytes_fetched=fetched, bytes_written=written)


# ---------------------------------------------------------------------------
# DSP-core layer lowering (bit-parallel schedule)
# ---------------------------------------------------------------------------


def lower_dsp_layer(g: GemmDims, cfg: DspCoreConfig, dev: FPGADevice,
                    depthwise: bool = False,
                    addrs: LayerAddrs = LayerAddrs(),
                    act_bytes: float | None = None) -> CoreProgram:
    """Lower one layer partition onto the DSP-core.

    The register arrays compute an [R x 16] x [16 x 16] product per
    K-step: 2 cycles to fill the weight registers (two columns per
    buffer per cycle), then 16 systolic MAC cycles. Activation row-tiles
    are double buffered; weight column-tiles are cached on chip when the
    weight buffer capacity allows, else re-fetched per row-tile.

    ``act_bytes`` overrides the total activation-fetch footprint (spread
    evenly over the row tiles) — conv layers pass the raw spatial NHWC
    source size since the fused kernels im2col on chip.
    """
    C = isa.CoreSel.DSP
    R = cfg.n_reg_row_a
    kstep = cfg.w_fill_cycles + cfg.n_reg_col_w + cfg.a_fill_cycles
    nt_m = math.ceil(g.m / R)
    nt_n = math.ceil(g.n / cfg.n_reg_col_w)
    bits_a_stored = 4  # activations are zero-padded to 4 bits in buffers
    if depthwise:
        # per-tap diagonal weight mode: 16 channels per pass, derated
        tile_exec = math.ceil(g.k * kstep /
                              (cfg.n_reg_col_a * cfg.dw_efficiency))
        bytes_a_tile = R * cfg.n_reg_col_w * bits_a_stored / 8.0
        bytes_w_tile = g.k * cfg.n_reg_col_w * 4 / 8.0
    else:
        nt_k = math.ceil(g.k / cfg.n_reg_col_a)
        tile_exec = nt_k * kstep
        bytes_a_tile = R * g.k * bits_a_stored / 8.0
        bytes_w_tile = g.k * cfg.n_reg_col_w * 4 / 8.0  # int4 weights
    if act_bytes is not None:
        bytes_a_tile = float(act_bytes) / nt_m  # spatial source, no im2col dup
    bytes_out_tile = R * cfg.n_reg_col_w * bits_a_stored / 8.0

    # Weight resident if every column tile fits the weight buffer pool.
    w_capacity_bits = (cfg.n_reg_col_w // 2) * cfg.d_w * (cfg.n_reg_col_a * 4)
    w_resident = nt_n * bytes_w_tile * 8 <= w_capacity_bits

    fetch: list[Op] = []
    execu: list[Op] = []
    result: list[Op] = []
    fetched = written = 0.0

    if w_resident:
        fetched += nt_n * bytes_w_tile
        fetch.append(Op(isa.FetchInstr(C, 0, 0, 0, addrs.wgt_base, 0,
                                       _clamp16(nt_n * bytes_w_tile)),
                        cycles=_dma_cycles(nt_n * bytes_w_tile, dev)))
        fetch.append(_send(C, isa.Engine.FETCH, isa.Engine.EXECUTE, "dsp.wall"))

    for i in range(nt_m):
        if i >= 2:
            fetch.append(_wait(C, isa.Engine.EXECUTE, isa.Engine.FETCH, "dsp.aslot"))
        fetched += bytes_a_tile
        fetch.append(Op(isa.FetchInstr(C, 0, 1, i % 2, addrs.act_base, i,
                                       _clamp16(bytes_a_tile)),
                        cycles=_dma_cycles(bytes_a_tile, dev)))
        fetch.append(_send(C, isa.Engine.FETCH, isa.Engine.EXECUTE, "dsp.atile"))
        if not w_resident:
            for j in range(nt_n):
                fetched += bytes_w_tile
                fetch.append(Op(isa.FetchInstr(C, 0, 0, j % 2, addrs.wgt_base, j,
                                               _clamp16(bytes_w_tile)),
                                cycles=_dma_cycles(bytes_w_tile, dev)))
                fetch.append(_send(C, isa.Engine.FETCH, isa.Engine.EXECUTE,
                                   "dsp.wtile"))

    if w_resident:
        execu.append(_wait(C, isa.Engine.FETCH, isa.Engine.EXECUTE, "dsp.wall"))
    for i in range(nt_m):
        execu.append(_wait(C, isa.Engine.FETCH, isa.Engine.EXECUTE, "dsp.atile"))
        for j in range(nt_n):
            if not w_resident:
                execu.append(_wait(C, isa.Engine.FETCH, isa.Engine.EXECUTE,
                                   "dsp.wtile"))
            execu.append(Op(isa.ExecuteInstr(
                C, buf_addr_a=i & 0xFFFF, buf_addr_w=j & 0xFFFF,
                tile_m=min(4095, R), tile_k=min(65535, g.k),
                tile_n=cfg.n_reg_col_w, bits_w=4, bits_a=4,
                accumulate=0), cycles=tile_exec))
            execu.append(_send(C, isa.Engine.EXECUTE, isa.Engine.RESULT, "dsp.res"))
        execu.append(_send(C, isa.Engine.EXECUTE, isa.Engine.FETCH, "dsp.aslot"))

    for i in range(nt_m):
        for j in range(nt_n):
            result.append(_wait(C, isa.Engine.EXECUTE, isa.Engine.RESULT, "dsp.res"))
            written += bytes_out_tile
            result.append(Op(isa.ResultInstr(C, 0, 2, 0, addrs.out_base,
                                             (i * nt_n + j) & 0xFFFFFF,
                                             _clamp16(bytes_out_tile)),
                             cycles=_dma_cycles(bytes_out_tile, dev)))

    return CoreProgram(
        core=C,
        streams={"fetch": fetch, "execute": execu, "result": result},
        initial_tokens={"dsp.aslot": 1},
        bytes_fetched=fetched, bytes_written=written)


# ---------------------------------------------------------------------------
# Neuron split on raw GEMM dims (Eq. 12 over the closed-form curves)
# ---------------------------------------------------------------------------


def solve_split_dims(g: GemmDims, depthwise: bool, lut_cfg: LutCoreConfig,
                     dsp_cfg: DspCoreConfig, dev: FPGADevice,
                     bits_w_lut: int, bits_a: int) -> int:
    """Exact Eq.-(12) argmin over n_lut in {0..n}; the curves come from
    ``core/split.py`` so the DSE and the compiler share one solver."""
    _, _, makespan = split_curves(g, depthwise, lut_cfg, dsp_cfg, dev,
                                  bits_w_lut, bits_a)
    return int(np.argmin(makespan))


# ---------------------------------------------------------------------------
# Whole-network lowering
# ---------------------------------------------------------------------------


def _barrier(core: isa.CoreSel, ch: str) -> tuple[Op, Op]:
    send = _send(core, isa.Engine.RESULT, isa.Engine.FETCH, ch)
    wait = _wait(core, isa.Engine.RESULT, isa.Engine.FETCH, ch)
    return send, wait


def _requant_bits(layers: list[GemmLayer], ba: list[int], i: int) -> int:
    """Write-back code width of conv layer ``i``: the activation
    bit-width of its first consumer — a later layer whose activation
    read (``geometry.src_offset``) or residual add reaches ``i``.
    Returns 0 for the final layer (no consumer: raw fp32 logits)."""
    for j in range(i + 1, len(layers)):
        gj = layers[j].geometry
        if j - (gj.src_offset if gj is not None else 1) == i:
            return ba[j]
        for op in layers[j].elementwise:
            if op.kind == "add" and j - op.src_offset == i:
                return ba[j]
    return 0


def lower_network(name: str, layers: list[GemmLayer],
                  lut_cfg: LutCoreConfig, dsp_cfg: DspCoreConfig,
                  dev: FPGADevice,
                  bits_w_lut: int | list[int] = 4,
                  bits_a: int | list[int] = 4,
                  n_luts: list[int] | None = None,
                  opt_level: int = 0,
                  plan=None,
                  step: StepSpec | None = None) -> Program:
    """Compile a whole network into a :class:`Program`.

    ``step`` (a :class:`~repro.compiler.program.StepSpec`) switches to
    *decode mode*: ``layers`` must be the m=batch single-step GEMM
    list, and the lowered program is decorated with the invocation
    contract — weight segments become ``weights``-resident, attention
    k/v projections gain persistent ``kv`` cache segments (stage-4
    append at the step position, stage-5 read-back before the output
    projection) and SSM blocks a persistent ``state`` segment — before
    the optimization pipeline runs (see :func:`decorate_decode`;
    :func:`steady_program` derives the warm-cache variant whose weight
    fetches are elided).

    ``plan`` (a ``partition.PartitionPlan``) switches to the
    multi-device path: the network is partitioned per the plan and a
    ``MultiDeviceProgram`` bundle of per-device programs with
    cross-device Sync channels is returned instead (a 1-device plan
    reproduces the single program bit for bit).

    Per layer: pick the neuron split (given ``n_luts`` or solved via
    Eq. 12), partition the GEMM along output filters, lower each
    partition on its core, and allocate DDR segments for weights and
    the activation chain. Plain GEMM layers read their producer's
    output segment directly (layer i reads layer i-1's output). Conv
    layers (a :class:`~repro.compiler.program.ConvGeometry` on the
    ``GemmLayer``) read the *spatial* NHWC segment of the producer
    named by ``geometry.src_offset`` (falling back to ``act.in`` when
    it precedes the program): the fused kernels generate im2col
    patches on chip, so no ``L{i}.col`` staging copy exists in the DDR
    map and the act-fetch DMA accounting covers only the raw spatial
    footprint. Layers are chained inter-layer synchronously: each
    core's fetch stream for layer i>0 opens with a barrier wait
    matched by a barrier send at the tail of its layer i-1 result
    stream.

    ``opt_level=0`` returns the canonical schedule; ``opt_level=1``
    additionally runs the ``passes.py`` optimization pipeline (the
    per-pass accounting lands on ``Program.opt_stats``).
    """
    if plan is not None:
        # deferred import: partition.py builds on this lowerer
        from repro.compiler.partition import lower_partitioned
        return lower_partitioned(name, layers, plan, lut_cfg, dsp_cfg,
                                 dev, bits_w_lut=bits_w_lut, bits_a=bits_a,
                                 n_luts=n_luts, opt_level=opt_level)
    nl = len(layers)
    bw = list(bits_w_lut) if isinstance(bits_w_lut, (list, tuple)) \
        else [bits_w_lut] * nl
    ba = list(bits_a) if isinstance(bits_a, (list, tuple)) else [bits_a] * nl
    if len(bw) != nl or len(ba) != nl:
        raise ValueError("per-layer bit lists must match the layer count")
    for i, (w, a) in enumerate(zip(bw, ba)):
        # paper range is 2-8 (and the ISA bit-width fields are 4 bits)
        if not (2 <= w <= 8 and 2 <= a <= 8):
            raise ValueError(
                f"layer {i}: bit-widths must be in 2..8, got "
                f"bits_w_lut={w} bits_a={a}")

    mem = MemoryMap()
    if nl and layers[0].geometry is not None:
        # conv programs ingest the spatial NHWC tensor, not its im2col
        geo0 = layers[0].geometry
        in_bytes = math.ceil(geo0.in_hw * geo0.in_hw * geo0.c_in
                             * ba[0] / 8)
    else:
        in_bytes = math.ceil(layers[0].dims.m * layers[0].dims.k
                             * ba[0] / 8) if nl else 0
    in_seg = mem.alloc("act.in", in_bytes)

    progs: list[LayerProgram] = []
    out_segs: list = []
    for i, layer in enumerate(layers):
        g = layer.dims
        geom = layer.geometry
        if n_luts is not None:
            n_lut = int(min(max(n_luts[i], 0), g.n))
        else:
            n_lut = solve_split_dims(g, layer.depthwise, lut_cfg, dsp_cfg,
                                     dev, bw[i], ba[i])
        g_lut = GemmDims(g.m, g.k, n_lut)
        g_dsp = GemmDims(g.m, g.k, g.n - n_lut)

        wgt_lut = mem.alloc(f"L{i}.wgt.lut",
                            math.ceil(g.k * g_lut.n * bw[i] / 8))
        wgt_dsp = mem.alloc(f"L{i}.wgt.dsp", math.ceil(g.k * g_dsp.n * 4 / 8))
        if geom is not None:
            # fused conv path: act fetches read the producer's spatial
            # NHWC segment directly; im2col happens inside the kernel,
            # so neither DDR nor DMA ever sees the column matrix.
            src = i - geom.src_offset
            act_seg = out_segs[src] if src >= 0 else in_seg
            act_bytes = math.ceil(geom.in_hw * geom.in_hw * geom.c_in
                                  * ba[i] / 8)
        else:
            src = i - 1
            act_seg = out_segs[src] if src >= 0 else in_seg
            act_bytes = None
        out_seg = mem.alloc(f"L{i}.out", math.ceil(g.m * g.n * ba[i] / 8))

        lut_cp = dsp_cp = None
        if g_lut.n > 0:
            lut_cp = lower_lut_layer(
                g_lut, lut_cfg, dev, bw[i], ba[i], layer.depthwise,
                LayerAddrs(wgt_lut.base, act_seg.base, out_seg.base),
                act_bytes=act_bytes)
        if g_dsp.n > 0:
            dsp_cp = lower_dsp_layer(
                g_dsp, dsp_cfg, dev, layer.depthwise,
                LayerAddrs(wgt_dsp.base, act_seg.base, out_seg.base),
                act_bytes=act_bytes)

        # Fused elementwise result tail (conv chains only): the spec's
        # add/activation ops plus the write-back requant at the first
        # consumer's activation bit-width. Emitted as stage-6 DMAs on
        # the layer's first active core — sequential in its streams, so
        # the event-driven simulator times them with no extra channel.
        ew = tuple(layer.elementwise)
        if geom is not None:
            qb = _requant_bits(layers, ba, i)
            if qb:
                ew = ew + (ElementwiseOp("requant", bits=qb),)
        if ew and geom is not None:
            cp = lut_cp if lut_cp is not None else dsp_cp
            qbits = ew[-1].bits if ew[-1].kind == "requant" else 32
            phw = geom.pooled_hw()
            ew_out_bytes = math.ceil(phw * phw * geom.c_out * qbits / 8)
            for op in ew:
                if op.kind != "add":
                    continue
                src_res = i - op.src_offset
                res_seg = out_segs[src_res] if src_res >= 0 else in_seg
                res_bytes = math.ceil(g.m * g.n * ba[i] / 8)
                cp.streams["fetch"].append(
                    Op(isa.FetchInstr(cp.core, 0, EW_STAGE, 0,
                                      res_seg.base, 0, _clamp16(res_bytes)),
                       cycles=_dma_cycles(res_bytes, dev)))
                cp.bytes_fetched += res_bytes
            ew_cycles = (len(ew) * math.ceil(g.m * g.n / EW_LANES)
                         + _dma_cycles(ew_out_bytes, dev))
            cp.streams["result"].append(
                Op(isa.ResultInstr(cp.core, 0, EW_STAGE, 0, out_seg.base,
                                   len(ew) & 0xFFFFFF,
                                   _clamp16(ew_out_bytes)),
                   cycles=ew_cycles))
            cp.bytes_written += ew_out_bytes

        progs.append(LayerProgram(
            index=i, name=layer.name, dims=g, n_lut=n_lut,
            bits_w_lut=bw[i], bits_a=ba[i], depthwise=layer.depthwise,
            lut=lut_cp, dsp=dsp_cp, geometry=geom, elementwise=ew))
        out_segs.append(out_seg)

    # Inter-layer barriers (per core, when active on both sides).
    for prev, cur in zip(progs, progs[1:]):
        for attr, ch in (("lut", "lut.bar"), ("dsp", "dsp.bar")):
            p_cp, c_cp = getattr(prev, attr), getattr(cur, attr)
            if p_cp is None or c_cp is None:
                continue
            send, wait = _barrier(p_cp.core, ch)
            p_cp.streams["result"].append(send)
            c_cp.streams["fetch"].insert(0, wait)

    prog = Program(name=name, device=dev, lut_cfg=lut_cfg, dsp_cfg=dsp_cfg,
                   layers=progs, memory=mem)
    if step is not None:
        decorate_decode(prog, step)
    if opt_level:
        # deferred import: passes.py consumes Program, not the lowerer
        from repro.compiler.passes import optimize_program
        prog = optimize_program(prog, opt_level, copy_program=False)
    return prog


# ---------------------------------------------------------------------------
# Decode mode: residency decoration + steady-state weight-fetch elision
# ---------------------------------------------------------------------------


def _first_core(lp: LayerProgram) -> CoreProgram:
    return lp.lut if lp.lut is not None else lp.dsp


def _persistent_insert_at(cp: CoreProgram) -> int:
    """Index after the leading barrier/cross-device waits of a fetch
    stream — persistent reads slot in once the layer is released."""
    at = 0
    stream = cp.streams["fetch"]
    while at < len(stream) and isinstance(stream[at].instr, isa.SyncInstr):
        at += 1
    return at


def _persistent_append_at(cp: CoreProgram) -> int:
    """Index before the trailing barrier sends of a result stream —
    persistent appends land before the next layer is released."""
    stream = cp.streams["result"]
    at = len(stream)
    while at > 0 and isinstance(stream[at - 1].instr, isa.SyncInstr):
        at -= 1
    return at


def decorate_decode(prog: Program, step: StepSpec) -> Program:
    """Stamp the invocation contract onto a lowered m=batch program.

    Driven purely by layer names (so it applies unchanged to the
    per-device shards of a partitioned bundle, which keep them):

      * every ``L{i}.wgt.*`` segment becomes ``weights``-resident;
      * ``*.attn.k`` / ``*.attn.v`` layers allocate a persistent ``kv``
        segment (``max_seq`` rows of the requantized projection output)
        and append one row per invocation via a stage-4 Result at the
        step position;
      * ``*.attn.o`` layers read both caches of their block back
        through stage-5 Fetches (timed at the worst-case full window);
      * ``*.ssm.out`` layers allocate a per-block fp32 ``state``
        segment, read it at the fetch head and write it back in place
        at the result tail.
    """
    mem, dev = prog.memory, prog.device
    for seg in list(mem.segments):
        if ".wgt." in seg.name:
            mem.set_residency(seg.name, "weights")
    for lp in prog.layers:
        cp = _first_core(lp)
        if lp.name.endswith((".attn.k", ".attn.v")):
            row = math.ceil(step.batch * lp.dims.n * lp.bits_a / 8)
            seg = mem.alloc(f"{lp.name}.cache", step.max_seq * row,
                            residency="kv")
            cp.streams["result"].insert(
                _persistent_append_at(cp),
                Op(isa.ResultInstr(cp.core, 0, KV_APPEND_STAGE, 0,
                                   seg.base, 0, _clamp16(row)),
                   cycles=_dma_cycles(row, dev)))
            cp.bytes_written += row
        elif lp.name.endswith(".attn.o"):
            blk = lp.name.rsplit(".", 2)[0]
            at = _persistent_insert_at(cp)
            for which in ("k", "v"):
                cache = f"{blk}.attn.{which}.cache"
                if cache not in mem:
                    continue
                seg = mem[cache]
                cp.streams["fetch"].insert(
                    at, Op(isa.FetchInstr(cp.core, 0, KV_READ_STAGE, 0,
                                          seg.base, 0, _clamp16(seg.size)),
                           cycles=_dma_cycles(seg.size, dev)))
                cp.bytes_fetched += seg.size
                at += 1
        elif lp.name.endswith(".ssm.out"):
            # fp32 recurrent state, one row per batch lane, in-place
            nbytes = step.batch * lp.dims.k * 4
            seg = mem.alloc(f"{lp.name.rsplit('.', 1)[0]}.state", nbytes,
                            residency="state")
            cp.streams["fetch"].insert(
                _persistent_insert_at(cp),
                Op(isa.FetchInstr(cp.core, 0, KV_READ_STAGE, 0,
                                  seg.base, 0, _clamp16(nbytes)),
                   cycles=_dma_cycles(nbytes, dev)))
            cp.streams["result"].insert(
                _persistent_append_at(cp),
                Op(isa.ResultInstr(cp.core, 0, KV_APPEND_STAGE, 0,
                                   seg.base, 0, _clamp16(nbytes)),
                   cycles=_dma_cycles(nbytes, dev)))
            cp.bytes_fetched += nbytes
            cp.bytes_written += nbytes
    prog.step = step
    return prog


def steady_program(prog: Program) -> Program:
    """Derive the steady-state variant of a decode program: stage-0
    fetches into ``weights``-resident segments are elided along with
    their slot waits and ready sends, whose tokens are armed as initial
    tokens instead (the tiles are already on chip from the warm-up
    invocation). Persistent kv/state traffic and all activation
    movement survive — steady state moves only the new token.
    """
    if prog.step is None:
        raise ValueError("steady_program needs a decode program "
                         "(Program.step is None)")
    out = copy.deepcopy(prog)
    out.name = f"{prog.name}.steady"
    resident = {s.base for s in out.memory.segments
                if s.residency == "weights"}
    for lp in out.layers:
        for cp in lp.cores():
            kept: list[Op] = []
            for op in cp.streams["fetch"]:
                ins = op.instr
                if (isinstance(ins, isa.FetchInstr)
                        and ins.stage_ctrl == 0
                        and ins.ddr_base in resident):
                    cp.bytes_fetched -= max(
                        0.0, (op.cycles - prog.device.dma_setup_cycles)
                        * prog.device.dma_bytes_per_cycle)
                    continue
                if isinstance(ins, isa.SyncInstr):
                    if ins.is_wait and op.channel in _WEIGHT_FETCH_WAITS:
                        continue
                    if not ins.is_wait and op.channel in _WEIGHT_FETCH_SENDS:
                        cp.initial_tokens[op.channel] = \
                            cp.initial_tokens.get(op.channel, 0) + 1
                        continue
                kept.append(op)
            cp.streams["fetch"] = kept
    return out
