"""Pluggable executor backends for compiled Programs.

  base.py    — :class:`ExecutorBackend` interface + shared binding,
               validation, chaining; error taxonomy.
  golden.py  — :class:`GoldenExecutor`: contract-checking reference
               interpreter (bit-exact vs ``core/hetero_linear.py``).
  pallas.py  — :class:`PallasExecutor`: fused fast path, one
               split-aware ``kernels`` call per *layer* (im2col-free
               convs; per-program JIT cache keyed on the program
               fingerprint; ``fused=False`` for the per-partition
               batched path).
  multi.py   — :class:`MultiDeviceExecutor`: steps a
               ``partition.MultiDeviceProgram`` bundle, one backend
               executor per device, with the cross-device hand-off.

Select by name via :func:`get_backend` (the CLI's ``--backend`` flag
resolves here). To add a backend: subclass ``ExecutorBackend``,
implement ``_run_core``, and register it in :data:`BACKENDS`.
"""
from repro.compiler.runtime.base import (
    ExecutionError,
    ExecutorBackend,
    LayerWeights,
    apply_pool,
    bind_synthetic,
    chain_layers,
    im2col_patches,
    requantize,
    requantize_rows,
    spatialize,
    synthetic_weights,
)
from repro.compiler.runtime.golden import GoldenExecutor
from repro.compiler.runtime.multi import MultiDeviceExecutor, global_layers
from repro.compiler.runtime.pallas import PallasExecutor
from repro.compiler.runtime.session import (
    DecodeSession,
    ExecutorSession,
    ReferenceSession,
    decode_step_ref,
    synthetic_decode_arrays,
)

BACKENDS: dict[str, type[ExecutorBackend]] = {
    GoldenExecutor.name: GoldenExecutor,
    PallasExecutor.name: PallasExecutor,
}


def get_backend(name: str) -> type[ExecutorBackend]:
    """Resolve an executor backend class by registry name."""
    try:
        return BACKENDS[name]
    except KeyError:
        raise ValueError(
            f"unknown executor backend {name!r}; available: "
            f"{sorted(BACKENDS)}") from None


__all__ = [
    "BACKENDS", "DecodeSession", "ExecutionError", "ExecutorBackend",
    "ExecutorSession", "GoldenExecutor", "LayerWeights",
    "MultiDeviceExecutor", "PallasExecutor", "ReferenceSession",
    "apply_pool", "bind_synthetic", "chain_layers", "decode_step_ref",
    "get_backend", "global_layers", "im2col_patches", "requantize",
    "requantize_rows", "spatialize", "synthetic_decode_arrays",
    "synthetic_weights",
]
