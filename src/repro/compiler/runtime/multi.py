"""Multi-device executor: step a fleet of per-device programs.

Executes a :class:`~repro.compiler.partition.MultiDeviceProgram`
functionally by driving one ordinary backend executor (golden or
Pallas — any ``runtime.BACKENDS`` entry) per device and performing the
cross-device hand-offs the bundle's channel edges describe:

  * pipeline plans — activations flow device-to-device in stage order;
    the boundary requantization is exactly the inter-layer
    requantization of ``ExecutorBackend.run``, so a pipelined chain is
    bit-identical to running the single-device program;
  * filter plans — every device computes its shard of each layer from
    the same (gathered) full activations; concatenating shards in
    device order reproduces the single-device split column order
    exactly, because shards are contiguous in that order by
    construction (``partition.lower_partitioned``).

The token pairing itself is honored *by construction* of the execution
order (producers always complete before their edges' consumers run);
:func:`~repro.compiler.partition.validate_bundle` is run at
construction so a corrupt bundle fails before execution, not during.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.core.scheduler import GemmDims
from repro.compiler.program import ConvGeometry
from repro.compiler.runtime.base import (
    ExecutorBackend,
    chain_layers,
    synthetic_weights,
)


@dataclasses.dataclass(frozen=True)
class GlobalLayer:
    """Full-network view of one layer across the device fleet."""
    index: int
    name: str
    dims: GemmDims         # un-sharded GEMM extents
    n_lut: int             # full-layer neuron split (sum of shards)
    bits_w_lut: int
    bits_a: int
    depthwise: bool
    # [(device, local layer index, col_lo, col_hi)] in device order;
    # col bounds are split-column-order output bounds (filter plans
    # shard them; pipeline plans own the whole [0, n) range).
    placements: tuple[tuple[int, int, int, int], ...]
    # full-layer spatial geometry for conv layers (filter shards carry
    # channel-sharded per-device geometries; this is the global one)
    geometry: ConvGeometry | None = None
    # fused elementwise result tail (identical on every shard: the ops
    # are size-free, so the global chain applies them once, full-width)
    elementwise: tuple = ()


def global_layers(bundle) -> list[GlobalLayer]:
    """Build the full-network layer table for a bundle: un-sharded
    extents plus per-device placements. Shared by
    :class:`MultiDeviceExecutor` and the serving fleet (which shards
    full-layer weights onto remote workers without instantiating local
    executors)."""
    plan = bundle.plan
    out = []
    for gi in range(bundle.n_layers):
        owners = bundle.placements(gi)
        if plan.kind == "pipeline":
            d, li = owners[0]
            lp = bundle.devices[d].layers[li]
            placements = ((d, li, 0, lp.dims.n),)
            dims, n_lut = lp.dims, lp.n_lut
            geom = lp.geometry
        else:
            bounds = plan.shards[gi]
            placements = tuple((d, li, bounds[d], bounds[d + 1])
                               for d, li in owners)
            first = bundle.devices[0].layers[gi]
            dims = GemmDims(first.dims.m, first.dims.k, bounds[-1])
            n_lut = sum(bundle.devices[d].layers[li].n_lut
                        for d, li in owners)
            lp = first
            # un-shard the conv geometry: device programs carry the
            # local filter shard's channel counts
            geom = lp.geometry
            if geom is not None:
                n = bounds[-1]
                geom = dataclasses.replace(
                    geom, c_out=n,
                    c_in=n if lp.depthwise else geom.c_in)
        out.append(GlobalLayer(
            index=gi, name=lp.name, dims=dims, n_lut=n_lut,
            bits_w_lut=lp.bits_w_lut, bits_a=lp.bits_a,
            depthwise=lp.depthwise, placements=placements,
            geometry=geom, elementwise=lp.elementwise))
    return out


class MultiDeviceExecutor:
    """Functional executor over a compiled multi-device bundle."""

    def __init__(self, bundle, backend: str | type[ExecutorBackend]
                 = "golden", tracer=None, **backend_kwargs):
        from repro.compiler.partition import validate_bundle
        from repro.compiler.runtime import get_backend
        validate_bundle(bundle)
        self.bundle = bundle
        if tracer is None:
            from repro.obs import NULL_TRACER
            tracer = NULL_TRACER
        self.tracer = tracer
        cls = get_backend(backend) if isinstance(backend, str) else backend
        # per-device executors share the bundle's measured timeline
        self.executors = [cls(p, tracer=tracer, **backend_kwargs)
                          for p in bundle.devices]
        self.layers = global_layers(bundle)

    # -- weight binding ------------------------------------------------------

    def bind_layer(self, index: int, w_lut=None, s_lut=None,
                   w_dsp=None, s_dsp=None) -> None:
        """Bind *full-layer* weights (split column order: the Eq.-12
        LUT columns first, then the DSP columns) and shard them onto
        the owning devices per the plan."""
        gl = self.layers[index]
        L = gl.n_lut

        def _cols(w, s, n, what):
            if n == 0:
                if w is not None:
                    raise ValueError(
                        f"layer {index} has no {what} partition")
                return None, None
            w = jnp.asarray(w)
            s = jnp.asarray(s).reshape(-1)
            if w.shape[1] != n or s.shape[0] != n:
                raise ValueError(
                    f"layer {index} {what} weights must have {n} columns "
                    f"(full layer), got {w.shape}/{s.shape}")
            return w, s

        w_lut, s_lut = _cols(w_lut, s_lut, L, "lut")
        w_dsp, s_dsp = _cols(w_dsp, s_dsp, gl.dims.n - L, "dsp")
        for d, li, lo, hi in gl.placements:
            l0, l1 = min(lo, L), min(hi, L)          # lut column overlap
            d0, d1 = max(lo, L) - L, max(hi, L) - L  # dsp column overlap
            self.executors[d].bind_layer(
                li,
                w_lut=w_lut[:, l0:l1] if l1 > l0 else None,
                s_lut=s_lut[l0:l1] if l1 > l0 else None,
                w_dsp=w_dsp[:, d0:d1] if d1 > d0 else None,
                s_dsp=s_dsp[d0:d1] if d1 > d0 else None)

    def bind_synthetic(self, index: int, seed: int | None = None) -> None:
        """Full-layer synthetic weights, identical to what
        ``runtime.bind_synthetic`` binds on the single-device program
        (same RNG stream over the same full extents) — then sharded."""
        gl = self.layers[index]
        w_lut, s_lut, w_dsp, s_dsp = synthetic_weights(
            gl.index, gl.dims.k, gl.n_lut, gl.dims.n - gl.n_lut,
            gl.bits_w_lut, seed)
        self.bind_layer(index, w_lut=w_lut, s_lut=s_lut,
                        w_dsp=w_dsp, s_dsp=s_dsp)

    # -- execution -----------------------------------------------------------

    def run_layer(self, index: int, x_q) -> jnp.ndarray:
        """Execute one global layer on full activations: the staged
        [m, k] GEMM matrix, the spatial [in_hw, in_hw, c_in] tensor for
        conv layers, or the staged [m, k, n] stack for depthwise.

        Returns the *full* fp32 [m, n] output in single-device split
        column order: shards concatenate in device order (filter), or
        the owning stage computes the whole layer (pipeline).
        """
        gl = self.layers[index]
        x_q = jnp.asarray(x_q, jnp.int8)
        outs = []
        with self.tracer.measure("exec.multi", gl.name, layer=index,
                                 shards=len(gl.placements)):
            for d, li, lo, hi in gl.placements:
                if hi <= lo:
                    continue
                x_d = x_q
                if gl.depthwise and hi - lo != gl.dims.n:
                    # a filter shard of a depthwise layer only consumes
                    # its own channels' input slices — split column
                    # order is the natural channel order for depthwise
                    # (LUT columns are the first n_lut channels), so
                    # channel bounds slice both the spatial [h, w, C]
                    # and staged [m, k, N] forms
                    x_d = x_q[..., lo:hi]
                outs.append(self.executors[d].run_layer(li, x_d))
        return jnp.concatenate(outs, axis=1) if len(outs) > 1 else outs[0]

    def run(self, x_q, x_scale: float = 1.0) -> jnp.ndarray:
        """Chain all global layers through the same ``chain_layers``
        requantization + fused elementwise tail (and, for conv
        programs, spatial NHWC staging) as ``ExecutorBackend.run`` —
        the cross-device hand-off (pipeline boundary or filter gather)
        carries exactly what the single-device chain would."""
        return chain_layers(self.layers, self.run_layer, x_q,
                            x_scale=x_scale)
