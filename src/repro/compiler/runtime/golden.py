"""Golden-model interpreter backend (the reference executor).

Executes a compiled program *instruction by instruction*: the streams
drive real data movement and tile GEMMs against the reference numerics
of ``kernels/ref.py`` — bitplane (bit-serial) arithmetic for LUT-core
partitions, packed-int4 for DSP-core partitions — so the result is
bit-exact against ``core/hetero_linear.py``'s deployed integer path on
the same codes/scales.

The interpreter enforces the ISA contract along the way:

  * Fetch instructions must address the layer's DDR segments from the
    program's memory map (weights at ``L{i}.wgt.{core}``, activations
    at the producer's output segment — for conv layers the producer's
    *spatial* NHWC segment named by ``geometry.src_offset``, since the
    fused kernels im2col on chip and no staging copy exists);
  * every Execute must only consume weight tiles a prior Fetch brought
    on chip, and the tile count must cover the partition exactly;
  * Result instructions place output tiles by their DDR offset and must
    tile the output without overlap — a fused Result burst
    (``passes.DmaFusionPass``) drains ``max(1, onchip_base)``
    consecutive tiles;
  * the sync-token protocol is validated by running the event-driven
    scheduler over the same streams (a deadlock there is an executor
    error here).

This is the slow path: a Python loop per tile plus the per-core
simulation check. Use ``runtime/pallas.py`` to execute large programs
at speed.
"""
from __future__ import annotations

import math

import jax.numpy as jnp

from repro.core import isa
from repro.kernels import ops as kops
from repro.kernels import ref as kref
from repro.compiler.lower import EW_STAGE, KV_APPEND_STAGE, KV_READ_STAGE
from repro.compiler.program import CORE_NAMES, CoreProgram, LayerProgram
from repro.compiler.runtime.base import ExecutionError, ExecutorBackend


class GoldenExecutor(ExecutorBackend):
    """Contract-checking functional interpreter over a compiled program."""

    name = "golden"

    # -- core interpretation ----------------------------------------------

    def _segments(self, lp: LayerProgram, core_name: str):
        mem = self.program.memory
        wgt = mem[f"L{lp.index}.wgt.{core_name}"]
        if lp.geometry is not None:
            # conv layers fetch their producer's *spatial* NHWC segment
            # (im2col happens inside the fused kernel — no staged copy)
            src = lp.index - lp.geometry.src_offset
        else:
            src = lp.index - 1
        act = mem["act.in"] if src < 0 else mem[f"L{src}.out"]
        out = mem[f"L{lp.index}.out"]
        return wgt, act, out

    def _persistent_segment(self, lp: LayerProgram, base: int):
        """The kv/state-residency segment at ``base``, or None."""
        for seg in self.program.memory.segments:
            if seg.base == base and seg.residency in ("kv", "state"):
                return seg
        return None

    def _run_core(self, lp: LayerProgram, cp: CoreProgram, x_q,
                  w_codes, w_scales) -> jnp.ndarray:
        core_name = CORE_NAMES[cp.core]
        g_n = w_codes.shape[1]
        if core_name == "lut":
            tm, tn = self.program.lut_cfg.m, self.program.lut_cfg.n
            bits = lp.bits_w_lut
        else:
            tm, tn = self.program.dsp_cfg.n_reg_row_a, \
                self.program.dsp_cfg.n_reg_col_w
            bits = 4
        m = lp.dims.m
        nt_m = math.ceil(m / tm)
        nt_n = math.ceil(g_n / tn)
        wgt_seg, act_seg, out_seg = self._segments(lp, core_name)

        # 1. Fetch stream: record what lands on chip, check addressing.
        fetched_wtiles: set[int] = set()
        n_wgt_fetches = 0
        act_loaded = False
        for op in cp.streams["fetch"]:
            i = op.instr
            if not isinstance(i, isa.FetchInstr):
                continue
            if i.stage_ctrl == 0:                    # weight tile / wall
                if i.ddr_base != wgt_seg.base:
                    raise ExecutionError(
                        f"L{lp.index} {core_name}: weight fetch addresses "
                        f"{i.ddr_base:#x}, expected segment "
                        f"{wgt_seg.name}@{wgt_seg.base:#x}")
                n_wgt_fetches += 1
                # a fused burst (passes.DmaFusionPass) lands
                # max(1, onchip_base) consecutive tiles
                fetched_wtiles.update(range(
                    i.ddr_offset, i.ddr_offset + max(1, i.onchip_base)))
            elif i.stage_ctrl == 1:                  # activations
                if i.ddr_base != act_seg.base:
                    raise ExecutionError(
                        f"L{lp.index} {core_name}: activation fetch addresses "
                        f"{i.ddr_base:#x}, expected segment "
                        f"{act_seg.name}@{act_seg.base:#x}")
                act_loaded = True
            elif i.stage_ctrl == 3:                  # cross-device gather
                # filter-parallel plans (compiler/partition.py) stage
                # peer activation shards in a gather segment — issued at
                # the producing layer's fetch tail (overlap placement)
                # or the consuming layer's fetch head (legacy); the data
                # itself arrives via the link (the executor is handed
                # the gathered activations), so only the addressing
                # contract is checked here.
                mem = self.program.memory
                names = (f"L{lp.index}.gather", f"L{lp.index - 1}.gather")
                if not any(g in mem and i.ddr_base == mem[g].base
                           for g in names):
                    raise ExecutionError(
                        f"L{lp.index} {core_name}: gather fetch addresses "
                        f"{i.ddr_base:#x}, expected one of {names}")
            elif i.stage_ctrl == EW_STAGE:           # residual-add operand
                # the fused elementwise tail reads the add producer's
                # stored output codes; the chain hands the executor the
                # dequantized operand, so only the addressing contract
                # (some earlier layer's output segment, or the program
                # input) is checked here.
                mem = self.program.memory
                names = tuple(f"L{j}.out" for j in range(lp.index)) \
                    + ("act.in",)
                if not any(s in mem and i.ddr_base == mem[s].base
                           for s in names):
                    raise ExecutionError(
                        f"L{lp.index} {core_name}: elementwise residual "
                        f"fetch addresses {i.ddr_base:#x}, which is not "
                        f"an earlier layer's output segment")
            elif i.stage_ctrl == KV_READ_STAGE:      # persistent KV/state
                # decode programs (compiler/lower.py decorate_decode)
                # read the layer's live cache/state segment; the session
                # runtime carries the actual cache contents, so only the
                # addressing contract (a kv/state-residency segment) is
                # checked here.
                seg = self._persistent_segment(lp, i.ddr_base)
                if seg is None:
                    raise ExecutionError(
                        f"L{lp.index} {core_name}: persistent read "
                        f"addresses {i.ddr_base:#x}, which is not a "
                        f"kv/state segment")
            else:
                raise ExecutionError(
                    f"L{lp.index} {core_name}: fetch stage_ctrl="
                    f"{i.stage_ctrl} is not a defined buffer stage")
        if not act_loaded:
            raise ExecutionError(
                f"L{lp.index} {core_name}: no activation fetch in stream")
        # DSP whole-weight residency: a single stage-0 fetch at offset 0
        # DMAs the entire weight matrix, covering every column tile.
        if core_name == "dsp" and n_wgt_fetches == 1 and 0 in fetched_wtiles:
            fetched_wtiles.update(range(nt_n))
        # Steady-state decode residency: a weights-resident segment with
        # no fetch in the stream means the tiles stayed on chip from the
        # warm-up invocation (compiler/lower.py steady_program).
        if n_wgt_fetches == 0 and wgt_seg.residency == "weights":
            fetched_wtiles.update(range(nt_n))

        # 2. Execute stream: tile GEMMs through the reference numerics.
        tiles: dict[int, jnp.ndarray] = {}
        t = 0
        for op in cp.streams["execute"]:
            i = op.instr
            if not isinstance(i, isa.ExecuteInstr):
                continue
            if core_name == "lut":
                j, ti = divmod(t, nt_m)              # column-major schedule
            else:
                ti, j = divmod(t, nt_n)              # row-major schedule
            if j not in fetched_wtiles:
                raise ExecutionError(
                    f"L{lp.index} {core_name}: execute consumes weight tile "
                    f"{j} before any fetch brought it on chip")
            r0, r1 = ti * tm, min((ti + 1) * tm, m)
            c0, c1 = j * tn, min((j + 1) * tn, g_n)
            if lp.depthwise:
                # grouped GEMM: channels c0:c1 each contract their own
                # im2col slice of the staged [m, k, n_part] stack
                x_t = x_q[r0:r1, :, c0:c1]
                if core_name == "lut":
                    tile = kref.bitserial_grouped_gemm_ref(
                        x_t, w_codes[:, c0:c1], w_scales[c0:c1], bits)
                else:
                    tile = kref.int4_grouped_gemm_ref(
                        x_t, w_codes[:, c0:c1], w_scales[c0:c1])
            elif core_name == "lut":
                tile = kref.bitserial_gemm_ref(
                    x_q[r0:r1], w_codes[:, c0:c1], w_scales[c0:c1], bits)
            else:
                tile = kops.int4_matmul(
                    x_q[r0:r1], w_codes[:, c0:c1], w_scales[c0:c1],
                    mode="ref")
            tiles[(j * nt_m + ti) if core_name == "lut"
                  else (ti * nt_n + j)] = tile
            t += 1
        if t != nt_m * nt_n:
            raise ExecutionError(
                f"L{lp.index} {core_name}: {t} execute instructions do not "
                f"tile the [{m},{g_n}] partition ({nt_m}x{nt_n} expected)")

        # 3. Result stream: drain tiles to the output DDR segment. A
        # fused burst drains max(1, onchip_base) consecutive tiles.
        out = jnp.zeros((m, g_n), jnp.float32)
        placed: set[int] = set()
        for op in cp.streams["result"]:
            i = op.instr
            if not isinstance(i, isa.ResultInstr):
                continue
            if i.stage_ctrl == KV_APPEND_STAGE:      # persistent KV/state
                # decode programs append this step's K/V rows (or write
                # back the recurrent state) to a live cache segment; the
                # session runtime owns the contents — check addressing
                # only, and do not count it toward the output tiling.
                seg = self._persistent_segment(lp, i.ddr_base)
                if seg is None:
                    raise ExecutionError(
                        f"L{lp.index} {core_name}: persistent write "
                        f"addresses {i.ddr_base:#x}, which is not a "
                        f"kv/state segment")
                continue
            if i.stage_ctrl == EW_STAGE:             # fused elementwise tail
                # the stage-6 write-back re-quantizes the layer's final
                # (post add/act/pool) output into L{i}.out; the chain
                # computes the data (runtime/base.py apply_elementwise)
                # — check addressing only, outside the output tiling.
                if i.ddr_base != out_seg.base:
                    raise ExecutionError(
                        f"L{lp.index} {core_name}: elementwise write-back "
                        f"addresses {i.ddr_base:#x}, expected segment "
                        f"{out_seg.name}@{out_seg.base:#x}")
                continue
            if i.ddr_base != out_seg.base:
                raise ExecutionError(
                    f"L{lp.index} {core_name}: result writes {i.ddr_base:#x},"
                    f" expected segment {out_seg.name}@{out_seg.base:#x}")
            burst = max(1, i.onchip_base)
            for off in range(i.ddr_offset, i.ddr_offset + burst):
                if off in placed:
                    raise ExecutionError(
                        f"L{lp.index} {core_name}: result tile {off} written "
                        f"twice")
                if off not in tiles:
                    raise ExecutionError(
                        f"L{lp.index} {core_name}: result drains tile {off} "
                        f"which was never executed")
                placed.add(off)
                if core_name == "lut":
                    j, ti = divmod(off, nt_m)
                else:
                    ti, j = divmod(off, nt_n)
                r0, r1 = ti * tm, min((ti + 1) * tm, m)
                c0, c1 = j * tn, min((j + 1) * tn, g_n)
                out = out.at[r0:r1, c0:c1].set(tiles[off])
        if len(placed) != nt_m * nt_n:
            raise ExecutionError(
                f"L{lp.index} {core_name}: result stream drained "
                f"{len(placed)}/{nt_m * nt_n} tiles")
        return out
