"""Decode sessions: resident weights + live KV/state over an executor.

An autoregressive decode invocation is not one program run — it is a
*session*: weights are bound once and stay resident on chip, each
``step(token, pos)`` executes the per-token step program against live
cache buffers, and only the first invocation pays for the weight DMAs
(``compiler/lower.py`` decorate_decode / steady_program pair).

Two session flavors share all the inter-GEMM glue (embedding lookup,
causal attention over the KV cache, SiLU-gated MLPs and MoE routing,
the diagonal SSM recurrence, inter-unit requantization):

  * :class:`ExecutorSession` — drives a compiled backend
    (``GoldenExecutor``/``PallasExecutor`` over a decorated
    :class:`~repro.compiler.program.Program`, or a
    ``MultiDeviceExecutor`` over a decorated bundle). The first step
    runs the warm-up program (weight fetches included); every later
    step runs the steady-state program whose weight fetches are elided
    — the golden backend's contract checks then *prove* no weight DMA
    is re-issued.
  * :class:`ReferenceSession` — the plain-jax ``decode_step``
    reference: whole-layer ``kernels/ref.py`` GEMMs (no tiling, no ISA
    walk) through the identical glue. Bit-exactness of an
    ExecutorSession against this reference is the decode analogue of
    the repo's executor-vs-oracle parity tests.

The glue models the *functional* shape of a decode step over the
compiled projection GEMMs — causal softmax attention with GQA over an
int-coded KV cache, SiLU-gated MLPs, softmax-weighted MoE experts, a
gated diagonal SSM recurrence — but (like the layer walk in
``compiler/networks.py``) no norms or residual adds for the LM
decode path: the reference and the sessions apply exactly the same
glue, so parity is meaningful without modeling the full model
frontends. (CNN chains are different: their residual adds and
activations *are* modeled, as each layer's in-program fused
elementwise stage — see ``runtime/base.py`` ``chain_layers``.)
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.quant.uniform import _inv_hi, fit_scale, qrange
from repro.kernels import ops as kops
from repro.kernels import ref as kref
from repro.compiler.runtime.base import (
    ExecutionError,
    LayerWeights,
    requantize,
    requantize_rows,
    synthetic_weights,
)

#: donated in-place cache append for the pallas path: the previous
#: cache buffer is handed to XLA for reuse, so a long decode session
#: updates one device-side buffer instead of allocating per step.
_donated_append = jax.jit(lambda cache, row, pos: cache.at[pos].set(row),
                          donate_argnums=(0,))


@dataclasses.dataclass(frozen=True)
class _Unit:
    """One glue unit of the decode step: a run of consecutive layers
    (attention q/k/v/o, MLP gate/up/down, MoE router+experts, SSM
    in/out projections, or the lm head) plus the glue between them."""
    kind: str                  # "attn" | "mlp" | "moe" | "ssm" | "head"
    idxs: tuple[int, ...]


def _block_plan(layers) -> list[_Unit]:
    """Group a decode program's layer list into glue units by the
    naming convention of ``compiler/networks.py``."""
    units: list[_Unit] = []
    i, n = 0, len(layers)
    while i < n:
        name = layers[i].name
        if name == "lm_head":
            units.append(_Unit("head", (i,)))
            i += 1
        elif name.endswith(".attn.q"):
            units.append(_Unit("attn", tuple(range(i, i + 4))))
            i += 4
        elif name.endswith(".ssm.in_zx"):
            units.append(_Unit("ssm", tuple(range(i, i + 4))))
            i += 4
        elif name.endswith(".mlp.gate"):
            units.append(_Unit("mlp", tuple(range(i, i + 3))))
            i += 3
        elif name.endswith(".mlp.router"):
            idxs = [i]
            i += 1
            while i < n and (".mlp.e" in layers[i].name
                             or ".mlp.shared." in layers[i].name):
                idxs.append(i)
                i += 1
            units.append(_Unit("moe", tuple(idxs)))
        else:
            raise ExecutionError(
                f"decode session cannot place layer {name!r} in a glue "
                f"unit (attn/mlp/moe/ssm/head naming expected)")
    return units


def _quant_with_scale(x: jnp.ndarray, bits: int):
    """``requantize`` that also returns the max-abs scale — cache rows
    are stored as integer codes (what the KV segment bytes hold) with
    their per-step dequant scale alongside."""
    s = fit_scale(x, bits)
    lo, hi = qrange(bits)
    return jnp.clip(jnp.round(x / s), lo, hi).astype(jnp.int8), s


def _quant_rows_with_scale(x: jnp.ndarray, bits: int):
    """Per-row twin of :func:`_quant_with_scale` (one scale per batch
    row, bit-identical to it at batch 1) for per-slot KV appends."""
    s = jnp.maximum(jnp.max(jnp.abs(x), axis=-1), 1e-8) * _inv_hi(bits)
    lo, hi_q = qrange(bits)
    q = jnp.clip(jnp.round(x / s[:, None]), lo, hi_q).astype(jnp.int8)
    return q, s


def synthetic_decode_arrays(layers, spec, seed: int | None = None
                            ) -> dict:
    """The exact arrays :meth:`DecodeSession.bind_synthetic_all` binds,
    as a flat name->ndarray dict (``L{i}.w_lut`` / ``L{i}.s_lut`` /
    ``L{i}.w_dsp`` / ``L{i}.s_dsp`` + ``embed``).

    One generation path shared by in-process binding and the serving
    fleet's wire shipping (``serve/protocol.pack_arrays``), so every
    worker binds byte-identical weight segments.
    """
    out: dict = {}
    for lp in layers:
        w_lut, s_lut, w_dsp, s_dsp = synthetic_weights(
            lp.index, lp.dims.k, lp.n_lut, lp.dims.n - lp.n_lut,
            lp.bits_w_lut, None if seed is None else seed + lp.index)
        for name, arr in (("w_lut", w_lut), ("s_lut", s_lut),
                          ("w_dsp", w_dsp), ("s_dsp", s_dsp)):
            if arr is not None:
                out[f"L{lp.index}.{name}"] = np.asarray(arr)
    bits = layers[0].bits_a
    vocab = layers[-1].dims.n
    rng = np.random.default_rng(10_000 + (seed or 0))
    lo, hi = qrange(bits)
    out["embed"] = rng.integers(lo, hi + 1, (vocab, spec.d_model))
    return out


class DecodeSession:
    """Shared decode-step state machine (glue + caches + embedding).

    Subclasses implement :meth:`_run_layer` (how one projection GEMM is
    computed) and :meth:`bind_layer`. ``step(token, pos)`` embeds the
    token, walks the glue units, and returns fp32 logits [batch,
    padded_vocab]; caches/state advance in place.
    """

    #: subclass tag used in tracer span names ("ref", "golden", ...)
    session_name = "base"

    def __init__(self, layers, spec, name: str, tracer=None):
        if spec is None:
            raise ExecutionError(
                f"{name}: program carries no StepSpec — compile it in "
                f"decode mode (lower_network(step=...))")
        if tracer is None:
            from repro.obs import NULL_TRACER
            tracer = NULL_TRACER
        self.tracer = tracer
        self.layers = list(layers)
        self.spec = spec
        self.program_name = name
        self.units = _block_plan(self.layers)
        self.pos = 0
        self.per_slot = False
        self._embed_table = None
        self._caches: dict[int, dict[str, jnp.ndarray]] = {}
        self.reset()

    # -- session state -----------------------------------------------------

    def reset(self, per_slot: bool | None = None) -> None:
        """Clear the KV caches / SSM states and rewind to position 0.
        Bound weights stay resident (a new sequence, not a new model).

        ``per_slot=True`` switches the session to slot-batched serving:
        the KV quant scales become per-slot (``[max_seq, batch]``
        instead of ``[max_seq]``) so each batch row can hold an
        unrelated request at its own position (:meth:`step_slots`),
        with :meth:`reset_slot` recycling one row for a new request.
        """
        if per_slot is not None:
            self.per_slot = bool(per_slot)
        S, B = self.spec.max_seq, self.spec.batch
        self.pos = 0
        self._caches = {}
        scale_shape = (S, B) if self.per_slot else (S,)
        for u_i, unit in enumerate(self.units):
            if unit.kind == "attn":
                n_kv = self.layers[unit.idxs[1]].dims.n
                self._caches[u_i] = {
                    "k": jnp.zeros((S, B, n_kv), jnp.int8),
                    "v": jnp.zeros((S, B, n_kv), jnp.int8),
                    "ks": jnp.zeros(scale_shape, jnp.float32),
                    "vs": jnp.zeros(scale_shape, jnp.float32),
                }
            elif unit.kind == "ssm":
                d_inner = self.layers[unit.idxs[3]].dims.k
                self._caches[u_i] = {
                    "state": jnp.zeros((B, d_inner), jnp.float32)}

    def reset_slot(self, slot: int) -> None:
        """Recycle one batch row for a newly admitted request: zero its
        KV cache columns, quant scales and SSM state rows. The other
        slots' in-flight requests are untouched (continuous batching
        admits at step boundaries without draining the batch)."""
        if not self.per_slot:
            raise ExecutionError(
                "reset_slot needs per-slot mode (reset(per_slot=True))")
        if not 0 <= slot < self.spec.batch:
            raise ExecutionError(
                f"slot {slot} outside [0, {self.spec.batch})")
        for u_i, unit in enumerate(self.units):
            c = self._caches.get(u_i)
            if unit.kind == "attn":
                c["k"] = c["k"].at[:, slot].set(0)
                c["v"] = c["v"].at[:, slot].set(0)
                c["ks"] = c["ks"].at[:, slot].set(0.0)
                c["vs"] = c["vs"].at[:, slot].set(0.0)
            elif unit.kind == "ssm":
                c["state"] = c["state"].at[slot].set(0.0)

    def bind_embedding(self, table) -> None:
        """Bind the token-embedding code table [vocab, d_model] int8
        (codes at the first layer's ``bits_a``)."""
        table = jnp.asarray(table, jnp.int8)
        if table.ndim != 2 or table.shape[1] != self.spec.d_model:
            raise ExecutionError(
                f"embedding table must be [vocab, {self.spec.d_model}], "
                f"got {tuple(table.shape)}")
        self._embed_table = table

    def bind_synthetic_all(self, seed: int | None = None) -> None:
        """Bind deterministic synthetic weights for every layer plus a
        synthetic embedding table — the same generation for every
        session flavor, so parity tests compare identical models."""
        self.bind_arrays(
            synthetic_decode_arrays(self.layers, self.spec, seed))

    def bind_arrays(self, arrays: dict) -> None:
        """Bind every layer + the embedding table from a flat
        name->array dict (the :func:`synthetic_decode_arrays` layout —
        also what arrives over the fleet wire protocol)."""
        for lp in self.layers:
            self.bind_layer(
                lp.index,
                w_lut=arrays.get(f"L{lp.index}.w_lut"),
                s_lut=arrays.get(f"L{lp.index}.s_lut"),
                w_dsp=arrays.get(f"L{lp.index}.w_dsp"),
                s_dsp=arrays.get(f"L{lp.index}.s_dsp"))
        self.bind_embedding(arrays["embed"])

    # -- the decode step ---------------------------------------------------

    def step(self, token, pos: int | None = None) -> jnp.ndarray:
        """Run one decode step: embed ``token`` ([batch] int32 or a
        scalar), advance the caches at ``pos`` (default: the session's
        running position) and return fp32 logits [batch, vocab]."""
        if self.per_slot:
            raise ExecutionError(
                "scalar step() on a per-slot session — use "
                "step_slots(tokens, pos) or reset(per_slot=False)")
        pos = self.pos if pos is None else int(pos)
        if not 0 <= pos < self.spec.max_seq:
            raise ExecutionError(
                f"step position {pos} outside the session's "
                f"[0, {self.spec.max_seq}) cache window")
        x = self._embed_tokens(token)
        logits = None
        for u_i, unit in enumerate(self.units):
            out = self._run_unit(u_i, unit, x, pos)
            if unit.kind == "head":
                logits = out
                break
            nxt = self.units[u_i + 1]
            x = requantize(out, self.layers[nxt.idxs[0]].bits_a)
        self.pos = pos + 1
        return logits

    def step_slots(self, tokens, pos) -> jnp.ndarray:
        """One continuous-batching step: slot ``j`` embeds ``tokens[j]``
        and advances its caches at its own ``pos[j]``.

        The slot-batched twin of :meth:`step`: every reduction that
        :meth:`step` takes per tensor (inter-unit requant scales, KV
        quant scales, the causal mask, cache appends) is taken per
        batch row here, so slot ``j``'s logits are bit-identical to a
        batch-1 session serving that request alone — the property the
        serving fleet's bit-exactness gate rests on. Requires
        ``reset(per_slot=True)``; the caller owns per-slot positions
        (``self.pos`` does not advance).
        """
        if not self.per_slot:
            raise ExecutionError(
                "step_slots needs per-slot mode (reset(per_slot=True))")
        B = self.spec.batch
        pos_arr = np.asarray(pos, np.int64).reshape(-1)
        if pos_arr.shape[0] != B:
            raise ExecutionError(
                f"step_slots pos must be [{B}], got {pos_arr.shape}")
        if pos_arr.min() < 0 or pos_arr.max() >= self.spec.max_seq:
            raise ExecutionError(
                f"slot positions {pos_arr.tolist()} outside the "
                f"session's [0, {self.spec.max_seq}) cache window")
        pos_v = jnp.asarray(pos_arr, jnp.int32)
        x = self._embed_tokens(tokens)
        for u_i, unit in enumerate(self.units):
            if unit.kind == "head":
                return self._run_layer(unit.idxs[0], x)
            if unit.kind == "attn":
                out = self._attn_unit_slots(u_i, unit, x, pos_v)
            elif unit.kind == "ssm":
                out = self._ssm_unit_slots(u_i, unit, x)
            elif unit.kind == "mlp":
                out = self._mlp_rows(unit.idxs, x)
            else:
                out = self._moe_unit_slots(unit, x)
            nxt = self.units[u_i + 1]
            x = requantize_rows(out, self.layers[nxt.idxs[0]].bits_a)
        return None

    def _embed_tokens(self, token) -> jnp.ndarray:
        B = self.spec.batch
        tok = jnp.asarray(token, jnp.int32).reshape(-1)
        if tok.shape[0] == 1 and B > 1:
            tok = jnp.broadcast_to(tok, (B,))
        if tok.shape[0] != B:
            raise ExecutionError(
                f"step token must be scalar or [{B}], got "
                f"{tuple(tok.shape)}")
        if self._embed_table is None:
            raise ExecutionError(
                "no embedding table bound (bind_embedding / "
                "bind_synthetic_all)")
        return self._embed_table[tok]

    # -- glue units --------------------------------------------------------

    def _run_unit(self, u_i: int, unit: _Unit, x_q, pos: int):
        if unit.kind == "head":
            return self._run_layer(unit.idxs[0], x_q)
        if unit.kind == "attn":
            return self._attn_unit(u_i, unit, x_q, pos)
        if unit.kind == "ssm":
            return self._ssm_unit(u_i, unit, x_q)
        if unit.kind == "mlp":
            return self._mlp(unit.idxs, x_q)
        return self._moe_unit(unit, x_q)

    def _mlp(self, idxs, x_q):
        ig, iu, idn = idxs
        h = jax.nn.silu(self._run_layer(ig, x_q)) * self._run_layer(iu, x_q)
        return self._run_layer(idn, requantize(h, self.layers[idn].bits_a))

    def _moe_unit(self, unit: _Unit, x_q):
        router_logits = self._run_layer(unit.idxs[0], x_q)
        experts, shared = [], None
        for j in range(1, len(unit.idxs), 3):
            triple = unit.idxs[j:j + 3]
            if ".mlp.shared." in self.layers[triple[0]].name:
                shared = triple
            else:
                experts.append(triple)
        # the compiled program carries the top_k routed experts as
        # static layers e0..e{k-1} (the compute that fires per token);
        # weight them by the router's softmax renormalized over them
        w = jax.nn.softmax(router_logits, axis=-1)[:, :len(experts)]
        w = w / jnp.sum(w, axis=-1, keepdims=True)
        out = jnp.zeros((self.spec.batch, self.spec.d_model), jnp.float32)
        for e, triple in enumerate(experts):
            out = out + w[:, e:e + 1] * self._mlp(triple, x_q)
        if shared is not None:
            out = out + self._mlp(shared, x_q)
        return out

    def _attn_unit(self, u_i: int, unit: _Unit, x_q, pos: int):
        iq, ik, iv, io = unit.idxs
        q = self._run_layer(iq, x_q)
        k = self._run_layer(ik, x_q)
        v = self._run_layer(iv, x_q)
        c = self._caches[u_i]
        bits_kv = self.layers[ik].bits_a
        kq, ks = _quant_with_scale(k, bits_kv)
        vq, vs = _quant_with_scale(v, bits_kv)
        c["k"] = self._cache_set(c["k"], kq, pos)
        c["v"] = self._cache_set(c["v"], vq, pos)
        c["ks"] = c["ks"].at[pos].set(ks)
        c["vs"] = c["vs"].at[pos].set(vs)
        ctx = self._attn_ctx(q, c, pos)
        return self._run_layer(io, requantize(ctx, self.layers[io].bits_a))

    def _attn_ctx(self, q, cache, pos: int):
        """Causal GQA softmax attention over the coded KV cache."""
        spec = self.spec
        B, hq, hkv, hd = spec.batch, spec.n_heads, spec.n_kv_heads, \
            spec.head_dim
        S = cache["k"].shape[0]
        kf = cache["k"].astype(jnp.float32) * cache["ks"][:, None, None]
        vf = cache["v"].astype(jnp.float32) * cache["vs"][:, None, None]
        qh = q.reshape(B, hq, hd)
        kh = jnp.repeat(kf.reshape(S, B, hkv, hd), hq // hkv, axis=2)
        vh = jnp.repeat(vf.reshape(S, B, hkv, hd), hq // hkv, axis=2)
        scores = jnp.einsum("bhd,sbhd->bhs", qh, kh) / math.sqrt(hd)
        mask = (jnp.arange(S) <= pos)[None, None, :]
        weights = jax.nn.softmax(
            jnp.where(mask, scores, -jnp.inf), axis=-1)
        ctx = jnp.einsum("bhs,sbhd->bhd", weights, vh)
        return ctx.reshape(B, hq * hd)

    def _ssm_unit(self, u_i: int, unit: _Unit, x_q):
        """Gated diagonal recurrence over the persistent fp32 state —
        the in-place-updated analogue of the ``state`` segment the
        decode decoration allocates (batch x d_inner x 4 bytes)."""
        izx, ibc, idt, iout = unit.idxs
        zx = self._run_layer(izx, x_q)
        bc = self._run_layer(ibc, x_q)
        dt = self._run_layer(idt, x_q)
        d_inner = self.layers[iout].dims.k
        z, xin = zx[:, :d_inner], zx[:, d_inner:]
        decay = jnp.repeat(jax.nn.sigmoid(dt), d_inner // dt.shape[1],
                           axis=1)
        state = self._caches[u_i]["state"]
        state = decay * state + (1.0 - decay) * jax.nn.silu(xin)
        self._caches[u_i]["state"] = state
        gate = 1.0 + jnp.tanh(jnp.mean(bc, axis=-1, keepdims=True))
        y = state * jax.nn.silu(z) * gate
        return self._run_layer(iout, requantize(y, self.layers[iout].bits_a))

    def _cache_set(self, cache, row, pos: int):
        return cache.at[pos].set(row)

    # -- per-slot glue (continuous batching) -------------------------------
    #
    # Row-independent twins of the units above: identical math, but no
    # reduction ever crosses batch rows and each row indexes the caches
    # at its own position. With a single slot they reduce to exactly
    # the scalar-pos path (tested), which is what makes mixed-request
    # batches bit-exact per request.

    def _mlp_rows(self, idxs, x_q):
        ig, iu, idn = idxs
        h = jax.nn.silu(self._run_layer(ig, x_q)) * self._run_layer(iu, x_q)
        return self._run_layer(
            idn, requantize_rows(h, self.layers[idn].bits_a))

    def _moe_unit_slots(self, unit: _Unit, x_q):
        router_logits = self._run_layer(unit.idxs[0], x_q)
        experts, shared = [], None
        for j in range(1, len(unit.idxs), 3):
            triple = unit.idxs[j:j + 3]
            if ".mlp.shared." in self.layers[triple[0]].name:
                shared = triple
            else:
                experts.append(triple)
        w = jax.nn.softmax(router_logits, axis=-1)[:, :len(experts)]
        w = w / jnp.sum(w, axis=-1, keepdims=True)
        out = jnp.zeros((self.spec.batch, self.spec.d_model), jnp.float32)
        for e, triple in enumerate(experts):
            out = out + w[:, e:e + 1] * self._mlp_rows(triple, x_q)
        if shared is not None:
            out = out + self._mlp_rows(shared, x_q)
        return out

    def _attn_unit_slots(self, u_i: int, unit: _Unit, x_q, pos):
        iq, ik, iv, io = unit.idxs
        q = self._run_layer(iq, x_q)
        k = self._run_layer(ik, x_q)
        v = self._run_layer(iv, x_q)
        c = self._caches[u_i]
        bits_kv = self.layers[ik].bits_a
        kq, ks = _quant_rows_with_scale(k, bits_kv)
        vq, vs = _quant_rows_with_scale(v, bits_kv)
        bidx = jnp.arange(self.spec.batch)
        c["k"] = c["k"].at[pos, bidx].set(kq)
        c["v"] = c["v"].at[pos, bidx].set(vq)
        c["ks"] = c["ks"].at[pos, bidx].set(ks)
        c["vs"] = c["vs"].at[pos, bidx].set(vs)
        ctx = self._attn_ctx_slots(q, c, pos)
        return self._run_layer(
            io, requantize_rows(ctx, self.layers[io].bits_a))

    def _attn_ctx_slots(self, q, cache, pos):
        """Causal GQA attention with a per-slot causal horizon: row
        ``b`` attends to cache positions ``<= pos[b]`` and dequantizes
        with its own per-slot scales."""
        spec = self.spec
        B, hq, hkv, hd = spec.batch, spec.n_heads, spec.n_kv_heads, \
            spec.head_dim
        S = cache["k"].shape[0]
        kf = cache["k"].astype(jnp.float32) * cache["ks"][:, :, None]
        vf = cache["v"].astype(jnp.float32) * cache["vs"][:, :, None]
        qh = q.reshape(B, hq, hd)
        kh = jnp.repeat(kf.reshape(S, B, hkv, hd), hq // hkv, axis=2)
        vh = jnp.repeat(vf.reshape(S, B, hkv, hd), hq // hkv, axis=2)
        scores = jnp.einsum("bhd,sbhd->bhs", qh, kh) / math.sqrt(hd)
        mask = jnp.arange(S)[None, None, :] <= pos[:, None, None]
        weights = jax.nn.softmax(
            jnp.where(mask, scores, -jnp.inf), axis=-1)
        ctx = jnp.einsum("bhs,sbhd->bhd", weights, vh)
        return ctx.reshape(B, hq * hd)

    def _ssm_unit_slots(self, u_i: int, unit: _Unit, x_q):
        izx, ibc, idt, iout = unit.idxs
        zx = self._run_layer(izx, x_q)
        bc = self._run_layer(ibc, x_q)
        dt = self._run_layer(idt, x_q)
        d_inner = self.layers[iout].dims.k
        z, xin = zx[:, :d_inner], zx[:, d_inner:]
        decay = jnp.repeat(jax.nn.sigmoid(dt), d_inner // dt.shape[1],
                           axis=1)
        state = self._caches[u_i]["state"]
        state = decay * state + (1.0 - decay) * jax.nn.silu(xin)
        self._caches[u_i]["state"] = state
        gate = 1.0 + jnp.tanh(jnp.mean(bc, axis=-1, keepdims=True))
        y = state * jax.nn.silu(z) * gate
        return self._run_layer(
            iout, requantize_rows(y, self.layers[iout].bits_a))

    # -- subclass hooks ----------------------------------------------------

    def _run_layer(self, index: int, x_q) -> jnp.ndarray:
        raise NotImplementedError

    def bind_layer(self, index: int, w_lut=None, s_lut=None,
                   w_dsp=None, s_dsp=None) -> None:
        raise NotImplementedError


class ReferenceSession(DecodeSession):
    """The plain-jax ``decode_step`` reference for a compiled decode
    program: whole-layer reference GEMMs (``kernels/ref.py`` bit-serial
    + packed-int4 numerics — no tiling, no instruction walk) through
    the shared glue. The oracle every ExecutorSession must match
    bit-exactly."""

    session_name = "ref"

    def __init__(self, program, tracer=None):
        self._weights: dict[int, LayerWeights] = {}
        super().__init__(program.layers, program.step, program.name,
                         tracer)

    def bind_layer(self, index, w_lut=None, s_lut=None,
                   w_dsp=None, s_dsp=None) -> None:
        as_w = (lambda w, s: (jnp.asarray(w, jnp.int32),
                              jnp.asarray(s, jnp.float32).reshape(-1)))
        wl, sl = as_w(w_lut, s_lut) if w_lut is not None else (None, None)
        wd, sd = as_w(w_dsp, s_dsp) if w_dsp is not None else (None, None)
        self._weights[index] = LayerWeights(wl, sl, wd, sd)

    def _run_layer(self, index, x_q):
        lp = self.layers[index]
        wts = self._weights[index]
        x = jnp.asarray(x_q, jnp.int8)
        outs = []
        if wts.w_lut is not None:
            outs.append(kref.bitserial_gemm_ref(
                x, wts.w_lut, wts.s_lut, lp.bits_w_lut))
        if wts.w_dsp is not None:
            outs.append(kops.int4_matmul(
                x, wts.w_dsp, wts.s_dsp, mode="ref"))
        return jnp.concatenate(outs, axis=1) if len(outs) > 1 else outs[0]


def decode_step_ref(program, tracer=None) -> ReferenceSession:
    """Convenience constructor for the plain-jax decode reference."""
    return ReferenceSession(program, tracer=tracer)


class ExecutorSession(DecodeSession):
    """Decode session over compiled backends: bind weights once, then
    ``step(token, pos)`` repeatedly.

    ``program`` is a decode-decorated
    :class:`~repro.compiler.program.Program` (or a decorated
    ``MultiDeviceProgram`` bundle — the session then drives a
    ``MultiDeviceExecutor`` per phase). The first step executes the
    warm-up program (weight DMAs included); later steps execute the
    steady-state variant (``compiler/lower.py steady_program``) whose
    weight fetches are elided — on the golden backend the contract
    checks verify the steady program touches no weight segment.

    Each step is measured as an ``exec.<backend>.step`` tracer span
    tagged ``phase=warmup|steady``, so ``--profile`` separates the two
    regimes; ``serve.decode.tokens`` counts steps in ``obs.METRICS``.
    """

    def __init__(self, program, backend: str | type = "golden",
                 tracer=None, **backend_kwargs):
        from repro.compiler.partition import (MultiDeviceProgram,
                                              steady_bundle)
        from repro.compiler.lower import steady_program
        if isinstance(program, MultiDeviceProgram):
            from repro.compiler.runtime.multi import MultiDeviceExecutor
            spec = program.devices[0].step
            if spec is None:
                raise ExecutionError(
                    f"{program.name}: bundle is not decode-decorated "
                    f"(partition.decorate_decode_bundle)")
            self.steady = steady_bundle(program)
            self._warm_ex = MultiDeviceExecutor(
                program, backend=backend, tracer=tracer, **backend_kwargs)
            self._steady_ex = MultiDeviceExecutor(
                self.steady, backend=backend, tracer=tracer,
                **backend_kwargs)
            bname = backend if isinstance(backend, str) else backend.name
            self.session_name = f"multi.{bname}"
            layers = self._warm_ex.layers
        else:
            from repro.compiler.runtime import get_backend
            spec = program.step
            self.steady = steady_program(program)
            cls = get_backend(backend) if isinstance(backend, str) \
                else backend
            self._warm_ex = cls(program, tracer=tracer, **backend_kwargs)
            self._steady_ex = cls(self.steady, tracer=tracer,
                                  **backend_kwargs)
            self.session_name = self._warm_ex.name
            layers = program.layers
        self.warm = program
        self._warmed = False
        super().__init__(layers, spec, program.name, tracer)

    def bind_layer(self, index, w_lut=None, s_lut=None,
                   w_dsp=None, s_dsp=None) -> None:
        """Bind one layer's weights on both program variants (the
        steady program reuses the resident tiles the warm-up loaded)."""
        for ex in (self._warm_ex, self._steady_ex):
            ex.bind_layer(index, w_lut=w_lut, s_lut=s_lut,
                          w_dsp=w_dsp, s_dsp=s_dsp)

    def step(self, token, pos: int | None = None) -> jnp.ndarray:
        from repro.obs import METRICS
        pos = self.pos if pos is None else int(pos)
        phase = "steady" if self._warmed else "warmup"
        with self.tracer.measure(f"exec.{self.session_name}.step",
                                 self.program_name, pos=pos, phase=phase):
            logits = super().step(token, pos)
        self._warmed = True
        METRICS.incr("serve.decode.tokens")
        return logits

    def step_slots(self, tokens, pos) -> jnp.ndarray:
        from repro.obs import METRICS
        phase = "steady" if self._warmed else "warmup"
        with self.tracer.measure(f"exec.{self.session_name}.step_slots",
                                 self.program_name, phase=phase):
            logits = super().step_slots(tokens, pos)
        self._warmed = True
        METRICS.incr("serve.decode.tokens", self.spec.batch)
        return logits

    def _run_layer(self, index, x_q):
        ex = self._steady_ex if self._warmed else self._warm_ex
        return ex.run_layer(index, x_q)

    def _cache_set(self, cache, row, pos: int):
        # pallas path: donate the previous buffer so the cache is
        # updated in place device-side across the whole session
        if "pallas" in self.session_name:
            return _donated_append(cache, row, jnp.int32(pos))
        return cache.at[pos].set(row)
