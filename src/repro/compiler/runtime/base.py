"""Executor backend interface for compiled Programs.

A backend executes a :class:`~repro.compiler.program.Program`
*functionally* — integer activations in, fp32 split-order outputs out —
against real weight codes and dequant scales. Two implementations ship:

  * ``runtime/golden.py`` — the reference interpreter: walks the
    instruction streams tile by tile, enforcing the ISA/program
    contract along the way (bit-exact, slow);
  * ``runtime/pallas.py`` — the fused fast path: one
    ``kernels.fused_matmul`` / ``fused_conv_matmul`` call per *layer*
    covering both sides of the split (bit-identical outputs, orders of
    magnitude faster, Pallas kernels on TPU; ``fused=False`` restores
    the per-partition batched path).

This module holds everything backends share: weight binding and
validation, activation checks and im2col staging (conv layers accept
spatial NHWC tensors and are staged per their
:class:`~repro.compiler.program.ConvGeometry`; depthwise layers stage
one im2col slice per output channel), layer chaining with inter-layer
requantization (FC chains, and spatial NHWC conv chains that execute
each layer's in-program fused elementwise tail — residual add,
activation, pool glue, write-back requant — in absolute fp32 units),
and the error taxonomy.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.scheduler import simulate
from repro.quant.uniform import _inv_hi, fit_scale, qrange
from repro.compiler.program import CORE_NAMES, ConvGeometry, CoreProgram, \
    LayerProgram, Program


class ExecutionError(RuntimeError):
    """An instruction stream violated the ISA/program contract."""


# ---------------------------------------------------------------------------
# im2col activation staging (§3.2.1)
# ---------------------------------------------------------------------------


def im2col_patches(x_sp: jnp.ndarray, geom: ConvGeometry) -> jnp.ndarray:
    """Stage a spatial [in_hw, in_hw, C] tensor into im2col patches
    [m, kernel**2, C] (m = out_hw**2, output positions row-major, taps
    in (kh, kw) order). Zero padding — code 0 is real 0.0 under the
    symmetric quantizer.

    Dense convs flatten the last two axes to the [m, k] GEMM activation
    matrix with k = kernel**2 * C in (kh, kw, c) order — exactly the
    HWIO weight flattening ``w.reshape(k, n)`` contracts against.
    Depthwise layers keep the channel axis: slice c is the only input
    channel output channel c sees.

    Delegates to ``kernels.ref.conv_patches_ref`` — the single source
    for the patch layout, shared with the fused conv kernels' in-kernel
    im2col and their oracles.
    """
    from repro.kernels.ref import conv_patches_ref
    return conv_patches_ref(x_sp, geom.kernel, geom.stride, geom.pad,
                            geom.out_hw)


def spatialize(out: jnp.ndarray, geom: ConvGeometry) -> jnp.ndarray:
    """A layer's [m, n] output as the NHWC [out_hw, out_hw, c_out]
    spatial tensor the next layer's staging reads (batch 1)."""
    return jnp.asarray(out).reshape(geom.out_hw, geom.out_hw, geom.c_out)


def apply_pool(x_sp: jnp.ndarray, pool: str) -> jnp.ndarray:
    """Spatial pooling glue between conv layers: ``"max"`` is the
    ResNet stem's 3x3 stride-2 SAME max pool, ``"gap"`` the global
    average pool before the classifier. ``""`` is the identity.

    The output spatial extents must agree with the shape rule
    ``core.workloads.pooled_hw`` (the single source the spec scaling
    and ``ConvGeometry.pooled_hw`` both delegate to)."""
    if pool == "max":
        return jax.lax.reduce_window(x_sp, -jnp.inf, jax.lax.max,
                                     (3, 3, 1), (2, 2, 1), "SAME")
    if pool == "gap":
        return jnp.mean(x_sp, axis=(0, 1), keepdims=True)
    return x_sp


@dataclasses.dataclass
class LayerWeights:
    """Integer weight codes + per-column dequant scales for one layer,
    already split: LUT (bit-serial) columns first, DSP (int4) columns
    after — the same column order ``hetero_gemm_ref`` concatenates."""
    w_lut: jnp.ndarray | None      # [k, n_lut] int32 codes
    s_lut: jnp.ndarray | None      # [n_lut] fp32
    w_dsp: jnp.ndarray | None      # [k, n_dsp] int32 codes (int4 range)
    s_dsp: jnp.ndarray | None      # [n_dsp] fp32


class ExecutorBackend:
    """Functional executor over a compiled program.

    Subclasses implement :meth:`_run_core` — how one layer partition's
    tiles are actually computed. Everything else (binding, validation,
    chaining) is shared so backends are interchangeable and
    bit-comparable.
    """

    #: registry key; subclasses override ("golden", "pallas", ...)
    name = "base"

    def __init__(self, program: Program, check_timing: bool = True,
                 tracer=None):
        self.program = program
        self.check_timing = check_timing
        # measured (wall-clock) timeline sink; the null tracer keeps
        # every hook free when observability is off
        if tracer is None:
            from repro.obs import NULL_TRACER
            tracer = NULL_TRACER
        self.tracer = tracer
        self._weights: dict[int, LayerWeights] = {}

    # -- weight binding ----------------------------------------------------

    def bind_layer(self, index: int, w_lut=None, s_lut=None,
                   w_dsp=None, s_dsp=None) -> None:
        lp = self.program.layers[index]
        k, n_lut, n_dsp = lp.dims.k, lp.n_lut, lp.dims.n - lp.n_lut

        def _chk(w, s, n, what, bits):
            if n == 0:
                if w is not None:
                    raise ValueError(f"layer {index} has no {what} partition")
                return None, None
            w = jnp.asarray(w, jnp.int32)
            s = jnp.asarray(s, jnp.float32).reshape(-1)
            if w.shape != (k, n) or s.shape != (n,):
                raise ValueError(
                    f"layer {index} {what} weights must be [{k},{n}] "
                    f"(+[{n}] scales), got {w.shape}/{s.shape}")
            lo, hi = qrange(bits)
            if int(w.min()) < lo or int(w.max()) > hi:
                raise ValueError(f"layer {index} {what} codes exceed "
                                 f"{bits}-bit range [{lo},{hi}]")
            return w, s

        w_lut, s_lut = _chk(w_lut, s_lut, n_lut, "lut", lp.bits_w_lut)
        w_dsp, s_dsp = _chk(w_dsp, s_dsp, n_dsp, "dsp", 4)
        self._weights[index] = LayerWeights(w_lut, s_lut, w_dsp, s_dsp)

    def bind_deployed(self, index: int, deployed) -> None:
        """Bind from a ``hetero_linear.DeployedHeteroLinear`` (its column
        order is already LUT-first, matching the program split)."""
        lp = self.program.layers[index]
        self.bind_layer(
            index,
            w_lut=deployed.wq_serial if lp.n_lut else None,
            s_lut=deployed.s_serial if lp.n_lut else None,
            w_dsp=deployed.wq_parallel if lp.n_dsp else None,
            s_dsp=deployed.s_parallel if lp.n_dsp else None)

    # -- execution ---------------------------------------------------------

    def run_layer(self, index: int, x_q) -> jnp.ndarray:
        """Execute one layer on int8 activations.

        ``x_q`` is the pre-staged GEMM activation matrix [m, k] (plain
        GEMM layers and dense convs), the spatial NHWC tensor
        [in_hw, in_hw, c_in] for conv layers (staged here per the
        layer's geometry), or the pre-staged per-channel im2col stack
        [m, k, n] for depthwise layers.

        Returns fp32 [m, n] in split column order (LUT partition first),
        i.e. exactly ``kernels.ref.hetero_gemm_ref``'s layout — which
        for depthwise layers is the natural channel order (the Eq.-12
        split assigns the *first* ``n_lut`` filters to the LUT core).
        """
        lp = self.program.layers[index]
        if index not in self._weights:
            raise ExecutionError(f"layer {index} has no bound weights")
        x_q = self._staged_activations(lp, jnp.asarray(x_q, jnp.int8))
        wts = self._weights[index]

        def _slice(lo, hi):
            # depthwise channel c consumes im2col slice c: hand each
            # partition exactly its channels' slices
            return x_q[:, :, lo:hi] if lp.depthwise else x_q

        outs = []
        if lp.lut is not None:
            self._check_stream(lp, lp.lut)
            with self.tracer.measure(f"exec.{self.name}.lut", lp.name,
                                     layer=lp.index, n=lp.n_lut):
                outs.append(self._run_core(lp, lp.lut, _slice(0, lp.n_lut),
                                           wts.w_lut, wts.s_lut))
        if lp.dsp is not None:
            self._check_stream(lp, lp.dsp)
            with self.tracer.measure(f"exec.{self.name}.dsp", lp.name,
                                     layer=lp.index,
                                     n=lp.dims.n - lp.n_lut):
                outs.append(self._run_core(lp, lp.dsp,
                                           _slice(lp.n_lut, lp.dims.n),
                                           wts.w_dsp, wts.s_dsp))
        return jnp.concatenate(outs, axis=1) if len(outs) > 1 else outs[0]

    def _staged_activations(self, lp: LayerProgram,
                            x_q: jnp.ndarray) -> jnp.ndarray:
        """Normalize layer input to the staged im2col form: [m, k] for
        dense layers, [m, k, n] per-channel slices for depthwise."""
        m, k, n = lp.dims.m, lp.dims.k, lp.dims.n
        geom = lp.geometry
        if geom is not None and x_q.shape == geom.in_shape:
            pat = im2col_patches(x_q, geom)
            return pat if lp.depthwise else pat.reshape(m, k)
        if lp.depthwise:
            if x_q.shape != (m, k, n):
                want = (f"{geom.in_shape} spatial or " if geom else "")
                raise ExecutionError(
                    f"depthwise layer {lp.index} activations must be "
                    f"{want}[{m},{k},{n}] staged, got {tuple(x_q.shape)}")
            return x_q
        if x_q.shape != (m, k):
            want = (f"{geom.in_shape} spatial or " if geom else "")
            raise ExecutionError(
                f"layer {lp.index} activations must be {want}"
                f"[{m},{k}], got {tuple(x_q.shape)}")
        return x_q

    def _check_stream(self, lp: LayerProgram, cp: CoreProgram) -> None:
        """Validate the sync-token protocol (when ``check_timing``) by
        running the event-driven scheduler over the core's streams."""
        if not self.check_timing:
            return
        try:
            simulate(cp.streams, cp.sim_tokens())
        except RuntimeError as e:
            raise ExecutionError(
                f"layer {lp.index} {CORE_NAMES[cp.core]} streams "
                f"deadlock: {e}") from e

    def run(self, x_q, x_scale: float = 1.0) -> jnp.ndarray:
        """Chain all layers end to end.

        FC-style networks (GEMMs compose: n_i == k_{i+1}) chain the
        [m, n] outputs directly; conv programs (every layer carries a
        geometry) chain spatially — each layer's fp32 result is scaled
        to absolute units, run through its fused elementwise tail
        (residual add / activation / pool glue / write-back requant,
        see ``LayerProgram.elementwise``) and the stored codes are
        staged through im2col by the consumers its ``src_offset`` /
        add ``src_offset`` name. ``x_q`` is int8: [m, k] for FC
        chains, the spatial [in_hw, in_hw, c_in] input image for conv
        chains; ``x_scale`` is the input's dequant scale (conv chains
        return absolute fp32 logits for the final layer).
        """
        return chain_layers(self.program.layers, self.run_layer, x_q,
                            x_scale=x_scale,
                            tail_factory=self._elementwise_tail)

    def _elementwise_tail(self, lp: LayerProgram):
        """Tail callable for one conv layer — overridable: the Pallas
        backend returns a jitted, program-cached fused epilogue; the
        default runs the shared jnp tail eagerly."""
        return elementwise_tail(tuple(lp.elementwise),
                                lp.geometry.pool if lp.geometry else "")

    # -- backend hook ------------------------------------------------------

    def _run_core(self, lp: LayerProgram, cp: CoreProgram, x_q,
                  w_codes, w_scales) -> jnp.ndarray:
        """Compute one layer partition's [m, n_part] fp32 output."""
        raise NotImplementedError


def requantize(x: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Inter-layer write-back requantization: fp32 -> int8 codes at
    ``bits`` with a per-tensor max-abs scale (the chain's single
    bit-exactness-critical quantizer)."""
    s_a = fit_scale(x, bits)
    lo, hi = qrange(bits)
    return jnp.clip(jnp.round(x / s_a), lo, hi).astype(jnp.int8)


def requantize_with_scale(x: jnp.ndarray, bits: int):
    """:func:`requantize` that also returns the per-tensor scale — the
    spatial chain tracks (codes, scale) pairs so residual adds and the
    non-scale-invariant activations (relu6/hswish) run in absolute fp32
    units. Bit-identical codes to :func:`requantize`."""
    s_a = fit_scale(x, bits)
    lo, hi = qrange(bits)
    return jnp.clip(jnp.round(x / s_a), lo, hi).astype(jnp.int8), s_a


def apply_elementwise(y: jnp.ndarray, ops, residual=None) -> jnp.ndarray:
    """Apply the add/activation ops of a fused elementwise tail to a
    layer's absolute fp32 output ``y`` (``requant`` is the chain's job:
    it produces the (codes, scale) pair; pool glue applies between the
    activation and the requant).

    ``residual`` is the dequantized add operand (same shape as ``y``),
    required iff an ``add`` op is present. Shared verbatim by the eager
    golden/multi chains and the jitted Pallas epilogue so every backend
    computes the exact same tail.
    """
    for op in ops:
        if op.kind == "add":
            if residual is None:
                raise ExecutionError("elementwise add without a residual "
                                     "operand")
            y = y + residual
        elif op.kind == "relu":
            y = jnp.maximum(y, 0.0)
        elif op.kind == "relu6":
            y = jnp.clip(y, 0.0, 6.0)
        elif op.kind == "hswish":
            y = y * jnp.clip(y + 3.0, 0.0, 6.0) * (1.0 / 6.0)
        elif op.kind != "requant":
            raise ExecutionError(f"unknown elementwise kind {op.kind!r}")
    return y


def elementwise_tail(ops, pool: str):
    """Build the functional form of one layer's fused elementwise tail:
    ``tail(y_abs, residual=None) -> (y_post, codes, scale)`` — add/act
    ops, the geometry's ``pool`` glue, then the write-back ``requant``
    producing the stored (codes, scale) pair (``(y, None, None)`` when
    the tail carries no requant, i.e. the final layer). Pure jnp, so
    the Pallas backend jits it as the layer's fused epilogue while the
    golden chain runs it eagerly — same function, bit-identical."""
    ops = tuple(ops)
    rq = [op for op in ops if op.kind == "requant"]

    def tail(y, residual=None):
        y = apply_elementwise(y, ops, residual)
        y = apply_pool(y, pool)
        if rq:
            codes, scale = requantize_with_scale(y, rq[0].bits)
            return y, codes, scale
        return y, None, None
    return tail


def requantize_rows(x: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Row-independent twin of :func:`requantize`: one max-abs scale
    per batch row instead of per tensor.

    For a single-row input the scale reduction sees exactly the same
    elements as the per-tensor path, so the two are bit-identical at
    batch 1 — which is what lets slot-batched serving
    (``DecodeSession.step_slots``) mix unrelated requests in one batch
    while each slot stays bit-exact against a dedicated batch-1
    session.
    """
    lo, hi = qrange(bits)
    s_a = jnp.maximum(jnp.max(jnp.abs(x), axis=-1, keepdims=True),
                      1e-8) * _inv_hi(bits)
    return jnp.clip(jnp.round(x / s_a), lo, hi).astype(jnp.int8)


def chain_layers(layers, run_layer, x_q, x_scale: float = 1.0,
                 tail_factory=None):
    """Chain ``layers`` through ``run_layer(index, x_q)`` with the
    inter-layer requantization the hardware applies on write-back.

    The single source of truth for the bit-exactness-critical chain:
    ``ExecutorBackend.run`` drives it over one program's layers,
    ``MultiDeviceExecutor.run`` over a bundle's global layers — so the
    multi-device hand-off requantizes exactly like the single-device
    chain. ``layers`` items need ``.index``, ``.dims``, ``.bits_a``,
    ``.geometry`` and ``.elementwise``; when every layer carries a
    geometry the chain is spatial (NHWC reshape + the in-program fused
    elementwise tail + im2col staging, shortcut layers reading
    ``src_offset`` producers), otherwise the FC rule n_i == k_{i+1}
    applies (the LM sessions own that glue). ``tail_factory(lp)``
    overrides how a layer's elementwise tail callable is built (the
    Pallas backend supplies jitted fused epilogues); the default is
    the eager :func:`elementwise_tail`.
    """
    layers = list(layers)
    if layers and all(getattr(lp, "geometry", None) is not None
                      for lp in layers):
        return _chain_spatial(layers, run_layer, x_q, x_scale,
                              tail_factory)
    out = None
    for lp in layers:
        if out is not None:
            if out.shape[1] != lp.dims.k or out.shape[0] != lp.dims.m:
                raise ExecutionError(
                    f"layer {lp.index} expects [{lp.dims.m},{lp.dims.k}] "
                    f"activations but layer {lp.index - 1} produced "
                    f"{tuple(out.shape)}; run_layer() drives "
                    f"non-chaining programs layer by layer")
            x_q = requantize(out, lp.bits_a)
        out = run_layer(lp.index, x_q)
    return out


def _chain_spatial(layers, run_layer, x_q, x_scale: float,
                   tail_factory=None) -> jnp.ndarray:
    """Spatial NHWC chain over conv layers (resnet18/mobilenet_v2).

    Layer ``pos`` consumes the stored post-tail codes of layer
    ``pos - src_offset`` (the plain chain or a ResNet downsample
    shortcut reading the block input). The chain tracks a
    (codes, scale) pair per producer: a layer's GEMM result is first
    scaled to absolute fp32 units (``run_layer`` applies the weight
    scales but not the staged input's activation scale), then its
    in-program fused elementwise tail runs — residual add of the
    dequantized ``src_offset`` producer, activation, the geometry's
    ``pool`` glue, and the write-back ``requant`` that produces the
    codes + scale its consumers stage. Residual adds and relu6/hswish
    are not scale invariant, which is why the tail must run in
    absolute units rather than on raw codes. The final layer carries
    no requant: its absolute fp32 output (the logits) is returned.
    """
    if tail_factory is None:
        def tail_factory(lp):
            return elementwise_tail(
                tuple(getattr(lp, "elementwise", ()) or ()),
                lp.geometry.pool)
    # per-position (abs fp32 post-pool output, codes, scale); codes are
    # materialized lazily for programs predating the elementwise stage
    stored: list[list] = []

    def _stage(pos: int, bits: int):
        y_abs, codes, scale = stored[pos]
        if codes is None:
            codes, scale = requantize_with_scale(y_abs, bits)
            stored[pos][1:] = [codes, scale]
        return codes, scale

    for pos, lp in enumerate(layers):
        geom = lp.geometry
        ew = tuple(getattr(lp, "elementwise", ()) or ())
        if pos == 0:
            x_sp = jnp.asarray(x_q, jnp.int8)
            if x_sp.shape != geom.in_shape:
                raise ExecutionError(
                    f"conv chain input must be spatial "
                    f"{geom.in_shape}, got {tuple(x_sp.shape)}")
            s_in = jnp.float32(x_scale)
        else:
            src = pos - geom.src_offset
            if src < 0:
                raise ExecutionError(
                    f"layer {lp.index} reads producer {src}, which "
                    f"precedes the chain")
            x_sp, s_in = _stage(src, lp.bits_a)
            if x_sp.shape != geom.in_shape:
                raise ExecutionError(
                    f"layer {lp.index} expects spatial {geom.in_shape} "
                    f"but producer {src} yields {tuple(x_sp.shape)}")
        y = spatialize(run_layer(lp.index, x_sp), geom) * s_in
        residual = None
        for op in ew:
            if op.kind != "add":
                continue
            r = pos - op.src_offset
            if r < 0:
                raise ExecutionError(
                    f"layer {lp.index} adds producer {r}, which "
                    f"precedes the chain")
            r_codes, r_scale = _stage(r, lp.bits_a)
            if r_codes.shape != y.shape:
                raise ExecutionError(
                    f"layer {lp.index} residual add expects "
                    f"{tuple(y.shape)} but producer {r} yields "
                    f"{tuple(r_codes.shape)}")
            residual = r_codes.astype(jnp.float32) * r_scale
        y, codes, scale = tail_factory(lp)(y, residual)
        stored.append([y, codes, scale])
    # final layer: absolute fp32 logits in GEMM [rows, c_out] form
    return stored[-1][0].reshape(-1, layers[-1].geometry.c_out)


def synthetic_weights(index: int, k: int, n_lut: int, n_dsp: int,
                      bits_w_lut: int, seed: int | None = None):
    """Deterministic synthetic (w_lut, s_lut, w_dsp, s_dsp) for a layer.

    Codes span each partition's full quantized range; scales are a
    0.5..1.5 ramp so column mixups cannot cancel out. The generation
    depends only on (index-or-seed, k, n_lut, n_dsp, bits), so a
    multi-device executor sharding these full-layer weights sees
    exactly what a single-device executor binds (bit-exactness tests).
    """
    rng = np.random.default_rng(index if seed is None else seed)
    lo_w, hi_w = qrange(bits_w_lut)
    lo_d, hi_d = qrange(4)
    return (
        rng.integers(lo_w, hi_w + 1, (k, n_lut)) if n_lut else None,
        np.linspace(0.5, 1.5, n_lut, dtype=np.float32) if n_lut else None,
        rng.integers(lo_d, hi_d + 1, (k, n_dsp)) if n_dsp else None,
        np.linspace(0.5, 1.5, n_dsp, dtype=np.float32) if n_dsp else None,
    )


def bind_synthetic(ex: ExecutorBackend, lp: LayerProgram,
                   seed: int | None = None) -> None:
    """Bind deterministic synthetic weight codes/scales for one layer.

    Shared by the CLI ``--execute`` path, the executor benchmark and the
    pass-invariance tests, so the bind_layer contract has one call site
    to keep current.
    """
    w_lut, s_lut, w_dsp, s_dsp = synthetic_weights(
        lp.index, lp.dims.k, lp.n_lut, lp.dims.n - lp.n_lut,
        lp.bits_w_lut, seed)
    ex.bind_layer(lp.index, w_lut=w_lut, s_lut=s_lut,
                  w_dsp=w_dsp, s_dsp=s_dsp)
