"""Executor backend interface for compiled Programs.

A backend executes a :class:`~repro.compiler.program.Program`
*functionally* — integer activations in, fp32 split-order outputs out —
against real weight codes and dequant scales. Two implementations ship:

  * ``runtime/golden.py`` — the reference interpreter: walks the
    instruction streams tile by tile, enforcing the ISA/program
    contract along the way (bit-exact, slow);
  * ``runtime/pallas.py`` — the batched fast path: one
    ``kernels.bitserial_matmul`` / ``kernels.int4_matmul`` call per
    layer partition (bit-identical outputs, orders of magnitude faster,
    Pallas kernels on TPU).

This module holds everything backends share: weight binding and
validation, activation checks, layer chaining with inter-layer
requantization, and the error taxonomy.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core.scheduler import simulate
from repro.quant.uniform import fit_scale, qrange
from repro.compiler.program import CORE_NAMES, CoreProgram, LayerProgram, \
    Program


class ExecutionError(RuntimeError):
    """An instruction stream violated the ISA/program contract."""


class UnsupportedLayerError(ExecutionError, NotImplementedError):
    """The layer is latency-modeled but has no functional executor
    semantics (today: depthwise convolutions, whose output channels
    each see a different im2col slice).

    Subclasses ``NotImplementedError`` so historical callers that
    caught that keep working; new callers (the CLI's skip-and-report
    path, batch runners) should catch this type specifically.
    """


@dataclasses.dataclass
class LayerWeights:
    """Integer weight codes + per-column dequant scales for one layer,
    already split: LUT (bit-serial) columns first, DSP (int4) columns
    after — the same column order ``hetero_gemm_ref`` concatenates."""
    w_lut: jnp.ndarray | None      # [k, n_lut] int32 codes
    s_lut: jnp.ndarray | None      # [n_lut] fp32
    w_dsp: jnp.ndarray | None      # [k, n_dsp] int32 codes (int4 range)
    s_dsp: jnp.ndarray | None      # [n_dsp] fp32


class ExecutorBackend:
    """Functional executor over a compiled program.

    Subclasses implement :meth:`_run_core` — how one layer partition's
    tiles are actually computed. Everything else (binding, validation,
    chaining) is shared so backends are interchangeable and
    bit-comparable.
    """

    #: registry key; subclasses override ("golden", "pallas", ...)
    name = "base"

    def __init__(self, program: Program, check_timing: bool = True):
        self.program = program
        self.check_timing = check_timing
        self._weights: dict[int, LayerWeights] = {}

    # -- weight binding ----------------------------------------------------

    def bind_layer(self, index: int, w_lut=None, s_lut=None,
                   w_dsp=None, s_dsp=None) -> None:
        lp = self.program.layers[index]
        k, n_lut, n_dsp = lp.dims.k, lp.n_lut, lp.dims.n - lp.n_lut

        def _chk(w, s, n, what, bits):
            if n == 0:
                if w is not None:
                    raise ValueError(f"layer {index} has no {what} partition")
                return None, None
            w = jnp.asarray(w, jnp.int32)
            s = jnp.asarray(s, jnp.float32).reshape(-1)
            if w.shape != (k, n) or s.shape != (n,):
                raise ValueError(
                    f"layer {index} {what} weights must be [{k},{n}] "
                    f"(+[{n}] scales), got {w.shape}/{s.shape}")
            lo, hi = qrange(bits)
            if int(w.min()) < lo or int(w.max()) > hi:
                raise ValueError(f"layer {index} {what} codes exceed "
                                 f"{bits}-bit range [{lo},{hi}]")
            return w, s

        w_lut, s_lut = _chk(w_lut, s_lut, n_lut, "lut", lp.bits_w_lut)
        w_dsp, s_dsp = _chk(w_dsp, s_dsp, n_dsp, "dsp", 4)
        self._weights[index] = LayerWeights(w_lut, s_lut, w_dsp, s_dsp)

    def bind_deployed(self, index: int, deployed) -> None:
        """Bind from a ``hetero_linear.DeployedHeteroLinear`` (its column
        order is already LUT-first, matching the program split)."""
        lp = self.program.layers[index]
        self.bind_layer(
            index,
            w_lut=deployed.wq_serial if lp.n_lut else None,
            s_lut=deployed.s_serial if lp.n_lut else None,
            w_dsp=deployed.wq_parallel if lp.n_dsp else None,
            s_dsp=deployed.s_parallel if lp.n_dsp else None)

    # -- execution ---------------------------------------------------------

    def run_layer(self, index: int, x_q) -> jnp.ndarray:
        """Execute one layer on int8 activations ``x_q`` [m, k].

        Returns fp32 [m, n] in split column order (LUT partition first),
        i.e. exactly ``kernels.ref.hetero_gemm_ref``'s layout.
        """
        lp = self.program.layers[index]
        if lp.depthwise:
            raise UnsupportedLayerError(
                f"layer {index} ({lp.name}) is depthwise: no functional "
                f"executor semantics (each output channel sees a "
                f"different im2col slice)")
        if index not in self._weights:
            raise ExecutionError(f"layer {index} has no bound weights")
        x_q = jnp.asarray(x_q, jnp.int8)
        if x_q.shape != (lp.dims.m, lp.dims.k):
            raise ExecutionError(
                f"activations must be [{lp.dims.m},{lp.dims.k}], "
                f"got {x_q.shape}")
        wts = self._weights[index]

        outs = []
        if lp.lut is not None:
            self._check_stream(lp, lp.lut)
            outs.append(self._run_core(lp, lp.lut, x_q, wts.w_lut, wts.s_lut))
        if lp.dsp is not None:
            self._check_stream(lp, lp.dsp)
            outs.append(self._run_core(lp, lp.dsp, x_q, wts.w_dsp, wts.s_dsp))
        return jnp.concatenate(outs, axis=1) if len(outs) > 1 else outs[0]

    def _check_stream(self, lp: LayerProgram, cp: CoreProgram) -> None:
        """Validate the sync-token protocol (when ``check_timing``) by
        running the event-driven scheduler over the core's streams."""
        if not self.check_timing:
            return
        try:
            simulate(cp.streams, cp.sim_tokens())
        except RuntimeError as e:
            raise ExecutionError(
                f"layer {lp.index} {CORE_NAMES[cp.core]} streams "
                f"deadlock: {e}") from e

    def run(self, x_q) -> jnp.ndarray:
        """Chain all layers (FC-style networks whose GEMMs compose:
        n_i == k_{i+1}). Activations are requantized to each layer's
        ``bits_a`` between layers, as the hardware writes them back."""
        return chain_layers(self.program.layers, self.run_layer, x_q)

    # -- backend hook ------------------------------------------------------

    def _run_core(self, lp: LayerProgram, cp: CoreProgram, x_q,
                  w_codes, w_scales) -> jnp.ndarray:
        """Compute one layer partition's [m, n_part] fp32 output."""
        raise NotImplementedError


def chain_layers(layers, run_layer, x_q) -> jnp.ndarray:
    """FC-chain ``layers`` through ``run_layer(index, x_q)`` with the
    inter-layer requantization the hardware applies on write-back.

    The single source of truth for the bit-exactness-critical requant
    chain: ``ExecutorBackend.run`` drives it over one program's layers,
    ``MultiDeviceExecutor.run`` over a bundle's global layers — so the
    multi-device hand-off requantizes exactly like the single-device
    chain. ``layers`` items need ``.index``, ``.dims`` and ``.bits_a``.
    """
    out = None
    for lp in layers:
        if out is not None:
            if out.shape[1] != lp.dims.k or out.shape[0] != lp.dims.m:
                raise ExecutionError(
                    f"layer {lp.index} expects [{lp.dims.m},{lp.dims.k}] "
                    f"activations but layer {lp.index - 1} produced "
                    f"{tuple(out.shape)}; run_layer() drives "
                    f"non-chaining (conv) programs layer by layer")
            s_a = fit_scale(out, lp.bits_a)
            lo, hi = qrange(lp.bits_a)
            x_q = jnp.clip(jnp.round(out / s_a), lo, hi).astype(jnp.int8)
        out = run_layer(lp.index, x_q)
    return out


def synthetic_weights(index: int, k: int, n_lut: int, n_dsp: int,
                      bits_w_lut: int, seed: int | None = None):
    """Deterministic synthetic (w_lut, s_lut, w_dsp, s_dsp) for a layer.

    Codes span each partition's full quantized range; scales are a
    0.5..1.5 ramp so column mixups cannot cancel out. The generation
    depends only on (index-or-seed, k, n_lut, n_dsp, bits), so a
    multi-device executor sharding these full-layer weights sees
    exactly what a single-device executor binds (bit-exactness tests).
    """
    rng = np.random.default_rng(index if seed is None else seed)
    lo_w, hi_w = qrange(bits_w_lut)
    lo_d, hi_d = qrange(4)
    return (
        rng.integers(lo_w, hi_w + 1, (k, n_lut)) if n_lut else None,
        np.linspace(0.5, 1.5, n_lut, dtype=np.float32) if n_lut else None,
        rng.integers(lo_d, hi_d + 1, (k, n_dsp)) if n_dsp else None,
        np.linspace(0.5, 1.5, n_dsp, dtype=np.float32) if n_dsp else None,
    )


def bind_synthetic(ex: ExecutorBackend, lp: LayerProgram,
                   seed: int | None = None) -> None:
    """Bind deterministic synthetic weight codes/scales for one layer.

    Shared by the CLI ``--execute`` path, the executor benchmark and the
    pass-invariance tests, so the bind_layer contract has one call site
    to keep current.
    """
    w_lut, s_lut, w_dsp, s_dsp = synthetic_weights(
        lp.index, lp.dims.k, lp.n_lut, lp.dims.n - lp.n_lut,
        lp.bits_w_lut, seed)
    ex.bind_layer(lp.index, w_lut=w_lut, s_lut=s_lut,
                  w_dsp=w_dsp, s_dsp=s_dsp)
