"""Batched executor backend — the Pallas fast path.

The golden interpreter loops a Python iteration per tile (plus a
per-core scheduler validation), which makes large registry LM programs
unusably slow to execute. This backend exploits that a layer's tile
grids compute a plain split GEMM, and (by default) dispatches ONE
fused kernel call per layer: ``kernels.fused_matmul`` consumes both
sides of the Eq.-12 split — the first ``n_lut`` output columns
bit-serially at the layer's LUT bit-width, the rest as packed int4 —
accumulating into a single int32 [m, n] tile with per-column fp32
dequant, so the per-layer concat and the second launch disappear. Conv
layers go through ``kernels.fused_conv_matmul`` /
``fused_depthwise_matmul``, which generate im2col patches *inside* the
launch from the raw spatial NHWC block (no ``L{i}.col`` staging copy
exists in compiled programs' DDR maps). ``fused=False`` restores the
per-partition batched path (one ``bitserial_matmul`` / ``int4_matmul``
call per core), which is also the fused path's reference in the
benchmark regression guard.

Bit-exactness: every path accumulates in exact int32 (bitplane or
packed-int4 arithmetic) and applies per-column fp32 scales
elementwise, so fused == per-partition == the golden interpreter's
tile-by-tile assembly bit for bit — tiling/fusing an exact integer
GEMM is associative, and the dequant scale is per output element. The
pass-invariance suite and ``tests/test_fused_kernels.py`` pin this.

On TPU the calls dispatch the actual Pallas kernels
(``kernels/fused_hetero_gemm.py`` etc.); on CPU they fall back to the
vectorized jnp oracles — still orders of magnitude faster than the
interpreter's per-tile loop. ``mode`` is forwarded to the kernel
wrappers ("auto" | "kernel" | "ref").

Per-program JIT cache: every distinct ``(program fingerprint, mode)``
gets one *complete* table of jitted callables (split and fused
entries), built atomically under the cache lock at construction and
never mutated afterwards — so concurrent executors can share a table
without races. The table is shared across executor instances
(class-level LRU whose capacity comes from the ``jit_cache_max``
constructor argument or the ``REPRO_PALLAS_JIT_CACHE_MAX`` env var).
Hits/misses are published to ``obs.metrics.METRICS`` as
``pallas.jit_cache.*`` so ``launch/serve.py --metrics`` reports
kernel-cache behavior alongside the program-image cache.

Timing/contract checks are *off* by default here (that is the golden
backend's job); pass ``check_timing=True`` to keep the per-core
scheduler validation (``ExecutorBackend._check_stream``) on the fast
path too.
"""
from __future__ import annotations

import collections
import os
import threading

import jax
import jax.numpy as jnp

from repro.core import isa
from repro.kernels import ops as kops
from repro.obs.metrics import METRICS
from repro.compiler.program import CoreProgram, LayerProgram
from repro.compiler.runtime.base import (
    ExecutionError,
    ExecutorBackend,
    elementwise_tail,
)


def _make_lut_fn(bits: int, mode: str):
    def f(x_q, w_codes, w_scales):
        return kops.bitserial_matmul(x_q, w_codes, w_scales, bits,
                                     mode=mode)
    return jax.jit(f)


def _make_dsp_fn(mode: str):
    def f(x_q, w_codes, w_scales):
        return kops.int4_matmul(x_q, w_codes, w_scales, mode=mode)
    return jax.jit(f)


def _make_lut_dw_fn(bits: int, mode: str):
    def f(x_col, w_codes, w_scales):
        return kops.bitserial_grouped_matmul(x_col, w_codes, w_scales,
                                             bits, mode=mode)
    return jax.jit(f)


def _make_dsp_dw_fn(mode: str):
    def f(x_col, w_codes, w_scales):
        return kops.int4_grouped_matmul(x_col, w_codes, w_scales,
                                        mode=mode)
    return jax.jit(f)


def _make_fused_fn(bits: int, depthwise: bool, mode: str):
    """One launch over the whole split: pre-staged [m, k] (dense) or
    [m, k, n] (depthwise) activations, both weight partitions in."""
    if depthwise:
        def f(x_col, w_lut, s_lut, w_dsp, s_dsp):
            return kops.fused_grouped_matmul(x_col, w_lut, s_lut, bits,
                                             w_dsp, s_dsp, mode=mode)
    else:
        def f(x_q, w_lut, s_lut, w_dsp, s_dsp):
            return kops.fused_matmul(x_q, w_lut, s_lut, bits,
                                     w_dsp, s_dsp, mode=mode)
    return jax.jit(f)


def _make_fused_sp_fn(bits: int, geom, depthwise: bool, mode: str):
    """One launch from the raw spatial NHWC block: im2col happens
    inside the call (in-kernel on TPU, in-jit on CPU)."""
    kk, st, p, oh = geom.kernel, geom.stride, geom.pad, geom.out_hw
    if depthwise:
        def f(x_sp, w_lut, s_lut, w_dsp, s_dsp):
            return kops.fused_depthwise_matmul(x_sp, kk, st, p, oh,
                                               w_lut, s_lut, bits,
                                               w_dsp, s_dsp, mode=mode)
    else:
        def f(x_sp, w_lut, s_lut, w_dsp, s_dsp):
            return kops.fused_conv_matmul(x_sp, kk, st, p, oh,
                                          w_lut, s_lut, bits,
                                          w_dsp, s_dsp, mode=mode)
    return jax.jit(f)


class PallasExecutor(ExecutorBackend):
    """One fused (jitted, program-cached) kernel call per layer."""

    name = "pallas"

    #: (program fingerprint, mode) -> complete (frozen) fn table; LRU
    #: over programs, shared across instances so re-executing the same
    #: compiled program skips retracing.
    _jit_cache: "collections.OrderedDict[tuple, dict]" = \
        collections.OrderedDict()
    _jit_cache_max = int(os.environ.get("REPRO_PALLAS_JIT_CACHE_MAX", "16"))
    _jit_cache_lock = threading.Lock()
    _cache_hits = 0
    _cache_misses = 0

    def __init__(self, program, check_timing: bool = False,
                 mode: str = "auto", tracer=None, fused: bool = True,
                 jit_cache_max: int | None = None):
        super().__init__(program, check_timing=check_timing, tracer=tracer)
        self.mode = mode
        self.fused = fused
        if jit_cache_max is not None:
            with PallasExecutor._jit_cache_lock:
                PallasExecutor._jit_cache_max = int(jit_cache_max)
                while len(PallasExecutor._jit_cache) > \
                        PallasExecutor._jit_cache_max:
                    PallasExecutor._jit_cache.popitem(last=False)
        self._fns = self._program_fns(program, mode)

    @classmethod
    def _build_fns(cls, program, mode: str) -> dict:
        """The complete jit table for one program: split entries (the
        per-partition path) and fused entries (the one-launch-per-layer
        path), keyed so layers sharing (core, bits[, geometry]) share a
        traced executable."""
        fns: dict = {}
        for lp in program.layers:
            dw = lp.depthwise
            bits = lp.bits_w_lut
            if lp.lut is not None:
                key = ("lut-dw" if dw else "lut", bits)
                if key not in fns:
                    make = _make_lut_dw_fn if dw else _make_lut_fn
                    fns[key] = make(bits, mode)
            if lp.dsp is not None:
                key = ("dsp-dw" if dw else "dsp", 4)
                if key not in fns:
                    make = _make_dsp_dw_fn if dw else _make_dsp_fn
                    fns[key] = make(mode)
            key = ("fused", bits, dw)
            if key not in fns:
                fns[key] = _make_fused_fn(bits, dw, mode)
            if lp.geometry is not None:
                key = ("fused-sp", bits, dw, lp.geometry)
                if key not in fns:
                    fns[key] = _make_fused_sp_fn(bits, lp.geometry, dw,
                                                 mode)
                if lp.elementwise:
                    # fused elementwise epilogue: one jitted call
                    # applying the layer's add/act/pool/requant tail
                    # (the exact jnp tail the golden chain runs eagerly)
                    key = ("ew", lp.elementwise, lp.geometry.pool)
                    if key not in fns:
                        fns[key] = jax.jit(elementwise_tail(
                            lp.elementwise, lp.geometry.pool))
        return fns

    @classmethod
    def _program_fns(cls, program, mode: str) -> dict:
        """Shared-table lookup. The table is built *complete* before it
        is published (and never mutated after), so readers outside the
        lock can never observe a partially-populated dict — the race
        the old lazy per-key insertion had."""
        key = (program.fingerprint(), mode)
        with cls._jit_cache_lock:
            fns = cls._jit_cache.get(key)
            if fns is not None:
                cls._jit_cache.move_to_end(key)
                cls._cache_hits += 1
                METRICS.incr("pallas.jit_cache.hit")
                return fns
            cls._cache_misses += 1
            METRICS.incr("pallas.jit_cache.miss")
            fns = cls._build_fns(program, mode)
            cls._jit_cache[key] = fns
            while len(cls._jit_cache) > cls._jit_cache_max:
                cls._jit_cache.popitem(last=False)
            METRICS.gauge("pallas.jit_cache.programs", len(cls._jit_cache))
            return fns

    @classmethod
    def cache_info(cls) -> dict:
        with cls._jit_cache_lock:
            return {"programs": len(cls._jit_cache),
                    "hits": cls._cache_hits,
                    "misses": cls._cache_misses,
                    "maxsize": cls._jit_cache_max}

    @classmethod
    def cache_clear(cls) -> None:
        with cls._jit_cache_lock:
            cls._jit_cache.clear()
            cls._cache_hits = cls._cache_misses = 0

    def run_layer(self, index: int, x_q) -> jnp.ndarray:
        """One fused kernel call for the whole layer (both split
        sides); falls back to the per-partition batched path
        (``ExecutorBackend.run_layer``) when ``fused=False``."""
        if not self.fused:
            return super().run_layer(index, x_q)
        lp = self.program.layers[index]
        if index not in self._weights:
            raise ExecutionError(f"layer {index} has no bound weights")
        wts = self._weights[index]
        for cp in (lp.lut, lp.dsp):
            if cp is not None:
                self._check_stream(lp, cp)
        x_q = jnp.asarray(x_q, jnp.int8)
        geom = lp.geometry
        if geom is not None and x_q.shape == geom.in_shape:
            # spatial input: im2col happens inside the fused call
            fn = self._fns[("fused-sp", lp.bits_w_lut, lp.depthwise, geom)]
        else:
            x_q = self._staged_activations(lp, x_q)
            fn = self._fns[("fused", lp.bits_w_lut, lp.depthwise)]
        with self.tracer.measure(f"exec.{self.name}.fused", lp.name,
                                 layer=lp.index, n=lp.dims.n,
                                 n_lut=lp.n_lut):
            return fn(x_q, wts.w_lut, wts.s_lut, wts.w_dsp, wts.s_dsp)

    def _elementwise_tail(self, lp: LayerProgram):
        """The layer's fused (jitted, program-cached) elementwise
        epilogue — falls back to the eager shared tail for layers
        without one in the table."""
        if lp.geometry is not None and lp.elementwise:
            fn = self._fns.get(("ew", lp.elementwise, lp.geometry.pool))
            if fn is not None:
                return fn
        return super()._elementwise_tail(lp)

    def _run_core(self, lp: LayerProgram, cp: CoreProgram, x_q,
                  w_codes, w_scales) -> jnp.ndarray:
        # the per-partition path (fused=False): depthwise partitions
        # batch the whole grouped contraction in one call, like dense
        # partitions batch their tile grid into one GEMM. Tables are
        # complete at construction — read-only here (thread-safe).
        dw = lp.depthwise
        if cp.core == isa.CoreSel.LUT:
            fn = self._fns[("lut-dw" if dw else "lut", lp.bits_w_lut)]
        else:
            fn = self._fns[("dsp-dw" if dw else "dsp", 4)]
        return fn(x_q, w_codes, w_scales)
