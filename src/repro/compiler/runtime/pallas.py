"""Batched executor backend — the Pallas fast path.

The golden interpreter loops a Python iteration per tile (plus a
per-core scheduler validation), which makes large registry LM programs
unusably slow to execute. This backend exploits that a layer
partition's tile grid computes a plain GEMM: all tiles of a partition
are grouped into a *single* ``kernels.bitserial_matmul`` /
``kernels.int4_matmul`` call over the whole [m, k] x [k, n_part]
extent.

Bit-exactness: both kernels accumulate in exact int32 (bitplane or
packed-int4 arithmetic) and apply per-column fp32 scales elementwise,
so the batched product equals the golden interpreter's tile-by-tile
assembly bit for bit — row/column tiling of an exact integer GEMM is
associative, and the dequant scale is per output element. The
pass-invariance suite (``tests/test_compiler_passes.py``) pins this.

On TPU the grouped calls dispatch the actual Pallas kernels
(``kernels/bitserial_gemm.py`` / ``kernels/int4_gemm.py``); on CPU they
fall back to the vectorized jnp oracles — still orders of magnitude
faster than the interpreter's per-tile loop. ``mode`` is forwarded to
the kernel wrappers ("auto" | "kernel" | "ref").

Per-program JIT cache: every distinct ``(program fingerprint, mode)``
gets one table of jitted per-partition callables, shared across
executor instances (class-level LRU). The fingerprint hashes the
encoded instruction words, which carry every GEMM extent — so it keys
the sequence length too — and repeated executions of the same compiled
program (serving hot paths, repeated ``--execute`` runs in one
process, benchmark loops) reuse the traced executables instead of
retracing layer by layer.

Timing/contract checks are *off* by default here (that is the golden
backend's job); pass ``check_timing=True`` to keep the per-core
scheduler validation (``ExecutorBackend._check_stream``) on the fast
path too.
"""
from __future__ import annotations

import collections
import threading

import jax
import jax.numpy as jnp

from repro.core import isa
from repro.kernels import ops as kops
from repro.compiler.program import CoreProgram, LayerProgram
from repro.compiler.runtime.base import ExecutorBackend


def _make_lut_fn(bits: int, mode: str):
    def f(x_q, w_codes, w_scales):
        return kops.bitserial_matmul(x_q, w_codes, w_scales, bits,
                                     mode=mode)
    return jax.jit(f)


def _make_dsp_fn(mode: str):
    def f(x_q, w_codes, w_scales):
        return kops.int4_matmul(x_q, w_codes, w_scales, mode=mode)
    return jax.jit(f)


def _make_lut_dw_fn(bits: int, mode: str):
    def f(x_col, w_codes, w_scales):
        return kops.bitserial_grouped_matmul(x_col, w_codes, w_scales,
                                             bits, mode=mode)
    return jax.jit(f)


def _make_dsp_dw_fn(mode: str):
    def f(x_col, w_codes, w_scales):
        return kops.int4_grouped_matmul(x_col, w_codes, w_scales,
                                        mode=mode)
    return jax.jit(f)


class PallasExecutor(ExecutorBackend):
    """One batched (jitted, program-cached) kernel call per partition."""

    name = "pallas"

    #: (program fingerprint, mode) -> {(core, bits): jitted fn}; LRU
    #: over programs, shared across instances so re-executing the same
    #: compiled program skips retracing.
    _jit_cache: "collections.OrderedDict[tuple, dict]" = \
        collections.OrderedDict()
    _jit_cache_max = 16
    _jit_cache_lock = threading.Lock()
    _cache_hits = 0
    _cache_misses = 0

    def __init__(self, program, check_timing: bool = False,
                 mode: str = "auto", tracer=None):
        super().__init__(program, check_timing=check_timing, tracer=tracer)
        self.mode = mode
        self._fns = self._program_fns(program, mode)

    @classmethod
    def _program_fns(cls, program, mode: str) -> dict:
        key = (program.fingerprint(), mode)
        with cls._jit_cache_lock:
            fns = cls._jit_cache.get(key)
            if fns is not None:
                cls._jit_cache.move_to_end(key)
                cls._cache_hits += 1
                return fns
            cls._cache_misses += 1
            fns = {}
            cls._jit_cache[key] = fns
            while len(cls._jit_cache) > cls._jit_cache_max:
                cls._jit_cache.popitem(last=False)
            return fns

    @classmethod
    def cache_info(cls) -> dict:
        with cls._jit_cache_lock:
            return {"programs": len(cls._jit_cache),
                    "hits": cls._cache_hits,
                    "misses": cls._cache_misses,
                    "maxsize": cls._jit_cache_max}

    @classmethod
    def cache_clear(cls) -> None:
        with cls._jit_cache_lock:
            cls._jit_cache.clear()
            cls._cache_hits = cls._cache_misses = 0

    def _run_core(self, lp: LayerProgram, cp: CoreProgram, x_q,
                  w_codes, w_scales) -> jnp.ndarray:
        # depthwise partitions batch the whole grouped (per-channel
        # im2col) contraction in one call, like dense partitions batch
        # their tile grid into one GEMM
        dw = lp.depthwise
        if cp.core == isa.CoreSel.LUT:
            key = ("lut-dw" if dw else "lut", lp.bits_w_lut)
            fn = self._fns.get(key)
            if fn is None:
                make = _make_lut_dw_fn if dw else _make_lut_fn
                fn = self._fns[key] = make(lp.bits_w_lut, self.mode)
        else:
            key = ("dsp-dw" if dw else "dsp", 4)
            fn = self._fns.get(key)
            if fn is None:
                make = _make_dsp_dw_fn if dw else _make_dsp_fn
                fn = self._fns[key] = make(self.mode)
        return fn(x_q, w_codes, w_scales)
