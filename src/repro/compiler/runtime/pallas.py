"""Batched executor backend — the Pallas fast path.

The golden interpreter loops a Python iteration per tile (plus a
per-core scheduler validation), which makes large registry LM programs
unusably slow to execute. This backend exploits that a layer
partition's tile grid computes a plain GEMM: all tiles of a partition
are grouped into a *single* ``kernels.bitserial_matmul`` /
``kernels.int4_matmul`` call over the whole [m, k] x [k, n_part]
extent.

Bit-exactness: both kernels accumulate in exact int32 (bitplane or
packed-int4 arithmetic) and apply per-column fp32 scales elementwise,
so the batched product equals the golden interpreter's tile-by-tile
assembly bit for bit — row/column tiling of an exact integer GEMM is
associative, and the dequant scale is per output element. The
pass-invariance suite (``tests/test_compiler_passes.py``) pins this.

On TPU the grouped calls dispatch the actual Pallas kernels
(``kernels/bitserial_gemm.py`` / ``kernels/int4_gemm.py``); on CPU they
fall back to the vectorized jnp oracles — still orders of magnitude
faster than the interpreter's per-tile loop. ``mode`` is forwarded to
the kernel wrappers ("auto" | "kernel" | "ref").

Timing/contract checks are *off* by default here (that is the golden
backend's job); pass ``check_timing=True`` to keep the per-core
scheduler validation (``ExecutorBackend._check_stream``) on the fast
path too.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import isa
from repro.kernels import ops as kops
from repro.compiler.program import CoreProgram, LayerProgram
from repro.compiler.runtime.base import ExecutorBackend


class PallasExecutor(ExecutorBackend):
    """One batched kernel call per layer partition."""

    name = "pallas"

    def __init__(self, program, check_timing: bool = False,
                 mode: str = "auto"):
        super().__init__(program, check_timing=check_timing)
        self.mode = mode

    def _run_core(self, lp: LayerProgram, cp: CoreProgram, x_q,
                  w_codes, w_scales) -> jnp.ndarray:
        if cp.core == isa.CoreSel.LUT:
            return kops.bitserial_matmul(x_q, w_codes, w_scales,
                                         lp.bits_w_lut, mode=self.mode)
        return kops.int4_matmul(x_q, w_codes, w_scales, mode=self.mode)
