"""Program IR of the NN→ISA compiler.

A :class:`Program` is the compiler's output artifact and the single
currency everything downstream consumes:

  * ``core/scheduler.py`` simulates its per-engine instruction streams
    (the Fig. 3/Fig. 5 latency decomposition);
  * ``compiler/runtime/`` executes it functionally against the
    reference GEMM numerics (golden model) or the batched Pallas path;
  * ``compiler/asm.py`` serializes it to text assembly and to a packed
    binary image, bit-exactly.

Structure: one :class:`LayerProgram` per network layer, each holding the
two per-core instruction streams (LUT bit-serial partition + DSP
bit-parallel partition) produced by the neuron split, plus the DDR
:class:`MemoryMap` that positions weights/activations/outputs.

Every instruction is a real 128-bit ``core/isa.py`` word; each carries a
timing closure (busy cycles once runnable — the scheduler's DMA/compute
cycle model evaluated at lowering time) and, for Sync instructions, the
token channel it posts to / consumes from. Channels are recoverable
from the encoded word alone via the per-core ``token_flag`` tables
below, so disassembly loses nothing.
"""
from __future__ import annotations

import dataclasses
import hashlib

from repro.core import isa
from repro.core.scheduler import (
    DspCoreConfig,
    FPGADevice,
    GemmDims,
    LutCoreConfig,
    Op,
)

# ---------------------------------------------------------------------------
# Sync channel <-> token_flag tables (3-bit flag per core)
# ---------------------------------------------------------------------------

# LUT-core channels: weight column tile ready (SE), activation matrix
# ready, free weight-buffer slot (WE), result tile ready, layer barrier,
# cross-device hand-off (multi-device plans, compiler/partition.py).
LUT_CHANNEL_FLAGS = {"lut.wtile": 1, "lut.act": 2, "lut.wslot": 3,
                     "lut.res": 4, "lut.bar": 5, "lut.xdev": 6}
# DSP-core channels: whole-weight-resident ready, activation row tile,
# weight column tile, free activation slot, result tile, layer barrier,
# cross-device hand-off.
DSP_CHANNEL_FLAGS = {"dsp.wall": 1, "dsp.atile": 2, "dsp.wtile": 3,
                     "dsp.aslot": 4, "dsp.res": 5, "dsp.bar": 6,
                     "dsp.xdev": 7}

CHANNEL_FLAGS = {**LUT_CHANNEL_FLAGS, **DSP_CHANNEL_FLAGS}

#: Channels whose tokens cross a device boundary (the matching send or
#: wait lives in *another* device's program). Local simulation arms
#: their waits at t=0; the optimization passes must never elide or
#: reorder them (compiler/passes.py), and ``partition.validate_bundle``
#: checks the cross-device pairing instead.
CROSS_DEVICE_CHANNELS = frozenset({"lut.xdev", "dsp.xdev"})
FLAG_CHANNELS = {
    isa.CoreSel.LUT: {f: ch for ch, f in LUT_CHANNEL_FLAGS.items()},
    isa.CoreSel.DSP: {f: ch for ch, f in DSP_CHANNEL_FLAGS.items()},
}

ENGINES = ("fetch", "execute", "result")
CORE_NAMES = {isa.CoreSel.LUT: "lut", isa.CoreSel.DSP: "dsp"}


def channel_of(instr: isa.SyncInstr) -> str:
    """Recover the token channel name from an encoded Sync instruction."""
    try:
        return FLAG_CHANNELS[instr.core][instr.token_flag]
    except KeyError:
        raise ValueError(
            f"unknown sync token flag {instr.token_flag} for core "
            f"{instr.core!r}") from None


# ---------------------------------------------------------------------------
# DDR memory map
# ---------------------------------------------------------------------------


#: Segment residency classes — the invocation contract for decode-mode
#: programs. ``io`` segments are per-step scratch (reloaded/rewritten on
#: every invocation); ``weights`` segments survive *across* invocations
#: (the first step loads them, steady-state steps reuse the resident
#: tiles); ``kv``/``state`` segments are persistent and updated in place
#: (attention KV rows appended at the step position, SSM recurrent state
#: read-modify-written each step).
RESIDENCY_CLASSES = ("io", "weights", "kv", "state")


@dataclasses.dataclass(frozen=True)
class Segment:
    """One named DDR region. ``size`` in bytes; tile-granular DMA
    instructions address it as (ddr_base=base, ddr_offset=tile index).
    ``residency`` is the invocation-contract class (RESIDENCY_CLASSES)."""
    name: str
    base: int
    size: int
    residency: str = "io"

    def __post_init__(self):
        if self.residency not in RESIDENCY_CLASSES:
            raise ValueError(f"unknown residency class {self.residency!r}")

    @property
    def end(self) -> int:
        return self.base + self.size


class MemoryMap:
    """Bump allocator over the 32-bit DDR space, 64-byte aligned."""

    ALIGN = 64

    def __init__(self):
        self.segments: list[Segment] = []
        self._by_name: dict[str, Segment] = {}
        self._cursor = 0

    def alloc(self, name: str, size: int,
              residency: str = "io") -> Segment:
        if name in self._by_name:
            raise ValueError(f"duplicate segment {name!r}")
        size = max(int(size), 0)
        base = self._cursor
        seg = Segment(name, base, size, residency)
        aligned = (size + self.ALIGN - 1) // self.ALIGN * self.ALIGN
        self._cursor = base + aligned
        if self._cursor >= (1 << 32):
            raise ValueError(f"DDR map overflows 32-bit space at {name!r}")
        self.segments.append(seg)
        self._by_name[name] = seg
        return seg

    def set_residency(self, name: str, residency: str) -> Segment:
        """Reclassify an existing segment (segments are frozen, so the
        record is replaced in place — base/size identity unchanged)."""
        old = self._by_name[name]
        seg = dataclasses.replace(old, residency=residency)
        self.segments[self.segments.index(old)] = seg
        self._by_name[name] = seg
        return seg

    def __getitem__(self, name: str) -> Segment:
        return self._by_name[name]

    def __contains__(self, name: str) -> bool:
        return name in self._by_name

    @property
    def footprint(self) -> int:
        return self._cursor

    def __eq__(self, other) -> bool:
        return (isinstance(other, MemoryMap)
                and self.segments == other.segments)

    def __repr__(self) -> str:
        return f"MemoryMap({len(self.segments)} segments, {self.footprint}B)"


# ---------------------------------------------------------------------------
# Conv-layer spatial geometry (im2col lowering, §3.2.1)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ConvGeometry:
    """Spatial geometry a conv layer's im2col lowering carries into the
    program.

    The GEMM view (``GemmDims``) is what the cores execute; the geometry
    is what the activation staging needs to *build* that view from an
    NHWC spatial tensor: ``m == out_hw**2``, ``k == c_in * kernel**2``
    for dense convs and ``k == kernel**2`` per channel for depthwise.

    ``src_offset`` names the layer whose output this layer consumes as
    its input — this layer's index minus ``src_offset`` (1 for the
    plain sequential chain, 3 for the ResNet downsample shortcuts that
    read the block input). A source falling before the program start
    reads the program input segment (``act.in``). ``pool`` is spatial
    glue applied to *this* layer's output before the consumer reads it:
    ``"max"`` (3x3 stride-2 SAME max pool, the ResNet stem) or
    ``"gap"`` (global average pool before the classifier).
    """
    kernel: int
    stride: int
    pad: int
    in_hw: int
    out_hw: int
    c_in: int
    c_out: int
    src_offset: int = 1
    pool: str = ""

    def __post_init__(self):
        if self.pool not in ("", "max", "gap"):
            raise ValueError(f"unknown pool kind {self.pool!r}")
        if self.src_offset < 1:
            raise ValueError("src_offset must be >= 1")

    @property
    def in_shape(self) -> tuple[int, int, int]:
        """Spatial NHWC input extents (batch 1): [in_hw, in_hw, c_in]."""
        return (self.in_hw, self.in_hw, self.c_in)

    def pooled_hw(self) -> int:
        """Output feature-map size after this layer's ``pool`` glue."""
        from repro.core.workloads import pooled_hw
        return pooled_hw(self.out_hw, self.pool)


# ---------------------------------------------------------------------------
# Fused elementwise result tail (§residual/activation glue, in-program)
# ---------------------------------------------------------------------------


#: Elementwise op kinds, in canonical tail order: an optional residual
#: ``add`` first, then one activation (``relu``/``relu6``/``hswish``),
#: then (after the layer's ``pool`` glue) the write-back ``requant``.
ELEMENTWISE_KINDS = ("add", "relu", "relu6", "hswish", "requant")


@dataclasses.dataclass(frozen=True)
class ElementwiseOp:
    """One operation of a layer's fused elementwise result tail.

    The tail runs on the layer's fp32 result tiles before write-back:
    ``add`` accumulates the stored output of the producer ``src_offset``
    layers back (dequantized at that producer's write-back scale —
    ResNet shortcuts, MobileNet inverted residuals), the activation
    kinds apply pointwise, and ``requant`` re-quantizes to ``bits``-bit
    codes with a per-tensor max-abs scale — the codes the layer's DDR
    output segment actually holds. The layer's ``geometry.pool`` glue
    applies between the activation and the requant, matching the fp32
    network (pool over activations, then quantize).
    """
    kind: str
    src_offset: int = 0   # add: producer distance (layer pos - src pos)
    bits: int = 0         # requant: target code width

    def __post_init__(self):
        if self.kind not in ELEMENTWISE_KINDS:
            raise ValueError(f"unknown elementwise kind {self.kind!r}")
        if self.kind == "add" and self.src_offset < 1:
            raise ValueError("elementwise add needs src_offset >= 1")
        if self.kind == "requant" and not (1 <= self.bits <= 8):
            raise ValueError(f"requant bits out of range: {self.bits}")


# ---------------------------------------------------------------------------
# Per-core, per-layer stream bundles
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class CoreProgram:
    """One core's three engine streams for one layer partition."""
    core: isa.CoreSel
    streams: dict[str, list[Op]]
    initial_tokens: dict[str, int]
    # lowering-time stats (bytes are exact, pre-clamp model quantities)
    bytes_fetched: float = 0.0
    bytes_written: float = 0.0

    @property
    def n_instructions(self) -> int:
        return sum(len(s) for s in self.streams.values())

    def ops(self):
        for e in ENGINES:
            yield from self.streams.get(e, [])

    def sim_tokens(self) -> dict[str, int]:
        """Initial tokens for simulating this layer *in isolation*.

        The program artifact keeps inter-layer barrier waits un-armed —
        on hardware (or a concurrent multi-layer consumer) the matching
        send at the tail of the previous layer's result stream posts
        them. Layer-at-a-time simulation/execution models the Eq.-10
        synchronous chain, where the previous layer has fully drained,
        so any barrier-channel deficit is pre-armed at t=0 here. The
        same applies to cross-device channels (``*.xdev``): their
        matching sends live in another device's program.
        """
        tokens = dict(self.initial_tokens)
        cn = CORE_NAMES[self.core]
        for ch in (f"{cn}.bar", f"{cn}.xdev"):
            # Arm every in-layer barrier/cross-device *wait*; the
            # layer's own sends target another layer (or device) and
            # must not offset the count.
            waits = sum(1 for op in self.ops()
                        if op.channel == ch
                        and isinstance(op.instr, isa.SyncInstr)
                        and op.instr.is_wait)
            deficit = waits - tokens.get(ch, 0)
            if deficit > 0:
                tokens[ch] = tokens.get(ch, 0) + deficit
        return tokens


@dataclasses.dataclass
class LayerProgram:
    """One network layer lowered under its neuron split."""
    index: int
    name: str
    dims: GemmDims               # full (un-split) layer GEMM
    n_lut: int                   # filters on the LUT (bit-serial) core
    bits_w_lut: int
    bits_a: int
    depthwise: bool
    lut: CoreProgram | None      # None when n_lut == 0
    dsp: CoreProgram | None      # None when n_lut == dims.n
    # Spatial geometry for conv layers (None for plain GEMM/FC layers):
    # drives the executor's im2col staging and the NHWC chain.
    geometry: ConvGeometry | None = None
    # Fused elementwise result tail (ElementwiseOp tuple, canonical
    # order add -> activation -> requant); empty for LM/FC layers whose
    # inter-layer glue stays in the session frontends.
    elementwise: tuple = ()

    @property
    def n_dsp(self) -> int:
        return self.dims.n - self.n_lut

    def cores(self) -> list[CoreProgram]:
        return [c for c in (self.lut, self.dsp) if c is not None]

    @property
    def n_instructions(self) -> int:
        return sum(c.n_instructions for c in self.cores())


# ---------------------------------------------------------------------------
# Decode-step invocation header
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class StepSpec:
    """Invocation header of a decode-mode program.

    A program carrying a StepSpec is a *step* program: one invocation
    advances generation by one token position. The runtime contract is
    a step-position register ``pos`` supplied per invocation — every
    persistent-segment access (``kv`` append/read) is addressed as
    ``segment.base + pos * row_bytes`` — plus the residency classes on
    the memory map: after the warm-up invocation, ``weights`` segments
    are resident and their fetches are elided (:func:`lower.steady_program`).

    ``family`` is the registry module kind (``lm``/``ssm``/``hybrid``)
    and the attention geometry fields drive the session glue between
    compiled GEMMs (zeros for pure-SSM programs).
    """
    family: str
    batch: int
    max_seq: int
    d_model: int
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: int = 0

    def to_meta(self) -> dict:
        return dataclasses.asdict(self)

    @staticmethod
    def from_meta(meta: dict) -> "StepSpec":
        return StepSpec(**meta)


# ---------------------------------------------------------------------------
# Whole-network Program
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ProgramStats:
    n_instructions: int
    by_opcode: dict[str, int]
    bytes_fetched: float
    bytes_written: float
    ddr_footprint: int

    @property
    def bytes_moved(self) -> float:
        return self.bytes_fetched + self.bytes_written

    @property
    def image_bytes(self) -> int:
        return self.n_instructions * isa.WORD_BITS // 8


@dataclasses.dataclass
class Program:
    """A whole network compiled to unified-ISA instruction streams."""
    name: str
    device: FPGADevice
    lut_cfg: LutCoreConfig
    dsp_cfg: DspCoreConfig
    layers: list[LayerProgram]
    memory: MemoryMap
    # Per-pass accounting attached by passes.PassPipeline (not part of
    # the program identity: excluded from __eq__ and serialization).
    opt_stats: list = dataclasses.field(default_factory=list, repr=False)
    # Decode invocation header (None for plain fixed-seq programs).
    step: StepSpec | None = None

    def stats(self) -> ProgramStats:
        by_op = {op.name: 0 for op in isa.Opcode}
        fetched = written = 0.0
        n = 0
        for lp in self.layers:
            for cp in lp.cores():
                fetched += cp.bytes_fetched
                written += cp.bytes_written
                for op in cp.ops():
                    by_op[op.instr.opcode.name] += 1
                    n += 1
        return ProgramStats(n, by_op, fetched, written, self.memory.footprint)

    @property
    def n_instructions(self) -> int:
        return sum(lp.n_instructions for lp in self.layers)

    def words(self) -> list[int]:
        """Flat 128-bit instruction image (layer-major, lut before dsp,
        fetch/execute/result engine order)."""
        return [op.instr.encode()
                for lp in self.layers
                for cp in lp.cores()
                for op in cp.ops()]

    def fingerprint(self) -> str:
        """Stable content hash of the instruction image + identity.

        Keyed on the encoded words (which capture every operand,
        bit-width and sync flag) plus name/device/seq extents, so two
        programs share a fingerprint iff they execute identically —
        the ``PallasExecutor`` per-program JIT cache keys on this.
        """
        h = hashlib.sha256(self.name.encode())
        h.update(self.device.name.encode())
        if self.step is not None:
            h.update(repr(self.step).encode())
        for lp in self.layers:
            if lp.elementwise:
                # tail semantics (op kinds, add sources, requant bits)
                # live in layer metadata, not the instruction words
                h.update(repr(lp.elementwise).encode())
        for w in self.words():
            h.update(w.to_bytes(16, "little"))
        return h.hexdigest()

    def __eq__(self, other) -> bool:
        if not isinstance(other, Program):
            return NotImplemented
        return (self.name == other.name
                and self.device == other.device
                and self.lut_cfg == other.lut_cfg
                and self.dsp_cfg == other.dsp_cfg
                and self.layers == other.layers
                and self.memory == other.memory
                and self.step == other.step)


# ---------------------------------------------------------------------------
# Generic layer description consumed by the lowering pass
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class GemmLayer:
    """A layer already reduced to GEMM extents (im2col view for convs,
    direct for linears). This is what ``networks.py`` produces for both
    the CNN workload zoo and the LM registry archs. Conv layers carry
    their :class:`ConvGeometry` so the executors can stage im2col
    activations and chain spatial tensors."""
    name: str
    dims: GemmDims
    depthwise: bool = False
    geometry: ConvGeometry | None = None
    # Residual-add / activation ops of the layer's fused result tail
    # (the write-back requant is appended by ``lower_network``, which
    # knows the consumer's activation bit-width).
    elementwise: tuple = ()

    @staticmethod
    def from_conv(spec) -> "GemmLayer":
        """Lower a ``core/workloads.py`` ConvSpec to its GEMM view,
        keeping the spatial geometry (the downsample shortcuts read the
        block input, three layers back in the zoo's layer order) and the
        spec's residual/activation glue as elementwise tail ops."""
        geom = ConvGeometry(
            kernel=spec.kernel, stride=spec.stride, pad=spec.kernel // 2,
            in_hw=spec.in_hw, out_hw=spec.out_hw,
            c_in=spec.c_out if spec.depthwise else spec.c_in,
            c_out=spec.c_out,
            src_offset=3 if spec.shortcut else 1,
            pool=getattr(spec, "pool", ""))
        ew = []
        if getattr(spec, "res_src", 0):
            ew.append(ElementwiseOp("add", src_offset=spec.res_src))
        if getattr(spec, "act", ""):
            ew.append(ElementwiseOp(spec.act))
        return GemmLayer(spec.name, spec.gemm(), spec.depthwise, geom,
                         elementwise=tuple(ew))
