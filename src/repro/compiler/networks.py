"""Network frontends: named models → GEMM layer lists for the compiler.

Two sources:

  * the CNN workload zoo (``core/workloads.py``): resnet18 /
    mobilenet_v2, lowered via im2col exactly as the latency models see
    them;
  * the LM architecture registry (``configs/registry.py``): every
    registered arch's *smoke* config is walked block by block and each
    projection GEMM (attention q/k/v/o or MLA low-rank factors, MLP or
    MoE expert mats, SSM in/out projections) becomes one layer at a
    given sequence length.

The LM walk is family-aware but intentionally coarse — it captures the
per-block GEMM shapes (what the accelerator executes), not the
softmax/norm glue. MoE layers contribute the router, the ``top_k``
routed experts and any always-on shared experts (the compute that
actually runs per token).
"""
from __future__ import annotations

from repro.core.scheduler import GemmDims
from repro.core.workloads import WORKLOADS
from repro.compiler.program import GemmLayer


def _gl(name: str, m: int, k: int, n: int) -> GemmLayer:
    return GemmLayer(name, GemmDims(m=m, k=k, n=max(int(n), 1)))


def _attn_layers(prefix: str, cfg, m: int) -> list[GemmLayer]:
    d = cfg.d_model
    mla = getattr(cfg, "mla", None)
    if mla is not None:
        hq = cfg.n_heads
        return [
            _gl(f"{prefix}.q_lora", m, d, mla.q_lora),
            _gl(f"{prefix}.q_proj", m, mla.q_lora,
                hq * (mla.qk_nope_dim + mla.qk_rope_dim)),
            _gl(f"{prefix}.kv_lora", m, d, mla.kv_lora + mla.qk_rope_dim),
            _gl(f"{prefix}.kv_proj", m, mla.kv_lora,
                hq * (mla.qk_nope_dim + mla.v_dim)),
            _gl(f"{prefix}.o", m, hq * mla.v_dim, d),
        ]
    hd, hq, hkv = cfg.head_dim, cfg.n_heads, cfg.n_kv_heads
    return [
        _gl(f"{prefix}.q", m, d, hq * hd),
        _gl(f"{prefix}.k", m, d, hkv * hd),
        _gl(f"{prefix}.v", m, d, hkv * hd),
        _gl(f"{prefix}.o", m, hq * hd, d),
    ]


def _mlp_layers(prefix: str, d: int, d_ff: int, m: int,
                moe=None) -> list[GemmLayer]:
    if moe is None:
        return [
            _gl(f"{prefix}.gate", m, d, d_ff),
            _gl(f"{prefix}.up", m, d, d_ff),
            _gl(f"{prefix}.down", m, d_ff, d),
        ]
    # router + the top_k routed experts + any always-on shared experts
    # (models/layers.py runs the shared block as one fused d_ff*n_shared
    # MLP on every token) — together, the compute that fires per token.
    out = [_gl(f"{prefix}.router", m, d, moe.n_experts)]
    for e in range(moe.top_k):
        out += [
            _gl(f"{prefix}.e{e}.gate", m, d, moe.d_ff),
            _gl(f"{prefix}.e{e}.up", m, d, moe.d_ff),
            _gl(f"{prefix}.e{e}.down", m, moe.d_ff, d),
        ]
    if getattr(moe, "n_shared", 0):
        ff = moe.d_ff * moe.n_shared
        out += [
            _gl(f"{prefix}.shared.gate", m, d, ff),
            _gl(f"{prefix}.shared.up", m, d, ff),
            _gl(f"{prefix}.shared.down", m, ff, d),
        ]
    return out


def _ssm_layers(prefix: str, d: int, ssm, m: int) -> list[GemmLayer]:
    n_heads = ssm.d_inner // ssm.head_dim
    return [
        _gl(f"{prefix}.in_zx", m, d, 2 * ssm.d_inner),
        _gl(f"{prefix}.in_bc", m, d, 2 * ssm.n_groups * ssm.d_state),
        _gl(f"{prefix}.in_dt", m, d, n_heads),
        _gl(f"{prefix}.out", m, ssm.d_inner, d),
    ]


def _lm_layers(cfg, m: int) -> list[GemmLayer]:
    """Decoder-only LM (dense or MoE, optional MLA)."""
    layers = []
    moe = getattr(cfg, "moe", None)
    n_dense = getattr(cfg, "n_dense_prefix", 0)
    for b in range(cfg.n_layers):
        layers += _attn_layers(f"b{b}.attn", cfg, m)
        block_moe = None if (moe is None or b < n_dense) else moe
        d_ff = cfg.d_ff if block_moe is None else moe.d_ff
        if block_moe is None and b < n_dense and cfg.d_ff_dense:
            d_ff = cfg.d_ff_dense
        layers += _mlp_layers(f"b{b}.mlp", cfg.d_model, d_ff, m,
                              moe=block_moe)
    layers.append(_gl("lm_head", m, cfg.d_model, cfg.padded_vocab))
    return layers


def _ssm_lm_layers(cfg, m: int) -> list[GemmLayer]:
    layers = []
    for b in range(cfg.n_layers):
        layers += _ssm_layers(f"b{b}.ssm", cfg.d_model, cfg.ssm, m)
    layers.append(_gl("lm_head", m, cfg.d_model, cfg.padded_vocab))
    return layers


def _encdec_layers(cfg, m: int) -> list[GemmLayer]:
    layers = []
    for b in range(cfg.n_enc_layers):
        layers += _attn_layers(f"enc{b}.attn", cfg, m)
        layers += _mlp_layers(f"enc{b}.mlp", cfg.d_model, cfg.d_ff, m)
    for b in range(cfg.n_dec_layers):
        layers += _attn_layers(f"dec{b}.self", cfg, m)
        layers += _attn_layers(f"dec{b}.cross", cfg, m)
        layers += _mlp_layers(f"dec{b}.mlp", cfg.d_model, cfg.d_ff, m)
    layers.append(_gl("lm_head", m, cfg.d_model, cfg.padded_vocab))
    return layers


def _hybrid_layers(cfg, m: int) -> list[GemmLayer]:
    """Jamba-style period: alternate attention/SSM mixers, MoE MLPs on
    odd blocks (coarse view of the published 1:7 attention:SSM period)."""
    layers = []
    for b in range(cfg.n_layers):
        if b % 2 == 0:
            layers += _ssm_layers(f"b{b}.ssm", cfg.d_model, cfg.ssm, m)
        else:
            layers += _attn_layers(f"b{b}.attn", cfg, m)
        moe = cfg.moe if b % 2 == 1 else None
        layers += _mlp_layers(f"b{b}.mlp", cfg.d_model, cfg.d_ff, m, moe=moe)
    layers.append(_gl("lm_head", m, cfg.d_model, cfg.padded_vocab))
    return layers


def lm_gemm_layers(cfg, seq_len: int = 64) -> list[GemmLayer]:
    """Per-block projection GEMMs of one model config at ``seq_len``."""
    if hasattr(cfg, "n_enc_layers"):
        return _encdec_layers(cfg, seq_len)
    if hasattr(cfg, "ssm") and hasattr(cfg, "n_heads"):
        return _hybrid_layers(cfg, seq_len)
    if hasattr(cfg, "ssm"):
        return _ssm_lm_layers(cfg, seq_len)
    return _lm_layers(cfg, seq_len)


def network_layers(name: str, seq_len: int = 64, smoke: bool = True,
                   in_hw: int | None = None,
                   width: float | None = None) -> list[GemmLayer]:
    """GEMM layer list for a named network.

    ``name`` is a CNN workload (``resnet18``/``mobilenet_v2``) or any
    registered arch id; registry archs use their smoke config unless
    ``smoke=False``. CNNs accept ``in_hw``/``width`` to compile the
    geometry-consistent reduced variants of ``models/cnn.py``
    (``specs_for`` propagates spatial sizes through the layer graph,
    so the scaled programs still chain end to end).
    """
    if name in WORKLOADS:
        if in_hw is not None or width is not None:
            from repro.models.cnn import CNNConfig, specs_for
            cfg = CNNConfig(arch=name, in_hw=in_hw or 224,
                            width=width if width is not None else 1.0)
            specs = specs_for(cfg)
        else:
            specs = WORKLOADS[name]()
        return [GemmLayer.from_conv(s) for s in specs]
    from repro.configs import registry
    arch = registry.get(name)
    cfg = arch.smoke if (smoke and arch.smoke is not None) else arch.model
    return lm_gemm_layers(cfg, seq_len)


def decode_step_layers(name: str, batch: int = 1, max_seq: int = 64,
                       smoke: bool = True):
    """(layers, StepSpec) for one autoregressive decode step.

    The layer list is the ordinary GEMM walk at ``m = batch`` (one
    token per sequence); the :class:`~repro.compiler.program.StepSpec`
    carries the glue geometry (family, attention heads, cache depth)
    that ``lower_network(step=...)`` needs to decorate the program with
    weight residency and KV-cache/state segments.
    """
    from repro.configs import registry
    from repro.compiler.program import StepSpec
    if name in WORKLOADS:
        raise ValueError(f"{name}: CNN workloads have no decode mode")
    arch = registry.get(name)
    if arch.module not in ("lm", "ssm", "hybrid"):
        raise ValueError(
            f"{name}: decode mode supports lm/ssm/hybrid archs, "
            f"not {arch.module}")
    cfg = arch.smoke if (smoke and arch.smoke is not None) else arch.model
    if getattr(cfg, "mla", None) is not None:
        raise ValueError(f"{name}: decode mode does not model MLA "
                         f"latent caches")
    has_attn = hasattr(cfg, "n_heads")
    spec = StepSpec(
        family=arch.module, batch=batch, max_seq=max_seq,
        d_model=cfg.d_model,
        n_heads=cfg.n_heads if has_attn else 0,
        n_kv_heads=cfg.n_kv_heads if has_attn else 0,
        head_dim=cfg.head_dim if has_attn else 0)
    return lm_gemm_layers(cfg, batch), spec


def list_networks() -> list[str]:
    from repro.configs import registry
    return sorted(WORKLOADS) + registry.list_archs()
