import sys

from repro.compiler.cli import main

sys.exit(main())
